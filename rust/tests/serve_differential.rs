//! The decode differential: the serving path must be a bit-exact
//! restatement of the training forward (PR: serving engine).
//!
//! Property under test, stated once: for any prefix, pool size,
//! `min_ops` threshold, arch (llama + gpt2), prefill/decode split, and
//! batch composition, the logits the KV-cache decoder produces at
//! position `t` are bit-identical to row `t` of the training-kernel
//! forward over the same prefix — and therefore a request's sampled
//! tokens are a pure function of (weights, prompt, sampling config,
//! seed), not of scheduling.
//!
//! Own test binary (see Cargo.toml): it constructs worker pools
//! freely, which must not race the spawn-counter assertions in
//! `integration.rs`.

use scale_llm::parallel::WorkerPool;
use scale_llm::serve::{Decoder, Outcome, Request, ServeEngine, ServeModel};
use scale_llm::util::rng::Pcg;

/// Pool sizes the whole suite sweeps: inline, small, larger-than-work.
const POOLS: [usize; 3] = [0, 2, 7];

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{what}: lane {i}: {g:?} vs {w:?}");
    }
}

/// A deterministic prompt that touches a spread of token ids.
fn prompt(len: usize, vocab: usize, salt: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 13 + salt * 7 + 3) % vocab) as i32).collect()
}

fn greedy_req(id: &str, prompt: &[i32], max_new: usize) -> Request {
    Request {
        id: id.into(),
        prompt: prompt.to_vec(),
        max_new,
        temperature: 0.0,
        top_k: 0,
        top_p: 1.0,
        seed: 0,
        deadline_ms: 0,
    }
}

/// Single-stream generation against a bare [`Decoder`]: the reference
/// the engine's batched output must reproduce token for token.
fn solo_chain(model: &ServeModel, req: &Request, pool: &WorkerPool, min_ops: usize) -> Vec<i32> {
    let mut dec = Decoder::new(model);
    let mut rng = Pcg::new(req.seed);
    dec.extend(model, &req.prompt, pool, min_ops);
    let mut out = Vec::new();
    let mut last = dec.sample(req.temperature, req.top_k, req.top_p, &mut rng);
    out.push(last);
    while out.len() < req.max_new {
        dec.extend(model, &[last], pool, min_ops);
        last = dec.sample(req.temperature, req.top_k, req.top_p, &mut rng);
        out.push(last);
    }
    out
}

/// The tentpole property: token-by-token decode reproduces every row of
/// the training forward bitwise, for every pool size and both archs.
#[test]
fn decode_matches_training_forward_at_every_position() {
    for size in ["tiny", "tinyg"] {
        let model = ServeModel::init(size, 11).unwrap();
        let (len, v) = (model.max_seq(), model.vocab());
        let toks = prompt(len, v, 0);
        let oracle_pool = WorkerPool::new(0);
        let oracle = model.full_forward_logits(&toks, &oracle_pool, usize::MAX);
        assert_eq!(oracle.len(), len * v);
        for workers in POOLS {
            let pool = WorkerPool::new(workers);
            for min_ops in [1, usize::MAX] {
                let mut dec = Decoder::new(&model);
                for t in 0..len {
                    let row = dec.extend(&model, &toks[t..t + 1], &pool, min_ops);
                    assert_bits(
                        row,
                        &oracle[t * v..(t + 1) * v],
                        &format!("{size} pos {t} ({workers} workers, min_ops {min_ops})"),
                    );
                }
                assert_eq!(dec.pos(), len);
            }
        }
    }
}

/// Prefill-then-decode lands on the same bits as pure token-by-token,
/// wherever the split falls.
#[test]
fn prefill_split_is_invisible_in_the_bits() {
    for size in ["tiny", "tinyg"] {
        let model = ServeModel::init(size, 5).unwrap();
        let (len, v) = (model.max_seq(), model.vocab());
        let toks = prompt(len, v, 1);
        let pool = WorkerPool::new(2);
        let oracle = model.full_forward_logits(&toks, &pool, usize::MAX);
        for split in [1, 2, len / 2, len - 1, len] {
            let mut dec = Decoder::new(&model);
            let row = dec.extend(&model, &toks[..split], &pool, 1);
            assert_bits(
                row,
                &oracle[(split - 1) * v..split * v],
                &format!("{size} prefill({split}) last row"),
            );
            for t in split..len {
                let row = dec.extend(&model, &toks[t..t + 1], &pool, 1);
                assert_bits(
                    row,
                    &oracle[t * v..(t + 1) * v],
                    &format!("{size} prefill({split}) then pos {t}"),
                );
            }
        }
    }
}

/// The oracle itself is prefix-stable: truncating the prefix does not
/// change the rows it shares with the longer run (causality check on
/// the training forward, so the differential above is meaningful).
#[test]
fn oracle_rows_are_prefix_stable() {
    let model = ServeModel::init("tiny", 9).unwrap();
    let (len, v) = (model.max_seq(), model.vocab());
    let toks = prompt(len, v, 2);
    let pool = WorkerPool::new(0);
    let full = model.full_forward_logits(&toks, &pool, usize::MAX);
    for k in [1, 3, len / 2, len - 1] {
        let short = model.full_forward_logits(&toks[..k], &pool, usize::MAX);
        assert_bits(&short, &full[..k * v], &format!("oracle prefix {k}"));
    }
}

/// `Decoder::reset` really forgets: a reused slab replays a different
/// sequence bit-identically to a fresh one.
#[test]
fn reset_slab_replays_like_fresh() {
    let model = ServeModel::init("tiny", 2).unwrap();
    let pool = WorkerPool::new(2);
    let a = greedy_req("a", &prompt(5, model.vocab(), 3), 6);
    let b = greedy_req("b", &prompt(3, model.vocab(), 4), 6);
    let fresh = solo_chain(&model, &b, &pool, 1);
    let mut dec = Decoder::new(&model);
    let mut rng = Pcg::new(a.seed);
    dec.extend(&model, &a.prompt, &pool, 1);
    dec.sample(a.temperature, a.top_k, a.top_p, &mut rng);
    dec.reset();
    assert_eq!(dec.pos(), 0);
    let mut rng = Pcg::new(b.seed);
    dec.extend(&model, &b.prompt, &pool, 1);
    let mut got = vec![dec.sample(b.temperature, b.top_k, b.top_p, &mut rng)];
    while got.len() < b.max_new {
        let last = *got.last().unwrap();
        dec.extend(&model, &[last], &pool, 1);
        got.push(dec.sample(b.temperature, b.top_k, b.top_p, &mut rng));
    }
    assert_eq!(got, fresh, "a reset slab must not leak its previous sequence");
}

/// Continuous batching with ragged lengths and mid-flight admission:
/// every request's tokens are bit-identical to its solo run, for every
/// pool size — scheduling is invisible in the output.
#[test]
fn ragged_batches_match_solo_runs_bitwise() {
    let model = ServeModel::init("tiny", 7).unwrap();
    let v = model.vocab();
    let reqs = vec![
        greedy_req("a", &prompt(3, v, 0), 5),
        greedy_req("b", &prompt(2, v, 1), 7),
        greedy_req("c", &prompt(1, v, 2), 3),
        greedy_req("d", &prompt(4, v, 3), 1),
        greedy_req("e", &prompt(6, v, 4), 4),
    ];
    let ref_pool = WorkerPool::new(0);
    let solo: Vec<(String, Vec<i32>)> = reqs
        .iter()
        .map(|r| (r.id.clone(), solo_chain(&model, r, &ref_pool, usize::MAX)))
        .collect();
    for workers in POOLS {
        // max_batch 2 against 5 ragged requests: c/d/e are admitted
        // mid-flight as a/b/... finish — the continuous-batching path
        let mut engine = ServeEngine::new(&model, 2);
        engine.set_exec(WorkerPool::new(workers), 1);
        for r in &reqs {
            engine.submit(r.clone()).unwrap();
        }
        let mut guard = 0;
        while !engine.idle() {
            engine.step();
            guard += 1;
            assert!(guard < 1000, "scheduler failed to drain");
        }
        let mut done = engine.take_finished();
        assert_eq!(done.len(), reqs.len());
        done.sort_by(|x, y| x.id.cmp(&y.id));
        for (c, (id, want)) in done.iter().zip(&solo) {
            assert_eq!(&c.id, id);
            assert_eq!(c.outcome, Outcome::Ok);
            assert_eq!(&c.tokens, want, "{id} ({workers} workers): batched != solo");
        }
    }
}

/// Seeded top-k/top-p sampling is bit-identical across pool sizes and
/// invariant to which batch slot the request lands in.
#[test]
fn seeded_sampling_is_slot_and_pool_invariant() {
    let model = ServeModel::init("tiny", 4).unwrap();
    let v = model.vocab();
    let sampled = Request {
        id: "s".into(),
        prompt: prompt(3, v, 5),
        max_new: 6,
        temperature: 0.8,
        top_k: 8,
        top_p: 0.9,
        seed: 42,
        deadline_ms: 0,
    };
    let ref_pool = WorkerPool::new(0);
    let want = solo_chain(&model, &sampled, &ref_pool, usize::MAX);
    // same seed, same draws — and a different seed actually diverges
    assert_eq!(want, solo_chain(&model, &sampled, &ref_pool, usize::MAX));
    let other = Request { seed: 43, ..sampled.clone() };
    assert_ne!(want, solo_chain(&model, &other, &ref_pool, usize::MAX));
    for workers in POOLS {
        // filler admitted first so the sampled request lands in slot 1
        let mut engine = ServeEngine::new(&model, 3);
        engine.set_exec(WorkerPool::new(workers), 1);
        engine.submit(greedy_req("filler", &prompt(2, v, 6), 8)).unwrap();
        engine.submit(sampled.clone()).unwrap();
        engine.submit(greedy_req("tail", &prompt(1, v, 7), 2)).unwrap();
        while !engine.idle() {
            engine.step();
        }
        let done = engine.take_finished();
        let got = done.iter().find(|c| c.id == "s").expect("sampled request finished");
        assert_eq!(got.outcome, Outcome::Ok);
        assert_eq!(got.tokens, want, "slot/pool changed seeded draws ({workers} workers)");
    }
}

/// Greedy decoding is exact argmax over the decode logits (which the
/// differential above ties to the training forward).
#[test]
fn greedy_is_exact_argmax_over_decode_logits() {
    let model = ServeModel::init("tinyg", 6).unwrap();
    let v = model.vocab();
    let toks = prompt(4, v, 8);
    let pool = WorkerPool::new(2);
    let mut dec = Decoder::new(&model);
    let mut rng = Pcg::new(0);
    let mut last = {
        let row = dec.extend(&model, &toks, &pool, 1);
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0 as i32;
        let got = dec.sample(0.0, 0, 1.0, &mut rng);
        assert_eq!(got, argmax);
        got
    };
    for _ in 0..6 {
        let row = dec.extend(&model, &[last], &pool, 1);
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .unwrap()
            .0 as i32;
        last = dec.sample(0.0, 0, 1.0, &mut rng);
        assert_eq!(last, argmax, "greedy must be exact argmax at every step");
    }
}

/// Engine-level validation: unservable requests are refused with the
/// typed `Invalid` error before touching a slab.
#[test]
fn invalid_requests_are_refused_typed() {
    use scale_llm::serve::RequestError;
    let model = ServeModel::init("tiny", 0).unwrap();
    let mut engine = ServeEngine::new(&model, 2);
    let v = model.vocab() as i32;
    let cap = model.max_seq();
    let base = greedy_req("x", &[1, 2], 4);
    let cases: Vec<Request> = vec![
        Request { prompt: vec![], ..base.clone() },
        Request { max_new: 0, ..base.clone() },
        Request { prompt: vec![v], ..base.clone() },
        Request { prompt: vec![-1], ..base.clone() },
        Request { max_new: cap, ..base.clone() },
        Request { temperature: f32::NAN, ..base.clone() },
        Request { temperature: -1.0, ..base.clone() },
        Request { top_p: 0.0, ..base.clone() },
        Request { top_p: 1.5, ..base.clone() },
    ];
    for req in cases {
        match engine.submit(req.clone()) {
            Err(RequestError::Invalid(_)) => {}
            other => panic!("{req:?} -> {other:?}, want Invalid"),
        }
    }
    assert!(engine.idle(), "refused requests must never occupy the engine");
    engine.submit(base).unwrap();
    while !engine.idle() {
        engine.step();
    }
    assert_eq!(engine.take_finished().len(), 1, "engine must stay usable after refusals");
}
