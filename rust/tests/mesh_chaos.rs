//! Mesh chaos suite: multi-process rank-failure drills for the
//! `mesh` supervisor/worker stack. The properties pinned here are the
//! PR's acceptance bar:
//!
//! - a 2- and 4-rank mesh run is **bit-identical** (params, optimizer
//!   state, final ppl) to a single-process run with `shards = ranks`,
//!   for inline and threaded reduction pools;
//! - a rank killed at step k (`rank_exit` failpoint) is respawned and
//!   the run, replayed from the newest snapshot, finishes bit-exact;
//! - a CRC-corrupted gradient frame (`frame_corrupt`) is rejected and
//!   re-requested without changing any result;
//! - a stalled rank (`frame_delay` past the read timeout) is detected
//!   as a hang, respawned, and the run still finishes bit-exact;
//! - an exhausted respawn budget surfaces as typed
//!   [`TrainError::Mesh`] — never a hang.
//!
//! Workers are real forked processes of the `scale` binary
//! (`CARGO_BIN_EXE_scale`); their failpoints arrive via `--faults` on
//! the *initial* spawn only, so a respawned worker never re-arms its
//! own killer. Supervisor-side faults (`conn_drop`) are armed in this
//! process through the global registry, hence the serialization lock.

use scale_llm::coordinator::{TrainError, TrainOptions, Trainer};
use scale_llm::fault;
use scale_llm::mesh::{self, MeshOptions};
use scale_llm::parallel;
use scale_llm::runtime::Engine;
use scale_llm::util::lock::StableMutex;

static LOCK: StableMutex<()> = StableMutex::new(());

struct FaultGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl Drop for FaultGuard<'_> {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn guard() -> FaultGuard<'static> {
    let g = LOCK.lock();
    fault::clear();
    FaultGuard(g)
}

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Engine plus the smallest trainable size its manifest offers.
fn engine() -> Option<(Engine, String)> {
    let eng = match Engine::new(artifacts_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping mesh chaos test (run `make artifacts`): {e}");
            return None;
        }
    };
    for s in ["tiny", "s60m"] {
        if eng.manifest.sizes.contains_key(s) {
            return Some((eng, s.to_string()));
        }
    }
    eprintln!("skipping mesh chaos test (no smoke-able size in manifest)");
    None
}

fn opts(size: &str, steps: usize, shards: usize) -> TrainOptions {
    TrainOptions {
        size: size.into(),
        optimizer: "scale".into(),
        steps,
        base_lr: 1e-2,
        schedule: None,
        shards,
        seed: 0,
        eval_every: 0,
        eval_batches: 2,
        log_every: 0,
        quiet: true,
    }
}

/// Mesh options aimed at the test binary's own artifacts, with the
/// worker binary resolved by Cargo (the test executable is not `scale`).
fn mesh_opts(size: &str, steps: usize, ranks: usize, name: &str) -> MeshOptions {
    let mut o = MeshOptions::new(opts(size, steps, ranks), ranks);
    o.artifacts = artifacts_dir().to_string_lossy().into_owned();
    o.worker_bin = Some(env!("CARGO_BIN_EXE_scale").into());
    o.ckpt_dir = std::env::temp_dir().join(format!("scale_mesh_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&o.ckpt_dir).ok();
    o
}

fn tensor_bits(ts: &[scale_llm::runtime::Tensor]) -> Vec<u32> {
    ts.iter().flat_map(|t| t.f32s().iter().map(|x| x.to_bits())).collect()
}

/// Single-process reference with the same shard count; returns
/// (trainer, final ppl).
fn reference(eng: &Engine, size: &str, steps: usize, shards: usize) -> (Trainer<'_>, f64) {
    let mut tr = Trainer::new(eng, opts(size, steps, shards)).unwrap();
    let ppl = tr.train().unwrap();
    (tr, ppl)
}

fn assert_mesh_matches(
    tr: &Trainer<'_>,
    ppl: f64,
    want: &Trainer<'_>,
    want_ppl: f64,
    what: &str,
) {
    assert_eq!(tensor_bits(&tr.params), tensor_bits(&want.params), "{what}: params");
    assert_eq!(tensor_bits(&tr.state), tensor_bits(&want.state), "{what}: optimizer state");
    assert_eq!(ppl.to_bits(), want_ppl.to_bits(), "{what}: final ppl");
}

/// Leg one of the tentpole: an N-rank mesh over real processes and a
/// CRC-framed TCP wire lands on the same bits as the in-process shards
/// loop — for 2 and 4 ranks, and (2 ranks) with the reduction forced
/// onto the threaded pool path.
#[test]
fn mesh_matches_single_process_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    for ranks in [2usize, 4] {
        let (want, want_ppl) = reference(&eng, &sz, 6, ranks);
        let mo = mesh_opts(&sz, 6, ranks, &format!("ident{ranks}"));
        let (tr, report) = mesh::train(&eng, &mo).unwrap();
        assert_mesh_matches(&tr, report.ppl, &want, want_ppl, &format!("{ranks} ranks"));
        assert_eq!(report.respawns, 0);
        assert_eq!(report.frame_retries, 0);
        std::fs::remove_dir_all(&mo.ckpt_dir).ok();
    }
    // pool-threshold independence: force even tiny tensors onto the
    // threaded reduction path — bits must not move
    let (want, want_ppl) = reference(&eng, &sz, 6, 2);
    let mo = mesh_opts(&sz, 6, 2, "identpool");
    parallel::set_min_ops_override(Some(1));
    let got = mesh::train(&eng, &mo);
    parallel::set_min_ops_override(None);
    let (tr, report) = got.unwrap();
    assert_mesh_matches(&tr, report.ppl, &want, want_ppl, "2 ranks, forced pool");
    std::fs::remove_dir_all(&mo.ckpt_dir).ok();
}

/// Kill rank 1 the moment it receives its 5th Step: the supervisor
/// respawns it (clean — the spec must not re-arm) and replays from the
/// step-4 snapshot, finishing bit-identical to a run that never died.
#[test]
fn kill_rank_at_step_k_resumes_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let (want, want_ppl) = reference(&eng, &sz, 8, 2);
    let mut mo = mesh_opts(&sz, 8, 2, "kill");
    mo.checkpoint_every = 2;
    mo.heartbeat_every = 0;
    mo.worker_faults = vec![(1, "rank_exit@5".into())];
    let (tr, report) = mesh::train(&eng, &mo).unwrap();
    assert_mesh_matches(&tr, report.ppl, &want, want_ppl, "killed rank");
    assert_eq!(report.respawns, 1, "exactly one respawn");
    assert_eq!(report.frame_retries, 0);
    std::fs::remove_dir_all(&mo.ckpt_dir).ok();
}

/// Deterministic view of the per-step metrics log: everything except
/// the wall-clock column, with the floats as raw bits.
fn metric_bits(tr: &Trainer<'_>) -> Vec<(usize, u64, u64, u64)> {
    tr.metrics
        .steps
        .iter()
        .map(|s| (s.step, s.loss.to_bits(), s.lr.to_bits(), s.tokens))
        .collect()
}

/// Tentpole leg: `--shard-state` moves optimizer-state ownership and
/// the update itself out to the ranks, yet a 2- and 4-rank sharded
/// mesh must land on the same bits as the single-process shards loop
/// — params, optimizer state (gathered at end of run), and final ppl.
#[test]
fn sharded_mesh_matches_single_process_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    for ranks in [2usize, 4] {
        let (want, want_ppl) = reference(&eng, &sz, 6, ranks);
        let mut mo = mesh_opts(&sz, 6, ranks, &format!("shard{ranks}"));
        mo.shard_state = true;
        let (tr, report) = mesh::train(&eng, &mo).unwrap();
        assert_mesh_matches(&tr, report.ppl, &want, want_ppl, &format!("{ranks} sharded ranks"));
        assert_eq!(metric_bits(&tr), metric_bits(&want), "{ranks} sharded ranks: metrics");
        assert_eq!(report.respawns, 0);
        assert_eq!(report.frame_retries, 0);
        std::fs::remove_dir_all(&mo.ckpt_dir).ok();
    }
}

/// Frontier optimizers ride the sharded-mesh contract unchanged: a
/// 2-rank `--shard-state` run with a partial-momentum plan
/// (`adapm_first_last`) and a momentum-norm plan (`adams`) is
/// bit-identical to the single-process shards loop — the shard plan
/// partitions the new state specs exactly like SCALE's.
#[test]
fn frontier_sharded_mesh_matches_single_process_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    for (opt, lr) in [("adapm_first_last", 1e-2), ("adams", 1e-3)] {
        let mut o = opts(&sz, 6, 2);
        o.optimizer = opt.into();
        o.base_lr = lr;
        let mut want = Trainer::new(&eng, o.clone()).unwrap();
        let want_ppl = want.train().unwrap();
        let mut mo = mesh_opts(&sz, 6, 2, &format!("frontier_{opt}"));
        mo.train = o;
        mo.shard_state = true;
        let (tr, report) = mesh::train(&eng, &mo).unwrap();
        assert_mesh_matches(&tr, report.ppl, &want, want_ppl, &format!("{opt} sharded"));
        assert_eq!(report.respawns, 0, "{opt}");
        assert_eq!(report.frame_retries, 0, "{opt}");
        std::fs::remove_dir_all(&mo.ckpt_dir).ok();
    }
}

/// Kill a shard-owning rank mid-run: rank 1 dies on its 5th Step, its
/// replacement starts with zeroed state, and recovery must re-seed
/// every rank's shard from the newest complete sharded snapshot
/// (`step_*.d/`) before replaying. Params, optimizer state, final ppl
/// AND the per-step metrics log finish bit-identical to a run that
/// never died — the replayed steps overwrite their truncated records
/// with the same bits.
#[test]
fn sharded_kill_rank_restores_shard_state_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let (want, want_ppl) = reference(&eng, &sz, 8, 2);
    let mut mo = mesh_opts(&sz, 8, 2, "shardkill");
    mo.shard_state = true;
    mo.checkpoint_every = 2;
    mo.heartbeat_every = 0;
    mo.worker_faults = vec![(1, "rank_exit@5".into())];
    let (tr, report) = mesh::train(&eng, &mo).unwrap();
    assert_mesh_matches(&tr, report.ppl, &want, want_ppl, "killed sharded rank");
    assert_eq!(metric_bits(&tr), metric_bits(&want), "killed sharded rank: metrics");
    assert_eq!(report.respawns, 1, "exactly one respawn");
    std::fs::remove_dir_all(&mo.ckpt_dir).ok();
}

/// Rank 0's 3rd wire send (= its step-2 Grads; Hello was send #1) goes
/// out with a flipped payload byte. The CRC check must reject it, the
/// supervisor must re-request, and the re-encoded frame must leave
/// every result bit-identical — no respawn, no rollback.
#[test]
fn corrupt_frame_is_rejected_and_rerequested_without_changing_results() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let (want, want_ppl) = reference(&eng, &sz, 5, 2);
    let mut mo = mesh_opts(&sz, 5, 2, "crc");
    mo.heartbeat_every = 0;
    mo.worker_faults = vec![(0, "frame_corrupt@3".into())];
    let (tr, report) = mesh::train(&eng, &mo).unwrap();
    assert_mesh_matches(&tr, report.ppl, &want, want_ppl, "corrupt frame");
    assert_eq!(report.frame_retries, 1, "exactly one CRC reject + resend");
    assert_eq!(report.respawns, 0, "a recoverable frame error must not burn a respawn");
    std::fs::remove_dir_all(&mo.ckpt_dir).ok();
}

/// Rank 1 stalls 1500 ms before its step-2 Grads while the supervisor
/// reads with an 800 ms timeout: the hang is detected, the rank is
/// respawned, and the replayed run finishes bit-exact.
#[test]
fn slow_rank_times_out_and_recovery_is_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let (want, want_ppl) = reference(&eng, &sz, 5, 2);
    let mut mo = mesh_opts(&sz, 5, 2, "slow");
    mo.heartbeat_every = 0;
    mo.checkpoint_every = 2;
    mo.read_timeout_ms = 800; // frame_delay sleeps 1500 ms
    mo.worker_faults = vec![(1, "frame_delay@3".into())];
    let (tr, report) = mesh::train(&eng, &mo).unwrap();
    assert_mesh_matches(&tr, report.ppl, &want, want_ppl, "slow rank");
    assert_eq!(report.respawns, 1, "a hang is a rank failure, not a retryable frame");
    std::fs::remove_dir_all(&mo.ckpt_dir).ok();
}

/// With a zero respawn budget, a dying rank must surface as the typed
/// `TrainError::Mesh` — promptly, with the fleet torn down, never as a
/// hang (the step exchange is strict request-response, so EOF is
/// observed on the next read).
#[test]
fn exhausted_respawn_budget_is_a_typed_mesh_error() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let mut mo = mesh_opts(&sz, 4, 2, "budget");
    mo.heartbeat_every = 0;
    mo.max_respawns = 0;
    mo.worker_faults = vec![(1, "rank_exit@2".into())];
    let err = mesh::train(&eng, &mo).unwrap_err();
    assert!(matches!(err, TrainError::Mesh(_)), "want Mesh, got {err}");
    assert!(err.to_string().contains("respawn budget"), "{err}");
    std::fs::remove_dir_all(&mo.ckpt_dir).ok();
}

/// Supervisor-side chaos: from its 3rd wire send onward, every frame
/// the supervisor tries to write is dropped (`conn_drop` armed in this
/// process). Both ranks fail their step-2 broadcast; the budget covers
/// one respawn, the second failure must exhaust it into a typed Mesh
/// error instead of a respawn storm.
#[test]
fn supervisor_side_conn_drop_degrades_to_typed_error() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let mut mo = mesh_opts(&sz, 4, 2, "conndrop");
    mo.heartbeat_every = 0;
    mo.max_respawns = 1;
    mo.backoff_base_ms = 1; // keep the single respawn quick
    fault::configure("conn_drop@3..").unwrap();
    let err = mesh::train(&eng, &mo).unwrap_err();
    fault::clear();
    assert!(matches!(err, TrainError::Mesh(_)), "want Mesh, got {err}");
    std::fs::remove_dir_all(&mo.ckpt_dir).ok();
}
