//! Differential suite for the concurrent sweep engine: trials
//! dispatched on a worker pool must produce bit-identical `SweepPoint`
//! vectors to the sequential reference loop — for every pool size,
//! including diverged trials slotted as `ppl = inf` — with zero thread
//! spawns outside pre-built pools.
//!
//! This lives in its own test target (cargo runs test binaries one at a
//! time) so the explicit `WorkerPool` constructions here can never race
//! `integration.rs`'s process-global spawn-counter assertions.

use scale_llm::coordinator::sweep::{lr_sweep, SweepPoint, SweepSpec};
use scale_llm::coordinator::TrainOptions;
use scale_llm::parallel::{self, WorkerPool};
use scale_llm::runtime::Engine;

/// Engine plus the smallest trainable size its manifest offers.
fn engine() -> Option<(Engine, String)> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let eng = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping sweep test (run `make artifacts`): {e}");
            return None;
        }
    };
    for s in ["tiny", "s60m"] {
        if eng.manifest.sizes.contains_key(s) {
            return Some((eng, s.to_string()));
        }
    }
    eprintln!("skipping sweep test (no smoke-able size in manifest)");
    None
}

fn base(size: &str, optimizer: &str, steps: usize) -> TrainOptions {
    TrainOptions {
        size: size.into(),
        optimizer: optimizer.into(),
        steps,
        base_lr: 1e-2,
        schedule: None,
        shards: 2,
        seed: 0,
        eval_every: 0,
        eval_batches: 2,
        log_every: 0,
        quiet: true,
    }
}

/// Bit-level comparison: f64 fields by `to_bits` so deterministic
/// non-finite slots (inf, and any NaN ema a diverged run produced)
/// compare exactly too.
fn assert_points_bit_identical(got: &[SweepPoint], want: &[SweepPoint], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: trial count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.optimizer, w.optimizer, "{what}: trial {i} optimizer");
        assert_eq!(g.lr.to_bits(), w.lr.to_bits(), "{what}: trial {i} lr");
        assert_eq!(g.seed, w.seed, "{what}: trial {i} seed");
        assert_eq!(g.ppl.to_bits(), w.ppl.to_bits(), "{what}: trial {i} ppl");
        assert_eq!(
            g.final_loss_ema.to_bits(),
            w.final_loss_ema.to_bits(),
            "{what}: trial {i} final_loss_ema"
        );
        assert_eq!(g.diverged, w.diverged, "{what}: trial {i} diverged");
        assert_eq!(g.outcome, w.outcome, "{what}: trial {i} outcome");
        assert_eq!(g.attempts, w.attempts, "{what}: trial {i} attempts");
    }
}

#[test]
fn sweep_concurrent_is_bit_identical_to_serial_and_spawn_free() {
    let Some((eng, sz)) = engine() else { return };
    // 2 optimizers x 3 LRs x 2 seeds; the 1e12 trials diverge, so the
    // inf slotting is exercised at every pool size
    let mut spec = SweepSpec::lr_grid(base(&sz, "scale", 3), &[1e-3, 1e-2, 1e12]);
    spec.optimizers = vec!["scale".into(), "adam".into()];
    spec.seeds = vec![0, 1];

    let want = spec.run_serial(&eng).expect("serial sweep");
    assert_eq!(want.len(), 12);
    assert!(
        want.iter().any(|p| p.diverged && p.ppl == f64::INFINITY),
        "the 1e12 trials must land in the ppl = inf slot"
    );
    assert!(want.iter().any(|p| !p.diverged), "sane LRs must converge");

    // all pool construction happens before the spawn snapshot; the
    // shared pool is warmed by a full run so its lazy init (and the
    // threshold calibration) is outside the gated region
    let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(7)];
    let shared_first = spec.run(&eng).expect("shared-pool sweep");
    let spawned = parallel::threads_spawned();
    for pool in &pools {
        let got = spec.run_on(&eng, pool).expect("concurrent sweep");
        assert_points_bit_identical(&got, &want, &format!("{} workers", pool.workers()));
    }
    let shared_again = spec.run(&eng).expect("shared-pool sweep (second run)");
    // the memory cap chunks trials into waves; results must not move
    let mut capped = spec.clone();
    capped.max_concurrent = 2;
    let capped_pts = capped.run(&eng).expect("capped sweep");
    assert_eq!(
        parallel::threads_spawned(),
        spawned,
        "sweeps must never spawn threads outside pre-built pools"
    );
    assert_points_bit_identical(&shared_first, &want, "shared pool (first run)");
    assert_points_bit_identical(&shared_again, &want, "shared pool (second run)");
    assert_points_bit_identical(&capped_pts, &want, "max_concurrent = 2");
}

#[test]
fn lr_sweep_entry_point_matches_sequential_reference() {
    let Some((eng, sz)) = engine() else { return };
    let b = base(&sz, "scale", 2);
    let grid = [5e-3, 1e-2, 3e-2];
    let spec = SweepSpec::lr_grid(b.clone(), &grid);
    let want = spec.run_serial(&eng).expect("serial reference");
    let got = lr_sweep(&eng, &b, &grid).expect("lr_sweep");
    assert_points_bit_identical(&got, &want, "lr_sweep");
    // slotting preserves grid order regardless of completion order
    let lrs: Vec<f64> = got.iter().map(|p| p.lr).collect();
    assert_eq!(lrs, grid.to_vec());
}

/// Frontier leg: the partial-momentum and momentum-norm optimizers ride
/// the same concurrent==serial contract as the rest of the zoo — bit
/// for bit, for every pool size.
#[test]
fn frontier_optimizer_sweep_is_bit_identical_to_serial() {
    let Some((eng, sz)) = engine() else { return };
    let mut spec = SweepSpec::lr_grid(base(&sz, "adams", 3), &[1e-3, 1e-2]);
    spec.optimizers = vec!["adams".into(), "adapm_first_last".into()];
    spec.seeds = vec![0, 1];

    let want = spec.run_serial(&eng).expect("serial frontier sweep");
    assert_eq!(want.len(), 8);
    let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(7)];
    for pool in &pools {
        let got = spec.run_on(&eng, pool).expect("concurrent frontier sweep");
        assert_points_bit_identical(&got, &want, &format!("frontier {} workers", pool.workers()));
    }
}

/// The frontier zoo trains finitely end to end at its tuned default
/// LRs, and `adapm_last` — same LR, same seed, same plan — lands on
/// exactly SCALE's perplexity bits: the policy axis generalizes the
/// hardcoded table all the way through a real training run.
#[test]
fn frontier_zoo_trains_finite_and_adapm_last_is_scale() {
    let Some((eng, sz)) = engine() else { return };
    let frontier =
        ["adapm_last", "adapm_first_last", "adapm_embed_head", "adapm_top2", "adams"];
    let mut spec = SweepSpec::optimizer_grid(base(&sz, "scale", 2), &frontier);
    spec.lr_for = Some(scale_llm::harness::default_lr);
    let pts = spec.run(&eng).expect("frontier zoo sweep");
    assert_eq!(pts.len(), 5);
    for p in &pts {
        assert!(
            p.ppl.is_finite() && !p.diverged,
            "{}: frontier rule diverged at its tuned default LR",
            p.optimizer
        );
    }
    let scale_spec = SweepSpec::optimizer_grid(base(&sz, "scale", 2), &["scale"]);
    let scale_pts = scale_spec.run(&eng).expect("scale reference");
    assert_eq!(pts[0].optimizer, "adapm_last");
    assert_eq!(
        pts[0].ppl.to_bits(),
        scale_pts[0].ppl.to_bits(),
        "adapm_last must train bit-identically to scale"
    );
}

#[test]
fn optimizer_axis_sweep_runs_the_mix_rules_natively() {
    // the Table-13 acceptance path: SCALE plus all four mix_* ablations
    // as one optimizer-axis sweep, end to end on the native executor
    let Some((eng, sz)) = engine() else { return };
    let mixes = [
        "mix_col_last_row_rest",
        "mix_row_first_col_rest",
        "mix_larger_dim",
        "mix_row_last_col_rest",
    ];
    let missing = mixes
        .iter()
        .any(|o| eng.manifest.artifact(&format!("update_{o}_{sz}")).is_err());
    if missing {
        eprintln!("skipping mix sweep (manifest lacks mix_* update artifacts)");
        return;
    }
    let mut all = vec!["scale"];
    all.extend_from_slice(&mixes);
    let spec = SweepSpec::optimizer_grid(base(&sz, "scale", 2), &all);
    let pts = spec.run(&eng).expect("optimizer-axis sweep");
    assert_eq!(pts.len(), 5);
    assert_eq!(pts[0].optimizer, "scale");
    assert_eq!(pts[1].optimizer, "mix_col_last_row_rest");
    for p in &pts {
        assert!(
            p.ppl.is_finite() && !p.diverged,
            "{}: norm-bounded rule diverged at the shared tiny LR",
            p.optimizer
        );
    }
}
