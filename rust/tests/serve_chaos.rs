//! Serve chaos suite: deterministic fault injection against the
//! serving engine (PR: serving engine). The drilled contracts:
//!
//! - a malformed request line becomes a typed protocol error, never a
//!   panic (`req_malformed`, plus genuinely hostile bytes);
//! - a vanished client frees its KV slab for immediate reuse and does
//!   not perturb co-batched sequences bitwise (`client_drop`);
//! - an expired deadline evicts with the tokens generated so far and
//!   the surviving sequences finish bit-identical to their solo runs
//!   (`deadline` failpoint + a real wall-clock deadline).
//!
//! Own test binary (see Cargo.toml): the failpoint registry is
//! process-global, so these tests serialize on `LOCK` and leave the
//! registry cleared, exactly like `chaos.rs`.

use std::io::Cursor;

use scale_llm::fault;
use scale_llm::parallel::WorkerPool;
use scale_llm::serve::server::serve_conn;
use scale_llm::serve::{Decoder, Outcome, Request, ServeEngine, ServeModel};
use scale_llm::util::json;
use scale_llm::util::lock::StableMutex;
use scale_llm::util::rng::Pcg;

static LOCK: StableMutex<()> = StableMutex::new(());

struct FaultGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl Drop for FaultGuard<'_> {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn guard() -> FaultGuard<'static> {
    let g = LOCK.lock();
    fault::clear();
    FaultGuard(g)
}

fn greedy_req(id: &str, prompt: &[i32], max_new: usize) -> Request {
    Request {
        id: id.into(),
        prompt: prompt.to_vec(),
        max_new,
        temperature: 0.0,
        top_k: 0,
        top_p: 1.0,
        seed: 0,
        deadline_ms: 0,
    }
}

fn solo_chain(model: &ServeModel, req: &Request, pool: &WorkerPool) -> Vec<i32> {
    let mut dec = Decoder::new(model);
    let mut rng = Pcg::new(req.seed);
    dec.extend(model, &req.prompt, pool, 1);
    let mut out = vec![dec.sample(req.temperature, req.top_k, req.top_p, &mut rng)];
    while out.len() < req.max_new {
        let last = *out.last().unwrap();
        dec.extend(model, &[last], pool, 1);
        out.push(dec.sample(req.temperature, req.top_k, req.top_p, &mut rng));
    }
    out
}

fn drain(engine: &mut ServeEngine<'_>) {
    let mut guard = 0;
    while !engine.idle() {
        engine.step();
        guard += 1;
        assert!(guard < 1000, "scheduler failed to drain");
    }
}

/// Run the full serve loop over a canned byte stream and return the
/// response lines.
fn serve_lines(model: &ServeModel, input: &str) -> Vec<json::Json> {
    let mut engine = ServeEngine::new(model, 2);
    engine.set_exec(WorkerPool::new(2), 1);
    let mut out = Vec::new();
    serve_conn(&mut engine, Cursor::new(input.as_bytes().to_vec()), &mut out).unwrap();
    String::from_utf8(out)
        .unwrap()
        .lines()
        .map(|l| json::parse(l).unwrap_or_else(|e| panic!("unparseable response {l:?}: {e}")))
        .collect()
}

/// The `req_malformed` failpoint forces the malformed path on a valid
/// line: the server answers with a typed error and keeps serving.
#[test]
fn req_malformed_failpoint_rejects_typed_and_server_survives() {
    let _g = guard();
    let model = ServeModel::init("tiny", 3).unwrap();
    fault::configure("req_malformed@1").unwrap();
    let input = "{\"id\":\"a\",\"prompt\":[1,2],\"max_new\":2}\n\
                 {\"id\":\"b\",\"prompt\":[1,2],\"max_new\":2}\n";
    let lines = serve_lines(&model, input);
    assert_eq!(lines.len(), 2, "one error + one completion");
    assert_eq!(lines[0].get("status").unwrap().as_str(), Some("error"));
    assert_eq!(lines[0].get("kind").unwrap().as_str(), Some("malformed"));
    assert_eq!(lines[1].get("id").unwrap().as_str(), Some("b"));
    assert_eq!(lines[1].get("status").unwrap().as_str(), Some("ok"));
    assert_eq!(lines[1].get("tokens").unwrap().as_arr().unwrap().len(), 2);
}

/// Hostile bytes (truncated JSON, wrong types, out-of-vocab ids) all
/// come back as typed errors; the valid request among them is served.
#[test]
fn hostile_request_lines_never_panic() {
    let _g = guard();
    let model = ServeModel::init("tiny", 3).unwrap();
    let input = "not json at all\n\
                 {\"id\":7,\"prompt\":[1]}\n\
                 {\"id\":\"big\",\"prompt\":[999999],\"max_new\":1}\n\
                 \n\
                 {\"id\":\"good\",\"prompt\":[3],\"max_new\":1}\n";
    let lines = serve_lines(&model, input);
    assert_eq!(lines.len(), 4, "three errors + one completion (blank line skipped)");
    for l in &lines[..3] {
        assert_eq!(l.get("status").unwrap().as_str(), Some("error"));
    }
    assert_eq!(lines[2].get("kind").unwrap().as_str(), Some("invalid"));
    assert_eq!(lines[3].get("id").unwrap().as_str(), Some("good"));
    assert_eq!(lines[3].get("status").unwrap().as_str(), Some("ok"));
}

/// A dropped client is evicted with its partial tokens, its slab is
/// reused by the next admission, and the co-batched sequence finishes
/// bit-identical to a solo run.
#[test]
fn client_drop_frees_the_slab_and_spares_the_batch() {
    let _g = guard();
    let model = ServeModel::init("tiny", 2).unwrap();
    let pool = WorkerPool::new(2);
    let a = greedy_req("a", &[1, 2, 3], 6);
    let b = greedy_req("b", &[4, 5], 7);
    let c = greedy_req("c", &[6], 3);
    let solo_a = solo_chain(&model, &a, &pool);
    let solo_b = solo_chain(&model, &b, &pool);
    let solo_c = solo_chain(&model, &c, &pool);

    let mut engine = ServeEngine::new(&model, 2);
    engine.set_exec(WorkerPool::new(2), 1);
    engine.submit(a).unwrap();
    engine.submit(b).unwrap();
    // slot order is admission order, and the sweep consumes one
    // failpoint hit per slot: @1 targets slot 0 == request "a"
    fault::configure("client_drop@1").unwrap();
    engine.step();
    fault::clear();
    assert_eq!(engine.active(), 1, "a evicted, b decoding");
    engine.submit(c).unwrap();
    engine.step();
    assert_eq!(engine.active(), 2, "freed slab re-admitted c");
    drain(&mut engine);

    let mut done = engine.take_finished();
    done.sort_by(|x, y| x.id.cmp(&y.id));
    assert_eq!(done.len(), 3);
    assert_eq!(done[0].outcome, Outcome::Disconnected);
    assert!(!done[0].tokens.is_empty() && done[0].tokens.len() < 6);
    assert_eq!(done[0].tokens, solo_a[..done[0].tokens.len()], "partial tokens are a prefix");
    assert_eq!((done[1].outcome, &done[1].tokens), (Outcome::Ok, &solo_b));
    assert_eq!((done[2].outcome, &done[2].tokens), (Outcome::Ok, &solo_c));
}

/// The `deadline` failpoint evicts a slot as expired mid-generation;
/// its partial tokens ride along and the co-batched sequence is
/// bit-unaffected.
#[test]
fn deadline_failpoint_evicts_with_partial_tokens() {
    let _g = guard();
    let model = ServeModel::init("tiny", 8).unwrap();
    let pool = WorkerPool::new(2);
    let a = greedy_req("a", &[7, 8], 8);
    let b = greedy_req("b", &[9], 4);
    let solo_a = solo_chain(&model, &a, &pool);
    let solo_b = solo_chain(&model, &b, &pool);

    let mut engine = ServeEngine::new(&model, 2);
    engine.set_exec(WorkerPool::new(2), 1);
    engine.submit(a).unwrap();
    engine.submit(b).unwrap();
    engine.step(); // both admitted + one decode round, no faults
    fault::configure("deadline@1").unwrap();
    engine.step(); // sweep evicts slot 0 ("a") as expired
    fault::clear();
    drain(&mut engine);

    let mut done = engine.take_finished();
    done.sort_by(|x, y| x.id.cmp(&y.id));
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].outcome, Outcome::Deadline);
    assert_eq!(done[0].tokens.len(), 2, "prefill token + one decode round before eviction");
    assert_eq!(done[0].tokens, solo_a[..2], "partial tokens are a prefix of the solo run");
    assert_eq!((done[1].outcome, &done[1].tokens), (Outcome::Ok, &solo_b));
}

/// A real wall-clock deadline: the expired request is evicted without
/// stalling the engine, and the co-batched deadline-free request runs
/// to completion.
#[test]
fn wall_clock_deadline_expires_without_stalling_the_batch() {
    let _g = guard();
    let model = ServeModel::init("tiny", 1).unwrap();
    let pool = WorkerPool::new(2);
    let hurried = Request { deadline_ms: 1, ..greedy_req("hurried", &[1, 2], 12) };
    let steady = greedy_req("steady", &[3], 4);
    let solo_steady = solo_chain(&model, &steady, &pool);

    let mut engine = ServeEngine::new(&model, 2);
    engine.set_exec(WorkerPool::new(2), 1);
    engine.submit(hurried).unwrap();
    engine.submit(steady).unwrap();
    engine.step(); // admission stamps the 1ms deadline
    std::thread::sleep(std::time::Duration::from_millis(10));
    drain(&mut engine);

    let mut done = engine.take_finished();
    done.sort_by(|x, y| x.id.cmp(&y.id));
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].outcome, Outcome::Deadline, "1ms budget must expire");
    assert!(!done[0].tokens.is_empty() && done[0].tokens.len() < 12);
    assert_eq!((done[1].outcome, &done[1].tokens), (Outcome::Ok, &solo_steady));
}
