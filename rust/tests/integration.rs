//! Integration tests over the full stack: artifacts + runtime +
//! coordinator. Require `make artifacts` to have been run (the manifest
//! and HLO files must exist).

use scale_llm::coordinator::{Checkpoint, Schedule, TrainOptions, Trainer};
use scale_llm::runtime::{Engine, Tensor};

/// Full-stack tests need `make artifacts` plus a real PJRT backend
/// (`--features xla`); skip gracefully where either is missing so the
/// tier-1 suite stays green in artifact-less environments.
fn engine() -> Option<Engine> {
    if !cfg!(feature = "xla") {
        eprintln!("skipping integration test (needs --features xla to execute artifacts)");
        return None;
    }
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Engine::new(dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("skipping integration test (run `make artifacts`): {e}");
            None
        }
    }
}

fn opts(optimizer: &str, steps: usize) -> TrainOptions {
    TrainOptions {
        size: "s60m".into(),
        optimizer: optimizer.into(),
        steps,
        base_lr: 1e-2,
        schedule: None,
        shards: 2,
        seed: 0,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        quiet: true,
    }
}

#[test]
fn scale_training_reduces_loss() {
    let Some(eng) = engine() else { return };
    let mut tr = Trainer::new(&eng, opts("scale", 40)).unwrap();
    let first = tr.train_step().unwrap();
    for _ in 0..39 {
        tr.train_step().unwrap();
    }
    let last = tr.metrics.ema_loss.unwrap();
    assert!(
        last < first - 0.3,
        "loss should drop by >0.3 nats: first {first:.3} last {last:.3}"
    );
}

#[test]
fn eval_perplexity_finite_and_below_uniform() {
    let Some(eng) = engine() else { return };
    let mut tr = Trainer::new(&eng, opts("scale", 30)).unwrap();
    let ppl = tr.train().unwrap();
    let vocab = eng.manifest.size("s60m").unwrap().vocab as f64;
    assert!(ppl.is_finite() && ppl < vocab, "ppl {ppl} vs uniform {vocab}");
}

#[test]
fn fwd_bwd_loss_matches_eval_artifact() {
    // the two artifacts must agree on the loss for identical inputs
    let Some(eng) = engine() else { return };
    let tr = Trainer::new(&eng, opts("scale", 1)).unwrap();
    let w = tr.seq_len + 1;
    let b = tr.microbatch;
    let batch = Tensor::from_i32(&[b, w], (0..(b * w) as i32).map(|x| x % 100).collect());
    let (loss_fb, grads) = tr.grad_step(&batch).unwrap();
    assert_eq!(grads.len(), tr.params.len());
    let evl = eng.load("eval_s60m").unwrap();
    let mut inputs: Vec<&Tensor> = tr.params.iter().collect();
    inputs.push(&batch);
    let out = eng.run_exe_refs(&evl, &inputs).unwrap();
    let loss_ev = out[0].item_f32() as f64;
    assert!((loss_fb - loss_ev).abs() < 1e-5, "{loss_fb} vs {loss_ev}");
}

#[test]
fn ddp_shard_counts_agree_in_expectation() {
    // 1-shard vs 4-shard runs differ in batch content but both must train;
    // determinism within a configuration must be exact.
    let Some(eng) = engine() else { return };
    let mut o1 = opts("scale", 10);
    o1.shards = 4;
    let mut a = Trainer::new(&eng, o1.clone()).unwrap();
    let mut b = Trainer::new(&eng, o1).unwrap();
    for _ in 0..10 {
        a.train_step().unwrap();
        b.train_step().unwrap();
    }
    for (x, y) in a.params.iter().zip(&b.params) {
        assert_eq!(x.f32s(), y.f32s(), "same config must be bit-identical");
    }
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    let Some(eng) = engine() else { return };
    // run A: 8 straight steps
    let mut a = Trainer::new(&eng, opts("scale", 8)).unwrap();
    for _ in 0..8 {
        a.train_step().unwrap();
    }
    // run B: 4 steps, checkpoint, restore into fresh trainer, 4 more
    let mut b1 = Trainer::new(&eng, opts("scale", 8)).unwrap();
    for _ in 0..4 {
        b1.train_step().unwrap();
    }
    let path = std::env::temp_dir().join(format!("scale_it_{}.ckpt", std::process::id()));
    b1.checkpoint().unwrap().save(&path).unwrap();
    let mut b2 = Trainer::new(&eng, opts("scale", 8)).unwrap();
    b2.restore(&Checkpoint::load(&path).unwrap()).unwrap();
    assert_eq!(b2.step, 4);
    for _ in 0..4 {
        b2.train_step().unwrap();
    }
    std::fs::remove_file(path).ok();
    for (x, y) in a.params.iter().zip(&b2.params) {
        let xd = x.f32s();
        let yd = y.f32s();
        for (u, v) in xd.iter().zip(yd) {
            assert!((u - v).abs() < 1e-6, "resume drift: {u} vs {v}");
        }
    }
}

#[test]
fn restore_rejects_wrong_optimizer() {
    let Some(eng) = engine() else { return };
    let a = Trainer::new(&eng, opts("scale", 1)).unwrap();
    let ckpt = a.checkpoint().unwrap();
    let mut b = Trainer::new(&eng, opts("adam", 1)).unwrap();
    assert!(b.restore(&ckpt).is_err());
}

#[test]
fn scale_state_footprint_is_sgd_like() {
    // the paper's memory claim, measured on the real state buffers
    let Some(eng) = engine() else { return };
    let scale = Trainer::new(&eng, opts("scale", 1)).unwrap();
    let adam = Trainer::new(&eng, opts("adam", 1)).unwrap();
    let params = 4 * eng.manifest.size("s60m").unwrap().param_count;
    assert_eq!(adam.state_bytes(), 2 * params);
    assert!(scale.state_bytes() < adam.state_bytes() / 4);
}

#[test]
fn all_s130m_optimizers_execute_one_step() {
    // every lowered update artifact must run and produce finite params
    let Some(eng) = engine() else { return };
    for opt in eng.manifest.optimizers_for("s130m") {
        let mut o = opts(&opt, 1);
        o.size = "s130m".into();
        o.base_lr = 1e-3;
        let mut tr = Trainer::new(&eng, o).unwrap();
        tr.train_step().unwrap_or_else(|e| panic!("{opt}: {e}"));
        for p in &tr.params {
            assert!(
                p.f32s().iter().all(|x| x.is_finite()),
                "{opt} produced non-finite params"
            );
        }
    }
}

#[test]
fn update_artifact_matches_native_scale_rule() {
    // cross-layer parity: the L1 Pallas fused update inside
    // update_scale_s60m == the native Rust mirror, for the lm_head.
    let Some(eng) = engine() else { return };
    let tr = Trainer::new(&eng, opts("scale", 1)).unwrap();
    let info = eng.manifest.size("s60m").unwrap().clone();
    let head_idx = info.params.len() - 1;
    assert_eq!(info.params[head_idx].name, "lm_head");

    // build one update call by hand
    let mut rng = scale_llm::util::rng::Pcg::new(3);
    let grads: Vec<Tensor> = info
        .params
        .iter()
        .map(|p| {
            Tensor::from_f32(
                &p.shape,
                (0..p.numel()).map(|_| 0.1 * rng.normal() as f32).collect(),
            )
        })
        .collect();
    let lr = 0.01f32;
    let upd = eng.load("update_scale_s60m").unwrap();
    let lr_t = Tensor::scalar_f32(lr);
    let step_t = Tensor::scalar_f32(1.0);
    let mut inputs: Vec<&Tensor> = Vec::new();
    inputs.extend(tr.params.iter());
    inputs.extend(tr.state.iter());
    inputs.extend(grads.iter());
    inputs.push(&lr_t);
    inputs.push(&step_t);
    let out = eng.run_exe_refs(&upd, &inputs).unwrap();

    // native mirror for the head (momentum path, beta=0.9, m0=0)
    let (d_in, vocab) = (info.d_model, info.vocab);
    let mut p = tr.params[head_idx].f32s().to_vec();
    let mut m = vec![0f32; d_in * vocab];
    scale_llm::optim::rules::scale_momentum(
        &mut p,
        &mut m,
        grads[head_idx].f32s(),
        d_in,
        vocab,
        lr,
        0.9,
    );
    let got = out[head_idx].f32s();
    for (i, (a, b)) in got.iter().zip(&p).enumerate() {
        assert!((a - b).abs() < 1e-4, "head elem {i}: artifact {a} vs native {b}");
    }

    // and a hidden matrix (stateless colnorm path)
    let wq_idx = info.params.iter().position(|p| p.name == "block0.wq").unwrap();
    let mut pw = tr.params[wq_idx].f32s().to_vec();
    scale_llm::optim::rules::scale_plain(
        &mut pw,
        grads[wq_idx].f32s(),
        info.d_model,
        info.d_model,
        lr,
    );
    for (i, (a, b)) in out[wq_idx].f32s().iter().zip(&pw).enumerate() {
        assert!((a - b).abs() < 1e-4, "wq elem {i}: {a} vs {b}");
    }
}

#[test]
fn schedule_drives_update_magnitude() {
    // warmup means step 1 uses a tiny LR: params barely move
    let Some(eng) = engine() else { return };
    let mut o = opts("scale", 100);
    o.schedule = Some(Schedule::paper_default(1e-2, 100));
    let mut tr = Trainer::new(&eng, o).unwrap();
    let before = tr.params[0].f32s().to_vec();
    tr.train_step().unwrap();
    let after = tr.params[0].f32s();
    let delta: f32 = before
        .iter()
        .zip(after)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    // lr at step 1 = 1e-2/10 = 1e-3; colnorm bounds per-entry update by lr
    assert!(delta <= 1.1e-3, "max delta {delta}");
}

#[test]
fn gpt2_architecture_trains() {
    let Some(eng) = engine() else { return };
    let mut o = opts("scale", 12);
    o.size = "gpt2s".into();
    let mut tr = Trainer::new(&eng, o).unwrap();
    let first = tr.train_step().unwrap();
    for _ in 0..11 {
        tr.train_step().unwrap();
    }
    assert!(tr.metrics.ema_loss.unwrap() < first);
}

#[test]
fn varprobe_artifact_runs() {
    let Some(eng) = engine() else { return };
    let tr = Trainer::new(&eng, opts("scale", 1)).unwrap();
    let info = eng.manifest.size("s60m").unwrap();
    let w = info.seq_len + 1;
    let mb = eng.manifest.microbatch;
    let big = mb * eng.manifest.varprobe_big_factor;
    let probe = eng.load("varprobe_s60m").unwrap();
    let small_batch = Tensor::from_i32(&[mb, w], vec![1; mb * w]);
    let big_batch = Tensor::from_i32(&[big, w], vec![1; big * w]);
    let mut inputs: Vec<&Tensor> = tr.params.iter().collect();
    inputs.push(&small_batch);
    inputs.push(&big_batch);
    let out = eng.run_exe_refs(&probe, &inputs).unwrap();
    assert_eq!(out.len(), info.params.len());
    // identical small/big token content -> small but nonnegative variance
    for v in &out {
        assert!(v.item_f32() >= 0.0);
    }
}
