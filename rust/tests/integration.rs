//! Integration tests over the full stack: manifest + runtime +
//! coordinator. On the default build these run end-to-end on the native
//! CPU executor (no artifacts, no Python, no PJRT — the manifest
//! synthesizes) against the debug-fast `tiny` smoke size; with
//! `--features xla` + `make artifacts` they exercise the PJRT path
//! against `s60m` (real manifests only define the paper family) and
//! skip gracefully when artifacts are missing.
//!
//! Equality tolerances: the native executor is bit-deterministic per
//! seed by construction, so the determinism tests assert *bit* equality
//! there; the PJRT executor gets small float tolerances (its kernels
//! are a different lowering of the same math).

use scale_llm::coordinator::{Checkpoint, Schedule, TrainOptions, Trainer};
use scale_llm::memory::estimator::{measured_param_bytes, measured_state_bytes};
use scale_llm::runtime::{Engine, Tensor};

/// Engine plus the smallest trainable size its manifest offers.
fn engine() -> Option<(Engine, String)> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let eng = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping integration test (run `make artifacts`): {e}");
            return None;
        }
    };
    for s in ["tiny", "s60m"] {
        if eng.manifest.sizes.contains_key(s) {
            let size = s.to_string();
            return Some((eng, size));
        }
    }
    eprintln!("skipping integration test (no smoke-able size in manifest)");
    None
}

fn gpt2_size(eng: &Engine) -> Option<String> {
    for s in ["tinyg", "gpt2s"] {
        if eng.manifest.sizes.contains_key(s) {
            return Some(s.to_string());
        }
    }
    None
}

fn opts(size: &str, optimizer: &str, steps: usize) -> TrainOptions {
    TrainOptions {
        size: size.into(),
        optimizer: optimizer.into(),
        steps,
        base_lr: 1e-2,
        schedule: None,
        shards: 2,
        seed: 0,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        quiet: true,
    }
}

/// Exact on the native executor, small float tolerance on PJRT.
fn assert_close(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    if cfg!(feature = "xla") {
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((x - y).abs() < 1e-4, "{what}[{i}]: {x} vs {y}");
        }
    } else {
        assert_eq!(a, b, "{what}: must be bit-identical on the native executor");
    }
}

#[test]
fn training_reduces_loss() {
    // the end-to-end smoke: Trainer::train on the default build, loss
    // decreasing over 30 steps
    let Some((eng, sz)) = engine() else { return };
    let mut tr = Trainer::new(&eng, opts(&sz, "scale", 30)).unwrap();
    let first = tr.train_step().unwrap();
    for _ in 0..29 {
        tr.train_step().unwrap();
    }
    let last = tr.metrics.ema_loss.unwrap();
    assert!(last.is_finite() && first.is_finite());
    assert!(
        last < first - 0.02,
        "loss should decrease: first {first:.4} ema-last {last:.4}"
    );
}

#[test]
fn eval_perplexity_finite_and_below_uniform() {
    let Some((eng, sz)) = engine() else { return };
    let mut tr = Trainer::new(&eng, opts(&sz, "scale", 20)).unwrap();
    let ppl = tr.train().unwrap();
    let vocab = eng.manifest.size(&sz).unwrap().vocab as f64;
    assert!(ppl.is_finite() && ppl < vocab, "ppl {ppl} vs uniform {vocab}");
}

#[test]
fn fwd_bwd_loss_matches_eval_artifact() {
    // the two executables must agree on the loss for identical inputs
    let Some((eng, sz)) = engine() else { return };
    let tr = Trainer::new(&eng, opts(&sz, "scale", 1)).unwrap();
    let w = tr.seq_len + 1;
    let b = tr.microbatch;
    let vocab = eng.manifest.size(&sz).unwrap().vocab as i32;
    let batch = Tensor::from_i32(&[b, w], (0..(b * w) as i32).map(|x| x % vocab).collect());
    let (loss_fb, grads) = tr.grad_step(&batch).unwrap();
    assert_eq!(grads.len(), tr.params.len());
    for (i, g) in grads.iter().enumerate() {
        assert!(g.f32s().iter().all(|x| x.is_finite()), "grad {i} not finite");
    }
    let evl = eng.load(&format!("eval_{sz}")).unwrap();
    let mut inputs: Vec<&Tensor> = tr.params.iter().collect();
    inputs.push(&batch);
    let out = eng.run_exe_refs(&evl, &inputs).unwrap();
    let loss_ev = out[0].item_f32() as f64;
    let tol = if cfg!(feature = "xla") { 1e-5 } else { 1e-7 };
    assert!((loss_fb - loss_ev).abs() < tol, "{loss_fb} vs {loss_ev}");
}

#[test]
fn same_config_is_deterministic() {
    let Some((eng, sz)) = engine() else { return };
    let mut o = opts(&sz, "scale", 8);
    o.shards = 4;
    let mut a = Trainer::new(&eng, o.clone()).unwrap();
    let mut b = Trainer::new(&eng, o).unwrap();
    for _ in 0..8 {
        a.train_step().unwrap();
        b.train_step().unwrap();
    }
    for (p, (x, y)) in a.params.iter().zip(&b.params).enumerate() {
        // same config in the same process must agree exactly on either
        // executor (PJRT kernels are deterministic run-to-run too)
        assert_eq!(x.f32s(), y.f32s(), "param {p}: same config must match");
    }
    for (s, (x, y)) in a.state.iter().zip(&b.state).enumerate() {
        assert_eq!(x.f32s(), y.f32s(), "state {s}: same config must match");
    }
}

#[test]
fn different_seeds_diverge() {
    let Some((eng, sz)) = engine() else { return };
    let mut o = opts(&sz, "scale", 2);
    o.seed = 1;
    let mut a = Trainer::new(&eng, opts(&sz, "scale", 2)).unwrap();
    let mut b = Trainer::new(&eng, o).unwrap();
    for _ in 0..2 {
        a.train_step().unwrap();
        b.train_step().unwrap();
    }
    assert_ne!(a.params[0].f32s(), b.params[0].f32s());
}

#[test]
fn checkpoint_resume_is_exact() {
    let Some((eng, sz)) = engine() else { return };
    // run A: 8 straight steps
    let mut a = Trainer::new(&eng, opts(&sz, "scale", 8)).unwrap();
    for _ in 0..8 {
        a.train_step().unwrap();
    }
    // run B: 4 steps, checkpoint, restore into fresh trainer, 4 more
    let mut b1 = Trainer::new(&eng, opts(&sz, "scale", 8)).unwrap();
    for _ in 0..4 {
        b1.train_step().unwrap();
    }
    let path = std::env::temp_dir().join(format!("scale_it_{}.ckpt", std::process::id()));
    b1.checkpoint().unwrap().save(&path).unwrap();
    let mut b2 = Trainer::new(&eng, opts(&sz, "scale", 8)).unwrap();
    b2.restore(&Checkpoint::load(&path).unwrap()).unwrap();
    assert_eq!(b2.step, 4);
    for _ in 0..4 {
        b2.train_step().unwrap();
    }
    std::fs::remove_file(path).ok();
    for (p, (x, y)) in a.params.iter().zip(&b2.params).enumerate() {
        assert_close(x.f32s(), y.f32s(), &format!("resume param {p}"));
    }
    for (s, (x, y)) in a.state.iter().zip(&b2.state).enumerate() {
        assert_close(x.f32s(), y.f32s(), &format!("resume state {s}"));
    }
}

#[test]
fn restore_rejects_wrong_optimizer() {
    let Some((eng, sz)) = engine() else { return };
    let a = Trainer::new(&eng, opts(&sz, "scale", 1)).unwrap();
    let ckpt = a.checkpoint().unwrap();
    let mut b = Trainer::new(&eng, opts(&sz, "adam", 1)).unwrap();
    assert!(b.restore(&ckpt).is_err());
}

#[test]
fn state_footprint_matches_memory_estimator() {
    // the paper's memory claim, measured on the real state buffers and
    // cross-checked against memory::estimator's manifest accounting
    let Some((eng, sz)) = engine() else { return };
    let scale = Trainer::new(&eng, opts(&sz, "scale", 1)).unwrap();
    let adam = Trainer::new(&eng, opts(&sz, "adam", 1)).unwrap();
    let m = &eng.manifest;
    assert_eq!(
        scale.state_bytes(),
        measured_state_bytes(m, "scale", &sz).unwrap()
    );
    assert_eq!(
        adam.state_bytes(),
        measured_state_bytes(m, "adam", &sz).unwrap()
    );
    let params = measured_param_bytes(m, &sz).unwrap();
    assert_eq!(adam.state_bytes(), 2 * params);
    assert!(scale.state_bytes() < adam.state_bytes() / 4);
}

#[test]
fn all_manifest_optimizers_execute_one_step() {
    // every update artifact the manifest declares for the smoke size
    // must run and produce finite params
    let Some((eng, sz)) = engine() else { return };
    let mut opts_list = eng.manifest.optimizers_for(&sz);
    opts_list.sort();
    assert!(opts_list.len() >= 10, "optimizer zoo too small: {opts_list:?}");
    for opt in opts_list {
        let mut o = opts(&sz, &opt, 1);
        o.base_lr = 1e-3;
        let mut tr = Trainer::new(&eng, o).unwrap();
        tr.train_step().unwrap_or_else(|e| panic!("{opt}: {e}"));
        for p in &tr.params {
            assert!(
                p.f32s().iter().all(|x| x.is_finite()),
                "{opt} produced non-finite params"
            );
        }
    }
}

#[test]
fn mix_optimizers_train_natively_with_estimator_state() {
    // Table 13's mix_* ablations, executed natively: each rule trains,
    // its measured state footprint matches the manifest-driven
    // estimator (momentum only on the head + Adam on vectors, like
    // SCALE), and steady-state steps spawn no threads. The matching
    // zero-alloc audit lives in benches/bench_throughput.rs, where the
    // counting global allocator can run without cross-test noise.
    let Some((eng, sz)) = engine() else { return };
    let scale_state = measured_state_bytes(&eng.manifest, "scale", &sz).unwrap();
    for opt in [
        "mix_col_last_row_rest",
        "mix_row_first_col_rest",
        "mix_larger_dim",
        "mix_row_last_col_rest",
    ] {
        if eng.manifest.artifact(&format!("update_{opt}_{sz}")).is_err() {
            // a real (xla) manifest may bound its artifact set below the
            // full registry; the synthesized native manifest always has
            // the mix entries
            eprintln!("skipping {opt} (no update artifact in this manifest)");
            continue;
        }
        let mut o = opts(&sz, opt, 3);
        o.base_lr = 1e-3;
        let mut tr = Trainer::new(&eng, o).unwrap_or_else(|e| panic!("{opt}: {e}"));
        tr.train_step().unwrap_or_else(|e| panic!("{opt}: {e}")); // warm
        let spawned = scale_llm::parallel::threads_spawned();
        tr.train_step().unwrap();
        tr.train_step().unwrap();
        assert_eq!(
            scale_llm::parallel::threads_spawned(),
            spawned,
            "{opt}: steady-state steps must not spawn threads"
        );
        assert_eq!(
            tr.state_bytes(),
            measured_state_bytes(&eng.manifest, opt, &sz).unwrap(),
            "{opt}: measured state must match the estimator"
        );
        assert_eq!(
            tr.state_bytes(),
            scale_state,
            "{opt}: mix state budget must equal SCALE's"
        );
        for p in &tr.params {
            assert!(
                p.f32s().iter().all(|x| x.is_finite()),
                "{opt} produced non-finite params"
            );
        }
    }
}

#[test]
fn gpt2_architecture_trains() {
    let Some((eng, _)) = engine() else { return };
    let Some(gsz) = gpt2_size(&eng) else {
        eprintln!("skipping gpt2 test (no gpt2 size in manifest)");
        return;
    };
    let mut tr = Trainer::new(&eng, opts(&gsz, "scale", 12)).unwrap();
    let first = tr.train_step().unwrap();
    for _ in 0..11 {
        tr.train_step().unwrap();
    }
    let last = tr.metrics.ema_loss.unwrap();
    assert!(last.is_finite());
    assert!(last < first, "gpt2 loss should decrease: {first:.4} -> {last:.4}");
}

#[test]
fn varprobe_artifact_runs() {
    let Some((eng, sz)) = engine() else { return };
    let tr = Trainer::new(&eng, opts(&sz, "scale", 1)).unwrap();
    let info = eng.manifest.size(&sz).unwrap();
    let w = info.seq_len + 1;
    let mb = eng.manifest.microbatch;
    let big = mb * eng.manifest.varprobe_big_factor;
    let probe = eng.load(&format!("varprobe_{sz}")).unwrap();
    let small_batch = Tensor::from_i32(&[mb, w], vec![1; mb * w]);
    let big_batch = Tensor::from_i32(&[big, w], vec![1; big * w]);
    let mut inputs: Vec<&Tensor> = tr.params.iter().collect();
    inputs.push(&small_batch);
    inputs.push(&big_batch);
    let out = eng.run_exe_refs(&probe, &inputs).unwrap();
    assert_eq!(out.len(), info.params.len());
    // identical small/big token content -> small but nonnegative variance
    for v in &out {
        assert!(v.item_f32() >= 0.0);
    }
}

#[test]
fn update_executable_matches_rules_kernels() {
    // the ISSUE property: the executable update path must match calling
    // the optim::rules workspace kernels directly (bit-for-bit on the
    // native executor), across several gradient draws
    let Some((eng, sz)) = engine() else { return };
    let tr = Trainer::new(&eng, opts(&sz, "scale", 1)).unwrap();
    let info = eng.manifest.size(&sz).unwrap().clone();
    let head_idx = info.params.len() - 1;
    assert_eq!(info.params[head_idx].name, "lm_head");
    let upd = eng.load(&format!("update_scale_{sz}")).unwrap();

    for seed in [3u64, 4, 5] {
        let mut rng = scale_llm::util::rng::Pcg::new(seed);
        let grads: Vec<Tensor> = info
            .params
            .iter()
            .map(|p| {
                let data = (0..p.numel()).map(|_| 0.1 * rng.normal() as f32).collect();
                Tensor::from_f32(&p.shape, data)
            })
            .collect();
        let lr = 0.01f32;
        let lr_t = Tensor::scalar_f32(lr);
        let step_t = Tensor::scalar_f32(1.0);
        let mut inputs: Vec<&Tensor> = Vec::new();
        inputs.extend(tr.params.iter());
        inputs.extend(tr.state.iter());
        inputs.extend(grads.iter());
        inputs.push(&lr_t);
        inputs.push(&step_t);
        let out = eng.run_exe_refs(&upd, &inputs).unwrap();

        // head: momentum path (beta=0.9, m0=0)
        let (d_in, vocab) = (info.d_model, info.vocab);
        let mut p = tr.params[head_idx].f32s().to_vec();
        let mut m = vec![0f32; d_in * vocab];
        let g = grads[head_idx].f32s();
        scale_llm::optim::rules::scale_momentum(&mut p, &mut m, g, d_in, vocab, lr, 0.9);
        assert_close(out[head_idx].f32s(), &p, &format!("head (seed {seed})"));

        // a hidden matrix: stateless colnorm path
        let wq_idx = info.params.iter().position(|p| p.name == "block0.wq").unwrap();
        let mut pw = tr.params[wq_idx].f32s().to_vec();
        let d = info.d_model;
        scale_llm::optim::rules::scale_plain(&mut pw, grads[wq_idx].f32s(), d, d, lr);
        assert_close(out[wq_idx].f32s(), &pw, &format!("wq (seed {seed})"));
    }
}

#[test]
fn schedule_drives_update_magnitude() {
    // warmup means step 1 uses a tiny LR: params barely move
    let Some((eng, sz)) = engine() else { return };
    let mut o = opts(&sz, "scale", 100);
    o.schedule = Some(Schedule::paper_default(1e-2, 100));
    let mut tr = Trainer::new(&eng, o).unwrap();
    let before = tr.params[0].f32s().to_vec();
    tr.train_step().unwrap();
    let after = tr.params[0].f32s();
    let delta: f32 = before
        .iter()
        .zip(after)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    // lr at step 1 = 1e-2/10 = 1e-3; colnorm bounds per-entry update by lr
    assert!(delta <= 1.1e-3, "max delta {delta}");
}

#[test]
fn steady_state_steps_spawn_no_threads() {
    let Some((eng, sz)) = engine() else { return };
    let mut tr = Trainer::new(&eng, opts(&sz, "scale", 12)).unwrap();
    tr.train_step().unwrap(); // warm: ring fill, buffer creation
    let spawned = scale_llm::parallel::threads_spawned();
    for _ in 0..10 {
        tr.train_step().unwrap();
    }
    assert_eq!(
        scale_llm::parallel::threads_spawned(),
        spawned,
        "train_step must never spawn threads"
    );
}
