//! Frontier differential suite: the AdaPM partial-momentum policies and
//! AdamS (momentum-as-normalizer) against composed-kernel oracles, and
//! the multi-seed verdict layer against its sequential reference.
//!
//! Four properties are the PR's acceptance bar:
//!
//! - every frontier optimizer's executable path is **bit-identical** to
//!   applying the `optim::rules` kernels sequentially in canonical
//!   parameter order, for every pool size and sequential-fallback
//!   threshold (the threshold selects a code path, never a result);
//! - the policy axis is pinned entry-by-entry on native sizes —
//!   including `s60m`, where `FirstLast` and `TopKVariance(2)` actually
//!   diverge (they coincide on one-block sizes);
//! - measured state bytes equal the memory estimator exactly, and the
//!   mesh shard partition tiles them with nothing dropped or doubled;
//! - the multi-seed verdict aggregation is bit-stable across pool sizes
//!   and `max_concurrent` caps, with the state-byte column read from
//!   the estimator.
//!
//! Like `sweep_differential.rs`, this lives in its own test target so
//! the explicit `WorkerPool` constructions can never race
//! `integration.rs`'s process-global spawn-counter assertions.

use scale_llm::coordinator::sweep::{aggregate_cells, CellStats, SweepSpec};
use scale_llm::coordinator::{TrainOptions, VerdictSpec};
use scale_llm::exec::update::{partial_momentum_policy, state_slots, UpdateProgram, UpdateWs, BETA};
use scale_llm::exec::{native_manifest, MomentumPolicy};
use scale_llm::memory::estimator::{measured_state_bytes, sharded_state_bytes};
use scale_llm::optim::colnorm::NormWorkspace;
use scale_llm::optim::rules::{self, AdamHp};
use scale_llm::parallel::WorkerPool;
use scale_llm::runtime::artifact::{Manifest, SizeInfo};
use scale_llm::runtime::{Engine, Tensor};
use scale_llm::util::rng::Pcg;

const FRONTIER: [&str; 5] =
    ["adapm_last", "adapm_first_last", "adapm_embed_head", "adapm_top2", "adams"];

fn manifest() -> Manifest {
    native_manifest(std::path::PathBuf::from("unused"))
}

/// Seed-5 input draws shared by the native path and the oracle:
/// params (normal), then grads (0.1 * normal), from one PCG stream;
/// state starts at zeros.
fn draw_inputs(size: &SizeInfo) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut rng = Pcg::new(5);
    let params: Vec<Vec<f32>> = size
        .params
        .iter()
        .map(|p| (0..p.numel()).map(|_| rng.normal() as f32).collect())
        .collect();
    let grads: Vec<Vec<f32>> = size
        .params
        .iter()
        .map(|p| (0..p.numel()).map(|_| 0.1 * rng.normal() as f32).collect())
        .collect();
    (params, grads)
}

/// One step of the native executable path on `pool` with an explicit
/// sequential-fallback threshold; returns `[params'.., state'..]`.
fn run_native(
    opt: &str,
    size: &SizeInfo,
    lr: f32,
    pool: &WorkerPool,
    min_ops: usize,
) -> (Vec<Tensor>, usize) {
    let prog = UpdateProgram::new(opt, size).unwrap();
    let slots = state_slots(opt, size).unwrap();
    assert_eq!(slots.len(), prog.n_state(), "{opt}: plan/state desync");
    let (params, grads) = draw_inputs(size);
    let mut inputs: Vec<Tensor> = Vec::new();
    for (p, data) in size.params.iter().zip(&params) {
        inputs.push(Tensor::from_f32(&p.shape, data.clone()));
    }
    for s in &slots {
        inputs.push(Tensor::zeros(&s.shape));
    }
    for (p, data) in size.params.iter().zip(&grads) {
        inputs.push(Tensor::from_f32(&p.shape, data.clone()));
    }
    inputs.push(Tensor::scalar_f32(lr));
    inputs.push(Tensor::scalar_f32(1.0));
    let refs: Vec<&Tensor> = inputs.iter().collect();
    let mut out: Vec<Tensor> = Vec::new();
    for p in &size.params {
        out.push(Tensor::zeros(&p.shape));
    }
    for s in &slots {
        out.push(Tensor::zeros(&s.shape));
    }
    let mut ws = UpdateWs::new();
    prog.execute(&refs, &mut out, &mut ws, pool, min_ops).unwrap();
    (out, size.params.len())
}

/// The composed-kernel oracle: the frontier plans applied sequentially
/// with the public `optim::rules` kernels — vectors get Adam, matrices
/// get the column-norm rule with the policy's momentum bit (AdaPM) or
/// `momentum_norm` (AdamS). Returns (params', flat state').
fn run_oracle(opt: &str, size: &SizeInfo, lr: f32) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let (mut params, grads) = draw_inputs(size);
    let mask = partial_momentum_policy(opt).map(|policy| policy.selects(&size.params));
    let hp = AdamHp::default();
    let mut ws = NormWorkspace::new();
    let mut state: Vec<Vec<f32>> = Vec::new();
    for (i, spec) in size.params.iter().enumerate() {
        let (p, g) = (&mut params[i], &grads[i]);
        if spec.kind == "vector" {
            let mut m = vec![0.0f32; spec.numel()];
            let mut v = vec![0.0f32; spec.numel()];
            rules::adam(p, &mut m, &mut v, g, lr, hp, 1);
            state.push(m);
            state.push(v);
            continue;
        }
        let (di, dn) = (spec.shape[0], spec.shape[1]);
        match &mask {
            Some(sel) if sel[i] => {
                let mut m = vec![0.0f32; spec.numel()];
                rules::scale_momentum_ws(p, &mut m, g, di, dn, lr, BETA, &mut ws);
                state.push(m);
            }
            Some(_) => rules::scale_plain_ws(p, g, di, dn, lr, &mut ws),
            None => {
                assert_eq!(opt, "adams");
                let mut m = vec![0.0f32; spec.numel()];
                rules::momentum_norm(p, &mut m, g, lr, hp);
                state.push(m);
            }
        }
    }
    (params, state)
}

/// Tentpole leg: for every frontier optimizer, the executable path on
/// every pool size and threshold lands bit for bit on the sequential
/// composed-kernel oracle. The thresholds straddle tiny's per-matrix
/// numel gate (d*d = 1024, embed/head = 2048), so the sequential, the
/// mixed, and the fully parallel paths are all exercised.
#[test]
fn frontier_rules_bit_match_their_composed_kernels_across_pools() {
    let m = manifest();
    let size = m.size("tiny").unwrap();
    let lr = 0.02f32;
    let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(7)];
    for opt in FRONTIER {
        let (want_params, want_state) = run_oracle(opt, size, lr);
        for pool in &pools {
            for min_ops in [0usize, 64, 2048, usize::MAX] {
                let (out, np) = run_native(opt, size, lr, pool, min_ops);
                assert_eq!(out.len(), np + want_state.len(), "{opt}: arity");
                for (i, want) in want_params.iter().enumerate() {
                    assert_eq!(
                        out[i].f32s(),
                        &want[..],
                        "{opt}: param {i} ({} workers, min_ops {min_ops})",
                        pool.workers()
                    );
                }
                for (j, want) in want_state.iter().enumerate() {
                    assert_eq!(
                        out[np + j].f32s(),
                        &want[..],
                        "{opt}: state {j} ({} workers, min_ops {min_ops})",
                        pool.workers()
                    );
                }
            }
        }
    }
}

/// The policy-axis state tables on the native `tiny` size, entry by
/// entry — the exact layout checkpoints and the manifest carry.
#[test]
fn frontier_state_tables_are_pinned_on_tiny() {
    let m = manifest();
    let size = m.size("tiny").unwrap();
    let vec_pairs = |tail: &[&str]| -> Vec<String> {
        let mut v = vec!["block0.attn_norm.m".into(), "block0.attn_norm.v".into()];
        v.extend(tail.iter().map(|s| s.to_string()));
        v
    };
    let cases: [(&str, Vec<String>); 5] = [
        (
            "adapm_last",
            vec_pairs(&[
                "block0.mlp_norm.m",
                "block0.mlp_norm.v",
                "final_norm.m",
                "final_norm.v",
                "lm_head.m",
            ]),
        ),
        (
            "adapm_first_last",
            vec_pairs(&[
                "block0.wq.m",
                "block0.wk.m",
                "block0.wv.m",
                "block0.wo.m",
                "block0.mlp_norm.m",
                "block0.mlp_norm.v",
                "block0.w_gate.m",
                "block0.w_up.m",
                "block0.w_down.m",
                "final_norm.m",
                "final_norm.v",
                "lm_head.m",
            ]),
        ),
        (
            "adapm_embed_head",
            {
                let mut v = vec!["embed.m".to_string()];
                v.extend(vec_pairs(&[
                    "block0.mlp_norm.m",
                    "block0.mlp_norm.v",
                    "final_norm.m",
                    "final_norm.v",
                    "lm_head.m",
                ]));
                v
            },
        ),
        (
            "adapm_top2",
            vec_pairs(&[
                "block0.mlp_norm.m",
                "block0.mlp_norm.v",
                "block0.w_down.m",
                "final_norm.m",
                "final_norm.v",
                "lm_head.m",
            ]),
        ),
        ("adams", {
            let mut v = vec!["embed.m".to_string()];
            v.extend(vec_pairs(&[
                "block0.wq.m",
                "block0.wk.m",
                "block0.wv.m",
                "block0.wo.m",
                "block0.mlp_norm.m",
                "block0.mlp_norm.v",
                "block0.w_gate.m",
                "block0.w_up.m",
                "block0.w_down.m",
                "final_norm.m",
                "final_norm.v",
                "lm_head.m",
            ]));
            v
        }),
    ];
    for (opt, want) in cases {
        let got: Vec<String> =
            m.state_spec(opt, "tiny").unwrap().iter().map(|s| s.name.clone()).collect();
        assert_eq!(got, want, "{opt}");
    }
    // the policies that coincide with the hardcoded tables must produce
    // byte-identical manifest entries, not merely similar ones
    assert_eq!(m.state_spec("adapm_last", "tiny").unwrap(), m.state_spec("scale", "tiny").unwrap());
    assert_eq!(
        m.state_spec("adapm_embed_head", "tiny").unwrap(),
        m.state_spec("scale_first_last", "tiny").unwrap()
    );
}

/// On the two-block `s60m`, `FirstLast` and `TopKVariance(2)` must
/// diverge: the former stays on block0's matrices + head, the latter
/// walks back from the head into block1 only.
#[test]
fn first_last_and_top2_diverge_on_multi_block_sizes() {
    let m = manifest();
    let size = m.size("s60m").unwrap();
    let names = |policy: MomentumPolicy| -> Vec<&str> {
        policy
            .selects(&size.params)
            .iter()
            .zip(&size.params)
            .filter(|(&s, _)| s)
            .map(|(_, p)| p.name.as_str())
            .collect::<Vec<_>>()
    };
    assert_eq!(
        names(MomentumPolicy::FirstLast),
        vec![
            "block0.wq",
            "block0.wk",
            "block0.wv",
            "block0.wo",
            "block0.w_gate",
            "block0.w_up",
            "block0.w_down",
            "lm_head",
        ]
    );
    assert_eq!(names(MomentumPolicy::TopKVariance(2)), vec!["block1.w_down", "lm_head"]);
    assert_eq!(names(MomentumPolicy::Last), vec!["lm_head"]);
    assert_eq!(names(MomentumPolicy::EmbedHead), vec!["embed", "lm_head"]);
}

/// Measured state bytes must equal the estimator exactly for every
/// frontier optimizer, and the mesh shard partition must tile them —
/// nothing dropped, nothing doubled — so `launch --shard-state` carries
/// the new state specs unchanged.
#[test]
fn frontier_state_bytes_match_estimator_and_tile_over_shards() {
    let m = manifest();
    for size in ["tiny", "s60m"] {
        for opt in FRONTIER {
            let measured = measured_state_bytes(&m, opt, size).unwrap();
            let planned: usize = state_slots(opt, m.size(size).unwrap())
                .unwrap()
                .iter()
                .map(|s| 4 * s.shape.iter().product::<usize>())
                .sum();
            assert_eq!(measured, planned, "{opt} {size}: estimator vs plan");
            for ranks in [1usize, 2, 4] {
                let shards = sharded_state_bytes(&m, opt, size, ranks).unwrap();
                assert_eq!(shards.len(), ranks);
                assert_eq!(
                    shards.iter().sum::<usize>(),
                    measured,
                    "{opt} {size} at {ranks} ranks"
                );
            }
        }
    }
    // the family ordering the paper's memory story predicts, measured:
    // head-only < first+last < everything (= sgd_momentum's bill)
    let last = measured_state_bytes(&m, "adapm_last", "s60m").unwrap();
    let fl = measured_state_bytes(&m, "adapm_first_last", "s60m").unwrap();
    let all = measured_state_bytes(&m, "adams", "s60m").unwrap();
    assert!(last < fl && fl < all, "{last} {fl} {all}");
    assert_eq!(all, measured_state_bytes(&m, "sgd_momentum", "s60m").unwrap());
}

/// Engine plus the smallest trainable size its manifest offers.
fn engine() -> Option<(Engine, String)> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let eng = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping frontier verdict test (run `make artifacts`): {e}");
            return None;
        }
    };
    for s in ["tiny", "s60m"] {
        if eng.manifest.sizes.contains_key(s) {
            return Some((eng, s.to_string()));
        }
    }
    eprintln!("skipping frontier verdict test (no smoke-able size in manifest)");
    None
}

fn assert_cells_bit_identical(got: &[CellStats], want: &[CellStats], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: cell count");
    for (g, w) in got.iter().zip(want) {
        assert_eq!(g.optimizer, w.optimizer, "{what}");
        assert_eq!(g.lr.to_bits(), w.lr.to_bits(), "{what}: {} lr", g.optimizer);
        assert_eq!(g.n_trials, w.n_trials, "{what}: {} n_trials", g.optimizer);
        assert_eq!(g.n_effective, w.n_effective, "{what}: {} n_effective", g.optimizer);
        assert_eq!(g.mean_ppl.to_bits(), w.mean_ppl.to_bits(), "{what}: {} mean", g.optimizer);
        assert_eq!(
            g.stddev_ppl.to_bits(),
            w.stddev_ppl.to_bits(),
            "{what}: {} stddev",
            g.optimizer
        );
        assert_eq!(g.ci95_ppl.to_bits(), w.ci95_ppl.to_bits(), "{what}: {} ci95", g.optimizer);
    }
}

/// Verdict leg: multi-seed mean/stddev/CI cells computed from a
/// concurrent sweep are bit-identical to the sequential reference, for
/// every pool size and `max_concurrent` cap — including cells where
/// some trials diverge (`n_effective < n_trials`) — and the verdict's
/// state-byte column reads the estimator exactly.
#[test]
fn verdict_aggregation_is_bit_stable_across_pools_and_caps() {
    let Some((eng, sz)) = engine() else { return };
    let base = TrainOptions {
        size: sz.clone(),
        optimizer: "adams".into(),
        steps: 2,
        base_lr: 1e-3,
        schedule: None,
        shards: 2,
        seed: 0,
        eval_every: 0,
        eval_batches: 2,
        log_every: 0,
        quiet: true,
    };
    // the 1e12 cells diverge, so exclusion (n_effective) is aggregated
    // identically on every path
    let mut spec = SweepSpec::lr_grid(base, &[1e-3, 1e12]);
    spec.optimizers = vec!["adams".into(), "adapm_last".into()];
    spec.seeds = vec![0, 1, 2];

    let want_pts = spec.run_serial(&eng).expect("serial sweep");
    let want = aggregate_cells(&want_pts);
    assert_eq!(want.len(), 4, "2 optimizers x 2 LRs");
    assert!(want.iter().any(|c| c.n_effective == 0), "the 1e12 cells must fully diverge");
    assert!(
        want.iter().any(|c| c.n_effective == c.n_trials && c.n_trials == 3),
        "the sane cells must keep all 3 seeds"
    );

    let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(7)];
    for pool in &pools {
        let got = aggregate_cells(&spec.run_on(&eng, pool).expect("concurrent sweep"));
        assert_cells_bit_identical(&got, &want, &format!("{} workers", pool.workers()));
    }
    for cap in [1usize, 2] {
        let mut capped = spec.clone();
        capped.max_concurrent = cap;
        let got = aggregate_cells(&capped.run(&eng).expect("capped sweep"));
        assert_cells_bit_identical(&got, &want, &format!("max_concurrent {cap}"));
    }

    // the ranking's state-byte column is the estimator, verbatim
    let vspec = VerdictSpec { memory_budget: None };
    let verdict = vspec
        .verdict(&want_pts, |opt| measured_state_bytes(&eng.manifest, opt, &sz))
        .expect("verdict");
    assert_eq!(verdict.ranking.len(), 2);
    for r in &verdict.ranking {
        assert_eq!(
            r.state_bytes,
            measured_state_bytes(&eng.manifest, &r.optimizer, &sz).unwrap(),
            "{}: state bytes must come from the estimator",
            r.optimizer
        );
        assert!(r.within_budget, "no budget set — everything fits");
    }
    // both optimizers have a finite best cell at 1e-3
    for r in &verdict.ranking {
        assert_eq!(r.best.lr, 1e-3, "{}", r.optimizer);
        assert!(r.best.mean_ppl.is_finite(), "{}", r.optimizer);
    }
}
