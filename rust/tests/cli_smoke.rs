//! CLI smoke tests: drive the `scale` binary end to end as a user would.

use std::process::Command;

fn scale_bin() -> std::path::PathBuf {
    // target dir is shared with the test binary's location
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop(); // release or debug
    p.push("scale");
    p
}

fn run(args: &[&str]) -> (bool, String) {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let out = Command::new(scale_bin())
        .args(args)
        .current_dir(&root)
        .output()
        .expect("scale binary missing — build first");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

/// On the default build the native executor (and its synthesized
/// manifest) makes every subcommand work with no artifacts at all; with
/// `--features xla` the binary still needs `make artifacts`.
fn runtime_available() -> bool {
    let ok = !cfg!(feature = "xla")
        || std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts")
            .join("manifest.json")
            .exists();
    if !ok {
        eprintln!("skipping CLI smoke test (xla build needs `make artifacts`)");
    }
    ok
}

#[test]
fn help_lists_subcommands() {
    let (ok, text) = run(&["help"]);
    assert!(ok);
    let cmds = [
        "train", "serve", "table", "figure", "memory-report", "sweep", "sweep-lr", "compare",
        "lr-curve",
    ];
    for cmd in cmds {
        assert!(text.contains(cmd), "missing {cmd} in help");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let (ok, text) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("unknown subcommand"));
}

#[test]
fn unknown_flag_fails() {
    let (ok, text) = run(&["train", "--does-not-exist", "1"]);
    assert!(!ok, "{text}");
    assert!(text.contains("unknown option"));
}

#[test]
fn list_shows_sizes() {
    if !runtime_available() {
        return;
    }
    let (ok, text) = run(&["list"]);
    assert!(ok, "{text}");
    for s in ["s60m", "s130m", "s350m", "e2e"] {
        assert!(text.contains(s), "{text}");
    }
}

#[test]
fn memory_report_reproduces_paper() {
    if !runtime_available() {
        return;
    }
    let (ok, text) = run(&["memory-report"]);
    assert!(ok, "{text}");
    // the Appendix-B 7B totals, printed to 2dp
    for v in ["13.48", "40.43", "26.95", "13.74"] {
        assert!(text.contains(v), "missing {v} in:\n{text}");
    }
}

#[test]
fn ablate_momentum_runs() {
    let (ok, text) = run(&["ablate-momentum", "--seeds", "2"]);
    assert!(ok, "{text}");
    assert!(text.contains("momentum on noisy"));
}

#[test]
fn train_and_eval_checkpoint() {
    if !runtime_available() {
        return;
    }
    // the tiny smoke size keeps the debug-built binary fast; xla builds
    // fall back to s60m (their manifest has no smoke sizes)
    let size = if cfg!(feature = "xla") { "s60m" } else { "tiny" };
    let ckpt = std::env::temp_dir().join(format!("scale_cli_{}.ckpt", std::process::id()));
    let ckpt_s = ckpt.to_str().unwrap();
    let (ok, text) = run(&[
        "train", "--size", size, "--optimizer", "scale", "--steps", "5",
        "--shards", "2", "--log-every", "0", "--save", ckpt_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("final eval ppl"));
    let (ok2, text2) = run(&["eval", "--load", ckpt_s, "--eval-batches", "2"]);
    assert!(ok2, "{text2}");
    assert!(text2.contains("step 5"));
    std::fs::remove_file(ckpt).ok();
}

#[test]
fn sweep_subcommand_emits_parseable_json() {
    if !runtime_available() {
        return;
    }
    // the native manifest always carries the mix_* ablations; a real
    // (xla) manifest may not, so that leg sticks to the universal zoo
    let size = if cfg!(feature = "xla") { "s60m" } else { "tiny" };
    let optimizers = if cfg!(feature = "xla") {
        "scale,adam"
    } else {
        "scale,mix_larger_dim"
    };
    let (ok, text) = run(&[
        "sweep", "--size", size, "--optimizers", optimizers, "--lrs", "1e-2,1e-3",
        "--steps", "2", "--shards", "1", "--eval-batches", "2", "--json",
    ]);
    assert!(ok, "{text}");
    let doc = scale_llm::util::json::parse(text.trim())
        .unwrap_or_else(|e| panic!("sweep --json must print valid JSON ({e}):\n{text}"));
    assert_eq!(doc.get("report").unwrap().as_str(), Some("sweep"));
    assert_eq!(doc.get("trials").unwrap().as_usize(), Some(4));
    let pts = doc.get("points").unwrap().as_arr().unwrap();
    assert_eq!(pts.len(), 4);
    for p in pts {
        assert!(p.get("optimizer").unwrap().as_str().is_some());
        assert!(p.get("lr").unwrap().as_f64().is_some());
        assert!(p.get("diverged").unwrap().as_bool().is_some());
    }
}

/// `scale compare --json` twice with the same arguments: the verdict
/// (multi-seed mean/CI ranking) must be byte-for-byte deterministic,
/// parse with our own JSON parser, and carry a state-byte column that
/// matches `memory::estimator::measured_state_bytes` exactly.
#[test]
fn compare_subcommand_emits_deterministic_verdict_json() {
    if !runtime_available() {
        return;
    }
    // a real (xla) manifest predates the frontier family; the native
    // manifest always carries it
    let size = if cfg!(feature = "xla") { "s60m" } else { "tiny" };
    let optimizers = if cfg!(feature = "xla") { "scale,adam" } else { "scale,adams" };
    let args = [
        "compare", "--size", size, "--optimizers", optimizers, "--seeds", "2",
        "--steps", "2", "--shards", "1", "--eval-batches", "2", "--json",
    ];
    let (ok, text) = run(&args);
    assert!(ok, "{text}");
    let (ok2, text2) = run(&args);
    assert!(ok2, "{text2}");
    assert_eq!(text, text2, "compare must be deterministic run to run");
    let doc = scale_llm::util::json::parse(text.trim())
        .unwrap_or_else(|e| panic!("compare --json must print valid JSON ({e}):\n{text}"));
    assert_eq!(doc.get("report").unwrap().as_str(), Some("compare"));
    let cells = doc.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 2, "one cell per optimizer at its default LR");
    for c in cells {
        assert_eq!(c.get("n_trials").unwrap().as_usize(), Some(2));
        assert!(c.get("n_effective").unwrap().as_usize().is_some());
    }
    let ranking = doc.get("ranking").unwrap().as_arr().unwrap();
    assert_eq!(ranking.len(), 2);
    if !cfg!(feature = "xla") {
        let m = scale_llm::exec::native_manifest(std::path::PathBuf::from("unused"));
        for r in ranking {
            let opt = r.get("optimizer").unwrap().as_str().unwrap();
            let want =
                scale_llm::memory::estimator::measured_state_bytes(&m, opt, size).unwrap();
            assert_eq!(
                r.get("state_bytes").unwrap().as_usize(),
                Some(want),
                "{opt}: verdict state bytes must match the estimator"
            );
        }
    }
}

/// `scale lr-curve --out` writes the Fig.-8 artifact, which must
/// re-parse with our own JSON parser and carry one curve per optimizer
/// with one point per LR.
#[test]
fn lr_curve_subcommand_writes_parseable_artifact() {
    if !runtime_available() {
        return;
    }
    let size = if cfg!(feature = "xla") { "s60m" } else { "tiny" };
    let out = std::env::temp_dir().join(format!("scale_lr_curve_{}.json", std::process::id()));
    let out_s = out.to_str().unwrap().to_string();
    let (ok, text) = run(&[
        "lr-curve", "--size", size, "--optimizers", "scale", "--seeds", "1",
        "--steps", "2", "--shards", "1", "--eval-batches", "2",
        "--lrs", "1e-3,1e-2", "--out", &out_s,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("wrote"), "{text}");
    let written = std::fs::read_to_string(&out).expect("artifact file missing");
    let doc = scale_llm::util::json::parse(&written)
        .unwrap_or_else(|e| panic!("lr-curve artifact must be valid JSON ({e}):\n{written}"));
    assert_eq!(doc.get("report").unwrap().as_str(), Some("lr_curve"));
    let curves = doc.get("curves").unwrap().as_arr().unwrap();
    assert_eq!(curves.len(), 1);
    assert_eq!(curves[0].get("optimizer").unwrap().as_str(), Some("scale"));
    let points = curves[0].get("points").unwrap().as_arr().unwrap();
    assert_eq!(points.len(), 2, "one point per LR");
    std::fs::remove_file(out).ok();
}

/// `scale serve` over piped stdio: two valid requests around a hostile
/// line; the server answers all three (typed error included), drains,
/// and exits cleanly on EOF. Response order is scheduling-dependent, so
/// lines are classified by content, not position.
#[test]
fn serve_stdio_roundtrip() {
    if !runtime_available() {
        return;
    }
    use scale_llm::util::json::{self, Json};
    use std::io::Write;
    use std::process::Stdio;
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let mut child = Command::new(scale_bin())
        .args(["serve", "--size", "tiny", "--max-batch", "2", "--quiet"])
        .current_dir(&root)
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("scale binary missing — build first");
    let mut stdin = child.stdin.take().unwrap();
    stdin
        .write_all(
            b"{\"id\":\"r1\",\"prompt\":[1,2,3],\"max_new\":4}\n\
              not json\n\
  {\"id\":\"r2\",\"prompt\":[5],\"max_new\":2,\"temperature\":0.7,\"top_k\":8,\"seed\":9}\n",
        )
        .unwrap();
    drop(stdin); // EOF: the server drains in-flight work and exits
    let out = child.wait_with_output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8(out.stdout).unwrap();
    let lines: Vec<Json> = text
        .lines()
        .map(|l| {
            json::parse(l).unwrap_or_else(|e| panic!("serve printed non-JSON {l:?} ({e}):\n{text}"))
        })
        .collect();
    assert_eq!(lines.len(), 3, "two completions + one error:\n{text}");
    let status = |d: &Json| d.get("status").unwrap().as_str().unwrap().to_string();
    let errors: Vec<_> = lines.iter().filter(|d| status(d) == "error").collect();
    assert_eq!(errors.len(), 1, "{text}");
    assert_eq!(errors[0].get("kind").unwrap().as_str(), Some("malformed"));
    for (id, want_tokens) in [("r1", 4), ("r2", 2)] {
        let line = lines
            .iter()
            .find(|d| d.get("id").and_then(|i| i.as_str()) == Some(id))
            .unwrap_or_else(|| panic!("no completion for {id}:\n{text}"));
        assert_eq!(status(line), "ok");
        assert_eq!(line.get("tokens").unwrap().as_arr().unwrap().len(), want_tokens, "{text}");
    }
}

#[test]
fn table4_is_instant_and_correct() {
    if !runtime_available() {
        return;
    }
    let (ok, text) = run(&["table", "4"]);
    assert!(ok, "{text}");
    assert!(text.contains("memory"));
    assert!(text.contains("SCALE"));
}
