//! Chaos suite: deterministic fault injection against the durability
//! layer (PR: robustness). Every test schedules faults through the
//! process-global `scale_llm::fault` registry and proves a recovery
//! property *bit-exactly* where the contract promises one:
//!
//! - crash at step k, resume from the store -> identical params/state
//!   to a run that never crashed;
//! - a torn mid-save `.tmp` is never picked up and the store falls
//!   back to the previous good snapshot;
//! - NaN-injected gradients roll back under the guard and (at
//!   `lr_backoff = 1.0`) finish bit-identical to a fault-free run;
//! - sweeps with retried trial panics report bit-identical numbers to
//!   fault-free sweeps for pool sizes {0, 2, 7}.
//!
//! This is its own test binary (see Cargo.toml): the registry is
//! process-global, so these tests must not share a process with suites
//! that assume no faults are armed. Within the binary, `#[test]`s run
//! on parallel threads, so every test serializes on `LOCK` and leaves
//! the registry cleared.

use scale_llm::coordinator::{
    Checkpoint, CheckpointStore, GuardPolicy, SweepPoint, SweepSpec, TrainError, TrainOptions,
    Trainer,
};
use scale_llm::fault;
use scale_llm::parallel::WorkerPool;
use scale_llm::runtime::Engine;
use scale_llm::util::lock::StableMutex;

/// Poison-tolerant by construction: a panicking test must not turn
/// every later test into a `PoisonError` unwrap — see
/// [`StableMutex`] for why that is sound for a serialization lock.
static LOCK: StableMutex<()> = StableMutex::new(());

/// Serialize on the registry and guarantee it ends up cleared even if
/// the test panics (the next test must start disarmed).
struct FaultGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl Drop for FaultGuard<'_> {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn guard() -> FaultGuard<'static> {
    let g = LOCK.lock();
    fault::clear();
    FaultGuard(g)
}

/// The combination the whole suite relies on: a test that panics while
/// holding the guard leaves (a) the lock takeable and (b) the registry
/// cleared for whoever comes next.
#[test]
fn fault_guard_clears_registry_even_after_panic() {
    let caught = std::panic::catch_unwind(|| {
        let _g = guard();
        fault::configure("grad_nan@1..").unwrap();
        panic!("test body blew up mid-fault");
    });
    assert!(caught.is_err());
    // relock *without* guard()'s own clear, so the assertion below
    // observes the unwind-time Drop and not a fresh clear
    let _g = LOCK.lock();
    assert!(!fault::fires("grad_nan"), "clear-on-drop must have run during the unwind");
}

/// Engine plus the smallest trainable size its manifest offers.
fn engine() -> Option<(Engine, String)> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let eng = match Engine::new(dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping chaos test (run `make artifacts`): {e}");
            return None;
        }
    };
    for s in ["tiny", "s60m"] {
        if eng.manifest.sizes.contains_key(s) {
            return Some((eng, s.to_string()));
        }
    }
    eprintln!("skipping chaos test (no smoke-able size in manifest)");
    None
}

fn opts(size: &str, steps: usize) -> TrainOptions {
    TrainOptions {
        size: size.into(),
        optimizer: "scale".into(),
        steps,
        base_lr: 1e-2,
        schedule: None,
        shards: 2,
        seed: 0,
        eval_every: 0,
        eval_batches: 2,
        log_every: 0,
        quiet: true,
    }
}

fn tmp_dir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("scale_chaos_{name}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn tensor_bits(ts: &[scale_llm::runtime::Tensor]) -> Vec<u32> {
    ts.iter().flat_map(|t| t.f32s().iter().map(|x| x.to_bits())).collect()
}

/// A process dies at step 7 of 10 with snapshots every 3 steps; a fresh
/// trainer resuming from the store must land on bit-identical params
/// and state to a run that never crashed.
#[test]
fn crash_at_step_k_resume_is_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let dir = tmp_dir("crash");

    // the uninterrupted reference
    let mut reference = Trainer::new(&eng, opts(&sz, 10)).unwrap();
    while reference.step < 10 {
        reference.train_step().unwrap();
    }

    // the "crashed" leg: same opts (the cosine schedule spans all 10
    // steps), killed after step 7 with snapshots at steps 3 and 6
    {
        let store = CheckpointStore::open(&dir, 3).unwrap();
        let mut tr = Trainer::new(&eng, opts(&sz, 10)).unwrap();
        while tr.step < 7 {
            tr.train_step().unwrap();
            if tr.step % 3 == 0 {
                store.save(&tr.checkpoint().unwrap()).unwrap();
            }
        }
        // drop without saving step 7: the crash loses it
    }

    // resume in a fresh trainer from the newest snapshot (step 6)
    let store = CheckpointStore::open(&dir, 3).unwrap();
    let (step, ck) = store.latest().unwrap().expect("snapshot to resume from");
    assert_eq!(step, 6);
    let mut resumed = Trainer::new(&eng, opts(&sz, 10)).unwrap();
    resumed.restore(&ck).unwrap();
    while resumed.step < 10 {
        resumed.train_step().unwrap();
    }

    assert_eq!(
        tensor_bits(&resumed.params),
        tensor_bits(&reference.params),
        "resumed params must be bit-identical to the uninterrupted run"
    );
    assert_eq!(
        tensor_bits(&resumed.state),
        tensor_bits(&reference.state),
        "resumed optimizer state must be bit-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A crash in the middle of writing a snapshot (the `save_partial`
/// failpoint) leaves only a torn `.tmp`: the store must keep serving
/// the previous good snapshot, never the torn bytes, and must sweep
/// the leftover on the next open.
#[test]
fn torn_mid_save_tmp_is_ignored_and_cleaned() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let dir = tmp_dir("torn");

    let store = CheckpointStore::open(&dir, 3).unwrap();
    let mut tr = Trainer::new(&eng, opts(&sz, 4)).unwrap();
    tr.train_step().unwrap();
    store.save(&tr.checkpoint().unwrap()).unwrap();

    tr.train_step().unwrap();
    fault::configure("save_partial@1").unwrap();
    let err = store.save(&tr.checkpoint().unwrap()).unwrap_err();
    assert!(err.to_string().contains("save_partial"), "{err}");
    fault::clear();
    let torn = dir.join("step_00000002.ckpt.tmp");
    assert!(torn.exists(), "a failed save must leave the torn .tmp, like a real crash");

    // the torn write published nothing: step 1 is still the newest
    let (step, ck) = store.latest().unwrap().expect("previous snapshot");
    assert_eq!((step, ck.step), (1, 1));

    // a restart (re-open) sweeps the leftover
    CheckpointStore::open(&dir, 3).unwrap();
    assert!(!torn.exists(), "stale .tmp must be swept on open");
    std::fs::remove_dir_all(&dir).ok();
}

/// `save_io` faults surface as typed `TrainError::Io` from the guarded
/// loop — classification, not string matching.
#[test]
fn save_fault_in_guarded_run_is_typed_io() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let dir = tmp_dir("saveio");

    fault::configure("save_io@1..").unwrap();
    let mut tr = Trainer::new(&eng, opts(&sz, 3)).unwrap();
    let err = tr.train_guarded(&GuardPolicy::new(&dir)).unwrap_err();
    assert!(matches!(err, TrainError::Io(_)), "want Io, got {err}");
    fault::clear();
    std::fs::remove_dir_all(&dir).ok();
}

/// NaNs injected into the reduced gradients at step 5: the guard rolls
/// back to the step-4 snapshot and replays. With `lr_backoff = 1.0`
/// the finished run — params, state, EMA, final ppl — must be
/// bit-identical to a run that never saw the fault.
#[test]
fn nan_injection_rollback_recovers_bit_exact() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let dir = tmp_dir("nan");

    let mut clean = Trainer::new(&eng, opts(&sz, 10)).unwrap();
    let clean_ppl = clean.train().unwrap();

    fault::configure("grad_nan@5").unwrap();
    let mut guarded = Trainer::new(&eng, opts(&sz, 10)).unwrap();
    let policy = GuardPolicy {
        dir: dir.clone(),
        checkpoint_every: 2,
        keep_last: 3,
        max_retries: 3,
        lr_backoff: 1.0, // identity: the injected fault wasn't the LR's fault
    };
    let guarded_ppl = guarded.train_guarded(&policy).unwrap();
    assert!(!fault::fires("grad_nan"), "the single scheduled injection must be consumed");
    fault::clear();

    assert_eq!(
        guarded_ppl.to_bits(),
        clean_ppl.to_bits(),
        "rollback replay must reproduce the clean run's final ppl bit-for-bit"
    );
    assert_eq!(tensor_bits(&guarded.params), tensor_bits(&clean.params), "params");
    assert_eq!(tensor_bits(&guarded.state), tensor_bits(&clean.state), "optimizer state");
    assert_eq!(
        guarded.metrics.ema_loss.unwrap().to_bits(),
        clean.metrics.ema_loss.unwrap().to_bits(),
        "the EMA rewind must replay the exact record_step fold"
    );
    assert_eq!(guarded.metrics.steps.len(), clean.metrics.steps.len());
    std::fs::remove_dir_all(&dir).ok();
}

/// A rollback with `lr_backoff = 0.5` halves the LR scale and the run
/// still finishes.
#[test]
fn lr_backoff_applied_on_rollback() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let dir = tmp_dir("backoff");

    fault::configure("grad_nan@3").unwrap();
    let mut tr = Trainer::new(&eng, opts(&sz, 6)).unwrap();
    let policy = GuardPolicy {
        dir: dir.clone(),
        checkpoint_every: 2,
        keep_last: 2,
        max_retries: 3,
        lr_backoff: 0.5,
    };
    let ppl = tr.train_guarded(&policy).unwrap();
    fault::clear();
    assert_eq!(tr.lr_scale(), 0.5, "one rollback must apply the backoff exactly once");
    assert!(ppl.is_finite());
    assert_eq!(tr.step, 6, "the run must still reach the full step count");
    std::fs::remove_dir_all(&dir).ok();
}

/// Genuine divergence (absurd LR) re-diverges on every replay: the
/// guard must stop after its retry budget and surface the typed error.
#[test]
fn guard_divergence_retries_are_bounded() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let dir = tmp_dir("bounded");

    let mut o = opts(&sz, 6);
    o.base_lr = 1e12;
    let mut tr = Trainer::new(&eng, o).unwrap();
    let policy = GuardPolicy {
        dir: dir.clone(),
        checkpoint_every: 2,
        keep_last: 2,
        max_retries: 2,
        lr_backoff: 1.0, // no backoff: the replay diverges identically
    };
    let err = tr.train_guarded(&policy).unwrap_err();
    assert!(matches!(err, TrainError::Divergence { .. }), "want Divergence, got {err}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Plain (unguarded) runs abort on divergence with the typed error
/// instead of training NaNs to completion.
#[test]
fn plain_train_aborts_on_divergence() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let mut o = opts(&sz, 5);
    o.base_lr = 1e12;
    let mut tr = Trainer::new(&eng, o).unwrap();
    let err = tr.train().unwrap_err();
    assert!(matches!(err, TrainError::Divergence { .. }), "want Divergence, got {err}");
}

fn assert_points_bit_identical(got: &[SweepPoint], want: &[SweepPoint], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: trial count");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.optimizer, w.optimizer, "{what}: trial {i} optimizer");
        assert_eq!(g.lr.to_bits(), w.lr.to_bits(), "{what}: trial {i} lr");
        assert_eq!(g.seed, w.seed, "{what}: trial {i} seed");
        assert_eq!(g.ppl.to_bits(), w.ppl.to_bits(), "{what}: trial {i} ppl");
        assert_eq!(
            g.final_loss_ema.to_bits(),
            w.final_loss_ema.to_bits(),
            "{what}: trial {i} final_loss_ema"
        );
        assert_eq!(g.diverged, w.diverged, "{what}: trial {i} diverged");
    }
}

/// A sweep whose trial 1 panics once and is retried must report
/// bit-identical numbers to a fault-free sweep — for a zero-worker
/// (inline) pool and for 2- and 7-worker pools. The scoped fault spec
/// targets the *grid index*, so the same trial is hit regardless of
/// which worker runs it.
#[test]
fn retried_sweep_bit_identical_to_fault_free_for_every_pool() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };

    let mut spec = SweepSpec::lr_grid(opts(&sz, 2), &[1e-3, 1e-2]);
    spec.seeds = vec![0, 1];
    spec.retries = 1;
    let want = spec.run_serial(&eng).expect("fault-free reference");
    assert_eq!(want.len(), 4);
    assert!(want.iter().all(|p| p.outcome == scale_llm::coordinator::TrialOutcome::Ok));

    for pool in [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(7)] {
        // fresh spec per run: hit counters are consumed
        fault::configure("trial1/trial_panic@1").unwrap();
        let got = spec.run_on(&eng, &pool).expect("faulted sweep must still complete");
        fault::clear();
        let what = format!("{} workers", pool.workers());
        assert_points_bit_identical(&got, &want, &what);
        for (i, p) in got.iter().enumerate() {
            let (o, a) = if i == 1 {
                (scale_llm::coordinator::TrialOutcome::Retried, 2)
            } else {
                (scale_llm::coordinator::TrialOutcome::Ok, 1)
            };
            assert_eq!(p.outcome, o, "{what}: trial {i} outcome");
            assert_eq!(p.attempts, a, "{what}: trial {i} attempts");
        }
    }
}

/// A trial that panics past its retry budget slots as `faulted` with
/// `ppl = inf` — the rest of the sweep completes and reports.
#[test]
fn faulted_trial_slots_inf_and_sweep_completes() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };

    let mut spec = SweepSpec::lr_grid(opts(&sz, 2), &[1e-3, 1e-2]);
    spec.retries = 1;
    fault::configure("trial0/trial_panic@1..").unwrap();
    let pts = spec.run(&eng).expect("sweep must absorb the faulted trial");
    fault::clear();

    use scale_llm::coordinator::TrialOutcome;
    assert_eq!(pts.len(), 2);
    assert_eq!(pts[0].outcome, TrialOutcome::Faulted);
    assert_eq!(pts[0].attempts, 2, "retry budget of 1 means two attempts");
    assert_eq!(pts[0].ppl, f64::INFINITY);
    assert!(!pts[0].diverged, "faulted is not diverged: the math never got to run");
    assert_eq!(pts[1].outcome, TrialOutcome::Ok);
    assert!(pts[1].ppl.is_finite());
}

/// The `pool_job` failpoint panics inside a pool job; the pool must
/// re-raise the payload on the dispatcher and stay fully usable.
#[test]
fn pool_job_panic_is_captured_and_pool_survives() {
    let _g = guard();
    for workers in [0usize, 3] {
        fault::configure("pool_job@2").unwrap();
        let pool = WorkerPool::new(workers);
        let tasks: Vec<_> = (0..4u64).map(|i| move || i * i).collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.run(tasks)));
        let payload = caught.expect_err("the injected job panic must propagate to run()");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("?");
        assert!(msg.contains("failpoint pool_job"), "payload: {msg}");
        fault::clear();
        let ok: Vec<u64> = pool.run((0..4u64).map(|i| move || i + 1).collect());
        assert_eq!(ok, vec![1, 2, 3, 4], "pool must survive an injected job panic");
    }
}

/// `load_io` faults make the newest snapshot unreadable: `latest()`
/// quarantines it and falls back to the older good one.
#[test]
fn load_fault_quarantines_and_falls_back() {
    let _g = guard();
    let dir = tmp_dir("loadq");
    let store = CheckpointStore::open(&dir, 3).unwrap();
    for step in [1u64, 2] {
        let ck = Checkpoint {
            size: "tiny".into(),
            optimizer: "scale".into(),
            step,
            tensors: vec![(
                "w".into(),
                scale_llm::runtime::Tensor::from_f32(&[2], vec![step as f32, 0.5]),
            )],
        };
        store.save(&ck).unwrap();
    }
    // the first load attempt (the newest snapshot, step 2) fails
    fault::configure("load_io@1").unwrap();
    let (step, ck) = store.latest().unwrap().expect("fallback snapshot");
    fault::clear();
    assert_eq!((step, ck.step), (1, 1), "must fall back past the unreadable snapshot");
    assert!(
        dir.join("step_00000002.ckpt.corrupt").exists(),
        "the unreadable snapshot must be quarantined for post-mortem"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// `--resume auto` semantics end to end: guard a run to completion,
/// then a fresh trainer resuming from the same store trains zero
/// additional steps and evaluates to the same result.
#[test]
fn guarded_store_resumes_a_fresh_trainer() {
    let _g = guard();
    let Some((eng, sz)) = engine() else { return };
    let dir = tmp_dir("resume");

    let mut tr = Trainer::new(&eng, opts(&sz, 6)).unwrap();
    let policy = GuardPolicy {
        dir: dir.clone(),
        checkpoint_every: 3,
        keep_last: 2,
        max_retries: 0,
        lr_backoff: 1.0,
    };
    let ppl = tr.train_guarded(&policy).unwrap();

    let store = CheckpointStore::open(&dir, 2).unwrap();
    let (step, ck) = store.latest().unwrap().expect("final snapshot");
    assert_eq!(step, 6, "checkpoint_every = 3 must have landed the step-6 snapshot");
    let mut resumed = Trainer::new(&eng, opts(&sz, 6)).unwrap();
    resumed.restore(&ck).unwrap();
    let resumed_ppl = resumed.train().unwrap();
    assert_eq!(
        resumed_ppl.to_bits(),
        ppl.to_bits(),
        "a fully-trained store resume must replay only the final eval"
    );
    assert_eq!(tensor_bits(&resumed.params), tensor_bits(&tr.params));
    std::fs::remove_dir_all(&dir).ok();
}
