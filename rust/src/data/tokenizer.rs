//! BPE-lite tokenizer learned from the corpus (SentencePiece stand-in).
//!
//! Classic byte-pair encoding: start from the character alphabet of the
//! training sample, repeatedly merge the most frequent adjacent pair
//! until the target vocabulary size is reached. Ids are assigned by
//! *descending frequency*, mirroring the SentencePiece property the
//! paper uses in Fig. 10 ("lower token ids generally correspond to more
//! frequent tokens") — that correspondence is what makes the LM-head
//! column-norm plots comparable.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// token id -> string
    pub vocab: Vec<String>,
    /// string -> id
    index: HashMap<String, u32>,
    max_len: usize,
}

impl Tokenizer {
    /// Learn a BPE vocabulary of `vocab_size` tokens from `text`.
    pub fn train(text: &str, vocab_size: usize) -> Tokenizer {
        // working sequence of token strings
        let mut seq: Vec<String> = text.chars().map(|c| c.to_string()).collect();
        let mut alphabet: Vec<String> = {
            let mut set: Vec<String> = seq.clone();
            set.sort();
            set.dedup();
            set
        };
        assert!(
            vocab_size > alphabet.len(),
            "vocab {} must exceed alphabet {}",
            vocab_size,
            alphabet.len()
        );
        let mut tokens: Vec<String> = alphabet.drain(..).collect();

        while tokens.len() < vocab_size {
            // count adjacent pairs
            let mut counts: HashMap<(&str, &str), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0].as_str(), w[1].as_str())).or_insert(0) += 1;
            }
            let Some((&(a, b), &n)) = counts
                .iter()
                .max_by_key(|(&(a, b), &n)| (n, std::cmp::Reverse((a, b))))
            else {
                break;
            };
            if n < 2 {
                break; // nothing worth merging
            }
            let merged = format!("{a}{b}");
            let (a, b) = (a.to_string(), b.to_string());
            tokens.push(merged.clone());
            // apply the merge in one pass
            let mut out = Vec::with_capacity(seq.len());
            let mut i = 0;
            while i < seq.len() {
                if i + 1 < seq.len() && seq[i] == a && seq[i + 1] == b {
                    out.push(merged.clone());
                    i += 2;
                } else {
                    out.push(std::mem::take(&mut seq[i]));
                    i += 1;
                }
            }
            seq = out;
        }

        // frequency-ranked ids: retokenize the sample and count
        let mut tok = Tokenizer::from_tokens(tokens);
        let ids = tok.encode(text);
        let mut counts = vec![0usize; tok.vocab.len()];
        for &id in &ids {
            counts[id as usize] += 1;
        }
        let mut order: Vec<usize> = (0..tok.vocab.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(counts[i]));
        let vocab: Vec<String> = order.iter().map(|&i| tok.vocab[i].clone()).collect();
        tok = Tokenizer::from_tokens(vocab);
        tok
    }

    fn from_tokens(vocab: Vec<String>) -> Tokenizer {
        let max_len = vocab.iter().map(|t| t.len()).max().unwrap_or(1);
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, t)| (t.clone(), i as u32))
            .collect();
        Tokenizer {
            vocab,
            index,
            max_len,
        }
    }

    /// Greedy longest-match encoding. Characters outside the alphabet are
    /// skipped (the corpus generator never emits them).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let chars: Vec<char> = text.chars().collect();
        let mut out = Vec::with_capacity(chars.len() / 2);
        let mut i = 0;
        while i < chars.len() {
            let mut matched = None;
            let end = (i + self.max_len).min(chars.len());
            let mut candidate = String::new();
            let mut lens = Vec::new();
            for j in i..end {
                candidate.push(chars[j]);
                lens.push(candidate.len());
                if let Some(&id) = self.index.get(&candidate) {
                    matched = Some((id, j + 1));
                }
            }
            match matched {
                Some((id, next)) => {
                    out.push(id);
                    i = next;
                }
                None => {
                    i += 1; // unknown char: skip
                }
            }
        }
        out
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.vocab[i as usize].as_str())
            .collect()
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};
    use crate::util::prop;

    fn sample() -> String {
        Corpus::new(CorpusConfig::default(), 1).text(30_000, 0)
    }

    #[test]
    fn trains_to_target_vocab() {
        let t = Tokenizer::train(&sample(), 300);
        assert_eq!(t.vocab_size(), 300);
    }

    #[test]
    fn roundtrip_on_corpus_text() {
        let text = sample();
        let t = Tokenizer::train(&text, 300);
        let held_out = Corpus::new(CorpusConfig::default(), 1).text(5_000, 7);
        let ids = t.encode(&held_out);
        assert_eq!(t.decode(&ids), held_out);
    }

    #[test]
    fn roundtrip_property() {
        let text = sample();
        let t = Tokenizer::train(&text, 256);
        let corpus = Corpus::new(CorpusConfig::default(), 1);
        prop::check("tokenizer-roundtrip", 16, |rng| {
            let shard = rng.next_u32() as u64 % 100;
            let n = prop::usize_in(rng, 10, 2000);
            let s = corpus.text(n, shard);
            prop::ensure(t.decode(&t.encode(&s)) == s, "roundtrip mismatch")
        });
    }

    #[test]
    fn compresses_relative_to_chars() {
        let text = sample();
        let t = Tokenizer::train(&text, 400);
        let ids = t.encode(&text);
        assert!(
            ids.len() * 2 < text.chars().count(),
            "BPE should compress >=2x: {} ids for {} chars",
            ids.len(),
            text.len()
        );
    }

    #[test]
    fn ids_are_frequency_ranked() {
        let text = sample();
        let t = Tokenizer::train(&text, 300);
        let ids = t.encode(&text);
        let mut counts = vec![0usize; 300];
        for &i in &ids {
            counts[i as usize] += 1;
        }
        // head ids should be (weakly) more frequent than tail ids
        let head: usize = counts[..30].iter().sum();
        let tail: usize = counts[270..].iter().sum();
        assert!(head > 10 * tail.max(1), "head {head} tail {tail}");
    }
}
