//! Data pipeline: synthetic C4-sim corpus -> BPE tokenizer -> batcher.
//!
//! [`pipeline`] bundles the three for the trainer: it trains the
//! tokenizer once per (corpus seed, vocab) pair and hands out shard-aware
//! batches.

pub mod batcher;
pub mod corpus;
pub mod tokenizer;

pub use batcher::Batcher;
pub use corpus::{Corpus, CorpusConfig};
pub use tokenizer::Tokenizer;

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// Build the standard data pipeline for a model vocabulary size.
/// The tokenizer is trained to ~vocab tokens on a held-out shard.
///
/// BPE training costs seconds, and experiment sweeps construct many
/// Trainers over the same (vocab, seed) pair — results are memoized
/// process-wide (EXPERIMENTS.md §Perf L3-1). Each key memoizes through
/// its own `OnceLock`, so concurrent sweep trials that race on the same
/// (vocab, seed) share ONE build (losers block on the winner's cell)
/// while distinct keys still build in parallel.
pub fn pipeline(vocab: usize, seed: u64) -> (Arc<Corpus>, Arc<Tokenizer>) {
    type Entry = Arc<OnceLock<(Arc<Corpus>, Arc<Tokenizer>)>>;
    static CACHE: OnceLock<Mutex<HashMap<(usize, u64), Entry>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let entry = cache
        .lock()
        .unwrap()
        .entry((vocab, seed))
        .or_insert_with(|| Arc::new(OnceLock::new()))
        .clone();
    entry
        .get_or_init(|| {
            let built = pipeline_uncached(vocab, seed);
            (Arc::new(built.0), Arc::new(built.1))
        })
        .clone()
}

/// The uncached construction (exposed for benchmarking the real cost).
pub fn pipeline_uncached(vocab: usize, seed: u64) -> (Corpus, Tokenizer) {
    let corpus = Corpus::new(CorpusConfig::default(), seed);
    // train the tokenizer on a dedicated shard never used for batches
    let sample = corpus.text(60_000, u64::MAX - 1);
    let tokenizer = Tokenizer::train(&sample, vocab.min(2048));
    (corpus, tokenizer)
}
