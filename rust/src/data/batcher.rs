//! Batcher: token stream -> `[B, S+1]` i32 batches for fwd_bwd/eval.
//!
//! Each DDP shard owns an independent (seeded) corpus stream; the
//! batcher maintains a rolling token buffer per shard and cuts dense
//! next-token-prediction windows from it (packing, no padding — the same
//! convention as the paper's GaLore-derived training setup).

use crate::data::corpus::Corpus;
use crate::data::tokenizer::Tokenizer;
use crate::runtime::Tensor;

pub struct Batcher<'a> {
    corpus: &'a Corpus,
    tokenizer: &'a Tokenizer,
    vocab_cap: u32,
    /// rolling buffers, one per shard
    buffers: Vec<Vec<u32>>,
    /// chars generated so far per shard (stream position)
    positions: Vec<usize>,
    chunk_chars: usize,
    pub tokens_served: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(
        corpus: &'a Corpus,
        tokenizer: &'a Tokenizer,
        vocab_cap: usize,
        shards: usize,
    ) -> Batcher<'a> {
        Batcher {
            corpus,
            tokenizer,
            vocab_cap: vocab_cap as u32,
            buffers: vec![Vec::new(); shards],
            positions: vec![0; shards],
            chunk_chars: 8192,
            tokens_served: 0,
        }
    }

    fn refill(&mut self, shard: usize, need: usize) {
        while self.buffers[shard].len() < need {
            let pos = self.positions[shard];
            // stream chunks from a shard-specific substream; the substream
            // index advances with position so text never repeats
            let sub = (shard as u64) << 32 | (pos / self.chunk_chars) as u64;
            let text = self.corpus.text(self.chunk_chars, sub);
            self.positions[shard] = pos + self.chunk_chars;
            let ids = self.tokenizer.encode(&text);
            self.buffers[shard]
                .extend(ids.into_iter().map(|i| i.min(self.vocab_cap - 1)));
        }
    }

    /// Next `[b, seq_len + 1]` batch for `shard`.
    pub fn next_batch(&mut self, shard: usize, b: usize, seq_len: usize) -> Tensor {
        let w = seq_len + 1;
        self.refill(shard, b * w);
        let buf = &mut self.buffers[shard];
        let data: Vec<i32> = buf.drain(..b * w).map(|x| x as i32).collect();
        self.tokens_served += (b * seq_len) as u64;
        Tensor::from_i32(&[b, w], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{Corpus, CorpusConfig};

    fn setup() -> (Corpus, Tokenizer) {
        let corpus = Corpus::new(CorpusConfig::default(), 1);
        let tok = Tokenizer::train(&corpus.text(20_000, 0), 256);
        (corpus, tok)
    }

    #[test]
    fn batches_have_shape_and_range() {
        let (corpus, tok) = setup();
        let mut b = Batcher::new(&corpus, &tok, 256, 2);
        let t = b.next_batch(0, 4, 32);
        assert_eq!(t.shape(), &[4, 33]);
        assert!(t.i32s().iter().all(|&x| (0..256).contains(&x)));
    }

    #[test]
    fn shards_get_different_data() {
        let (corpus, tok) = setup();
        let mut b = Batcher::new(&corpus, &tok, 256, 2);
        let a = b.next_batch(0, 2, 16);
        let c = b.next_batch(1, 2, 16);
        assert_ne!(a.i32s(), c.i32s());
    }

    #[test]
    fn stream_does_not_repeat() {
        let (corpus, tok) = setup();
        let mut b = Batcher::new(&corpus, &tok, 256, 1);
        let a = b.next_batch(0, 2, 16);
        let c = b.next_batch(0, 2, 16);
        assert_ne!(a.i32s(), c.i32s());
    }

    #[test]
    fn deterministic_across_instances() {
        let (corpus, tok) = setup();
        let mut b1 = Batcher::new(&corpus, &tok, 256, 1);
        let mut b2 = Batcher::new(&corpus, &tok, 256, 1);
        assert_eq!(b1.next_batch(0, 2, 16).i32s(), b2.next_batch(0, 2, 16).i32s());
    }

    #[test]
    fn vocab_cap_clamps() {
        let (corpus, tok) = setup();
        let mut b = Batcher::new(&corpus, &tok, 100, 1);
        let t = b.next_batch(0, 4, 32);
        assert!(t.i32s().iter().all(|&x| x < 100));
    }

    #[test]
    fn counts_tokens() {
        let (corpus, tok) = setup();
        let mut b = Batcher::new(&corpus, &tok, 256, 1);
        b.next_batch(0, 4, 32);
        assert_eq!(b.tokens_served, 128);
    }
}
