//! `c4sim`: a deterministic synthetic stand-in for the C4 corpus.
//!
//! The substitution (DESIGN.md §3) must preserve the two statistics the
//! paper's analysis leans on:
//!   1. **heavy-tailed unigram frequencies** (Zipf) — Appendix M traces
//!      column-norm skew in the LM-head gradient to frequent tokens;
//!   2. **learnable sequential structure** — loss must be reducible below
//!      the unigram entropy so optimizer quality separates (Fig. 2/9).
//!
//! Construction: a seeded random "vocabulary" of words over a byte
//! alphabet with Zipf-ranked frequencies, emitted through a sparse
//! first-order Markov chain (each word has a small successor set, making
//! bigrams informative), with sentence/document delimiters. The text
//! stream is what the tokenizer consumes — the pipeline exercises real
//! text handling end to end.

use crate::util::rng::{Pcg, Zipf};

#[derive(Debug, Clone)]
pub struct CorpusConfig {
    /// distinct words in the generator's vocabulary
    pub n_words: usize,
    /// Zipf exponent for word frequencies (C4-like ~ 1.1-1.3)
    pub zipf_s: f64,
    /// successors per word in the Markov chain
    pub branching: usize,
    /// probability of following the chain vs. resampling from Zipf
    pub chain_p: f64,
    /// mean words per sentence
    pub sentence_len: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            n_words: 2000,
            zipf_s: 1.2,
            branching: 4,
            chain_p: 0.75,
            sentence_len: 12,
        }
    }
}

pub struct Corpus {
    words: Vec<String>,
    zipf: Zipf,
    successors: Vec<Vec<u32>>,
    cfg: CorpusConfig,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Corpus {
        let mut rng = Pcg::with_stream(seed, 0xC0_4515);
        let mut words = Vec::with_capacity(cfg.n_words);
        let alphabet = b"abcdefghijklmnopqrstuvwxyz";
        let mut seen = std::collections::HashSet::new();
        while words.len() < cfg.n_words {
            let len = 2 + rng.below(7) as usize;
            let w: String = (0..len)
                .map(|_| alphabet[rng.below(26) as usize] as char)
                .collect();
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        let successors = (0..cfg.n_words)
            .map(|_| {
                (0..cfg.branching)
                    .map(|_| rng.below(cfg.n_words as u32))
                    .collect()
            })
            .collect();
        let zipf = Zipf::new(cfg.n_words, cfg.zipf_s);
        Corpus {
            words,
            zipf,
            successors,
            cfg,
        }
    }

    /// Deterministic text stream for (seed, shard). Different shards are
    /// independent streams — this is what the DDP shards consume.
    pub fn text(&self, n_chars: usize, shard: u64) -> String {
        let mut rng = Pcg::with_stream(0x7e97, shard);
        let mut out = String::with_capacity(n_chars + 64);
        let mut word = self.zipf.sample(&mut rng);
        let mut in_sentence = 0usize;
        while out.len() < n_chars {
            out.push_str(&self.words[word]);
            in_sentence += 1;
            // sentence boundary?
            if rng.next_f64() < 1.0 / self.cfg.sentence_len as f64 && in_sentence > 2 {
                out.push('.');
                out.push(' ');
                in_sentence = 0;
                word = self.zipf.sample(&mut rng);
                continue;
            }
            out.push(' ');
            // follow the Markov chain or resample
            word = if rng.next_f64() < self.cfg.chain_p {
                let succ = &self.successors[word];
                succ[rng.below(succ.len() as u32) as usize] as usize
            } else {
                self.zipf.sample(&mut rng)
            };
        }
        out.truncate(n_chars);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_shard() {
        let c = Corpus::new(CorpusConfig::default(), 1);
        assert_eq!(c.text(500, 0), c.text(500, 0));
        assert_ne!(c.text(500, 0), c.text(500, 1));
    }

    #[test]
    fn heavy_tailed_word_frequencies() {
        let c = Corpus::new(CorpusConfig::default(), 1);
        let text = c.text(200_000, 0);
        let mut counts = std::collections::HashMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w.trim_end_matches('.')).or_insert(0usize) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = freqs.iter().sum();
        let top10: usize = freqs.iter().take(10).sum();
        // Zipf head dominance: top-10 words take >15% of tokens
        assert!(top10 * 100 / total > 15, "top10 share {}", top10 * 100 / total);
    }

    #[test]
    fn bigram_structure_is_informative() {
        // conditional entropy of the next word given current should be well
        // below the unigram entropy — that's what makes the corpus learnable
        let c = Corpus::new(CorpusConfig::default(), 1);
        let text = c.text(300_000, 0);
        let words: Vec<&str> = text.split_whitespace().collect();
        let mut uni = std::collections::HashMap::new();
        let mut bi = std::collections::HashMap::new();
        for w in words.windows(2) {
            *uni.entry(w[0]).or_insert(0f64) += 1.0;
            *bi.entry((w[0], w[1])).or_insert(0f64) += 1.0;
        }
        let n = (words.len() - 1) as f64;
        let h_uni: f64 = uni.values().map(|c| -(c / n) * (c / n).ln()).sum();
        let h_joint: f64 = bi.values().map(|c| -(c / n) * (c / n).ln()).sum();
        let h_cond = h_joint - h_uni;
        assert!(
            h_cond < 0.75 * h_uni,
            "H(next|cur)={h_cond:.3} vs H={h_uni:.3}"
        );
    }

    #[test]
    fn char_budget_respected() {
        let c = Corpus::new(CorpusConfig::default(), 2);
        assert_eq!(c.text(1234, 3).len(), 1234);
    }
}
