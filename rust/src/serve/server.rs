//! Transports for the serving engine: newline-JSON request lines in,
//! newline-JSON completion / error lines out, over stdin/stdout or a
//! minimal std-only TCP accept loop.
//!
//! A reader thread feeds lines into a channel so the scheduler can keep
//! decoding while the client types: the serve loop drains whatever
//! requests have arrived (without blocking), runs one engine tick, and
//! writes out whatever finished. It only blocks on input when the
//! engine is idle. EOF stops admission; in-flight sequences run to
//! completion before the loop exits.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpListener;
use std::sync::mpsc;
use std::thread;

use super::engine::{ServeEngine, ServeModel};
use super::{completion_line, error_line, parse_request};

#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// KV slabs to preallocate == max concurrent sequences.
    pub max_batch: usize,
    /// Suppress the stderr banner (stdout is protocol either way).
    pub quiet: bool,
}

/// Serve one connection's line stream until EOF + drained. Returns
/// `Err` only on a failed response write (client gone); the caller
/// decides what to do with the engine's in-flight work.
pub fn serve_conn<R, W>(
    engine: &mut ServeEngine<'_>,
    input: R,
    output: &mut W,
) -> std::io::Result<()>
where
    R: BufRead + Send + 'static,
    W: Write,
{
    let (tx, rx) = mpsc::channel::<String>();
    // the reader owns `input` and exits on EOF / read error / our drop
    // of `rx`; an early-error return leaves it parked until the client
    // side actually closes, which is the cheapest correct behavior here
    let reader = thread::spawn(move || {
        for line in input.lines() {
            let Ok(line) = line else { break };
            if tx.send(line).is_err() {
                break;
            }
        }
    });
    let mut open = true;
    loop {
        // ingest everything that has arrived, without blocking decode
        while open {
            match rx.try_recv() {
                Ok(line) => handle_line(engine, &line, output)?,
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => open = false,
            }
        }
        if engine.idle() {
            if !open {
                break;
            }
            // nothing in flight: block for the next request (or EOF)
            match rx.recv() {
                Ok(line) => handle_line(engine, &line, output)?,
                Err(_) => open = false,
            }
            continue;
        }
        engine.step();
        flush_finished(engine, output)?;
    }
    drop(rx);
    let _ = reader.join();
    Ok(())
}

fn handle_line<W: Write>(
    engine: &mut ServeEngine<'_>,
    line: &str,
    out: &mut W,
) -> std::io::Result<()> {
    let line = line.trim();
    if line.is_empty() {
        return Ok(());
    }
    let rejection = match parse_request(line) {
        Ok(req) => engine.submit(req).err(),
        Err(e) => Some(e),
    };
    if let Some(e) = rejection {
        writeln!(out, "{}", error_line(&e))?;
        out.flush()?;
    }
    Ok(())
}

fn flush_finished<W: Write>(engine: &mut ServeEngine<'_>, out: &mut W) -> std::io::Result<()> {
    let done = engine.take_finished();
    if done.is_empty() {
        return Ok(());
    }
    for c in &done {
        writeln!(out, "{}", completion_line(c))?;
    }
    out.flush()
}

/// `scale serve` default transport: the protocol over stdin/stdout
/// until EOF. The banner goes to stderr — stdout carries only protocol
/// lines.
pub fn run_stdio(model: &ServeModel, opts: &ServeOptions) -> anyhow::Result<()> {
    let mut engine = ServeEngine::new(model, opts.max_batch);
    if !opts.quiet {
        eprintln!(
            "scale serve: size {}, {} slabs, context {}, stdio",
            model.size_name(),
            opts.max_batch.max(1),
            model.max_seq()
        );
    }
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    serve_conn(&mut engine, BufReader::new(std::io::stdin()), &mut out)?;
    Ok(())
}

/// `scale serve --tcp ADDR`: a std-only accept loop, one connection at
/// a time, same line protocol per connection. The engine (and its warm
/// slabs) is reused across connections; a client that vanishes
/// mid-write gets its sequences evicted so the next connection starts
/// with every slab free.
pub fn run_tcp(model: &ServeModel, addr: &str, opts: &ServeOptions) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    if !opts.quiet {
        eprintln!(
            "scale serve: size {}, {} slabs, listening on {}",
            model.size_name(),
            opts.max_batch.max(1),
            listener.local_addr()?
        );
    }
    let mut engine = ServeEngine::new(model, opts.max_batch);
    for conn in listener.incoming() {
        let Ok(stream) = conn else { continue };
        let Ok(reader) = stream.try_clone().map(BufReader::new) else { continue };
        let mut out = stream;
        if serve_conn(&mut engine, reader, &mut out).is_err() {
            engine.evict_all();
            engine.take_finished();
        }
    }
    Ok(())
}
