//! The serving engine: model weights, per-request decode slabs, and the
//! continuous-batching scheduler.
//!
//! Slab ownership mirrors the training arena contract: a
//! [`ServeEngine`] preallocates `max_batch` [`Decoder`] slabs (KV cache
//! + decode workspace, fully sized for the model's context) into a
//! bounded `WsPool` free list. Admission *is* slab acquisition — a
//! request leaves the FIFO queue the moment a slab is free, joining the
//! running decode batch between rounds (continuous batching); eviction
//! (completion, deadline, client drop) resets the slab and returns it
//! for immediate reuse. Steady-state decode rounds therefore allocate
//! nothing (the gate in `benches/bench_throughput.rs`; the parallel
//! fan-out path allocates only its per-round task list, exactly like
//! the training fan-outs, and is bypassed below the `min_ops` gate).
//!
//! Determinism: each slot's floats, sampler scratch, and RNG live in
//! its own slab, and slots only ever fan out as whole-sequence tasks —
//! no cross-slot reduction exists, so a request's tokens are
//! bit-identical whatever the pool size, the batch composition, or the
//! slot it landed in (`rust/tests/serve_differential.rs`).

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::coordinator::Checkpoint;
use crate::exec::model::{self, DecodeWs, KvCache, ModelSpec, SampleCfg};
use crate::exec::program::WsPool;
use crate::exec::{native_init, native_manifest};
use crate::parallel::{self, WorkerPool};
use crate::runtime::artifact::SizeInfo;
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

use super::{Completion, Outcome, Request, RequestError};

/// Weights + dimensions for serving: either a fresh seeded init of a
/// manifest size or the parameter prefix of a training checkpoint.
pub struct ServeModel {
    info: SizeInfo,
    spec: ModelSpec,
    params: Vec<Tensor>,
}

impl ServeModel {
    /// Fresh seeded weights for a manifest size (same init scheme as
    /// training).
    pub fn init(size: &str, seed: u64) -> anyhow::Result<ServeModel> {
        let m = native_manifest(PathBuf::from("unused"));
        let info = m.size(size)?.clone();
        let params = native_init(&info, seed);
        Ok(ServeModel::from_parts(info, params))
    }

    /// Load trained weights from a checkpoint. Parameters are the
    /// leading tensors (optimizer state is not needed to serve); names
    /// and shapes are checked against the manifest before use.
    pub fn from_checkpoint(path: &Path) -> anyhow::Result<ServeModel> {
        let ckpt = Checkpoint::load(path)?;
        let m = native_manifest(PathBuf::from("unused"));
        let info = m.size(&ckpt.size)?.clone();
        let n = info.params.len();
        anyhow::ensure!(
            ckpt.tensors.len() >= n,
            "checkpoint holds {} tensors, size {:?} needs {} params",
            ckpt.tensors.len(),
            info.name,
            n
        );
        let mut params = Vec::with_capacity(n);
        for (ps, (name, t)) in info.params.iter().zip(&ckpt.tensors[..n]) {
            anyhow::ensure!(
                *name == ps.name && t.shape() == ps.shape.as_slice(),
                "checkpoint tensor {name:?} {:?} does not match manifest param {:?} {:?}",
                t.shape(),
                ps.name,
                ps.shape
            );
            params.push(t.clone());
        }
        Ok(ServeModel::from_parts(info, params))
    }

    fn from_parts(info: SizeInfo, params: Vec<Tensor>) -> ServeModel {
        let spec = ModelSpec::from_size(&info);
        ServeModel { info, spec, params }
    }

    pub fn size_name(&self) -> &str {
        &self.info.name
    }

    pub fn vocab(&self) -> usize {
        self.spec.vocab
    }

    /// KV capacity per sequence (the trained context length).
    pub fn max_seq(&self) -> usize {
        self.spec.seq
    }

    /// Training-kernel forward over one prefix (b = 1), returning the
    /// full `[len, vocab]` logits block: the oracle side of the decode
    /// differential. Allocates its own arena — never a serving path.
    pub fn full_forward_logits(
        &self,
        prefix: &[i32],
        pool: &WorkerPool,
        min_ops: usize,
    ) -> Vec<f32> {
        model::forward_logits(&self.spec, &self.params, prefix, pool, min_ops)
    }
}

/// One sequence's decode state: the pool-owned KV slab plus the decode
/// workspace and sampler scratch. Usable directly for single-stream
/// generation; [`ServeEngine`] owns a bounded set of these.
pub struct Decoder {
    cache: KvCache,
    ws: Box<DecodeWs>,
}

impl Decoder {
    pub fn new(model: &ServeModel) -> Decoder {
        Decoder { cache: KvCache::new(&model.spec), ws: Box::new(DecodeWs::new(&model.spec)) }
    }

    /// Forget the sequence; buffers are reused as-is.
    pub fn reset(&mut self) {
        self.cache.reset();
    }

    /// Tokens cached so far (== the next token's position).
    pub fn pos(&self) -> usize {
        self.cache.pos()
    }

    /// Append `toks` (prefill when several, decode when one) and return
    /// the logits for the last appended position — bit-identical to row
    /// `pos` of the training forward over the same prefix.
    pub fn extend(
        &mut self,
        model: &ServeModel,
        toks: &[i32],
        pool: &WorkerPool,
        min_ops: usize,
    ) -> &[f32] {
        model::extend(
            &model.spec,
            &model.params,
            toks,
            &mut self.cache,
            &mut self.ws,
            pool,
            min_ops,
        );
        &self.ws.logits
    }

    /// Draw the next token from the logits left by [`Decoder::extend`],
    /// using this slab's scratch (no allocation). `temperature == 0` is
    /// exact argmax; the draw is a pure function of (logits, knobs, rng
    /// state).
    pub fn sample(&mut self, temperature: f32, top_k: usize, top_p: f64, rng: &mut Pcg) -> i32 {
        let cfg = SampleCfg { temperature, top_k, top_p };
        let DecodeWs { logits, order, cdf, .. } = &mut *self.ws;
        model::sample_logits(logits, &cfg, rng, order, cdf) as i32
    }
}

/// One admitted request mid-generation.
struct Active {
    slab: Box<Decoder>,
    id: String,
    cfg: SampleCfg,
    rng: Pcg,
    max_new: usize,
    tokens: Vec<i32>,
    last: i32,
    deadline: Option<Instant>,
}

impl Active {
    /// Feed the last sampled token, sample the next. Every float and
    /// the RNG are private to this slot, so slots fan out to the pool
    /// as whole tasks without cross-talk.
    fn step_token(&mut self, model: &ServeModel, pool: &WorkerPool, min_ops: usize) {
        let fed = [self.last];
        self.slab.extend(model, &fed, pool, min_ops);
        let next =
            self.slab.sample(self.cfg.temperature, self.cfg.top_k, self.cfg.top_p, &mut self.rng);
        self.tokens.push(next);
        self.last = next;
    }
}

/// Continuous-batching scheduler over a bounded set of KV slabs. Drive
/// it with [`submit`](ServeEngine::submit) and
/// [`step`](ServeEngine::step); collect results with
/// [`take_finished`](ServeEngine::take_finished).
pub struct ServeEngine<'m> {
    model: &'m ServeModel,
    slabs: WsPool<Decoder>,
    active: Vec<Active>,
    queue: VecDeque<Request>,
    finished: Vec<Completion>,
    /// Test/bench hook: route kernels through an explicit pool +
    /// threshold instead of the shared pool and calibrated gate.
    exec: Option<(WorkerPool, usize)>,
    /// Per-token multiply-add estimate, for the slot fan-out gate.
    cost: usize,
}

impl<'m> ServeEngine<'m> {
    pub fn new(model: &'m ServeModel, max_batch: usize) -> ServeEngine<'m> {
        let slabs = WsPool::new();
        for _ in 0..max_batch.max(1) {
            slabs.put(Box::new(Decoder::new(model)));
        }
        let sp = &model.spec;
        let cost = sp.n_layers * (4 * sp.d * sp.d + 3 * sp.d * sp.d_ff) + sp.d * sp.vocab;
        ServeEngine {
            model,
            slabs,
            active: Vec::new(),
            queue: VecDeque::new(),
            finished: Vec::new(),
            exec: None,
            cost,
        }
    }

    /// Route all decode kernels through `pool` with a fixed `min_ops`
    /// threshold (tests sweep pool sizes; the default is the shared
    /// pool + calibrated threshold).
    pub fn set_exec(&mut self, pool: WorkerPool, min_ops: usize) {
        self.exec = Some((pool, min_ops));
    }

    /// Validate and enqueue one request. Admission into the running
    /// batch happens in [`step`](ServeEngine::step) as slabs free up.
    pub fn submit(&mut self, req: Request) -> Result<(), RequestError> {
        if req.prompt.is_empty() {
            return Err(RequestError::Invalid("empty prompt".into()));
        }
        if req.max_new == 0 {
            return Err(RequestError::Invalid("max_new must be >= 1".into()));
        }
        let cap = self.model.max_seq();
        if req.prompt.len() + req.max_new > cap {
            return Err(RequestError::Invalid(format!(
                "prompt ({}) + max_new ({}) exceeds the {cap}-token context",
                req.prompt.len(),
                req.max_new
            )));
        }
        let v = self.model.vocab() as i32;
        if let Some(&t) = req.prompt.iter().find(|&&t| t < 0 || t >= v) {
            return Err(RequestError::Invalid(format!("prompt token {t} outside vocab 0..{v}")));
        }
        if !req.temperature.is_finite() || req.temperature < 0.0 {
            return Err(RequestError::Invalid("temperature must be finite and >= 0".into()));
        }
        if !(req.top_p > 0.0 && req.top_p <= 1.0) {
            return Err(RequestError::Invalid("top_p must be in (0, 1]".into()));
        }
        self.queue.push_back(req);
        Ok(())
    }

    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.queue.is_empty()
    }

    /// Requests currently holding a slab.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// Requests queued behind the slab pool.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Drain finished and evicted requests, in the order they retired.
    pub fn take_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }

    /// Evict everything (client gone): every active request retires as
    /// [`Outcome::Disconnected`] with its partial tokens, slabs return
    /// to the pool, and the queue is dropped.
    pub fn evict_all(&mut self) {
        while !self.active.is_empty() {
            self.finish_at(0, Outcome::Disconnected);
        }
        self.queue.clear();
    }

    /// One scheduler tick: admit queued requests into free slabs
    /// (prefill + first sampled token), sweep deadlines and dropped
    /// clients, then run one decode round — one token per surviving
    /// sequence. Returns the number of tokens produced this tick.
    pub fn step(&mut self) -> usize {
        self.admit_ready();
        let now = Instant::now();
        // Eviction sweep in slot order: the `client_drop` / `deadline`
        // failpoints consume one hit per active slot per tick, in this
        // order, so chaos specs target slots deterministically.
        let mut i = 0;
        while i < self.active.len() {
            let dropped = crate::fault::fires("client_drop");
            let expired = crate::fault::fires("deadline")
                || self.active[i].deadline.is_some_and(|d| now >= d);
            if dropped {
                self.finish_at(i, Outcome::Disconnected);
            } else if expired {
                self.finish_at(i, Outcome::Deadline);
            } else {
                i += 1;
            }
        }
        let n = self.active.len();
        if n == 0 {
            return 0;
        }
        // field-level borrows: `pool` borrows only `self.exec` (or a
        // 'static pool) so the decode fan-out can borrow `self.active`
        let (pool, min_ops) = match &self.exec {
            Some((p, m)) => (p, *m),
            None => (parallel::shared(), parallel::tuned_min_ops()),
        };
        if n > 1 && pool.parallelism() > 1 && n * self.cost >= min_ops.max(1) {
            let model = self.model;
            let tasks: Vec<_> = self
                .active
                .iter_mut()
                .map(|a| move || a.step_token(model, pool, min_ops))
                .collect();
            pool.run(tasks);
        } else {
            for a in self.active.iter_mut() {
                a.step_token(self.model, pool, min_ops);
            }
        }
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].tokens.len() >= self.active[i].max_new {
                self.finish_at(i, Outcome::Ok);
            } else {
                i += 1;
            }
        }
        n
    }

    /// Admit while a queued request and a free slab both exist:
    /// prefill the prompt and sample the request's first token.
    fn admit_ready(&mut self) {
        while !self.queue.is_empty() {
            let Some(mut slab) = self.slabs.try_take() else { break };
            let req = self.queue.pop_front().expect("checked non-empty");
            slab.reset();
            let (pool, min_ops) = match &self.exec {
                Some((p, m)) => (p, *m),
                None => (parallel::shared(), parallel::tuned_min_ops()),
            };
            let cfg =
                SampleCfg { temperature: req.temperature, top_k: req.top_k, top_p: req.top_p };
            let mut rng = Pcg::new(req.seed);
            slab.extend(self.model, &req.prompt, pool, min_ops);
            let first = slab.sample(cfg.temperature, cfg.top_k, cfg.top_p, &mut rng);
            let mut tokens = Vec::with_capacity(req.max_new);
            tokens.push(first);
            let deadline = (req.deadline_ms > 0)
                .then(|| Instant::now() + Duration::from_millis(req.deadline_ms));
            self.active.push(Active {
                slab,
                id: req.id,
                cfg,
                rng,
                max_new: req.max_new,
                tokens,
                last: first,
                deadline,
            });
        }
        // a 1-token budget is complete straight out of prefill
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].tokens.len() >= self.active[i].max_new {
                self.finish_at(i, Outcome::Ok);
            } else {
                i += 1;
            }
        }
    }

    /// Retire `active[i]`: slab back to the free list (reset), tokens
    /// into the finished queue.
    fn finish_at(&mut self, i: usize, outcome: Outcome) {
        let mut a = self.active.remove(i);
        a.slab.reset();
        self.slabs.put(a.slab);
        self.finished.push(Completion { id: a.id, tokens: a.tokens, outcome });
    }
}
