//! Inference serving: KV-cache incremental decode behind a
//! continuous-batching scheduler, speaking newline-JSON.
//!
//! Three layers:
//!
//! * this module — the wire protocol: [`Request`] / [`Completion`] /
//!   [`RequestError`] and their newline-JSON encodings. A request line
//!   that cannot be parsed or validated becomes a typed error response,
//!   never a panic (chaos-drilled via the `req_malformed` failpoint).
//! * [`engine`] — [`ServeEngine`], the scheduler: a FIFO queue feeding
//!   a fixed set of pool-owned KV/decode slabs ([`Decoder`]), with new
//!   sequences admitted into the running decode batch as slots free
//!   (continuous batching), per-request wall-clock deadlines, and
//!   eviction that returns the slab for immediate reuse.
//! * [`server`] — the transports: `scale serve` runs the protocol over
//!   stdin/stdout or a minimal std-only TCP accept loop.
//!
//! Determinism carries over from training: decode logits are
//! bit-identical to the full training forward at every position
//! (`rust/tests/serve_differential.rs`), and sampling is a pure
//! function of (logits, sampling config, per-request seed) — so a
//! request's output tokens do not depend on pool size, batch
//! composition, or which slot it landed in.

pub mod engine;
pub mod server;

pub use engine::{Decoder, ServeEngine, ServeModel};

use crate::util::json::{self, Json};

/// One generation request: the unit the scheduler queues and admits.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: String,
    /// Prompt token ids (the repo has no tokenizer; clients send ids).
    pub prompt: Vec<i32>,
    /// Tokens to generate (>= 1).
    pub max_new: usize,
    /// 0 = greedy (exact argmax); otherwise softmax temperature.
    pub temperature: f32,
    /// 0 disables the top-k filter.
    pub top_k: usize,
    /// 1 disables the nucleus filter; otherwise in (0, 1].
    pub top_p: f64,
    /// Per-request sampling seed: same seed, same tokens, bit for bit.
    pub seed: u64,
    /// Wall-clock budget in ms from admission; 0 = no deadline.
    pub deadline_ms: u64,
}

/// Why a request left the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Generated its full `max_new` budget.
    Ok,
    /// Deadline expired mid-generation; tokens so far ride along.
    Deadline,
    /// Client vanished mid-generation; the slab was reclaimed.
    Disconnected,
}

/// A finished (or evicted) request, ready to serialize.
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: String,
    pub tokens: Vec<i32>,
    pub outcome: Outcome,
}

/// Typed request-level failures — every way a request can be refused
/// before it touches a KV slab. These become protocol error lines; a
/// hostile or truncated request must never panic the server.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// Unparseable JSON, or a missing / ill-typed field.
    Malformed(String),
    /// Well-formed but unservable: empty prompt, token id outside the
    /// vocabulary, prompt + budget past the KV capacity, bad sampling
    /// range.
    Invalid(String),
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::Malformed(m) => write!(f, "malformed request: {m}"),
            RequestError::Invalid(m) => write!(f, "invalid request: {m}"),
        }
    }
}

impl std::error::Error for RequestError {}

fn malformed(msg: &str) -> RequestError {
    RequestError::Malformed(msg.to_string())
}

/// Parse one request line. Field defaults: `max_new` 16, greedy
/// sampling, no deadline. The `req_malformed` failpoint forces the
/// malformed path so chaos tests drill the typed-error contract
/// without crafting hostile bytes.
pub fn parse_request(line: &str) -> Result<Request, RequestError> {
    if crate::fault::fires("req_malformed") {
        return Err(malformed("injected by failpoint req_malformed"));
    }
    let doc = json::parse(line).map_err(|e| RequestError::Malformed(e.to_string()))?;
    if doc.as_obj().is_none() {
        return Err(malformed("request must be a JSON object"));
    }
    let id = doc
        .get("id")
        .and_then(Json::as_str)
        .ok_or_else(|| malformed("missing string field \"id\""))?
        .to_string();
    let prompt_arr = doc
        .get("prompt")
        .and_then(Json::as_arr)
        .ok_or_else(|| malformed("missing array field \"prompt\""))?;
    let mut prompt = Vec::with_capacity(prompt_arr.len());
    for el in prompt_arr {
        let n = el.as_f64().ok_or_else(|| malformed("prompt entries must be numbers"))?;
        if n.fract() != 0.0 || !(0.0..=i32::MAX as f64).contains(&n) {
            return Err(malformed("prompt entries must be non-negative integers"));
        }
        prompt.push(n as i32);
    }
    let num = |key: &str, default: f64| -> Result<f64, RequestError> {
        match doc.get(key) {
            None => Ok(default),
            Some(v) => {
                v.as_f64().ok_or_else(|| malformed(&format!("field {key:?} must be a number")))
            }
        }
    };
    let max_new = num("max_new", 16.0)? as usize;
    let temperature = num("temperature", 0.0)? as f32;
    let top_k = num("top_k", 0.0)? as usize;
    let top_p = num("top_p", 1.0)?;
    let seed = num("seed", 0.0)? as u64;
    let deadline_ms = num("deadline_ms", 0.0)? as u64;
    Ok(Request { id, prompt, max_new, temperature, top_k, top_p, seed, deadline_ms })
}

/// Serialize one finished request as a response line.
pub fn completion_line(c: &Completion) -> String {
    let status = match c.outcome {
        Outcome::Ok => "ok",
        Outcome::Deadline => "deadline",
        Outcome::Disconnected => "disconnected",
    };
    Json::obj(vec![
        ("id", Json::str(&c.id)),
        ("status", Json::str(status)),
        ("tokens", Json::Arr(c.tokens.iter().map(|&t| Json::num(t as f64)).collect())),
    ])
    .to_string()
}

/// Serialize a rejected request as an error line.
pub fn error_line(err: &RequestError) -> String {
    let (kind, detail) = match err {
        RequestError::Malformed(m) => ("malformed", m),
        RequestError::Invalid(m) => ("invalid", m),
    };
    Json::obj(vec![
        ("status", Json::str("error")),
        ("kind", Json::str(kind)),
        ("detail", Json::str(detail)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_minimal_and_full_requests() {
        let r = parse_request(r#"{"id":"a","prompt":[1,2,3]}"#).unwrap();
        assert_eq!(r.id, "a");
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new, 16);
        assert_eq!(r.temperature, 0.0);
        assert_eq!((r.top_k, r.top_p, r.seed, r.deadline_ms), (0, 1.0, 0, 0));
        let full = r#"{"id":"b","prompt":[0],"max_new":4,"temperature":0.8,
                       "top_k":5,"top_p":0.9,"seed":42,"deadline_ms":250}"#;
        let r = parse_request(full).unwrap();
        assert_eq!(r.max_new, 4);
        assert_eq!(r.temperature, 0.8);
        assert_eq!((r.top_k, r.top_p, r.seed, r.deadline_ms), (5, 0.9, 42, 250));
    }

    #[test]
    fn parse_rejects_bad_lines_with_typed_errors() {
        for bad in [
            "not json",
            "[1,2,3]",
            r#"{"prompt":[1]}"#,
            r#"{"id":"x"}"#,
            r#"{"id":"x","prompt":["y"]}"#,
            r#"{"id":"x","prompt":[1.5]}"#,
            r#"{"id":"x","prompt":[-3]}"#,
            r#"{"id":"x","prompt":[1],"max_new":"lots"}"#,
        ] {
            match parse_request(bad) {
                Err(RequestError::Malformed(_)) => {}
                other => panic!("{bad:?} -> {other:?}, want Malformed"),
            }
        }
    }

    #[test]
    fn response_lines_round_trip_through_the_json_parser() {
        let c = Completion { id: "r1".into(), tokens: vec![5, 0, 63], outcome: Outcome::Ok };
        let doc = json::parse(&completion_line(&c)).unwrap();
        assert_eq!(doc.get("id").and_then(Json::as_str), Some("r1"));
        assert_eq!(doc.get("status").and_then(Json::as_str), Some("ok"));
        let toks = doc.get("tokens").and_then(Json::as_arr).unwrap();
        let got: Vec<i32> = toks.iter().map(|t| t.as_f64().unwrap() as i32).collect();
        assert_eq!(got, c.tokens);
        let e = error_line(&RequestError::Invalid("too long".into()));
        let doc = json::parse(&e).unwrap();
        assert_eq!(doc.get("status").and_then(Json::as_str), Some("error"));
        assert_eq!(doc.get("kind").and_then(Json::as_str), Some("invalid"));
    }
}
