//! The persistent worker pool: `std`-only threads created once and fed
//! type-erased jobs through a mutex-protected queue.
//!
//! See the module docs ([`super`]) for the determinism contract. The
//! implementation notes that matter for soundness live on [`WorkerPool::run`].

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Threads ever spawned by worker pools in this process (monotonic).
///
/// This is the zero-per-step-spawn acceptance gate: record the value,
/// drive N steps through the pool, and assert it has not moved —
/// `benches/bench_hot_path.rs` and the tests below both do.
static SPAWNED: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Spawns performed *by the current thread* — pool construction
    /// spawns on the constructing thread, and a regression where `run`
    /// spawned would land on the calling thread, so this isolates the
    /// assertion from unrelated pool constructions on parallel test
    /// threads.
    static SPAWNED_HERE: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Total pool worker threads spawned so far, process-wide.
pub fn threads_spawned() -> usize {
    SPAWNED.load(Ordering::SeqCst)
}

/// Pool worker threads spawned by the calling thread (race-free under
/// concurrent test execution; see `SPAWNED_HERE`).
pub fn threads_spawned_by_current_thread() -> usize {
    SPAWNED_HERE.with(|c| c.get())
}

/// A lifetime-erased task closure: the batch bookkeeping inside
/// [`WorkerPool::run`] guarantees the borrowed environment outlives
/// every job.
type Call = Box<dyn FnOnce() + Send + 'static>;

/// A queued unit of work, tagged with its batch so a submitting thread
/// only ever helps with *its own* batch (see [`WorkerPool::run`]).
struct Job {
    batch: u64,
    call: Call,
}

#[derive(Default)]
struct JobQueue {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    queue: Mutex<JobQueue>,
    work: Condvar,
    /// Monotonic batch-id source for `run` dispatches.
    next_batch: AtomicU64,
}

/// Completion latch for one `run` batch: counts outstanding jobs and
/// wakes the submitting thread when the last one lands.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            remaining: Mutex::new(n),
            done: Condvar::new(),
        }
    }

    fn count_down(&self) {
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn is_open(&self) -> bool {
        *self.remaining.lock().unwrap() > 0
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// Blocks on drop until the batch completes. This is the soundness
/// backstop for the lifetime erasure in [`WorkerPool::run`]: even if the
/// submitting thread unwinds between enqueue and join, the borrowed task
/// environment stays alive until every job has finished with it.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// A fixed set of worker threads created once and reused for every
/// dispatch. Construction is the only place threads are spawned; `run`
/// never spawns.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `workers` pool threads. `workers == 0` is valid and makes
    /// every `run` execute inline on the caller.
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            queue: Mutex::new(JobQueue::default()),
            work: Condvar::new(),
            next_batch: AtomicU64::new(0),
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                SPAWNED.fetch_add(1, Ordering::SeqCst);
                SPAWNED_HERE.with(|c| c.set(c.get() + 1));
                thread::Builder::new()
                    .name(format!("scale-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Worker threads owned by the pool (excluding the caller).
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Concurrent lanes a `run` can use: the workers plus the submitting
    /// thread, which executes its own batch's queued jobs while it waits.
    pub fn parallelism(&self) -> usize {
        self.handles.len() + 1
    }

    /// Execute `tasks` on the pool and return their results **in task
    /// order**, blocking until all have completed (`run` = submit + join).
    ///
    /// * Results are slotted by submission index, so the output order is
    ///   deterministic regardless of which worker runs what.
    /// * A panicking task does not kill its worker: the payload is
    ///   captured and re-raised here on the submitting thread, after the
    ///   whole batch has completed. With several panics, the
    ///   lowest-indexed payload is the one re-raised (deterministic).
    /// * Tasks may borrow the caller's stack (`'env`): `run` does not
    ///   return — or unwind — until every job has finished with those
    ///   borrows.
    /// * The caller participates: while waiting it drains *its own
    ///   batch's* queued jobs (never another dispatcher's — no
    ///   head-of-line blocking behind a foreign task), so a task that
    ///   itself calls `run` on the same pool cannot deadlock: every
    ///   nested batch can always be drained by its own submitter. A
    ///   zero-worker pool degenerates to inline execution with the same
    ///   all-tasks-run panic contract.
    pub fn run<'env, T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        F: FnOnce() -> T + Send + 'env,
        T: Send + 'env,
    {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        if n == 1 || self.handles.is_empty() {
            // nothing to overlap: run inline — with the same contract as
            // the pooled path (every task runs to completion, then the
            // lowest-indexed panic is re-raised), so side effects never
            // depend on the pool size
            let mut first_panic = None;
            let mut out = Vec::with_capacity(n);
            for task in tasks {
                match panic::catch_unwind(AssertUnwindSafe(|| {
                    if crate::fault::fires("pool_job") {
                        panic!("failpoint pool_job");
                    }
                    task()
                })) {
                    Ok(v) => out.push(v),
                    Err(p) => {
                        if first_panic.is_none() {
                            first_panic = Some(p);
                        }
                    }
                }
            }
            if let Some(p) = first_panic {
                panic::resume_unwind(p);
            }
            return out;
        }

        // per-job bookkeeping is *owned* (Arc) by each job, so the erased
        // closure's only borrowed state is the tasks' own 'env captures
        let batch = self.shared.next_batch.fetch_add(1, Ordering::Relaxed);
        let latch = Arc::new(Latch::new(n));
        let slots: Vec<Arc<Mutex<Option<thread::Result<T>>>>> =
            (0..n).map(|_| Arc::new(Mutex::new(None))).collect();
        let guard = WaitGuard(latch.as_ref());
        {
            let mut q = self.shared.queue.lock().unwrap();
            for (task, slot) in tasks.into_iter().zip(&slots) {
                let slot = Arc::clone(slot);
                let latch = Arc::clone(&latch);
                let call: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                    // the `pool_job` failpoint fires *inside* the catch,
                    // so an injected panic exercises exactly the capture
                    // path a real task panic takes
                    let result = panic::catch_unwind(AssertUnwindSafe(|| {
                        if crate::fault::fires("pool_job") {
                            panic!("failpoint pool_job");
                        }
                        task()
                    }));
                    *slot.lock().unwrap() = Some(result);
                    latch.count_down();
                });
                // SAFETY: the transmute only erases 'env; layout is
                // unchanged. The job's captures are its own Arcs plus the
                // task's 'env environment, and `help_until` below — with
                // `guard` as the unwind-path backstop — blocks until
                // `latch` reports every job in this batch complete, so
                // the 'env borrows can never dangle while a worker still
                // holds the erased closure.
                let call: Call = unsafe {
                    std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Call>(call)
                };
                q.jobs.push_back(Job { batch, call });
            }
        }
        self.shared.work.notify_all();
        self.help_until(&latch, batch);
        // the batch is complete; the guard's drop-wait is a no-op
        drop(guard);

        // every count_down happened after its slot store (program order)
        // and before our latch wait returned (latch mutex), so the takes
        // below observe every result
        let mut first_panic = None;
        let mut out = Vec::with_capacity(n);
        for slot in &slots {
            match slot.lock().unwrap().take() {
                Some(Ok(v)) => out.push(v),
                Some(Err(p)) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
                None => unreachable!("batch latch released with a result missing"),
            }
        }
        if let Some(p) = first_panic {
            panic::resume_unwind(p);
        }
        out
    }

    /// Drain this batch's queued jobs on the calling thread until
    /// `latch` opens, then sleep on the latch once none of them are left
    /// in the queue (the stragglers are in flight on workers). Only jobs
    /// tagged with `batch` are taken — helping with a foreign batch's
    /// job would block this dispatcher behind work it does not own.
    fn help_until(&self, latch: &Latch, batch: u64) {
        loop {
            if !latch.is_open() {
                return;
            }
            let job = {
                let mut q = self.shared.queue.lock().unwrap();
                match q.jobs.iter().position(|j| j.batch == batch) {
                    Some(idx) => q.jobs.remove(idx),
                    None => None,
                }
            };
            match job {
                Some(job) => (job.call)(),
                None => {
                    latch.wait();
                    return;
                }
            }
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.shutdown {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        // the call catches its own panics, so the worker never unwinds
        // and the queue mutex is never poisoned
        (job.call)();
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.queue.lock().unwrap().shutdown = true;
        self.shared.work.notify_all();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn results_arrive_in_submission_order() {
        let pool = WorkerPool::new(4);
        // reverse-staggered sleeps: completion order is roughly the
        // reverse of submission order, results must still be 0..n
        let tasks: Vec<_> = (0..8u64)
            .map(|i| {
                move || {
                    thread::sleep(Duration::from_millis(2 * (8 - i)));
                    i
                }
            })
            .collect();
        assert_eq!(pool.run(tasks), (0..8).collect::<Vec<u64>>());
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom-from-task")),
            Box::new(|| 3),
        ];
        let caught = panic::catch_unwind(AssertUnwindSafe(|| pool.run(tasks)));
        let payload = caught.expect_err("task panic must propagate to run()");
        let msg = payload
            .downcast_ref::<&str>()
            .copied()
            .unwrap_or("non-str payload");
        assert!(msg.contains("boom-from-task"), "payload: {msg}");
        // the pool must stay fully usable after a propagated panic
        let ok: Vec<usize> = pool.run((0..6).map(|i| move || i * i).collect());
        assert_eq!(ok, vec![0, 1, 4, 9, 16, 25]);
    }

    #[test]
    fn lowest_index_panic_wins() {
        let pool = WorkerPool::new(3);
        for _ in 0..8 {
            let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                Box::new(|| panic!("first")),
                Box::new(|| panic!("second")),
                Box::new(|| 0),
            ];
            let payload =
                panic::catch_unwind(AssertUnwindSafe(|| pool.run(tasks))).expect_err("must panic");
            let msg = payload.downcast_ref::<&str>().copied().unwrap_or("?");
            assert_eq!(msg, "first", "propagated payload must be deterministic");
        }
    }

    #[test]
    fn reuse_across_100_simulated_steps_spawns_nothing() {
        let pool = WorkerPool::new(4);
        let spawned_after_construction = threads_spawned_by_current_thread();
        let mut acc = 0u64;
        for step in 0..100u64 {
            // a "step": fan out 8 tasks, join, fold the results
            let parts: Vec<u64> = pool.run((0..8u64).map(|s| move || step * 100 + s).collect());
            acc += parts.iter().sum::<u64>();
        }
        assert_eq!(
            threads_spawned_by_current_thread(),
            spawned_after_construction,
            "run() must never spawn threads after pool construction"
        );
        // sum over steps of (800*step + 28)
        let want: u64 = (0..100u64).map(|s| 800 * s + 28).sum();
        assert_eq!(acc, want);
    }

    #[test]
    fn tasks_may_borrow_and_mutate_stack_data() {
        let pool = WorkerPool::new(3);
        let mut data = vec![0u32; 64];
        {
            let tasks: Vec<_> = data
                .chunks_mut(16)
                .enumerate()
                .map(|(i, chunk)| {
                    move || {
                        for (j, x) in chunk.iter_mut().enumerate() {
                            *x = (i * 16 + j) as u32;
                        }
                    }
                })
                .collect();
            pool.run(tasks);
        }
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i as u32);
        }
    }

    #[test]
    fn nested_run_on_same_pool_makes_progress() {
        // more outer tasks than workers, each dispatching an inner batch:
        // the caller-helping loop must drain the queue instead of
        // deadlocking on exhausted workers
        let pool = WorkerPool::new(2);
        let outer: Vec<u64> = pool.run(
            (0..6u64)
                .map(|i| {
                    let pool = &pool;
                    move || {
                        let inner_tasks: Vec<_> = (0..4u64).map(|j| move || i * 10 + j).collect();
                        let inner: Vec<u64> = pool.run(inner_tasks);
                        inner.iter().sum()
                    }
                })
                .collect(),
        );
        let want: Vec<u64> = (0..6u64).map(|i| 4 * (i * 10) + 6).collect();
        assert_eq!(outer, want);
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.parallelism(), 1);
        let out = pool.run((0..5usize).map(|i| move || i + 1).collect());
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn inline_path_honors_all_tasks_run_panic_contract() {
        // a panicking task must not stop later tasks, whatever the pool
        // size — side effects are identical inline and pooled
        for workers in [0usize, 2] {
            let pool = WorkerPool::new(workers);
            let ran_after = AtomicU64::new(0);
            let tasks: Vec<_> = (0..3u64)
                .map(|i| {
                    let ran_after = &ran_after;
                    move || {
                        if i == 0 {
                            panic!("early");
                        }
                        ran_after.fetch_add(1, Ordering::SeqCst);
                        i
                    }
                })
                .collect();
            let caught = panic::catch_unwind(AssertUnwindSafe(|| pool.run(tasks)));
            assert!(caught.is_err(), "panic must propagate ({workers} workers)");
            assert_eq!(
                ran_after.load(Ordering::SeqCst),
                2,
                "all tasks must run despite the panic ({workers} workers)"
            );
        }
    }

    #[test]
    fn concurrent_dispatchers_get_their_own_results() {
        // several threads share one pool; batch tagging must keep every
        // dispatcher's results correct and its helping confined to its
        // own batch
        let pool = WorkerPool::new(3);
        thread::scope(|s| {
            for t in 0..4u64 {
                let pool = &pool;
                s.spawn(move || {
                    for step in 0..25u64 {
                        let base = t * 1_000 + step * 10;
                        let tasks: Vec<_> = (0..6u64).map(|i| move || base + i).collect();
                        let got = pool.run(tasks);
                        let want: Vec<u64> = (0..6u64).map(|i| base + i).collect();
                        assert_eq!(got, want, "dispatcher {t} step {step}");
                    }
                });
            }
        });
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let pool = WorkerPool::new(1);
        let out: Vec<u8> = pool.run(Vec::<fn() -> u8>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn heavy_concurrent_counting_is_exact() {
        // many small batches with shared atomics: no lost jobs, no
        // double-executed jobs
        let pool = WorkerPool::new(4);
        let counter = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(
                (0..16)
                    .map(|_| {
                        let counter = &counter;
                        move || {
                            counter.fetch_add(1, Ordering::Relaxed);
                        }
                    })
                    .collect(),
            );
        }
        assert_eq!(counter.load(Ordering::Relaxed), 50 * 16);
    }

    #[test]
    fn shared_pool_is_created_once() {
        let a = super::super::shared() as *const WorkerPool;
        let before = threads_spawned_by_current_thread();
        let b = super::super::shared() as *const WorkerPool;
        assert_eq!(a, b, "shared() must return the same pool");
        assert_eq!(
            threads_spawned_by_current_thread(),
            before,
            "a second shared() call must not respawn"
        );
    }
}
