//! Persistent worker-pool runtime: the parallel substrate for every
//! per-step fan-out in the coordinator, the tiled optimizer kernels,
//! and the native executor's GEMMs.
//!
//! `std`-only by design (this build environment has no external crates):
//! a fixed set of worker threads created **once** — at pool construction,
//! never on the step path — fed through a mutex-protected job queue, with
//! a scoped `run` (= submit + join) entry point that supports borrowed
//! task environments, exactly like `std::thread::scope` but without the
//! per-call thread spawns. `coordinator::Trainer` (shard fwd/bwd, batch
//! tokenization, ring refill), `coordinator::ddp::tree_all_reduce`, the
//! `optim` `*_par` kernels, `exec::gemm`, the per-(batch, head)
//! attention fan-out in `exec::model`, and whole sweep trials
//! (`coordinator::sweep` — one training per job, its per-step fan-outs
//! as nested batches) all dispatch through one pool.
//!
//! Nesting is safe by construction: jobs are batch-tagged, and a
//! waiting submitter only ever drains jobs from *its own* batch, so a
//! job that dispatches a nested batch can always complete that batch
//! itself even when every worker is busy — trial-level and intra-trial
//! parallelism compose without deadlock or head-of-line blocking.
//!
//! # Determinism guarantees
//!
//! Scheduling is *not* deterministic — which worker runs which task, and
//! in what interleaving, varies run to run. The pool's contract is that
//! none of that nondeterminism can leak into results:
//!
//! * **Result ordering.** [`WorkerPool::run`] returns results slotted by
//!   submission index. Output `i` is task `i`'s return value, always.
//! * **Panic determinism.** A task panic is captured, the rest of the
//!   batch still runs to completion, and the panic payload with the
//!   lowest task index is re-raised at the `run` call site.
//! * **No hidden reassociation.** The pool never splits, merges, or
//!   reorders the *work inside* a task. Callers that need bit-identical
//!   float results (tree reduction columns, column-tiled norm kernels,
//!   GEMM row blocks) get them by partitioning work into tasks whose
//!   internal operation order matches the sequential implementation —
//!   the pool only decides *when* each task runs, never what it
//!   computes. See `optim::colnorm`, `coordinator::ddp`, `exec::gemm`,
//!   and `exec::model` (attention pair blocks) for the property tests
//!   that pin this down.
//!
//! # Threshold calibration
//!
//! Whether a kernel dispatches to the pool at all is gated on a
//! work-size threshold in float ops: below it, dispatch latency (~µs)
//! dominates the arithmetic. [`calibrate`] measures the *actual*
//! dispatch latency of a pool and the single-thread per-op throughput,
//! and [`tuned_min_ops`] memoizes that measurement for the shared pool —
//! replacing the two hard-coded constants (`optim`'s `PAR_MIN_ELEMS`,
//! ddp's old `PAR_THRESHOLD`) that PR 2 deferred. Every `_with` kernel
//! variant takes the threshold explicitly; the property tests sweep it
//! across the boundary to pin down that it selects a code path, never a
//! result.
//!
//! # Spawn accounting
//!
//! [`threads_spawned`] (and its per-thread variant) counts every worker
//! the pool module has ever created. After construction the count must
//! stay flat across any number of `run` calls — the zero-per-step-spawn
//! acceptance gate enforced in `benches/bench_hot_path.rs`,
//! `benches/bench_throughput.rs`, and the pool tests.

mod pool;

pub use pool::{threads_spawned, threads_spawned_by_current_thread, WorkerPool};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

static SHARED: OnceLock<WorkerPool> = OnceLock::new();

/// Upper bound on shared-pool workers; beyond this the queue lock
/// outweighs the extra lanes for the tensor sizes this crate handles.
const MAX_SHARED_WORKERS: usize = 15;

/// The process-wide shared pool, created on first use and reused by
/// every `Trainer`/`Engine` consumer for the life of the process
/// (sweeps construct many trainers; sharing one pool keeps the thread
/// count flat instead of multiplying it per run). Sized to
/// `available_parallelism - 1` workers — the dispatching thread is the
/// extra lane — capped at `MAX_SHARED_WORKERS`.
pub fn shared() -> &'static WorkerPool {
    SHARED.get_or_init(|| WorkerPool::new(default_workers()))
}

fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .saturating_sub(1)
        .min(MAX_SHARED_WORKERS)
}

static TUNED: OnceLock<usize> = OnceLock::new();
static OVERRIDE_OPS: AtomicUsize = AtomicUsize::new(0);

/// Force a process-wide threshold (benches pin `usize::MAX` to audit the
/// sequential path's allocations, `Some(1)` — or `Some(0)`, which is
/// clamped to 1 and means the same thing — to force pool dispatch);
/// `None` restores the calibrated value. Thresholds select a code path,
/// never a result, so this can never change any computed number.
pub fn set_min_ops_override(ops: Option<usize>) {
    // 0 is the internal "no override" sentinel; a caller passing
    // Some(0) clearly wants everything parallel, which 1 also delivers
    // (every kernel gates on `work < min_ops.max(1)`)
    OVERRIDE_OPS.store(ops.map_or(0, |o| o.max(1)), Ordering::SeqCst);
}

/// The sequential-fallback threshold in float ops (elements for
/// elementwise kernels, `m*n*k` for GEMM): calibrated once against the
/// shared pool and memoized for the life of the process.
pub fn tuned_min_ops() -> usize {
    let o = OVERRIDE_OPS.load(Ordering::SeqCst);
    if o != 0 {
        return o;
    }
    *TUNED.get_or_init(|| calibrate(shared()))
}

/// Measure `pool`'s dispatch latency (best of 32 empty fan-outs) and the
/// single-thread per-op throughput of an L1-resident multiply-add pass,
/// and return the op count at which a parallel dispatch breaks even with
/// a 2x margin, clamped to `[2^12, 2^22]`. Costs ~1 ms; runs once per
/// process via [`tuned_min_ops`].
pub fn calibrate(pool: &WorkerPool) -> usize {
    if pool.workers() == 0 {
        return usize::MAX; // no extra lanes: parallel dispatch can never win
    }
    let lanes = pool.parallelism();
    let mut dispatch = Duration::MAX;
    for _ in 0..32 {
        let tasks: Vec<fn()> = (0..lanes).map(|_| (|| {}) as fn()).collect();
        let t0 = Instant::now();
        pool.run(tasks);
        dispatch = dispatch.min(t0.elapsed());
    }
    let n = 1 << 14;
    let mut y = vec![1.0f32; n];
    let x = vec![0.5f32; n];
    let passes = 64u32;
    let t0 = Instant::now();
    for p in 0..passes {
        let s = 1.0 + (p as f32) * 1e-9;
        for (yi, xi) in y.iter_mut().zip(&x) {
            *yi += s * xi;
        }
    }
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    std::hint::black_box(&y);
    let per_op = (elapsed / (passes as usize * n) as f64).max(1e-12);
    let min_ops = (2.0 * dispatch.as_secs_f64() / per_op) as usize;
    min_ops.clamp(1 << 12, 1 << 22)
}

#[cfg(test)]
mod calibrate_tests {
    use super::*;

    #[test]
    fn calibrated_threshold_is_in_band() {
        let pool = WorkerPool::new(2);
        let t = calibrate(&pool);
        assert!((1 << 12..=1 << 22).contains(&t), "threshold {t}");
    }

    #[test]
    fn zero_worker_pool_never_parallelizes() {
        let pool = WorkerPool::new(0);
        assert_eq!(calibrate(&pool), usize::MAX);
    }

    #[test]
    fn override_wins_and_clears() {
        set_min_ops_override(Some(12345));
        assert_eq!(tuned_min_ops(), 12345);
        set_min_ops_override(None);
        let t = tuned_min_ops();
        assert!(t >= 1 << 12, "tuned {t}");
        // memoized: a second call returns the identical value
        assert_eq!(tuned_min_ops(), t);
    }
}
