//! Persistent worker-pool runtime: the parallel substrate for every
//! per-step fan-out in the coordinator and the tiled optimizer kernels.
//!
//! `std`-only by design (this build environment has no external crates):
//! a fixed set of worker threads created **once** — at pool construction,
//! never on the step path — fed through a mutex-protected job queue, with
//! a scoped `run` (= submit + join) entry point that supports borrowed
//! task environments, exactly like `std::thread::scope` but without the
//! per-call thread spawns. `coordinator::Trainer` (shard fwd/bwd, batch
//! tokenization, ring refill), `coordinator::ddp::tree_all_reduce`, and
//! the `optim` `*_par` kernels all dispatch through one pool.
//!
//! # Determinism guarantees
//!
//! Scheduling is *not* deterministic — which worker runs which task, and
//! in what interleaving, varies run to run. The pool's contract is that
//! none of that nondeterminism can leak into results:
//!
//! * **Result ordering.** [`WorkerPool::run`] returns results slotted by
//!   submission index. Output `i` is task `i`'s return value, always.
//! * **Panic determinism.** A task panic is captured, the rest of the
//!   batch still runs to completion, and the panic payload with the
//!   lowest task index is re-raised at the `run` call site.
//! * **No hidden reassociation.** The pool never splits, merges, or
//!   reorders the *work inside* a task. Callers that need bit-identical
//!   float results (tree reduction columns, column-tiled norm kernels)
//!   get them by partitioning work into tasks whose internal operation
//!   order matches the sequential implementation — the pool only decides
//!   *when* each task runs, never what it computes. See
//!   `optim::colnorm` and `coordinator::ddp` for the property tests that
//!   pin this down.
//!
//! # Spawn accounting
//!
//! [`threads_spawned`] (and its per-thread variant) counts every worker
//! the pool module has ever created. After construction the count must
//! stay flat across any number of `run` calls — the zero-per-step-spawn
//! acceptance gate enforced in `benches/bench_hot_path.rs` and the pool
//! tests.

mod pool;

pub use pool::{threads_spawned, threads_spawned_by_current_thread, WorkerPool};

use std::sync::OnceLock;

static SHARED: OnceLock<WorkerPool> = OnceLock::new();

/// Upper bound on shared-pool workers; beyond this the queue lock
/// outweighs the extra lanes for the tensor sizes this crate handles.
const MAX_SHARED_WORKERS: usize = 15;

/// The process-wide shared pool, created on first use and reused by
/// every `Trainer`/`Engine` consumer for the life of the process
/// (sweeps construct many trainers; sharing one pool keeps the thread
/// count flat instead of multiplying it per run). Sized to
/// `available_parallelism - 1` workers — the dispatching thread is the
/// extra lane — capped at [`MAX_SHARED_WORKERS`].
pub fn shared() -> &'static WorkerPool {
    SHARED.get_or_init(|| WorkerPool::new(default_workers()))
}

fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .saturating_sub(1)
        .min(MAX_SHARED_WORKERS)
}
