//! Tiny CLI argument parser (no clap in this environment).
//!
//! Grammar: `scale <subcommand> [positional...] [--flag] [--key value]`.
//! `--key=value` is also accepted. Unknown flags are an error so typos
//! fail loudly. Note the one ambiguity of this grammar: a bare `--flag`
//! immediately followed by a positional is parsed as `--flag <value>`;
//! positionals therefore come before options (or use `--flag=`).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    out.opts
                        .insert(rest.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&mut self, key: &str) -> Option<&str> {
        self.known.push(key.to_string());
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_f64(&mut self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn get_usize(&mut self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.known.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Call after all get()/flag() lookups: errors on unrecognized input.
    pub fn finish(&self) -> anyhow::Result<()> {
        for k in self.opts.keys() {
            if !self.known.contains(k) {
                anyhow::bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                anyhow::bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_opts() {
        let args = sv(&["train", "extra", "--size", "s60m", "--steps=100", "--quiet"]);
        let mut a = Args::parse(&args).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get("size"), Some("s60m"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert!(a.flag("quiet"));
        assert_eq!(a.positional, vec!["extra"]);
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_errors() {
        let mut a = Args::parse(&sv(&["train", "--oops", "1"])).unwrap();
        let _ = a.get("size");
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults() {
        let mut a = Args::parse(&sv(&["x"])).unwrap();
        assert_eq!(a.get_or("opt", "scale"), "scale");
        assert_eq!(a.get_f64("lr", 1e-3).unwrap(), 1e-3);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn bad_number_errors() {
        let mut a = Args::parse(&sv(&["x", "--lr", "abc"])).unwrap();
        assert!(a.get_f64("lr", 0.0).is_err());
    }
}
