//! Mini benchmarking harness (criterion is unavailable offline).
//!
//! Usage mirrors criterion closely enough for `cargo bench` targets with
//! `harness = false`: warm up, collect wall-clock samples, report
//! mean / p50 / p95 / min plus a derived throughput line. Sample counts
//! adapt to the per-iteration cost so slow end-to-end benches stay fast.
//!
//! Besides the human tables, results serialize to JSON
//! ([`Bencher::write_json`] -> `BENCH_<name>.json`) so the perf
//! trajectory is machine-diffable across PRs.

use std::time::{Duration, Instant};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("samples", Json::num(self.samples as f64)),
            ("mean_ms", Json::num(self.mean.as_secs_f64() * 1e3)),
            ("p50_ms", Json::num(self.p50.as_secs_f64() * 1e3)),
            ("p95_ms", Json::num(self.p95.as_secs_f64() * 1e3)),
            ("min_ms", Json::num(self.min.as_secs_f64() * 1e3)),
        ])
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms mean   {:>10.3} ms p50   {:>10.3} ms p95   {:>10.3} ms min   ({} samples)",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.p50.as_secs_f64() * 1e3,
            self.p95.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.samples,
        )
    }
}

pub struct Bencher {
    /// Total time budget per benchmark (warmup + sampling).
    pub budget: Duration,
    pub max_samples: usize,
    pub results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_secs(3),
            max_samples: 200,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn with_budget(secs: f64) -> Self {
        Bencher {
            budget: Duration::from_secs_f64(secs),
            ..Default::default()
        }
    }

    /// Benchmark `f`, which performs ONE iteration of the workload.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup: one call always; keep warming until 10% of budget.
        let warm_budget = self.budget / 10;
        let t0 = Instant::now();
        f();
        while t0.elapsed() < warm_budget {
            f();
        }

        let sample_budget = self.budget - t0.elapsed().min(self.budget / 2);
        let mut samples: Vec<Duration> = Vec::new();
        let s0 = Instant::now();
        while s0.elapsed() < sample_budget && samples.len() < self.max_samples {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        if samples.is_empty() {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let stats = Self::summarize(name, &mut samples);
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    /// Benchmark with a derived-throughput report (items per second).
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        f: F,
    ) -> (Stats, f64) {
        let stats = self.bench(name, f);
        let thr = items_per_iter / stats.mean.as_secs_f64();
        println!("{:<40} {:>14.0} items/s", format!("{name} [throughput]"), thr);
        (stats, thr)
    }

    /// All collected results as a JSON document: `{"bench": <label>,
    /// "results": [...], "extra": {...}}`. `extra` carries bench-specific
    /// scalars (speedups, allocation counts) alongside the timing rows.
    pub fn to_json(&self, label: &str, extra: Vec<(&str, Json)>) -> Json {
        Json::obj(vec![
            ("bench", Json::str(label)),
            (
                "results",
                Json::Arr(self.results.iter().map(|s| s.to_json()).collect()),
            ),
            ("extra", Json::obj(extra)),
        ])
    }

    /// Write the JSON document next to the human tables; path convention
    /// is `BENCH_<name>.json` in the working directory.
    pub fn write_json(
        &self,
        path: impl AsRef<std::path::Path>,
        label: &str,
        extra: Vec<(&str, Json)>,
    ) -> anyhow::Result<()> {
        let doc = self.to_json(label, extra);
        std::fs::write(&path, doc.to_string())?;
        println!("bench json -> {}", path.as_ref().display());
        Ok(())
    }

    fn summarize(name: &str, samples: &mut [Duration]) -> Stats {
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        Stats {
            name: name.to_string(),
            samples: n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            min: samples[0],
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_orders_percentiles() {
        let mut b = Bencher::with_budget(0.05);
        let s = b.bench("spin", || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.samples >= 1);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn throughput_positive() {
        let mut b = Bencher::with_budget(0.05);
        let (_, thr) = b.bench_throughput("t", 100.0, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(thr > 0.0);
    }

    #[test]
    fn json_roundtrips_results() {
        let mut b = Bencher::with_budget(0.05);
        b.bench("spin", || {
            black_box((0..100).sum::<u64>());
        });
        let doc = b.to_json("unit", vec![("speedup", crate::util::json::Json::num(2.0))]);
        let text = doc.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("unit"));
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("spin"));
        assert!(results[0].get("mean_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            back.get("extra").unwrap().get("speedup").unwrap().as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn write_json_creates_file() {
        let mut b = Bencher::with_budget(0.05);
        b.bench("w", || {
            black_box(1 + 1);
        });
        let p = std::env::temp_dir().join(format!("BENCH_test_{}.json", std::process::id()));
        b.write_json(&p, "unit", vec![]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(crate::util::json::parse(&text).is_ok());
        std::fs::remove_file(p).ok();
    }
}
