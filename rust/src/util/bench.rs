//! Mini benchmarking harness (criterion is unavailable offline).
//!
//! Usage mirrors criterion closely enough for `cargo bench` targets with
//! `harness = false`: warm up, collect wall-clock samples, report
//! mean / p50 / p95 / min plus a derived throughput line. Sample counts
//! adapt to the per-iteration cost so slow end-to-end benches stay fast.
//!
//! Besides the human tables, results serialize to JSON
//! ([`Bencher::write_json`] -> `BENCH_<name>.json`) so the perf
//! trajectory is machine-diffable across PRs.

use std::time::{Duration, Instant};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("samples", Json::num(self.samples as f64)),
            ("mean_ms", Json::num(self.mean.as_secs_f64() * 1e3)),
            ("p50_ms", Json::num(self.p50.as_secs_f64() * 1e3)),
            ("p95_ms", Json::num(self.p95.as_secs_f64() * 1e3)),
            ("min_ms", Json::num(self.min.as_secs_f64() * 1e3)),
        ])
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms mean   {:>10.3} ms p50   {:>10.3} ms p95   {:>10.3} ms min   ({} samples)",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.p50.as_secs_f64() * 1e3,
            self.p95.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.samples,
        )
    }
}

pub struct Bencher {
    /// Total time budget per benchmark (warmup + sampling).
    pub budget: Duration,
    pub max_samples: usize,
    pub results: Vec<Stats>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            budget: Duration::from_secs(3),
            max_samples: 200,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn with_budget(secs: f64) -> Self {
        Bencher {
            budget: Duration::from_secs_f64(secs),
            ..Default::default()
        }
    }

    /// Benchmark `f`, which performs ONE iteration of the workload.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup: one call always; keep warming until 10% of budget.
        let warm_budget = self.budget / 10;
        let t0 = Instant::now();
        f();
        while t0.elapsed() < warm_budget {
            f();
        }

        let sample_budget = self.budget - t0.elapsed().min(self.budget / 2);
        let mut samples: Vec<Duration> = Vec::new();
        let s0 = Instant::now();
        while s0.elapsed() < sample_budget && samples.len() < self.max_samples {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        if samples.is_empty() {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        let stats = Self::summarize(name, &mut samples);
        println!("{stats}");
        self.results.push(stats.clone());
        stats
    }

    /// Benchmark with a derived-throughput report (items per second).
    pub fn bench_throughput<F: FnMut()>(
        &mut self,
        name: &str,
        items_per_iter: f64,
        f: F,
    ) -> (Stats, f64) {
        let stats = self.bench(name, f);
        let thr = items_per_iter / stats.mean.as_secs_f64();
        println!("{:<40} {:>14.0} items/s", format!("{name} [throughput]"), thr);
        (stats, thr)
    }

    /// All collected results as a JSON document: `{"bench": <label>,
    /// "results": [...], "extra": {...}}`. `extra` carries bench-specific
    /// scalars (speedups, allocation counts) alongside the timing rows.
    pub fn to_json(&self, label: &str, extra: Vec<(&str, Json)>) -> Json {
        Json::obj(vec![
            ("bench", Json::str(label)),
            (
                "results",
                Json::Arr(self.results.iter().map(|s| s.to_json()).collect()),
            ),
            ("extra", Json::obj(extra)),
        ])
    }

    /// Write the JSON document next to the human tables; path convention
    /// is `BENCH_<name>.json` in the working directory.
    pub fn write_json(
        &self,
        path: impl AsRef<std::path::Path>,
        label: &str,
        extra: Vec<(&str, Json)>,
    ) -> anyhow::Result<()> {
        let doc = self.to_json(label, extra);
        std::fs::write(&path, doc.to_string())?;
        println!("bench json -> {}", path.as_ref().display());
        Ok(())
    }

    fn summarize(name: &str, samples: &mut [Duration]) -> Stats {
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        Stats {
            name: name.to_string(),
            samples: n,
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n * 95 / 100).min(n - 1)],
            min: samples[0],
        }
    }
}

/// Prevent the optimizer from discarding a computed value.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Parse a bench-history file body: a JSON array of entry objects.
/// Corrupt content — invalid JSON, a non-array document, or any
/// non-object element — is a hard error naming `path`. Clobbering a
/// corrupted trajectory would silently erase every past data point; a
/// bench run must never do that.
pub fn parse_history(text: &str, path: &str) -> anyhow::Result<Vec<Json>> {
    let doc = crate::util::json::parse(text)
        .map_err(|e| anyhow::anyhow!("{path} is not valid JSON ({e}); refusing to clobber it"))?;
    let Json::Arr(v) = doc else {
        anyhow::bail!("{path} is not a JSON array; refusing to clobber it");
    };
    for (i, item) in v.iter().enumerate() {
        anyhow::ensure!(
            item.as_obj().is_some(),
            "{path}[{i}] is not an entry object; refusing to clobber it"
        );
    }
    Ok(v)
}

/// The silent-empty guard on a history entry: the entry must be an
/// object, and every key in `row_keys` must be present and hold a
/// NON-empty array. A bench run that produced zero rows for a section
/// (skipped engine, filtered-out artifacts) must fail loudly rather
/// than append a hollow data point that reads as a measured one.
pub fn validate_history_entry(entry: &Json, row_keys: &[&str]) -> anyhow::Result<()> {
    anyhow::ensure!(entry.as_obj().is_some(), "history entry is not a JSON object");
    for &key in row_keys {
        let rows = entry
            .get(key)
            .ok_or_else(|| anyhow::anyhow!("history entry is missing the `{key}` row section"))?;
        let arr = rows
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("history entry `{key}` is not an array of rows"))?;
        anyhow::ensure!(
            !arr.is_empty(),
            "history entry `{key}` has zero rows; refusing to append a silent-empty run"
        );
    }
    Ok(())
}

/// Append `entry` to the JSON-array history at `path`. A missing file
/// starts a fresh history; existing content must parse as an array of
/// objects ([`parse_history`]), and the entry must pass the
/// [`validate_history_entry`] silent-empty guard for `row_keys`.
pub fn append_history(
    path: impl AsRef<std::path::Path>,
    entry: Json,
    row_keys: &[&str],
) -> anyhow::Result<()> {
    let path = path.as_ref();
    validate_history_entry(&entry, row_keys)?;
    let mut hist = match std::fs::read_to_string(path) {
        Ok(text) => parse_history(&text, &path.display().to_string())?,
        Err(_) => Vec::new(),
    };
    hist.push(entry);
    std::fs::write(path, Json::Arr(hist).to_string())?;
    println!("history -> {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples_and_orders_percentiles() {
        let mut b = Bencher::with_budget(0.05);
        let s = b.bench("spin", || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.samples >= 1);
        assert!(s.min <= s.p50 && s.p50 <= s.p95);
    }

    #[test]
    fn throughput_positive() {
        let mut b = Bencher::with_budget(0.05);
        let (_, thr) = b.bench_throughput("t", 100.0, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(thr > 0.0);
    }

    #[test]
    fn json_roundtrips_results() {
        let mut b = Bencher::with_budget(0.05);
        b.bench("spin", || {
            black_box((0..100).sum::<u64>());
        });
        let doc = b.to_json("unit", vec![("speedup", crate::util::json::Json::num(2.0))]);
        let text = doc.to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("unit"));
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").unwrap().as_str(), Some("spin"));
        assert!(results[0].get("mean_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            back.get("extra").unwrap().get("speedup").unwrap().as_f64(),
            Some(2.0)
        );
    }

    #[test]
    fn parse_history_accepts_arrays_of_objects_only() {
        assert_eq!(parse_history("[]", "h.json").unwrap().len(), 0);
        let v = parse_history("[{\"bench\": \"t\"}]", "h.json").unwrap();
        assert_eq!(v.len(), 1);
        for (text, why) in [
            ("{not json", "invalid JSON"),
            ("{\"bench\": \"t\"}", "non-array document"),
            ("[1, 2]", "non-object element"),
        ] {
            let err = parse_history(text, "h.json").unwrap_err().to_string();
            assert!(err.contains("h.json"), "{why}: error must name the path, got: {err}");
            assert!(err.contains("refusing to clobber"), "{why}: {err}");
        }
    }

    #[test]
    fn validate_history_entry_refuses_silent_empty_rows() {
        let full = Json::obj(vec![
            ("bench", Json::str("throughput")),
            ("rows_a", Json::Arr(vec![Json::obj(vec![("ms", Json::num(1.0))])])),
            ("rows_b", Json::Arr(vec![Json::obj(vec![("ms", Json::num(2.0))])])),
        ]);
        validate_history_entry(&full, &["rows_a", "rows_b"]).unwrap();
        // an unlisted key is free-form; scalars next to the row sections are fine
        validate_history_entry(&full, &["rows_a"]).unwrap();

        let empty = Json::obj(vec![("rows_a", Json::Arr(vec![]))]);
        let err = validate_history_entry(&empty, &["rows_a"]).unwrap_err().to_string();
        assert!(err.contains("zero rows"), "{err}");

        let missing = Json::obj(vec![("rows_a", Json::Arr(vec![Json::num(1.0)]))]);
        let err = validate_history_entry(&missing, &["rows_b"]).unwrap_err().to_string();
        assert!(err.contains("missing"), "{err}");

        let scalar = Json::obj(vec![("rows_a", Json::num(3.0))]);
        let err = validate_history_entry(&scalar, &["rows_a"]).unwrap_err().to_string();
        assert!(err.contains("not an array"), "{err}");

        let err = validate_history_entry(&Json::Arr(vec![]), &[]).unwrap_err().to_string();
        assert!(err.contains("not a JSON object"), "{err}");
    }

    #[test]
    fn append_history_round_trips_and_guards() {
        let p = std::env::temp_dir().join(format!("BENCH_hist_{}.json", std::process::id()));
        std::fs::remove_file(&p).ok();
        let entry = |ms: f64| {
            Json::obj(vec![(
                "rows",
                Json::Arr(vec![Json::obj(vec![("ms", Json::num(ms))])]),
            )])
        };
        append_history(&p, entry(1.0), &["rows"]).unwrap(); // fresh file
        append_history(&p, entry(2.0), &["rows"]).unwrap(); // append
        let hist = parse_history(&std::fs::read_to_string(&p).unwrap(), "h").unwrap();
        assert_eq!(hist.len(), 2);

        // a zero-row entry must refuse to append AND leave the file alone
        let empty = Json::obj(vec![("rows", Json::Arr(vec![]))]);
        assert!(append_history(&p, empty, &["rows"]).is_err());
        let hist = parse_history(&std::fs::read_to_string(&p).unwrap(), "h").unwrap();
        assert_eq!(hist.len(), 2, "a refused append must not touch the history");

        // corrupt on-disk history blocks the append entirely
        std::fs::write(&p, "{broken").unwrap();
        assert!(append_history(&p, entry(3.0), &["rows"]).is_err());
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "{broken");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn write_json_creates_file() {
        let mut b = Bencher::with_budget(0.05);
        b.bench("w", || {
            black_box(1 + 1);
        });
        let p = std::env::temp_dir().join(format!("BENCH_test_{}.json", std::process::id()));
        b.write_json(&p, "unit", vec![]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(crate::util::json::parse(&text).is_ok());
        std::fs::remove_file(p).ok();
    }
}
