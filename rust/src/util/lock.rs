//! Poison-tolerant mutex.
//!
//! [`std::sync::Mutex`] poisons itself when a holder panics, and every
//! later `lock()` returns `Err(PoisonError)`. For locks that guard
//! *serialization* rather than invariants — the global failpoint
//! registry, test-suite locks that exist only to keep process-global
//! state from interleaving — poisoning converts one failing test into a
//! cascade of unrelated failures. [`StableMutex`] recovers the guard via
//! [`PoisonError::into_inner`](std::sync::PoisonError::into_inner): a
//! panic under the lock never makes the lock itself unusable.
//!
//! Use it only where the protected data stays valid across a panic
//! (registries that are cleared/replaced wholesale, unit `()` test
//! locks). Data with tearable multi-step invariants should keep the
//! poisoning behavior.

use std::sync::{Mutex, MutexGuard};

/// A [`Mutex`] whose `lock()` shrugs off poisoning.
#[derive(Debug, Default)]
pub struct StableMutex<T> {
    inner: Mutex<T>,
}

impl<T> StableMutex<T> {
    /// Creates a new lock. `const` so it can back `static` registries.
    pub const fn new(value: T) -> Self {
        Self { inner: Mutex::new(value) }
    }

    /// Acquires the lock, recovering the guard if a previous holder
    /// panicked.
    pub fn lock(&self) -> MutexGuard<'_, T> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Consumes the lock, returning the inner value (poison ignored).
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(|poisoned| poisoned.into_inner())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_survives_panic_while_held() {
        static M: StableMutex<u32> = StableMutex::new(0);
        let result = std::panic::catch_unwind(|| {
            let mut g = M.lock();
            *g = 7;
            panic!("poison the lock");
        });
        assert!(result.is_err());
        // A plain Mutex would return Err(PoisonError) here forever; the
        // stable lock hands back the guard and the last written value.
        assert_eq!(*M.lock(), 7);
        *M.lock() = 9;
        assert_eq!(*M.lock(), 9);
    }

    #[test]
    fn into_inner_recovers_value() {
        let m = StableMutex::new(3usize);
        *m.lock() += 1;
        assert_eq!(m.into_inner(), 4);
    }
}
