//! From-scratch substrates: JSON, CLI parsing, PRNG, benchmarking, and
//! property testing. No third-party crates beyond `xla`/`anyhow` exist in
//! this environment (DESIGN.md §3), so these are first-class modules with
//! their own test suites rather than dependencies.

pub mod bench;
pub mod cli;
pub mod crc;
pub mod json;
pub mod lock;
pub mod prop;
pub mod rng;
