//! Deterministic PRNG (PCG-XSH-RR 64/32) + samplers.
//!
//! Every stochastic component of the coordinator — corpus generation,
//! batching, property tests, the noisy-quadratic simulator — draws from
//! this generator so runs are bit-reproducible from a single seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small state, good statistical
/// quality, trivially seedable per-stream.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Independent stream for the same seed (DDP shards, workers...).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u32) -> u32 {
        assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n || l >= (u32::MAX - n + 1) % n {
                return (m >> 32) as u32;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }
}

/// Zipf-distributed sampler over `n` ranks with exponent `s`, built as an
/// inverse-CDF table. This is what gives the synthetic corpus the
/// heavy-tailed token frequencies the paper's Appendix M analysis needs.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in [0, n); rank 0 is the most frequent.
    pub fn sample(&self, rng: &mut Pcg) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert!((0..10).any(|_| a.next_u32() != b.next_u32()));
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::with_stream(1, 0);
        let mut b = Pcg::with_stream(1, 1);
        assert!((0..10).any(|_| a.next_u32() != b.next_u32()));
    }

    #[test]
    fn uniform_range() {
        let mut rng = Pcg::new(7);
        for _ in 0..1000 {
            let x = rng.below(17);
            assert!(x < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let z = Zipf::new(1000, 1.2);
        let mut rng = Pcg::new(9);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // rank 0 strictly dominates; top-10 take a large share
        assert!(counts[0] > counts[10] && counts[0] > counts[100]);
        let head: usize = counts[..10].iter().sum();
        assert!(head > 50_000 / 4, "head share {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::new(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
