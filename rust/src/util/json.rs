//! Minimal JSON parser/serializer (no external crates exist in this
//! environment — DESIGN.md §3). Covers the full JSON grammar needed by
//! `artifacts/manifest.json` and the run-config files: objects, arrays,
//! strings (with escapes), numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest lookups want this.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Shape helper: `[2, 3]` -> `vec![2, 3]`.
    pub fn as_shape(&self) -> anyhow::Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected array shape"))?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("non-numeric dim"))
            })
            .collect()
    }

    // ---- construction ----------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ---- serialization ---------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact single-line serialization; `Json::to_string()` (via the
/// blanket `ToString`) is the usual entry point.
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// --------------------------------------------------------------------------
// Parsing
// --------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json, JsonError> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x80 => {
                    // fast path: ASCII byte
                    out.push(c as char);
                    self.i += 1;
                }
                Some(c) => {
                    // multibyte UTF-8: decode just this codepoint
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf8")),
                    };
                    if self.i + len > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    let s = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|_| self.err("invalid utf8"))?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}, null], "c": 2}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64(), Some(2.0));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"dims":[64,128],"lr":0.001,"name":"scale","ok":true}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn shape_helper() {
        let v = parse("[2, 3, 4]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![2, 3, 4]);
        assert!(parse("[2, \"x\"]").unwrap().as_shape().is_err());
    }
}
