//! CRC-32/ISO-HDLC (the zlib/PNG polynomial, reflected 0xEDB88320),
//! built from scratch like every other substrate here. Checkpoint v2
//! frames its header and each tensor record with this checksum so a
//! torn write or bit rot is detected at load time instead of silently
//! resuming from garbage.
//!
//! The 256-entry table is computed in a `const fn`, so the whole module
//! is allocation-free and has no process-global init.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 hasher; feed bytes with [`Crc32::update`], read the
/// digest with [`Crc32::value`]. `Default`-constructed state equals
/// `Crc32::new()`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = !self.state;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = !c;
    }

    pub fn value(&self) -> u32 {
        self.state
    }
}

/// One-shot convenience over [`Crc32`].
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.value()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the standard CRC-32/ISO-HDLC check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data: Vec<u8> = (0u16..1024).map(|i| (i % 251) as u8).collect();
        let whole = crc32(&data);
        for split in [0, 1, 7, 512, 1023, 1024] {
            let mut h = Crc32::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.value(), whole, "split at {split}");
        }
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0x5Au8; 64];
        let clean = crc32(&data);
        data[33] ^= 0x04;
        assert_ne!(crc32(&data), clean);
    }
}
