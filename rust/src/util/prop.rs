//! Mini property-testing runner (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Pcg`]; the runner executes it
//! across many derived seeds and, on failure, reports the offending seed
//! so the case replays deterministically. Generators are free functions
//! over the RNG — composition is ordinary Rust.

use super::rng::Pcg;

pub const DEFAULT_CASES: usize = 64;

/// Run `prop` for `cases` derived seeds; panic (with the seed) on the
/// first failure. `prop` returns `Err(msg)` or panics to signal failure.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x5eed_0000_0000 + case as u64;
        let mut rng = Pcg::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name:?} failed at seed {seed:#x} (case {case}): {msg}");
        }
    }
}

/// `check` with the default case count.
pub fn quick<F>(name: &str, prop: F)
where
    F: FnMut(&mut Pcg) -> Result<(), String>,
{
    check(name, DEFAULT_CASES, prop)
}

// ---- common generators -----------------------------------------------------

pub fn usize_in(rng: &mut Pcg, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u32) as usize
}

pub fn f32_in(rng: &mut Pcg, lo: f32, hi: f32) -> f32 {
    lo + rng.next_f32() * (hi - lo)
}

/// Gaussian matrix of the given shape, flattened row-major.
pub fn matrix(rng: &mut Pcg, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| scale * rng.normal() as f32)
        .collect()
}

/// Assert helper producing the Result shape `check` wants.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn approx_eq(a: f32, b: f32, tol: f32) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

pub fn slices_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        if !approx_eq(*x, *y, tol) {
            return Err(format!("elem {i}: {x} vs {y}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        quick("add-commutes", |rng| {
            let a = rng.next_u32() as u64;
            let b = rng.next_u32() as u64;
            ensure(a + b == b + a, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "seed")]
    fn failing_property_reports_seed() {
        check("always-fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn generators_in_bounds() {
        quick("bounds", |rng| {
            let n = usize_in(rng, 3, 9);
            ensure((3..=9).contains(&n), format!("n={n}"))?;
            let x = f32_in(rng, -1.0, 1.0);
            ensure((-1.0..=1.0).contains(&x), format!("x={x}"))?;
            let m = matrix(rng, 2, 3, 1.0);
            ensure(m.len() == 6, "matrix len")
        });
    }

    #[test]
    fn slices_close_detects_mismatch() {
        assert!(slices_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(slices_close(&[1.0], &[1.1], 1e-6).is_err());
        assert!(slices_close(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }
}
