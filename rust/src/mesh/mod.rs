//! Fault-tolerant multi-process mesh training.
//!
//! `scale launch --ranks N` forks N worker processes of the same binary
//! (`scale worker`), connects them to a coordinator-side supervisor
//! over localhost TCP, and trains with the exact step semantics of the
//! single-process [`Trainer`] — while surviving rank crashes, hangs,
//! and corrupt frames. The three submodules split cleanly:
//!
//! * [`wire`] — the framing + codec layer. Every frame is
//!   `u32 payload_len | payload | u32 crc32(payload)` (little-endian,
//!   CRC from [`crate::util::crc::crc32`]), so torn or bit-flipped
//!   frames are *detected* (and re-requested) rather than silently
//!   folded into the gradient mean. Hosts the deterministic wire
//!   failpoints (`conn_drop`, `frame_corrupt`, `frame_delay`).
//! * [`worker`] — the rank body: request-driven loop that answers
//!   `Step{params}` with `Grads{[loss, grads..]}` for its shard —
//!   stateless by default; under `--shard-state` it additionally owns
//!   and applies its optimizer-state shard (`ShardGrads`/`ShardParams`).
//! * [`supervisor`] — process lifecycle, heartbeats, bounded-backoff
//!   respawn, checkpoint rollback, and the typed
//!   [`TrainError::Mesh`](crate::coordinator::TrainError) abort when
//!   the recovery budget runs out.
//!
//! ## Bit-determinism argument (three legs)
//!
//! The acceptance bar is that an N-rank mesh run — even one that lost
//! and respawned ranks mid-flight — produces **bit-identical** params,
//! optimizer state, and perplexity to a single-process run with
//! `shards = N`. That holds because:
//!
//! 1. **Workers compute what the shards loop computes.** Rank r runs
//!    [`Trainer::shard_forward`] for shard r at stream position
//!    `step - 1` — the same executable, seed-keyed token rings, and
//!    position arithmetic as the in-process shard loop. Params arrive
//!    with every `Step` frame, so worker floats are a pure function of
//!    the coordinator's broadcast.
//! 2. **The wire is bit-transparent.** f32 payloads travel as raw
//!    little-endian bytes ([`Tensor::f32s`] → `to_le_bytes` →
//!    `from_le_bytes`), which round-trips every bit pattern including
//!    NaN payloads — no text formatting, no re-rounding.
//! 3. **The reduction is the single-process reduction.** Gathered
//!    outputs are installed *in rank order* into the same slots the
//!    shards loop fills, and [`reduce_ranks_into`] is literally
//!    [`ddp::tree_all_reduce_into`] — already pinned bit-identical for
//!    every pool size. The loss mean reads slot 0 of each rank in rank
//!    order, matching the fused path's f64 accumulation order.
//!
//! Recovery preserves all three: a respawned worker is stateless
//! (leg 1), and the supervisor rolls its trainer back to a checksummed
//! snapshot whose round-trip is bit-exact, then replays. The
//! `mesh_chaos` suite pins the whole story against never-failed
//! single-process runs.
//!
//! Sharded optimizer state (`--shard-state`) adds one deliberate
//! exception to leg 1: each rank persistently owns the optimizer-state
//! shard for its contiguous slice of the update plan and applies that
//! slice of the update itself. The exception stays bit-exact because
//! (a) the plan is a pure function of `(optimizer, size, ranks)`
//! computed identically on every process, (b) per-parameter updates
//! have no cross-parameter data flow, so a contiguous partition
//! reproduces the full update bit for bit, and (c) recovery re-seeds
//! *every* rank's shard from the newest complete sharded snapshot,
//! restoring the stateless-replay invariant at the rollback point.
//!
//! [`Trainer`]: crate::coordinator::Trainer
//! [`Trainer::shard_forward`]: crate::coordinator::Trainer
//! [`Tensor::f32s`]: crate::runtime::Tensor::f32s
//! [`ddp::tree_all_reduce_into`]: crate::coordinator::ddp::tree_all_reduce_into

pub mod supervisor;
pub mod wire;
pub mod worker;

pub use supervisor::{train, MeshOptions, MeshReport};
pub use worker::{run as run_worker, WorkerOptions, RANK_EXIT_CODE};

use crate::coordinator::ddp;
use crate::parallel::WorkerPool;
use crate::runtime::Tensor;

/// Cross-process tree reduction: mean-reduce `rank_outs[r][p]` over
/// ranks r for every `p >= skip`, leaving the mean in `rank_outs[0][p]`.
///
/// This is a thin, named delegation to [`ddp::tree_all_reduce_into`] —
/// deliberately *not* a reimplementation, so the mesh inherits the
/// in-process reduction's pinned bit-determinism (same pairwise tree
/// order for every rank count and pool size) by construction.
pub fn reduce_ranks_into(pool: &WorkerPool, rank_outs: &mut [Vec<Tensor>], skip: usize) {
    ddp::tree_all_reduce_into(pool, rank_outs, skip);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ddp::tree_all_reduce_sequential;
    use crate::parallel;

    fn rank_outs(ranks: usize, params: usize) -> Vec<Vec<Tensor>> {
        (0..ranks)
            .map(|r| {
                (0..params)
                    .map(|p| {
                        let data: Vec<f32> = (0..24)
                            .map(|i| ((r * 131 + p * 17 + i) as f32).sin() * 3.0 + 0.125)
                            .collect();
                        let mut t = Tensor::zeros(&[4, 6]);
                        t.f32s_mut().copy_from_slice(&data);
                        t
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn matches_sequential_reference_for_every_rank_count_and_pool() {
        for ranks in [1usize, 2, 3, 4, 5, 8] {
            let want = tree_all_reduce_sequential(rank_outs(ranks, 3));
            for pool_threads in [0usize, 2, 7] {
                let pool = WorkerPool::new(pool_threads);
                let mut outs = rank_outs(ranks, 3);
                // force the parallel path even on tiny tensors
                parallel::set_min_ops_override(Some(1));
                reduce_ranks_into(&pool, &mut outs, 0);
                parallel::set_min_ops_override(None);
                for (p, w) in want.iter().enumerate() {
                    assert_eq!(
                        outs[0][p].f32s(),
                        w.f32s(),
                        "ranks={ranks} pool={pool_threads} param={p}"
                    );
                }
            }
        }
    }

    #[test]
    fn skip_leaves_leading_slots_untouched() {
        let pool = WorkerPool::new(2);
        let mut outs = rank_outs(3, 2);
        let keep: Vec<Vec<f32>> = outs.iter().map(|o| o[0].f32s().to_vec()).collect();
        reduce_ranks_into(&pool, &mut outs, 1);
        for (r, k) in keep.iter().enumerate() {
            assert_eq!(outs[r][0].f32s(), &k[..], "skip slot of rank {r} was clobbered");
        }
        let want = tree_all_reduce_sequential(rank_outs(3, 2));
        assert_eq!(outs[0][1].f32s(), want[1].f32s());
    }
}
