//! The `scale worker` body: one rank of a mesh run.
//!
//! A worker is *stateless between steps by construction*: every `Step`
//! frame carries the full parameter set, and the microbatch a worker
//! feeds its shard is a pure function of `(shard, stream_pos)` via the
//! trainer's token rings — so a freshly respawned worker at step `k`
//! computes bit-identical gradients to one that has been alive since
//! step 1. That property is what makes the supervisor's
//! kill-and-respawn recovery bit-exact, and `mesh_chaos.rs` pins it.
//!
//! The loop is request-driven: block on [`wire::read_frame`] (no read
//! timeout — a parked worker waiting out another rank's recovery simply
//! stays blocked here), answer `Step` with `Grads`, `Resend` with a
//! re-encode of the last outputs, `Ping` with `Pong`, and exit on
//! `Shutdown` or when the supervisor's death surfaces as EOF. Any
//! protocol or engine failure exits the process — the supervisor owns
//! recovery, the worker just dies loudly.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::coordinator::{TrainOptions, Trainer};
use crate::fault;
use crate::mesh::wire::{self, Frame, WireError};
use crate::runtime::Engine;
use anyhow::{bail, ensure};

/// Exit code a `rank_exit` failpoint dies with — distinguishable from
/// a panic (101) or a clean exit in the chaos suite's post-mortems.
pub const RANK_EXIT_CODE: i32 = 17;

/// Per-attempt connect budget; total connect time is bounded by the
/// supervisor's accept deadline, not by the worker.
const CONNECT_TIMEOUT_MS: u64 = 10_000;

pub struct WorkerOptions {
    /// This worker's rank — the DDP shard it computes.
    pub rank: usize,
    /// Total ranks in the mesh (the trainer's shard count).
    pub ranks: usize,
    /// Supervisor address, e.g. `127.0.0.1:41234`.
    pub connect: String,
    /// Must match the supervisor's `TrainOptions` where it matters for
    /// bits: `size`, `optimizer`, `seed` (corpus + rings), `shards`
    /// (= `ranks`). The supervisor's spawner guarantees this.
    pub train: TrainOptions,
}

/// Dial the supervisor with bounded exponential backoff — the listener
/// may not be accepting yet when a (re)spawned worker comes up.
fn connect_with_backoff(addr: &str) -> anyhow::Result<TcpStream> {
    let deadline = Instant::now() + Duration::from_millis(CONNECT_TIMEOUT_MS);
    let mut delay = Duration::from_millis(10);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() + delay >= deadline {
                    bail!("worker: connect to {addr} failed: {e}");
                }
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(500));
            }
        }
    }
}

/// Run one worker rank to completion. Returns `Ok(())` on a clean
/// `Shutdown`; errors propagate to the CLI and exit the process, which
/// the supervisor observes as a rank failure.
pub fn run(engine: &Engine, opts: &WorkerOptions) -> anyhow::Result<()> {
    ensure!(opts.ranks >= 1, "worker: ranks must be >= 1");
    ensure!(opts.rank < opts.ranks, "worker: rank {} out of 0..{}", opts.rank, opts.ranks);
    ensure!(
        opts.train.shards == opts.ranks,
        "worker: trainer shards ({}) must equal mesh ranks ({})",
        opts.train.shards,
        opts.ranks
    );
    let mut tr = Trainer::new(engine, opts.train.clone())
        .map_err(|e| e.context(format!("worker rank {}: trainer init", opts.rank)))?;
    let mut stream = connect_with_backoff(&opts.connect)?;
    stream.set_nodelay(true)?;
    wire::write_hello(&mut stream, opts.rank)?;

    loop {
        match wire::read_frame(&mut stream) {
            Ok(Frame::Step { step, tensors }) => {
                // deterministic crash injection: die exactly where a real
                // worker fault would land — after accepting the step,
                // before computing or answering
                if fault::fires("rank_exit") {
                    std::process::exit(RANK_EXIT_CODE);
                }
                ensure!(step >= 1, "worker: step 0 on the wire");
                ensure!(
                    tensors.len() == tr.n_params(),
                    "worker: got {} param tensors, expected {}",
                    tensors.len(),
                    tr.n_params()
                );
                for (p, t) in tr.params.iter_mut().zip(&tensors) {
                    ensure!(
                        p.shape() == t.shape(),
                        "worker: param shape mismatch ({:?} vs {:?})",
                        p.shape(),
                        t.shape()
                    );
                    p.f32s_mut().copy_from_slice(t.f32s());
                }
                tr.step = step as usize;
                // rank r computes shard r; the stream position is dictated
                // by the coordinator's step counter (step k reads position
                // k-1), which is the whole respawn-resume story
                tr.shard_forward(opts.rank, (step - 1) as usize)?;
                wire::write_grads(&mut stream, step, tr.shard_out(opts.rank))?;
            }
            Ok(Frame::Resend) => {
                // the supervisor rejected our last frame (CRC); re-encode
                // from the intact output buffers
                wire::write_grads(&mut stream, tr.step as u64, tr.shard_out(opts.rank))?;
            }
            Ok(Frame::Ping) => wire::write_pong(&mut stream)?,
            Ok(Frame::Shutdown) => return Ok(()),
            Ok(other) => bail!("worker: unexpected {} frame", other.name()),
            // a corrupt supervisor->worker frame can't be re-requested
            // from this side (the supervisor is mid-broadcast); die and
            // let the supervisor's recovery path respawn us
            Err(WireError::Crc { expect, got }) => {
                bail!("worker: corrupt frame from supervisor (crc {expect:#010x}/{got:#010x})")
            }
            Err(WireError::Fatal(e)) => {
                return Err(e.context(format!("worker rank {}", opts.rank)));
            }
        }
    }
}
