//! The `scale worker` body: one rank of a mesh run.
//!
//! In the default mode a worker is *stateless between steps by
//! construction*: every `Step` frame carries the full parameter set,
//! and the microbatch a worker feeds its shard is a pure function of
//! `(shard, stream_pos)` via the trainer's token rings — so a freshly
//! respawned worker at step `k` computes bit-identical gradients to one
//! that has been alive since step 1. That property is what makes the
//! supervisor's kill-and-respawn recovery bit-exact, and
//! `mesh_chaos.rs` pins it.
//!
//! `--shard-state` mode adds exactly one piece of owned state: the
//! optimizer-state shard for this rank's contiguous slice of the update
//! plan ([`UpdateProgram::shard_plan`] — a pure function of
//! `(optimizer, size, ranks)`, computed here and by the supervisor
//! independently). Per step the worker still answers `Step` with
//! `Grads`, then receives `ShardGrads` (the exact lr bits + its slice
//! of the *reduced* gradients), applies its slice of the update via
//! [`UpdateProgram::execute_range`] — mutating its param slice and its
//! persistent state shard in place — and returns the updated param
//! shard. Because the state shard starts at zero (like a fresh
//! single-process trainer) and is re-seeded by the supervisor from the
//! newest complete sharded snapshot after any rollback (`ShardState`),
//! the respawn-resume story stays bit-exact even though state now lives
//! out here.
//!
//! The loop is request-driven: block on [`wire::read_frame`] (no read
//! timeout — a parked worker waiting out another rank's recovery simply
//! stays blocked here), answer `Step` with `Grads`, `Resend` with a
//! re-encode of the last reply, `Ping` with `Pong`, and exit on
//! `Shutdown` or when the supervisor's death surfaces as EOF. Any
//! protocol or engine failure exits the process — the supervisor owns
//! recovery, the worker just dies loudly.

use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::coordinator::{TrainOptions, Trainer};
use crate::exec::update::{UpdateProgram, UpdateWs};
use crate::fault;
use crate::mesh::wire::{self, Frame, WireError};
use crate::parallel;
use crate::runtime::{Engine, Tensor};
use anyhow::{bail, ensure};

/// Exit code a `rank_exit` failpoint dies with — distinguishable from
/// a panic (101) or a clean exit in the chaos suite's post-mortems.
pub const RANK_EXIT_CODE: i32 = 17;

/// Per-attempt connect budget; total connect time is bounded by the
/// supervisor's accept deadline, not by the worker.
const CONNECT_TIMEOUT_MS: u64 = 10_000;

pub struct WorkerOptions {
    /// This worker's rank — the DDP shard it computes.
    pub rank: usize,
    /// Total ranks in the mesh (the trainer's shard count).
    pub ranks: usize,
    /// Supervisor address, e.g. `127.0.0.1:41234`.
    pub connect: String,
    /// Own the optimizer state for this rank's shard of the update plan
    /// and apply that slice of the update (`scale launch --shard-state`).
    /// Must match the supervisor's mode; frames from the other mode are
    /// protocol errors.
    pub shard_state: bool,
    /// Must match the supervisor's `TrainOptions` where it matters for
    /// bits: `size`, `optimizer`, `seed` (corpus + rings), `shards`
    /// (= `ranks`). The supervisor's spawner guarantees this.
    pub train: TrainOptions,
}

/// What the worker sent last — what a `Resend` must re-encode.
#[derive(Clone, Copy)]
enum Reply {
    Grads,
    Params,
    State,
}

/// The sharded-mode context: this rank's slice of the update plan plus
/// the reusable kernel workspace.
struct ShardCtx {
    prog: UpdateProgram,
    ws: UpdateWs,
    params: std::ops::Range<usize>,
    state: std::ops::Range<usize>,
}

/// Dial the supervisor with bounded exponential backoff — the listener
/// may not be accepting yet when a (re)spawned worker comes up.
fn connect_with_backoff(addr: &str) -> anyhow::Result<TcpStream> {
    let deadline = Instant::now() + Duration::from_millis(CONNECT_TIMEOUT_MS);
    let mut delay = Duration::from_millis(10);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if Instant::now() + delay >= deadline {
                    bail!("worker: connect to {addr} failed: {e}");
                }
                std::thread::sleep(delay);
                delay = (delay * 2).min(Duration::from_millis(500));
            }
        }
    }
}

/// Run one worker rank to completion. Returns `Ok(())` on a clean
/// `Shutdown`; errors propagate to the CLI and exit the process, which
/// the supervisor observes as a rank failure.
pub fn run(engine: &Engine, opts: &WorkerOptions) -> anyhow::Result<()> {
    ensure!(opts.ranks >= 1, "worker: ranks must be >= 1");
    ensure!(opts.rank < opts.ranks, "worker: rank {} out of 0..{}", opts.rank, opts.ranks);
    ensure!(
        opts.train.shards == opts.ranks,
        "worker: trainer shards ({}) must equal mesh ranks ({})",
        opts.train.shards,
        opts.ranks
    );
    let mut tr = Trainer::new(engine, opts.train.clone())
        .map_err(|e| e.context(format!("worker rank {}: trainer init", opts.rank)))?;
    let mut shard: Option<ShardCtx> = if opts.shard_state {
        let size = engine.manifest.size(&opts.train.size)?;
        let prog = UpdateProgram::new(&opts.train.optimizer, size)?;
        let plan = prog.shard_plan(opts.ranks);
        Some(ShardCtx {
            params: plan.params[opts.rank].clone(),
            state: plan.state[opts.rank].clone(),
            prog,
            ws: UpdateWs::new(),
        })
    } else {
        None
    };
    let mut stream = connect_with_backoff(&opts.connect)?;
    stream.set_nodelay(true)?;
    wire::write_hello(&mut stream, opts.rank)?;
    let mut last = Reply::Grads;

    loop {
        match wire::read_frame(&mut stream) {
            Ok(Frame::Step { step, tensors }) => {
                // deterministic crash injection: die exactly where a real
                // worker fault would land — after accepting the step,
                // before computing or answering
                if fault::fires("rank_exit") {
                    std::process::exit(RANK_EXIT_CODE);
                }
                ensure!(step >= 1, "worker: step 0 on the wire");
                ensure!(
                    tensors.len() == tr.n_params(),
                    "worker: got {} param tensors, expected {}",
                    tensors.len(),
                    tr.n_params()
                );
                for (p, t) in tr.params.iter_mut().zip(&tensors) {
                    ensure!(
                        p.shape() == t.shape(),
                        "worker: param shape mismatch ({:?} vs {:?})",
                        p.shape(),
                        t.shape()
                    );
                    p.f32s_mut().copy_from_slice(t.f32s());
                }
                tr.step = step as usize;
                // rank r computes shard r; the stream position is dictated
                // by the coordinator's step counter (step k reads position
                // k-1), which is the whole respawn-resume story
                tr.shard_forward(opts.rank, (step - 1) as usize)?;
                wire::write_grads(&mut stream, step, tr.shard_out(opts.rank))?;
                last = Reply::Grads;
            }
            Ok(Frame::ShardGrads { step, tensors }) => {
                let Some(ctx) = shard.as_mut() else {
                    bail!("worker: ShardGrads frame without --shard-state");
                };
                ensure!(
                    step as usize == tr.step,
                    "worker: ShardGrads for step {step}, current step is {}",
                    tr.step
                );
                ensure!(
                    tensors.len() == ctx.params.len() + 1,
                    "worker: got {} shard-grad tensors, expected {}",
                    tensors.len(),
                    ctx.params.len() + 1
                );
                ensure!(tensors[0].numel() == 1, "worker: lr slot must be a scalar");
                let lr = tensors[0].f32s()[0];
                let grads: Vec<&Tensor> = tensors[1..].iter().collect();
                for (g, p) in grads.iter().zip(&tr.params[ctx.params.clone()]) {
                    ensure!(
                        g.shape() == p.shape(),
                        "worker: shard-grad shape mismatch ({:?} vs {:?})",
                        g.shape(),
                        p.shape()
                    );
                }
                // apply this rank's slice of the update in place: the
                // param slice and the persistently owned state shard
                let pslice = &mut tr.params[ctx.params.clone()];
                let sslice = &mut tr.state[ctx.state.clone()];
                ctx.prog.execute_range(
                    ctx.params.start,
                    ctx.params.end,
                    pslice,
                    sslice,
                    &grads,
                    lr,
                    step as u32,
                    &mut ctx.ws,
                    parallel::shared(),
                    parallel::tuned_min_ops(),
                )?;
                wire::write_shard_params(&mut stream, step, &tr.params[ctx.params.clone()])?;
                last = Reply::Params;
            }
            Ok(Frame::FetchState { .. }) => {
                let Some(ctx) = shard.as_ref() else {
                    bail!("worker: FetchState frame without --shard-state");
                };
                wire::write_shard_state(&mut stream, tr.step as u64, &tr.state[ctx.state.clone()])?;
                last = Reply::State;
            }
            Ok(Frame::ShardState { step, tensors }) => {
                // recovery re-seed: install the snapshot's state shard
                // (and step) over whatever this rank had
                let Some(ctx) = shard.as_ref() else {
                    bail!("worker: ShardState frame without --shard-state");
                };
                ensure!(
                    tensors.len() == ctx.state.len(),
                    "worker: got {} state tensors, expected {}",
                    tensors.len(),
                    ctx.state.len()
                );
                for (slot, t) in tr.state[ctx.state.clone()].iter_mut().zip(&tensors) {
                    ensure!(
                        slot.shape() == t.shape(),
                        "worker: state shape mismatch ({:?} vs {:?})",
                        slot.shape(),
                        t.shape()
                    );
                    slot.f32s_mut().copy_from_slice(t.f32s());
                }
                tr.step = step as usize;
            }
            Ok(Frame::Resend) => {
                // the supervisor rejected our last frame (CRC); re-encode
                // it from the intact buffers
                match (last, shard.as_ref()) {
                    (Reply::Grads, _) => {
                        wire::write_grads(&mut stream, tr.step as u64, tr.shard_out(opts.rank))?
                    }
                    (Reply::Params, Some(ctx)) => wire::write_shard_params(
                        &mut stream,
                        tr.step as u64,
                        &tr.params[ctx.params.clone()],
                    )?,
                    (Reply::State, Some(ctx)) => wire::write_shard_state(
                        &mut stream,
                        tr.step as u64,
                        &tr.state[ctx.state.clone()],
                    )?,
                    _ => bail!("worker: Resend for a sharded reply without --shard-state"),
                }
            }
            Ok(Frame::Ping) => wire::write_pong(&mut stream)?,
            Ok(Frame::Shutdown) => return Ok(()),
            Ok(other) => bail!("worker: unexpected {} frame", other.name()),
            // a corrupt supervisor->worker frame can't be re-requested
            // from this side (the supervisor is mid-broadcast); die and
            // let the supervisor's recovery path respawn us
            Err(WireError::Crc { expect, got }) => {
                bail!("worker: corrupt frame from supervisor (crc {expect:#010x}/{got:#010x})")
            }
            Err(WireError::Fatal(e)) => {
                return Err(e.context(format!("worker rank {}", opts.rank)));
            }
        }
    }
}
