//! Coordinator-side mesh supervisor: spawns worker ranks, drives the
//! step exchange, and owns every recovery decision.
//!
//! ## Step exchange
//!
//! The supervisor holds the canonical [`Trainer`] (params, optimizer
//! state, schedule, metrics). Each step it broadcasts
//! `Step { step, params }` to every rank, gathers
//! `Grads { step, [loss, grads..] }` *in rank order* into the trainer's
//! per-shard output slots, and runs the exact single-process step tail
//! ([`Trainer::finish_step`]: loss mean, tree all-reduce, divergence
//! guard, optimizer update). Workers never talk to each other — the
//! star topology keeps every float-ordering decision in one process,
//! which is leg one of the bit-determinism argument (see
//! [`crate::mesh`]).
//!
//! ## Sharded optimizer state (`--shard-state`)
//!
//! With [`MeshOptions::shard_state`] the optimizer update itself is
//! distributed, ZeRO-style: the update plan's parameters are
//! partitioned into contiguous rank-owned shards
//! ([`UpdateProgram::shard_plan`] — a pure function of
//! `(optimizer, size, ranks)`, so supervisor and workers compute the
//! identical partition independently). After the gradient gather and
//! reduce, the supervisor ships rank r `ShardGrads { lr, grads[r] }`
//! (the exact f32 lr bits the single-process kernels would see), rank r
//! applies its slice against its *persistently owned* optimizer-state
//! shard and returns the updated param shard, which the supervisor
//! installs in place. Per-parameter updates are independent, so the
//! partition is bit-exact by construction. Checkpoints in this mode are
//! sharded snapshots ([`CheckpointStore::save_sharded`]); state shards
//! are fetched home (`FetchState`/`ShardState`) only at checkpoint
//! cadence and at end of run, and recovery re-seeds *every* rank's
//! shard from the restored snapshot — replacements came up with zeros
//! and survivors are ahead of the rollback point.
//!
//! [`UpdateProgram::shard_plan`]: crate::exec::update::UpdateProgram::shard_plan
//!
//! ## Recovery state machine
//!
//! ```text
//! EXCHANGE ──all ranks answer──────────────► FINISH (update, metrics,
//!    │                                         checkpoint cadence)
//!    │ CRC mismatch on a frame
//!    ├──────► RE-REQUEST (Resend, bounded by max_frame_retries;
//!    │          exhausted => the rank counts as failed)
//!    │ send error / read timeout / EOF / protocol violation
//!    ▼
//! RECOVER: drain survivors (they park on their next blocking read),
//!    kill + respawn each failed rank (bounded exponential backoff,
//!    budget max_respawns), restore the newest CheckpointStore
//!    snapshot, truncate metrics, replay from the restored step.
//!    Budget exhausted => TrainError::Mesh (clean typed abort — the
//!    fleet is shut down, nothing hangs).
//! ```
//!
//! Heartbeats (`Ping`/`Pong` every `heartbeat_every` steps, before the
//! step broadcast) catch ranks that died *between* steps, so a crash
//! never waits for the next multi-megabyte broadcast to surface.
//! Divergence is deliberately **not** a mesh event: a non-finite loss
//! propagates as [`TrainError::Divergence`] exactly like single-process
//! `train()` — respawning a worker cannot fix math.
//!
//! ## Why respawn + rollback is bit-exact
//!
//! Workers are stateless between steps (params arrive with every
//! `Step`; microbatches are pure functions of `(shard, stream_pos)`),
//! so the only state that matters lives in the supervisor's trainer —
//! and that is restored from a checksummed snapshot whose round-trip is
//! bit-exact. A replayed step therefore reproduces the failed step's
//! floats exactly, which `mesh_chaos.rs` pins against a never-failed
//! single-process run. Sharded mode keeps the argument by closing its
//! one exception: the worker-owned state shards are themselves restored
//! from the sharded snapshot (every rank re-seeded, not just the
//! replacements), so the whole mesh replays from one consistent point.

use std::io;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::ops::Range;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use crate::coordinator::checkpoint::CheckpointStore;
use crate::coordinator::recovery::TrainError;
use crate::coordinator::{TrainOptions, Trainer};
use crate::exec::update::UpdateProgram;
use crate::mesh::wire::{self, Frame, WireError};
use crate::runtime::{Engine, Tensor};
use anyhow::{bail, ensure};

/// `(param index range, state slot range)` per rank — the supervisor's
/// view of the shard plan.
type ShardRanges = Vec<(Range<usize>, Range<usize>)>;

/// Configuration for a mesh run. Defaults mirror [`GuardPolicy`]'s
/// cadence where the concepts overlap.
///
/// [`GuardPolicy`]: crate::coordinator::recovery::GuardPolicy
#[derive(Debug, Clone)]
pub struct MeshOptions {
    /// Base training options; `shards` is overridden to `ranks`.
    pub train: TrainOptions,
    /// Worker process count; rank r computes DDP shard r.
    pub ranks: usize,
    /// Shard the optimizer state over the ranks: each worker owns the
    /// state for its contiguous slice of the update plan and applies
    /// that slice of the update; checkpoints become sharded snapshot
    /// dirs. Bit-identical to the default mode for every rank count.
    pub shard_state: bool,
    /// Artifacts dir handed to spawned workers (`--artifacts`).
    pub artifacts: String,
    /// Run directory for the rollback [`CheckpointStore`].
    pub ckpt_dir: PathBuf,
    /// Auto-checkpoint cadence (>= 1); a step-0 baseline is always
    /// saved so recovery has a target.
    pub checkpoint_every: usize,
    /// Keep-last-k retention in the store.
    pub keep_last: usize,
    /// Total rank respawns allowed across the run; exhausted =>
    /// [`TrainError::Mesh`].
    pub max_respawns: usize,
    /// Resend requests allowed per gather before a corrupt-framing rank
    /// counts as failed.
    pub max_frame_retries: usize,
    /// Deadline for a (re)spawned worker to connect and say Hello.
    pub connect_timeout_ms: u64,
    /// Socket read/write timeout — how long a hung rank can stall the
    /// mesh before it is declared failed.
    pub read_timeout_ms: u64,
    /// Ping/Pong round every N steps, before the step broadcast
    /// (0 = off).
    pub heartbeat_every: usize,
    /// Respawn backoff: `base << consecutive_failures`, capped.
    pub backoff_base_ms: u64,
    pub backoff_max_ms: u64,
    /// Failpoint specs armed on specific ranks' *initial* spawn only
    /// (chaos tests). Respawned workers always come up clean — the same
    /// spec would re-arm with reset hit counters and kill the fresh
    /// process forever.
    pub worker_faults: Vec<(usize, String)>,
    /// Worker executable; `None` = `std::env::current_exe()`. Tests
    /// pass `env!("CARGO_BIN_EXE_scale")` (the test binary is not the
    /// CLI).
    pub worker_bin: Option<PathBuf>,
}

impl MeshOptions {
    pub fn new(train: TrainOptions, ranks: usize) -> MeshOptions {
        MeshOptions {
            train,
            ranks,
            shard_state: false,
            artifacts: "./artifacts".into(),
            ckpt_dir: PathBuf::from("mesh_ckpts"),
            checkpoint_every: 50,
            keep_last: 3,
            max_respawns: 3,
            max_frame_retries: 3,
            connect_timeout_ms: 30_000,
            read_timeout_ms: 30_000,
            heartbeat_every: 16,
            backoff_base_ms: 50,
            backoff_max_ms: 2_000,
            worker_faults: Vec::new(),
            worker_bin: None,
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        ensure!(self.ranks >= 1, "mesh: ranks must be >= 1");
        ensure!(self.checkpoint_every >= 1, "mesh: checkpoint_every must be >= 1");
        ensure!(self.read_timeout_ms >= 1, "mesh: read_timeout_ms must be >= 1");
        for (r, _) in &self.worker_faults {
            ensure!(*r < self.ranks, "mesh: worker_faults names rank {r} of {}", self.ranks);
        }
        Ok(())
    }
}

/// What a completed mesh run did, beyond the trainer's own metrics.
#[derive(Debug, Clone)]
pub struct MeshReport {
    /// Final eval perplexity (same eval the single-process loop runs).
    pub ppl: f64,
    /// Worker processes respawned after a crash/hang.
    pub respawns: usize,
    /// Corrupt frames rejected by CRC and re-requested.
    pub frame_retries: usize,
}

/// Run a full mesh training: spawn `ranks` workers, train to
/// `opts.train.steps`, eval, shut the fleet down. Returns the trainer
/// (params/state/metrics all populated, bit-identical to a
/// single-process run with `shards = ranks`) plus the recovery report.
pub fn train<'e>(
    engine: &'e Engine,
    opts: &MeshOptions,
) -> Result<(Trainer<'e>, MeshReport), TrainError> {
    opts.validate().map_err(TrainError::mesh)?;
    let mut topts = opts.train.clone();
    topts.shards = opts.ranks;
    let mut tr = Trainer::new(engine, topts).map_err(TrainError::engine)?;
    // the shard plan is a pure function of (optimizer, size, ranks) —
    // every worker derives the identical partition on its own
    let shard_ranges: Option<ShardRanges> = if opts.shard_state {
        let size = engine.manifest.size(&opts.train.size).map_err(TrainError::engine)?;
        let prog = UpdateProgram::new(&opts.train.optimizer, size).map_err(TrainError::engine)?;
        let plan = prog.shard_plan(opts.ranks);
        Some(plan.params.into_iter().zip(plan.state).collect())
    } else {
        None
    };
    let store = CheckpointStore::open(&opts.ckpt_dir, opts.keep_last).map_err(TrainError::io)?;
    // step-0 baseline so recovery always has a rollback target (in
    // sharded mode the state is still all-zeros here, matching the
    // freshly spawned workers — no fetch round needed)
    let ck = tr.checkpoint().map_err(TrainError::engine)?;
    match &shard_ranges {
        Some(ranges) => {
            store.save_sharded(&ck, tr.n_params(), ranges).map_err(TrainError::io)?;
        }
        None => {
            store.save(&ck).map_err(TrainError::io)?;
        }
    }

    let listener = TcpListener::bind("127.0.0.1:0").map_err(|e| TrainError::mesh(e.into()))?;
    listener.set_nonblocking(true).map_err(|e| TrainError::mesh(e.into()))?;
    let addr = listener.local_addr().map_err(|e| TrainError::mesh(e.into()))?;

    let mut fleet = Fleet::new(opts, addr);
    for r in 0..opts.ranks {
        fleet.spawn(r, true).map_err(TrainError::mesh)?;
    }
    for _ in 0..opts.ranks {
        fleet.accept_hello(&listener).map_err(TrainError::mesh)?;
    }

    let mut report = MeshReport { ppl: f64::NAN, respawns: 0, frame_retries: 0 };
    let mut respawns_left = opts.max_respawns;
    let mut consec_failures: u32 = 0;

    loop {
        while tr.step < tr.opts.steps {
            let mut failed = if opts.heartbeat_every > 0 && tr.step % opts.heartbeat_every == 0 {
                fleet.heartbeat_round()
            } else {
                Vec::new()
            };
            if failed.is_empty() {
                tr.begin_step();
                failed = exchange(&mut tr, &mut fleet, opts, &mut report);
            }
            if failed.is_empty() {
                match &shard_ranges {
                    Some(ranges) => {
                        // Divergence and Engine errors propagate typed
                        // *before* the remote apply, exactly where the
                        // single-process step would fail
                        let loss = tr.reduce_and_guard()?;
                        failed = shard_apply(&mut tr, &mut fleet, opts, &mut report, ranges);
                        if failed.is_empty() {
                            consec_failures = 0;
                            tr.record_step(loss);
                            tr.after_step(loss)?;
                            if tr.step % opts.checkpoint_every == 0 {
                                failed =
                                    fetch_state_all(&mut tr, &mut fleet, opts, &mut report, ranges);
                                if failed.is_empty() {
                                    let ck = tr.checkpoint().map_err(TrainError::engine)?;
                                    store
                                        .save_sharded(&ck, tr.n_params(), ranges)
                                        .map_err(TrainError::io)?;
                                }
                            }
                        }
                    }
                    None => {
                        consec_failures = 0;
                        // Divergence and Engine errors propagate typed,
                        // exactly like single-process train(): respawning
                        // cannot fix math
                        let loss = tr.finish_step()?;
                        tr.after_step(loss)?;
                        if tr.step % opts.checkpoint_every == 0 {
                            let ck = tr.checkpoint().map_err(TrainError::engine)?;
                            store.save(&ck).map_err(TrainError::io)?;
                        }
                    }
                }
            }
            if !failed.is_empty() {
                recover(
                    &mut tr,
                    &mut fleet,
                    &listener,
                    &store,
                    opts,
                    &mut report,
                    &mut respawns_left,
                    &mut consec_failures,
                    &failed,
                    shard_ranges.as_deref(),
                )?;
            }
        }
        // sharded mode: pull every rank's final state shard home so the
        // returned trainer is bit-identical to a single-process run; a
        // failure here re-enters the training loop via rollback
        let Some(ranges) = &shard_ranges else { break };
        let failed = fetch_state_all(&mut tr, &mut fleet, opts, &mut report, ranges);
        if failed.is_empty() {
            break;
        }
        recover(
            &mut tr,
            &mut fleet,
            &listener,
            &store,
            opts,
            &mut report,
            &mut respawns_left,
            &mut consec_failures,
            &failed,
            shard_ranges.as_deref(),
        )?;
    }
    report.ppl = tr.eval().map_err(TrainError::engine)?.exp();
    fleet.shutdown_all();
    Ok((tr, report))
}

/// One broadcast + gather round. Returns the ranks that failed
/// (empty = every shard's `[loss, grads..]` is installed in the
/// trainer). Survivors are always drained — even after a failure — so
/// they end up parked on their next blocking read with no stale frames
/// in flight.
fn exchange(
    tr: &mut Trainer<'_>,
    fleet: &mut Fleet<'_>,
    opts: &MeshOptions,
    report: &mut MeshReport,
) -> Vec<usize> {
    let step = tr.step as u64;
    let ranks = fleet.conns.len();
    let mut reached = vec![false; ranks];
    let mut failed = Vec::new();
    for r in 0..ranks {
        let sent = match fleet.conns[r].as_mut() {
            Some(stream) => wire::write_step(stream, step, &tr.params).is_ok(),
            None => false,
        };
        if sent {
            reached[r] = true;
        } else {
            failed.push(r);
        }
    }
    for r in 0..ranks {
        if !reached[r] {
            continue;
        }
        if let Err(e) = gather_rank(tr, fleet, r, step, opts, report) {
            if !opts.train.quiet {
                eprintln!("mesh: rank {r} failed at step {step}: {e}");
            }
            failed.push(r);
        }
    }
    failed
}

/// Read one rank's `Grads` for `step`, with bounded CRC re-requests.
fn gather_rank(
    tr: &mut Trainer<'_>,
    fleet: &mut Fleet<'_>,
    r: usize,
    step: u64,
    opts: &MeshOptions,
    report: &mut MeshReport,
) -> anyhow::Result<()> {
    let mut retries = 0usize;
    loop {
        let stream = match fleet.conns[r].as_mut() {
            Some(s) => s,
            None => bail!("no connection"),
        };
        match wire::read_frame(stream) {
            Ok(Frame::Grads { step: s, tensors }) => {
                ensure!(s == step, "stale grads for step {s} (want {step})");
                validate_grads(tr, &tensors)?;
                *tr.shard_out_mut(r) = tensors;
                return Ok(());
            }
            Ok(other) => bail!("unexpected {} frame (want Grads)", other.name()),
            Err(WireError::Crc { .. }) => {
                ensure!(
                    retries < opts.max_frame_retries,
                    "frame retries ({}) exhausted",
                    opts.max_frame_retries
                );
                retries += 1;
                report.frame_retries += 1;
                wire::write_resend(stream)?;
            }
            Err(WireError::Fatal(e)) => return Err(e),
        }
    }
}

/// The gathered tensors come off the network: validate against the
/// trainer's own layout before installing them.
fn validate_grads(tr: &Trainer<'_>, tensors: &[Tensor]) -> anyhow::Result<()> {
    ensure!(
        tensors.len() == tr.n_params() + 1,
        "got {} tensors, want loss + {} grads",
        tensors.len(),
        tr.n_params()
    );
    ensure!(tensors[0].numel() == 1, "slot 0 must be the loss scalar");
    for (g, p) in tensors[1..].iter().zip(tr.params.iter()) {
        ensure!(
            g.shape() == p.shape(),
            "grad shape {:?} does not match param shape {:?}",
            g.shape(),
            p.shape()
        );
    }
    Ok(())
}

/// Sharded-mode apply: ship each rank its slice of the reduced
/// gradients (plus the exact lr bits the single-process kernels would
/// see) and gather the updated param shards back, installing them in
/// place. Returns the failed ranks (empty = `tr.params` is fully
/// updated). Like [`exchange`], every reached rank is drained even
/// after an earlier failure, so survivors park cleanly.
fn shard_apply(
    tr: &mut Trainer<'_>,
    fleet: &mut Fleet<'_>,
    opts: &MeshOptions,
    report: &mut MeshReport,
    ranges: &[(Range<usize>, Range<usize>)],
) -> Vec<usize> {
    let step = tr.step as u64;
    let lr = Tensor::scalar_f32(tr.step_lr_f32());
    let mut reached = vec![false; ranges.len()];
    let mut failed = Vec::new();
    for (r, (pr, _)) in ranges.iter().enumerate() {
        let sent = match fleet.conns[r].as_mut() {
            Some(stream) => {
                wire::write_shard_grads(stream, step, &lr, &tr.reduced_grads()[pr.clone()]).is_ok()
            }
            None => false,
        };
        if sent {
            reached[r] = true;
        } else {
            failed.push(r);
        }
    }
    for (r, (pr, _)) in ranges.iter().enumerate() {
        if !reached[r] {
            continue;
        }
        if let Err(e) = gather_shard_params(tr, fleet, r, step, pr, opts, report) {
            if !opts.train.quiet {
                eprintln!("mesh: rank {r} failed applying step {step}: {e}");
            }
            failed.push(r);
        }
    }
    failed
}

/// Read one rank's `ShardParams` for `step`, with bounded CRC
/// re-requests, and install the shard into `tr.params`.
fn gather_shard_params(
    tr: &mut Trainer<'_>,
    fleet: &mut Fleet<'_>,
    r: usize,
    step: u64,
    pr: &Range<usize>,
    opts: &MeshOptions,
    report: &mut MeshReport,
) -> anyhow::Result<()> {
    let mut retries = 0usize;
    loop {
        let stream = match fleet.conns[r].as_mut() {
            Some(s) => s,
            None => bail!("no connection"),
        };
        match wire::read_frame(stream) {
            Ok(Frame::ShardParams { step: s, tensors }) => {
                ensure!(s == step, "stale param shard for step {s} (want {step})");
                ensure!(
                    tensors.len() == pr.len(),
                    "got {} param tensors, want {}",
                    tensors.len(),
                    pr.len()
                );
                for (t, p) in tensors.iter().zip(&tr.params[pr.clone()]) {
                    ensure!(
                        t.shape() == p.shape(),
                        "param shard shape {:?} does not match {:?}",
                        t.shape(),
                        p.shape()
                    );
                }
                for (p, t) in tr.params[pr.clone()].iter_mut().zip(tensors) {
                    *p = t;
                }
                return Ok(());
            }
            Ok(other) => bail!("unexpected {} frame (want ShardParams)", other.name()),
            Err(WireError::Crc { .. }) => {
                ensure!(
                    retries < opts.max_frame_retries,
                    "frame retries ({}) exhausted",
                    opts.max_frame_retries
                );
                retries += 1;
                report.frame_retries += 1;
                wire::write_resend(stream)?;
            }
            Err(WireError::Fatal(e)) => return Err(e),
        }
    }
}

/// Pull every rank's optimizer-state shard into `tr.state` (checkpoint
/// cadence and end of run). Returns the failed ranks.
fn fetch_state_all(
    tr: &mut Trainer<'_>,
    fleet: &mut Fleet<'_>,
    opts: &MeshOptions,
    report: &mut MeshReport,
    ranges: &[(Range<usize>, Range<usize>)],
) -> Vec<usize> {
    let step = tr.step as u64;
    let mut reached = vec![false; ranges.len()];
    let mut failed = Vec::new();
    for r in 0..ranges.len() {
        let sent = match fleet.conns[r].as_mut() {
            Some(s) => wire::write_fetch_state(s, step).is_ok(),
            None => false,
        };
        if sent {
            reached[r] = true;
        } else {
            failed.push(r);
        }
    }
    for (r, (_, sr)) in ranges.iter().enumerate() {
        if !reached[r] {
            continue;
        }
        if let Err(e) = gather_shard_state(tr, fleet, r, step, sr, opts, report) {
            if !opts.train.quiet {
                eprintln!("mesh: rank {r} failed returning state at step {step}: {e}");
            }
            failed.push(r);
        }
    }
    failed
}

/// Read one rank's `ShardState` for `step`, with bounded CRC
/// re-requests, and install the shard into `tr.state`.
fn gather_shard_state(
    tr: &mut Trainer<'_>,
    fleet: &mut Fleet<'_>,
    r: usize,
    step: u64,
    sr: &Range<usize>,
    opts: &MeshOptions,
    report: &mut MeshReport,
) -> anyhow::Result<()> {
    let mut retries = 0usize;
    loop {
        let stream = match fleet.conns[r].as_mut() {
            Some(s) => s,
            None => bail!("no connection"),
        };
        match wire::read_frame(stream) {
            Ok(Frame::ShardState { step: s, tensors }) => {
                ensure!(s == step, "stale state shard for step {s} (want {step})");
                ensure!(
                    tensors.len() == sr.len(),
                    "got {} state tensors, want {}",
                    tensors.len(),
                    sr.len()
                );
                for (t, slot) in tensors.iter().zip(&tr.state[sr.clone()]) {
                    ensure!(
                        t.shape() == slot.shape(),
                        "state shard shape {:?} does not match {:?}",
                        t.shape(),
                        slot.shape()
                    );
                }
                for (slot, t) in tr.state[sr.clone()].iter_mut().zip(tensors) {
                    *slot = t;
                }
                return Ok(());
            }
            Ok(other) => bail!("unexpected {} frame (want ShardState)", other.name()),
            Err(WireError::Crc { .. }) => {
                ensure!(
                    retries < opts.max_frame_retries,
                    "frame retries ({}) exhausted",
                    opts.max_frame_retries
                );
                retries += 1;
                report.frame_retries += 1;
                wire::write_resend(stream)?;
            }
            Err(WireError::Fatal(e)) => return Err(e),
        }
    }
}

/// Re-seed every rank's owned state shard from the trainer's (just
/// restored) state. Returns the ranks whose re-seed write failed.
fn reseed_state(
    tr: &Trainer<'_>,
    fleet: &mut Fleet<'_>,
    ranges: &[(Range<usize>, Range<usize>)],
) -> Vec<usize> {
    let step = tr.step as u64;
    let mut failed = Vec::new();
    for (r, (_, sr)) in ranges.iter().enumerate() {
        let ok = match fleet.conns[r].as_mut() {
            Some(s) => wire::write_shard_state(s, step, &tr.state[sr.clone()]).is_ok(),
            None => false,
        };
        if !ok {
            failed.push(r);
        }
    }
    failed
}

/// Kill + respawn each failed rank (bounded budget, exponential
/// backoff), then roll the trainer back to the newest snapshot so the
/// whole mesh replays from a clean point. In sharded mode the rollback
/// source is the newest *complete* sharded snapshot and every rank —
/// survivor or replacement — gets its state shard re-seeded from it; a
/// rank that fails during re-seeding joins the failed set and the loop
/// repeats under the same respawn budget.
#[allow(clippy::too_many_arguments)]
fn recover(
    tr: &mut Trainer<'_>,
    fleet: &mut Fleet<'_>,
    listener: &TcpListener,
    store: &CheckpointStore,
    opts: &MeshOptions,
    report: &mut MeshReport,
    respawns_left: &mut usize,
    consec_failures: &mut u32,
    failed: &[usize],
    shard_ranges: Option<&[(Range<usize>, Range<usize>)]>,
) -> Result<(), TrainError> {
    let mut pending: Vec<usize> = failed.to_vec();
    while !pending.is_empty() {
        for &r in &pending {
            if *respawns_left == 0 {
                fleet.shutdown_all();
                return Err(TrainError::mesh(anyhow::anyhow!(
                    "rank {r} failed and the respawn budget ({}) is exhausted",
                    opts.max_respawns
                )));
            }
            *respawns_left -= 1;
            report.respawns += 1;
            fleet.kill(r);
            let backoff = backoff_ms(opts, *consec_failures);
            std::thread::sleep(Duration::from_millis(backoff));
            // respawned clean: no --faults, no SCALE_FAULTS — the original
            // spec would re-arm with reset hit counters in the fresh process
            // and kill it again forever
            fleet.spawn(r, false).map_err(TrainError::mesh)?;
            fleet.accept_hello(listener).map_err(TrainError::mesh)?;
        }
        *consec_failures += 1;
        let restored = match shard_ranges {
            Some(ranges) => store.latest_sharded(ranges.len()).map_err(TrainError::io)?,
            None => store.latest().map_err(TrainError::io)?,
        };
        let (_, ck) = restored
            .ok_or_else(|| TrainError::io(anyhow::anyhow!("no snapshot to roll back to")))?;
        tr.restore(&ck).map_err(TrainError::engine)?;
        tr.metrics.truncate_to_step(tr.step);
        pending = match shard_ranges {
            Some(ranges) => reseed_state(tr, fleet, ranges),
            None => Vec::new(),
        };
        if !opts.train.quiet && pending.is_empty() {
            println!("  mesh: respawned rank(s) {failed:?}, rolled back to step {}", tr.step);
        }
    }
    Ok(())
}

fn backoff_ms(opts: &MeshOptions, consec: u32) -> u64 {
    opts.backoff_base_ms.saturating_mul(1u64 << consec.min(6)).min(opts.backoff_max_ms)
}

/// The worker processes and their connections, slotted by rank.
/// Dropping the fleet kills any children still alive, so an early
/// error return never leaks processes.
struct Fleet<'a> {
    opts: &'a MeshOptions,
    addr: SocketAddr,
    children: Vec<Option<Child>>,
    conns: Vec<Option<TcpStream>>,
}

impl<'a> Fleet<'a> {
    fn new(opts: &'a MeshOptions, addr: SocketAddr) -> Fleet<'a> {
        Fleet {
            opts,
            addr,
            children: (0..opts.ranks).map(|_| None).collect(),
            conns: (0..opts.ranks).map(|_| None).collect(),
        }
    }

    /// Fork/exec one worker rank of the same binary. `initial` arms the
    /// rank's `worker_faults` spec; respawns never do.
    fn spawn(&mut self, rank: usize, initial: bool) -> anyhow::Result<()> {
        let bin = match &self.opts.worker_bin {
            Some(p) => p.clone(),
            None => std::env::current_exe()?,
        };
        let t = &self.opts.train;
        let mut cmd = Command::new(bin);
        cmd.arg("worker");
        cmd.arg("--rank").arg(rank.to_string());
        cmd.arg("--ranks").arg(self.opts.ranks.to_string());
        cmd.arg("--connect").arg(self.addr.to_string());
        cmd.arg("--artifacts").arg(&self.opts.artifacts);
        cmd.arg("--size").arg(&t.size);
        cmd.arg("--optimizer").arg(&t.optimizer);
        cmd.arg("--steps").arg(t.steps.to_string());
        // f64 Display is shortest-round-trip, so the worker parses the
        // identical float (it never uses it for bits; rings key on seed)
        cmd.arg("--lr").arg(format!("{}", t.base_lr));
        cmd.arg("--seed").arg(t.seed.to_string());
        if self.opts.shard_state {
            cmd.arg("--shard-state");
        }
        cmd.arg("--quiet");
        cmd.stdout(Stdio::null());
        // supervisor-side env faults must not leak into workers
        cmd.env_remove("SCALE_FAULTS");
        if initial {
            if let Some((_, spec)) = self.opts.worker_faults.iter().find(|(fr, _)| *fr == rank) {
                cmd.arg("--faults").arg(spec);
            }
        }
        let child = cmd.spawn().map_err(|e| anyhow::anyhow!("spawn worker rank {rank}: {e}"))?;
        self.children[rank] = Some(child);
        Ok(())
    }

    /// Accept one worker connection (the nonblocking listener is polled
    /// against `connect_timeout_ms`) and slot it by its Hello rank.
    fn accept_hello(&mut self, listener: &TcpListener) -> anyhow::Result<()> {
        let deadline = Instant::now() + Duration::from_millis(self.opts.connect_timeout_ms);
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    stream.set_nodelay(true)?;
                    let t = Duration::from_millis(self.opts.read_timeout_ms);
                    stream.set_read_timeout(Some(t))?;
                    stream.set_write_timeout(Some(t))?;
                    let mut stream = stream;
                    // version-checked handshake: a worker from another
                    // build is refused here with a typed error instead
                    // of misdecoding its frames mid-run
                    let rank = match wire::read_frame(&mut stream) {
                        Ok(frame) => wire::hello_rank(&frame)
                            .map_err(|e| anyhow::anyhow!("mesh: handshake rejected: {e}"))?,
                        Err(e) => bail!("mesh: bad Hello handshake: {e}"),
                    };
                    ensure!(rank < self.conns.len(), "mesh: Hello from unknown rank {rank}");
                    ensure!(
                        self.conns[rank].is_none(),
                        "mesh: duplicate connection for rank {rank}"
                    );
                    self.conns[rank] = Some(stream);
                    return Ok(());
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    ensure!(
                        Instant::now() < deadline,
                        "mesh: timed out waiting for a worker to connect ({} ms)",
                        self.opts.connect_timeout_ms
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Ping every rank, then collect Pongs. Returns the unresponsive
    /// ranks (empty = fleet healthy).
    fn heartbeat_round(&mut self) -> Vec<usize> {
        let ranks = self.conns.len();
        let mut reached = vec![false; ranks];
        let mut failed = Vec::new();
        for r in 0..ranks {
            let sent = match self.conns[r].as_mut() {
                Some(s) => wire::write_ping(s).is_ok(),
                None => false,
            };
            if sent {
                reached[r] = true;
            } else {
                failed.push(r);
            }
        }
        for r in 0..ranks {
            if !reached[r] {
                continue;
            }
            let alive = match self.conns[r].as_mut() {
                Some(s) => matches!(wire::read_frame(s), Ok(Frame::Pong)),
                None => false,
            };
            if !alive {
                failed.push(r);
            }
        }
        failed
    }

    /// Drop the rank's connection and kill + reap its process.
    fn kill(&mut self, rank: usize) {
        self.conns[rank] = None;
        if let Some(mut child) = self.children[rank].take() {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    /// Best-effort Shutdown frames, then a bounded grace period before
    /// killing stragglers. Never errors, never hangs.
    fn shutdown_all(&mut self) {
        for conn in self.conns.iter_mut() {
            if let Some(s) = conn.as_mut() {
                let _ = wire::write_shutdown(s);
            }
        }
        let deadline = Instant::now() + Duration::from_millis(2_000);
        for child in self.children.iter_mut() {
            if let Some(c) = child.as_mut() {
                loop {
                    match c.try_wait() {
                        Ok(Some(_)) => break,
                        Ok(None) if Instant::now() < deadline => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        _ => {
                            let _ = c.kill();
                            let _ = c.wait();
                            break;
                        }
                    }
                }
            }
            *child = None;
        }
        for conn in self.conns.iter_mut() {
            *conn = None;
        }
    }
}

impl Drop for Fleet<'_> {
    fn drop(&mut self) {
        for child in self.children.iter_mut() {
            if let Some(mut c) = child.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_bounded_and_exponential() {
        let mut o = MeshOptions::new(TrainOptions::default(), 2);
        o.backoff_base_ms = 50;
        o.backoff_max_ms = 2_000;
        assert_eq!(backoff_ms(&o, 0), 50);
        assert_eq!(backoff_ms(&o, 1), 100);
        assert_eq!(backoff_ms(&o, 2), 200);
        assert_eq!(backoff_ms(&o, 10), 2_000, "capped");
        assert_eq!(backoff_ms(&o, 63), 2_000, "shift never overflows");
    }

    #[test]
    fn options_validate() {
        let mut o = MeshOptions::new(TrainOptions::default(), 2);
        o.validate().unwrap();
        o.ranks = 0;
        assert!(o.validate().is_err());
        o.ranks = 2;
        o.checkpoint_every = 0;
        assert!(o.validate().is_err());
        o.checkpoint_every = 1;
        o.worker_faults = vec![(5, "rank_exit@1".into())];
        assert!(o.validate().is_err());
    }
}
