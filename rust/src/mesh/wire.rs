//! Length-prefixed, CRC32-framed wire protocol between the mesh
//! supervisor and its worker ranks.
//!
//! ## Frame layout (all integers little-endian)
//!
//! ```text
//! u32 payload_len | payload bytes | u32 crc32(payload)
//! payload := u8 tag | body
//! ```
//!
//! The CRC ([`crate::util::crc::crc32`], the same polynomial checkpoint
//! v2 uses) covers the payload only, so a damaged payload is detected
//! while the length framing stays intact — the reader consumes exactly
//! one frame and can ask for a resend instead of tearing the
//! connection down. That split is the whole point of
//! [`WireError::Crc`] vs [`WireError::Fatal`]: a CRC mismatch is
//! *recoverable* (bounded re-request), everything else (EOF, timeout,
//! oversized frame, unknown tag) means the connection is gone or
//! desynced and the rank must be treated as failed.
//!
//! ## Frames
//!
//! | tag | frame         | body                                     | direction |
//! |-----|---------------|------------------------------------------|-----------|
//! | 1   | `Hello`       | `u32 version`, `u32 rank`                | w -> s    |
//! | 2   | `Step`        | `u64 step`, tensors (params)             | s -> w    |
//! | 3   | `Grads`       | `u64 step`, tensors (`[loss, grads]`)    | w -> s    |
//! | 4   | `Resend`      | —                                        | s -> w    |
//! | 5   | `Ping`        | —                                        | s -> w    |
//! | 6   | `Pong`        | —                                        | w -> s    |
//! | 7   | `Shutdown`    | —                                        | s -> w    |
//! | 8   | `ShardGrads`  | `u64 step`, tensors (`[lr, grad shard]`) | s -> w    |
//! | 9   | `ShardParams` | `u64 step`, tensors (param shard)        | w -> s    |
//! | 10  | `ShardState`  | `u64 step`, tensors (state shard)        | both      |
//! | 11  | `FetchState`  | `u64 step`                               | s -> w    |
//!
//! `Hello` carries [`WIRE_VERSION`]; the supervisor rejects a
//! mismatched worker with a typed fatal error at the handshake
//! ([`hello_rank`]) instead of misdecoding its frames later. The
//! `Shard*` frames are the sharded-optimizer-state mode: the supervisor
//! ships each rank its slice of the reduced gradients (plus the exact
//! lr bits), the rank applies its owned slice of the update plan and
//! returns the updated param shard, and `ShardState`/`FetchState` move
//! optimizer-state shards for checkpoints and recovery re-seeding.
//!
//! Tensors travel as `u32 count`, then per tensor `u32 ndims`,
//! `u64 dims..`, raw little-endian f32 data. Only f32 tensors travel
//! (params and gradients); f32 bits round-trip exactly through
//! `to_le_bytes`/`from_le_bytes`, which is one of the three legs of the
//! mesh bit-determinism argument (see the [`crate::mesh`] module docs).
//! The decoder treats the peer as untrusted: counts, dims, and data
//! lengths are validated against the remaining payload *before* any
//! allocation.
//!
//! ## Failpoints
//!
//! Every frame write funnels through [`send`], which hosts the wire
//! failpoints (`conn_drop`, `frame_delay`, `frame_corrupt` — see
//! [`crate::fault`]). `frame_corrupt` flips one payload byte while
//! writing the CRC of the *clean* payload, producing exactly the torn
//! frame the CRC leg must catch. Disarmed, each is one relaxed atomic
//! load.

use std::io::{self, Read, Write};

use crate::fault;
use crate::runtime::Tensor;
use crate::util::crc::crc32;
use anyhow::{bail, ensure};

/// Upper bound on a frame payload; a declared length beyond this is a
/// protocol violation, not an allocation request.
pub const MAX_FRAME: usize = 1 << 30;
/// Tensor-codec bounds, mirrored from the checkpoint loader's hostile-
/// input posture.
const MAX_WIRE_TENSORS: usize = 1 << 16;
const MAX_WIRE_DIMS: usize = 8;
const MAX_WIRE_DIM: u64 = 1 << 31;
/// How long a `frame_delay` failpoint stalls the write — comfortably
/// past the chaos suite's read timeout, comfortably under its overall
/// test budget.
const FRAME_DELAY_MS: u64 = 1500;

/// Protocol version carried by every `Hello`. Bumped whenever the frame
/// grammar changes incompatibly (v2 added the version field itself plus
/// the `Shard*` frames); a supervisor only accepts its own version.
pub const WIRE_VERSION: u32 = 2;

const TAG_HELLO: u8 = 1;
const TAG_STEP: u8 = 2;
const TAG_GRADS: u8 = 3;
const TAG_RESEND: u8 = 4;
const TAG_PING: u8 = 5;
const TAG_PONG: u8 = 6;
const TAG_SHUTDOWN: u8 = 7;
const TAG_SHARD_GRADS: u8 = 8;
const TAG_SHARD_PARAMS: u8 = 9;
const TAG_SHARD_STATE: u8 = 10;
const TAG_FETCH_STATE: u8 = 11;

/// A decoded frame. Tensor-bearing frames own their tensors; the write
/// side never builds this enum (the `write_*` helpers serialize straight
/// from borrowed `&[Tensor]`, so params are never cloned per step).
pub enum Frame {
    Hello { version: u32, rank: usize },
    Step { step: u64, tensors: Vec<Tensor> },
    Grads { step: u64, tensors: Vec<Tensor> },
    Resend,
    Ping,
    Pong,
    Shutdown,
    ShardGrads { step: u64, tensors: Vec<Tensor> },
    ShardParams { step: u64, tensors: Vec<Tensor> },
    ShardState { step: u64, tensors: Vec<Tensor> },
    FetchState { step: u64 },
}

impl Frame {
    /// Frame name for error messages (avoids Debug-printing tensors).
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::Step { .. } => "Step",
            Frame::Grads { .. } => "Grads",
            Frame::Resend => "Resend",
            Frame::Ping => "Ping",
            Frame::Pong => "Pong",
            Frame::Shutdown => "Shutdown",
            Frame::ShardGrads { .. } => "ShardGrads",
            Frame::ShardParams { .. } => "ShardParams",
            Frame::ShardState { .. } => "ShardState",
            Frame::FetchState { .. } => "FetchState",
        }
    }
}

/// Validate a handshake frame: a `Hello` speaking [`WIRE_VERSION`]
/// yields the rank; anything else is a typed fatal error (the peer is
/// from a different build or not a worker at all — misdecoding its
/// later frames would be worse than refusing it here).
pub fn hello_rank(frame: &Frame) -> Result<usize, WireError> {
    match frame {
        Frame::Hello { version, rank } if *version == WIRE_VERSION => Ok(*rank),
        Frame::Hello { version, .. } => Err(WireError::Fatal(anyhow::anyhow!(
            "peer speaks protocol version {version}, this supervisor requires {WIRE_VERSION}"
        ))),
        f => Err(WireError::Fatal(anyhow::anyhow!("expected Hello handshake, got {}", f.name()))),
    }
}

/// Read-side failure, split by recoverability.
#[derive(Debug)]
pub enum WireError {
    /// The frame arrived intact *as a frame* but its payload checksum
    /// failed — ask the peer to resend.
    Crc { expect: u32, got: u32 },
    /// EOF, timeout, oversized or malformed frame: the connection is
    /// unusable and the peer must be treated as failed.
    Fatal(anyhow::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Crc { expect, got } => {
                write!(f, "frame CRC mismatch (expect {expect:#010x}, got {got:#010x})")
            }
            WireError::Fatal(e) => write!(f, "wire failure: {e}"),
        }
    }
}

// ---- write side ------------------------------------------------------------

/// Write one frame: length prefix, payload, payload CRC. All wire
/// failpoints live here, in a fixed order:
///
/// 1. `conn_drop` — bail before writing anything; the caller abandons
///    the connection and its teardown (process exit or rank kill) is
///    what the peer observes as EOF.
/// 2. `frame_delay` — sleep [`FRAME_DELAY_MS`] before writing, so a
///    peer with a read timeout sees a hung rank.
/// 3. `frame_corrupt` — flip one payload byte on the wire while keeping
///    the clean payload's CRC, so the peer's checksum rejects it.
pub fn send<S: Write>(stream: &mut S, payload: &[u8]) -> anyhow::Result<()> {
    if fault::fires("conn_drop") {
        bail!("conn_drop failpoint: connection dropped");
    }
    if fault::fires("frame_delay") {
        std::thread::sleep(std::time::Duration::from_millis(FRAME_DELAY_MS));
    }
    ensure!(payload.len() <= MAX_FRAME, "wire: frame too large ({} bytes)", payload.len());
    let crc = crc32(payload);
    stream.write_all(&(payload.len() as u32).to_le_bytes())?;
    if fault::fires("frame_corrupt") {
        let mut bad = payload.to_vec();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x20;
        stream.write_all(&bad)?;
    } else {
        stream.write_all(payload)?;
    }
    stream.write_all(&crc.to_le_bytes())?;
    stream.flush()?;
    Ok(())
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn encode_tensors(buf: &mut Vec<u8>, tensors: &[Tensor]) -> anyhow::Result<()> {
    ensure!(tensors.len() <= MAX_WIRE_TENSORS, "wire: too many tensors");
    put_u32(buf, tensors.len() as u32);
    for t in tensors {
        encode_one(buf, t)?;
    }
    Ok(())
}

fn encode_one(buf: &mut Vec<u8>, t: &Tensor) -> anyhow::Result<()> {
    let Tensor::F32 { shape, data } = t else {
        bail!("wire: only f32 tensors travel between ranks");
    };
    ensure!(shape.len() <= MAX_WIRE_DIMS, "wire: tensor rank {} too deep", shape.len());
    put_u32(buf, shape.len() as u32);
    for &d in shape {
        put_u64(buf, d as u64);
    }
    for &x in data {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    Ok(())
}

pub fn write_hello<S: Write>(stream: &mut S, rank: usize) -> anyhow::Result<()> {
    let mut p = Vec::with_capacity(9);
    p.push(TAG_HELLO);
    put_u32(&mut p, WIRE_VERSION);
    put_u32(&mut p, rank as u32);
    send(stream, &p)
}

pub fn write_step<S: Write>(stream: &mut S, step: u64, tensors: &[Tensor]) -> anyhow::Result<()> {
    write_tensor_frame(stream, TAG_STEP, step, tensors)
}

pub fn write_grads<S: Write>(stream: &mut S, step: u64, tensors: &[Tensor]) -> anyhow::Result<()> {
    write_tensor_frame(stream, TAG_GRADS, step, tensors)
}

fn write_tensor_frame<S: Write>(
    stream: &mut S,
    tag: u8,
    step: u64,
    tensors: &[Tensor],
) -> anyhow::Result<()> {
    let bytes: usize = tensors.iter().map(|t| 4 + 8 * t.shape().len() + 4 * t.numel()).sum();
    let mut p = Vec::with_capacity(13 + bytes);
    p.push(tag);
    put_u64(&mut p, step);
    encode_tensors(&mut p, tensors)?;
    send(stream, &p)
}

/// `ShardGrads` is `[lr, grad shard..]` on the wire; taking the lr
/// scalar and the grad slice separately lets the supervisor serialize
/// straight out of the trainer's reduced-grad buffer — no per-step
/// clone of a gradient shard just to prepend one scalar.
pub fn write_shard_grads<S: Write>(
    stream: &mut S,
    step: u64,
    lr: &Tensor,
    grads: &[Tensor],
) -> anyhow::Result<()> {
    ensure!(grads.len() < MAX_WIRE_TENSORS, "wire: too many tensors");
    let bytes: usize = grads.iter().map(|t| 4 + 8 * t.shape().len() + 4 * t.numel()).sum();
    let mut p = Vec::with_capacity(13 + 16 + bytes);
    p.push(TAG_SHARD_GRADS);
    put_u64(&mut p, step);
    put_u32(&mut p, (grads.len() + 1) as u32);
    encode_one(&mut p, lr)?;
    for g in grads {
        encode_one(&mut p, g)?;
    }
    send(stream, &p)
}

pub fn write_shard_params<S: Write>(
    stream: &mut S,
    step: u64,
    tensors: &[Tensor],
) -> anyhow::Result<()> {
    write_tensor_frame(stream, TAG_SHARD_PARAMS, step, tensors)
}

pub fn write_shard_state<S: Write>(
    stream: &mut S,
    step: u64,
    tensors: &[Tensor],
) -> anyhow::Result<()> {
    write_tensor_frame(stream, TAG_SHARD_STATE, step, tensors)
}

pub fn write_fetch_state<S: Write>(stream: &mut S, step: u64) -> anyhow::Result<()> {
    let mut p = Vec::with_capacity(9);
    p.push(TAG_FETCH_STATE);
    put_u64(&mut p, step);
    send(stream, &p)
}

pub fn write_resend<S: Write>(stream: &mut S) -> anyhow::Result<()> {
    send(stream, &[TAG_RESEND])
}

pub fn write_ping<S: Write>(stream: &mut S) -> anyhow::Result<()> {
    send(stream, &[TAG_PING])
}

pub fn write_pong<S: Write>(stream: &mut S) -> anyhow::Result<()> {
    send(stream, &[TAG_PONG])
}

pub fn write_shutdown<S: Write>(stream: &mut S) -> anyhow::Result<()> {
    send(stream, &[TAG_SHUTDOWN])
}

// ---- read side -------------------------------------------------------------

fn read_bytes<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), WireError> {
    r.read_exact(buf).map_err(|e| {
        WireError::Fatal(match e.kind() {
            io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => {
                anyhow::anyhow!("read timed out (peer hung or stalled)")
            }
            io::ErrorKind::UnexpectedEof => anyhow::anyhow!("connection closed by peer"),
            _ => anyhow::anyhow!("read failed: {e}"),
        })
    })
}

/// Read and decode exactly one frame. On [`WireError::Crc`] the whole
/// frame (length, payload, CRC) has been consumed, so the stream is
/// still framed and the caller may request a resend.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut hdr = [0u8; 4];
    read_bytes(r, &mut hdr)?;
    let len = u32::from_le_bytes(hdr) as usize;
    if len == 0 || len > MAX_FRAME {
        return Err(WireError::Fatal(anyhow::anyhow!("bad frame length {len}")));
    }
    let mut payload = vec![0u8; len];
    read_bytes(r, &mut payload)?;
    let mut crc_b = [0u8; 4];
    read_bytes(r, &mut crc_b)?;
    let expect = u32::from_le_bytes(crc_b);
    let got = crc32(&payload);
    if got != expect {
        return Err(WireError::Crc { expect, got });
    }
    decode_payload(&payload).map_err(WireError::Fatal)
}

struct Cur<'a> {
    b: &'a [u8],
    off: usize,
}

impl<'a> Cur<'a> {
    fn remaining(&self) -> usize {
        self.b.len() - self.off
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        ensure!(self.remaining() >= n, "wire: truncated payload");
        let s = &self.b[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

fn decode_tensors(c: &mut Cur<'_>) -> anyhow::Result<Vec<Tensor>> {
    let count = c.u32()? as usize;
    ensure!(count <= MAX_WIRE_TENSORS, "wire: tensor count {count} too large");
    // every tensor needs at least its ndims word: a hostile count can't
    // reserve more than the payload could possibly hold
    ensure!(count * 4 <= c.remaining(), "wire: tensor count {count} exceeds payload");
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let ndims = c.u32()? as usize;
        ensure!(ndims <= MAX_WIRE_DIMS, "wire: tensor rank {ndims} too deep");
        let mut shape = Vec::with_capacity(ndims);
        let mut numel: usize = 1;
        for _ in 0..ndims {
            let d = c.u64()?;
            ensure!(d <= MAX_WIRE_DIM, "wire: dim {d} too large");
            shape.push(d as usize);
            numel = numel
                .checked_mul(d as usize)
                .ok_or_else(|| anyhow::anyhow!("wire: tensor size overflow"))?;
        }
        let raw = c.take(numel * 4)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
            .collect();
        out.push(Tensor::from_f32(&shape, data));
    }
    Ok(out)
}

fn decode_payload(payload: &[u8]) -> anyhow::Result<Frame> {
    let mut c = Cur { b: payload, off: 0 };
    let tag = c.take(1)?[0];
    let frame = match tag {
        TAG_HELLO => Frame::Hello { version: c.u32()?, rank: c.u32()? as usize },
        TAG_STEP => Frame::Step { step: c.u64()?, tensors: decode_tensors(&mut c)? },
        TAG_GRADS => Frame::Grads { step: c.u64()?, tensors: decode_tensors(&mut c)? },
        TAG_RESEND => Frame::Resend,
        TAG_PING => Frame::Ping,
        TAG_PONG => Frame::Pong,
        TAG_SHUTDOWN => Frame::Shutdown,
        TAG_SHARD_GRADS => Frame::ShardGrads { step: c.u64()?, tensors: decode_tensors(&mut c)? },
        TAG_SHARD_PARAMS => Frame::ShardParams { step: c.u64()?, tensors: decode_tensors(&mut c)? },
        TAG_SHARD_STATE => Frame::ShardState { step: c.u64()?, tensors: decode_tensors(&mut c)? },
        TAG_FETCH_STATE => Frame::FetchState { step: c.u64()? },
        other => bail!("wire: unknown frame tag {other}"),
    };
    ensure!(c.remaining() == 0, "wire: {} bytes of trailing garbage", c.remaining());
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn tensors() -> Vec<Tensor> {
        vec![
            Tensor::scalar_f32(1.25),
            Tensor::from_f32(&[2, 3], vec![1.0, -2.0, 3.5, f32::MIN_POSITIVE, 0.0, -0.0]),
            Tensor::from_f32(&[4], vec![9.0, 8.0, 7.0, 6.0]),
        ]
    }

    fn read_all(bytes: &[u8]) -> Vec<Frame> {
        let mut cur = Cursor::new(bytes);
        let mut out = Vec::new();
        while (cur.position() as usize) < bytes.len() {
            out.push(read_frame(&mut cur).unwrap());
        }
        out
    }

    #[test]
    fn roundtrip_every_frame_kind() {
        let mut buf: Vec<u8> = Vec::new();
        write_hello(&mut buf, 3).unwrap();
        write_step(&mut buf, 42, &tensors()).unwrap();
        write_grads(&mut buf, 42, &tensors()).unwrap();
        write_resend(&mut buf).unwrap();
        write_ping(&mut buf).unwrap();
        write_pong(&mut buf).unwrap();
        write_shutdown(&mut buf).unwrap();
        write_shard_grads(&mut buf, 42, &tensors()[0], &tensors()[1..]).unwrap();
        write_shard_params(&mut buf, 42, &tensors()).unwrap();
        write_shard_state(&mut buf, 42, &tensors()).unwrap();
        write_fetch_state(&mut buf, 42).unwrap();
        let frames = read_all(&buf);
        assert_eq!(frames.len(), 11);
        assert!(matches!(frames[0], Frame::Hello { version: WIRE_VERSION, rank: 3 }));
        assert_eq!(hello_rank(&frames[0]).unwrap(), 3);
        match &frames[1] {
            Frame::Step { step, tensors: ts } => {
                assert_eq!(*step, 42);
                // bit-exact f32 round-trip, shapes included
                assert_eq!(ts, &tensors());
            }
            f => panic!("expected Step, got {}", f.name()),
        }
        match &frames[2] {
            Frame::Grads { step, tensors: ts } => {
                assert_eq!(*step, 42);
                assert_eq!(ts, &tensors());
            }
            f => panic!("expected Grads, got {}", f.name()),
        }
        assert!(matches!(frames[3], Frame::Resend));
        assert!(matches!(frames[4], Frame::Ping));
        assert!(matches!(frames[5], Frame::Pong));
        assert!(matches!(frames[6], Frame::Shutdown));
        for (i, want) in [(7usize, "ShardGrads"), (8, "ShardParams"), (9, "ShardState")] {
            assert_eq!(frames[i].name(), want);
            match &frames[i] {
                Frame::ShardGrads { step, tensors: ts }
                | Frame::ShardParams { step, tensors: ts }
                | Frame::ShardState { step, tensors: ts } => {
                    assert_eq!(*step, 42);
                    assert_eq!(ts, &tensors());
                }
                f => panic!("expected {want}, got {}", f.name()),
            }
        }
        assert!(matches!(frames[10], Frame::FetchState { step: 42 }));
    }

    #[test]
    fn old_version_hello_is_a_clean_typed_rejection() {
        // hand-craft a v1-style Hello: the version word says 1
        let mut payload = vec![1u8]; // TAG_HELLO
        payload.extend_from_slice(&1u32.to_le_bytes());
        payload.extend_from_slice(&3u32.to_le_bytes());
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&payload);
        buf.extend_from_slice(&crc32(&payload).to_le_bytes());
        let frame = read_frame(&mut Cursor::new(buf)).unwrap();
        match hello_rank(&frame) {
            Err(WireError::Fatal(e)) => {
                assert!(e.to_string().contains("protocol version"), "{e}");
            }
            Err(e) => panic!("want Fatal, got {e}"),
            Ok(r) => panic!("old-version Hello accepted as rank {r}"),
        }
        // a non-Hello frame is rejected the same way
        let mut ping = Vec::new();
        write_ping(&mut ping).unwrap();
        let frame = read_frame(&mut Cursor::new(ping)).unwrap();
        assert!(matches!(hello_rank(&frame), Err(WireError::Fatal(_))));
    }

    #[test]
    fn nan_and_inf_round_trip_bitwise() {
        let t = vec![Tensor::from_f32(&[3], vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY])];
        let mut buf: Vec<u8> = Vec::new();
        write_grads(&mut buf, 1, &t).unwrap();
        match read_frame(&mut Cursor::new(&buf)).unwrap() {
            Frame::Grads { tensors: ts, .. } => {
                let bits: Vec<u32> = ts[0].f32s().iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = t[0].f32s().iter().map(|x| x.to_bits()).collect();
                assert_eq!(bits, want);
            }
            f => panic!("expected Grads, got {}", f.name()),
        }
    }

    #[test]
    fn flipped_payload_byte_is_a_crc_error_and_stream_stays_framed() {
        let mut buf: Vec<u8> = Vec::new();
        write_step(&mut buf, 7, &tensors()).unwrap();
        let first_len = buf.len();
        write_ping(&mut buf).unwrap();
        // flip one byte inside the first frame's payload
        buf[4 + first_len / 2] ^= 0x01;
        let mut cur = Cursor::new(&buf[..]);
        match read_frame(&mut cur) {
            Err(WireError::Crc { expect, got }) => assert_ne!(expect, got),
            Err(WireError::Fatal(e)) => panic!("want Crc, got Fatal: {e}"),
            Ok(f) => panic!("corrupt frame decoded as {}", f.name()),
        }
        // the length prefix was honest, so the next frame still parses
        assert!(matches!(read_frame(&mut cur).unwrap(), Frame::Ping));
    }

    #[test]
    fn truncation_and_garbage_are_fatal_not_panics() {
        let mut good: Vec<u8> = Vec::new();
        write_step(&mut good, 7, &tensors()).unwrap();
        // every strict prefix either times out (io::Cursor: UnexpectedEof)
        // or fails validation — never panics, never allocates wildly
        for cut in [0, 1, 3, 4, 5, 12, good.len() - 1] {
            let mut cur = Cursor::new(&good[..cut]);
            match read_frame(&mut cur) {
                Err(WireError::Fatal(_)) => {}
                Err(WireError::Crc { .. }) => panic!("prefix {cut}: want Fatal, got Crc"),
                Ok(f) => panic!("prefix {cut} decoded as {}", f.name()),
            }
        }
        // a zero/oversized declared length is rejected before allocating
        for bad_len in [0u32, (MAX_FRAME as u32) + 1] {
            let mut cur = Cursor::new(bad_len.to_le_bytes().to_vec());
            assert!(matches!(read_frame(&mut cur), Err(WireError::Fatal(_))));
        }
        // unknown tag, valid CRC
        let payload = [99u8];
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&payload);
        buf.extend_from_slice(&crc32(&payload).to_le_bytes());
        assert!(matches!(read_frame(&mut Cursor::new(buf)), Err(WireError::Fatal(_))));
    }

    #[test]
    fn hostile_tensor_counts_rejected_before_allocation() {
        // Grads frame claiming u32::MAX tensors in a tiny payload
        let mut payload = vec![TAG_GRADS];
        payload.extend_from_slice(&7u64.to_le_bytes());
        payload.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&payload);
        buf.extend_from_slice(&crc32(&payload).to_le_bytes());
        match read_frame(&mut Cursor::new(buf)) {
            Err(WireError::Fatal(e)) => {
                assert!(e.to_string().contains("tensor count"), "{e}");
            }
            _ => panic!("hostile count must be fatal"),
        }
    }

    #[test]
    fn i32_tensors_refuse_to_travel() {
        let t = vec![Tensor::from_i32(&[2], vec![1, 2])];
        let mut buf: Vec<u8> = Vec::new();
        assert!(write_step(&mut buf, 1, &t).is_err());
    }
}
