//! Native Rust optimizer substrate: mirrors of the L1/L2 update math
//! (parity oracles for the AOT artifacts) and the noisy-quadratic
//! simulator that validates the Theorem 2.1 momentum-placement story.
//!
//! # Zero-copy hot path: buffer ownership
//!
//! The optimizer inner loop is allocation-free by construction. Ownership
//! is layered so no buffer is ever created inside a per-step kernel:
//!
//! * **[`colnorm::NormWorkspace`]** owns the per-column norm scratch
//!   (`d_out` floats). It lives with the *call site* — one per thread per
//!   kernel user — and is resized, never reallocated, as shapes vary.
//!   `colnorm::col_norms_into` / `colnorm_into` / `colnorm_in_place`
//!   write through it; `rownorm_into` / `sign_into` are single-pass and
//!   need no scratch at all.
//! * **[`rules`]** fuses the normalization denominator into the parameter
//!   update (`scale_plain_ws` / `scale_momentum_ws`): parameters and
//!   momentum are mutated in place and *no direction buffer exists* —
//!   the division happens inside the subtract. The slice primitives
//!   `ema_` / `axpy_` are the shared in-place building blocks.
//! * **[`sim`]** allocates its gradient scratch once per run (outside the
//!   step loop) and drives the same `ema_`/`axpy_` kernels.
//! * One level up, `coordinator::ddp::tree_all_reduce` reduces shard
//!   gradients by mutating shard 0's buffers in place (parallel across
//!   parameters), and `coordinator::Trainer` feeds executables by
//!   reference (`Engine::run_exe_refs`) — the old per-step
//!   params/state clones are gone.
//!
//! Every `_into`/`_ws` kernel sequences its float operations identically
//! to the allocating wrapper it replaced, so results are bit-identical
//! (property-tested in `colnorm::tests` and `rules::tests`), and
//! `benches/bench_hot_path.rs` asserts the inner loop performs zero heap
//! allocations per iteration.
//!
//! # Tiling and the threshold contract
//!
//! The `_par` kernels (`colnorm::colnorm_into_par`,
//! `rules::scale_plain_ws_par`, `rules::scale_momentum_ws_par`) layer
//! pool parallelism on top of the same buffers without changing any of
//! the guarantees above:
//!
//! * **Partitioning, never reassociation.** Work is tiled along axes
//!   whose units are independent: the norm pass splits the `d_out`
//!   column axis (each column's row-accumulation order is exactly the
//!   sequential order), elementwise passes (EMA, the fused apply) split
//!   the row axis. No float reduction ever crosses a tile, so results
//!   are bit-identical to the sequential kernels for *every* pool size —
//!   property-tested across pools and shapes in both test modules.
//! * **Disjoint output slices.** Each pool task owns a contiguous
//!   `&mut` slice of the output (workspace norms in the column pass,
//!   params/momentum rows in the apply passes) obtained via
//!   `chunks_mut` — safe Rust, no aliasing, no locks on the data path.
//! * **Size threshold.** Below a work-size threshold the `_par` entry
//!   points call the sequential kernels inline: pool dispatch costs
//!   ~µs, which dominates small tensors. There is no hard-coded default
//!   anymore: every default entry point reads the *calibrated*
//!   threshold ([`crate::parallel::tuned_min_ops`], measured once per
//!   process from real dispatch latency by
//!   [`crate::parallel::calibrate`], pinnable through
//!   [`crate::parallel::set_min_ops_override`] for the bench gates).
//!   The PR 2 constant [`colnorm::PAR_MIN_ELEMS`] survives only as a
//!   fixed reference point for tests and docs — no kernel consults it.
//!   The threshold (and the `_with` variants that take it explicitly)
//!   selects a code path only — the property tests sweep it across the
//!   boundary to pin down that it can never select a different
//!   *result*.
//! * **Allocation contract.** The sequential `_into`/`_ws` kernels stay
//!   allocation-free (the bench gate is unchanged). The `_par` forms
//!   allocate O(pool workers) task boxes per call — amortized to noise
//!   for the large tensors they gate on, and zero inside the per-element
//!   loops.

pub mod colnorm;
pub mod rules;
pub mod sim;
