//! Native Rust optimizer substrate: mirrors of the L1/L2 update math
//! (parity oracles for the AOT artifacts) and the noisy-quadratic
//! simulator that validates the Theorem 2.1 momentum-placement story.

pub mod colnorm;
pub mod rules;
pub mod sim;
