//! Noisy-quadratic multi-layer simulator: a fast, pure-Rust testbed for
//! the paper's Theorem 2.1 story — *momentum helps most on the layers
//! with the largest gradient variance*.
//!
//! Problem: L independent quadratic "layers" f_l(x) = 0.5 * h_l ||x_l||^2
//! with stochastic gradients g_l = h_l x_l + sigma_l * noise. The statistic
//! is the *update-direction tracking error* E||dir_l - grad f_l||^2 — the
//! quantity Lemma N.1 bounds by ((1-beta)/(1+beta)) sigma_l^2 and the one
//! Fig. 4(b) plots ("lm_head momentum" variance dropping to a low level).
//! Theorem 2.1 aggregates exactly these per-layer error terms, so:
//!   * adding momentum to the high-sigma layer should cut the total error
//!     the most per byte of state,
//!   * momentum on a near-zero-sigma layer should buy almost nothing.
//! The `scale ablate-momentum` bench and the property tests below check
//! exactly that shape.

use crate::optim::rules::{axpy_, ema_};
use crate::util::rng::Pcg;

#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub dim: usize,
    /// curvature h_l
    pub curvature: f32,
    /// gradient noise std sigma_l
    pub sigma: f32,
    /// momentum coefficient beta_l (0 disables momentum & its state)
    pub beta: f32,
}

#[derive(Debug, Clone)]
pub struct SimResult {
    /// mean ||dir_l - grad f_l||^2 per layer over the averaging window —
    /// the per-layer tracking error of Lemma N.1 / Fig. 4(b).
    pub dir_err: Vec<f64>,
    /// final loss value
    pub loss: f64,
    /// bytes of optimizer state used (4 bytes/f32)
    pub state_bytes: usize,
}

pub struct QuadraticSim {
    pub layers: Vec<LayerSpec>,
    pub lr: f32,
    pub steps: usize,
    /// fraction of trailing steps to average stationarity over
    pub tail: f64,
}

impl QuadraticSim {
    pub fn run(&self, seed: u64) -> SimResult {
        let mut rng = Pcg::new(seed);
        let mut xs: Vec<Vec<f32>> = self
            .layers
            .iter()
            .map(|l| (0..l.dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut ms: Vec<Vec<f32>> = self.layers.iter().map(|l| vec![0.0; l.dim]).collect();
        let state_bytes: usize = self
            .layers
            .iter()
            .map(|l| if l.beta > 0.0 { 4 * l.dim } else { 0 })
            .sum();

        let tail_start = ((1.0 - self.tail) * self.steps as f64) as usize;
        let mut acc = vec![0.0f64; self.layers.len()];
        let mut count = 0usize;
        // per-layer gradient scratch, allocated once and reused every step
        // (the step loop below is allocation-free — same discipline as the
        // optim::rules workspace kernels it shares ema_/axpy_ with)
        let mut gbufs: Vec<Vec<f32>> = self.layers.iter().map(|l| vec![0.0; l.dim]).collect();

        for t in 0..self.steps {
            for (li, layer) in self.layers.iter().enumerate() {
                let x = &mut xs[li];
                let m = &mut ms[li];
                let gb = &mut gbufs[li];
                for i in 0..layer.dim {
                    gb[i] = layer.curvature * x[i] + layer.sigma * rng.normal() as f32;
                }
                let dir: &[f32] = if layer.beta > 0.0 {
                    ema_(m, gb, layer.beta);
                    m
                } else {
                    gb
                };
                let mut err = 0.0f64;
                for i in 0..layer.dim {
                    let d = (dir[i] - layer.curvature * x[i]) as f64;
                    err += d * d;
                }
                axpy_(x, -self.lr, dir);
                if t >= tail_start {
                    acc[li] += err;
                }
            }
            if t >= tail_start {
                count += 1;
            }
        }

        let loss: f64 = self
            .layers
            .iter()
            .zip(&xs)
            .map(|(l, x)| {
                0.5 * l.curvature as f64 * x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
            })
            .sum();
        SimResult {
            dir_err: acc.iter().map(|a| a / count.max(1) as f64).collect(),
            loss,
            state_bytes,
        }
    }
}

/// The Theorem 2.1 scenario: one high-noise "last layer" among quiet
/// layers. Returns (no_momentum, momentum_on_noisy, momentum_on_quiet)
/// tail stationarity, averaged over `seeds` runs.
pub fn momentum_placement_study(seeds: u64) -> (f64, f64, f64) {
    let base = |beta_noisy: f32, beta_quiet: f32| {
        let mut layers = vec![
            LayerSpec { dim: 64, curvature: 1.0, sigma: 0.05, beta: beta_quiet };
            3
        ];
        layers.push(LayerSpec {
            dim: 64,
            curvature: 1.0,
            sigma: 1.0, // the "lm_head": 20x the noise
            beta: beta_noisy,
        });
        QuadraticSim {
            layers,
            lr: 0.05,
            steps: 2000,
            tail: 0.25,
        }
    };
    let avg = |sim: QuadraticSim| -> f64 {
        (0..seeds)
            .map(|s| sim.run(1000 + s).dir_err.iter().sum::<f64>())
            .sum::<f64>()
            / seeds as f64
    };
    let none = avg(base(0.0, 0.0));
    let on_noisy = avg(base(0.9, 0.0));
    let on_quiet = avg(base(0.0, 0.9));
    (none, on_noisy, on_quiet)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_without_noise() {
        let sim = QuadraticSim {
            layers: vec![LayerSpec { dim: 16, curvature: 1.0, sigma: 0.0, beta: 0.0 }],
            lr: 0.1,
            steps: 500,
            tail: 0.1,
        };
        let r = sim.run(1);
        assert!(r.loss < 1e-6, "loss {}", r.loss);
    }

    #[test]
    fn momentum_on_noisy_layer_beats_none_and_quiet_placement() {
        // The Theorem 2.1 shape: placing the single momentum buffer on the
        // high-variance layer gives the best stationarity per state byte.
        let (none, on_noisy, on_quiet) = momentum_placement_study(3);
        assert!(
            on_noisy < 0.5 * none,
            "momentum on noisy layer should cut error: {on_noisy} vs {none}"
        );
        assert!(
            on_noisy < on_quiet,
            "noisy placement {on_noisy} should beat quiet placement {on_quiet}"
        );
    }

    #[test]
    fn state_bytes_accounting() {
        let sim = QuadraticSim {
            layers: vec![
                LayerSpec { dim: 10, curvature: 1.0, sigma: 0.1, beta: 0.9 },
                LayerSpec { dim: 20, curvature: 1.0, sigma: 0.1, beta: 0.0 },
            ],
            lr: 0.01,
            steps: 10,
            tail: 0.5,
        };
        assert_eq!(sim.run(0).state_bytes, 40);
    }

    #[test]
    fn higher_noise_raises_stationarity_error() {
        let mk = |sigma: f32| QuadraticSim {
            layers: vec![LayerSpec { dim: 32, curvature: 1.0, sigma, beta: 0.0 }],
            lr: 0.05,
            steps: 1500,
            tail: 0.25,
        };
        let low = mk(0.1).run(7).dir_err[0];
        let high = mk(1.0).run(7).dir_err[0];
        assert!(high > 5.0 * low, "high {high} vs low {low}");
    }
}
