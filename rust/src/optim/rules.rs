//! Native Rust optimizer rules mirroring the L1/L2 update math.
//!
//! These power the noisy-quadratic theory simulator ([`super::sim`]) and
//! serve as an independent second implementation for parity tests against
//! the AOT artifacts — the same role ref.py plays for the Pallas kernels,
//! one layer down.

use super::colnorm::colnorm;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamHp {
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        AdamHp {
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
        }
    }
}

/// SGD: `p -= lr * g`.
pub fn sgd(p: &mut [f32], g: &[f32], lr: f32) {
    for (pi, gi) in p.iter_mut().zip(g) {
        *pi -= lr * gi;
    }
}

/// SGD with EMA momentum (eq. 7): `m = beta*m + (1-beta)*g; p -= lr*m`.
pub fn sgd_momentum(p: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, beta: f32) {
    for ((pi, mi), gi) in p.iter_mut().zip(m.iter_mut()).zip(g) {
        *mi = beta * *mi + (1.0 - beta) * gi;
        *pi -= lr * *mi;
    }
}

/// Bias-corrected Adam (eq. 3). `step` is 1-based.
pub fn adam(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    hp: AdamHp,
    step: u32,
) {
    let bc1 = 1.0 - hp.b1.powi(step as i32);
    let bc2 = 1.0 - hp.b2.powi(step as i32);
    for (((pi, mi), vi), gi) in p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g) {
        *mi = hp.b1 * *mi + (1.0 - hp.b1) * gi;
        *vi = hp.b2 * *vi + (1.0 - hp.b2) * gi * gi;
        let mh = *mi / bc1;
        let vh = *vi / bc2;
        *pi -= lr * mh / (vh.sqrt() + hp.eps);
    }
}

/// SCALE stateless rule: `p -= lr * C(g)` over a (d_in, d_out) matrix.
pub fn scale_plain(p: &mut [f32], g: &[f32], d_in: usize, d_out: usize, lr: f32) {
    let dir = colnorm(g, d_in, d_out);
    for (pi, di) in p.iter_mut().zip(dir) {
        *pi -= lr * di;
    }
}

/// SCALE momentum rule (last layer): EMA then column-normalized apply.
pub fn scale_momentum(
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    beta: f32,
) {
    for (mi, gi) in m.iter_mut().zip(g) {
        *mi = beta * *mi + (1.0 - beta) * gi;
    }
    let dir = colnorm(m, d_in, d_out);
    for (pi, di) in p.iter_mut().zip(dir) {
        *pi -= lr * di;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure};

    #[test]
    fn sgd_descends_quadratic() {
        // f(p) = 0.5 * ||p||^2, g = p -> iterates contract geometrically
        let mut p = vec![1.0f32, -2.0, 3.0];
        for _ in 0..100 {
            let g = p.clone();
            sgd(&mut p, &g, 0.1);
        }
        assert!(p.iter().all(|x| x.abs() < 1e-3), "{p:?}");
    }

    #[test]
    fn momentum_matches_unrolled_ema() {
        prop::quick("sgdm-ema", |rng| {
            let n = prop::usize_in(rng, 1, 8);
            let beta = prop::f32_in(rng, 0.0, 0.95);
            let mut p = prop::matrix(rng, 1, n, 1.0);
            let mut m = vec![0.0; n];
            let g1 = prop::matrix(rng, 1, n, 1.0);
            let g2 = prop::matrix(rng, 1, n, 1.0);
            sgd_momentum(&mut p, &mut m, &g1, 0.0, beta);
            sgd_momentum(&mut p, &mut m, &g2, 0.0, beta);
            for i in 0..n {
                let want = beta * (1.0 - beta) * g1[i] + (1.0 - beta) * g2[i];
                ensure(
                    prop::approx_eq(m[i], want, 1e-5),
                    format!("m[{i}]={} want {want}", m[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn adam_first_step_is_signlike() {
        // step 1 with zero state: update = lr * g/(|g| + eps') ~ lr*sign(g)
        let mut p = vec![0.0f32; 4];
        let mut m = vec![0.0; 4];
        let mut v = vec![0.0; 4];
        let g = vec![0.5, -2.0, 10.0, -0.01];
        adam(&mut p, &mut m, &mut v, &g, 0.1, AdamHp::default(), 1);
        for (pi, gi) in p.iter().zip(&g) {
            assert!((pi.abs() - 0.1).abs() < 1e-3, "{pi} for g={gi}");
            assert_eq!(pi.signum(), -gi.signum());
        }
    }

    #[test]
    fn scale_update_norm_is_sqrt_cols() {
        // ||C(g)||_F = sqrt(d_out) for generic g -> step size is fixed
        prop::quick("scale-step-norm", |rng| {
            let (m_, n) = (prop::usize_in(rng, 2, 12), prop::usize_in(rng, 2, 12));
            let g = prop::matrix(rng, m_, n, 1.0);
            let mut p = vec![0.0f32; m_ * n];
            scale_plain(&mut p, &g, m_, n, 1.0);
            let norm: f32 = p.iter().map(|x| x * x).sum::<f32>().sqrt();
            ensure(
                (norm - (n as f32).sqrt()).abs() < 1e-2,
                format!("norm {norm} vs sqrt({n})"),
            )
        });
    }

    #[test]
    fn scale_momentum_state_carries() {
        let mut p = vec![0.0f32; 4];
        let mut m = vec![0.0f32; 4];
        let g = vec![1.0f32, 1.0, 1.0, 1.0];
        scale_momentum(&mut p, &mut m, &g, 2, 2, 0.1, 0.9);
        for mi in &m {
            assert!((mi - 0.1).abs() < 1e-6);
        }
    }
}
