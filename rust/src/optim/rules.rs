//! Native Rust optimizer rules mirroring the L1/L2 update math.
//!
//! These power the noisy-quadratic theory simulator ([`super::sim`]) and
//! serve as an independent second implementation for parity tests against
//! the AOT artifacts — the same role ref.py plays for the Pallas kernels,
//! one layer down.
//!
//! The SCALE rules come in three forms: `_ws` variants that fuse the
//! column-norm denominator into the parameter update through a
//! caller-owned [`NormWorkspace`] (zero heap allocations, no direction
//! buffer at all — the division happens inside the subtract), `_par`
//! variants ([`scale_plain_ws_par`], [`scale_momentum_ws_par`]) that
//! tile the same passes across a persistent [`WorkerPool`] for large
//! matrices, and the original allocating signatures as thin wrappers.
//! All produce bit-identical results: the float operations are
//! sequenced the same (tiling only partitions independent columns/rows,
//! it never reassociates a reduction).

use super::colnorm::{col_norms_into, col_norms_tiled, tile_width, NormWorkspace};
use crate::parallel::WorkerPool;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamHp {
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
}

impl Default for AdamHp {
    fn default() -> Self {
        AdamHp {
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
        }
    }
}

/// In-place EMA over slices: `m = beta*m + (1-beta)*g`. Shared by the
/// momentum rules and the noisy-quadratic simulator.
#[inline]
pub fn ema_(m: &mut [f32], g: &[f32], beta: f32) {
    for (mi, gi) in m.iter_mut().zip(g) {
        *mi = beta * *mi + (1.0 - beta) * gi;
    }
}

/// In-place axpy over slices: `y += alpha * x`. Also the scalar body of
/// the native executor's [`crate::exec::kernels::axpy8`] microkernel
/// (rank-1 GEMM, attention context rows), hence `#[inline]`.
#[inline]
pub fn axpy_(y: &mut [f32], alpha: f32, x: &[f32]) {
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// SGD: `p -= lr * g`.
pub fn sgd(p: &mut [f32], g: &[f32], lr: f32) {
    for (pi, gi) in p.iter_mut().zip(g) {
        *pi -= lr * gi;
    }
}

/// SGD with EMA momentum (eq. 7): `m = beta*m + (1-beta)*g; p -= lr*m`.
pub fn sgd_momentum(p: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, beta: f32) {
    for ((pi, mi), gi) in p.iter_mut().zip(m.iter_mut()).zip(g) {
        *mi = beta * *mi + (1.0 - beta) * gi;
        *pi -= lr * *mi;
    }
}

/// Bias-corrected Adam (eq. 3). `step` is 1-based.
pub fn adam(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    g: &[f32],
    lr: f32,
    hp: AdamHp,
    step: u32,
) {
    let bc1 = 1.0 - hp.b1.powi(step as i32);
    let bc2 = 1.0 - hp.b2.powi(step as i32);
    for (((pi, mi), vi), gi) in p.iter_mut().zip(m.iter_mut()).zip(v.iter_mut()).zip(g) {
        *mi = hp.b1 * *mi + (1.0 - hp.b1) * gi;
        *vi = hp.b2 * *vi + (1.0 - hp.b2) * gi * gi;
        let mh = *mi / bc1;
        let vh = *vi / bc2;
        *pi -= lr * mh / (vh.sqrt() + hp.eps);
    }
}

/// SCALE stateless rule, allocation-free: `p -= lr * C(g)` with the
/// column norms held in `ws` and the normalize fused into the subtract —
/// no direction buffer is ever materialized.
pub fn scale_plain_ws(
    p: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    ws: &mut NormWorkspace,
) {
    assert_eq!(p.len(), d_in * d_out);
    col_norms_into(g, d_in, d_out, ws);
    let norms = ws.norms();
    for r in 0..d_in {
        for c in 0..d_out {
            let i = r * d_out + c;
            p[i] -= lr * (g[i] / norms[c]);
        }
    }
}

/// SCALE momentum rule, allocation-free: EMA into `m` in place, then the
/// column-normalized apply fused against `m` through the workspace.
pub fn scale_momentum_ws(
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    beta: f32,
    ws: &mut NormWorkspace,
) {
    assert_eq!(p.len(), d_in * d_out);
    assert_eq!(m.len(), d_in * d_out);
    ema_(m, g, beta);
    col_norms_into(m, d_in, d_out, ws);
    let norms = ws.norms();
    for r in 0..d_in {
        for c in 0..d_out {
            let i = r * d_out + c;
            p[i] -= lr * (m[i] / norms[c]);
        }
    }
}

/// Parallel form of [`scale_plain_ws`]: column-tiled norm pass, then a
/// row-tiled fused apply with disjoint parameter slices — bit-identical
/// to the sequential rule for every pool size. Matrices below the
/// calibrated [`crate::parallel::tuned_min_ops`] threshold run the
/// sequential rule inline.
pub fn scale_plain_ws_par(
    pool: &WorkerPool,
    p: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    ws: &mut NormWorkspace,
) {
    let min_elems = crate::parallel::tuned_min_ops();
    scale_plain_ws_par_with(pool, p, g, d_in, d_out, lr, ws, min_elems)
}

/// [`scale_plain_ws_par`] with an explicit threshold (see
/// `colnorm::colnorm_into_par_with`); the threshold selects a path,
/// never a result.
pub fn scale_plain_ws_par_with(
    pool: &WorkerPool,
    p: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    ws: &mut NormWorkspace,
    min_elems: usize,
) {
    assert_eq!(p.len(), d_in * d_out);
    assert_eq!(g.len(), d_in * d_out);
    if d_in * d_out < min_elems.max(1) || pool.parallelism() == 1 {
        return scale_plain_ws(p, g, d_in, d_out, lr, ws);
    }
    col_norms_tiled(pool, g, d_in, d_out, ws);
    let norms: &[f32] = ws.norms();
    let rows = tile_width(d_in, pool.parallelism());
    let mut tasks = Vec::new();
    for (ti, p_chunk) in p.chunks_mut(rows * d_out).enumerate() {
        let start = ti * rows * d_out;
        let g_chunk = &g[start..start + p_chunk.len()];
        tasks.push(move || {
            for (p_row, g_row) in p_chunk.chunks_mut(d_out).zip(g_chunk.chunks(d_out)) {
                for ((pi, &gi), &nm) in p_row.iter_mut().zip(g_row).zip(norms) {
                    *pi -= lr * (gi / nm);
                }
            }
        });
    }
    pool.run(tasks);
}

/// Parallel form of [`scale_momentum_ws`]: row-tiled in-place EMA,
/// column-tiled norms of the updated momentum, row-tiled fused apply —
/// three pool barriers, each partitioning independent work, so the
/// result is bit-identical to the sequential rule for every pool size.
pub fn scale_momentum_ws_par(
    pool: &WorkerPool,
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    beta: f32,
    ws: &mut NormWorkspace,
) {
    let min_elems = crate::parallel::tuned_min_ops();
    scale_momentum_ws_par_with(pool, p, m, g, d_in, d_out, lr, beta, ws, min_elems)
}

/// [`scale_momentum_ws_par`] with an explicit threshold.
#[allow(clippy::too_many_arguments)]
pub fn scale_momentum_ws_par_with(
    pool: &WorkerPool,
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    beta: f32,
    ws: &mut NormWorkspace,
    min_elems: usize,
) {
    assert_eq!(p.len(), d_in * d_out);
    assert_eq!(m.len(), d_in * d_out);
    assert_eq!(g.len(), d_in * d_out);
    if d_in * d_out < min_elems.max(1) || pool.parallelism() == 1 {
        return scale_momentum_ws(p, m, g, d_in, d_out, lr, beta, ws);
    }
    let rows = tile_width(d_in, pool.parallelism());
    // phase A: EMA into the momentum, row-tiled (elementwise, disjoint)
    let mut tasks = Vec::new();
    for (ti, m_chunk) in m.chunks_mut(rows * d_out).enumerate() {
        let start = ti * rows * d_out;
        let g_chunk = &g[start..start + m_chunk.len()];
        tasks.push(move || ema_(m_chunk, g_chunk, beta));
    }
    pool.run(tasks);
    // phase B: column norms of the updated momentum (column-tiled)
    col_norms_tiled(pool, m, d_in, d_out, ws);
    // phase C: fused normalized apply, row-tiled over the parameters
    let norms: &[f32] = ws.norms();
    let mut tasks = Vec::new();
    for (ti, p_chunk) in p.chunks_mut(rows * d_out).enumerate() {
        let start = ti * rows * d_out;
        let m_chunk = &m[start..start + p_chunk.len()];
        tasks.push(move || {
            for (p_row, m_row) in p_chunk.chunks_mut(d_out).zip(m_chunk.chunks(d_out)) {
                for ((pi, &mi), &nm) in p_row.iter_mut().zip(m_row).zip(norms) {
                    *pi -= lr * (mi / nm);
                }
            }
        });
    }
    pool.run(tasks);
}

/// AdamS rule (arXiv:2505.16363): momentum itself is the normalizer —
/// `m = b1*m + (1-b1)*g; p -= lr * m / sqrt(b2*m² + eps)`. Sign-free,
/// elementwise, and crucially *stateless beyond `m`*: there is no
/// second-moment buffer, so the memory footprint matches SGD-momentum
/// while the per-coordinate step size stays Adam-bounded (|update| ≤
/// lr/√b2). No bias correction — the b2·m² denominator self-scales.
pub fn momentum_norm(p: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, hp: AdamHp) {
    for ((pi, mi), gi) in p.iter_mut().zip(m.iter_mut()).zip(g) {
        *mi = hp.b1 * *mi + (1.0 - hp.b1) * gi;
        *pi -= lr * *mi / (hp.b2 * *mi * *mi + hp.eps).sqrt();
    }
}

/// Parallel form of [`momentum_norm`]: purely elementwise, so the tiling
/// partitions disjoint row blocks and never reassociates anything —
/// bit-identical to the sequential rule for every pool size. Matrices
/// below the calibrated [`crate::parallel::tuned_min_ops`] threshold run
/// the sequential rule inline.
pub fn momentum_norm_par(
    pool: &WorkerPool,
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    hp: AdamHp,
) {
    let min_elems = crate::parallel::tuned_min_ops();
    momentum_norm_par_with(pool, p, m, g, d_in, d_out, lr, hp, min_elems)
}

/// [`momentum_norm_par`] with an explicit threshold; the threshold
/// selects a path, never a result.
#[allow(clippy::too_many_arguments)]
pub fn momentum_norm_par_with(
    pool: &WorkerPool,
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    hp: AdamHp,
    min_elems: usize,
) {
    assert_eq!(p.len(), d_in * d_out);
    assert_eq!(m.len(), d_in * d_out);
    assert_eq!(g.len(), d_in * d_out);
    if d_in * d_out < min_elems.max(1) || pool.parallelism() == 1 {
        return momentum_norm(p, m, g, lr, hp);
    }
    let rows = tile_width(d_in, pool.parallelism());
    let mut tasks = Vec::new();
    for (ti, (p_chunk, m_chunk)) in
        p.chunks_mut(rows * d_out).zip(m.chunks_mut(rows * d_out)).enumerate()
    {
        let start = ti * rows * d_out;
        let g_chunk = &g[start..start + p_chunk.len()];
        tasks.push(move || momentum_norm(p_chunk, m_chunk, g_chunk, lr, hp));
    }
    pool.run(tasks);
}

/// SCALE stateless rule: `p -= lr * C(g)` over a (d_in, d_out) matrix.
/// Allocating wrapper over [`scale_plain_ws`].
pub fn scale_plain(p: &mut [f32], g: &[f32], d_in: usize, d_out: usize, lr: f32) {
    let mut ws = NormWorkspace::with_capacity(d_out);
    scale_plain_ws(p, g, d_in, d_out, lr, &mut ws);
}

/// SCALE momentum rule (last layer): EMA then column-normalized apply.
/// Allocating wrapper over [`scale_momentum_ws`].
pub fn scale_momentum(
    p: &mut [f32],
    m: &mut [f32],
    g: &[f32],
    d_in: usize,
    d_out: usize,
    lr: f32,
    beta: f32,
) {
    let mut ws = NormWorkspace::with_capacity(d_out);
    scale_momentum_ws(p, m, g, d_in, d_out, lr, beta, &mut ws);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::colnorm::{colnorm, PAR_MIN_ELEMS};
    use crate::util::prop::{self, ensure};

    #[test]
    fn sgd_descends_quadratic() {
        // f(p) = 0.5 * ||p||^2, g = p -> iterates contract geometrically
        let mut p = vec![1.0f32, -2.0, 3.0];
        for _ in 0..100 {
            let g = p.clone();
            sgd(&mut p, &g, 0.1);
        }
        assert!(p.iter().all(|x| x.abs() < 1e-3), "{p:?}");
    }

    #[test]
    fn momentum_matches_unrolled_ema() {
        prop::quick("sgdm-ema", |rng| {
            let n = prop::usize_in(rng, 1, 8);
            let beta = prop::f32_in(rng, 0.0, 0.95);
            let mut p = prop::matrix(rng, 1, n, 1.0);
            let mut m = vec![0.0; n];
            let g1 = prop::matrix(rng, 1, n, 1.0);
            let g2 = prop::matrix(rng, 1, n, 1.0);
            sgd_momentum(&mut p, &mut m, &g1, 0.0, beta);
            sgd_momentum(&mut p, &mut m, &g2, 0.0, beta);
            for i in 0..n {
                let want = beta * (1.0 - beta) * g1[i] + (1.0 - beta) * g2[i];
                ensure(
                    prop::approx_eq(m[i], want, 1e-5),
                    format!("m[{i}]={} want {want}", m[i]),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn adam_first_step_is_signlike() {
        // step 1 with zero state: update = lr * g/(|g| + eps') ~ lr*sign(g)
        let mut p = vec![0.0f32; 4];
        let mut m = vec![0.0; 4];
        let mut v = vec![0.0; 4];
        let g = vec![0.5, -2.0, 10.0, -0.01];
        adam(&mut p, &mut m, &mut v, &g, 0.1, AdamHp::default(), 1);
        for (pi, gi) in p.iter().zip(&g) {
            assert!((pi.abs() - 0.1).abs() < 1e-3, "{pi} for g={gi}");
            assert_eq!(pi.signum(), -gi.signum());
        }
    }

    #[test]
    fn scale_update_norm_is_sqrt_cols() {
        // ||C(g)||_F = sqrt(d_out) for generic g -> step size is fixed
        prop::quick("scale-step-norm", |rng| {
            let (m_, n) = (prop::usize_in(rng, 2, 12), prop::usize_in(rng, 2, 12));
            let g = prop::matrix(rng, m_, n, 1.0);
            let mut p = vec![0.0f32; m_ * n];
            scale_plain(&mut p, &g, m_, n, 1.0);
            let norm: f32 = p.iter().map(|x| x * x).sum::<f32>().sqrt();
            ensure(
                (norm - (n as f32).sqrt()).abs() < 1e-2,
                format!("norm {norm} vs sqrt({n})"),
            )
        });
    }

    #[test]
    fn scale_momentum_state_carries() {
        let mut p = vec![0.0f32; 4];
        let mut m = vec![0.0f32; 4];
        let g = vec![1.0f32, 1.0, 1.0, 1.0];
        scale_momentum(&mut p, &mut m, &g, 2, 2, 0.1, 0.9);
        for mi in &m {
            assert!((mi - 0.1).abs() < 1e-6);
        }
    }

    // ---- workspace-rule parity -------------------------------------------

    /// Reference forms written against the allocating colnorm directly,
    /// exactly as the pre-workspace implementation computed them.
    fn scale_plain_reference(p: &mut [f32], g: &[f32], d_in: usize, d_out: usize, lr: f32) {
        let dir = colnorm(g, d_in, d_out);
        for (pi, di) in p.iter_mut().zip(dir) {
            *pi -= lr * di;
        }
    }

    fn scale_momentum_reference(
        p: &mut [f32],
        m: &mut [f32],
        g: &[f32],
        d_in: usize,
        d_out: usize,
        lr: f32,
        beta: f32,
    ) {
        for (mi, gi) in m.iter_mut().zip(g) {
            *mi = beta * *mi + (1.0 - beta) * gi;
        }
        let dir = colnorm(m, d_in, d_out);
        for (pi, di) in p.iter_mut().zip(dir) {
            *pi -= lr * di;
        }
    }

    #[test]
    fn ws_rules_bit_identical_to_reference() {
        let mut ws = NormWorkspace::new();
        prop::quick("scale-ws-bit-identical", |rng| {
            let (di, dn) = (prop::usize_in(rng, 1, 16), prop::usize_in(rng, 1, 16));
            let g_scale = prop::f32_in(rng, 0.1, 5.0);
            let g = prop::matrix(rng, di, dn, g_scale);
            let p0 = prop::matrix(rng, di, dn, 1.0);
            let lr = prop::f32_in(rng, 1e-4, 0.5);
            let beta = prop::f32_in(rng, 0.0, 0.99);

            let mut p_ref = p0.clone();
            scale_plain_reference(&mut p_ref, &g, di, dn, lr);
            let mut p_ws = p0.clone();
            scale_plain_ws(&mut p_ws, &g, di, dn, lr, &mut ws);
            ensure(p_ws == p_ref, "scale_plain_ws differs from reference")?;

            let m0 = prop::matrix(rng, di, dn, 0.3);
            let (mut p_ref, mut m_ref) = (p0.clone(), m0.clone());
            scale_momentum_reference(&mut p_ref, &mut m_ref, &g, di, dn, lr, beta);
            let (mut p_ws, mut m_ws) = (p0.clone(), m0.clone());
            scale_momentum_ws(&mut p_ws, &mut m_ws, &g, di, dn, lr, beta, &mut ws);
            ensure(m_ws == m_ref, "momentum state differs")?;
            ensure(p_ws == p_ref, "scale_momentum_ws differs from reference")
        });
    }

    #[test]
    fn par_rules_bit_identical_over_pools_and_thresholds() {
        // the ISSUE acceptance property: `*_par` rules must reproduce the
        // sequential `_ws` rules bit for bit across pool sizes, random
        // shapes, and thresholds straddling the numel gate
        let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(5)];
        let mut ws = NormWorkspace::new();
        let mut ws_par = NormWorkspace::new();
        prop::check("scale-par-bit-identical", 32, |rng| {
            let (di, dn) = (prop::usize_in(rng, 1, 40), prop::usize_in(rng, 1, 40));
            let g_scale = prop::f32_in(rng, 0.1, 5.0);
            let g = prop::matrix(rng, di, dn, g_scale);
            let p0 = prop::matrix(rng, di, dn, 1.0);
            let m0 = prop::matrix(rng, di, dn, 0.3);
            let lr = prop::f32_in(rng, 1e-4, 0.5);
            let beta = prop::f32_in(rng, 0.0, 0.99);
            let numel = di * dn;

            let mut p_want = p0.clone();
            scale_plain_ws(&mut p_want, &g, di, dn, lr, &mut ws);
            let (mut pm_want, mut m_want) = (p0.clone(), m0.clone());
            scale_momentum_ws(&mut pm_want, &mut m_want, &g, di, dn, lr, beta, &mut ws);

            for pool in &pools {
                for min_elems in [0usize, numel, numel + 1] {
                    let mut p = p0.clone();
                    scale_plain_ws_par_with(pool, &mut p, &g, di, dn, lr, &mut ws_par, min_elems);
                    ensure(
                        p == p_want,
                        format!(
                            "scale_plain_ws_par differs: {di}x{dn}, {} workers, min {min_elems}",
                            pool.workers()
                        ),
                    )?;

                    let (mut pm, mut m) = (p0.clone(), m0.clone());
                    scale_momentum_ws_par_with(
                        pool, &mut pm, &mut m, &g, di, dn, lr, beta, &mut ws_par, min_elems,
                    );
                    ensure(
                        m == m_want,
                        format!("momentum state differs: {di}x{dn}, min {min_elems}"),
                    )?;
                    ensure(
                        pm == pm_want,
                        format!("scale_momentum_ws_par differs: {di}x{dn}, min {min_elems}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn momentum_norm_par_bit_identical_over_pools_and_thresholds() {
        // same acceptance property for the AdamS kernel: the tiled form
        // must reproduce the sequential rule bit for bit across pool
        // sizes, shapes, and thresholds straddling the numel gate
        let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(5)];
        prop::check("momentum-norm-par-bit-identical", 32, |rng| {
            let (di, dn) = (prop::usize_in(rng, 1, 40), prop::usize_in(rng, 1, 40));
            let g = prop::matrix(rng, di, dn, prop::f32_in(rng, 0.1, 5.0));
            let p0 = prop::matrix(rng, di, dn, 1.0);
            let m0 = prop::matrix(rng, di, dn, 0.3);
            let lr = prop::f32_in(rng, 1e-4, 0.5);
            let hp = AdamHp::default();
            let numel = di * dn;

            let (mut p_want, mut m_want) = (p0.clone(), m0.clone());
            momentum_norm(&mut p_want, &mut m_want, &g, lr, hp);
            ensure(p_want.iter().all(|x| x.is_finite()), "non-finite update")?;

            for pool in &pools {
                for min_elems in [0usize, numel, numel + 1] {
                    let (mut p, mut m) = (p0.clone(), m0.clone());
                    momentum_norm_par_with(pool, &mut p, &mut m, &g, di, dn, lr, hp, min_elems);
                    ensure(
                        m == m_want,
                        format!("momentum state differs: {di}x{dn}, min {min_elems}"),
                    )?;
                    ensure(
                        p == p_want,
                        format!(
                            "momentum_norm_par differs: {di}x{dn}, {} workers, min {min_elems}",
                            pool.workers()
                        ),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn momentum_norm_step_is_adam_bounded() {
        // the AdamS denominator caps every coordinate: |Δp| ≤ lr/√b2
        let hp = AdamHp::default();
        let mut p = vec![0.0f32; 4];
        let mut m = vec![0.0f32; 4];
        let g = vec![1e6f32, -1e6, 0.5, -1e-9];
        momentum_norm(&mut p, &mut m, &g, 0.1, hp);
        let bound = 0.1 / hp.b2.sqrt() + 1e-6;
        for (pi, gi) in p.iter().zip(&g) {
            assert!(pi.abs() <= bound, "{pi} for g={gi}");
            assert!(pi.signum() == -gi.signum() || *pi == 0.0);
        }
    }

    #[test]
    fn par_rules_large_matrix_default_threshold() {
        // above PAR_MIN_ELEMS the default entry points take the tiled
        // path; pin bit-identity at a realistic lm_head-ish shape
        let pool = WorkerPool::new(4);
        let (di, dn) = (128usize, 512usize);
        assert!(di * dn >= PAR_MIN_ELEMS);
        let mut rng = crate::util::rng::Pcg::new(21);
        let g: Vec<f32> = (0..di * dn).map(|_| 0.1 * rng.normal() as f32).collect();
        let p0: Vec<f32> = (0..di * dn).map(|_| rng.normal() as f32).collect();
        let m0 = vec![0.05f32; di * dn];
        let mut ws = NormWorkspace::new();

        let mut p_want = p0.clone();
        scale_plain_ws(&mut p_want, &g, di, dn, 0.01, &mut ws);
        let mut p = p0.clone();
        let mut ws_par = NormWorkspace::new();
        scale_plain_ws_par(&pool, &mut p, &g, di, dn, 0.01, &mut ws_par);
        assert_eq!(p, p_want);

        let (mut pm_want, mut m_want) = (p0.clone(), m0.clone());
        scale_momentum_ws(&mut pm_want, &mut m_want, &g, di, dn, 0.01, 0.9, &mut ws);
        let (mut pm, mut m) = (p0, m0);
        scale_momentum_ws_par(&pool, &mut pm, &mut m, &g, di, dn, 0.01, 0.9, &mut ws_par);
        assert_eq!(m, m_want);
        assert_eq!(pm, pm_want);
    }

    #[test]
    fn par_rules_reuse_pool_without_spawning() {
        let pool = WorkerPool::new(3);
        let spawned = crate::parallel::threads_spawned_by_current_thread();
        let (di, dn) = (64usize, 64usize);
        let mut rng = crate::util::rng::Pcg::new(5);
        let g: Vec<f32> = (0..di * dn).map(|_| rng.normal() as f32).collect();
        let mut p = vec![0.0f32; di * dn];
        let mut ws = NormWorkspace::new();
        for _ in 0..100 {
            scale_plain_ws_par_with(&pool, &mut p, &g, di, dn, 1e-3, &mut ws, 0);
        }
        assert_eq!(
            crate::parallel::threads_spawned_by_current_thread(),
            spawned,
            "tiled kernels must never spawn threads"
        );
        assert!(p.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn slice_primitives() {
        let mut m = vec![1.0f32, -2.0];
        ema_(&mut m, &[3.0, 4.0], 0.5);
        assert_eq!(m, vec![2.0, 1.0]);
        let mut y = vec![1.0f32, 1.0];
        axpy_(&mut y, 2.0, &[10.0, -10.0]);
        assert_eq!(y, vec![21.0, -19.0]);
    }
}
