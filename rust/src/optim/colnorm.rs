//! Native Rust mirror of the column-wise normalization kernel (eq. 6).
//!
//! Used three ways: (1) cross-layer parity tests against the L1 Pallas
//! kernel's HLO artifact, (2) the noisy-quadratic theory simulator
//! ([`super::sim`]), (3) property tests of the normalization invariants.
//! Matrices are row-major `(d_in, d_out)`, matching the JAX layout.

pub const EPS: f32 = 1e-30;

/// Column-wise normalization: each column (stride `d_out`) scaled to unit
/// L2 norm; zero columns stay zero.
pub fn colnorm(g: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
    assert_eq!(g.len(), d_in * d_out);
    let mut norms = vec![0.0f32; d_out];
    for r in 0..d_in {
        let row = &g[r * d_out..(r + 1) * d_out];
        for (n, &x) in norms.iter_mut().zip(row) {
            *n += x * x;
        }
    }
    for n in norms.iter_mut() {
        *n = n.sqrt().max(EPS);
    }
    let mut out = vec![0.0f32; g.len()];
    for r in 0..d_in {
        for c in 0..d_out {
            out[r * d_out + c] = g[r * d_out + c] / norms[c];
        }
    }
    out
}

/// Row-wise normalization (unit L2 rows).
pub fn rownorm(g: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
    assert_eq!(g.len(), d_in * d_out);
    let mut out = vec![0.0f32; g.len()];
    for r in 0..d_in {
        let row = &g[r * d_out..(r + 1) * d_out];
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(EPS);
        for (o, &x) in out[r * d_out..(r + 1) * d_out].iter_mut().zip(row) {
            *o = x / norm;
        }
    }
    out
}

/// Sign normalization (eq. 4).
pub fn sign(g: &[f32]) -> Vec<f32> {
    g.iter()
        .map(|&x| {
            if x > 0.0 {
                1.0
            } else if x < 0.0 {
                -1.0
            } else {
                0.0
            }
        })
        .collect()
}

/// Per-column L2 norms — the Fig. 10 statistic (LM-head column norms).
pub fn column_norms(g: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
    let mut norms = vec![0.0f32; d_out];
    for r in 0..d_in {
        for c in 0..d_out {
            let x = g[r * d_out + c];
            norms[c] += x * x;
        }
    }
    for n in norms.iter_mut() {
        *n = n.sqrt();
    }
    norms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure};

    #[test]
    fn unit_columns() {
        prop::quick("colnorm-unit-columns", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 30), prop::usize_in(rng, 1, 30));
            let g = prop::matrix(rng, m, n, 1.0);
            let out = colnorm(&g, m, n);
            for (c, norm) in column_norms(&out, m, n).iter().enumerate() {
                prop::ensure((norm - 1.0).abs() < 1e-3, format!("col {c}: {norm}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn scale_invariance() {
        prop::quick("colnorm-scale-invariant", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 20), prop::usize_in(rng, 1, 20));
            let g = prop::matrix(rng, m, n, 1.0);
            let alpha = prop::f32_in(rng, 0.01, 50.0);
            let scaled: Vec<f32> = g.iter().map(|x| x * alpha).collect();
            prop::slices_close(&colnorm(&scaled, m, n), &colnorm(&g, m, n), 1e-3)
        });
    }

    #[test]
    fn idempotent() {
        prop::quick("colnorm-idempotent", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 20), prop::usize_in(rng, 1, 20));
            let g = prop::matrix(rng, m, n, 1.0);
            let once = colnorm(&g, m, n);
            prop::slices_close(&colnorm(&once, m, n), &once, 1e-4)
        });
    }

    #[test]
    fn zero_column_stays_zero() {
        let g = vec![0.0, 1.0, 0.0, 2.0]; // 2x2, column 0 is zero
        let out = colnorm(&g, 2, 2);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[2], 0.0);
        let n = (out[1] * out[1] + out[3] * out[3]).sqrt();
        assert!((n - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rownorm_transposes_colnorm() {
        prop::quick("rownorm-is-transposed-colnorm", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 15), prop::usize_in(rng, 1, 15));
            let g = prop::matrix(rng, m, n, 1.0);
            // transpose, colnorm, transpose back == rownorm
            let mut gt = vec![0.0f32; g.len()];
            for r in 0..m {
                for c in 0..n {
                    gt[c * m + r] = g[r * n + c];
                }
            }
            let cn = colnorm(&gt, n, m);
            let mut back = vec![0.0f32; g.len()];
            for c in 0..n {
                for r in 0..m {
                    back[r * n + c] = cn[c * m + r];
                }
            }
            prop::slices_close(&back, &rownorm(&g, m, n), 1e-4)
        });
    }

    #[test]
    fn sign_values() {
        assert_eq!(sign(&[2.0, -3.0, 0.0]), vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn bounded_update_under_huge_gradients() {
        // the Fig. 3 stability property: colnorm bounds every entry by 1
        prop::quick("colnorm-bounded", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 10), prop::usize_in(rng, 1, 10));
            let g: Vec<f32> = prop::matrix(rng, m, n, 1e18);
            let out = colnorm(&g, m, n);
            ensure(
                out.iter().all(|x| x.is_finite() && x.abs() <= 1.0 + 1e-5),
                "entry out of bounds",
            )
        });
    }
}
