//! Native Rust mirror of the column-wise normalization kernel (eq. 6).
//!
//! Used three ways: (1) cross-layer parity tests against the L1 Pallas
//! kernel's HLO artifact, (2) the noisy-quadratic theory simulator
//! ([`super::sim`]), (3) property tests of the normalization invariants.
//! Matrices are row-major `(d_in, d_out)`, matching the JAX layout.
//!
//! Three API tiers:
//! * allocation-free `_into` / `_in_place` kernels over a caller-owned
//!   [`NormWorkspace`] — the training hot path (see `optim::rules` and
//!   `benches/bench_hot_path.rs`); every float operation is sequenced
//!   identically to the allocating forms, so results are bit-identical;
//! * `_par` variants ([`colnorm_into_par`]) that tile the work across a
//!   persistent [`WorkerPool`] for large matrices — bit-identical to the
//!   sequential forms by construction (see the tiling contract in
//!   [`super`]'s module docs), falling back inline below the calibrated
//!   [`crate::parallel::tuned_min_ops`] threshold (or the explicit one
//!   handed to a `_with` variant);
//! * the original allocating signatures (`colnorm`, `rownorm`, `sign`),
//!   kept as thin wrappers for tests, analysis, and one-shot callers.

use crate::parallel::WorkerPool;

pub const EPS: f32 = 1e-30;

/// Pre-calibration default for the sequential-fallback threshold:
/// matrices below this many elements run the sequential kernels even
/// through the `_par` entry points, because pool dispatch costs
/// ~microseconds, which dominates the arithmetic for small tensors. The
/// default `_par` entry points now use the *measured* threshold
/// ([`crate::parallel::tuned_min_ops`]); this constant remains as the
/// documented fallback and for tests that need a fixed reference point.
/// The exact value never affects results — both paths are bit-identical
/// — only latency.
pub const PAR_MIN_ELEMS: usize = 1 << 15;

/// Reusable per-column norm scratch. One workspace per (thread, kernel
/// call site); `d_out` may vary call to call — the buffer is resized
/// (never reallocated once it has seen the largest `d_out`).
#[derive(Debug, Clone, Default)]
pub struct NormWorkspace {
    norms: Vec<f32>,
}

impl NormWorkspace {
    pub fn new() -> NormWorkspace {
        NormWorkspace { norms: Vec::new() }
    }

    /// Pre-size for a known `d_out` so the first call is allocation-free.
    pub fn with_capacity(d_out: usize) -> NormWorkspace {
        NormWorkspace {
            norms: Vec::with_capacity(d_out),
        }
    }

    /// The norms computed by the last `col_norms_into` call.
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    fn reset(&mut self, d_out: usize) {
        self.norms.clear();
        self.norms.resize(d_out, 0.0);
    }
}

/// Per-column L2 norms with the `EPS` floor (the kernel denominator of
/// eq. 6), accumulated row-major into the workspace. Allocation-free
/// once the workspace has capacity `d_out`.
pub fn col_norms_into<'w>(
    g: &[f32],
    d_in: usize,
    d_out: usize,
    ws: &'w mut NormWorkspace,
) -> &'w [f32] {
    assert_eq!(g.len(), d_in * d_out);
    ws.reset(d_out);
    let norms = &mut ws.norms;
    for r in 0..d_in {
        let row = &g[r * d_out..(r + 1) * d_out];
        for (n, &x) in norms.iter_mut().zip(row) {
            *n += x * x;
        }
    }
    for n in norms.iter_mut() {
        *n = n.sqrt().max(EPS);
    }
    norms
}

/// Column-wise normalization into a caller-provided buffer. Two passes
/// (per-column norms need the full column before any entry can be
/// scaled), zero heap allocations.
pub fn colnorm_into(g: &[f32], d_in: usize, d_out: usize, ws: &mut NormWorkspace, out: &mut [f32]) {
    assert_eq!(out.len(), g.len());
    col_norms_into(g, d_in, d_out, ws);
    let norms = &ws.norms;
    for r in 0..d_in {
        for c in 0..d_out {
            out[r * d_out + c] = g[r * d_out + c] / norms[c];
        }
    }
}

/// Contiguous tile width covering `len` items with `parts` workers.
pub(crate) fn tile_width(len: usize, parts: usize) -> usize {
    let parts = parts.max(1);
    ((len + parts - 1) / parts).max(1)
}

/// Column-tiled parallel form of [`col_norms_into`]: the `d_out` axis is
/// split into contiguous tiles, one pool task per tile, each writing a
/// disjoint slice of the workspace. Per column the accumulation order
/// over rows is exactly the sequential order, so the result is
/// bit-identical for every pool size. Callers gate on size; this always
/// tiles (except for empty matrices).
pub(crate) fn col_norms_tiled<'w>(
    pool: &WorkerPool,
    g: &[f32],
    d_in: usize,
    d_out: usize,
    ws: &'w mut NormWorkspace,
) -> &'w [f32] {
    assert_eq!(g.len(), d_in * d_out);
    if d_in == 0 || d_out == 0 {
        return col_norms_into(g, d_in, d_out, ws);
    }
    ws.reset(d_out);
    let tile = tile_width(d_out, pool.parallelism());
    let mut tasks = Vec::new();
    for (ti, chunk) in ws.norms.chunks_mut(tile).enumerate() {
        let c0 = ti * tile;
        tasks.push(move || {
            let width = chunk.len();
            for r in 0..d_in {
                let row = &g[r * d_out + c0..r * d_out + c0 + width];
                for (n, &x) in chunk.iter_mut().zip(row) {
                    *n += x * x;
                }
            }
            for n in chunk.iter_mut() {
                *n = n.sqrt().max(EPS);
            }
        });
    }
    pool.run(tasks);
    &ws.norms
}

/// Column-wise normalization tiled across the pool — the parallel form
/// of [`colnorm_into`], bit-identical to it for every pool size (the
/// per-element operations and their order are unchanged; only the
/// partitioning differs, and column reductions are independent). Small
/// matrices (below the calibrated [`crate::parallel::tuned_min_ops`]
/// threshold) run the sequential kernel inline.
pub fn colnorm_into_par(
    pool: &WorkerPool,
    g: &[f32],
    d_in: usize,
    d_out: usize,
    ws: &mut NormWorkspace,
    out: &mut [f32],
) {
    let min_elems = crate::parallel::tuned_min_ops();
    colnorm_into_par_with(pool, g, d_in, d_out, ws, out, min_elems)
}

/// [`colnorm_into_par`] with an explicit sequential-fallback threshold
/// (elements); property tests sweep `min_elems` across the boundary to
/// pin down that the threshold only selects a path, never a result.
pub fn colnorm_into_par_with(
    pool: &WorkerPool,
    g: &[f32],
    d_in: usize,
    d_out: usize,
    ws: &mut NormWorkspace,
    out: &mut [f32],
    min_elems: usize,
) {
    assert_eq!(g.len(), d_in * d_out);
    assert_eq!(out.len(), g.len());
    if d_in * d_out < min_elems.max(1) || pool.parallelism() == 1 {
        return colnorm_into(g, d_in, d_out, ws, out);
    }
    // phase 1: per-column norms, tiled over columns (disjoint norm slices)
    col_norms_tiled(pool, g, d_in, d_out, ws);
    // phase 2: the scale pass, tiled over rows (disjoint output slices,
    // shared read of the finished norms)
    let norms: &[f32] = &ws.norms;
    let rows = tile_width(d_in, pool.parallelism());
    let mut tasks = Vec::new();
    for (ti, out_chunk) in out.chunks_mut(rows * d_out).enumerate() {
        let start = ti * rows * d_out;
        let g_chunk = &g[start..start + out_chunk.len()];
        tasks.push(move || {
            for (row_out, row_g) in out_chunk.chunks_mut(d_out).zip(g_chunk.chunks(d_out)) {
                for ((o, &x), &nm) in row_out.iter_mut().zip(row_g).zip(norms) {
                    *o = x / nm;
                }
            }
        });
    }
    pool.run(tasks);
}

/// Column-wise normalization of `g` in place.
pub fn colnorm_in_place(g: &mut [f32], d_in: usize, d_out: usize, ws: &mut NormWorkspace) {
    col_norms_into(g, d_in, d_out, ws);
    let norms = &ws.norms;
    for r in 0..d_in {
        for c in 0..d_out {
            g[r * d_out + c] /= norms[c];
        }
    }
}

/// Row-wise normalization into a caller-provided buffer: one fused
/// streaming pass per row (norm, then scale), zero heap allocations.
pub fn rownorm_into(g: &[f32], d_in: usize, d_out: usize, out: &mut [f32]) {
    assert_eq!(g.len(), d_in * d_out);
    assert_eq!(out.len(), g.len());
    for r in 0..d_in {
        let row = &g[r * d_out..(r + 1) * d_out];
        let norm = row.iter().map(|x| x * x).sum::<f32>().sqrt().max(EPS);
        for (o, &x) in out[r * d_out..(r + 1) * d_out].iter_mut().zip(row) {
            *o = x / norm;
        }
    }
}

/// Sign normalization (eq. 4) into a caller-provided buffer — single
/// fused pass, zero heap allocations.
pub fn sign_into(g: &[f32], out: &mut [f32]) {
    assert_eq!(out.len(), g.len());
    for (o, &x) in out.iter_mut().zip(g) {
        *o = if x > 0.0 {
            1.0
        } else if x < 0.0 {
            -1.0
        } else {
            0.0
        };
    }
}

/// Column-wise normalization: each column (stride `d_out`) scaled to unit
/// L2 norm; zero columns stay zero. Allocating wrapper over
/// [`colnorm_into`].
pub fn colnorm(g: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
    let mut ws = NormWorkspace::with_capacity(d_out);
    let mut out = vec![0.0f32; g.len()];
    colnorm_into(g, d_in, d_out, &mut ws, &mut out);
    out
}

/// Row-wise normalization (unit L2 rows). Allocating wrapper over
/// [`rownorm_into`].
pub fn rownorm(g: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; g.len()];
    rownorm_into(g, d_in, d_out, &mut out);
    out
}

/// Sign normalization (eq. 4). Allocating wrapper over [`sign_into`].
pub fn sign(g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; g.len()];
    sign_into(g, &mut out);
    out
}

/// Per-column L2 norms — the Fig. 10 statistic (LM-head column norms).
/// No `EPS` floor: this is an observed statistic, not a denominator.
pub fn column_norms(g: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
    let mut norms = vec![0.0f32; d_out];
    for r in 0..d_in {
        for c in 0..d_out {
            let x = g[r * d_out + c];
            norms[c] += x * x;
        }
    }
    for n in norms.iter_mut() {
        *n = n.sqrt();
    }
    norms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure};

    /// The original allocating algorithm, kept verbatim as the reference
    /// the `_into` kernels must match bit for bit.
    fn colnorm_reference(g: &[f32], d_in: usize, d_out: usize) -> Vec<f32> {
        let mut norms = vec![0.0f32; d_out];
        for r in 0..d_in {
            let row = &g[r * d_out..(r + 1) * d_out];
            for (n, &x) in norms.iter_mut().zip(row) {
                *n += x * x;
            }
        }
        for n in norms.iter_mut() {
            *n = n.sqrt().max(EPS);
        }
        let mut out = vec![0.0f32; g.len()];
        for r in 0..d_in {
            for c in 0..d_out {
                out[r * d_out + c] = g[r * d_out + c] / norms[c];
            }
        }
        out
    }

    #[test]
    fn unit_columns() {
        prop::quick("colnorm-unit-columns", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 30), prop::usize_in(rng, 1, 30));
            let g = prop::matrix(rng, m, n, 1.0);
            let out = colnorm(&g, m, n);
            for (c, norm) in column_norms(&out, m, n).iter().enumerate() {
                prop::ensure((norm - 1.0).abs() < 1e-3, format!("col {c}: {norm}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn scale_invariance() {
        prop::quick("colnorm-scale-invariant", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 20), prop::usize_in(rng, 1, 20));
            let g = prop::matrix(rng, m, n, 1.0);
            let alpha = prop::f32_in(rng, 0.01, 50.0);
            let scaled: Vec<f32> = g.iter().map(|x| x * alpha).collect();
            prop::slices_close(&colnorm(&scaled, m, n), &colnorm(&g, m, n), 1e-3)
        });
    }

    #[test]
    fn idempotent() {
        prop::quick("colnorm-idempotent", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 20), prop::usize_in(rng, 1, 20));
            let g = prop::matrix(rng, m, n, 1.0);
            let once = colnorm(&g, m, n);
            prop::slices_close(&colnorm(&once, m, n), &once, 1e-4)
        });
    }

    #[test]
    fn zero_column_stays_zero() {
        let g = vec![0.0, 1.0, 0.0, 2.0]; // 2x2, column 0 is zero
        let out = colnorm(&g, 2, 2);
        assert_eq!(out[0], 0.0);
        assert_eq!(out[2], 0.0);
        let n = (out[1] * out[1] + out[3] * out[3]).sqrt();
        assert!((n - 1.0).abs() < 1e-6);
    }

    #[test]
    fn rownorm_transposes_colnorm() {
        prop::quick("rownorm-is-transposed-colnorm", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 15), prop::usize_in(rng, 1, 15));
            let g = prop::matrix(rng, m, n, 1.0);
            // transpose, colnorm, transpose back == rownorm
            let mut gt = vec![0.0f32; g.len()];
            for r in 0..m {
                for c in 0..n {
                    gt[c * m + r] = g[r * n + c];
                }
            }
            let cn = colnorm(&gt, n, m);
            let mut back = vec![0.0f32; g.len()];
            for c in 0..n {
                for r in 0..m {
                    back[r * n + c] = cn[c * m + r];
                }
            }
            prop::slices_close(&back, &rownorm(&g, m, n), 1e-4)
        });
    }

    #[test]
    fn sign_values() {
        assert_eq!(sign(&[2.0, -3.0, 0.0]), vec![1.0, -1.0, 0.0]);
    }

    #[test]
    fn bounded_update_under_huge_gradients() {
        // the Fig. 3 stability property: colnorm bounds every entry by 1
        prop::quick("colnorm-bounded", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 10), prop::usize_in(rng, 1, 10));
            let g: Vec<f32> = prop::matrix(rng, m, n, 1e18);
            let out = colnorm(&g, m, n);
            ensure(
                out.iter().all(|x| x.is_finite() && x.abs() <= 1.0 + 1e-5),
                "entry out of bounds",
            )
        });
    }

    // ---- in-place / workspace kernel parity ------------------------------

    #[test]
    fn into_kernels_bit_identical_to_reference() {
        // One shared workspace across every case: reuse must not leak
        // state between calls of different shapes.
        let mut ws = NormWorkspace::new();
        prop::quick("colnorm-into-bit-identical", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 24), prop::usize_in(rng, 1, 24));
            let g_scale = prop::f32_in(rng, 0.01, 10.0);
            let g = prop::matrix(rng, m, n, g_scale);
            let want = colnorm_reference(&g, m, n);
            let mut out = vec![0.0f32; g.len()];
            colnorm_into(&g, m, n, &mut ws, &mut out);
            ensure(out == want, "colnorm_into differs from reference")?;
            let mut in_place = g.clone();
            colnorm_in_place(&mut in_place, m, n, &mut ws);
            ensure(in_place == want, "colnorm_in_place differs from reference")?;
            let mut row_out = vec![0.0f32; g.len()];
            rownorm_into(&g, m, n, &mut row_out);
            ensure(row_out == rownorm(&g, m, n), "rownorm_into differs")?;
            let mut sign_out = vec![0.0f32; g.len()];
            sign_into(&g, &mut sign_out);
            ensure(sign_out == sign(&g), "sign_into differs")
        });
    }

    #[test]
    fn into_kernel_edge_cases_match_reference() {
        let mut ws = NormWorkspace::new();
        // zero column
        let g = vec![0.0, 1.0, 0.0, 2.0];
        let mut out = vec![0.0f32; 4];
        colnorm_into(&g, 2, 2, &mut ws, &mut out);
        assert_eq!(out, colnorm_reference(&g, 2, 2));
        assert_eq!(out[0], 0.0);
        assert_eq!(out[2], 0.0);
        // huge gradients stay bounded and match the reference bits
        let huge = vec![1e18f32, -3e18, 2e18, 5e17, -1e18, 4e18];
        let mut out = vec![0.0f32; 6];
        colnorm_into(&huge, 2, 3, &mut ws, &mut out);
        assert_eq!(out, colnorm_reference(&huge, 2, 3));
        assert!(out.iter().all(|x| x.is_finite() && x.abs() <= 1.0 + 1e-5));
        // all-zero matrix: EPS floor keeps everything finite zero
        let z = vec![0.0f32; 6];
        let mut out = vec![9.0f32; 6];
        colnorm_into(&z, 3, 2, &mut ws, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn workspace_reuse_across_shapes() {
        let mut ws = NormWorkspace::with_capacity(8);
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut out_a = vec![0.0f32; 6];
        colnorm_into(&a, 2, 3, &mut ws, &mut out_a);
        assert_eq!(ws.norms().len(), 3);
        let b = vec![2.0f32, 0.0, 0.0, 2.0];
        let mut out_b = vec![0.0f32; 4];
        colnorm_into(&b, 2, 2, &mut ws, &mut out_b);
        assert_eq!(ws.norms().len(), 2);
        assert_eq!(out_b, colnorm_reference(&b, 2, 2));
        // shrinking then growing again must not carry stale accumulators
        let mut out_a2 = vec![0.0f32; 6];
        colnorm_into(&a, 2, 3, &mut ws, &mut out_a2);
        assert_eq!(out_a, out_a2);
    }

    // ---- column-tiled parallel kernel bit-identity -----------------------

    #[test]
    fn par_kernel_bit_identical_over_pools_and_thresholds() {
        // random shapes, several pool sizes, and thresholds straddling
        // the numel boundary: every combination must reproduce the
        // sequential kernel bit for bit (column reductions are
        // independent, so tiling reassociates nothing)
        let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(7)];
        let mut ws = NormWorkspace::new();
        let mut ws_par = NormWorkspace::new();
        prop::check("colnorm-par-bit-identical", 32, |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 48), prop::usize_in(rng, 1, 48));
            let g_scale = prop::f32_in(rng, 0.01, 10.0);
            let g = prop::matrix(rng, m, n, g_scale);
            let mut want = vec![0.0f32; g.len()];
            colnorm_into(&g, m, n, &mut ws, &mut want);
            let numel = m * n;
            for pool in &pools {
                // thresholds straddling the gate: 0/1 force the tiled
                // path, numel sits exactly on the boundary (tiled, since
                // the gate is `numel < min`), numel+1 forces sequential
                for min_elems in [0usize, 1, numel, numel + 1] {
                    let mut got = vec![1e9f32; g.len()];
                    colnorm_into_par_with(pool, &g, m, n, &mut ws_par, &mut got, min_elems);
                    ensure(
                        got == want,
                        format!(
                            "colnorm_into_par differs: {m}x{n}, {} workers, min {min_elems}",
                            pool.workers()
                        ),
                    )?;
                    ensure(
                        ws_par.norms() == ws.norms(),
                        format!("workspace norms differ: {m}x{n}, min {min_elems}"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn col_norms_tiled_matches_sequential_exactly() {
        let pool = WorkerPool::new(3);
        let mut ws = NormWorkspace::new();
        let mut ws_tiled = NormWorkspace::new();
        prop::quick("col-norms-tiled-bits", |rng| {
            let (m, n) = (prop::usize_in(rng, 1, 40), prop::usize_in(rng, 1, 40));
            let g_scale = prop::f32_in(rng, 0.01, 5.0);
            let g = prop::matrix(rng, m, n, g_scale);
            let want = col_norms_into(&g, m, n, &mut ws).to_vec();
            let got = col_norms_tiled(&pool, &g, m, n, &mut ws_tiled).to_vec();
            ensure(got == want, format!("tiled norms differ at {m}x{n}"))
        });
    }

    #[test]
    fn par_kernel_default_threshold_tiles_large_matrices() {
        // 256x256 = 65536 elements >= PAR_MIN_ELEMS: the default entry
        // point takes the tiled path and must still match exactly
        let pool = WorkerPool::new(4);
        let mut rng = crate::util::rng::Pcg::new(77);
        let (m, n) = (256usize, 256usize);
        assert!(m * n >= PAR_MIN_ELEMS);
        let g: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut ws = NormWorkspace::new();
        let mut want = vec![0.0f32; g.len()];
        colnorm_into(&g, m, n, &mut ws, &mut want);
        let mut ws_par = NormWorkspace::new();
        let mut got = vec![0.0f32; g.len()];
        colnorm_into_par(&pool, &g, m, n, &mut ws_par, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn par_kernel_single_column_and_single_row_edges() {
        // degenerate shapes stress the tile arithmetic: one column
        // (tiles collapse to width 1) and one row (row chunks collapse)
        let pool = WorkerPool::new(3);
        let mut ws = NormWorkspace::new();
        let mut ws_par = NormWorkspace::new();
        for (m, n) in [(64usize, 1usize), (1, 64), (5, 3), (3, 5)] {
            let mut rng = crate::util::rng::Pcg::new((m * 100 + n) as u64);
            let g: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
            let mut want = vec![0.0f32; g.len()];
            colnorm_into(&g, m, n, &mut ws, &mut want);
            let mut got = vec![0.0f32; g.len()];
            colnorm_into_par_with(&pool, &g, m, n, &mut ws_par, &mut got, 0);
            assert_eq!(got, want, "shape {m}x{n}");
        }
    }
}
