//! Appendix-B memory estimator.
//!
//! The paper counts weights + optimizer states in bf16 (2 bytes/value)
//! over the "major parameters" (embedding, attention, MLP, LM head) of
//! real LLaMA configs. Those numbers are exactly reproducible:
//!
//!   7B: pre-last 6.607B + last 0.131B params
//!       SGD 13.476G · Adam 40.428G · Muon 26.952G · SWAN 14.524G
//!       APOLLO 16.144G · APOLLO-Mini 14.531G · SCALE 13.738G
//!
//! plus the 1B variants of Appendix B / Table 5. The per-method state
//! formulas below mirror the paper's accounting: GaLore/Fira/APOLLO(-Mini)
//! and SWAN run full Adam on the first and last layers; low-rank states
//! for APOLLO are `r x max(d_in, d_out)` per hidden matrix; GaLore/Fira
//! additionally store the projector `min(d) x r`.

use crate::runtime::artifact::{DType, Manifest, PaperDims};

pub const BYTES: f64 = 2.0; // bf16
const GB: f64 = 1e9; // the paper uses decimal GB

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MethodMemory {
    pub params_gb: f64,
    pub state_gb: f64,
}

impl MethodMemory {
    pub fn total_gb(&self) -> f64 {
        self.params_gb + self.state_gb
    }
}

/// Per-matrix inventory of one LLaMA model at paper scale.
pub struct MemoryModel {
    pub dims: PaperDims,
    /// hidden (non-embed/head) matrices as (d_in, d_out)
    pub hidden: Vec<(usize, usize)>,
    pub embed: usize,
    pub head: usize,
}

impl MemoryModel {
    pub fn new(dims: PaperDims) -> MemoryModel {
        let d = dims.d_model;
        let f = dims.d_ff;
        let mut hidden = Vec::new();
        for _ in 0..dims.n_layers {
            hidden.extend_from_slice(&[
                (d, d), // wq
                (d, d), // wk
                (d, d), // wv
                (d, d), // wo
                (d, f), // gate
                (d, f), // up
                (f, d), // down
            ]);
        }
        MemoryModel {
            dims,
            hidden,
            embed: dims.vocab * d,
            head: d * dims.vocab,
        }
    }

    pub fn hidden_params(&self) -> usize {
        self.hidden.iter().map(|(a, b)| a * b).sum()
    }

    pub fn total_params(&self) -> usize {
        self.hidden_params() + self.embed + self.head
    }

    /// Paper's "pre-last layers" = everything except the LM head.
    pub fn pre_last_params(&self) -> usize {
        self.total_params() - self.head
    }

    fn gb(elems: f64) -> f64 {
        elems * BYTES / GB
    }

    /// Optimizer state elements for `method` (rank for projection methods).
    pub fn state_elems(&self, method: &str, rank: usize) -> f64 {
        let total = self.total_params() as f64;
        let first_last = (self.embed + self.head) as f64;
        let lowrank_mv: f64 = self
            .hidden
            .iter()
            .map(|&(a, b)| (rank * a.max(b)) as f64)
            .sum::<f64>()
            * 2.0;
        let projector: f64 = self
            .hidden
            .iter()
            .map(|&(a, b)| (rank * a.min(b)) as f64)
            .sum();
        // AdaPM-style partial-momentum policies: one momentum slot per
        // selected matrix, nothing else. The selections mirror
        // `MomentumPolicy::selects` over the canonical parameter order
        // (embed, block0.., lm_head), translated to paper-scale matrices.
        let first_layer: f64 = self.hidden[..7].iter().map(|&(a, b)| (a * b) as f64).sum();
        let last_hidden = self.hidden.last().map_or(0.0, |&(a, b)| (a * b) as f64);
        match method {
            "sgd" => 0.0,
            "adam" | "stable_spam" => 2.0 * total,
            "muon" => total,
            "swan" => 2.0 * first_last,
            "scale" | "adapm_last" => self.head as f64,
            "scale_first_last" | "adapm_embed_head" => first_last,
            "adapm_first_last" => first_layer + self.head as f64,
            "adapm_top2" => last_hidden + self.head as f64,
            "adams" => total,
            "sgd_momentum" => total,
            "apollo" | "apollo_mini" => 2.0 * first_last + lowrank_mv,
            "galore" | "fira" => 2.0 * first_last + lowrank_mv + projector,
            "sgd_colnorm" | "sgd_rownorm" | "sign_sgd" | "sgd_ns" => 0.0,
            other => panic!("unknown method {other:?}"),
        }
    }

    pub fn method(&self, method: &str, rank: usize) -> MethodMemory {
        MethodMemory {
            params_gb: Self::gb(self.total_params() as f64),
            state_gb: Self::gb(self.state_elems(method, rank)),
        }
    }
}

/// Measured (not modeled) state bytes for a tiny run in this repo:
/// read straight from the manifest's state layout (f32 slots on CPU —
/// sized through [`DType::bytes`] so a future lower-precision state
/// dtype cannot silently mis-size this).
pub fn measured_state_bytes(
    manifest: &Manifest,
    optimizer: &str,
    size: &str,
) -> anyhow::Result<usize> {
    let per = DType::F32.bytes();
    let slots = manifest.state_spec(optimizer, size)?;
    Ok(slots
        .iter()
        .map(|s| per * s.shape.iter().product::<usize>())
        .sum())
}

pub fn measured_param_bytes(manifest: &Manifest, size: &str) -> anyhow::Result<usize> {
    Ok(DType::F32.bytes() * manifest.size(size)?.param_count)
}

/// Measured per-rank optimizer-state bytes under `scale launch
/// --shard-state`: the manifest's state layout sliced by the update
/// plan's contiguous shard partition — the exact partition the mesh
/// uses, so these are the bytes each rank holds persistently, not a
/// model. `out[r]` is rank r's share; the shares sum to
/// [`measured_state_bytes`].
pub fn sharded_state_bytes(
    manifest: &Manifest,
    optimizer: &str,
    size: &str,
    ranks: usize,
) -> anyhow::Result<Vec<usize>> {
    let per = DType::F32.bytes();
    let slots = manifest.state_spec(optimizer, size)?;
    let prog = crate::exec::update::UpdateProgram::new(optimizer, manifest.size(size)?)?;
    anyhow::ensure!(
        slots.len() == prog.n_state(),
        "state spec ({} slots) disagrees with the update plan ({} slots)",
        slots.len(),
        prog.n_state()
    );
    let plan = prog.shard_plan(ranks);
    Ok(plan
        .state
        .iter()
        .map(|sr| {
            slots[sr.clone()].iter().map(|s| per * s.shape.iter().product::<usize>()).sum()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims7b() -> PaperDims {
        PaperDims {
            vocab: 32000,
            d_model: 4096,
            n_layers: 32,
            d_ff: 11008,
        }
    }

    fn dims1b() -> PaperDims {
        PaperDims {
            vocab: 32000,
            d_model: 2048,
            n_layers: 24,
            d_ff: 5461,
        }
    }

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn reproduces_7b_param_split() {
        let m = MemoryModel::new(dims7b());
        // paper: pre-last 6.607B, last 0.131B, total 6.738B
        assert!(close(m.pre_last_params() as f64 / 1e9, 6.607, 0.01));
        assert!(close(m.head as f64 / 1e9, 0.131, 0.001));
        assert!(close(m.total_params() as f64 / 1e9, 6.738, 0.01));
    }

    #[test]
    fn reproduces_table4_memory_column() {
        let m = MemoryModel::new(dims7b());
        // paper Table 4 (GB): SGD 13.48, Adam 40.43, Muon 26.95,
        // SWAN 14.52, APOLLO 16.14, APOLLO-Mini 14.53, SCALE 13.74
        assert!(close(m.method("sgd", 0).total_gb(), 13.48, 0.05));
        assert!(close(m.method("adam", 0).total_gb(), 40.43, 0.1));
        assert!(close(m.method("muon", 0).total_gb(), 26.95, 0.1));
        assert!(close(m.method("swan", 0).total_gb(), 14.52, 0.05));
        assert!(close(m.method("apollo", 256).total_gb(), 16.14, 0.1));
        assert!(close(m.method("apollo_mini", 1).total_gb(), 14.53, 0.05));
        assert!(close(m.method("scale", 0).total_gb(), 13.74, 0.05));
    }

    #[test]
    fn reproduces_1b_appendix_b() {
        let m = MemoryModel::new(dims1b());
        assert!(close(m.total_params() as f64 / 1e9, 1.339, 0.01));
        assert!(close(m.method("sgd", 0).total_gb(), 2.678, 0.02));
        assert!(close(m.method("adam", 0).total_gb(), 8.034, 0.05));
        assert!(close(m.method("muon", 0).total_gb(), 5.356, 0.03));
        assert!(close(m.method("swan", 0).total_gb(), 3.202, 0.03));
        assert!(close(m.method("scale", 0).total_gb(), 2.809, 0.02));
    }

    #[test]
    fn scale_is_sgd_like() {
        // the abstract's claim: SCALE needs ~2% extra memory over SGD at 7B
        let m = MemoryModel::new(dims7b());
        let sgd = m.method("sgd", 0).total_gb();
        let scale = m.method("scale", 0).total_gb();
        let overhead = (scale - sgd) / sgd;
        assert!(overhead < 0.025, "overhead {overhead}");
        // ... and ~35% of Adam's total
        let adam = m.method("adam", 0).total_gb();
        assert!(scale / adam < 0.45, "ratio {}", scale / adam);
    }

    #[test]
    fn memory_ordering_matches_figure_1() {
        let m = MemoryModel::new(dims1b());
        let order = [
            m.method("scale", 0).total_gb(),
            m.method("apollo_mini", 1).total_gb(),
            m.method("apollo", 256).total_gb(),
            m.method("muon", 0).total_gb(),
            m.method("adam", 0).total_gb(),
        ];
        for w in order.windows(2) {
            assert!(w[0] < w[1], "{order:?}");
        }
    }

    #[test]
    fn sharded_state_partitions_exactly_and_keeps_the_paper_ratio() {
        let m = crate::exec::native_manifest(std::path::PathBuf::from("unused"));
        for size in ["tiny", "s60m", "s130m", "s350m", "e2e"] {
            let full_scale = measured_state_bytes(&m, "scale", size).unwrap();
            let full_adam = measured_state_bytes(&m, "adam", size).unwrap();
            for ranks in [1usize, 2, 4] {
                let scale = sharded_state_bytes(&m, "scale", size, ranks).unwrap();
                let adam = sharded_state_bytes(&m, "adam", size, ranks).unwrap();
                assert_eq!(scale.len(), ranks);
                assert_eq!(adam.len(), ranks);
                // the shards tile the full state exactly — nothing double
                // counted, nothing dropped
                assert_eq!(scale.iter().sum::<usize>(), full_scale, "{size} at {ranks} ranks");
                assert_eq!(adam.iter().sum::<usize>(), full_adam, "{size} at {ranks} ranks");
                // the paper's memory claim, peak rank vs peak rank: the
                // heaviest SCALE rank stays within 45% of the heaviest
                // Adam rank at every rank count
                let peak_scale = *scale.iter().max().unwrap() as f64;
                let peak_adam = *adam.iter().max().unwrap() as f64;
                assert!(
                    peak_scale <= 0.45 * peak_adam,
                    "{size} at {ranks} ranks: {peak_scale} vs {peak_adam}"
                );
            }
        }
    }

    #[test]
    fn frontier_memory_arms_match_their_policies() {
        let m = MemoryModel::new(dims1b());
        // `adapm_last` selects exactly the lm_head — SCALE's footprint.
        assert_eq!(m.state_elems("adapm_last", 0), m.state_elems("scale", 0));
        // `adapm_embed_head` selects embed + head — scale_first_last's.
        assert_eq!(m.state_elems("adapm_embed_head", 0), m.state_elems("scale_first_last", 0));
        // AdamS keeps one momentum slot everywhere — SGD-momentum's bill.
        assert_eq!(m.state_elems("adams", 0), m.state_elems("sgd_momentum", 0));
        // first_last = block0's seven matrices + head, strictly between
        // the head-only and the everything policies
        let fl = m.state_elems("adapm_first_last", 0);
        let expect: f64 =
            m.hidden[..7].iter().map(|&(a, b)| (a * b) as f64).sum::<f64>() + m.head as f64;
        assert_eq!(fl, expect);
        assert!(m.state_elems("adapm_last", 0) < fl && fl < m.state_elems("adams", 0));
        // top2 = last hidden matrix + head
        let (a, b) = *m.hidden.last().unwrap();
        assert_eq!(m.state_elems("adapm_top2", 0), (a * b + m.head) as f64);
    }

    #[test]
    fn monotone_in_model_size() {
        let small = MemoryModel::new(dims1b());
        let big = MemoryModel::new(dims7b());
        for method in ["sgd", "adam", "scale", "muon"] {
            assert!(
                big.method(method, 64).total_gb() > small.method(method, 64).total_gb()
            );
        }
    }
}
