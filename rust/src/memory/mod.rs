//! Memory accounting — the paper's Appendix B / Table 4, reproduced
//! exactly (it is pure arithmetic over real LLaMA dimensions), plus
//! measured optimizer-state accounting for this repo's tiny runs.

pub mod estimator;

pub use estimator::{MemoryModel, MethodMemory};
