//! SCALE-LLM: reproduction of "Memory-Efficient LLM Pretraining via
//! Minimalist Optimizer Design" (SCALE), built as a three-layer
//! Rust + JAX + Pallas stack (AOT via XLA/PJRT).
//!
//! Layers:
//! - L1 (build-time Python): Pallas kernels for the optimizer hot path
//!   (column-wise normalization, fused SCALE/Adam updates).
//! - L2 (build-time Python): JAX LLaMA-style model fwd/bwd and the full
//!   optimizer zoo, lowered once to HLO text artifacts.
//! - L3 (this crate): the training coordinator — data pipeline, DDP
//!   simulation, scheduler, checkpointing, metrics, memory accounting,
//!   and the benchmark harness that regenerates the paper's tables.
//!
//! On the default (no-`xla`) build, the [`exec`] native CPU engine
//! stands in for L1/L2 at runtime: the same manifest contract, executed
//! by pure-Rust pool-parallel kernels, so training runs end-to-end with
//! no Python and no FFI.

// Dense index arithmetic is the idiom of the exec kernels: one loop
// variable typically strides several coupled buffers at once, and the
// iterator/zip rewrites clippy suggests obscure the offset math without
// changing codegen. Everything else the CI clippy gate flags is fixed
// at the site, not allowed.
#![allow(clippy::needless_range_loop)]

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod fault;
pub mod harness;
pub mod memory;
pub mod mesh;
pub mod optim;
pub mod parallel;
pub mod runtime;
pub mod serve;
pub mod util;
