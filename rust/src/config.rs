//! Run configuration: JSON config files + CLI overrides -> TrainOptions.
//!
//! `configs/*.json` hold named experiment presets (the launcher's unit of
//! reproducibility); every field can be overridden on the command line.

use std::path::Path;

use crate::coordinator::TrainOptions;
use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// Load a preset from a JSON file. Unknown keys are rejected.
pub fn load_preset(path: impl AsRef<Path>) -> anyhow::Result<TrainOptions> {
    let text = std::fs::read_to_string(&path)?;
    let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
    from_json(&j)
}

pub fn from_json(j: &Json) -> anyhow::Result<TrainOptions> {
    let mut o = TrainOptions::default();
    let obj = j
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("config must be an object"))?;
    for (k, v) in obj {
        match k.as_str() {
            "size" => o.size = v.as_str().unwrap_or(&o.size).to_string(),
            "optimizer" => o.optimizer = v.as_str().unwrap_or(&o.optimizer).to_string(),
            "steps" => o.steps = v.as_usize().unwrap_or(o.steps),
            "lr" => o.base_lr = v.as_f64().unwrap_or(o.base_lr),
            "shards" => o.shards = v.as_usize().unwrap_or(o.shards),
            "seed" => o.seed = v.as_f64().unwrap_or(0.0) as u64,
            "eval_every" => o.eval_every = v.as_usize().unwrap_or(0),
            "eval_batches" => o.eval_batches = v.as_usize().unwrap_or(o.eval_batches),
            "log_every" => o.log_every = v.as_usize().unwrap_or(o.log_every),
            "quiet" => o.quiet = v.as_bool().unwrap_or(false),
            "comment" => {}
            other => anyhow::bail!("unknown config key {other:?}"),
        }
    }
    Ok(o)
}

/// Apply CLI overrides on top of a preset (or the defaults).
pub fn apply_cli(mut o: TrainOptions, args: &mut Args) -> anyhow::Result<TrainOptions> {
    if let Some(s) = args.get("size") {
        o.size = s.to_string();
    }
    if let Some(s) = args.get("optimizer") {
        o.optimizer = s.to_string();
    }
    o.steps = args.get_usize("steps", o.steps)?;
    o.base_lr = args.get_f64("lr", o.base_lr)?;
    o.shards = args.get_usize("shards", o.shards)?;
    o.seed = args.get_usize("seed", o.seed as usize)? as u64;
    o.eval_every = args.get_usize("eval-every", o.eval_every)?;
    o.eval_batches = args.get_usize("eval-batches", o.eval_batches)?;
    o.log_every = args.get_usize("log-every", o.log_every)?;
    if args.flag("quiet") {
        o.quiet = true;
    }
    Ok(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let j = json::parse(
            r#"{"size":"s130m","optimizer":"adam","steps":50,"lr":0.0005,
                "shards":2,"seed":3,"eval_every":10,"comment":"x"}"#,
        )
        .unwrap();
        let o = from_json(&j).unwrap();
        assert_eq!(o.size, "s130m");
        assert_eq!(o.optimizer, "adam");
        assert_eq!(o.steps, 50);
        assert_eq!(o.base_lr, 5e-4);
        assert_eq!(o.shards, 2);
        assert_eq!(o.seed, 3);
    }

    #[test]
    fn rejects_unknown_key() {
        let j = json::parse(r#"{"sizee":"s130m"}"#).unwrap();
        assert!(from_json(&j).is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut args = crate::util::cli::Args::parse(&[
            "train".into(),
            "--optimizer".into(),
            "muon".into(),
            "--steps=7".into(),
        ])
        .unwrap();
        let o = apply_cli(TrainOptions::default(), &mut args).unwrap();
        assert_eq!(o.optimizer, "muon");
        assert_eq!(o.steps, 7);
    }
}
