//! Native CPU execution engine: pure-Rust implementations of the
//! manifest's artifact semantics (`fwd_bwd_*`, `eval_*`, `update_*_*`,
//! `init_*`, `varprobe_*`, `norm_*_*`), selected by
//! `runtime::client::Engine::load` whenever PJRT is unavailable — the
//! default build trains end-to-end with no Python and no FFI. The `xla`
//! cargo feature keeps its PJRT path untouched, which makes the two
//! executors parity-testable against each other once the FFI is wired.
//!
//! # Kernel tiling / packing contract
//!
//! All heavy math routes through [`gemm`]'s three orientations (`nn`
//! activations×weights with a packed-transposed B panel, `nt` backward
//! data with contiguous-row dots, `tn` backward weights as row-blocked
//! rank-1 accumulation). Two invariants hold everywhere:
//!
//! * **Disjoint output blocks.** Parallelism only ever partitions the
//!   output matrix into contiguous row blocks, one pool task per block,
//!   obtained via `chunks_mut` — no locks, no aliasing on the data path.
//! * **Fixed accumulation order.** Each output element's reduction over
//!   `k` is a function of `k` alone (8-lane dot association, sequential
//!   rank-1 order), independent of the tiling. Results are therefore
//!   bit-identical for every worker-pool size and every `min_ops`
//!   threshold — the property tests in `gemm`, `ns`, and `model` sweep
//!   pools and thresholds to pin this down.
//!
//! The sequential-fallback threshold (`min_ops`, multiply-add count) is
//! calibrated at runtime from measured pool dispatch latency
//! ([`crate::parallel::calibrate`]) rather than hard-coded; it selects a
//! code path, never a result.
//!
//! # Arena ownership
//!
//! Every program owns its scratch: model programs keep a pool of
//! [`model::ModelWs`] arenas (one per concurrent executor — DDP shards
//! share one `Arc<Executable>`), update programs a single mutexed
//! workspace. Arenas are fully sized at construction from the model
//! dims, so a steady-state `fwd_bwd`/`update` execution touches the heap
//! zero times when the caller reuses its output tensors
//! (`Engine::run_exe_refs_into`) — the gate asserted by
//! `benches/bench_throughput.rs`, extending the `bench_hot_path`
//! discipline from the optimizer kernels to the whole step.

pub mod gemm;
pub mod manifest;
pub(crate) mod model;
pub(crate) mod ns;
mod program;
pub(crate) mod update;

pub use manifest::native_manifest;
pub use program::{native_init, NativeProgram};
pub use update::NATIVE_OPTIMIZERS;
