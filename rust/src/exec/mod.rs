//! Native CPU execution engine: pure-Rust implementations of the
//! manifest's artifact semantics (`fwd_bwd_*`, `eval_*`, `update_*_*`,
//! `init_*`, `varprobe_*`, `norm_*_*`), selected by
//! `runtime::client::Engine::load` whenever PJRT is unavailable — the
//! default build trains end-to-end with no Python and no FFI. The `xla`
//! cargo feature keeps its PJRT path untouched, which makes the two
//! executors parity-testable against each other once the FFI is wired.
//!
//! # Kernel tiling / packing contract
//!
//! All heavy math routes through [`gemm`]'s three orientations (`nn`
//! activations×weights with a packed-transposed B panel, `nt` backward
//! data with contiguous-row dots, `tn` backward weights as row-blocked
//! rank-1 accumulation), whose inner loops bottom out in the [`kernels`]
//! microkernels (`dot8`/`axpy8` — scalar 8-lane by default, bit-identical
//! AVX2 under the off-by-default `simd` cargo feature). Two invariants
//! hold everywhere:
//!
//! * **Disjoint output blocks.** Parallelism only ever partitions
//!   outputs into contiguous blocks, one pool task per block, obtained
//!   via `chunks_mut` — no locks, no aliasing on the data path. This
//!   covers both the GEMM row blocks and the transformer's
//!   per-(batch, head) attention pairs, whose softmax/context/gradient
//!   rows are disjoint slices of the head-layout buffers (`model`).
//! * **Fixed accumulation order.** Each output element's reduction over
//!   `k` is a function of `k` alone (8-lane dot association, sequential
//!   rank-1 order), independent of the tiling, the pool size, and the
//!   build flavor. Results are therefore bit-identical for every
//!   worker-pool size, every `min_ops` threshold, and with or without
//!   `simd` — the property tests in `gemm`, `kernels`, `ns`, and
//!   `model` sweep all of these to pin it down.
//!
//! The sequential-fallback threshold (`min_ops`, multiply-add count) is
//! calibrated at runtime from measured pool dispatch latency
//! ([`crate::parallel::calibrate`]) rather than hard-coded; it selects a
//! code path, never a result. The attention fan-out obeys the same gate
//! (pair count × `s²·dh` score ops against the threshold), with a
//! bench-only override ([`set_attn_pair_override`]) for A/B rows.
//!
//! # Arena ownership
//!
//! Every program owns its scratch: model programs keep a pool of
//! `model::ModelWs` arenas (one per concurrent executor — DDP shards
//! share one `Arc<Executable>`), update programs a single mutexed
//! workspace. Arenas are fully sized at construction from the model
//! dims, so a steady-state `fwd_bwd`/`update` execution touches the heap
//! zero times when the caller reuses its output tensors
//! (`Engine::run_exe_refs_into`) — the gate asserted by
//! `benches/bench_throughput.rs`, extending the `bench_hot_path`
//! discipline from the optimizer kernels to the whole step. The serve
//! layer (`crate::serve`) reuses the same free-list type
//! (`program::WsPool`) for its per-request KV-cache + decode slabs, and
//! the same bench file gates the decode loop.

pub mod gemm;
pub mod kernels;
pub mod manifest;
pub(crate) mod model;
pub(crate) mod ns;
pub(crate) mod program;
pub mod update;

pub use manifest::native_manifest;
pub use model::set_attn_pair_override;
pub use program::{native_init, NativeProgram};
pub use update::{MomentumPolicy, NATIVE_OPTIMIZERS};
