//! Native transformer forward/backward: the LLaMA-style block of
//! `python/compile/model.py` (RMSNorm → RoPE attention → SwiGLU, untied
//! LM head, mean next-token cross-entropy) and its GPT2 variant (learned
//! positional embeddings, GELU MLP, no RoPE), in pure Rust over the
//! [`super::gemm`] kernels.
//!
//! All intermediates live in a [`ModelWs`] arena owned by the
//! `NativeProgram`: buffers are sized once at construction for the
//! largest batch the program executes, so steady-state `fwd_bwd` calls
//! perform zero heap allocations (the bench gate in
//! `benches/bench_throughput.rs`). The backward pass is fused where it
//! pays: softmax-cross-entropy produces `dlogits` in place of the logits
//! buffer, and the attention softmax backward rescales and masks in one
//! sweep over the probability rows.
//!
//! Determinism: every reduction (row norms, loss accumulation, attention
//! dots) is sequenced identically regardless of pool size — parallelism
//! enters only through two partitionings that never reassociate a float:
//! the GEMM row blocks (pinned bit-stable by the gemm module) and the
//! per-(batch, head) attention pairs. Each pair's softmax rows, context
//! rows, and gradient rows are contiguous disjoint slices of the
//! head-layout buffers (`probs`, `att`, `dq/dk/dv`, `dprobs`), so pairs
//! fan out to the shared worker pool via `chunks_mut` with no aliasing,
//! each pair running the sequential code verbatim; the fan-out is gated
//! by the calibrated `min_ops` threshold and its small per-pair matmuls
//! stay off the pool queue (`matmul_tn_seq`). `fwd_bwd` is therefore
//! bit-identical for every worker-pool size and threshold
//! (property-tested below, including ragged pair counts).

use std::sync::atomic::{AtomicU8, Ordering};

use crate::exec::gemm::{axpy, dot, matmul_nn, matmul_nt, matmul_tn, matmul_tn_seq};
use crate::optim::colnorm::tile_width;
use crate::parallel::WorkerPool;
use crate::runtime::artifact::SizeInfo;
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

const NORM_EPS: f32 = 1e-6;
const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi)

/// Process-wide override for the attention pair dispatch: 0 = gate on
/// `min_ops` (default), 1 = force the parallel path, 2 = force the
/// sequential path. Both paths are bit-identical (property-tested), so
/// this selects a code path, never a result — it exists so the
/// throughput bench can emit attention-parallel vs sequential A/B rows
/// with everything else held at the calibrated thresholds.
static ATTN_PAIR_FORCE: AtomicU8 = AtomicU8::new(0);

/// Force the per-(batch, head) attention fan-out on (`Some(true)`), off
/// (`Some(false)`), or restore the `tuned_min_ops` gate (`None`). See
/// `ATTN_PAIR_FORCE` above; bench/test hook, never needed for
/// correctness — both paths are bit-identical.
pub fn set_attn_pair_override(force: Option<bool>) {
    let v = match force {
        None => 0,
        Some(true) => 1,
        Some(false) => 2,
    };
    ATTN_PAIR_FORCE.store(v, Ordering::SeqCst);
}

/// Decide whether one layer's (batch, head) attention pairs fan out to
/// the pool. `pairs * s * s * dh` approximates the pair loops'
/// multiply-add count (scores + context; causal masking halves it),
/// comparable with the GEMM `m*n*k` convention the calibrated `min_ops`
/// threshold is expressed in. A single-lane pool or a single pair always
/// runs inline — dispatch could only add latency there.
fn attn_pairs_parallel(
    pool: &WorkerPool,
    min_ops: usize,
    pairs: usize,
    s: usize,
    dh: usize,
) -> bool {
    if pool.parallelism() == 1 || pairs == 1 {
        return false;
    }
    match ATTN_PAIR_FORCE.load(Ordering::SeqCst) {
        1 => true,
        2 => false,
        _ => pairs * s * s * dh >= min_ops.max(1),
    }
}

/// Model dimensions + parameter-order bookkeeping, derived from the
/// manifest's [`SizeInfo`]. Parameter order matches `model.param_specs`:
/// embed, (pos_embed), per block [attn_norm, wq, wk, wv, wo, mlp_norm,
/// (w_gate,) w_up, w_down], final_norm, lm_head.
#[derive(Debug, Clone)]
pub(crate) struct ModelSpec {
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub gpt2: bool,
}

impl ModelSpec {
    pub fn from_size(info: &SizeInfo) -> ModelSpec {
        ModelSpec {
            vocab: info.vocab,
            d: info.d_model,
            n_layers: info.n_layers,
            n_heads: info.n_heads,
            head_dim: info.d_model / info.n_heads,
            d_ff: info.d_ff,
            seq: info.seq_len,
            gpt2: info.arch == "gpt2",
        }
    }

    fn base(&self) -> usize {
        if self.gpt2 {
            2 // embed, pos_embed
        } else {
            1 // embed
        }
    }

    fn per_block(&self) -> usize {
        if self.gpt2 {
            8
        } else {
            9
        }
    }

    pub fn n_params(&self) -> usize {
        self.base() + self.n_layers * self.per_block() + 2
    }

    fn p_attn_norm(&self, l: usize) -> usize {
        self.base() + l * self.per_block()
    }

    fn p_wq(&self, l: usize) -> usize {
        self.p_attn_norm(l) + 1
    }

    fn p_wk(&self, l: usize) -> usize {
        self.p_attn_norm(l) + 2
    }

    fn p_wv(&self, l: usize) -> usize {
        self.p_attn_norm(l) + 3
    }

    fn p_wo(&self, l: usize) -> usize {
        self.p_attn_norm(l) + 4
    }

    fn p_mlp_norm(&self, l: usize) -> usize {
        self.p_attn_norm(l) + 5
    }

    /// LLaMA only (SwiGLU gate matrix).
    fn p_wgate(&self, l: usize) -> usize {
        self.p_attn_norm(l) + 6
    }

    fn p_wup(&self, l: usize) -> usize {
        self.p_attn_norm(l) + if self.gpt2 { 6 } else { 7 }
    }

    fn p_wdown(&self, l: usize) -> usize {
        self.p_attn_norm(l) + if self.gpt2 { 7 } else { 8 }
    }

    pub fn idx_final_norm(&self) -> usize {
        self.n_params() - 2
    }

    pub fn idx_head(&self) -> usize {
        self.n_params() - 1
    }
}

/// Per-layer activation stash (forward values the backward pass needs).
struct LayerWs {
    xn: Vec<f32>,     // rmsnorm(h) feeding attention        [b*s*d]
    q: Vec<f32>,      // post-rope queries, head layout      [b*nh*s*dh]
    k: Vec<f32>,      // post-rope keys                      [b*nh*s*dh]
    v: Vec<f32>,      // values                              [b*nh*s*dh]
    probs: Vec<f32>,  // attention probabilities             [b*nh*s*s]
    merged: Vec<f32>, // merged attention output, pre-Wo     [b*s*d]
    h_mid: Vec<f32>,  // h after the attention residual      [b*s*d]
    xn2: Vec<f32>,    // rmsnorm(h_mid) feeding the MLP      [b*s*d]
    gate: Vec<f32>,   // gate pre-activation (gpt2: up pre)  [b*s*f]
    up: Vec<f32>,     // up projection (llama only)          [b*s*f]
    act: Vec<f32>,    // MLP activation feeding w_down       [b*s*f]
}

impl LayerWs {
    fn new(bsd: usize, bhss: usize, bsf: usize) -> LayerWs {
        LayerWs {
            xn: vec![0.0; bsd],
            q: vec![0.0; bsd],
            k: vec![0.0; bsd],
            v: vec![0.0; bsd],
            probs: vec![0.0; bhss],
            merged: vec![0.0; bsd],
            h_mid: vec![0.0; bsd],
            xn2: vec![0.0; bsd],
            gate: vec![0.0; bsf],
            up: vec![0.0; bsf],
            act: vec![0.0; bsf],
        }
    }
}

/// The per-program workspace arena: every forward/backward intermediate,
/// sized once for `max_b` sequences and reused for the program's life.
pub(crate) struct ModelWs {
    hs: Vec<Vec<f32>>, // residual stream before each layer (+ final) [b*s*d]
    layers: Vec<LayerWs>,
    hf: Vec<f32>,       // final rmsnorm output                [b*s*d]
    logits: Vec<f32>,   // logits, overwritten by dlogits      [b*s*v]
    att: Vec<f32>,      // attention context, head layout (fwd) [b*nh*s*dh]
    dh_a: Vec<f32>,     // running residual-stream gradient    [b*s*d]
    dh_b: Vec<f32>,     // branch gradient scratch             [b*s*d]
    tmp_d: Vec<f32>,    // flat [b*s, d] GEMM scratch          [b*s*d]
    df1: Vec<f32>,      // MLP gradient scratch                [b*s*f]
    df2: Vec<f32>,      // MLP gradient scratch                [b*s*f]
    datt: Vec<f32>,     // d(merged attention), head layout    [b*nh*s*dh]
    dq: Vec<f32>,       // [b*nh*s*dh]
    dk: Vec<f32>,       // [b*nh*s*dh]
    dv: Vec<f32>,       // [b*nh*s*dh]
    dprobs: Vec<f32>,   // dprobs, rewritten to dscores        [b*nh*s*s]
    rope_cos: Vec<f32>, // [s * dh/2]
    rope_sin: Vec<f32>, // [s * dh/2]
    pack: Vec<f32>,     // GEMM panel buffer
}

impl ModelWs {
    pub fn new(spec: &ModelSpec, max_b: usize) -> ModelWs {
        let (s, d, f, v) = (spec.seq, spec.d, spec.d_ff, spec.vocab);
        let bsd = max_b * s * d;
        let bsf = max_b * s * f;
        let bhss = max_b * spec.n_heads * s * s;
        let (rope_cos, rope_sin) = rope_tables(s, spec.head_dim / 2);
        ModelWs {
            hs: (0..spec.n_layers + 1).map(|_| vec![0.0; bsd]).collect(),
            layers: (0..spec.n_layers).map(|_| LayerWs::new(bsd, bhss, bsf)).collect(),
            hf: vec![0.0; bsd],
            logits: vec![0.0; max_b * s * v],
            att: vec![0.0; bsd],
            dh_a: vec![0.0; bsd],
            dh_b: vec![0.0; bsd],
            tmp_d: vec![0.0; bsd],
            df1: vec![0.0; bsf],
            df2: vec![0.0; bsf],
            datt: vec![0.0; bsd],
            dq: vec![0.0; bsd],
            dk: vec![0.0; bsd],
            dv: vec![0.0; bsd],
            dprobs: vec![0.0; bhss],
            rope_cos,
            rope_sin,
            pack: Vec::with_capacity(d * v.max(f).max(d)),
        }
    }
}

// ---- elementwise building blocks -------------------------------------------

fn rmsnorm_fwd(x: &[f32], gain: &[f32], out: &mut [f32], d: usize) {
    for (xr, or) in x.chunks(d).zip(out.chunks_mut(d)) {
        let mut ms = 0.0f32;
        for &xi in xr {
            ms += xi * xi;
        }
        ms /= d as f32;
        let rr = 1.0 / (ms + NORM_EPS).sqrt();
        for i in 0..d {
            or[i] = xr[i] * rr * gain[i];
        }
    }
}

/// RMSNorm backward: rewrites `dy` into `dx` in place and accumulates
/// the gain gradient (caller zeroes `dgain` first).
fn rmsnorm_bwd(x: &[f32], gain: &[f32], dy: &mut [f32], dgain: &mut [f32], d: usize) {
    for (xr, dyr) in x.chunks(d).zip(dy.chunks_mut(d)) {
        let mut ms = 0.0f32;
        for &xi in xr {
            ms += xi * xi;
        }
        ms /= d as f32;
        let rr = 1.0 / (ms + NORM_EPS).sqrt();
        let mut t1 = 0.0f32;
        for i in 0..d {
            t1 += dyr[i] * gain[i] * xr[i];
        }
        let coef = rr * rr * rr * t1 / d as f32;
        for i in 0..d {
            dgain[i] += dyr[i] * xr[i] * rr;
            dyr[i] = rr * gain[i] * dyr[i] - coef * xr[i];
        }
    }
}

fn gelu(x: f32) -> f32 {
    let u = GELU_C * (x + 0.044715 * x * x * x);
    0.5 * x * (1.0 + u.tanh())
}

fn gelu_grad(x: f32) -> f32 {
    let x2 = x * x;
    let u = GELU_C * (x + 0.044715 * x * x2);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * 0.044715 * x2)
}

/// `[b*s, d]` flat rows -> `[b, nh, s, dh]` head-major layout.
fn split_heads(src: &[f32], dst: &mut [f32], b: usize, s: usize, nh: usize, dh: usize) {
    let d = nh * dh;
    for bi in 0..b {
        for h in 0..nh {
            for t in 0..s {
                let so = (bi * s + t) * d + h * dh;
                let dofs = ((bi * nh + h) * s + t) * dh;
                dst[dofs..dofs + dh].copy_from_slice(&src[so..so + dh]);
            }
        }
    }
}

/// Inverse of [`split_heads`].
fn merge_heads(src: &[f32], dst: &mut [f32], b: usize, s: usize, nh: usize, dh: usize) {
    let d = nh * dh;
    for bi in 0..b {
        for h in 0..nh {
            for t in 0..s {
                let so = ((bi * nh + h) * s + t) * dh;
                let dofs = (bi * s + t) * d + h * dh;
                dst[dofs..dofs + dh].copy_from_slice(&src[so..so + dh]);
            }
        }
    }
}

/// RoPE cos/sin tables for positions `0..s` (the `model.py` frequency
/// schedule). Shared by the training arena and the decode workspace so
/// both rotate with exactly the same table bits.
fn rope_tables(s: usize, half: usize) -> (Vec<f32>, Vec<f32>) {
    let mut cos = vec![0.0f32; s * half];
    let mut sin = vec![0.0f32; s * half];
    for t in 0..s {
        for i in 0..half {
            let freq = 10000f32.powf(-(i as f32) / half as f32);
            let ang = t as f32 * freq;
            cos[t * half + i] = ang.cos();
            sin[t * half + i] = ang.sin();
        }
    }
    (cos, sin)
}

/// Rotate one head row in place by RoPE table row `t` (its absolute
/// position). The per-row body of [`rope_fwd`], shared with the decode
/// path, which rotates rows at positions the training loop never
/// enumerates (`pos0 + i` for a mid-sequence append).
fn rope_row(row: &mut [f32], cos: &[f32], sin: &[f32], t: usize, half: usize) {
    for i in 0..half {
        let (c, sn) = (cos[t * half + i], sin[t * half + i]);
        let (x1, x2) = (row[i], row[i + half]);
        row[i] = x1 * c - x2 * sn;
        row[i + half] = x1 * sn + x2 * c;
    }
}

/// Rotate `x` (head layout, `groups = b*nh`) by the RoPE tables.
fn rope_fwd(x: &mut [f32], cos: &[f32], sin: &[f32], groups: usize, s: usize, dh: usize) {
    let half = dh / 2;
    for g in 0..groups {
        for t in 0..s {
            let off = (g * s + t) * dh;
            rope_row(&mut x[off..off + dh], cos, sin, t, half);
        }
    }
}

/// Transpose of [`rope_fwd`] (rotation by the negated angle).
fn rope_bwd(x: &mut [f32], cos: &[f32], sin: &[f32], groups: usize, s: usize, dh: usize) {
    let half = dh / 2;
    for g in 0..groups {
        for t in 0..s {
            let off = (g * s + t) * dh;
            let row = &mut x[off..off + dh];
            for i in 0..half {
                let (c, sn) = (cos[t * half + i], sin[t * half + i]);
                let (y1, y2) = (row[i], row[i + half]);
                row[i] = y1 * c + y2 * sn;
                row[i + half] = -y1 * sn + y2 * c;
            }
        }
    }
}

// ---- attention pair kernels ------------------------------------------------
//
// One (batch, head) pair is the unit of attention parallelism: its
// probability rows, context rows, and gradient rows are contiguous
// disjoint slices of the head-layout buffers, so pairs fan out to the
// worker pool with no locks and no aliasing, and each pair's float
// sequence is the sequential code verbatim — the parallel and inline
// paths are bit-identical for every pool size (property-tested below).

/// Generalized attention forward for one (batch, head) pair over an
/// `s_q × s_kv` shape: query row `i` sits at absolute position
/// `pos0 + i` and attends keys `0..=pos0 + i` of the `s_kv`-row K/V
/// block; `p_bh` is `[s_q, s_kv]` with the invisible tail zeroed. The
/// training shape is the special case `s_q == s_kv, pos0 == 0`
/// ([`attn_pair_fwd`]). Each query row's float sequence is a function
/// of its absolute position and the K/V prefix alone — never of `s_q`
/// — which is what makes incremental decode bit-identical to the full
/// forward (see [`extend`]).
#[allow(clippy::too_many_arguments)]
fn attn_pair_fwd_ext(
    q_bh: &[f32],
    k_bh: &[f32],
    v_bh: &[f32],
    p_bh: &mut [f32],
    a_bh: &mut [f32],
    s_q: usize,
    s_kv: usize,
    pos0: usize,
    dh: usize,
    inv: f32,
) {
    for i in 0..s_q {
        let lim = pos0 + i; // last visible key index for this query row
        let qi = &q_bh[i * dh..(i + 1) * dh];
        let row = &mut p_bh[i * s_kv..(i + 1) * s_kv];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..=lim {
            let sc = dot(qi, &k_bh[j * dh..(j + 1) * dh]) * inv;
            row[j] = sc;
            if sc > mx {
                mx = sc;
            }
        }
        let mut sum = 0.0f32;
        for rj in row.iter_mut().take(lim + 1) {
            let e = (*rj - mx).exp();
            *rj = e;
            sum += e;
        }
        let isum = 1.0 / sum;
        for rj in row.iter_mut().take(lim + 1) {
            *rj *= isum;
        }
        for rj in row.iter_mut().take(s_kv).skip(lim + 1) {
            *rj = 0.0;
        }
    }
    for i in 0..s_q {
        let lim = pos0 + i;
        let orow = &mut a_bh[i * dh..(i + 1) * dh];
        orow.fill(0.0);
        for j in 0..=lim {
            axpy(orow, p_bh[i * s_kv + j], &v_bh[j * dh..(j + 1) * dh]);
        }
    }
}

/// Forward for one (batch, head) pair: causal `softmax(q·kᵀ/√dh)` into
/// `p_bh` (`[s, s]`, upper triangle zeroed) and the context `probs · v`
/// into `a_bh` (`[s, dh]`, head layout). The training-shape instance of
/// [`attn_pair_fwd_ext`] — same loops, same bits.
fn attn_pair_fwd(
    q_bh: &[f32],
    k_bh: &[f32],
    v_bh: &[f32],
    p_bh: &mut [f32],
    a_bh: &mut [f32],
    s: usize,
    dh: usize,
    inv: f32,
) {
    attn_pair_fwd_ext(q_bh, k_bh, v_bh, p_bh, a_bh, s, s, 0, dh, inv);
}

/// Backward for one (batch, head) pair: rewrites `dp` from d(probs) to
/// d(scores) (softmax backward, rescaled and causally masked in one
/// sweep) and writes `dq/dk/dv` for the pair. The small per-pair matmuls
/// go through [`matmul_tn_seq`] — this function runs *inside* pool
/// tasks, so it must never touch the queue itself.
#[allow(clippy::too_many_arguments)]
fn attn_pair_bwd(
    q_bh: &[f32],
    k_bh: &[f32],
    v_bh: &[f32],
    p_bh: &[f32],
    da_bh: &[f32],
    dp: &mut [f32],
    dq_bh: &mut [f32],
    dk_bh: &mut [f32],
    dv_bh: &mut [f32],
    s: usize,
    dh: usize,
    inv: f32,
) {
    for i in 0..s {
        let da_row = &da_bh[i * dh..(i + 1) * dh];
        let p_row = &p_bh[i * s..(i + 1) * s];
        let dp_row = &mut dp[i * s..(i + 1) * s];
        for j in 0..=i {
            dp_row[j] = dot(da_row, &v_bh[j * dh..(j + 1) * dh]);
        }
        let mut tsum = 0.0f32;
        for j in 0..=i {
            tsum += p_row[j] * dp_row[j];
        }
        for j in 0..=i {
            dp_row[j] = p_row[j] * (dp_row[j] - tsum) * inv;
        }
        for dj in dp_row.iter_mut().take(s).skip(i + 1) {
            *dj = 0.0;
        }
    }
    matmul_tn_seq(p_bh, da_bh, dv_bh, s, s, dh);
    for i in 0..s {
        let row = &mut dq_bh[i * dh..(i + 1) * dh];
        row.fill(0.0);
        for j in 0..=i {
            axpy(row, dp[i * s + j], &k_bh[j * dh..(j + 1) * dh]);
        }
    }
    matmul_tn_seq(dp, q_bh, dk_bh, s, s, dh);
}

/// Every (batch, head) forward for one layer: `probs` and `att` are the
/// pair-major buffers (`pairs * s*s` / `pairs * s*dh`), carved into
/// per-pair slices. Above the `min_ops` gate, pairs are grouped into
/// `tile_width` blocks and dispatched as disjoint pool tasks.
#[allow(clippy::too_many_arguments)]
fn attn_pairs_fwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &mut [f32],
    att: &mut [f32],
    pairs: usize,
    s: usize,
    dh: usize,
    pool: &WorkerPool,
    min_ops: usize,
) {
    let (ss, sd) = (s * s, s * dh);
    let inv = 1.0 / (dh as f32).sqrt();
    if !attn_pairs_parallel(pool, min_ops, pairs, s, dh) {
        for (bh, (p_bh, a_bh)) in probs.chunks_mut(ss).zip(att.chunks_mut(sd)).enumerate() {
            let o = bh * sd;
            let (q_bh, k_bh, v_bh) = (&q[o..o + sd], &k[o..o + sd], &v[o..o + sd]);
            attn_pair_fwd(q_bh, k_bh, v_bh, p_bh, a_bh, s, dh, inv);
        }
        return;
    }
    let pb = tile_width(pairs, pool.parallelism());
    let mut tasks = Vec::new();
    let blocks = probs.chunks_mut(pb * ss).zip(att.chunks_mut(pb * sd));
    for (ti, (p_blk, a_blk)) in blocks.enumerate() {
        tasks.push(move || {
            let pair_slices = p_blk.chunks_mut(ss).zip(a_blk.chunks_mut(sd));
            for (i, (p_bh, a_bh)) in pair_slices.enumerate() {
                let o = (ti * pb + i) * sd;
                let (q_bh, k_bh, v_bh) = (&q[o..o + sd], &k[o..o + sd], &v[o..o + sd]);
                attn_pair_fwd(q_bh, k_bh, v_bh, p_bh, a_bh, s, dh, inv);
            }
        });
    }
    pool.run(tasks);
}

/// Sequential run of one contiguous block of backward pairs (`base` is
/// the first pair's index): the shared body of both dispatch paths in
/// [`attn_pairs_bwd`].
#[allow(clippy::too_many_arguments)]
fn attn_pair_bwd_block(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    datt: &[f32],
    dp_blk: &mut [f32],
    dq_blk: &mut [f32],
    dk_blk: &mut [f32],
    dv_blk: &mut [f32],
    base: usize,
    s: usize,
    dh: usize,
) {
    let (ss, sd) = (s * s, s * dh);
    let inv = 1.0 / (dh as f32).sqrt();
    let n = dp_blk.len() / ss;
    for i in 0..n {
        let bh = base + i;
        let (po, so) = (bh * ss, bh * sd);
        attn_pair_bwd(
            &q[so..so + sd],
            &k[so..so + sd],
            &v[so..so + sd],
            &probs[po..po + ss],
            &datt[so..so + sd],
            &mut dp_blk[i * ss..(i + 1) * ss],
            &mut dq_blk[i * sd..(i + 1) * sd],
            &mut dk_blk[i * sd..(i + 1) * sd],
            &mut dv_blk[i * sd..(i + 1) * sd],
            s,
            dh,
            inv,
        );
    }
}

/// Every (batch, head) backward for one layer: reads the stashed
/// `probs`/`q`/`k`/`v` and the incoming `datt`, writes the pair-major
/// `dprobs`/`dq`/`dk`/`dv`. Same dispatch shape as [`attn_pairs_fwd`]:
/// pair blocks are disjoint `chunks_mut` slices, one pool task each.
#[allow(clippy::too_many_arguments)]
fn attn_pairs_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    datt: &[f32],
    dprobs: &mut [f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    pairs: usize,
    s: usize,
    dh: usize,
    pool: &WorkerPool,
    min_ops: usize,
) {
    let (ss, sd) = (s * s, s * dh);
    if !attn_pairs_parallel(pool, min_ops, pairs, s, dh) {
        attn_pair_bwd_block(q, k, v, probs, datt, dprobs, dq, dk, dv, 0, s, dh);
        return;
    }
    let pb = tile_width(pairs, pool.parallelism());
    let mut tasks = Vec::new();
    let dkv = dk.chunks_mut(pb * sd).zip(dv.chunks_mut(pb * sd));
    let grads = dq.chunks_mut(pb * sd).zip(dkv);
    let blocks = dprobs.chunks_mut(pb * ss).zip(grads);
    for (ti, (dp_blk, (dq_blk, (dk_blk, dv_blk)))) in blocks.enumerate() {
        tasks.push(move || {
            let base = ti * pb;
            attn_pair_bwd_block(q, k, v, probs, datt, dp_blk, dq_blk, dk_blk, dv_blk, base, s, dh);
        });
    }
    pool.run(tasks);
}

/// Mean next-token cross-entropy over the logits (nats).
fn xent_loss(logits: &[f32], toks: &[i32], b: usize, s: usize, v: usize) -> f32 {
    let mut total = 0.0f64;
    for bi in 0..b {
        for t in 0..s {
            let row = &logits[(bi * s + t) * v..(bi * s + t + 1) * v];
            let tg = toks[bi * (s + 1) + t + 1] as usize;
            let mut mx = row[0];
            for &x in row {
                if x > mx {
                    mx = x;
                }
            }
            let mut sum = 0.0f32;
            for &x in row {
                sum += (x - mx).exp();
            }
            let lse = mx + sum.ln();
            total += (lse - row[tg]) as f64;
        }
    }
    (total / (b * s) as f64) as f32
}

/// Fused loss + backward: same accumulation order as [`xent_loss`]
/// (their results are bit-identical), then rewrites the logits buffer
/// into `dlogits = (softmax - onehot) / (b*s)` in place.
fn xent_loss_bwd(logits: &mut [f32], toks: &[i32], b: usize, s: usize, v: usize) -> f32 {
    let inv_n = 1.0 / (b * s) as f32;
    let mut total = 0.0f64;
    for bi in 0..b {
        for t in 0..s {
            let row = &mut logits[(bi * s + t) * v..(bi * s + t + 1) * v];
            let tg = toks[bi * (s + 1) + t + 1] as usize;
            let mut mx = row[0];
            for &x in row.iter() {
                if x > mx {
                    mx = x;
                }
            }
            let mut sum = 0.0f32;
            for &x in row.iter() {
                sum += (x - mx).exp();
            }
            let lse = mx + sum.ln();
            total += (lse - row[tg]) as f64;
            for x in row.iter_mut() {
                *x = (*x - lse).exp() * inv_n;
            }
            row[tg] -= inv_n;
        }
    }
    (total / (b * s) as f64) as f32
}

// ---- forward ---------------------------------------------------------------

/// Run the forward pass, leaving logits and all per-layer stashes in
/// `ws`. `toks` is the `[b, s+1]` token batch flattened.
fn forward(
    spec: &ModelSpec,
    params: &[&Tensor],
    toks: &[i32],
    b: usize,
    ws: &mut ModelWs,
    pool: &WorkerPool,
    min_ops: usize,
) {
    let (s, d, v) = (spec.seq, spec.d, spec.vocab);
    let bs = b * s;
    let bsd = bs * d;
    assert_eq!(toks.len(), b * (s + 1));

    let ModelWs { hs, layers, hf, logits, tmp_d, att, rope_cos, rope_sin, pack, .. } = ws;
    let (cos, sin) = (rope_cos.as_slice(), rope_sin.as_slice());

    // token embedding (+ learned positions for gpt2)
    {
        let embed = params[0].f32s();
        let h0 = &mut hs[0][..bsd];
        for bi in 0..b {
            for t in 0..s {
                let id = toks[bi * (s + 1) + t] as usize;
                let dst = (bi * s + t) * d;
                h0[dst..dst + d].copy_from_slice(&embed[id * d..(id + 1) * d]);
            }
        }
        if spec.gpt2 {
            let pos = params[1].f32s();
            for bi in 0..b {
                for t in 0..s {
                    let row = &mut h0[(bi * s + t) * d..(bi * s + t + 1) * d];
                    for (hv, pv) in row.iter_mut().zip(&pos[t * d..(t + 1) * d]) {
                        *hv += pv;
                    }
                }
            }
        }
    }

    for l in 0..spec.n_layers {
        let (lo, hi) = hs.split_at_mut(l + 1);
        let x = &lo[l][..bsd];
        let hn = &mut hi[0][..bsd];
        let lw = &mut layers[l];
        layer_forward(spec, params, l, x, hn, lw, tmp_d, att, pack, cos, sin, b, pool, min_ops);
    }

    let x = &hs[spec.n_layers][..bsd];
    rmsnorm_fwd(x, params[spec.idx_final_norm()].f32s(), &mut hf[..bsd], d);
    let w_head = params[spec.idx_head()].f32s();
    matmul_nn(pool, min_ops, &hf[..bsd], w_head, &mut logits[..bs * v], bs, d, v, pack);
}

#[allow(clippy::too_many_arguments)]
fn layer_forward(
    spec: &ModelSpec,
    params: &[&Tensor],
    l: usize,
    x: &[f32],
    h_next: &mut [f32],
    lw: &mut LayerWs,
    tmp_d: &mut [f32],
    att: &mut [f32],
    pack: &mut Vec<f32>,
    rope_cos: &[f32],
    rope_sin: &[f32],
    b: usize,
    pool: &WorkerPool,
    min_ops: usize,
) {
    let (s, d, f) = (spec.seq, spec.d, spec.d_ff);
    let (nh, dh) = (spec.n_heads, spec.head_dim);
    let bs = b * s;
    let bsd = bs * d;
    let bsf = bs * f;
    let LayerWs { xn, q, k, v, probs, merged, h_mid, xn2, gate, up, act } = lw;
    let tmp = &mut tmp_d[..bsd];

    // attention branch
    rmsnorm_fwd(x, params[spec.p_attn_norm(l)].f32s(), &mut xn[..bsd], d);
    for (w_idx, dst) in [
        (spec.p_wq(l), &mut *q),
        (spec.p_wk(l), &mut *k),
        (spec.p_wv(l), &mut *v),
    ] {
        matmul_nn(pool, min_ops, &xn[..bsd], params[w_idx].f32s(), tmp, bs, d, d, pack);
        split_heads(tmp, &mut dst[..bsd], b, s, nh, dh);
    }
    if !spec.gpt2 {
        rope_fwd(&mut q[..bsd], rope_cos, rope_sin, b * nh, s, dh);
        rope_fwd(&mut k[..bsd], rope_cos, rope_sin, b * nh, s, dh);
    }
    let att = &mut att[..bsd];
    let bhss = b * nh * s * s;
    attn_pairs_fwd(
        &q[..bsd], &k[..bsd], &v[..bsd], &mut probs[..bhss], att, b * nh, s, dh, pool, min_ops,
    );
    merge_heads(att, &mut merged[..bsd], b, s, nh, dh);
    let wo = params[spec.p_wo(l)].f32s();
    matmul_nn(pool, min_ops, &merged[..bsd], wo, tmp, bs, d, d, pack);
    for i in 0..bsd {
        h_mid[i] = x[i] + tmp[i];
    }

    // MLP branch
    rmsnorm_fwd(&h_mid[..bsd], params[spec.p_mlp_norm(l)].f32s(), &mut xn2[..bsd], d);
    if spec.gpt2 {
        let wu = params[spec.p_wup(l)].f32s();
        matmul_nn(pool, min_ops, &xn2[..bsd], wu, &mut gate[..bsf], bs, d, f, pack);
        for i in 0..bsf {
            act[i] = gelu(gate[i]);
        }
    } else {
        let wg = params[spec.p_wgate(l)].f32s();
        let wu = params[spec.p_wup(l)].f32s();
        matmul_nn(pool, min_ops, &xn2[..bsd], wg, &mut gate[..bsf], bs, d, f, pack);
        matmul_nn(pool, min_ops, &xn2[..bsd], wu, &mut up[..bsf], bs, d, f, pack);
        for i in 0..bsf {
            let a = gate[i];
            let sg = a / (1.0 + (-a).exp()); // silu
            act[i] = sg * up[i];
        }
    }
    let wd = params[spec.p_wdown(l)].f32s();
    matmul_nn(pool, min_ops, &act[..bsf], wd, tmp, bs, f, d, pack);
    for i in 0..bsd {
        h_next[i] = h_mid[i] + tmp[i];
    }
}

// ---- incremental decode ----------------------------------------------------
//
// Serving reuses the training kernels unchanged. The gemm module's
// per-element reduction rule (each output element's dot over k is a
// fixed 8-lane sequence, independent of m, tiling, or pool size) means
// an m=1 decode GEMM row is bit-identical to the same row of a full
// `[s, d]` forward; rmsnorm/silu/gelu/rope are per-row; and
// `attn_pair_fwd_ext` makes each query row's float sequence a function
// of its absolute position and the K/V prefix alone. Stacking those
// invariants layer by layer gives the decode contract
// `rust/tests/serve_differential.rs` enforces: logits at position t
// computed from the KV cache == logits row t of the training forward
// over the full prefix, bit for bit, for every pool size and
// threshold.

/// Per-sequence KV cache: one pool-owned slab holding every layer's
/// keys and values in head-major rows, `offset(l, h, t) =
/// ((l*nh + h)*max_seq + t)*dh`, so the visible prefix for one
/// (layer, head) is a single contiguous slice. Sized once for the
/// model's context length and reused across requests via
/// [`KvCache::reset`].
pub(crate) struct KvCache {
    k: Vec<f32>,
    v: Vec<f32>,
    max_seq: usize,
    len: usize,
}

impl KvCache {
    pub fn new(spec: &ModelSpec) -> KvCache {
        let n = spec.n_layers * spec.n_heads * spec.seq * spec.head_dim;
        KvCache { k: vec![0.0; n], v: vec![0.0; n], max_seq: spec.seq, len: 0 }
    }

    /// Tokens currently cached (== the next token's absolute position).
    pub fn pos(&self) -> usize {
        self.len
    }

    /// Forget the cached sequence; the slab is reused as-is (stale rows
    /// past `len` are never read).
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Scatter `[n, d]` projection rows into per-(layer, head) cache
    /// rows `pos0..pos0+n` (keys when `dst_k`, else values).
    fn store(&mut self, dst_k: bool, l: usize, pos0: usize, rows: &[f32], nh: usize, dh: usize) {
        let d = nh * dh;
        let n = rows.len() / d;
        let dst = if dst_k { &mut self.k } else { &mut self.v };
        for h in 0..nh {
            for i in 0..n {
                let o = ((l * nh + h) * self.max_seq + pos0 + i) * dh;
                dst[o..o + dh].copy_from_slice(&rows[i * d + h * dh..][..dh]);
            }
        }
    }

    /// Rotate the newly stored key rows at their absolute positions.
    fn rope_keys(
        &mut self,
        l: usize,
        pos0: usize,
        n: usize,
        cos: &[f32],
        sin: &[f32],
        nh: usize,
        dh: usize,
    ) {
        let half = dh / 2;
        for h in 0..nh {
            for i in 0..n {
                let o = ((l * nh + h) * self.max_seq + pos0 + i) * dh;
                rope_row(&mut self.k[o..o + dh], cos, sin, pos0 + i, half);
            }
        }
    }

    /// The visible `[s_kv, dh]` prefix for one (layer, head).
    fn head(&self, of_k: bool, l: usize, h: usize, nh: usize, s_kv: usize, dh: usize) -> &[f32] {
        let o = (l * nh + h) * self.max_seq * dh;
        let src = if of_k { &self.k } else { &self.v };
        &src[o..o + s_kv * dh]
    }
}

/// Decode workspace: every intermediate for one [`extend`] call, sized
/// once for the model's full context (so a whole-prompt prefill fits)
/// and reused for the slab's life — steady-state decode performs zero
/// heap allocations (gated in `benches/bench_throughput.rs`).
pub(crate) struct DecodeWs {
    h: Vec<f32>,          // residual stream                  [s*d]
    xn: Vec<f32>,         // rmsnorm scratch                  [s*d]
    tmp: Vec<f32>,        // flat GEMM scratch                [s*d]
    qh: Vec<f32>,         // queries, head layout             [nh*s*dh]
    att: Vec<f32>,        // attention context, head layout   [nh*s*dh]
    probs: Vec<f32>,      // attention probabilities          [nh*s*s]
    merged: Vec<f32>,     // merged context, pre-Wo           [s*d]
    h_mid: Vec<f32>,      // post-attention residual          [s*d]
    xn2: Vec<f32>,        // MLP rmsnorm scratch              [s*d]
    gate: Vec<f32>,       // gate pre-activation (gpt2: up)   [s*f]
    up: Vec<f32>,         // up projection (llama only)       [s*f]
    act: Vec<f32>,        // MLP activation                   [s*f]
    hf: Vec<f32>,         // final rmsnorm of the last row    [d]
    pub logits: Vec<f32>, // last-position logits             [v]
    rope_cos: Vec<f32>,   // [s * dh/2]
    rope_sin: Vec<f32>,
    pack: Vec<f32>,       // GEMM panel buffer
    pub order: Vec<u32>,  // sampler scratch: sorted vocab ids
    pub cdf: Vec<f64>,    // sampler scratch: cumulative weights
}

impl DecodeWs {
    pub fn new(spec: &ModelSpec) -> DecodeWs {
        let (s, d, f, v) = (spec.seq, spec.d, spec.d_ff, spec.vocab);
        let (sd, sf) = (s * d, s * f);
        let (rope_cos, rope_sin) = rope_tables(s, spec.head_dim / 2);
        DecodeWs {
            h: vec![0.0; sd],
            xn: vec![0.0; sd],
            tmp: vec![0.0; sd],
            qh: vec![0.0; sd],
            att: vec![0.0; sd],
            probs: vec![0.0; spec.n_heads * s * s],
            merged: vec![0.0; sd],
            h_mid: vec![0.0; sd],
            xn2: vec![0.0; sd],
            gate: vec![0.0; sf],
            up: vec![0.0; sf],
            act: vec![0.0; sf],
            hf: vec![0.0; d],
            logits: vec![0.0; v],
            rope_cos,
            rope_sin,
            pack: Vec::with_capacity(d * v.max(f).max(d)),
            order: Vec::with_capacity(v),
            cdf: Vec::with_capacity(v),
        }
    }
}

/// Append `toks` to the cached sequence and leave the logits for the
/// last appended position in `ws.logits`. Prefill is `extend` over the
/// whole prompt; decode is `extend` over one token — both produce, at
/// every position, the exact logits bits of the training forward over
/// the same prefix (see the section comment above).
pub(crate) fn extend(
    spec: &ModelSpec,
    params: &[Tensor],
    toks: &[i32],
    cache: &mut KvCache,
    ws: &mut DecodeWs,
    pool: &WorkerPool,
    min_ops: usize,
) {
    let n = toks.len();
    let pos0 = cache.len;
    assert!(n >= 1, "extend needs at least one token");
    assert!(pos0 + n <= cache.max_seq, "kv cache overflow: {pos0}+{n} > {}", cache.max_seq);
    let (d, f, v) = (spec.d, spec.d_ff, spec.vocab);
    let (nh, dh) = (spec.n_heads, spec.head_dim);
    let s_kv = pos0 + n;
    let nd = n * d;
    let nf = n * f;

    let DecodeWs {
        h,
        xn,
        tmp,
        qh,
        att,
        probs,
        merged,
        h_mid,
        xn2,
        gate,
        up,
        act,
        hf,
        logits,
        rope_cos,
        rope_sin,
        pack,
        ..
    } = ws;

    // token embedding (+ learned positions for gpt2) at absolute positions
    {
        let embed = params[0].f32s();
        for (i, &tk) in toks.iter().enumerate() {
            let id = tk as usize;
            h[i * d..(i + 1) * d].copy_from_slice(&embed[id * d..(id + 1) * d]);
        }
        if spec.gpt2 {
            let pos = params[1].f32s();
            for i in 0..n {
                let row = &mut h[i * d..(i + 1) * d];
                let pr = &pos[(pos0 + i) * d..(pos0 + i + 1) * d];
                for (hv, pv) in row.iter_mut().zip(pr) {
                    *hv += pv;
                }
            }
        }
    }

    let inv = 1.0 / (dh as f32).sqrt();
    let half = dh / 2;
    for l in 0..spec.n_layers {
        // attention branch: queries stay local, keys/values land in the cache
        rmsnorm_fwd(&h[..nd], params[spec.p_attn_norm(l)].f32s(), &mut xn[..nd], d);
        let wq = params[spec.p_wq(l)].f32s();
        matmul_nn(pool, min_ops, &xn[..nd], wq, &mut tmp[..nd], n, d, d, pack);
        split_heads(&tmp[..nd], &mut qh[..nd], 1, n, nh, dh);
        let wk = params[spec.p_wk(l)].f32s();
        matmul_nn(pool, min_ops, &xn[..nd], wk, &mut tmp[..nd], n, d, d, pack);
        cache.store(true, l, pos0, &tmp[..nd], nh, dh);
        let wv = params[spec.p_wv(l)].f32s();
        matmul_nn(pool, min_ops, &xn[..nd], wv, &mut tmp[..nd], n, d, d, pack);
        cache.store(false, l, pos0, &tmp[..nd], nh, dh);
        if !spec.gpt2 {
            for g in 0..nh {
                for i in 0..n {
                    let off = (g * n + i) * dh;
                    rope_row(&mut qh[off..off + dh], rope_cos, rope_sin, pos0 + i, half);
                }
            }
            cache.rope_keys(l, pos0, n, rope_cos, rope_sin, nh, dh);
        }
        for hd in 0..nh {
            let k_bh = cache.head(true, l, hd, nh, s_kv, dh);
            let v_bh = cache.head(false, l, hd, nh, s_kv, dh);
            let q_bh = &qh[hd * n * dh..(hd + 1) * n * dh];
            let p_bh = &mut probs[hd * n * s_kv..(hd + 1) * n * s_kv];
            let a_bh = &mut att[hd * n * dh..(hd + 1) * n * dh];
            attn_pair_fwd_ext(q_bh, k_bh, v_bh, p_bh, a_bh, n, s_kv, pos0, dh, inv);
        }
        merge_heads(&att[..nd], &mut merged[..nd], 1, n, nh, dh);
        let wo = params[spec.p_wo(l)].f32s();
        matmul_nn(pool, min_ops, &merged[..nd], wo, &mut tmp[..nd], n, d, d, pack);
        for i in 0..nd {
            h_mid[i] = h[i] + tmp[i];
        }

        // MLP branch
        rmsnorm_fwd(&h_mid[..nd], params[spec.p_mlp_norm(l)].f32s(), &mut xn2[..nd], d);
        if spec.gpt2 {
            let wu = params[spec.p_wup(l)].f32s();
            matmul_nn(pool, min_ops, &xn2[..nd], wu, &mut gate[..nf], n, d, f, pack);
            for i in 0..nf {
                act[i] = gelu(gate[i]);
            }
        } else {
            let wg = params[spec.p_wgate(l)].f32s();
            let wu = params[spec.p_wup(l)].f32s();
            matmul_nn(pool, min_ops, &xn2[..nd], wg, &mut gate[..nf], n, d, f, pack);
            matmul_nn(pool, min_ops, &xn2[..nd], wu, &mut up[..nf], n, d, f, pack);
            for i in 0..nf {
                let a = gate[i];
                let sg = a / (1.0 + (-a).exp()); // silu
                act[i] = sg * up[i];
            }
        }
        let wd = params[spec.p_wdown(l)].f32s();
        matmul_nn(pool, min_ops, &act[..nf], wd, &mut tmp[..nd], n, f, d, pack);
        for i in 0..nd {
            h[i] = h_mid[i] + tmp[i];
        }
    }

    // final norm + LM head over the last appended row only
    rmsnorm_fwd(&h[(n - 1) * d..nd], params[spec.idx_final_norm()].f32s(), &mut hf[..d], d);
    let w_head = params[spec.idx_head()].f32s();
    matmul_nn(pool, min_ops, &hf[..d], w_head, &mut logits[..v], 1, d, v, pack);
    cache.len = s_kv;
}

/// Full-forward logits oracle: run the *training* forward over one
/// `[1, len]` prefix and return all `len * vocab` logits rows. The
/// reference side of the decode differential; it allocates its own
/// arena, so it is never a steady-state path.
pub(crate) fn forward_logits(
    spec: &ModelSpec,
    params: &[Tensor],
    prefix: &[i32],
    pool: &WorkerPool,
    min_ops: usize,
) -> Vec<f32> {
    assert!(!prefix.is_empty() && prefix.len() <= spec.seq, "oracle prefix out of range");
    let mut sp = spec.clone();
    sp.seq = prefix.len();
    let mut toks = prefix.to_vec();
    toks.push(0); // target slot: forward embeds rows 0..len only
    let refs: Vec<&Tensor> = params.iter().collect();
    let mut ws = ModelWs::new(&sp, 1);
    forward(&sp, &refs, &toks, 1, &mut ws, pool, min_ops);
    ws.logits[..prefix.len() * spec.vocab].to_vec()
}

/// Sampling controls for one sequence: `temperature == 0` selects
/// greedy (exact argmax, lowest index on ties); `top_k == 0` and
/// `top_p >= 1` disable those filters.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SampleCfg {
    pub temperature: f32,
    pub top_k: usize,
    pub top_p: f64,
}

/// Draw one token from a logits row. All arithmetic is sequential f64
/// over an index-tie-broken descending sort, so the result is a pure
/// function of (logits bits, cfg, rng state): pool sizes and batch-slot
/// position cannot perturb it. `order`/`cdf` are caller-owned scratch
/// (capacity `vocab`, cleared and refilled, never regrown) so
/// steady-state decode stays allocation-free — `sort_unstable_by`
/// sorts in place without a heap buffer.
pub(crate) fn sample_logits(
    logits: &[f32],
    cfg: &SampleCfg,
    rng: &mut Pcg,
    order: &mut Vec<u32>,
    cdf: &mut Vec<f64>,
) -> usize {
    if cfg.temperature == 0.0 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        return best;
    }
    order.clear();
    order.extend(0..logits.len() as u32);
    order.sort_unstable_by(|&a, &b| {
        let (la, lb) = (logits[a as usize], logits[b as usize]);
        lb.partial_cmp(&la).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let mut keep = order.len();
    if cfg.top_k > 0 {
        keep = keep.min(cfg.top_k);
    }
    let t = cfg.temperature as f64;
    let mx = logits[order[0] as usize] as f64;
    cdf.clear();
    let mut total = 0.0f64;
    for &id in order[..keep].iter() {
        total += ((logits[id as usize] as f64 - mx) / t).exp();
        cdf.push(total);
    }
    if cfg.top_p < 1.0 {
        // nucleus: smallest sorted prefix with mass >= top_p (always >= 1 token)
        let cut = total * cfg.top_p;
        let mut kp = 1;
        while kp < keep && cdf[kp - 1] < cut {
            kp += 1;
        }
        keep = kp;
        total = cdf[keep - 1];
    }
    let u = rng.next_f64() * total;
    let mut pick = keep - 1;
    for (j, &c) in cdf[..keep].iter().enumerate() {
        if c > u {
            pick = j;
            break;
        }
    }
    order[pick] as usize
}

// ---- entry points ----------------------------------------------------------

/// Forward-only loss (the `eval_<size>` artifact semantics).
pub(crate) fn eval_loss(
    spec: &ModelSpec,
    params: &[&Tensor],
    toks: &[i32],
    b: usize,
    ws: &mut ModelWs,
    pool: &WorkerPool,
    min_ops: usize,
) -> f32 {
    forward(spec, params, toks, b, ws, pool, min_ops);
    let (s, v) = (spec.seq, spec.vocab);
    xent_loss(&ws.logits[..b * s * v], toks, b, s, v)
}

/// Forward + backward (the `fwd_bwd_<size>` artifact semantics): returns
/// the loss and writes every parameter gradient into `grads` (same order
/// and shapes as the parameters; previous contents are overwritten).
#[allow(clippy::too_many_arguments)]
pub(crate) fn fwd_bwd(
    spec: &ModelSpec,
    params: &[&Tensor],
    toks: &[i32],
    b: usize,
    grads: &mut [Tensor],
    ws: &mut ModelWs,
    pool: &WorkerPool,
    min_ops: usize,
) -> f32 {
    forward(spec, params, toks, b, ws, pool, min_ops);
    let (s, d, v) = (spec.seq, spec.d, spec.vocab);
    let bs = b * s;
    let bsd = bs * d;
    assert_eq!(grads.len(), spec.n_params());

    let ModelWs {
        hs,
        layers,
        hf,
        logits,
        dh_a,
        dh_b,
        tmp_d,
        df1,
        df2,
        datt,
        dq,
        dk,
        dv,
        dprobs,
        rope_cos,
        rope_sin,
        pack,
        ..
    } = ws;

    let loss = xent_loss_bwd(&mut logits[..bs * v], toks, b, s, v);
    let dlog = &logits[..bs * v];

    // LM head + final norm
    matmul_tn(pool, min_ops, &hf[..bsd], dlog, grads[spec.idx_head()].f32s_mut(), d, bs, v);
    let w_head = params[spec.idx_head()].f32s();
    matmul_nt(pool, min_ops, dlog, w_head, &mut dh_a[..bsd], bs, v, d, false);
    {
        let g_final = params[spec.idx_final_norm()].f32s();
        let dgain = grads[spec.idx_final_norm()].f32s_mut();
        dgain.fill(0.0);
        rmsnorm_bwd(&hs[spec.n_layers][..bsd], g_final, &mut dh_a[..bsd], dgain, d);
    }

    for l in (0..spec.n_layers).rev() {
        layer_backward(
            spec,
            params,
            l,
            hs,
            &mut layers[l],
            grads,
            dh_a,
            dh_b,
            tmp_d,
            df1,
            df2,
            datt,
            dq,
            dk,
            dv,
            dprobs,
            rope_cos,
            rope_sin,
            pack,
            b,
            pool,
            min_ops,
        );
    }

    // embedding (+ positional) gradients: ordered scatter-add
    {
        let ge = grads[0].f32s_mut();
        ge.fill(0.0);
        let dh0 = &dh_a[..bsd];
        for bi in 0..b {
            for t in 0..s {
                let id = toks[bi * (s + 1) + t] as usize;
                axpy(&mut ge[id * d..(id + 1) * d], 1.0, &dh0[(bi * s + t) * d..][..d]);
            }
        }
    }
    if spec.gpt2 {
        let gp = grads[1].f32s_mut();
        gp.fill(0.0);
        let dh0 = &dh_a[..bsd];
        for bi in 0..b {
            for t in 0..s {
                axpy(&mut gp[t * d..(t + 1) * d], 1.0, &dh0[(bi * s + t) * d..][..d]);
            }
        }
    }
    loss
}

#[allow(clippy::too_many_arguments)]
fn layer_backward(
    spec: &ModelSpec,
    params: &[&Tensor],
    l: usize,
    hs: &[Vec<f32>],
    lw: &mut LayerWs,
    grads: &mut [Tensor],
    dh_a: &mut [f32],
    dh_b: &mut [f32],
    tmp_d: &mut [f32],
    df1: &mut [f32],
    df2: &mut [f32],
    datt: &mut [f32],
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    dprobs: &mut [f32],
    rope_cos: &[f32],
    rope_sin: &[f32],
    pack: &mut Vec<f32>,
    b: usize,
    pool: &WorkerPool,
    min_ops: usize,
) {
    let (s, d, f) = (spec.seq, spec.d, spec.d_ff);
    let (nh, dh) = (spec.n_heads, spec.head_dim);
    let bs = b * s;
    let bsd = bs * d;
    let bsf = bs * f;
    let LayerWs { xn, q, k, v, probs, merged, h_mid, xn2, gate, up, act } = lw;
    let x = &hs[l][..bsd];

    // ---- MLP backward (dh_a holds dL/d h_next on entry) ----
    let wd = params[spec.p_wdown(l)].f32s();
    matmul_nt(pool, min_ops, &dh_a[..bsd], wd, &mut df1[..bsf], bs, d, f, false);
    let gw = grads[spec.p_wdown(l)].f32s_mut();
    matmul_tn(pool, min_ops, &act[..bsf], &dh_a[..bsd], gw, f, bs, d);
    if spec.gpt2 {
        for i in 0..bsf {
            df1[i] *= gelu_grad(gate[i]);
        }
        let wu = params[spec.p_wup(l)].f32s();
        let gw = grads[spec.p_wup(l)].f32s_mut();
        matmul_tn(pool, min_ops, &xn2[..bsd], &df1[..bsf], gw, d, bs, f);
        matmul_nt(pool, min_ops, &df1[..bsf], wu, &mut dh_b[..bsd], bs, f, d, false);
    } else {
        for i in 0..bsf {
            let a = gate[i];
            let sig = 1.0 / (1.0 + (-a).exp());
            let dact = df1[i];
            df2[i] = dact * up[i] * (sig * (1.0 + a * (1.0 - sig)));
            df1[i] = dact * (a * sig);
        }
        let wg = params[spec.p_wgate(l)].f32s();
        let wu = params[spec.p_wup(l)].f32s();
        let gw = grads[spec.p_wup(l)].f32s_mut();
        matmul_tn(pool, min_ops, &xn2[..bsd], &df1[..bsf], gw, d, bs, f);
        let gw = grads[spec.p_wgate(l)].f32s_mut();
        matmul_tn(pool, min_ops, &xn2[..bsd], &df2[..bsf], gw, d, bs, f);
        matmul_nt(pool, min_ops, &df1[..bsf], wu, &mut dh_b[..bsd], bs, f, d, false);
        matmul_nt(pool, min_ops, &df2[..bsf], wg, &mut dh_b[..bsd], bs, f, d, true);
    }
    {
        let g_mlp = params[spec.p_mlp_norm(l)].f32s();
        let dgain = grads[spec.p_mlp_norm(l)].f32s_mut();
        dgain.fill(0.0);
        rmsnorm_bwd(&h_mid[..bsd], g_mlp, &mut dh_b[..bsd], dgain, d);
    }
    for i in 0..bsd {
        dh_a[i] += dh_b[i]; // dh_a now holds dL/d h_mid
    }

    // ---- attention backward ----
    let wo = params[spec.p_wo(l)].f32s();
    matmul_nt(pool, min_ops, &dh_a[..bsd], wo, &mut tmp_d[..bsd], bs, d, d, false);
    let gw = grads[spec.p_wo(l)].f32s_mut();
    matmul_tn(pool, min_ops, &merged[..bsd], &dh_a[..bsd], gw, d, bs, d);
    split_heads(&tmp_d[..bsd], &mut datt[..bsd], b, s, nh, dh);
    let bhss = b * nh * s * s;
    attn_pairs_bwd(
        &q[..bsd],
        &k[..bsd],
        &v[..bsd],
        &probs[..bhss],
        &datt[..bsd],
        &mut dprobs[..bhss],
        &mut dq[..bsd],
        &mut dk[..bsd],
        &mut dv[..bsd],
        b * nh,
        s,
        dh,
        pool,
        min_ops,
    );
    if !spec.gpt2 {
        rope_bwd(&mut dq[..bsd], rope_cos, rope_sin, b * nh, s, dh);
        rope_bwd(&mut dk[..bsd], rope_cos, rope_sin, b * nh, s, dh);
    }
    for (hd, w_idx, acc) in [
        (&*dq, spec.p_wq(l), false),
        (&*dk, spec.p_wk(l), true),
        (&*dv, spec.p_wv(l), true),
    ] {
        merge_heads(&hd[..bsd], &mut tmp_d[..bsd], b, s, nh, dh);
        let gw = grads[w_idx].f32s_mut();
        matmul_tn(pool, min_ops, &xn[..bsd], &tmp_d[..bsd], gw, d, bs, d);
        let w = params[w_idx].f32s();
        matmul_nt(pool, min_ops, &tmp_d[..bsd], w, &mut dh_b[..bsd], bs, d, d, acc);
    }
    {
        let g_attn = params[spec.p_attn_norm(l)].f32s();
        let dgain = grads[spec.p_attn_norm(l)].f32s_mut();
        dgain.fill(0.0);
        rmsnorm_bwd(x, g_attn, &mut dh_b[..bsd], dgain, d);
    }
    for i in 0..bsd {
        dh_a[i] += dh_b[i]; // dh_a now holds dL/d hs[l]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(gpt2: bool) -> ModelSpec {
        ModelSpec {
            vocab: 11,
            d: 8,
            n_layers: 2,
            n_heads: 2,
            head_dim: 4,
            d_ff: 12,
            seq: 5,
            gpt2,
        }
    }

    /// Random parameters in the model's canonical order and shapes.
    fn random_params(spec: &ModelSpec, seed: u64) -> Vec<Tensor> {
        let mut rng = Pcg::new(seed);
        let (v, d, f, s) = (spec.vocab, spec.d, spec.d_ff, spec.seq);
        let mut shapes: Vec<Vec<usize>> = vec![vec![v, d]];
        if spec.gpt2 {
            shapes.push(vec![s, d]);
        }
        for _ in 0..spec.n_layers {
            shapes.push(vec![d]); // attn_norm
            for _ in 0..4 {
                shapes.push(vec![d, d]); // wq wk wv wo
            }
            shapes.push(vec![d]); // mlp_norm
            if !spec.gpt2 {
                shapes.push(vec![d, f]); // w_gate
            }
            shapes.push(vec![d, f]); // w_up
            shapes.push(vec![f, d]); // w_down
        }
        shapes.push(vec![d]); // final_norm
        shapes.push(vec![d, v]); // lm_head
        shapes
            .into_iter()
            .map(|sh| {
                let n: usize = sh.iter().product();
                let data: Vec<f32> = if sh.len() == 1 {
                    vec![1.0; n]
                } else {
                    let scale = 1.0 / (sh[0] as f32).sqrt();
                    (0..n).map(|_| scale * rng.normal() as f32).collect()
                };
                Tensor::from_f32(&sh, data)
            })
            .collect()
    }

    fn random_toks(spec: &ModelSpec, b: usize, seed: u64) -> Vec<i32> {
        let mut rng = Pcg::new(seed);
        let n = b * (spec.seq + 1);
        (0..n).map(|_| rng.below(spec.vocab as u32) as i32).collect()
    }

    fn zeros_like(params: &[Tensor]) -> Vec<Tensor> {
        params.iter().map(|p| Tensor::zeros(p.shape())).collect()
    }

    fn loss_of(spec: &ModelSpec, params: &[Tensor], toks: &[i32], b: usize) -> f32 {
        let refs: Vec<&Tensor> = params.iter().collect();
        let mut ws = ModelWs::new(spec, b);
        let pool = WorkerPool::new(0);
        eval_loss(spec, &refs, toks, b, &mut ws, &pool, usize::MAX)
    }

    #[test]
    fn directional_derivative_matches_backward() {
        // the backward-pass oracle: for a random direction u,
        // (L(p+eps*u) - L(p-eps*u)) / (2 eps) must equal <grad, u>
        for gpt2 in [false, true] {
            let spec = tiny_spec(gpt2);
            let b = 2;
            let params = random_params(&spec, 7);
            let toks = random_toks(&spec, b, 8);
            let refs: Vec<&Tensor> = params.iter().collect();
            let mut grads = zeros_like(&params);
            let mut ws = ModelWs::new(&spec, b);
            let pool = WorkerPool::new(0);
            let _ = fwd_bwd(&spec, &refs, &toks, b, &mut grads, &mut ws, &pool, usize::MAX);

            let mut rng = Pcg::new(99);
            let dirs: Vec<Vec<f32>> = params
                .iter()
                .map(|p| (0..p.numel()).map(|_| rng.normal() as f32).collect())
                .collect();
            let mut analytic = 0.0f64;
            for (g, u) in grads.iter().zip(&dirs) {
                for (gi, ui) in g.f32s().iter().zip(u) {
                    analytic += (*gi as f64) * (*ui as f64);
                }
            }
            let eps = 1e-3f32;
            let shift = |sign: f32| -> Vec<Tensor> {
                params
                    .iter()
                    .zip(&dirs)
                    .map(|(p, u)| {
                        let data: Vec<f32> = p
                            .f32s()
                            .iter()
                            .zip(u)
                            .map(|(pi, ui)| pi + sign * eps * ui)
                            .collect();
                        Tensor::from_f32(p.shape(), data)
                    })
                    .collect()
            };
            let lp = loss_of(&spec, &shift(1.0), &toks, b) as f64;
            let lm = loss_of(&spec, &shift(-1.0), &toks, b) as f64;
            let numeric = (lp - lm) / (2.0 * eps as f64);
            let denom = numeric.abs().max(analytic.abs()).max(1e-3);
            let rel = (numeric - analytic).abs() / denom;
            assert!(
                rel < 2e-2,
                "gpt2={gpt2}: directional derivative {numeric:.6} vs analytic {analytic:.6}"
            );
        }
    }

    #[test]
    fn gradient_step_reduces_loss() {
        for gpt2 in [false, true] {
            let spec = tiny_spec(gpt2);
            let b = 2;
            let params = random_params(&spec, 3);
            let toks = random_toks(&spec, b, 4);
            let refs: Vec<&Tensor> = params.iter().collect();
            let mut grads = zeros_like(&params);
            let mut ws = ModelWs::new(&spec, b);
            let pool = WorkerPool::new(0);
            let l0 = fwd_bwd(&spec, &refs, &toks, b, &mut grads, &mut ws, &pool, usize::MAX);
            let stepped: Vec<Tensor> = params
                .iter()
                .zip(&grads)
                .map(|(p, g)| {
                    let data: Vec<f32> = p
                        .f32s()
                        .iter()
                        .zip(g.f32s())
                        .map(|(pi, gi)| pi - 0.05 * gi)
                        .collect();
                    Tensor::from_f32(p.shape(), data)
                })
                .collect();
            let l1 = loss_of(&spec, &stepped, &toks, b);
            assert!(l1 < l0, "gpt2={gpt2}: step did not reduce loss ({l0} -> {l1})");
        }
    }

    #[test]
    fn fwd_bwd_bit_identical_across_pools_and_thresholds() {
        let spec = tiny_spec(false);
        let b = 2;
        let params = random_params(&spec, 11);
        let toks = random_toks(&spec, b, 12);
        let refs: Vec<&Tensor> = params.iter().collect();
        let seq_pool = WorkerPool::new(0);
        let mut want_grads = zeros_like(&params);
        let mut ws = ModelWs::new(&spec, b);
        let mp = usize::MAX;
        let want_loss = fwd_bwd(&spec, &refs, &toks, b, &mut want_grads, &mut ws, &seq_pool, mp);
        for workers in [0usize, 2, 5] {
            let pool = WorkerPool::new(workers);
            for min_ops in [0usize, usize::MAX] {
                let mut grads = zeros_like(&params);
                let mut ws = ModelWs::new(&spec, b);
                let loss = fwd_bwd(&spec, &refs, &toks, b, &mut grads, &mut ws, &pool, min_ops);
                assert_eq!(loss, want_loss, "{workers} workers, min {min_ops}");
                for (p, (g, w)) in grads.iter().zip(&want_grads).enumerate() {
                    assert_eq!(
                        g.f32s(),
                        w.f32s(),
                        "param {p} differs: {workers} workers, min {min_ops}"
                    );
                }
            }
        }
    }

    #[test]
    fn attention_pair_tiling_bit_identical_with_ragged_pairs() {
        // 3 heads x batch 3 = 9 pairs: indivisible by the tested pool
        // lane counts, so the pair blocks are ragged (the last block is
        // short). Every pool size and threshold must produce the exact
        // bits of the sequential reference.
        let spec = ModelSpec {
            vocab: 13,
            d: 12,
            n_layers: 2,
            n_heads: 3,
            head_dim: 4,
            d_ff: 10,
            seq: 6,
            gpt2: false,
        };
        let b = 3;
        let params = random_params(&spec, 31);
        let toks = random_toks(&spec, b, 32);
        let refs: Vec<&Tensor> = params.iter().collect();
        let seq_pool = WorkerPool::new(0);
        let mut want_grads = zeros_like(&params);
        let mut ws = ModelWs::new(&spec, b);
        let mp = usize::MAX;
        let want_loss = fwd_bwd(&spec, &refs, &toks, b, &mut want_grads, &mut ws, &seq_pool, mp);
        for workers in [0usize, 2, 3, 7] {
            let pool = WorkerPool::new(workers);
            for min_ops in [0usize, 1 << 10, usize::MAX] {
                let mut grads = zeros_like(&params);
                let mut ws = ModelWs::new(&spec, b);
                let loss = fwd_bwd(&spec, &refs, &toks, b, &mut grads, &mut ws, &pool, min_ops);
                assert_eq!(loss, want_loss, "{workers} workers, min {min_ops}");
                for (p, (g, w)) in grads.iter().zip(&want_grads).enumerate() {
                    assert_eq!(g.f32s(), w.f32s(), "param {p}: {workers} workers, min {min_ops}");
                }
            }
        }
    }

    #[test]
    fn attn_pair_override_selects_path_never_result() {
        // the bench A/B knob: forcing either dispatch path (with the
        // threshold pinned so the gate alone would choose sequentially)
        // must not change a single bit
        let spec = tiny_spec(false);
        let b = 2;
        let params = random_params(&spec, 41);
        let toks = random_toks(&spec, b, 42);
        let refs: Vec<&Tensor> = params.iter().collect();
        let pool = WorkerPool::new(3);
        let mut base = zeros_like(&params);
        let mut ws = ModelWs::new(&spec, b);
        let l0 = fwd_bwd(&spec, &refs, &toks, b, &mut base, &mut ws, &pool, usize::MAX);
        for force in [Some(true), Some(false), None] {
            set_attn_pair_override(force);
            let mut grads = zeros_like(&params);
            let mut ws = ModelWs::new(&spec, b);
            let loss = fwd_bwd(&spec, &refs, &toks, b, &mut grads, &mut ws, &pool, usize::MAX);
            set_attn_pair_override(None);
            assert_eq!(loss, l0, "force {force:?}");
            for (g, w) in grads.iter().zip(&base) {
                assert_eq!(g.f32s(), w.f32s(), "force {force:?}");
            }
        }
    }

    #[test]
    fn eval_loss_matches_fwd_bwd_loss_exactly() {
        let spec = tiny_spec(false);
        let b = 2;
        let params = random_params(&spec, 21);
        let toks = random_toks(&spec, b, 22);
        let refs: Vec<&Tensor> = params.iter().collect();
        let pool = WorkerPool::new(2);
        let mut ws = ModelWs::new(&spec, b);
        let le = eval_loss(&spec, &refs, &toks, b, &mut ws, &pool, 0);
        let mut grads = zeros_like(&params);
        let lf = fwd_bwd(&spec, &refs, &toks, b, &mut grads, &mut ws, &pool, 0);
        assert_eq!(le, lf);
    }

    #[test]
    fn loss_is_near_uniform_with_zero_weights() {
        // zero matrices (norm gains kept at 1) -> logits 0 -> loss ln(V)
        let spec = tiny_spec(false);
        let b = 1;
        let params: Vec<Tensor> = random_params(&spec, 5)
            .into_iter()
            .map(|p| {
                if p.shape().len() == 1 {
                    p
                } else {
                    Tensor::zeros(p.shape())
                }
            })
            .collect();
        let toks = random_toks(&spec, b, 6);
        let loss = loss_of(&spec, &params, &toks, b);
        let want = (spec.vocab as f32).ln();
        assert!((loss - want).abs() < 1e-4, "{loss} vs ln(v)={want}");
    }

    #[test]
    fn rmsnorm_bwd_matches_finite_difference() {
        let mut rng = Pcg::new(17);
        let d = 6;
        let rows = 3;
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
        let gain: Vec<f32> = (0..d).map(|_| 1.0 + 0.1 * rng.normal() as f32).collect();
        let dy: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
        let mut dx = dy.clone();
        let mut dgain = vec![0.0f32; d];
        rmsnorm_bwd(&x, &gain, &mut dx, &mut dgain, d);
        // numeric gradients of the scalar objective sum(dy * rmsnorm(x))
        let obj = |x: &[f32], gain: &[f32]| -> f64 {
            let mut out = vec![0.0f32; x.len()];
            rmsnorm_fwd(x, gain, &mut out, d);
            let pairs = out.iter().zip(&dy);
            pairs.map(|(o, dyi)| (*o as f64) * (*dyi as f64)).sum()
        };
        let eps = 1e-3f32;
        for i in [0usize, 5, 7, rows * d - 1] {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (obj(&xp, &gain) - obj(&xm, &gain)) / (2.0 * eps as f64);
            assert!(
                (fd - dx[i] as f64).abs() < 1e-3,
                "dx[{i}]: fd {fd} vs analytic {}",
                dx[i]
            );
        }
        for i in [0usize, d - 1] {
            let mut gp = gain.clone();
            gp[i] += eps;
            let mut gm = gain.clone();
            gm[i] -= eps;
            let fd = (obj(&x, &gp) - obj(&x, &gm)) / (2.0 * eps as f64);
            assert!(
                (fd - dgain[i] as f64).abs() < 1e-3,
                "dgain[{i}]: fd {fd} vs analytic {}",
                dgain[i]
            );
        }
    }

    #[test]
    fn rope_bwd_is_transpose_of_fwd() {
        // <rope(x), y> == <x, rope_bwd(y)> (rotation is orthogonal)
        let mut rng = Pcg::new(23);
        let (groups, s, dh) = (3usize, 4usize, 6usize);
        let half = dh / 2;
        let mut cos = vec![0.0f32; s * half];
        let mut sin = vec![0.0f32; s * half];
        for t in 0..s {
            for i in 0..half {
                let ang = t as f32 * 0.3 + i as f32 * 0.7;
                cos[t * half + i] = ang.cos();
                sin[t * half + i] = ang.sin();
            }
        }
        let n = groups * s * dh;
        let x: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut rx = x.clone();
        rope_fwd(&mut rx, &cos, &sin, groups, s, dh);
        let mut ry = y.clone();
        rope_bwd(&mut ry, &cos, &sin, groups, s, dh);
        let ip = |u: &[f32], w: &[f32]| -> f64 {
            u.iter().zip(w).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let lhs = ip(&rx, &y);
        let rhs = ip(&x, &ry);
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn decode_matches_full_forward_bitwise() {
        // the unit-level decode differential (the integration suite in
        // rust/tests/serve_differential.rs sweeps pools and batches):
        // token-by-token KV-cache decode and a one-shot prefill must
        // both reproduce the training forward's logits exactly
        for gpt2 in [false, true] {
            let spec = tiny_spec(gpt2);
            let v = spec.vocab;
            let params = random_params(&spec, 51);
            let prefix: Vec<i32> = random_toks(&spec, 1, 52)[..spec.seq].to_vec();
            let pool = WorkerPool::new(2);
            let oracle = forward_logits(&spec, &params, &prefix, &pool, 0);
            let mut cache = KvCache::new(&spec);
            let mut ws = DecodeWs::new(&spec);
            for t in 0..prefix.len() {
                extend(&spec, &params, &prefix[t..t + 1], &mut cache, &mut ws, &pool, 0);
                assert_eq!(
                    &ws.logits[..v],
                    &oracle[t * v..(t + 1) * v],
                    "gpt2={gpt2} position {t}"
                );
            }
            cache.reset();
            extend(&spec, &params, &prefix, &mut cache, &mut ws, &pool, 0);
            assert_eq!(&ws.logits[..v], &oracle[(prefix.len() - 1) * v..], "gpt2={gpt2} prefill");
        }
    }

    #[test]
    fn sampler_greedy_is_argmax_and_seeded_draws_reproduce() {
        let logits: Vec<f32> = (0..16).map(|i| ((i * 7919) % 23) as f32 * 0.13 - 1.0).collect();
        let mut order = Vec::new();
        let mut cdf = Vec::new();
        let greedy = SampleCfg { temperature: 0.0, top_k: 0, top_p: 1.0 };
        let mut want = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[want] {
                want = i;
            }
        }
        let mut rng = Pcg::new(1);
        assert_eq!(sample_logits(&logits, &greedy, &mut rng, &mut order, &mut cdf), want);
        // top_k = 1 collapses any temperature to the argmax
        let k1 = SampleCfg { temperature: 0.7, top_k: 1, top_p: 1.0 };
        assert_eq!(sample_logits(&logits, &k1, &mut rng, &mut order, &mut cdf), want);
        // a seeded stream of draws reproduces exactly and stays in-filter
        let cfg = SampleCfg { temperature: 0.8, top_k: 5, top_p: 0.9 };
        let draws = |seed: u64| -> Vec<usize> {
            let mut rng = Pcg::new(seed);
            let mut order = Vec::new();
            let mut cdf = Vec::new();
            (0..32).map(|_| sample_logits(&logits, &cfg, &mut rng, &mut order, &mut cdf)).collect()
        };
        let a = draws(9);
        assert_eq!(a, draws(9));
        assert_ne!(a, draws(10), "different seeds should diverge somewhere in 32 draws");
    }
}
