//! Native Newton–Schulz orthogonalization (quintic iteration, Jordan et
//! al. 2024 coefficients) — the matmul-only stand-in for exact SVD used
//! by the `norm_ns_<d>` artifacts and the Muon/SWAN/`sgd_ns` update
//! rules, mirroring `python/compile/newton_schulz.py`.
//!
//! Non-square matrices are handled by iterating on the short side (the
//! transpose when `m > n`); spectral norm <= 1 is ensured by a Frobenius
//! prescale. All matmuls route through [`super::gemm`], so the result is
//! bit-identical for every worker-pool size.

use crate::exec::gemm::{matmul_nn, matmul_nt};
use crate::exec::kernels::dot8;
use crate::parallel::WorkerPool;

pub(crate) const NS_STEPS: usize = 5;
const NS_A: f32 = 3.4445;
const NS_B: f32 = -4.7750;
const NS_C: f32 = 2.0315;

/// Scratch for the iteration: sized lazily, reused across calls.
#[derive(Default)]
pub(crate) struct NsWs {
    xt: Vec<f32>,
    a: Vec<f32>,
    aa: Vec<f32>,
    bx: Vec<f32>,
    pack: Vec<f32>,
}

impl NsWs {
    pub fn new() -> NsWs {
        NsWs::default()
    }
}

/// Clear-and-resize a scratch vector (no allocation once warm). Shared
/// with the update rules (`exec::update`), which lean on the same
/// capacity-reuse contract for their zero-steady-state-alloc gate.
pub(crate) fn buf(v: &mut Vec<f32>, n: usize) -> &mut [f32] {
    v.clear();
    v.resize(n, 0.0);
    &mut v[..]
}

fn transpose(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    for i in 0..rows {
        for j in 0..cols {
            dst[j * rows + i] = src[i * cols + j];
        }
    }
}

/// The quintic iteration on `x` with `r <= c` rows: `x <- A x + (B a +
/// C a²) x` where `a = x xᵀ`.
#[allow(clippy::too_many_arguments)]
fn iterate(
    x: &mut [f32],
    r: usize,
    c: usize,
    steps: usize,
    a_buf: &mut Vec<f32>,
    aa_buf: &mut Vec<f32>,
    bx_buf: &mut Vec<f32>,
    pack: &mut Vec<f32>,
    pool: &WorkerPool,
    min_ops: usize,
) {
    // Frobenius norm via the shared dot microkernel (fixed 8-lane
    // association — deterministic, and vectorized under `simd`).
    let frob = dot8(&x[..], &x[..]);
    let scale = 1.0 / (frob.sqrt() + 1e-7);
    for v in x.iter_mut() {
        *v *= scale;
    }
    let a = buf(a_buf, r * r);
    let aa = buf(aa_buf, r * r);
    let bx = buf(bx_buf, r * c);
    for _ in 0..steps {
        matmul_nt(pool, min_ops, x, x, a, r, c, r, false);
        matmul_nn(pool, min_ops, a, a, aa, r, r, r, pack);
        for i in 0..r * r {
            aa[i] = NS_B * a[i] + NS_C * aa[i];
        }
        matmul_nn(pool, min_ops, aa, x, bx, r, r, c, pack);
        for i in 0..r * c {
            x[i] = NS_A * x[i] + bx[i];
        }
    }
}

/// Approximate `U Vᵀ` of `g` (shape `[m, n]`) into `out`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn ns_orth(
    g: &[f32],
    m: usize,
    n: usize,
    steps: usize,
    out: &mut [f32],
    ws: &mut NsWs,
    pool: &WorkerPool,
    min_ops: usize,
) {
    assert_eq!(g.len(), m * n);
    assert_eq!(out.len(), m * n);
    let NsWs { xt, a, aa, bx, pack } = ws;
    if m <= n {
        out.copy_from_slice(g);
        iterate(out, m, n, steps, a, aa, bx, pack, pool, min_ops);
    } else {
        let xt = buf(xt, m * n);
        transpose(g, m, n, xt);
        iterate(xt, n, m, steps, a, aa, bx, pack, pool, min_ops);
        transpose(xt, n, m, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn gram(x: &[f32], r: usize, c: usize) -> Vec<f32> {
        let mut g = vec![0.0f32; r * r];
        for i in 0..r {
            for j in 0..r {
                let mut s = 0.0f32;
                for p in 0..c {
                    s += x[i * c + p] * x[j * c + p];
                }
                g[i * r + j] = s;
            }
        }
        g
    }

    #[test]
    fn pushes_singular_values_toward_one() {
        let mut rng = Pcg::new(4);
        let (m, n) = (6usize, 10usize);
        let g: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; m * n];
        let mut ws = NsWs::new();
        let pool = WorkerPool::new(0);
        ns_orth(&g, m, n, NS_STEPS, &mut out, &mut ws, &pool, usize::MAX);
        let gm = gram(&out, m, n);
        for i in 0..m {
            let dii = gm[i * m + i];
            assert!((0.4..1.6).contains(&dii), "diag {i} = {dii}");
            for j in 0..m {
                if i != j {
                    assert!(gm[i * m + j].abs() < 0.35, "off-diag ({i},{j}) = {}", gm[i * m + j]);
                }
            }
        }
    }

    #[test]
    fn tall_matrix_handled_via_transpose() {
        let mut rng = Pcg::new(9);
        let (m, n) = (12usize, 5usize);
        let g: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; m * n];
        let mut ws = NsWs::new();
        let pool = WorkerPool::new(2);
        ns_orth(&g, m, n, NS_STEPS, &mut out, &mut ws, &pool, 0);
        // columns of a tall orthogonal factor are near-orthonormal:
        // gram of the transpose is near identity
        let mut gt = vec![0.0f32; m * n];
        transpose(&out, m, n, &mut gt);
        let gm = gram(&gt, n, m);
        for i in 0..n {
            assert!((0.4..1.6).contains(&gm[i * n + i]), "diag {i} = {}", gm[i * n + i]);
        }
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn bit_identical_across_pools() {
        let mut rng = Pcg::new(13);
        let (m, n) = (7usize, 9usize);
        let g: Vec<f32> = (0..m * n).map(|_| rng.normal() as f32).collect();
        let mut want = vec![0.0f32; m * n];
        let mut ws = NsWs::new();
        let seq = WorkerPool::new(0);
        ns_orth(&g, m, n, NS_STEPS, &mut want, &mut ws, &seq, usize::MAX);
        for workers in [0usize, 2, 5] {
            let pool = WorkerPool::new(workers);
            for min_ops in [0usize, usize::MAX] {
                let mut out = vec![9.0f32; m * n];
                let mut ws = NsWs::new();
                ns_orth(&g, m, n, NS_STEPS, &mut out, &mut ws, &pool, min_ops);
                assert_eq!(out, want, "{workers} workers, min {min_ops}");
            }
        }
    }
}
