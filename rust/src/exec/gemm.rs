//! Pool-parallel blocked f32 GEMM kernels for the native executor.
//!
//! Three orientations cover every matmul the transformer fwd/bwd needs
//! (row-major throughout):
//!
//! * [`matmul_nn`]  — `C[m,n] = A[m,k] · B[k,n]` (activations × weights).
//!   B is packed transposed into a caller-owned panel buffer first, so
//!   the inner kernel is a contiguous-by-contiguous dot product.
//! * [`matmul_nt`]  — `C[m,n] (+)= A[m,k] · B[n,k]ᵀ` (backward data:
//!   `dX = dY · Wᵀ`). Rows of both operands are already contiguous —
//!   no packing needed.
//! * [`matmul_tn`]  — `C[m,n] = A[k,m]ᵀ · B[k,n]` (backward weights:
//!   `dW = Xᵀ · dY`), computed as row-blocked rank-1 accumulation so B
//!   rows stream once per small block of C rows.
//!
//! The scalar inner loops live one module down in [`super::kernels`]
//! ([`dot`] = `dot8`, `axpy` = `axpy8`); building every orientation on
//! those two microkernels is what lets the optional `simd` feature
//! vectorize the whole executor in one place without touching any
//! tiling code here.
//!
//! # Determinism contract (see the `exec` module docs)
//!
//! Every output element is produced by exactly one task, and its
//! accumulation order over `k` is a fixed function of `k` alone:
//! `matmul_nn`/`matmul_nt` use the shared 8-lane [`dot`] (fixed lane
//! association, sequential tail), `matmul_tn` accumulates rank-1 updates
//! in sequential `r` order. Parallelism only partitions C into disjoint
//! row blocks — it never changes which floats meet in which order — so
//! results are bit-identical for every pool size and every threshold,
//! property-tested below.
//!
//! The `min_ops` gate (`m*n*k` multiply-adds) selects the sequential
//! path for small problems where pool dispatch (~µs) would dominate; it
//! is calibrated at runtime by [`crate::parallel::calibrate`].

use crate::optim::colnorm::tile_width;
use crate::parallel::WorkerPool;

/// Column-block width for the packed-panel kernels: one block of packed
/// B rows (NB × k floats) stays L1/L2-resident across every A row that
/// streams against it.
const NB: usize = 64;

/// C row-block height for the rank-1 `matmul_tn` kernel: each B row is
/// loaded once per IB output rows instead of once per row.
const IB: usize = 8;

/// Contiguous dot product with a fixed 8-lane accumulation order — the
/// [`super::kernels::dot8`] microkernel under the name the GEMM inner
/// loops (and their docs) use. The association depends only on the slice
/// length, never on the caller's tiling, which is what makes the GEMMs
/// bit-stable; with `--features simd` it dispatches to the bit-identical
/// AVX2 body (see the `kernels` module docs).
pub use crate::exec::kernels::dot8 as dot;

/// In-place `y += s * x` over contiguous slices — the
/// [`super::kernels::axpy8`] microkernel, shared with the attention
/// inner loops and (via its scalar body) the optimizer update rules:
/// one place to vectorize.
pub(crate) use crate::exec::kernels::axpy8 as axpy;

/// Pack `B[k,n]` transposed into `pack` (n rows of k contiguous floats),
/// in 32x32 blocks so both source and destination stay cache-friendly.
fn pack_bt(b: &[f32], k: usize, n: usize, pack: &mut Vec<f32>) {
    debug_assert_eq!(b.len(), k * n);
    pack.clear();
    pack.resize(k * n, 0.0);
    const TB: usize = 32;
    let mut j0 = 0;
    while j0 < n {
        let jn = (j0 + TB).min(n);
        let mut p0 = 0;
        while p0 < k {
            let pn = (p0 + TB).min(k);
            for j in j0..jn {
                let row = &mut pack[j * k..];
                for p in p0..pn {
                    row[p] = b[p * n + j];
                }
            }
            p0 = pn;
        }
        j0 = jn;
    }
}

/// The nn inner kernel over one block of C rows. `a_rows` holds the same
/// row range of A that `c_rows` covers in C; `bt` is the packed Bᵀ.
fn nn_rows(a_rows: &[f32], bt: &[f32], c_rows: &mut [f32], k: usize, n: usize) {
    let rows = c_rows.len() / n.max(1);
    let mut j0 = 0;
    while j0 < n {
        let jn = (j0 + NB).min(n);
        for i in 0..rows {
            let a_row = &a_rows[i * k..(i + 1) * k];
            let c_row = &mut c_rows[i * n..(i + 1) * n];
            for j in j0..jn {
                c_row[j] = dot(a_row, &bt[j * k..(j + 1) * k]);
            }
        }
        j0 = jn;
    }
}

/// `C[m,n] = A[m,k] · B[k,n]`, panel-packed and row-blocked across the
/// pool. `pack` is the caller-owned panel buffer (resized, reused).
#[allow(clippy::too_many_arguments)]
pub fn matmul_nn(
    pool: &WorkerPool,
    min_ops: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    pack: &mut Vec<f32>,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    pack_bt(b, k, n, pack);
    let bt: &[f32] = pack;
    if m * n * k < min_ops.max(1) || pool.parallelism() == 1 || m == 1 {
        return nn_rows(a, bt, c, k, n);
    }
    let rows = tile_width(m, pool.parallelism());
    let mut tasks = Vec::new();
    for (ti, c_rows) in c.chunks_mut(rows * n).enumerate() {
        let r0 = ti * rows;
        let a_rows = &a[r0 * k..r0 * k + (c_rows.len() / n) * k];
        tasks.push(move || nn_rows(a_rows, bt, c_rows, k, n));
    }
    pool.run(tasks);
}

/// The nt inner kernel over one block of C rows.
fn nt_rows(a_rows: &[f32], b: &[f32], c_rows: &mut [f32], k: usize, n: usize, acc: bool) {
    let rows = c_rows.len() / n.max(1);
    let mut j0 = 0;
    while j0 < n {
        let jn = (j0 + NB).min(n);
        for i in 0..rows {
            let a_row = &a_rows[i * k..(i + 1) * k];
            let c_row = &mut c_rows[i * n..(i + 1) * n];
            for j in j0..jn {
                let v = dot(a_row, &b[j * k..(j + 1) * k]);
                if acc {
                    c_row[j] += v;
                } else {
                    c_row[j] = v;
                }
            }
        }
        j0 = jn;
    }
}

/// `C[m,n] = A[m,k] · B[n,k]ᵀ` (or `+=` with `acc`) — the backward-data
/// orientation. Both operands are read along contiguous rows.
#[allow(clippy::too_many_arguments)]
pub fn matmul_nt(
    pool: &WorkerPool,
    min_ops: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    acc: bool,
) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), n * k);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if m * n * k < min_ops.max(1) || pool.parallelism() == 1 || m == 1 {
        return nt_rows(a, b, c, k, n, acc);
    }
    let rows = tile_width(m, pool.parallelism());
    let mut tasks = Vec::new();
    for (ti, c_rows) in c.chunks_mut(rows * n).enumerate() {
        let r0 = ti * rows;
        let a_rows = &a[r0 * k..r0 * k + (c_rows.len() / n) * k];
        tasks.push(move || nt_rows(a_rows, b, c_rows, k, n, acc));
    }
    pool.run(tasks);
}

/// The tn inner kernel over one block of C rows (`i0..i0+rows` of m).
fn tn_rows(a: &[f32], b: &[f32], c_rows: &mut [f32], i0: usize, k: usize, m: usize, n: usize) {
    let rows = c_rows.len() / n.max(1);
    c_rows.fill(0.0);
    let mut ib0 = 0;
    while ib0 < rows {
        let ibn = (ib0 + IB).min(rows);
        for r in 0..k {
            let b_row = &b[r * n..(r + 1) * n];
            let a_row = &a[r * m..(r + 1) * m];
            for i in ib0..ibn {
                axpy(&mut c_rows[i * n..(i + 1) * n], a_row[i0 + i], b_row);
            }
        }
        ib0 = ibn;
    }
}

/// `C[m,n] = A[k,m]ᵀ · B[k,n]` — the backward-weights orientation,
/// accumulated as rank-1 updates in sequential `r` order (bit-stable).
#[allow(clippy::too_many_arguments)]
pub fn matmul_tn(
    pool: &WorkerPool,
    min_ops: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    if m * n * k < min_ops.max(1) || pool.parallelism() == 1 || m == 1 {
        return tn_rows(a, b, c, 0, k, m, n);
    }
    let rows = tile_width(m, pool.parallelism());
    let mut tasks = Vec::new();
    for (ti, c_rows) in c.chunks_mut(rows * n).enumerate() {
        let i0 = ti * rows;
        tasks.push(move || tn_rows(a, b, c_rows, i0, k, m, n));
    }
    pool.run(tasks);
}

/// Sequential-by-construction [`matmul_tn`]: the same inner kernel with
/// no pool interaction at all, for callers that are themselves pool
/// tasks (the per-(batch, head) attention backward in `exec::model`) and
/// should stay off the queue. Bit-identical to [`matmul_tn`] for every
/// pool size and threshold — that is the gemm determinism contract.
pub(crate) fn matmul_tn_seq(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    tn_rows(a, b, c, 0, k, m, n);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure};

    /// Textbook triple loop — the semantic reference (not bit reference;
    /// the kernels' fixed lane association is its own bit contract).
    fn naive_nn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f64; m * n];
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p] as f64;
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j] as f64;
                }
            }
        }
        c.into_iter().map(|x| x as f32).collect()
    }

    fn close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
        prop::slices_close(a, b, tol)
    }

    #[test]
    fn nn_matches_naive_reference() {
        let mut pack = Vec::new();
        let pool = WorkerPool::new(2);
        prop::check("gemm-nn-naive", 32, |rng| {
            let m = prop::usize_in(rng, 1, 33);
            let k = prop::usize_in(rng, 1, 40);
            let n = prop::usize_in(rng, 1, 70);
            let a = prop::matrix(rng, m, k, 1.0);
            let b = prop::matrix(rng, k, n, 1.0);
            let want = naive_nn(&a, &b, m, k, n);
            let mut c = vec![0.0f32; m * n];
            matmul_nn(&pool, 0, &a, &b, &mut c, m, k, n, &mut pack);
            close(&c, &want, 1e-4)
        });
    }

    #[test]
    fn orientations_agree_through_transposes() {
        // nt and tn must equal nn applied to explicitly transposed inputs
        let mut pack = Vec::new();
        let pool = WorkerPool::new(3);
        prop::check("gemm-orientations", 32, |rng| {
            let m = prop::usize_in(rng, 1, 20);
            let k = prop::usize_in(rng, 1, 24);
            let n = prop::usize_in(rng, 1, 20);
            let a = prop::matrix(rng, m, k, 1.0);
            let b = prop::matrix(rng, k, n, 1.0);
            // B stored transposed: bt[n,k]
            let mut bt = vec![0.0f32; k * n];
            for p in 0..k {
                for j in 0..n {
                    bt[j * k + p] = b[p * n + j];
                }
            }
            // A stored transposed: at[k,m]
            let mut at = vec![0.0f32; k * m];
            for i in 0..m {
                for p in 0..k {
                    at[p * m + i] = a[i * k + p];
                }
            }
            let mut want = vec![0.0f32; m * n];
            matmul_nn(&pool, 0, &a, &b, &mut want, m, k, n, &mut pack);
            let mut c_nt = vec![0.0f32; m * n];
            matmul_nt(&pool, 0, &a, &bt, &mut c_nt, m, k, n, false);
            close(&c_nt, &want, 1e-5)?;
            let mut c_tn = vec![0.0f32; m * n];
            matmul_tn(&pool, 0, &at, &b, &mut c_tn, m, k, n);
            close(&c_tn, &want, 1e-5)
        });
    }

    #[test]
    fn nt_accumulate_adds_on_top() {
        let pool = WorkerPool::new(0);
        let a = vec![1.0f32, 2.0, 3.0, 4.0]; // 2x2
        let bt = vec![1.0f32, 0.0, 0.0, 1.0]; // identity, stored [n,k]
        let mut c = vec![10.0f32; 4];
        matmul_nt(&pool, 0, &a, &bt, &mut c, 2, 2, 2, true);
        assert_eq!(c, vec![11.0, 12.0, 13.0, 14.0]);
    }

    #[test]
    fn tn_seq_matches_tn_bitwise() {
        // the attention backward runs matmul_tn_seq inside pool tasks;
        // it must be the exact bits of the dispatching form
        let pool = WorkerPool::new(3);
        prop::check("gemm-tn-seq", 16, |rng| {
            let m = prop::usize_in(rng, 1, 30);
            let k = prop::usize_in(rng, 1, 24);
            let n = prop::usize_in(rng, 1, 30);
            let a = prop::matrix(rng, k, m, 1.0);
            let b = prop::matrix(rng, k, n, 1.0);
            let mut want = vec![0.0f32; m * n];
            matmul_tn(&pool, 0, &a, &b, &mut want, m, k, n);
            let mut c = vec![9.0f32; m * n];
            matmul_tn_seq(&a, &b, &mut c, m, k, n);
            ensure(c == want, format!("tn_seq {m}x{k}x{n}"))
        });
    }

    #[test]
    fn bit_identical_across_pools_and_thresholds() {
        // the tentpole acceptance property: every orientation, random
        // shapes spanning the NB/IB tile boundaries, pools of several
        // sizes, thresholds forcing both paths — identical bits
        let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(5)];
        let mut pack = Vec::new();
        prop::check("gemm-bits-pools", 24, |rng| {
            let m = prop::usize_in(rng, 1, 80);
            let k = prop::usize_in(rng, 1, 40);
            let n = prop::usize_in(rng, 1, 80);
            let a = prop::matrix(rng, m, k, 1.0);
            let b = prop::matrix(rng, k, n, 1.0);
            let mut bt = vec![0.0f32; k * n];
            for p in 0..k {
                for j in 0..n {
                    bt[j * k + p] = b[p * n + j];
                }
            }
            let mut at = vec![0.0f32; k * m];
            for i in 0..m {
                for p in 0..k {
                    at[p * m + i] = a[i * k + p];
                }
            }
            let seq = WorkerPool::new(0);
            let mut want_nn = vec![0.0f32; m * n];
            matmul_nn(&seq, usize::MAX, &a, &b, &mut want_nn, m, k, n, &mut pack);
            let mut want_nt = vec![0.0f32; m * n];
            matmul_nt(&seq, usize::MAX, &a, &bt, &mut want_nt, m, k, n, false);
            let mut want_tn = vec![0.0f32; m * n];
            matmul_tn(&seq, usize::MAX, &at, &b, &mut want_tn, m, k, n);
            for pool in &pools {
                for min_ops in [0usize, m * n * k, usize::MAX] {
                    let mut c = vec![9.0f32; m * n];
                    matmul_nn(pool, min_ops, &a, &b, &mut c, m, k, n, &mut pack);
                    ensure(c == want_nn, format!("nn {m}x{k}x{n} min {min_ops}"))?;
                    let mut c = vec![9.0f32; m * n];
                    matmul_nt(pool, min_ops, &a, &bt, &mut c, m, k, n, false);
                    ensure(c == want_nt, format!("nt {m}x{k}x{n} min {min_ops}"))?;
                    let mut c = vec![9.0f32; m * n];
                    matmul_tn(pool, min_ops, &at, &b, &mut c, m, k, n);
                    ensure(c == want_tn, format!("tn {m}x{k}x{n} min {min_ops}"))?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dot_association_is_length_only() {
        // same data split across different call sites must agree exactly
        let mut rng = crate::util::rng::Pcg::new(3);
        let a: Vec<f32> = (0..100).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..100).map(|_| rng.normal() as f32).collect();
        let d1 = dot(&a, &b);
        let d2 = dot(&a[..100], &b[..100]);
        assert_eq!(d1, d2);
        assert!((0..17).all(|i| dot(&a[..i], &b[..i]).is_finite()));
    }

    #[test]
    fn degenerate_shapes() {
        let pool = WorkerPool::new(2);
        let mut pack = Vec::new();
        // 1-row, 1-col, and k=1 paths all defined
        let a = vec![2.0f32; 7];
        let b = vec![3.0f32; 7];
        let mut c = vec![0.0f32; 1];
        matmul_nn(&pool, 0, &a, &b, &mut c, 1, 7, 1, &mut pack);
        assert!((c[0] - 42.0).abs() < 1e-5);
        let mut c = vec![0.0f32; 49];
        matmul_tn(&pool, 0, &a, &b, &mut c, 7, 1, 7);
        assert!((c[0] - 6.0).abs() < 1e-6 && (c[48] - 6.0).abs() < 1e-6);
    }
}
