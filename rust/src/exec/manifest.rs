//! Native manifest synthesis: the same contract `aot.py` serializes to
//! `artifacts/manifest.json`, built directly in Rust so the default
//! (no-PJRT) build can train without ever running Python.
//!
//! Mirrors `python/compile/configs.py` (the tiny simulation family and
//! the Appendix-B paper dims) and `model.param_specs` (canonical
//! parameter order), and adds two smoke-test sizes (`tiny`, `tinyg`)
//! small enough for debug-mode CI. Update artifacts are emitted for
//! every optimizer in [`crate::exec::NATIVE_OPTIMIZERS`] — since PR 5
//! that is the complete registry, Table-13 `mix_*` ablations included —
//! with state layouts from the same plan the executor runs: a single
//! source of truth, so checkpoints and `state_spec` lookups agree by
//! construction.

use std::collections::BTreeMap;
use std::path::PathBuf;

use crate::exec::update::{state_slots, NATIVE_OPTIMIZERS};
use crate::runtime::artifact::{
    ArtifactSpec, DType, Manifest, PaperDims, ParamSpec, SizeInfo, StateSlot, TensorSpec,
};

pub(crate) const MICROBATCH: usize = 4;
pub(crate) const VARPROBE_BIG_FACTOR: usize = 4;
const NORM_DIMS: [usize; 3] = [128, 256, 512];

struct Cfg {
    name: &'static str,
    paper: &'static str,
    vocab: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    d_ff: usize,
    seq_len: usize,
    batch: usize,
    gpt2: bool,
}

/// The configs.py size table, plus debug-fast smoke sizes.
fn native_cfgs() -> Vec<Cfg> {
    let c = |name, paper, vocab, d_model, n_layers, n_heads, d_ff, seq_len, batch, gpt2| Cfg {
        name,
        paper,
        vocab,
        d_model,
        n_layers,
        n_heads,
        d_ff,
        seq_len,
        batch,
        gpt2,
    };
    vec![
        c("tiny", "smoke", 64, 32, 1, 2, 96, 16, 8, false),
        c("tinyg", "smoke", 64, 32, 1, 2, 64, 16, 8, true),
        c("s60m", "60M", 512, 64, 2, 2, 176, 64, 16, false),
        c("s130m", "130M", 1024, 96, 3, 3, 256, 64, 16, false),
        c("s350m", "350M", 2048, 128, 4, 4, 344, 96, 16, false),
        c("e2e", "1B/7B", 4096, 192, 4, 4, 512, 128, 16, false),
        c("gpt2s", "GPT2-M", 1024, 96, 3, 3, 384, 64, 16, true),
    ]
}

/// Variance-analysis grouping label (`_layer_of` in aot.py): the name
/// up to the first dot.
fn layer_of(name: &str) -> String {
    name.split('.').next().unwrap_or(name).to_string()
}

/// `model.param_specs(cfg)` in Rust: the canonical parameter inventory.
fn param_specs(cfg: &Cfg) -> Vec<ParamSpec> {
    let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
    let mut out = Vec::new();
    let mut push = |name: String, kind: &str, shape: Vec<usize>| {
        out.push(ParamSpec {
            layer: layer_of(&name),
            name,
            kind: kind.to_string(),
            shape,
        });
    };
    push("embed".into(), "embed", vec![v, d]);
    if cfg.gpt2 {
        push("pos_embed".into(), "matrix", vec![cfg.seq_len, d]);
    }
    for i in 0..cfg.n_layers {
        push(format!("block{i}.attn_norm"), "vector", vec![d]);
        push(format!("block{i}.wq"), "matrix", vec![d, d]);
        push(format!("block{i}.wk"), "matrix", vec![d, d]);
        push(format!("block{i}.wv"), "matrix", vec![d, d]);
        push(format!("block{i}.wo"), "matrix", vec![d, d]);
        push(format!("block{i}.mlp_norm"), "vector", vec![d]);
        if !cfg.gpt2 {
            push(format!("block{i}.w_gate"), "matrix", vec![d, f]);
        }
        push(format!("block{i}.w_up"), "matrix", vec![d, f]);
        push(format!("block{i}.w_down"), "matrix", vec![f, d]);
    }
    push("final_norm".into(), "vector", vec![d]);
    push("lm_head".into(), "head", vec![d, v]);
    out
}

fn size_info(cfg: &Cfg) -> SizeInfo {
    let params = param_specs(cfg);
    SizeInfo {
        name: cfg.name.to_string(),
        paper_size: cfg.paper.to_string(),
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_layers: cfg.n_layers,
        n_heads: cfg.n_heads,
        d_ff: cfg.d_ff,
        seq_len: cfg.seq_len,
        batch: cfg.batch,
        arch: if cfg.gpt2 { "gpt2" } else { "llama" }.to_string(),
        param_count: params.iter().map(|p| p.numel()).sum(),
        params,
    }
}

fn t_f32(name: &str, shape: Vec<usize>) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape,
        dtype: DType::F32,
    }
}

fn t_i32(name: &str, shape: Vec<usize>) -> TensorSpec {
    TensorSpec {
        name: name.to_string(),
        shape,
        dtype: DType::I32,
    }
}

fn param_tensors(info: &SizeInfo) -> Vec<TensorSpec> {
    let ps = &info.params;
    ps.iter().map(|p| t_f32(&p.name, p.shape.clone())).collect()
}

fn slot_tensors(slots: &[StateSlot]) -> Vec<TensorSpec> {
    slots.iter().map(|s| t_f32(&s.name, s.shape.clone())).collect()
}

fn artifact(
    name: &str,
    kind: &str,
    size: Option<&str>,
    optimizer: Option<&str>,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
) -> ArtifactSpec {
    ArtifactSpec {
        name: name.to_string(),
        file: format!("native://{name}"),
        kind: kind.to_string(),
        size: size.map(String::from),
        optimizer: optimizer.map(String::from),
        inputs,
        outputs,
    }
}

/// Build the complete native manifest. `dir` is kept for display only —
/// no file under it is ever read by the native executor.
pub fn native_manifest(dir: PathBuf) -> Manifest {
    let mut sizes = BTreeMap::new();
    let mut artifacts = BTreeMap::new();
    let mut state_specs = BTreeMap::new();

    for cfg in native_cfgs() {
        let info = size_info(&cfg);
        let sname = cfg.name;
        let pins = param_tensors(&info);
        let batch = t_i32("batch", vec![MICROBATCH, info.seq_len + 1]);
        let big_n = MICROBATCH * VARPROBE_BIG_FACTOR;
        let big = t_i32("big_batch", vec![big_n, info.seq_len + 1]);
        let loss = t_f32("loss", vec![]);

        let mut inputs = pins.clone();
        inputs.push(batch.clone());
        let mut outputs = vec![loss.clone()];
        outputs.extend(pins.clone());
        let name = format!("fwd_bwd_{sname}");
        let art = artifact(&name, "fwd_bwd", Some(sname), None, inputs, outputs);
        artifacts.insert(name, art);

        let mut inputs = pins.clone();
        inputs.push(batch.clone());
        let name = format!("eval_{sname}");
        let art = artifact(&name, "eval", Some(sname), None, inputs, vec![loss.clone()]);
        artifacts.insert(name, art);

        let mut inputs = pins.clone();
        inputs.push(batch.clone());
        inputs.push(big);
        let vouts: Vec<TensorSpec> = info.params.iter().map(|p| t_f32(&p.name, vec![])).collect();
        let name = format!("varprobe_{sname}");
        let art = artifact(&name, "varprobe", Some(sname), None, inputs, vouts);
        artifacts.insert(name, art);

        let name = format!("init_{sname}");
        let seed_in = vec![t_i32("seed", vec![])];
        let art = artifact(&name, "init", Some(sname), None, seed_in, pins.clone());
        artifacts.insert(name, art);

        for &opt in NATIVE_OPTIMIZERS {
            let slots = state_slots(opt, &info).expect("native optimizer must have a plan");
            let sins = slot_tensors(&slots);
            let gins: Vec<TensorSpec> = info
                .params
                .iter()
                .map(|p| t_f32(&format!("grad.{}", p.name), p.shape.clone()))
                .collect();
            let mut inputs = pins.clone();
            inputs.extend(sins.clone());
            inputs.extend(gins);
            inputs.push(t_f32("lr", vec![]));
            inputs.push(t_f32("step", vec![]));
            let mut outputs = pins.clone();
            outputs.extend(sins);
            let name = format!("update_{opt}_{sname}");
            let art = artifact(&name, "update", Some(sname), Some(opt), inputs, outputs);
            artifacts.insert(name, art);
            state_specs.insert(format!("{opt}_{sname}"), slots);
        }

        sizes.insert(sname.to_string(), info);
    }

    for d in NORM_DIMS {
        for op in ["col", "row", "sign", "ns"] {
            let name = format!("norm_{op}_{d}");
            let io = vec![t_f32("x", vec![d, d])];
            let out = vec![t_f32("y", vec![d, d])];
            artifacts.insert(name.clone(), artifact(&name, "norm", None, None, io, out));
        }
    }

    let mut paper_dims = BTreeMap::new();
    let pd = |vocab, d_model, n_layers, d_ff| PaperDims {
        vocab,
        d_model,
        n_layers,
        d_ff,
    };
    paper_dims.insert("60M".to_string(), pd(32000, 512, 8, 1376));
    paper_dims.insert("130M".to_string(), pd(32000, 768, 12, 2048));
    paper_dims.insert("350M".to_string(), pd(32000, 1024, 24, 2736));
    paper_dims.insert("1B".to_string(), pd(32000, 2048, 24, 5461));
    paper_dims.insert("7B".to_string(), pd(32000, 4096, 32, 11008));

    Manifest {
        dir,
        microbatch: MICROBATCH,
        varprobe_big_factor: VARPROBE_BIG_FACTOR,
        sizes,
        artifacts,
        state_specs,
        paper_dims,
        norm_bench_dims: NORM_DIMS.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_configs_py_param_counts() {
        let m = native_manifest(PathBuf::from("unused"));
        // param_count formula from configs.py: v*d + L*(4d² + 3df + 2d) + d + d*v
        let s = m.size("s60m").unwrap();
        let (v, d, f, l) = (512usize, 64usize, 176usize, 2usize);
        let per_block = 4 * d * d + 3 * d * f + 2 * d;
        assert_eq!(s.param_count, v * d + l * per_block + d + d * v);
        // gpt2 variant: pos-emb + 2-matrix MLP
        let g = m.size("gpt2s").unwrap();
        let (v, d, f, l, s_len) = (1024usize, 96usize, 384usize, 3usize, 64usize);
        let per_block = 4 * d * d + 2 * d * f + 2 * d;
        assert_eq!(g.param_count, v * d + s_len * d + l * per_block + d + d * v);
    }

    #[test]
    fn update_artifact_io_arity_matches_contract() {
        // the same invariant the file-manifest test pins for real artifacts
        let m = native_manifest(PathBuf::from("unused"));
        let s = m.size("tiny").unwrap();
        let a = m.artifact("update_scale_tiny").unwrap();
        let st = m.state_spec("scale", "tiny").unwrap();
        assert_eq!(a.inputs.len(), 2 * s.params.len() + st.len() + 2);
        assert_eq!(a.outputs.len(), s.params.len() + st.len());
        assert!(st.iter().any(|x| x.name == "lm_head.m"));
    }

    #[test]
    fn fwd_bwd_artifact_shapes_line_up() {
        let m = native_manifest(PathBuf::from("unused"));
        let s = m.size("tiny").unwrap();
        let a = m.artifact("fwd_bwd_tiny").unwrap();
        assert_eq!(a.inputs.len(), s.params.len() + 1);
        let batch = a.inputs.last().unwrap();
        assert_eq!(batch.shape, vec![MICROBATCH, s.seq_len + 1]);
        assert_eq!(a.outputs.len(), 1 + s.params.len());
        assert!(a.outputs[0].shape.is_empty());
        assert_eq!(a.outputs[1].shape, s.params[0].shape);
    }

    #[test]
    fn optimizers_for_covers_native_zoo() {
        let m = native_manifest(PathBuf::from("unused"));
        let opts = m.optimizers_for("s130m");
        for need in [
            "scale",
            "adam",
            "muon",
            "galore",
            "apollo_mini",
            "stable_spam",
            "mix_col_last_row_rest",
            "mix_row_first_col_rest",
            "mix_larger_dim",
            "mix_row_last_col_rest",
        ] {
            assert!(opts.iter().any(|o| o == need), "{need}");
        }
    }

    #[test]
    fn head_dim_divides_for_every_size() {
        for cfg in native_cfgs() {
            assert_eq!(cfg.d_model % cfg.n_heads, 0, "{}", cfg.name);
            assert_eq!((cfg.d_model / cfg.n_heads) % 2, 0, "{}: odd head_dim", cfg.name);
        }
    }
}
