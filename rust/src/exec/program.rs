//! [`NativeProgram`]: one manifest artifact compiled to a pure-Rust
//! executor. `runtime::client::Engine::load` constructs these whenever
//! PJRT is unavailable, so the coordinator's call sites are untouched —
//! the same `(inputs) -> outputs` contract, the same shape checks.
//!
//! Workspace ownership: model and update programs keep a pool of
//! workspaces behind a mutex (popped per call, so concurrent executions
//! — DDP shards of one trainer, or whole sweep trials sharing one
//! update program — each get their own scratch and steady-state calls
//! allocate nothing); norm programs serialize on a single workspace —
//! they are bench/table one-shots.

use std::sync::Mutex;

use crate::exec::model::{self, ModelSpec, ModelWs};
use crate::exec::ns::{ns_orth, NsWs, NS_STEPS};
use crate::exec::update::{UpdateProgram, UpdateWs};
use crate::optim::colnorm::{colnorm_into, rownorm_into, sign_into, NormWorkspace};
use crate::parallel;
use crate::runtime::artifact::{ArtifactSpec, DType, Manifest, SizeInfo};
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

/// A mutexed free-list of boxed workspaces: popped per call (so
/// concurrent executors each get their own scratch), pushed back after
/// use, and created lazily on first take — steady-state calls allocate
/// nothing. Shared by the model/update programs here and by the serve
/// engine, whose per-request KV/decode slabs (`crate::serve`) are a
/// *bounded* instance: preloaded at construction and drawn with
/// [`WsPool::try_take`], so "no free slab" is an admission decision
/// rather than an allocation.
pub(crate) struct WsPool<T>(Mutex<Vec<Box<T>>>);

impl<T> WsPool<T> {
    pub fn new() -> WsPool<T> {
        WsPool(Mutex::new(Vec::new()))
    }

    /// Pop a cached workspace, or build one with `init`.
    pub fn take(&self, init: impl FnOnce() -> T) -> Box<T> {
        let cached = self.0.lock().unwrap().pop();
        cached.unwrap_or_else(|| Box::new(init()))
    }

    /// Pop a cached workspace only, never allocating one.
    pub fn try_take(&self) -> Option<Box<T>> {
        self.0.lock().unwrap().pop()
    }

    pub fn put(&self, ws: Box<T>) {
        self.0.lock().unwrap().push(ws);
    }
}

impl<T> Default for WsPool<T> {
    fn default() -> WsPool<T> {
        WsPool::new()
    }
}

pub struct NativeProgram(Kind);

enum Kind {
    FwdBwd(ModelProg),
    Eval(ModelProg),
    VarProbe(ModelProg),
    Update(UpdateProg),
    Init(SizeInfo),
    Norm {
        op: NormOp,
        d: usize,
        ws: Mutex<NormState>,
    },
}

struct ModelProg {
    mspec: ModelSpec,
    n_params: usize,
    mb: usize,
    max_b: usize,
    /// Arena pool: one [`ModelWs`] per concurrent executor, created on
    /// first use and recycled forever after (no steady-state allocs).
    ws: WsPool<ModelWs>,
}

impl ModelProg {
    fn new(info: &SizeInfo, mb: usize, max_b: usize) -> ModelProg {
        ModelProg {
            mspec: ModelSpec::from_size(info),
            n_params: info.params.len(),
            mb,
            max_b,
            ws: WsPool::new(),
        }
    }

    fn take_ws(&self) -> Box<ModelWs> {
        self.ws.take(|| ModelWs::new(&self.mspec, self.max_b))
    }

    fn put_ws(&self, ws: Box<ModelWs>) {
        self.ws.put(ws);
    }
}

struct UpdateProg {
    prog: UpdateProgram,
    /// Workspace pool, one [`UpdateWs`] per concurrent executor:
    /// concurrent sweep trials of the same (optimizer, size) share one
    /// program, and holding a single workspace mutex across the whole
    /// update would serialize them (blocking a pool worker, which
    /// cannot drain queued jobs while parked on a lock).
    ws: WsPool<UpdateWs>,
}

#[derive(Clone, Copy)]
enum NormOp {
    Col,
    Row,
    Sign,
    Ns,
}

struct NormState {
    norm: NormWorkspace,
    ns: NsWs,
}

fn size_of<'m>(manifest: &'m Manifest, spec: &ArtifactSpec) -> anyhow::Result<&'m SizeInfo> {
    let sname = spec
        .size
        .as_deref()
        .ok_or_else(|| anyhow::anyhow!("artifact {} has no size tag", spec.name))?;
    manifest.size(sname)
}

impl NativeProgram {
    pub fn new(manifest: &Manifest, spec: &ArtifactSpec) -> anyhow::Result<NativeProgram> {
        let kind = match spec.kind.as_str() {
            "fwd_bwd" => {
                let info = size_of(manifest, spec)?;
                Kind::FwdBwd(ModelProg::new(info, manifest.microbatch, manifest.microbatch))
            }
            "eval" => {
                let info = size_of(manifest, spec)?;
                Kind::Eval(ModelProg::new(info, manifest.microbatch, manifest.microbatch))
            }
            "varprobe" => {
                let info = size_of(manifest, spec)?;
                let big = manifest.microbatch * manifest.varprobe_big_factor;
                Kind::VarProbe(ModelProg::new(info, manifest.microbatch, big))
            }
            "update" => {
                let info = size_of(manifest, spec)?;
                let opt = spec
                    .optimizer
                    .as_deref()
                    .ok_or_else(|| anyhow::anyhow!("{}: no optimizer tag", spec.name))?;
                let prog = UpdateProgram::new(opt, info)?;
                let declared = manifest.state_spec(opt, &info.name)?;
                anyhow::ensure!(
                    declared.len() == prog.n_state(),
                    "{}: state layout drift (manifest {} slots, plan {})",
                    spec.name,
                    declared.len(),
                    prog.n_state()
                );
                Kind::Update(UpdateProg {
                    prog,
                    ws: WsPool::new(),
                })
            }
            "init" => Kind::Init(size_of(manifest, spec)?.clone()),
            "norm" => {
                let rest = spec.name.strip_prefix("norm_").unwrap_or(&spec.name);
                let (op_s, d_s) = rest
                    .rsplit_once('_')
                    .ok_or_else(|| anyhow::anyhow!("bad norm artifact name {}", spec.name))?;
                let d: usize = d_s.parse()?;
                let op = match op_s {
                    "col" => NormOp::Col,
                    "row" => NormOp::Row,
                    "sign" => NormOp::Sign,
                    "ns" => NormOp::Ns,
                    other => anyhow::bail!("unknown norm op {other:?}"),
                };
                let st = NormState {
                    norm: NormWorkspace::new(),
                    ns: NsWs::new(),
                };
                Kind::Norm {
                    op,
                    d,
                    ws: Mutex::new(st),
                }
            }
            other => anyhow::bail!(
                "artifact kind {other:?} has no native executor; rebuild with --features xla"
            ),
        };
        Ok(NativeProgram(kind))
    }

    /// Execute with borrowed inputs, writing into `out`. When `out`
    /// already matches the artifact's output signature its buffers are
    /// reused in place — the steady-state zero-allocation path.
    pub fn execute_into(
        &self,
        spec: &ArtifactSpec,
        inputs: &[&Tensor],
        out: &mut Vec<Tensor>,
    ) -> anyhow::Result<()> {
        ensure_outputs(spec, out);
        let pool = parallel::shared();
        let min_ops = parallel::tuned_min_ops();
        match &self.0 {
            Kind::FwdBwd(mp) => {
                let n = mp.n_params;
                let toks = inputs[n].i32s();
                let params = &inputs[..n];
                let mut ws = mp.take_ws();
                let grads = &mut out[1..];
                let ms = &mp.mspec;
                let loss = model::fwd_bwd(ms, params, toks, mp.mb, grads, &mut ws, pool, min_ops);
                mp.put_ws(ws);
                out[0].f32s_mut()[0] = loss;
            }
            Kind::Eval(mp) => {
                let n = mp.n_params;
                let toks = inputs[n].i32s();
                let params = &inputs[..n];
                let mut ws = mp.take_ws();
                let loss = model::eval_loss(&mp.mspec, params, toks, mp.mb, &mut ws, pool, min_ops);
                mp.put_ws(ws);
                out[0].f32s_mut()[0] = loss;
            }
            Kind::VarProbe(mp) => {
                let n = mp.n_params;
                let params = &inputs[..n];
                let small = inputs[n].i32s();
                let big = inputs[n + 1].i32s();
                let big_b = big.len() / (mp.mspec.seq + 1);
                let mut gs: Vec<Tensor> = params.iter().map(|p| Tensor::zeros(p.shape())).collect();
                let mut gb = gs.clone();
                let mut ws = mp.take_ws();
                model::fwd_bwd(&mp.mspec, params, small, mp.mb, &mut gs, &mut ws, pool, min_ops);
                model::fwd_bwd(&mp.mspec, params, big, big_b, &mut gb, &mut ws, pool, min_ops);
                mp.put_ws(ws);
                for (i, (a, b)) in gs.iter().zip(&gb).enumerate() {
                    let mut s = 0.0f64;
                    for (x, y) in a.f32s().iter().zip(b.f32s()) {
                        let dxy = (*x - *y) as f64;
                        s += dxy * dxy;
                    }
                    out[i].f32s_mut()[0] = (s / a.numel() as f64) as f32;
                }
            }
            Kind::Update(up) => {
                let mut ws = up.ws.take(UpdateWs::new);
                let result = up.prog.execute(inputs, out, &mut ws, pool, min_ops);
                up.ws.put(ws);
                result?;
            }
            Kind::Init(info) => {
                let seed = inputs[0].i32s()[0] as i64 as u64;
                native_init_into(info, seed, out);
            }
            Kind::Norm { op, d, ws } => {
                let x = inputs[0].f32s();
                let mut st = ws.lock().unwrap();
                let y = out[0].f32s_mut();
                match op {
                    NormOp::Col => colnorm_into(x, *d, *d, &mut st.norm, y),
                    NormOp::Row => rownorm_into(x, *d, *d, y),
                    NormOp::Sign => sign_into(x, y),
                    NormOp::Ns => ns_orth(x, *d, *d, NS_STEPS, y, &mut st.ns, pool, min_ops),
                }
            }
        }
        Ok(())
    }
}

/// Reuse `out` if it already matches the artifact signature; otherwise
/// rebuild it with correctly shaped zero tensors (first call, or a
/// caller recycling buffers across artifacts).
fn ensure_outputs(spec: &ArtifactSpec, out: &mut Vec<Tensor>) {
    let ok = out.len() == spec.outputs.len()
        && out
            .iter()
            .zip(&spec.outputs)
            .all(|(t, s)| t.shape() == s.shape.as_slice() && t.dtype() == s.dtype);
    if ok {
        return;
    }
    out.clear();
    for s in &spec.outputs {
        out.push(match s.dtype {
            DType::F32 => Tensor::zeros(&s.shape),
            DType::I32 => Tensor::from_i32(&s.shape, vec![0; s.numel()]),
        });
    }
}

/// Native parameter init mirroring `model.init_params`' scheme (ones for
/// norm gains, N(0, 0.02) embeddings, 1/sqrt(d_in) fan-in matrices).
/// Seeds are independent per parameter; exact agreement with the jax
/// init artifact is not required (both are valid draws of the same
/// scheme), only determinism per (size, seed).
pub fn native_init(size: &SizeInfo, seed: u64) -> Vec<Tensor> {
    let ps = &size.params;
    let mut out: Vec<Tensor> = ps.iter().map(|p| Tensor::zeros(&p.shape)).collect();
    native_init_into(size, seed, &mut out);
    out
}

fn native_init_into(size: &SizeInfo, seed: u64, out: &mut [Tensor]) {
    for (i, p) in size.params.iter().enumerate() {
        let data = out[i].f32s_mut();
        let mut rng = Pcg::with_stream(seed.wrapping_add(1), i as u64);
        match (p.kind.as_str(), p.name.as_str()) {
            ("vector", _) => data.fill(1.0),
            ("embed", _) | (_, "pos_embed") => {
                for v in data.iter_mut() {
                    *v = 0.02 * rng.normal() as f32;
                }
            }
            _ => {
                let scale = 1.0 / (p.shape[0] as f32).sqrt();
                for v in data.iter_mut() {
                    *v = scale * rng.normal() as f32;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::manifest::native_manifest;
    use std::path::PathBuf;

    fn manifest() -> Manifest {
        native_manifest(PathBuf::from("unused"))
    }

    fn program(m: &Manifest, name: &str) -> NativeProgram {
        NativeProgram::new(m, m.artifact(name).unwrap()).unwrap()
    }

    fn tiny_inputs(m: &Manifest) -> (Vec<Tensor>, Tensor) {
        let info = m.size("tiny").unwrap();
        let params = native_init(info, 3);
        let w = info.seq_len + 1;
        let mb = m.microbatch;
        let toks: Vec<i32> = (0..mb * w).map(|i| (i % info.vocab) as i32).collect();
        (params, Tensor::from_i32(&[mb, w], toks))
    }

    #[test]
    fn fwd_bwd_program_runs_and_reuses_buffers() {
        let m = manifest();
        let prog = program(&m, "fwd_bwd_tiny");
        let spec = m.artifact("fwd_bwd_tiny").unwrap();
        let (params, batch) = tiny_inputs(&m);
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&batch);
        let mut out = Vec::new();
        prog.execute_into(spec, &inputs, &mut out).unwrap();
        assert_eq!(out.len(), 1 + params.len());
        let loss1 = out[0].item_f32();
        assert!(loss1.is_finite() && loss1 > 0.0);
        let ptr_before = out[1].f32s().as_ptr();
        prog.execute_into(spec, &inputs, &mut out).unwrap();
        assert_eq!(out[1].f32s().as_ptr(), ptr_before, "grad buffer must be reused");
        assert_eq!(out[0].item_f32(), loss1, "same inputs -> bit-identical loss");
    }

    #[test]
    fn eval_matches_fwd_bwd_loss() {
        let m = manifest();
        let fwd = program(&m, "fwd_bwd_tiny");
        let evl = program(&m, "eval_tiny");
        let (params, batch) = tiny_inputs(&m);
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&batch);
        let spec_f = m.artifact("fwd_bwd_tiny").unwrap();
        let mut out_f = Vec::new();
        fwd.execute_into(spec_f, &inputs, &mut out_f).unwrap();
        let spec_e = m.artifact("eval_tiny").unwrap();
        let mut out_e = Vec::new();
        evl.execute_into(spec_e, &inputs, &mut out_e).unwrap();
        assert_eq!(out_f[0].item_f32(), out_e[0].item_f32());
    }

    #[test]
    fn init_program_is_seed_deterministic() {
        let m = manifest();
        let prog = program(&m, "init_tiny");
        let spec = m.artifact("init_tiny").unwrap();
        let seed5 = Tensor::scalar_i32(5);
        let seed6 = Tensor::scalar_i32(6);
        let mut a = Vec::new();
        prog.execute_into(spec, &[&seed5], &mut a).unwrap();
        let mut b = Vec::new();
        prog.execute_into(spec, &[&seed5], &mut b).unwrap();
        let mut c = Vec::new();
        prog.execute_into(spec, &[&seed6], &mut c).unwrap();
        assert_eq!(a[0].f32s(), b[0].f32s());
        assert_ne!(a[0].f32s(), c[0].f32s());
        // norm gains are ones regardless of seed
        let info = m.size("tiny").unwrap();
        let gain_idx = info.params.iter().position(|p| p.kind == "vector").unwrap();
        assert!(a[gain_idx].f32s().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn varprobe_outputs_are_nonnegative_scalars() {
        let m = manifest();
        let prog = program(&m, "varprobe_tiny");
        let spec = m.artifact("varprobe_tiny").unwrap();
        let info = m.size("tiny").unwrap();
        let (params, small) = tiny_inputs(&m);
        let w = info.seq_len + 1;
        let big_n = m.microbatch * m.varprobe_big_factor;
        let toks: Vec<i32> = (0..big_n * w).map(|i| (i % info.vocab) as i32).collect();
        let big = Tensor::from_i32(&[big_n, w], toks);
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&small);
        inputs.push(&big);
        let mut out = Vec::new();
        prog.execute_into(spec, &inputs, &mut out).unwrap();
        assert_eq!(out.len(), info.params.len());
        for t in &out {
            assert!(t.shape().is_empty());
            assert!(t.item_f32() >= 0.0);
        }
    }

    #[test]
    fn norm_programs_match_native_kernels() {
        let m = manifest();
        let d = 128usize;
        let mut rng = Pcg::new(7);
        let x: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        let xt = Tensor::from_f32(&[d, d], x.clone());
        for op in ["col", "row", "sign"] {
            let name = format!("norm_{op}_{d}");
            let prog = program(&m, &name);
            let spec = m.artifact(&name).unwrap();
            let mut out = Vec::new();
            prog.execute_into(spec, &[&xt], &mut out).unwrap();
            let want = match op {
                "col" => crate::optim::colnorm::colnorm(&x, d, d),
                "row" => crate::optim::colnorm::rownorm(&x, d, d),
                _ => crate::optim::colnorm::sign(&x),
            };
            assert_eq!(out[0].f32s(), &want[..], "{op}");
        }
        let prog = program(&m, "norm_ns_128");
        let spec = m.artifact("norm_ns_128").unwrap();
        let mut out = Vec::new();
        prog.execute_into(spec, &[&xt], &mut out).unwrap();
        assert!(out[0].f32s().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn unsupported_kind_errors_clearly() {
        let m = manifest();
        let mut spec = m.artifact("fwd_bwd_tiny").unwrap().clone();
        spec.kind = "mystery".into();
        let err = NativeProgram::new(&m, &spec).unwrap_err().to_string();
        assert!(err.contains("native executor"), "{err}");
    }
}
