//! The dot/axpy microkernels every heavy loop in the native executor
//! bottoms out in: [`dot8`] (contiguous dot product, fixed 8-lane
//! accumulation) and [`axpy8`] (in-place `y += alpha * x`). `gemm` builds
//! its three matmul orientations on them, `model` uses them directly in
//! the attention inner loops, and `ns` uses [`dot8`] for its Frobenius
//! prescale — one module to vectorize, one association contract to audit.
//!
//! # The 8-lane association contract
//!
//! [`dot8`] accumulates into eight independent lanes (`acc[l] += a[8i+l]
//! * b[8i+l]`), reduces them with a fixed pairwise tree, and folds the
//! `len % 8` tail in sequentially. The association depends only on the
//! slice *length* — never on the caller's tiling, the worker-pool size,
//! or the build flavor — which is what makes every kernel built on top
//! bit-stable (see the determinism contract in [`super`]'s module docs).
//! [`axpy8`] is elementwise, so it has no association to pin; it is
//! bit-stable by construction.
//!
//! # The `simd` cargo feature
//!
//! Off by default, `--features simd` swaps in explicit `core::arch`
//! x86-64 intrinsics. At runtime the first kernel call probes
//! `is_x86_feature_detected!("avx2")` + `("fma")` once (memoized in an
//! atomic); on CPUs without both, every call falls back to the scalar
//! path — the feature can never make a binary crash on older hardware,
//! only make it faster on newer hardware.
//!
//! The vector bodies mirror the scalar ones exactly: one 256-bit lane
//! register holds the same eight accumulators, combined by
//! `mul` + `add` — deliberately **not** `fmadd`, whose fused single
//! rounding would diverge from the scalar path's two roundings — and the
//! horizontal reduction replays the same pairwise tree on the stored
//! lanes. SIMD output is therefore bit-identical to the scalar output
//! (property-tested below on AVX2 hardware), so `--features simd`
//! changes no computed number anywhere in the crate: the same contract
//! the worker pool makes for parallelism, made for vectorization.

use crate::optim::rules::axpy_;

/// Contiguous dot product with a fixed 8-lane accumulation order. The
/// association depends only on the slice length (see the module docs),
/// so every GEMM tiling built on it is bit-stable.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        // The length check keeps this safe fn sound: the AVX body reads
        // raw pointers off `a.len()`, so mismatched slices (a programmer
        // error — every in-crate caller passes equal lengths) must take
        // the scalar path and get its defined index-panic behavior.
        if a.len() == b.len() && avx::enabled() {
            // SAFETY: lengths are equal and `enabled()` verified
            // AVX2 and FMA at runtime.
            return unsafe { avx::dot8_avx2(a, b) };
        }
    }
    dot8_scalar(a, b)
}

/// In-place `y += alpha * x` over contiguous slices (zipped to the
/// shorter length, like [`crate::optim::rules::axpy_`], which is the
/// scalar body). Elementwise, hence bit-stable under any tiling.
#[inline]
pub fn axpy8(y: &mut [f32], alpha: f32, x: &[f32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if avx::enabled() {
            // SAFETY: `enabled()` verified AVX2 and FMA at runtime.
            unsafe { avx::axpy8_avx2(y, alpha, x) };
            return;
        }
    }
    axpy_(y, alpha, x);
}

/// The portable body of [`dot8`]: eight accumulator lanes, a fixed
/// pairwise reduction tree, a sequential tail. Auto-vectorizes well; the
/// `simd` feature's explicit path must match it bit for bit.
fn dot8_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let ia = &a[i * 8..i * 8 + 8];
        let ib = &b[i * 8..i * 8 + 8];
        for l in 0..8 {
            acc[l] += ia[l] * ib[l];
        }
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx {
    //! Explicit AVX2 bodies. Every intrinsic sequence is lane-for-lane
    //! the scalar loop: `mul` + `add` (two roundings, never `fmadd`'s
    //! one) and the identical pairwise horizontal tree, so the outputs
    //! are bit-identical to the scalar kernels — asserted by the
    //! property tests in the parent module.

    use core::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };
    use std::sync::atomic::{AtomicU8, Ordering};

    /// Runtime AVX2+FMA probe, memoized (0 = unknown, 1 = yes, 2 = no).
    /// FMA is required by the gate even though the kernels avoid fused
    /// ops: it pins the detected baseline to the CPUs this path was
    /// validated on, and future kernels that *can* fuse without changing
    /// bits may rely on it.
    pub(super) fn enabled() -> bool {
        static DETECTED: AtomicU8 = AtomicU8::new(0);
        match DETECTED.load(Ordering::Relaxed) {
            1 => true,
            2 => false,
            _ => {
                let ok = is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
                DETECTED.store(if ok { 1 } else { 2 }, Ordering::Relaxed);
                ok
            }
        }
    }

    /// # Safety
    /// The CPU must support AVX2 (callers go through [`enabled`]) and
    /// `b` must be at least as long as `a`: the vector loads index `b`
    /// by raw pointer off `a.len()`.
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn dot8_avx2(a: &[f32], b: &[f32]) -> f32 {
        let chunks = a.len() / 8;
        let mut acc = _mm256_setzero_ps();
        for i in 0..chunks {
            let va = _mm256_loadu_ps(a.as_ptr().add(i * 8));
            let vb = _mm256_loadu_ps(b.as_ptr().add(i * 8));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut tail = 0.0f32;
        for i in chunks * 8..a.len() {
            tail += a[i] * b[i];
        }
        let tree = ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
            + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]));
        tree + tail
    }

    /// # Safety
    /// The CPU must support AVX2 (callers go through [`enabled`]).
    #[target_feature(enable = "avx2,fma")]
    pub(super) unsafe fn axpy8_avx2(y: &mut [f32], alpha: f32, x: &[f32]) {
        let n = y.len().min(x.len());
        let va = _mm256_set1_ps(alpha);
        let chunks = n / 8;
        for i in 0..chunks {
            let vy = _mm256_loadu_ps(y.as_ptr().add(i * 8));
            let vx = _mm256_loadu_ps(x.as_ptr().add(i * 8));
            let sum = _mm256_add_ps(vy, _mm256_mul_ps(va, vx));
            _mm256_storeu_ps(y.as_mut_ptr().add(i * 8), sum);
        }
        for i in chunks * 8..n {
            y[i] += alpha * x[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn vecs(rng: &mut Pcg, n: usize) -> (Vec<f32>, Vec<f32>) {
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        (a, b)
    }

    #[test]
    fn dot8_association_is_length_only() {
        // the same data dotted through different call sites (subslices of
        // identical length) must agree exactly, and every length from the
        // empty slice through several 8-lane chunks plus tails is defined
        let mut rng = Pcg::new(3);
        let (a, b) = vecs(&mut rng, 100);
        assert_eq!(dot8(&a, &b), dot8(&a[..100], &b[..100]));
        for n in 0..40 {
            assert!(dot8(&a[..n], &b[..n]).is_finite());
        }
    }

    #[test]
    fn dot8_matches_f64_reference() {
        let mut rng = Pcg::new(5);
        for n in [0usize, 1, 7, 8, 9, 16, 63, 64, 65, 200] {
            let (a, b) = vecs(&mut rng, n);
            let pairs = a.iter().zip(&b);
            let want: f64 = pairs.map(|(x, y)| (*x as f64) * (*y as f64)).sum();
            let got = dot8(&a, &b) as f64;
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn axpy8_matches_scalar_body() {
        // axpy8 must equal the optim-layer scalar kernel bit for bit on
        // every length (including the zipped-to-shorter contract)
        let mut rng = Pcg::new(7);
        for n in [0usize, 1, 5, 8, 13, 32, 77] {
            let (y0, x) = vecs(&mut rng, n);
            let mut fast = y0.clone();
            axpy8(&mut fast, 1.25, &x);
            let mut slow = y0.clone();
            axpy_(&mut slow, 1.25, &x);
            assert_eq!(fast, slow, "n={n}");
        }
        let mut y = vec![1.0f32; 4];
        axpy8(&mut y, 2.0, &[10.0, -10.0]);
        assert_eq!(y, vec![21.0, -19.0, 1.0, 1.0]);
    }

    #[test]
    fn public_entry_points_match_scalar_bodies_bitwise() {
        // on a non-simd build this is an identity check; with `--features
        // simd` on AVX2 hardware it is the core acceptance property: the
        // intrinsic path produces the very same bits as the scalar path
        let mut rng = Pcg::new(11);
        for n in [0usize, 1, 3, 8, 15, 16, 31, 64, 100, 257] {
            let (a, b) = vecs(&mut rng, n);
            assert_eq!(dot8(&a, &b).to_bits(), dot8_scalar(&a, &b).to_bits(), "dot n={n}");
            let mut fast = a.clone();
            let mut slow = a.clone();
            axpy8(&mut fast, -0.75, &b);
            axpy_(&mut slow, -0.75, &b);
            assert_eq!(fast, slow, "axpy n={n}");
        }
        // (mismatched dot8 lengths are a caller bug: debug builds fire
        // the debug_assert, and release builds stay sound because the
        // simd dispatch requires equal lengths before touching raw
        // pointers — no test can exercise both without tripping one)
    }

    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    #[test]
    fn simd_path_bit_identical_or_gracefully_absent() {
        if !avx::enabled() {
            // unsupported CPU: the public entry points must have fallen
            // back to the scalar path (already covered above) — nothing
            // to compare, and nothing may have crashed getting here
            println!("skipping AVX2 bit-identity sweep: cpu lacks avx2+fma");
            return;
        }
        let mut rng = Pcg::new(13);
        for trial in 0..64usize {
            let n = (trial * 13) % 300;
            let (a, b) = vecs(&mut rng, n);
            // SAFETY: enabled() verified AVX2+FMA above.
            let vect = unsafe { avx::dot8_avx2(&a, &b) };
            assert_eq!(vect.to_bits(), dot8_scalar(&a, &b).to_bits(), "dot n={n}");
            let mut fast = a.clone();
            let mut slow = a.clone();
            // SAFETY: enabled() verified AVX2+FMA above.
            unsafe { avx::axpy8_avx2(&mut fast, 0.37, &b) };
            axpy_(&mut slow, 0.37, &b);
            assert_eq!(fast, slow, "axpy n={n}");
        }
    }
}
