//! Native `update_<opt>_<size>` execution: the per-parameter rule
//! framework of `python/compile/optimizers.py` in pure Rust.
//!
//! Every optimizer is a plan — one `Rule` plus state-slot inventory
//! per model parameter, in canonical order — and `execute` walks the
//! plan with a cursor over the flat state list, exactly like the Python
//! layer, so the state layout in checkpoints and the manifest is
//! identical across executors. `plan_rules` is the single source of
//! truth for that plan: [`state_slots`] (hence the manifest's
//! `state_specs`, checkpoints, and the memory estimator) and
//! [`UpdateProgram`] (hence the executable and the mesh
//! [`UpdateProgram::shard_plan`]) both derive from it.
//!
//! The SCALE and Adam hot paths route through the `optim::rules`
//! workspace kernels (`scale_plain_ws_par_with`, `scale_momentum_ws_par_with`,
//! `adam`) — the executable path is bit-identical to calling those
//! kernels directly, which the integration suite property-tests. The
//! Table-13 `mix_*` ablations are pure compositions of the same
//! col/row/momentum kernels selected per parameter kind (the property
//! tests below pin each composition bit-for-bit across pool sizes and
//! thresholds). The frontier family generalizes the paper's rule along
//! two axes: the AdaPM optimizers (`adapm_*`) turn SCALE's hardcoded
//! lm_head momentum into a declarative [`MomentumPolicy`] resolved per
//! parameter at plan-build time, and `adams` (AdamS) replaces the
//! column-norm denominator with the momentum itself
//! (`optim::rules::momentum_norm` — no second-moment buffer). The
//! projection optimizers (GaLore/Fira/APOLLO) use a deterministic PCG
//! sketch in place of JAX's `fold_in` key schedule: same construction,
//! different (but fixed) random bits, refreshed on the same epoch
//! boundary (`(step-1) / 50`).

use crate::exec::gemm::{axpy, matmul_nn, matmul_tn};
use crate::exec::ns::{buf, ns_orth, NsWs, NS_STEPS};
use crate::optim::colnorm::{rownorm_into, sign_into, NormWorkspace};
use crate::optim::rules::{
    self, momentum_norm_par_with, scale_momentum_ws_par_with, scale_plain_ws_par_with, AdamHp,
};
use crate::parallel::WorkerPool;
use crate::runtime::artifact::{ParamSpec, SizeInfo, StateSlot};
use crate::runtime::Tensor;
use crate::util::rng::Pcg;

/// EMA coefficient (β₁ = 0.9) shared by every momentum rule.
pub const BETA: f32 = 0.9;
const SPAM_RESET: u32 = 500;
const SPAM_THETA: f32 = 2.0;
const PROJ_REFRESH: u32 = 50;
const PROJ_KEY: u64 = 0xA90110;

/// Optimizers the native executor can run — the complete Python
/// registry (Table-13 `mix_*` ablations included) plus the frontier
/// family: the AdaPM partial-momentum policies (`adapm_*`, one per
/// [`MomentumPolicy`]) and AdamS (`adams`, momentum-as-normalizer).
pub const NATIVE_OPTIMIZERS: &[&str] = &[
    "sgd",
    "sgd_momentum",
    "adam",
    "stable_spam",
    "sign_sgd",
    "sgd_colnorm",
    "sgd_rownorm",
    "sgd_ns",
    "scale",
    "scale_first_last",
    "ns_mmt_last",
    "muon",
    "swan",
    "galore",
    "fira",
    "apollo",
    "apollo_mini",
    "mix_col_last_row_rest",
    "mix_row_first_col_rest",
    "mix_larger_dim",
    "mix_row_last_col_rest",
    "adapm_last",
    "adapm_first_last",
    "adapm_embed_head",
    "adapm_top2",
    "adams",
];

/// Per-layer momentum placement (AdaPM, arXiv:2510.09103): which
/// matrices carry an EMA momentum buffer, generalizing SCALE's
/// hardcoded "momentum on the LM head only" into a policy axis. The
/// selected matrices run the column-normalized momentum rule, the rest
/// run the stateless column-norm rule, and vectors always keep Adam —
/// so `adapm_last` is the paper's SCALE bit for bit and
/// `adapm_embed_head` is `scale_first_last` bit for bit (the policy
/// provably generalizes, not forks, the hardcoded tables).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MomentumPolicy {
    /// Momentum on the last matrix in canonical order (the LM head) —
    /// exactly the paper's SCALE rule.
    Last,
    /// Momentum on the first transformer block's matrices plus the last
    /// matrix: the "first and last layers" reading of partial momentum.
    FirstLast,
    /// Momentum on the embedding and the head (by parameter kind) —
    /// exactly the paper's `scale_first_last` ablation.
    EmbedHead,
    /// Momentum on the K matrices where gradient variance concentrates.
    /// Fig. 4 shows variance growing toward the output, so the
    /// deterministic structural proxy is "the last K matrices in
    /// canonical order" — keeping the state layout a pure function of
    /// `(optimizer, size)` as every plan consumer requires.
    TopKVariance(usize),
}

impl MomentumPolicy {
    /// The momentum mask over `params` in canonical order. Only 2-D
    /// parameters are ever selected; vectors keep Adam regardless.
    pub fn selects(self, params: &[ParamSpec]) -> Vec<bool> {
        let is_mat: Vec<bool> = params.iter().map(|p| p.shape.len() == 2).collect();
        let last = is_mat.iter().rposition(|&b| b);
        let mut sel = vec![false; params.len()];
        match self {
            MomentumPolicy::Last => {
                if let Some(i) = last {
                    sel[i] = true;
                }
            }
            MomentumPolicy::FirstLast => {
                for (i, p) in params.iter().enumerate() {
                    if is_mat[i] && p.layer == "block0" {
                        sel[i] = true;
                    }
                }
                if let Some(i) = last {
                    sel[i] = true;
                }
            }
            MomentumPolicy::EmbedHead => {
                for (i, p) in params.iter().enumerate() {
                    if is_mat[i] && (p.kind == "embed" || p.kind == "head") {
                        sel[i] = true;
                    }
                }
            }
            MomentumPolicy::TopKVariance(k) => {
                for i in (0..params.len()).rev().filter(|&i| is_mat[i]).take(k) {
                    sel[i] = true;
                }
            }
        }
        sel
    }
}

/// The [`MomentumPolicy`] behind a named optimizer, when it belongs to
/// the AdaPM partial-momentum family.
pub fn partial_momentum_policy(optimizer: &str) -> Option<MomentumPolicy> {
    Some(match optimizer {
        "adapm_last" => MomentumPolicy::Last,
        "adapm_first_last" => MomentumPolicy::FirstLast,
        "adapm_embed_head" => MomentumPolicy::EmbedHead,
        "adapm_top2" => MomentumPolicy::TopKVariance(2),
        _ => return None,
    })
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Rule {
    Sgd,
    SgdMomentum,
    Adam,
    StableSpam,
    ScalePlain,
    ScaleMomentum,
    RowNorm,
    RowNormMomentum,
    /// Table-13 "larger dim": colnorm when `d_in >= d_out`, rownorm
    /// otherwise (`_norm_larger_dim` in optimizers.py).
    LargerPlain,
    LargerMomentum,
    SignSgd,
    NsPlain,
    NsMomentum,
    Muon,
    Swan,
    Galore { residual: bool },
    Apollo { rank1: bool },
    /// AdaPM: the column-norm rule with the momentum bit resolved from
    /// the optimizer's [`MomentumPolicy`] at plan-build time — `true`
    /// is exactly `ScaleMomentum`, `false` exactly `ScalePlain`.
    PartialMomentum { momentum: bool },
    /// AdamS: momentum as the normalizer, `p -= lr·m/√(β₂m²+ε)` — one
    /// state buffer, no second moment.
    MomentumNorm,
}

fn rank_for(shape: &[usize]) -> usize {
    (shape[0].min(shape[1]) / 16).max(1)
}

impl Rule {
    /// State slots (suffix, shape) this rule needs for a parameter.
    fn slots(self, shape: &[usize]) -> Vec<(&'static str, Vec<usize>)> {
        match self {
            Rule::Sgd
            | Rule::ScalePlain
            | Rule::RowNorm
            | Rule::LargerPlain
            | Rule::SignSgd
            | Rule::NsPlain
            | Rule::Swan
            | Rule::PartialMomentum { momentum: false } => vec![],
            Rule::SgdMomentum
            | Rule::ScaleMomentum
            | Rule::RowNormMomentum
            | Rule::LargerMomentum
            | Rule::NsMomentum
            | Rule::Muon
            | Rule::PartialMomentum { momentum: true }
            | Rule::MomentumNorm => {
                vec![("m", shape.to_vec())]
            }
            Rule::Adam => vec![("m", shape.to_vec()), ("v", shape.to_vec())],
            Rule::StableSpam => {
                vec![("m", shape.to_vec()), ("v", shape.to_vec()), ("gmax", shape.to_vec())]
            }
            Rule::Galore { .. } => {
                let r = rank_for(shape);
                vec![
                    ("P", vec![shape[0], r]),
                    ("m", vec![r, shape[1]]),
                    ("v", vec![r, shape[1]]),
                ]
            }
            Rule::Apollo { rank1 } => {
                let r = if rank1 { 1 } else { rank_for(shape) };
                vec![("m", vec![r, shape[1]]), ("v", vec![r, shape[1]])]
            }
        }
    }
}

/// (matrix, head, embed, vector) rules for a named optimizer; `None`
/// when the optimizer has no native implementation.
fn rule_table(optimizer: &str) -> Option<[Rule; 4]> {
    use Rule::*;
    Some(match optimizer {
        "sgd" => [Sgd, Sgd, Sgd, Sgd],
        "sgd_momentum" => [SgdMomentum, SgdMomentum, SgdMomentum, Sgd],
        "adam" => [Adam, Adam, Adam, Adam],
        "stable_spam" => [StableSpam, StableSpam, StableSpam, Adam],
        "sign_sgd" => [SignSgd, SignSgd, SignSgd, Adam],
        "sgd_colnorm" => [ScalePlain, ScalePlain, ScalePlain, Adam],
        "sgd_rownorm" => [RowNorm, RowNorm, RowNorm, Adam],
        "sgd_ns" => [NsPlain, NsPlain, NsPlain, Adam],
        "scale" => [ScalePlain, ScaleMomentum, ScalePlain, Adam],
        "scale_first_last" => [ScalePlain, ScaleMomentum, ScaleMomentum, Adam],
        "ns_mmt_last" => [NsPlain, NsMomentum, NsPlain, Adam],
        "muon" => [Muon, Adam, Adam, Adam],
        "swan" => [Swan, Adam, Adam, Adam],
        "galore" => [Galore { residual: false }, Adam, Adam, Adam],
        "fira" => [Galore { residual: true }, Adam, Adam, Adam],
        "apollo" => [Apollo { rank1: false }, Adam, Adam, Adam],
        "apollo_mini" => [Apollo { rank1: true }, Adam, Adam, Adam],
        // Table-13 mixed-normalization ablations (App. M): compositions
        // of the col/row kernels with momentum only on the LM head,
        // mirroring the optimizers.py registry entry by entry
        "mix_col_last_row_rest" => [RowNorm, ScaleMomentum, RowNorm, Adam],
        "mix_row_first_col_rest" => [ScalePlain, ScaleMomentum, RowNorm, Adam],
        "mix_larger_dim" => [LargerPlain, LargerMomentum, LargerPlain, Adam],
        "mix_row_last_col_rest" => [ScalePlain, RowNormMomentum, ScalePlain, Adam],
        _ => return None,
    })
}

fn rule_for(table: &[Rule; 4], kind: &str) -> Rule {
    match kind {
        "head" => table[1],
        "embed" => table[2],
        "vector" => table[3],
        _ => table[0], // "matrix" (incl. pos_embed)
    }
}

/// The per-parameter rule plan for `(optimizer, size)`, in canonical
/// parameter order — the single source of truth every consumer derives
/// from: [`state_slots`] (hence the manifest's `state_specs`,
/// checkpoints, and the memory estimator) and [`UpdateProgram`] (hence
/// the executable and the mesh shard plan). Policy-driven optimizers
/// resolve their [`MomentumPolicy`] mask here, so a policy change can
/// never desynchronize the state layout from the executed rules.
fn plan_rules(optimizer: &str, size: &SizeInfo) -> Option<Vec<Rule>> {
    if let Some(policy) = partial_momentum_policy(optimizer) {
        let sel = policy.selects(&size.params);
        return Some(
            size.params
                .iter()
                .zip(&sel)
                .map(|(p, &momentum)| {
                    if p.kind == "vector" {
                        Rule::Adam
                    } else {
                        Rule::PartialMomentum { momentum }
                    }
                })
                .collect(),
        );
    }
    if optimizer == "adams" {
        return Some(
            size.params
                .iter()
                .map(|p| if p.kind == "vector" { Rule::Adam } else { Rule::MomentumNorm })
                .collect(),
        );
    }
    let table = rule_table(optimizer)?;
    Some(size.params.iter().map(|p| rule_for(&table, &p.kind)).collect())
}

/// The flat state inventory for `(optimizer, size)` — the single source
/// of truth behind the native manifest's `state_specs`, derived from
/// the same `plan_rules` plan the executor runs.
pub fn state_slots(optimizer: &str, size: &SizeInfo) -> Option<Vec<StateSlot>> {
    let rules = plan_rules(optimizer, size)?;
    let mut out = Vec::new();
    for (p, rule) in size.params.iter().zip(&rules) {
        for (suffix, shape) in rule.slots(&p.shape) {
            out.push(StateSlot {
                name: format!("{}.{}", p.name, suffix),
                shape,
            });
        }
    }
    Some(out)
}

/// Reusable scratch for one update program (behind the program's mutex).
pub struct UpdateWs {
    norm: NormWorkspace,
    ns: NsWs,
    dir: Vec<f32>,
    dir2: Vec<f32>,
    omega: Vec<f32>,
    g_lo: Vec<f32>,
    d_lo: Vec<f32>,
    sk: Vec<f32>,
    pack: Vec<f32>,
}

impl UpdateWs {
    pub fn new() -> UpdateWs {
        UpdateWs {
            norm: NormWorkspace::new(),
            ns: NsWs::new(),
            dir: Vec::new(),
            dir2: Vec::new(),
            omega: Vec::new(),
            g_lo: Vec::new(),
            d_lo: Vec::new(),
            sk: Vec::new(),
            pack: Vec::new(),
        }
    }
}

impl Default for UpdateWs {
    fn default() -> Self {
        Self::new()
    }
}

/// One compiled update plan: rules + slot counts aligned with the
/// parameter list.
pub struct UpdateProgram {
    rules: Vec<Rule>,
    shapes: Vec<Vec<usize>>,
    slot_counts: Vec<usize>,
    n_params: usize,
    n_state: usize,
}

/// A contiguous partition of the update plan across mesh ranks:
/// `params[r]` is rank r's parameter-index range, `state[r]` the
/// matching range over the flat state-slot list. Produced by
/// [`UpdateProgram::shard_plan`], which is a pure function of
/// `(optimizer, size, ranks)` — the supervisor and every worker compute
/// the identical plan independently, so no plan ever travels the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub params: Vec<std::ops::Range<usize>>,
    pub state: Vec<std::ops::Range<usize>>,
}

impl UpdateProgram {
    /// Compile the plan for `(optimizer, size)`. Errors when the
    /// optimizer has no native implementation.
    pub fn new(optimizer: &str, size: &SizeInfo) -> anyhow::Result<UpdateProgram> {
        let Some(rules) = plan_rules(optimizer, size) else {
            anyhow::bail!("optimizer {optimizer:?} has no native implementation");
        };
        let mut shapes = Vec::new();
        let mut slot_counts = Vec::new();
        let mut n_state = 0;
        for (p, rule) in size.params.iter().zip(&rules) {
            let slots = rule.slots(&p.shape);
            slot_counts.push(slots.len());
            n_state += slots.len();
            shapes.push(p.shape.clone());
        }
        Ok(UpdateProgram {
            n_params: rules.len(),
            rules,
            shapes,
            slot_counts,
            n_state,
        })
    }

    pub fn n_state(&self) -> usize {
        self.n_state
    }

    pub fn n_params(&self) -> usize {
        self.n_params
    }

    /// Partition the plan into `ranks` contiguous shards, balanced by
    /// parameter numel. Greedy against cumulative targets
    /// `total * (r+1) / ranks`; every rank gets at least one parameter
    /// while parameters remain (ranks beyond `n_params` get empty
    /// ranges). Deterministic: same `(optimizer, size, ranks)` → same
    /// plan, on every process.
    pub fn shard_plan(&self, ranks: usize) -> ShardPlan {
        let ranks = ranks.max(1);
        let numels: Vec<usize> = self.shapes.iter().map(|s| s.iter().product()).collect();
        let total: usize = numels.iter().sum();
        let mut params = Vec::with_capacity(ranks);
        let mut state = Vec::with_capacity(ranks);
        let mut start = 0usize;
        let mut slot_lo = 0usize;
        let mut acc = 0usize;
        for r in 0..ranks {
            let target = total * (r + 1) / ranks;
            // leave at least one parameter for each rank after this one
            let avail = self.n_params.saturating_sub(ranks - 1 - r);
            let mut end = start;
            while end < avail && (end == start || acc < target) {
                acc += numels[end];
                end += 1;
            }
            let slot_hi = slot_lo + self.slot_counts[start..end].iter().sum::<usize>();
            params.push(start..end);
            state.push(slot_lo..slot_hi);
            start = end;
            slot_lo = slot_hi;
        }
        debug_assert_eq!(start, self.n_params);
        debug_assert_eq!(slot_lo, self.n_state);
        ShardPlan { params, state }
    }

    /// Apply one optimizer step. `inputs` = `[params.., state.., grads..,
    /// lr, step]`, `out` = `[params'.., state'..]` (pre-shaped by the
    /// caller). Inputs are never mutated: outputs are copied first, then
    /// updated in place through the workspace kernels.
    pub fn execute(
        &self,
        inputs: &[&Tensor],
        out: &mut [Tensor],
        ws: &mut UpdateWs,
        pool: &WorkerPool,
        min_ops: usize,
    ) -> anyhow::Result<()> {
        let (np, nst) = (self.n_params, self.n_state);
        anyhow::ensure!(inputs.len() == 2 * np + nst + 2, "update input arity");
        anyhow::ensure!(out.len() == np + nst, "update output arity");
        let lr = inputs[2 * np + nst].item_f32();
        let step_f = inputs[2 * np + nst + 1].item_f32();
        let step = (step_f as u32).max(1);

        for i in 0..np + nst {
            out[i].f32s_mut().copy_from_slice(inputs[i].f32s());
        }
        let (params_out, state_out) = out.split_at_mut(np);
        let grads = &inputs[np + nst..2 * np + nst];
        self.execute_range(0, np, params_out, state_out, grads, lr, step, ws, pool, min_ops)
    }

    /// Apply the update for the contiguous parameter range `lo..hi` in
    /// place: `params`/`state`/`grads` hold only that range's tensors
    /// (state sliced per [`ShardPlan::state`]), while rules, shapes, and
    /// the projector sketch streams are addressed by *absolute*
    /// parameter index — so a rank applying its shard computes bit for
    /// bit what the full [`UpdateProgram::execute`] computes for the
    /// same indices. The per-parameter loop has no cross-parameter data
    /// flow, which is what makes the sharded step exact by construction.
    #[allow(clippy::too_many_arguments)]
    pub fn execute_range(
        &self,
        lo: usize,
        hi: usize,
        params: &mut [Tensor],
        state: &mut [Tensor],
        grads: &[&Tensor],
        lr: f32,
        step: u32,
        ws: &mut UpdateWs,
        pool: &WorkerPool,
        min_ops: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(lo <= hi && hi <= self.n_params, "update shard range");
        anyhow::ensure!(params.len() == hi - lo, "update shard param arity");
        anyhow::ensure!(grads.len() == hi - lo, "update shard grad arity");
        let slots: usize = self.slot_counts[lo..hi].iter().sum();
        anyhow::ensure!(state.len() == slots, "update shard state arity");
        let step = step.max(1);
        let hp = AdamHp::default();
        let (params_out, state_out) = (params, state);
        let UpdateWs { norm, ns, dir, dir2, omega, g_lo, d_lo, sk, pack } = ws;

        let mut cursor = 0usize;
        for i in lo..hi {
            let p = params_out[i - lo].f32s_mut();
            let g = grads[i - lo].f32s();
            let shape = &self.shapes[i];
            let (di, dn) = if shape.len() == 2 {
                (shape[0], shape[1])
            } else {
                (1, shape[0])
            };
            match self.rules[i] {
                Rule::Sgd => rules::sgd(p, g, lr),
                Rule::SgdMomentum => {
                    let m = state_out[cursor].f32s_mut();
                    rules::sgd_momentum(p, m, g, lr, BETA);
                }
                Rule::Adam => {
                    let (m, v) = state2(state_out, cursor);
                    rules::adam(p, m, v, g, lr, hp, step);
                }
                Rule::StableSpam => {
                    let (m, v, gmax) = state3(state_out, cursor);
                    spam_update(p, m, v, gmax, g, lr, hp, step);
                }
                Rule::ScalePlain => {
                    scale_plain_ws_par_with(pool, p, g, di, dn, lr, norm, min_ops);
                }
                Rule::ScaleMomentum | Rule::PartialMomentum { momentum: true } => {
                    let m = state_out[cursor].f32s_mut();
                    scale_momentum_ws_par_with(pool, p, m, g, di, dn, lr, BETA, norm, min_ops);
                }
                Rule::PartialMomentum { momentum: false } => {
                    scale_plain_ws_par_with(pool, p, g, di, dn, lr, norm, min_ops);
                }
                Rule::MomentumNorm => {
                    let m = state_out[cursor].f32s_mut();
                    momentum_norm_par_with(pool, p, m, g, di, dn, lr, hp, min_ops);
                }
                Rule::RowNorm => {
                    let d = buf(dir, g.len());
                    rownorm_into(g, di, dn, d);
                    axpy(p, -lr, d);
                }
                Rule::RowNormMomentum => {
                    let m = state_out[cursor].f32s_mut();
                    rules::ema_(m, g, BETA);
                    let d = buf(dir, g.len());
                    rownorm_into(m, di, dn, d);
                    axpy(p, -lr, d);
                }
                Rule::LargerPlain => {
                    if di >= dn {
                        scale_plain_ws_par_with(pool, p, g, di, dn, lr, norm, min_ops);
                    } else {
                        let d = buf(dir, g.len());
                        rownorm_into(g, di, dn, d);
                        axpy(p, -lr, d);
                    }
                }
                Rule::LargerMomentum => {
                    let m = state_out[cursor].f32s_mut();
                    if di >= dn {
                        scale_momentum_ws_par_with(pool, p, m, g, di, dn, lr, BETA, norm, min_ops);
                    } else {
                        rules::ema_(m, g, BETA);
                        let d = buf(dir, g.len());
                        rownorm_into(m, di, dn, d);
                        axpy(p, -lr, d);
                    }
                }
                Rule::SignSgd => {
                    let d = buf(dir, g.len());
                    sign_into(g, d);
                    axpy(p, -lr, d);
                }
                Rule::NsPlain => {
                    let d = buf(dir, g.len());
                    ns_orth(g, di, dn, NS_STEPS, d, ns, pool, min_ops);
                    axpy(p, -lr, d);
                }
                Rule::NsMomentum => {
                    let m = state_out[cursor].f32s_mut();
                    rules::ema_(m, g, BETA);
                    let d = buf(dir, g.len());
                    ns_orth(m, di, dn, NS_STEPS, d, ns, pool, min_ops);
                    axpy(p, -lr, d);
                }
                Rule::Muon => {
                    let m = state_out[cursor].f32s_mut();
                    rules::ema_(m, g, BETA);
                    let d = buf(dir, g.len());
                    ns_orth(m, di, dn, NS_STEPS, d, ns, pool, min_ops);
                    let scale = 0.2 * (di.max(dn) as f32).sqrt();
                    axpy(p, -lr * scale, d);
                }
                Rule::Swan => {
                    let rn = buf(dir, g.len());
                    rownorm_into(g, di, dn, rn);
                    let d = buf(dir2, g.len());
                    ns_orth(rn, di, dn, NS_STEPS, d, ns, pool, min_ops);
                    let scale = 0.2 * (di.max(dn) as f32).sqrt();
                    axpy(p, -lr * scale, d);
                }
                Rule::Galore { residual } => {
                    let (pr, m, v) = state3(state_out, cursor);
                    let r = pr.len() / di;
                    if (step - 1) % PROJ_REFRESH == 0 {
                        let om = buf(omega, dn * r);
                        fill_omega(om, r, (step - 1) / PROJ_REFRESH, i as u64);
                        let sketch = buf(sk, di * r);
                        matmul_nn(pool, min_ops, g, om, sketch, di, dn, r, pack);
                        ns_orth(sketch, di, r, NS_STEPS, pr, ns, pool, min_ops);
                    }
                    let gl = buf(g_lo, r * dn);
                    matmul_tn(pool, min_ops, pr, g, gl, r, di, dn);
                    let dl = buf(d_lo, r * dn);
                    lowrank_adam(m, v, gl, dl, hp, step);
                    let d = buf(dir, g.len());
                    matmul_nn(pool, min_ops, pr, dl, d, di, r, dn, pack);
                    if residual {
                        let pg = buf(dir2, g.len());
                        matmul_nn(pool, min_ops, pr, gl, pg, di, r, dn, pack);
                        let phi = l2(dl) / (l2(gl) + 1e-12);
                        for idx in 0..g.len() {
                            d[idx] += phi * (g[idx] - pg[idx]);
                        }
                    }
                    axpy(p, -lr, d);
                }
                Rule::Apollo { rank1 } => {
                    let (m, v) = state2(state_out, cursor);
                    let r = m.len() / dn;
                    let om = buf(omega, di * r);
                    fill_omega(om, r, (step - 1) / PROJ_REFRESH, i as u64);
                    let gl = buf(g_lo, r * dn);
                    matmul_tn(pool, min_ops, om, g, gl, r, di, dn);
                    let dl = buf(d_lo, r * dn);
                    lowrank_adam(m, v, gl, dl, hp, step);
                    if rank1 {
                        let s = l2(dl) / (l2(gl) + 1e-12);
                        axpy(p, -lr * s, g);
                    } else {
                        for j in 0..dn {
                            let mut num = 0.0f32;
                            let mut den = 0.0f32;
                            for rr in 0..r {
                                num += dl[rr * dn + j] * dl[rr * dn + j];
                                den += gl[rr * dn + j] * gl[rr * dn + j];
                            }
                            let coef = num.sqrt() / (den.sqrt() + 1e-12);
                            for row in 0..di {
                                p[row * dn + j] -= lr * g[row * dn + j] * coef;
                            }
                        }
                    }
                }
            }
            cursor += self.slot_counts[i];
        }
        Ok(())
    }
}

fn state2<'a>(st: &'a mut [Tensor], cur: usize) -> (&'a mut [f32], &'a mut [f32]) {
    let (a, b) = st[cur..cur + 2].split_at_mut(1);
    (a[0].f32s_mut(), b[0].f32s_mut())
}

fn state3<'a>(st: &'a mut [Tensor], cur: usize) -> (&'a mut [f32], &'a mut [f32], &'a mut [f32]) {
    let (a, rest) = st[cur..cur + 3].split_at_mut(1);
    let (b, c) = rest.split_at_mut(1);
    (a[0].f32s_mut(), b[0].f32s_mut(), c[0].f32s_mut())
}

fn l2(x: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for &v in x {
        s += v * v;
    }
    s.sqrt()
}

/// Deterministic pseudo-random sketch, refreshed per projector epoch —
/// the native counterpart of `_proj_omega` (values differ from JAX's,
/// the construction and refresh schedule are the same). `r` is the
/// sketch rank (the scaling denominator).
fn fill_omega(om: &mut [f32], r: usize, epoch: u32, idx: u64) {
    let mut rng = Pcg::with_stream(PROJ_KEY, (epoch as u64) * 4096 + idx);
    let inv = 1.0 / (r as f32).sqrt();
    for v in om.iter_mut() {
        *v = inv * rng.normal() as f32;
    }
}

/// Bias-corrected Adam moments in the sketch space; writes the update
/// direction `mh / (sqrt(vh) + eps)` into `d_lo`.
fn lowrank_adam(
    m: &mut [f32],
    v: &mut [f32],
    g_lo: &[f32],
    d_lo: &mut [f32],
    hp: AdamHp,
    step: u32,
) {
    let bc1 = 1.0 - hp.b1.powi(step as i32);
    let bc2 = 1.0 - hp.b2.powi(step as i32);
    for i in 0..g_lo.len() {
        m[i] = hp.b1 * m[i] + (1.0 - hp.b1) * g_lo[i];
        v[i] = hp.b2 * v[i] + (1.0 - hp.b2) * g_lo[i] * g_lo[i];
        let mh = m[i] / bc1;
        let vh = v[i] / bc2;
        d_lo[i] = mh / (vh.sqrt() + hp.eps);
    }
}

/// Stable-SPAM: spike-aware clipping (decaying |g| history) + periodic
/// momentum reset with restarted bias correction. Matches `_spam` in
/// optimizers.py.
#[allow(clippy::too_many_arguments)]
fn spam_update(
    p: &mut [f32],
    m: &mut [f32],
    v: &mut [f32],
    gmax: &mut [f32],
    g: &[f32],
    lr: f32,
    hp: AdamHp,
    step: u32,
) {
    let reset = step % SPAM_RESET == 0;
    let eff = if step < SPAM_RESET {
        step
    } else if reset {
        1
    } else {
        step % SPAM_RESET + 1
    };
    let bc1 = 1.0 - hp.b1.powi(eff as i32);
    let bc2 = 1.0 - hp.b2.powi(eff as i32);
    for i in 0..g.len() {
        let gm = (0.999 * gmax[i]).max(g[i].abs());
        gmax[i] = gm;
        let thresh = SPAM_THETA * gm + 1e-12;
        let gc = g[i].clamp(-thresh, thresh);
        let m0 = if reset { 0.0 } else { m[i] };
        let v0 = if reset { 0.0 } else { v[i] };
        let mn = hp.b1 * m0 + (1.0 - hp.b1) * gc;
        let vn = hp.b2 * v0 + (1.0 - hp.b2) * gc * gc;
        m[i] = mn;
        v[i] = vn;
        let mh = mn / bc1;
        let vh = vn / bc2;
        p[i] -= lr * mh / (vh.sqrt() + hp.eps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::ParamSpec;

    fn toy_size() -> SizeInfo {
        let params = vec![
            ParamSpec {
                name: "embed".into(),
                kind: "embed".into(),
                shape: vec![16, 4],
                layer: "embed".into(),
            },
            ParamSpec {
                name: "block0.attn_norm".into(),
                kind: "vector".into(),
                shape: vec![4],
                layer: "block0".into(),
            },
            ParamSpec {
                name: "block0.wq".into(),
                kind: "matrix".into(),
                shape: vec![4, 4],
                layer: "block0".into(),
            },
            ParamSpec {
                name: "lm_head".into(),
                kind: "head".into(),
                shape: vec![4, 16],
                layer: "lm_head".into(),
            },
        ];
        SizeInfo {
            name: "toy".into(),
            paper_size: "toy".into(),
            vocab: 16,
            d_model: 4,
            n_layers: 1,
            n_heads: 1,
            d_ff: 8,
            seq_len: 4,
            batch: 4,
            arch: "llama".into(),
            param_count: params.iter().map(|p| p.numel()).sum(),
            params,
        }
    }

    fn run_update(optimizer: &str, lr: f32, step: f32) -> (Vec<Tensor>, usize) {
        run_update_on(optimizer, lr, step, &WorkerPool::new(2), 0)
    }

    /// Same draw order as [`run_update`] (params, then grads, from one
    /// seed-5 PCG stream; state slots are zeros) with the pool and the
    /// sequential-fallback threshold parameterized, so the mix property
    /// tests can sweep both.
    fn run_update_on(
        optimizer: &str,
        lr: f32,
        step: f32,
        pool: &WorkerPool,
        min_ops: usize,
    ) -> (Vec<Tensor>, usize) {
        let size = toy_size();
        let prog = UpdateProgram::new(optimizer, &size).unwrap();
        let slots = state_slots(optimizer, &size).unwrap();
        assert_eq!(slots.len(), prog.n_state());
        let mut rng = crate::util::rng::Pcg::new(5);
        let mut inputs: Vec<Tensor> = Vec::new();
        for p in &size.params {
            let data: Vec<f32> = (0..p.numel()).map(|_| rng.normal() as f32).collect();
            inputs.push(Tensor::from_f32(&p.shape, data));
        }
        for s in &slots {
            inputs.push(Tensor::zeros(&s.shape));
        }
        for p in &size.params {
            let data: Vec<f32> = (0..p.numel()).map(|_| 0.1 * rng.normal() as f32).collect();
            inputs.push(Tensor::from_f32(&p.shape, data));
        }
        inputs.push(Tensor::scalar_f32(lr));
        inputs.push(Tensor::scalar_f32(step));
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let mut out: Vec<Tensor> = Vec::new();
        for s in &size.params {
            out.push(Tensor::zeros(&s.shape));
        }
        for s in &slots {
            out.push(Tensor::zeros(&s.shape));
        }
        let mut ws = UpdateWs::new();
        prog.execute(&refs, &mut out, &mut ws, pool, min_ops).unwrap();
        (out, size.params.len())
    }

    #[test]
    fn every_native_optimizer_steps_finitely() {
        for opt in NATIVE_OPTIMIZERS {
            let (out, np) = run_update(opt, 1e-2, 1.0);
            for (i, t) in out.iter().enumerate() {
                assert!(
                    t.f32s().iter().all(|x| x.is_finite()),
                    "{opt}: output {i} not finite"
                );
            }
            assert!(np > 0);
        }
    }

    #[test]
    fn update_is_deterministic() {
        for opt in ["scale", "adam", "galore", "apollo_mini", "stable_spam", "mix_larger_dim"] {
            let (a, _) = run_update(opt, 1e-2, 1.0);
            let (b, _) = run_update(opt, 1e-2, 1.0);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.f32s(), y.f32s(), "{opt} not deterministic");
            }
        }
    }

    #[test]
    fn scale_plan_matches_paper_state_budget() {
        // SCALE state = head momentum + Adam pairs on vectors, nothing else
        let size = toy_size();
        let slots = state_slots("scale", &size).unwrap();
        let names: Vec<&str> = slots.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["block0.attn_norm.m", "block0.attn_norm.v", "lm_head.m"]);
        // Adam doubles every parameter
        let adam = state_slots("adam", &size).unwrap();
        let total: usize = adam.iter().map(|s| s.shape.iter().product::<usize>()).sum();
        assert_eq!(total, 2 * size.param_count);
    }

    #[test]
    fn spam_first_step_bit_matches_adam() {
        // step 1, zero history: no clipping, no reset -> exactly Adam
        let g = vec![0.5f32, -2.0, 10.0, -0.01];
        let hp = AdamHp::default();
        let mut pa = vec![1.0f32; 4];
        let mut ma = vec![0.0f32; 4];
        let mut va = vec![0.0f32; 4];
        rules::adam(&mut pa, &mut ma, &mut va, &g, 0.1, hp, 1);
        let mut ps = vec![1.0f32; 4];
        let mut ms = vec![0.0f32; 4];
        let mut vs = vec![0.0f32; 4];
        let mut gmax = vec![0.0f32; 4];
        spam_update(&mut ps, &mut ms, &mut vs, &mut gmax, &g, 0.1, hp, 1);
        assert_eq!(pa, ps);
        assert_eq!(ma, ms);
        assert_eq!(va, vs);
    }

    #[test]
    fn scale_rule_routes_through_workspace_kernels() {
        // the executable path must be bit-identical to calling the
        // optim::rules kernels directly with the same inputs
        let (out, _np) = run_update("scale", 0.02, 1.0);
        let size = toy_size();
        // rebuild the same inputs (same seed) and apply rules by hand
        let mut rng = crate::util::rng::Pcg::new(5);
        let mut params: Vec<Vec<f32>> = Vec::new();
        for p in &size.params {
            params.push((0..p.numel()).map(|_| rng.normal() as f32).collect());
        }
        let mut grads: Vec<Vec<f32>> = Vec::new();
        for p in &size.params {
            grads.push((0..p.numel()).map(|_| 0.1 * rng.normal() as f32).collect());
        }
        let mut ws = NormWorkspace::new();
        // embed (16x4) and wq (4x4): stateless colnorm rule
        let mut want_embed = params[0].clone();
        rules::scale_plain_ws(&mut want_embed, &grads[0], 16, 4, 0.02, &mut ws);
        assert_eq!(out[0].f32s(), &want_embed[..]);
        let mut want_wq = params[2].clone();
        rules::scale_plain_ws(&mut want_wq, &grads[2], 4, 4, 0.02, &mut ws);
        assert_eq!(out[2].f32s(), &want_wq[..]);
        // head (4x16): momentum rule from zero state
        let mut want_head = params[3].clone();
        let mut m = vec![0.0f32; 4 * 16];
        rules::scale_momentum_ws(&mut want_head, &mut m, &grads[3], 4, 16, 0.02, BETA, &mut ws);
        assert_eq!(out[3].f32s(), &want_head[..]);
        // vector (attn_norm): Adam
        let mut want_vec = params[1].clone();
        let mut vm = vec![0.0f32; 4];
        let mut vv = vec![0.0f32; 4];
        rules::adam(&mut want_vec, &mut vm, &mut vv, &grads[1], 0.02, AdamHp::default(), 1);
        assert_eq!(out[1].f32s(), &want_vec[..]);
    }

    #[test]
    fn galore_projector_refreshes_on_schedule() {
        // P is written at step 1 (epoch 0) and untouched at step 2
        let (out1, np) = run_update("galore", 1e-2, 1.0);
        let p_slot = np; // first state slot of the first matrix param
        // find the P slot: embed is Adam (m,v), vector is Adam (m,v),
        // wq is Galore (P,m,v) -> index np + 4
        let p_idx = np + 4;
        assert!(out1[p_slot].f32s().iter().all(|x| x.is_finite()));
        let p1 = out1[p_idx].f32s();
        assert!(p1.iter().any(|&x| x != 0.0), "projector not refreshed at step 1");
        let (out2, _) = run_update("galore", 1e-2, 2.0);
        // at step 2 the projector input state was zeros and must remain so
        assert!(out2[p_idx].f32s().iter().all(|&x| x == 0.0));
    }

    // ---- Table-13 mix_* compositions ---------------------------------

    /// The composed-kernel vocabulary of the `mix_*` plans, applied
    /// sequentially — the oracle the executable path must match bit for
    /// bit. `Larger*` resolves to col/row by `d_in >= d_out`, exactly
    /// like `_norm_larger_dim` in optimizers.py.
    #[derive(Clone, Copy)]
    enum RefRule {
        ColPlain,
        ColMmt,
        RowPlain,
        RowMmt,
        LargerPlain,
        LargerMmt,
        VectorAdam,
    }

    #[allow(clippy::too_many_arguments)]
    fn apply_ref_rule(
        rule: RefRule,
        p: &mut [f32],
        st: &mut [Vec<f32>],
        g: &[f32],
        di: usize,
        dn: usize,
        lr: f32,
        ws: &mut NormWorkspace,
    ) {
        use RefRule::*;
        match rule {
            ColPlain => rules::scale_plain_ws(p, g, di, dn, lr, ws),
            ColMmt => rules::scale_momentum_ws(p, &mut st[0], g, di, dn, lr, BETA, ws),
            RowPlain => {
                let mut d = vec![0.0f32; g.len()];
                rownorm_into(g, di, dn, &mut d);
                rules::axpy_(p, -lr, &d);
            }
            RowMmt => {
                rules::ema_(&mut st[0], g, BETA);
                let mut d = vec![0.0f32; g.len()];
                rownorm_into(&st[0], di, dn, &mut d);
                rules::axpy_(p, -lr, &d);
            }
            LargerPlain => {
                let r = if di >= dn { ColPlain } else { RowPlain };
                apply_ref_rule(r, p, st, g, di, dn, lr, ws);
            }
            LargerMmt => {
                let r = if di >= dn { ColMmt } else { RowMmt };
                apply_ref_rule(r, p, st, g, di, dn, lr, ws);
            }
            VectorAdam => {
                let (m, v) = st.split_at_mut(1);
                rules::adam(p, &mut m[0], &mut v[0], g, lr, AdamHp::default(), 1);
            }
        }
    }

    #[test]
    fn mix_rules_bit_match_their_composed_kernels() {
        use RefRule::*;
        // per toy-size parameter order: embed(16x4, embed),
        // attn_norm(4, vector), wq(4x4, matrix), lm_head(4x16, head).
        // embed is tall (col branch of Larger*), the head is wide (row
        // branch), so both _norm_larger_dim arms are exercised.
        let cases: [(&str, [RefRule; 4]); 4] = [
            ("mix_col_last_row_rest", [RowPlain, VectorAdam, RowPlain, ColMmt]),
            ("mix_row_first_col_rest", [RowPlain, VectorAdam, ColPlain, ColMmt]),
            ("mix_larger_dim", [LargerPlain, VectorAdam, LargerPlain, LargerMmt]),
            ("mix_row_last_col_rest", [ColPlain, VectorAdam, ColPlain, RowMmt]),
        ];
        let size = toy_size();
        let lr = 0.02f32;
        let pools = [WorkerPool::new(0), WorkerPool::new(2), WorkerPool::new(7)];
        for (opt, rules_by_param) in cases {
            // reference: identical seed-5 draws to run_update_on, the
            // composed kernels applied sequentially in canonical order
            let mut rng = crate::util::rng::Pcg::new(5);
            let mut params: Vec<Vec<f32>> = size
                .params
                .iter()
                .map(|p| (0..p.numel()).map(|_| rng.normal() as f32).collect())
                .collect();
            let grads: Vec<Vec<f32>> = size
                .params
                .iter()
                .map(|p| (0..p.numel()).map(|_| 0.1 * rng.normal() as f32).collect())
                .collect();
            let mut ws = NormWorkspace::new();
            let mut state_ref: Vec<Vec<f32>> = Vec::new();
            for (i, p) in size.params.iter().enumerate() {
                let (di, dn) = if p.shape.len() == 2 {
                    (p.shape[0], p.shape[1])
                } else {
                    (1, p.shape[0])
                };
                let n_slots = match rules_by_param[i] {
                    VectorAdam => 2,
                    ColMmt | RowMmt | LargerMmt => 1,
                    _ => 0,
                };
                let mut st: Vec<Vec<f32>> = vec![vec![0.0f32; p.numel()]; n_slots];
                apply_ref_rule(
                    rules_by_param[i], &mut params[i], &mut st, &grads[i], di, dn, lr, &mut ws,
                );
                state_ref.extend(st);
            }
            // executable path: every pool size x thresholds straddling
            // the per-matrix numel gate (largest toy matrix = 64 elems)
            for pool in &pools {
                for min_ops in [0usize, 64, usize::MAX] {
                    let (out, np) = run_update_on(opt, lr, 1.0, pool, min_ops);
                    assert_eq!(out.len(), np + state_ref.len(), "{opt}: arity");
                    for i in 0..np {
                        assert_eq!(
                            out[i].f32s(),
                            &params[i][..],
                            "{opt}: param {i} ({} workers, min_ops {min_ops})",
                            pool.workers()
                        );
                    }
                    for (j, st) in state_ref.iter().enumerate() {
                        assert_eq!(
                            out[np + j].f32s(),
                            &st[..],
                            "{opt}: state {j} ({} workers, min_ops {min_ops})",
                            pool.workers()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn larger_dim_momentum_takes_the_colnorm_branch_on_tall_heads() {
        // a 16x4 head: d_in >= d_out, so LargerMomentum must be exactly
        // the colnorm momentum kernel (the toy size only covers the wide
        // head's rownorm branch)
        let params = vec![ParamSpec {
            name: "lm_head".into(),
            kind: "head".into(),
            shape: vec![16, 4],
            layer: "lm_head".into(),
        }];
        let size = SizeInfo {
            name: "tall".into(),
            paper_size: "tall".into(),
            vocab: 4,
            d_model: 16,
            n_layers: 0,
            n_heads: 1,
            d_ff: 8,
            seq_len: 4,
            batch: 4,
            arch: "llama".into(),
            param_count: 64,
            params,
        };
        let prog = UpdateProgram::new("mix_larger_dim", &size).unwrap();
        assert_eq!(prog.n_state(), 1);
        let mut rng = crate::util::rng::Pcg::new(11);
        let p0: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
        let g0: Vec<f32> = (0..64).map(|_| 0.1 * rng.normal() as f32).collect();
        let inputs = [
            Tensor::from_f32(&[16, 4], p0.clone()),
            Tensor::zeros(&[16, 4]),
            Tensor::from_f32(&[16, 4], g0.clone()),
            Tensor::scalar_f32(0.05),
            Tensor::scalar_f32(1.0),
        ];
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let mut out = vec![Tensor::zeros(&[16, 4]), Tensor::zeros(&[16, 4])];
        let mut ws = UpdateWs::new();
        let pool = WorkerPool::new(3);
        prog.execute(&refs, &mut out, &mut ws, &pool, 0).unwrap();
        let mut p_want = p0;
        let mut m_want = vec![0.0f32; 64];
        let mut nws = NormWorkspace::new();
        rules::scale_momentum_ws(&mut p_want, &mut m_want, &g0, 16, 4, 0.05, BETA, &mut nws);
        assert_eq!(out[0].f32s(), &p_want[..]);
        assert_eq!(out[1].f32s(), &m_want[..]);
    }

    #[test]
    fn mix_plans_carry_momentum_only_on_the_head() {
        let size = toy_size();
        for opt in [
            "mix_col_last_row_rest",
            "mix_row_first_col_rest",
            "mix_larger_dim",
            "mix_row_last_col_rest",
        ] {
            let slots = state_slots(opt, &size).unwrap();
            let names: Vec<&str> = slots.iter().map(|s| s.name.as_str()).collect();
            assert_eq!(
                names,
                vec!["block0.attn_norm.m", "block0.attn_norm.v", "lm_head.m"],
                "{opt}: mix state must equal SCALE's (vector Adam + head momentum)"
            );
        }
    }

    // ---- frontier family: AdaPM policies + AdamS ---------------------

    #[test]
    fn adapm_policies_bit_match_the_hardcoded_scale_plans() {
        // the ISSUE acceptance property: the policy axis generalizes,
        // not forks, the paper's tables — `last` IS scale, `embed+head`
        // IS scale_first_last, output for output, state for state
        for (policy_opt, table_opt) in
            [("adapm_last", "scale"), ("adapm_embed_head", "scale_first_last")]
        {
            let size = toy_size();
            assert_eq!(
                state_slots(policy_opt, &size).unwrap(),
                state_slots(table_opt, &size).unwrap(),
                "{policy_opt}: state layout must equal {table_opt}'s"
            );
            let (a, _) = run_update(policy_opt, 2e-2, 1.0);
            let (b, _) = run_update(table_opt, 2e-2, 1.0);
            assert_eq!(a.len(), b.len());
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.f32s(), y.f32s(), "{policy_opt} vs {table_opt}: output {i}");
            }
        }
    }

    #[test]
    fn momentum_policy_masks_are_pinned() {
        // toy order: embed(2-D), attn_norm(vector), wq(block0 2-D),
        // lm_head(2-D). FirstLast and TopKVariance(2) coincide here
        // (block0 has a single matrix); they diverge on real sizes,
        // which frontier_differential pins via the state tables.
        let size = toy_size();
        let cases = [
            (MomentumPolicy::Last, vec![false, false, false, true]),
            (MomentumPolicy::FirstLast, vec![false, false, true, true]),
            (MomentumPolicy::EmbedHead, vec![true, false, false, true]),
            (MomentumPolicy::TopKVariance(2), vec![false, false, true, true]),
            (MomentumPolicy::TopKVariance(99), vec![true, false, true, true]),
        ];
        for (policy, want) in cases {
            assert_eq!(policy.selects(&size.params), want, "{policy:?}");
        }
    }

    #[test]
    fn frontier_state_tables_are_pinned() {
        let size = toy_size();
        let cases: [(&str, Vec<&str>); 5] = [
            ("adapm_last", vec!["block0.attn_norm.m", "block0.attn_norm.v", "lm_head.m"]),
            (
                "adapm_first_last",
                vec!["block0.attn_norm.m", "block0.attn_norm.v", "block0.wq.m", "lm_head.m"],
            ),
            (
                "adapm_embed_head",
                vec!["embed.m", "block0.attn_norm.m", "block0.attn_norm.v", "lm_head.m"],
            ),
            (
                "adapm_top2",
                vec!["block0.attn_norm.m", "block0.attn_norm.v", "block0.wq.m", "lm_head.m"],
            ),
            (
                "adams",
                vec![
                    "embed.m",
                    "block0.attn_norm.m",
                    "block0.attn_norm.v",
                    "block0.wq.m",
                    "lm_head.m",
                ],
            ),
        ];
        for (opt, want) in cases {
            let slots = state_slots(opt, &size).unwrap();
            let names: Vec<&str> = slots.iter().map(|s| s.name.as_str()).collect();
            assert_eq!(names, want, "{opt}");
            let prog = UpdateProgram::new(opt, &size).unwrap();
            assert_eq!(prog.n_state(), slots.len(), "{opt}: plan/state desync");
        }
    }

    #[test]
    fn adams_rule_routes_through_momentum_norm_kernel() {
        // executable path vs direct kernel calls, same seed-5 draws
        let (out, _np) = run_update("adams", 0.02, 1.0);
        let size = toy_size();
        let mut rng = crate::util::rng::Pcg::new(5);
        let mut params: Vec<Vec<f32>> = Vec::new();
        for p in &size.params {
            params.push((0..p.numel()).map(|_| rng.normal() as f32).collect());
        }
        let mut grads: Vec<Vec<f32>> = Vec::new();
        for p in &size.params {
            grads.push((0..p.numel()).map(|_| 0.1 * rng.normal() as f32).collect());
        }
        let hp = AdamHp::default();
        // embed (16x4) and wq (4x4) and lm_head (4x16): momentum_norm
        for (i, (di, dn)) in [(0usize, (16usize, 4usize)), (2, (4, 4)), (3, (4, 16))] {
            let mut want = params[i].clone();
            let mut m = vec![0.0f32; di * dn];
            rules::momentum_norm(&mut want, &mut m, &grads[i], 0.02, hp);
            assert_eq!(out[i].f32s(), &want[..], "param {i}");
        }
        // vector (attn_norm): Adam
        let mut want_vec = params[1].clone();
        let mut vm = vec![0.0f32; 4];
        let mut vv = vec![0.0f32; 4];
        rules::adam(&mut want_vec, &mut vm, &mut vv, &grads[1], 0.02, hp, 1);
        assert_eq!(out[1].f32s(), &want_vec[..]);
    }
}
