//! Deterministic failpoint registry: scheduled fault injection for the
//! chaos suite and for `--faults` on the CLI.
//!
//! A failpoint is a named *site* compiled permanently into the code
//! path it guards (`crate::fault::fires("grad_nan")`). With no spec
//! installed the call is a single relaxed atomic load — no lock, no
//! allocation, no branch taken — so the zero-alloc / zero-spawn
//! steady-state gates are untouched. Installing a spec arms the
//! registry; every matching site call then increments a per-entry hit
//! counter under a mutex and fires when the counter lands in the
//! entry's scheduled range.
//!
//! ## Spec grammar
//!
//! ```text
//! spec    := entry ("," entry)*
//! entry   := [scope "/"] site "@" range
//! range   := N          fire on exactly the Nth hit (1-based)
//!          | N..M       fire on hits N through M inclusive
//!          | N..        fire on every hit from the Nth on
//!          | *          fire on every hit
//! ```
//!
//! Examples: `grad_nan@5` poisons the gradients once, at the fifth
//! training step; `save_io@1..` makes every checkpoint save fail;
//! `trial2/trial_panic@1` panics the first attempt of sweep trial 2
//! only. Hit counters are consumed as they accumulate, which is what
//! makes retries deterministic: after `grad_nan@5` has fired, hit 6
//! (the retried step) passes clean.
//!
//! ## Scopes
//!
//! A `scope/` prefix restricts an entry to call sites running inside
//! [`scoped`] on the *same thread* — the sweep engine wraps every trial
//! in `scoped("trial{i}", ..)`, so a scoped spec targets the same trial
//! index no matter which pool worker executes it or how many workers
//! exist. Scopes are thread-local and do not propagate into nested pool
//! batches dispatched onto other workers.
//!
//! ## Sites
//!
//! | site          | lives in                  | effect when fired            |
//! |---------------|---------------------------|------------------------------|
//! | `save_io`     | `Checkpoint::save`        | IO error before writing      |
//! | `save_partial`| `Checkpoint::save`        | error mid-write (torn .tmp)  |
//! | `load_io`     | `Checkpoint::load`        | IO error before reading      |
//! | `grad_nan`    | `Trainer::train_step`     | NaN written into gradients   |
//! | `trial_panic` | `sweep::run_trial`        | panic inside the trial job   |
//! | `pool_job`    | `parallel::WorkerPool`    | panic inside a pool job      |

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use anyhow::{bail, ensure, Result};

#[derive(Debug, Clone)]
struct Entry {
    scope: Option<String>,
    site: String,
    from: u64,
    to: u64,
    hits: u64,
}

/// Fast-path arm flag: `false` means [`fires`] returns immediately
/// after one relaxed load, touching neither the registry mutex nor the
/// thread-local scope.
static ARMED: AtomicBool = AtomicBool::new(false);
static ENTRIES: Mutex<Vec<Entry>> = Mutex::new(Vec::new());

thread_local! {
    static SCOPE: RefCell<Option<String>> = const { RefCell::new(None) };
}

fn lock() -> std::sync::MutexGuard<'static, Vec<Entry>> {
    // a panic while holding the lock is impossible below, but a
    // poisoned registry should keep injecting, not cascade
    ENTRIES.lock().unwrap_or_else(|p| p.into_inner())
}

fn parse_range(range: &str) -> Result<(u64, u64)> {
    if range == "*" {
        return Ok((1, u64::MAX));
    }
    if let Some((a, b)) = range.split_once("..") {
        let from: u64 = a.trim().parse().map_err(|_| {
            anyhow::anyhow!("fault spec: bad range start {a:?} (want N.. or N..M)")
        })?;
        let to = if b.trim().is_empty() {
            u64::MAX
        } else {
            b.trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec: bad range end {b:?}"))?
        };
        ensure!(from >= 1, "fault spec: hit counts are 1-based, got {from}");
        ensure!(to >= from, "fault spec: empty range {from}..{to}");
        return Ok((from, to));
    }
    let n: u64 = range
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("fault spec: bad range {range:?} (want N, N..M, N.., or *)"))?;
    ensure!(n >= 1, "fault spec: hit counts are 1-based, got {n}");
    Ok((n, n))
}

/// Install a failpoint spec (see the module docs for the grammar),
/// replacing any previous one, and arm the registry. Errors on an
/// empty or malformed spec without disturbing the installed one.
pub fn configure(spec: &str) -> Result<()> {
    let mut entries = Vec::new();
    for raw in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let Some((target, range)) = raw.split_once('@') else {
            bail!("fault spec: missing '@' in {raw:?} (want [scope/]site@range)");
        };
        let (scope, site) = match target.split_once('/') {
            Some((sc, st)) => (Some(sc.trim().to_string()), st.trim()),
            None => (None, target.trim()),
        };
        ensure!(!site.is_empty(), "fault spec: empty site in {raw:?}");
        if let Some(sc) = &scope {
            ensure!(!sc.is_empty(), "fault spec: empty scope in {raw:?}");
        }
        let (from, to) = parse_range(range.trim())?;
        entries.push(Entry { scope, site: site.to_string(), from, to, hits: 0 });
    }
    ensure!(!entries.is_empty(), "fault spec: no entries in {spec:?}");
    *lock() = entries;
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Install from the `SCALE_FAULTS` environment variable if it is set
/// and non-empty; a no-op otherwise.
pub fn configure_from_env() -> Result<()> {
    match std::env::var("SCALE_FAULTS") {
        Ok(s) if !s.trim().is_empty() => configure(&s),
        _ => Ok(()),
    }
}

/// Disarm the registry and drop all entries (and this thread's scope).
pub fn clear() {
    ARMED.store(false, Ordering::Relaxed);
    lock().clear();
    SCOPE.with(|s| *s.borrow_mut() = None);
}

/// Whether any spec is installed. When this is `false`, [`fires`] is a
/// single relaxed load.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// The injection check. Every call increments the hit counter of each
/// entry whose site (and scope, if any) matches; returns `true` when
/// at least one matching entry's counter lies in its scheduled range.
#[inline]
pub fn fires(site: &str) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    fires_slow(site)
}

#[cold]
fn fires_slow(site: &str) -> bool {
    SCOPE.with(|scope| {
        let scope = scope.borrow();
        let mut fire = false;
        for e in lock().iter_mut() {
            if e.site != site {
                continue;
            }
            if let Some(want) = &e.scope {
                if scope.as_deref() != Some(want.as_str()) {
                    continue;
                }
            }
            e.hits += 1;
            if e.hits >= e.from && e.hits <= e.to {
                fire = true;
            }
        }
        fire
    })
}

/// Run `f` with this thread's failpoint scope set to `scope`, restoring
/// the previous scope afterwards — including on unwind, so a panicking
/// scoped region (the whole point of `trial_panic`) cannot leak its
/// scope onto a reused pool worker. Free when the registry is disarmed.
pub fn scoped<T>(scope: &str, f: impl FnOnce() -> T) -> T {
    if !ARMED.load(Ordering::Relaxed) {
        return f();
    }
    struct Restore(Option<String>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            SCOPE.with(|s| *s.borrow_mut() = prev);
        }
    }
    let prev = SCOPE.with(|s| s.borrow_mut().replace(scope.to_string()));
    let _restore = Restore(prev);
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global, so tests serialize on one lock
    /// and always leave it disarmed.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disarmed_never_fires() {
        let _g = guard();
        clear();
        assert!(!armed());
        for _ in 0..100 {
            assert!(!fires("grad_nan"));
        }
    }

    #[test]
    fn single_hit_fires_once_then_passes() {
        let _g = guard();
        configure("grad_nan@3").unwrap();
        let pattern: Vec<bool> = (0..6).map(|_| fires("grad_nan")).collect();
        assert_eq!(pattern, [false, false, true, false, false, false]);
        clear();
    }

    #[test]
    fn ranges_and_star() {
        let _g = guard();
        configure("a@2..3, b@2.., c@*").unwrap();
        let a: Vec<bool> = (0..4).map(|_| fires("a")).collect();
        assert_eq!(a, [false, true, true, false]);
        let b: Vec<bool> = (0..4).map(|_| fires("b")).collect();
        assert_eq!(b, [false, true, true, true]);
        let c: Vec<bool> = (0..3).map(|_| fires("c")).collect();
        assert_eq!(c, [true, true, true]);
        clear();
    }

    #[test]
    fn sites_count_independently() {
        let _g = guard();
        configure("x@1").unwrap();
        assert!(!fires("y"));
        assert!(fires("x"), "y hits must not consume x's counter");
        clear();
    }

    #[test]
    fn scoped_entries_match_only_inside_scope() {
        let _g = guard();
        configure("trial1/p@1").unwrap();
        assert!(!fires("p"), "unscoped call must not match");
        assert!(!scoped("trial0", || fires("p")), "wrong scope");
        assert!(scoped("trial1", || fires("p")), "right scope, first hit");
        assert!(!scoped("trial1", || fires("p")), "consumed");
        clear();
    }

    #[test]
    fn scope_restored_after_panic() {
        let _g = guard();
        configure("trial9/p@*").unwrap();
        let r = std::panic::catch_unwind(|| scoped("trial9", || panic!("boom")));
        assert!(r.is_err());
        assert!(!fires("p"), "scope must not leak out of the unwound region");
        clear();
    }

    #[test]
    fn nested_scopes_restore_outer() {
        let _g = guard();
        configure("outer/p@*").unwrap();
        scoped("outer", || {
            assert!(fires("p"));
            scoped("inner", || assert!(!fires("p")));
            assert!(fires("p"), "outer scope restored after nested region");
        });
        clear();
    }

    #[test]
    fn malformed_specs_rejected() {
        let _g = guard();
        clear();
        for bad in ["", "nosigil", "x@", "x@0", "x@0..2", "x@3..2", "x@z", "/x@1", "s/@1"] {
            assert!(configure(bad).is_err(), "spec {bad:?} must be rejected");
        }
        assert!(!armed(), "failed configure must not arm the registry");
    }

    #[test]
    fn reconfigure_replaces_counters() {
        let _g = guard();
        configure("x@1").unwrap();
        assert!(fires("x"));
        configure("x@1").unwrap();
        assert!(fires("x"), "fresh spec restarts the hit counter");
        clear();
    }
}
