//! Deterministic failpoint registry: scheduled fault injection for the
//! chaos suite and for `--faults` on the CLI.
//!
//! A failpoint is a named *site* compiled permanently into the code
//! path it guards (`crate::fault::fires("grad_nan")`). With no spec
//! installed the call is a single relaxed atomic load — no lock, no
//! allocation, no branch taken — so the zero-alloc / zero-spawn
//! steady-state gates are untouched. Installing a spec arms the
//! registry; every matching site call then increments a per-entry hit
//! counter under a mutex and fires when the counter lands in the
//! entry's scheduled range.
//!
//! ## Spec grammar
//!
//! ```text
//! spec    := entry ("," entry)*
//! entry   := [scope "/"] site "@" range
//! range   := N          fire on exactly the Nth hit (1-based)
//!          | N..M       fire on hits N through M inclusive
//!          | N..        fire on every hit from the Nth on
//!          | *          fire on every hit
//! ```
//!
//! Examples: `grad_nan@5` poisons the gradients once, at the fifth
//! training step; `save_io@1..` makes every checkpoint save fail;
//! `trial2/trial_panic@1` panics the first attempt of sweep trial 2
//! only. Hit counters are consumed as they accumulate, which is what
//! makes retries deterministic: after `grad_nan@5` has fired, hit 6
//! (the retried step) passes clean.
//!
//! ## Scopes
//!
//! A `scope/` prefix restricts an entry to call sites running inside
//! [`scoped`] on the *same thread* — the sweep engine wraps every trial
//! in `scoped("trial{i}", ..)`, so a scoped spec targets the same trial
//! index no matter which pool worker executes it or how many workers
//! exist. Scopes are thread-local and do not propagate into nested pool
//! batches dispatched onto other workers.
//!
//! ## Sites
//!
//! | site           | lives in                  | effect when fired            |
//! |----------------|---------------------------|------------------------------|
//! | `save_io`      | `Checkpoint::save`        | IO error before writing      |
//! | `save_partial` | `Checkpoint::save`        | error mid-write (torn .tmp)  |
//! | `load_io`      | `Checkpoint::load`        | IO error before reading      |
//! | `grad_nan`     | `Trainer::train_step`     | NaN written into gradients   |
//! | `trial_panic`  | `sweep::run_trial`        | panic inside the trial job   |
//! | `pool_job`     | `parallel::WorkerPool`    | panic inside a pool job      |
//! | `conn_drop`    | `mesh::wire` send path    | socket shut down, send fails |
//! | `frame_corrupt`| `mesh::wire` send path    | payload byte flipped (CRC)   |
//! | `frame_delay`  | `mesh::wire` send path    | sleep past the read timeout  |
//! | `rank_exit`    | `mesh::worker` step loop  | worker process exits         |
//! | `req_malformed`| `serve::parse_request`    | request line rejected typed  |
//! | `client_drop`  | `ServeEngine::step` sweep | active slot evicted, slab    |
//! |                |                           | reclaimed (client vanished)  |
//! | `deadline`     | `ServeEngine::step` sweep | slot evicted as expired with |
//! |                |                           | its partial tokens           |
//!
//! Specs naming a site outside this table are rejected by [`configure`]
//! — a typo'd site fails loudly instead of silently never firing.
//!
//! ## Arming sources
//!
//! `--faults SPEC` on any CLI subcommand, or the `SCALE_FAULTS`
//! environment variable. When both are given the CLI flag wins
//! ([`configure_from_sources`] applies the env first, then lets the
//! flag replace it).

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::util::lock::StableMutex;
use anyhow::{bail, ensure, Result};

/// Every site name compiled into the codebase, in registration order.
pub const KNOWN_SITES: &[&str] = &[
    "save_io",
    "save_partial",
    "load_io",
    "grad_nan",
    "trial_panic",
    "pool_job",
    "conn_drop",
    "frame_corrupt",
    "frame_delay",
    "rank_exit",
    "req_malformed",
    "client_drop",
    "deadline",
];

#[derive(Debug, Clone)]
struct Entry {
    scope: Option<String>,
    site: String,
    from: u64,
    to: u64,
    hits: u64,
}

/// Fast-path arm flag: `false` means [`fires`] returns immediately
/// after one relaxed load, touching neither the registry mutex nor the
/// thread-local scope.
static ARMED: AtomicBool = AtomicBool::new(false);
// StableMutex: a panicking holder (chaos tests panic on purpose) must
// not poison the registry and cascade into unrelated failures.
static ENTRIES: StableMutex<Vec<Entry>> = StableMutex::new(Vec::new());

thread_local! {
    static SCOPE: RefCell<Option<String>> = const { RefCell::new(None) };
}

fn lock() -> std::sync::MutexGuard<'static, Vec<Entry>> {
    ENTRIES.lock()
}

fn parse_range(range: &str) -> Result<(u64, u64)> {
    if range == "*" {
        return Ok((1, u64::MAX));
    }
    if let Some((a, b)) = range.split_once("..") {
        let from: u64 = a.trim().parse().map_err(|_| {
            anyhow::anyhow!("fault spec: bad range start {a:?} (want N.. or N..M)")
        })?;
        let to = if b.trim().is_empty() {
            u64::MAX
        } else {
            b.trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec: bad range end {b:?}"))?
        };
        ensure!(from >= 1, "fault spec: hit counts are 1-based, got {from}");
        ensure!(to >= from, "fault spec: empty range {from}..{to}");
        return Ok((from, to));
    }
    let n: u64 = range
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("fault spec: bad range {range:?} (want N, N..M, N.., or *)"))?;
    ensure!(n >= 1, "fault spec: hit counts are 1-based, got {n}");
    Ok((n, n))
}

/// Install a failpoint spec (see the module docs for the grammar),
/// replacing any previous one, and arm the registry. Errors on an
/// empty or malformed spec without disturbing the installed one.
pub fn configure(spec: &str) -> Result<()> {
    let mut entries = Vec::new();
    for raw in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let Some((target, range)) = raw.split_once('@') else {
            bail!("fault spec: missing '@' in {raw:?} (want [scope/]site@range)");
        };
        let (scope, site) = match target.split_once('/') {
            Some((sc, st)) => (Some(sc.trim().to_string()), st.trim()),
            None => (None, target.trim()),
        };
        ensure!(!site.is_empty(), "fault spec: empty site in {raw:?}");
        if let Some(sc) = &scope {
            ensure!(!sc.is_empty(), "fault spec: empty scope in {raw:?}");
        }
        ensure!(
            KNOWN_SITES.contains(&site),
            "fault spec: unknown site {site:?} in {raw:?} (known: {})",
            KNOWN_SITES.join(", ")
        );
        let (from, to) = parse_range(range.trim())?;
        entries.push(Entry { scope, site: site.to_string(), from, to, hits: 0 });
    }
    ensure!(!entries.is_empty(), "fault spec: no entries in {spec:?}");
    *lock() = entries;
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Install from the `SCALE_FAULTS` environment variable if it is set
/// and non-empty; a no-op otherwise.
pub fn configure_from_env() -> Result<()> {
    match std::env::var("SCALE_FAULTS") {
        Ok(s) if !s.trim().is_empty() => configure(&s),
        _ => Ok(()),
    }
}

/// Install failpoints from both arming sources with CLI precedence:
/// `SCALE_FAULTS` is applied first, then a `--faults` spec (when given)
/// *replaces* whatever the environment installed — the flag wins.
pub fn configure_from_sources(cli: Option<&str>) -> Result<()> {
    configure_from_env()?;
    match cli {
        Some(spec) => configure(spec),
        None => Ok(()),
    }
}

/// Disarm the registry and drop all entries (and this thread's scope).
pub fn clear() {
    ARMED.store(false, Ordering::Relaxed);
    lock().clear();
    SCOPE.with(|s| *s.borrow_mut() = None);
}

/// Whether any spec is installed. When this is `false`, [`fires`] is a
/// single relaxed load.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// The injection check. Every call increments the hit counter of each
/// entry whose site (and scope, if any) matches; returns `true` when
/// at least one matching entry's counter lies in its scheduled range.
#[inline]
pub fn fires(site: &str) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    fires_slow(site)
}

#[cold]
fn fires_slow(site: &str) -> bool {
    SCOPE.with(|scope| {
        let scope = scope.borrow();
        let mut fire = false;
        for e in lock().iter_mut() {
            if e.site != site {
                continue;
            }
            if let Some(want) = &e.scope {
                if scope.as_deref() != Some(want.as_str()) {
                    continue;
                }
            }
            e.hits += 1;
            if e.hits >= e.from && e.hits <= e.to {
                fire = true;
            }
        }
        fire
    })
}

/// Run `f` with this thread's failpoint scope set to `scope`, restoring
/// the previous scope afterwards — including on unwind, so a panicking
/// scoped region (the whole point of `trial_panic`) cannot leak its
/// scope onto a reused pool worker. Free when the registry is disarmed.
pub fn scoped<T>(scope: &str, f: impl FnOnce() -> T) -> T {
    if !ARMED.load(Ordering::Relaxed) {
        return f();
    }
    struct Restore(Option<String>);
    impl Drop for Restore {
        fn drop(&mut self) {
            let prev = self.0.take();
            SCOPE.with(|s| *s.borrow_mut() = prev);
        }
    }
    let prev = SCOPE.with(|s| s.borrow_mut().replace(scope.to_string()));
    let _restore = Restore(prev);
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global, so tests serialize on one lock
    /// and always leave it disarmed. StableMutex: a failing assertion
    /// under the lock must not cascade into every later test.
    static TEST_LOCK: StableMutex<()> = StableMutex::new(());

    fn guard() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock()
    }

    #[test]
    fn disarmed_never_fires() {
        let _g = guard();
        clear();
        assert!(!armed());
        for _ in 0..100 {
            assert!(!fires("grad_nan"));
        }
    }

    #[test]
    fn single_hit_fires_once_then_passes() {
        let _g = guard();
        configure("grad_nan@3").unwrap();
        let pattern: Vec<bool> = (0..6).map(|_| fires("grad_nan")).collect();
        assert_eq!(pattern, [false, false, true, false, false, false]);
        clear();
    }

    #[test]
    fn ranges_and_star() {
        let _g = guard();
        configure("save_io@2..3, load_io@2.., grad_nan@*").unwrap();
        let a: Vec<bool> = (0..4).map(|_| fires("save_io")).collect();
        assert_eq!(a, [false, true, true, false]);
        let b: Vec<bool> = (0..4).map(|_| fires("load_io")).collect();
        assert_eq!(b, [false, true, true, true]);
        let c: Vec<bool> = (0..3).map(|_| fires("grad_nan")).collect();
        assert_eq!(c, [true, true, true]);
        clear();
    }

    #[test]
    fn sites_count_independently() {
        let _g = guard();
        configure("save_io@1").unwrap();
        assert!(!fires("load_io"));
        assert!(fires("save_io"), "load_io hits must not consume save_io's counter");
        clear();
    }

    #[test]
    fn scoped_entries_match_only_inside_scope() {
        let _g = guard();
        configure("trial1/trial_panic@1").unwrap();
        assert!(!fires("trial_panic"), "unscoped call must not match");
        assert!(!scoped("trial0", || fires("trial_panic")), "wrong scope");
        assert!(scoped("trial1", || fires("trial_panic")), "right scope, first hit");
        assert!(!scoped("trial1", || fires("trial_panic")), "consumed");
        clear();
    }

    #[test]
    fn scope_restored_after_panic() {
        let _g = guard();
        configure("trial9/trial_panic@*").unwrap();
        let r = std::panic::catch_unwind(|| scoped("trial9", || panic!("boom")));
        assert!(r.is_err());
        assert!(!fires("trial_panic"), "scope must not leak out of the unwound region");
        clear();
    }

    #[test]
    fn nested_scopes_restore_outer() {
        let _g = guard();
        configure("outer/trial_panic@*").unwrap();
        scoped("outer", || {
            assert!(fires("trial_panic"));
            scoped("inner", || assert!(!fires("trial_panic")));
            assert!(fires("trial_panic"), "outer scope restored after nested region");
        });
        clear();
    }

    #[test]
    fn malformed_specs_rejected() {
        let _g = guard();
        clear();
        let bad_specs = [
            "",                    // no entries
            "nosigil",             // missing '@'
            "grad_nan@",           // site without range
            "@3",                  // range without site
            "grad_nan@0",          // hit counts are 1-based
            "grad_nan@0..2",       // 0-based range start
            "grad_nan@3..2",       // reversed range
            "grad_nan@z",          // non-numeric range
            "/grad_nan@1",         // empty scope
            "trial1/@1",           // empty site under a scope
            "typo_site@1",         // unknown site
            "trial1/typo_site@1",  // unknown site under a scope
            "grad_nan@1, typo@2",  // one bad entry rejects the whole spec
        ];
        for bad in bad_specs {
            assert!(configure(bad).is_err(), "spec {bad:?} must be rejected");
        }
        assert!(!armed(), "failed configure must not arm the registry");
    }

    #[test]
    fn every_known_site_configures() {
        let _g = guard();
        for site in KNOWN_SITES {
            configure(&format!("{site}@1")).unwrap();
        }
        clear();
    }

    #[test]
    fn reconfigure_replaces_counters() {
        let _g = guard();
        configure("save_io@1").unwrap();
        assert!(fires("save_io"));
        configure("save_io@1").unwrap();
        assert!(fires("save_io"), "fresh spec restarts the hit counter");
        clear();
    }

    #[test]
    fn cli_spec_overrides_env() {
        let _g = guard();
        clear();
        std::env::set_var("SCALE_FAULTS", "grad_nan@1");
        let r = configure_from_sources(Some("save_io@1"));
        std::env::remove_var("SCALE_FAULTS");
        r.unwrap();
        assert!(!fires("grad_nan"), "--faults must replace the env spec entirely");
        assert!(fires("save_io"), "--faults wins when both sources are set");
        clear();
    }

    #[test]
    fn env_applies_when_no_cli_spec() {
        let _g = guard();
        clear();
        std::env::set_var("SCALE_FAULTS", "load_io@1");
        let r = configure_from_sources(None);
        std::env::remove_var("SCALE_FAULTS");
        r.unwrap();
        assert!(fires("load_io"), "SCALE_FAULTS applies when --faults is absent");
        clear();
    }
}
