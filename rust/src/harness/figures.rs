//! Figure regenerators: `scale figure <n>` → ASCII series + CSV files.
//!
//! Figures are rendered as terminal plots and, where useful, written as
//! CSV next to the working directory (`plots/fig<N>_*.csv`) so they can
//! re-plotted with any tool.

use std::fmt::Write as _;

use crate::analysis::histogram::{head_column_norms, head_grad_histograms};
use crate::analysis::tables::{opt_label, Table};
use crate::analysis::variance::run_probed_training;
use crate::coordinator::metrics::ascii_curve;
use crate::coordinator::{TrainOptions, Trainer};
use crate::harness::{default_lr, ppl_cell, train_once, RunSpec};
use crate::memory::estimator::MemoryModel;
use crate::runtime::Engine;

fn plots_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("plots");
    std::fs::create_dir_all(&d).ok();
    d
}

/// Fig. 1: perplexity vs memory Pareto scatter.
pub fn figure1(engine: &Engine, size: &str, steps: usize) -> anyhow::Result<String> {
    let opts = ["adam", "stable_spam", "muon", "galore", "fira", "apollo", "apollo_mini", "scale"];
    let mm = MemoryModel::new(engine.manifest.paper_dims["1B"]);
    let mut out = String::new();
    let mut pts = Vec::new();
    for opt in opts {
        let r = train_once(engine, &RunSpec::new(opt, size, steps))?;
        let rank = if opt == "apollo_mini" { 1 } else { 256 };
        let mem = mm.method(opt, rank).total_gb();
        println!("  [{opt}] ppl {:.2} mem(1B-scale) {mem:.2}G", r.final_ppl);
        pts.push((opt, mem, r.final_ppl));
    }
    writeln!(out, "\n== Figure 1 — perplexity vs memory (x: 1B-scale GB, y: measured ppl) ==")?;
    // simple 2D ascii scatter
    let (xmin, xmax) = (2.0f64, 9.0f64);
    let ymin = pts.iter().map(|p| p.2).fold(f64::INFINITY, f64::min);
    let ymax = pts.iter().map(|p| p.2).fold(0.0f64, f64::max);
    let (w, h) = (64usize, 16usize);
    let mut grid = vec![vec![' '; w + 14]; h];
    for (i, &(opt, mem, ppl)) in pts.iter().enumerate() {
        let x = (((mem - xmin) / (xmax - xmin)).clamp(0.0, 1.0) * (w - 1) as f64) as usize;
        let yf = ((ymax - ppl) / (ymax - ymin).max(1e-9)).clamp(0.0, 1.0);
        let y = (yf * (h - 1) as f64) as usize;
        let label = (b'A' + i as u8) as char;
        grid[y][x] = label;
        writeln!(out, "  {label} = {:<18} mem {mem:.2}G  ppl {:.2}", opt_label(opt), ppl)?;
    }
    for row in grid {
        writeln!(out, "    |{}", row.iter().collect::<String>())?;
    }
    writeln!(out, "    +{}-> memory (GB at 1B scale)", "-".repeat(w))?;
    writeln!(out, "  paper shape: SCALE on the Pareto frontier (bottom-left)")?;
    let mut csv = String::from("optimizer,mem_gb_1b,ppl\n");
    for (opt, mem, ppl) in &pts {
        writeln!(csv, "{opt},{mem},{ppl}")?;
    }
    std::fs::write(plots_dir().join("fig1_pareto.csv"), csv)?;
    Ok(out)
}

/// Fig. 2: SGD vs Adam divergence-in-practice.
pub fn figure2(engine: &Engine, size: &str, steps: usize) -> anyhow::Result<String> {
    let mut out = String::new();
    writeln!(out, "\n== Figure 2 — SGD vs Adam (training loss) ==")?;
    let mut csv = String::from("optimizer,step,loss\n");
    for (opt, lr) in [("sgd", 0.1), ("adam", 2e-3)] {
        let mut spec = RunSpec::new(opt, size, steps);
        spec.lr = Some(lr);
        let r = train_once(engine, &spec)?;
        writeln!(out, "  {} (lr {lr}):  final ppl {}", opt_label(opt), ppl_cell(r.final_ppl))?;
        writeln!(out, "{}", ascii_curve(&r.curve, 60, 10))?;
        for (s, l) in &r.curve {
            writeln!(csv, "{opt},{s},{l}")?;
        }
    }
    writeln!(out, "  paper shape: SGD stalls far above Adam at any stable LR")?;
    std::fs::write(plots_dir().join("fig2_sgd_vs_adam.csv"), csv)?;
    Ok(out)
}

/// Fig. 3: LM-head gradient histograms under row- vs column-norm.
pub fn figure3(engine: &Engine, size: &str, warm_steps: usize) -> anyhow::Result<String> {
    let opts = TrainOptions {
        size: size.into(),
        optimizer: "sgd_colnorm".into(),
        steps: warm_steps,
        base_lr: default_lr("sgd_colnorm"),
        schedule: None,
        shards: 4,
        seed: 0,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        quiet: true,
    };
    let mut tr = Trainer::new(engine, opts)?;
    for _ in 0..warm_steps {
        tr.train_step()?;
    }
    let sz = engine.manifest.size(size)?.clone();
    // one more gradient evaluation to harvest the LM-head gradient: a
    // train_step-free probe from a dedicated stream (ref-assembled
    // inputs inside grad_step — params are never cloned)
    let (_, grads) = {
        let batch = tr.encode_batch(engine.manifest.microbatch, 0xF16_3);
        tr.grad_step(&batch)?
    };
    let head = grads.last().unwrap();
    let (row_h, col_h) = head_grad_histograms(head.f32s(), sz.d_model, sz.vocab, 24);
    let mut out = String::new();
    writeln!(
        out,
        "\n== Figure 3 — LM-head gradient after normalization (step {warm_steps}) =="
    )?;
    writeln!(out, "-- (a) row-wise normalized: max |g| = {:.2} --", row_h.max_abs)?;
    out.push_str(&row_h.render(48));
    writeln!(out, "-- (b) column-wise normalized: max |g| = {:.2} --", col_h.max_abs)?;
    out.push_str(&col_h.render(48));
    writeln!(
        out,
        "  paper shape: row-wise produces extreme values (paper: up to ~150 at |V|=32k);\n  column-wise stays in an O(1) band"
    )?;
    Ok(out)
}

/// Fig. 4 (and 6/7): per-layer gradient variance during training.
pub fn figure4(
    engine: &Engine,
    size: &str,
    steps: usize,
    optimizer: &str,
) -> anyhow::Result<String> {
    let opts = TrainOptions {
        size: size.into(),
        optimizer: optimizer.into(),
        steps,
        base_lr: default_lr(optimizer),
        schedule: None,
        shards: 4,
        seed: 0,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        quiet: true,
    };
    let mut tr = Trainer::new(engine, opts)?;
    let every = (steps / 8).max(1);
    let series = run_probed_training(&mut tr, steps, every)?;
    let mut out = String::new();
    writeln!(out, "\n== Figure 4 — per-layer gradient variance ({optimizer}, {size}) ==")?;
    let mut t = Table::new("mean layer variance over probes", &["layer", "variance", "bar"]);
    let means = series.means();
    let max = means.values().cloned().fold(1e-30, f64::max);
    for (layer, v) in &means {
        t.row(vec![
            layer.clone(),
            format!("{v:.3e}"),
            "#".repeat(((v / max) * 40.0).ceil() as usize),
        ]);
    }
    out.push_str(&t.render());
    writeln!(
        out,
        "  lm_head dominates: {} (paper Fig. 4a shape)",
        series.head_dominates()
    )?;
    let mut csv = String::from("layer,step,variance\n");
    for (layer, vals) in &series.by_layer {
        for (s, v) in series.probe_steps.iter().zip(vals) {
            writeln!(csv, "{layer},{s},{v}")?;
        }
    }
    std::fs::write(plots_dir().join(format!("fig4_variance_{optimizer}.csv")), csv)?;
    Ok(out)
}

/// Fig. 5: long-run stability (loss curve, no spikes) — e2e config.
pub fn figure5(engine: &Engine, steps: usize) -> anyhow::Result<String> {
    let mut spec = RunSpec::new("scale", "e2e", steps);
    spec.eval_every = (steps / 8).max(1);
    let r = train_once(engine, &spec)?;
    let mut out = String::new();
    writeln!(out, "\n== Figure 5 — extended run stability (SCALE, e2e config) ==")?;
    out.push_str(&ascii_curve(&r.curve, 64, 12));
    writeln!(out, "  final eval ppl: {}", ppl_cell(r.final_ppl))?;
    // spike check: no training-loss step increases by > 20% of its level
    let spikes = r
        .curve
        .windows(2)
        .filter(|w| w[1].1 > w[0].1 * 1.2 && w[0].1 < 6.0)
        .count();
    writeln!(out, "  loss spikes (>20% jumps): {spikes} (paper: none)")?;
    let mut csv = String::from("step,loss\n");
    for (s, l) in &r.curve {
        writeln!(csv, "{s},{l}")?;
    }
    std::fs::write(plots_dir().join("fig5_stability.csv"), csv)?;
    Ok(out)
}

/// Fig. 8: LR sensitivity of SCALE vs Stable-SPAM.
pub fn figure8(engine: &Engine, size: &str, steps: usize) -> anyhow::Result<String> {
    use crate::coordinator::sweep::{lr_sweep, paper_lr_grid};
    let mut out = String::new();
    writeln!(out, "\n== Figure 8 — LR sensitivity ({size}, {steps} steps) ==")?;
    let mut t = Table::new("final ppl per peak LR", &["lr", "SCALE", "Adam (Stable-SPAM)"]);
    let grid = paper_lr_grid();
    let base = TrainOptions {
        size: size.into(),
        optimizer: "scale".into(),
        steps,
        base_lr: 0.0,
        schedule: None,
        shards: 4,
        seed: 0,
        eval_every: 0,
        eval_batches: 8,
        log_every: 0,
        quiet: true,
    };
    let scale_pts = lr_sweep(engine, &base, &grid)?;
    let mut spam_base = base.clone();
    spam_base.optimizer = "stable_spam".into();
    let spam_pts = lr_sweep(engine, &spam_base, &grid)?;
    let mut csv = String::from("lr,scale_ppl,spam_ppl\n");
    for (a, b) in scale_pts.iter().zip(&spam_pts) {
        t.row(vec![
            format!("{:.0e}", a.lr),
            ppl_cell(a.ppl),
            ppl_cell(b.ppl),
        ]);
        writeln!(csv, "{},{},{}", a.lr, a.ppl, b.ppl)?;
    }
    out.push_str(&t.render());
    writeln!(out, "  paper shape: both flat across a wide LR band, diverging only at extremes")?;
    std::fs::write(plots_dir().join("fig8_lr_sensitivity.csv"), csv)?;
    Ok(out)
}

/// Fig. 9: eval-perplexity curves for the core optimizers.
pub fn figure9(engine: &Engine, size: &str, steps: usize) -> anyhow::Result<String> {
    let opts = ["muon", "stable_spam", "apollo_mini", "scale"];
    let mut out = String::new();
    writeln!(out, "\n== Figure 9 — eval perplexity vs iteration ({size}) ==")?;
    let mut csv = String::from("optimizer,step,ppl\n");
    for opt in opts {
        let mut spec = RunSpec::new(opt, size, steps);
        spec.eval_every = (steps / 10).max(1);
        let r = train_once(engine, &spec)?;
        let pts: Vec<(usize, f64)> = r.eval_curve.clone();
        writeln!(out, "  {} -> final {}", opt_label(opt), ppl_cell(r.final_ppl))?;
        out.push_str(&ascii_curve(&pts, 60, 8));
        for (s, p) in &pts {
            writeln!(csv, "{opt},{s},{p}")?;
        }
    }
    writeln!(
        out,
        "  paper shape: Muon fastest early; SCALE/Stable-SPAM/APOLLO-Mini catch up late"
    )?;
    std::fs::write(plots_dir().join("fig9_curves.csv"), csv)?;
    Ok(out)
}

/// Fig. 10: LM-head column norms vs token id, early and late in training.
pub fn figure10(engine: &Engine, size: &str, steps: usize) -> anyhow::Result<String> {
    let opts = TrainOptions {
        size: size.into(),
        optimizer: "sgd_colnorm".into(),
        steps,
        base_lr: default_lr("sgd_colnorm"),
        schedule: None,
        shards: 4,
        seed: 0,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        quiet: true,
    };
    let sz = engine.manifest.size(size)?.clone();
    let mut tr = Trainer::new(engine, opts)?;
    let mut out = String::new();
    writeln!(out, "\n== Figure 10 — LM-head column norms by token id ({size}) ==")?;
    let mut csv = String::from("phase,token_id,col_norm\n");
    for (phase, upto) in [("early", steps / 4), ("late", steps)] {
        while tr.step < upto {
            tr.train_step()?;
        }
        let batch = tr.encode_batch(engine.manifest.microbatch, 0xF16_10);
        let (_, grads) = tr.grad_step(&batch)?;
        let norms = head_column_norms(grads.last().unwrap().f32s(), sz.d_model, sz.vocab);
        // bucket the first 512 token ids into 16 buckets of mean norms
        let show = norms.len().min(512);
        let buckets = 16;
        writeln!(
            out,
            "-- {phase} (step {}) — mean column norm per token-id bucket --",
            tr.step
        )?;
        let bmax = {
            let mut vals = Vec::new();
            for b in 0..buckets {
                let lo = b * show / buckets;
                let hi = ((b + 1) * show / buckets).max(lo + 1);
                let mean: f32 = norms[lo..hi].iter().sum::<f32>() / (hi - lo) as f32;
                vals.push(mean);
            }
            let m = vals.iter().cloned().fold(1e-30f32, f32::max);
            for (b, v) in vals.iter().enumerate() {
                let lo = b * show / buckets;
                writeln!(
                    out,
                    "  ids {lo:>4}+ {:>10.3e} |{}",
                    v,
                    "#".repeat(((v / m) * 40.0).ceil() as usize)
                )?;
            }
            m
        };
        let _ = bmax;
        for (i, n) in norms.iter().take(show).enumerate() {
            writeln!(csv, "{phase},{i},{n}")?;
        }
    }
    writeln!(out, "  paper shape: low (frequent) token ids carry far larger column norms")?;
    std::fs::write(plots_dir().join("fig10_col_norms.csv"), csv)?;
    Ok(out)
}
