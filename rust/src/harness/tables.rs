//! Table regenerators: `scale table <n>` → paper-vs-measured output.

use crate::analysis::tables::{opt_label, Table};
use crate::harness::{paper, ppl_cell, run_zoo, train_once, RunSpec};
use crate::memory::estimator::{measured_state_bytes, MemoryModel};
use crate::runtime::{Engine, Tensor};
use crate::util::bench::Bencher;
use crate::util::rng::Pcg;

/// Table 1: wall-clock of each normalization vs matrix dim.
/// Paper: A40 GPU at d=1024..4096; here: CPU PJRT at the manifest's bench
/// dims. Exact SVD is not reproducible (no LAPACK custom-calls in
/// xla_extension 0.5.1) — the NS row stands in, as it does for all of the
/// paper's actual training runs.
pub fn table1(engine: &Engine, budget_secs: f64) -> anyhow::Result<String> {
    let dims = engine.manifest.norm_bench_dims.clone();
    let mut t = Table::new(
        "Table 1 — normalization time (ms), measured on CPU PJRT",
        &["method", "paper (A40, d=1024/2048/4096)", "measured (ms per dim)"],
    );
    let mut bench = Bencher::with_budget(budget_secs);
    for op in ["ns", "col", "row", "sign"] {
        let mut measured = Vec::new();
        for &d in &dims {
            let name = format!("norm_{op}_{d}");
            let exe = engine.load(&name)?;
            let mut rng = Pcg::new(7);
            let x = Tensor::from_f32(
                &[d, d],
                (0..d * d).map(|_| rng.normal() as f32).collect(),
            );
            let stats = bench.bench(&format!("{op} d={d}"), || {
                engine.run_exe(&exe, std::slice::from_ref(&x)).unwrap();
            });
            measured.push(format!("{:.3}", stats.mean_ms()));
        }
        let paper_row = paper::TABLE1
            .iter()
            .find(|(o, _)| *o == op)
            .map(|(_, v)| format!("{:.2}/{:.2}/{:.2}", v[0], v[1], v[2]))
            .unwrap_or_default();
        t.row(vec![op.to_string(), paper_row, measured.join(" / ")]);
    }
    t.footnote(
        "paper's exact-SVD row omitted: LAPACK custom-calls unsupported here (DESIGN.md §3)",
    );
    t.footnote(&format!("measured dims: {dims:?} (CPU, f32, interpret-lowered kernels)"));
    Ok(t.render())
}

/// Shared engine for the 3-size perplexity tables (Tables 2/3/8).
fn size3_table(
    engine: &Engine,
    title: &str,
    rows: &[&str],
    paper_rows: &[(&str, [f64; 3])],
    sizes: &[String],
    steps: usize,
) -> anyhow::Result<String> {
    let mut t = Table::new(title, &["method", "size", "paper ppl", "measured ppl"]);
    for (si, size) in sizes.iter().enumerate() {
        let outs = run_zoo(engine, rows, size, steps, false)?;
        for r in &outs {
            let paper_v = paper::lookup3(paper_rows, &r.spec.optimizer)
                .map(|v| {
                    let idx = paper::SIZE3.iter().position(|s| s == size).unwrap_or(si);
                    let x = v[idx.min(2)];
                    if x.is_nan() {
                        "-".to_string()
                    } else {
                        format!("{x:.2}")
                    }
                })
                .unwrap_or_else(|| "-".to_string());
            t.row(vec![
                opt_label(&r.spec.optimizer).to_string(),
                size.clone(),
                paper_v,
                ppl_cell(r.final_ppl),
            ]);
        }
    }
    t.footnote(&format!(
        "measured: tiny-LLaMA family, {steps} steps, synthetic c4sim corpus — compare orderings, not magnitudes"
    ));
    Ok(t.render())
}

/// Table 2: SGD + one normalization, across sizes.
pub fn table2(engine: &Engine, sizes: &[String], steps: usize) -> anyhow::Result<String> {
    size3_table(
        engine,
        "Table 2 — gradient normalizations (perplexity)",
        &["adam", "stable_spam", "sgd_ns", "sgd_colnorm", "sgd_rownorm", "sign_sgd"],
        paper::TABLE2,
        sizes,
        steps,
    )
}

/// Table 3: normalization + last-layer momentum vs Adam.
pub fn table3(engine: &Engine, sizes: &[String], steps: usize) -> anyhow::Result<String> {
    size3_table(
        engine,
        "Table 3 — normalization + mmt-last vs Adam (perplexity)",
        &["adam", "stable_spam", "ns_mmt_last", "scale"],
        paper::TABLE3,
        sizes,
        steps,
    )
}

/// Table 4 + Appendix B: exact memory accounting at paper scale.
pub fn table4(engine: &Engine) -> anyhow::Result<String> {
    let mut t = Table::new(
        "Table 4 / Appendix B — memory (GB, bf16) at paper scale",
        &["method", "1B total", "7B total", "7B paper", "components"],
    );
    let m1 = MemoryModel::new(engine.manifest.paper_dims["1B"]);
    let m7 = MemoryModel::new(engine.manifest.paper_dims["7B"]);
    let rows: &[(&str, usize, f64, &str)] = &[
        ("sgd", 0, 13.48, "weights only"),
        ("adam", 0, 40.43, "1st+2nd EMA"),
        ("muon", 0, 26.95, "singular-val + 1st EMA"),
        ("swan", 0, 14.52, "row+sv norm, Adam first/last"),
        ("apollo", 256, 16.14, "rank-256 EMAs, Adam first/last"),
        ("apollo_mini", 1, 14.53, "rank-1 EMAs, Adam first/last"),
        ("scale", 0, 13.74, "col-wise + last-layer EMA"),
    ];
    for &(method, rank, paper7, components) in rows {
        t.row(vec![
            opt_label(method).to_string(),
            format!("{:.2}", m1.method(method, rank).total_gb()),
            format!("{:.2}", m7.method(method, rank).total_gb()),
            format!("{paper7:.2}"),
            components.to_string(),
        ]);
    }
    t.footnote("analytic reproduction of Appendix B — matches the paper exactly");
    Ok(t.render())
}

/// Table 5: main results. Perplexity measured at tiny scale; memory from
/// the paper-scale estimator AND measured state bytes of the tiny runs.
pub fn table5(engine: &Engine, sizes: &[String], steps: usize) -> anyhow::Result<String> {
    let opts = [
        "adam", "stable_spam", "muon", "galore", "fira", "swan",
        "apollo", "apollo_mini", "scale",
    ];
    let mut t = Table::new(
        "Table 5 — main results (perplexity & memory)",
        &["method", "size", "paper ppl", "measured ppl", "paper mem", "state KiB (measured)"],
    );
    for size in sizes {
        let outs = run_zoo(engine, &opts, size, steps, false)?;
        for r in &outs {
            let idx = paper::SIZE3.iter().position(|s| s == size).unwrap_or(3);
            let prow = paper::TABLE5.iter().find(|x| x.0 == r.spec.optimizer);
            let (pppl, pmem) = prow
                .map(|(_, p, m)| (p[idx.min(3)], m[idx.min(3)]))
                .unwrap_or((f64::NAN, f64::NAN));
            let kib = measured_state_bytes(&engine.manifest, &r.spec.optimizer, size)? / 1024;
            t.row(vec![
                opt_label(&r.spec.optimizer).to_string(),
                size.clone(),
                if pppl.is_nan() { "-".into() } else { format!("{pppl:.2}") },
                ppl_cell(r.final_ppl),
                if pmem.is_nan() { "-".into() } else { format!("{pmem:.2}G") },
                format!("{kib}"),
            ]);
        }
    }
    t.footnote(
        "paper mem column: real-LLaMA bf16; measured state: f32 optimizer state of the tiny run",
    );
    Ok(t.render())
}

/// Table 6: the 7B run — substituted by the `e2e` config with
/// intermediate perplexities at 25/50/75/100% of the budget.
pub fn table6(engine: &Engine, steps: usize) -> anyhow::Result<String> {
    let opts = ["apollo", "apollo_mini", "muon", "scale"];
    let mut t = Table::new(
        "Table 6 — large-model run (e2e config stands in for 7B)",
        &["method", "paper mem", "paper final ppl", "measured ppl @25/50/75/100%"],
    );
    for opt in opts {
        let mut spec = RunSpec::new(opt, "e2e", steps);
        spec.eval_every = (steps / 4).max(1);
        let r = train_once(engine, &spec)?;
        let marks: Vec<String> = r.eval_curve.iter().map(|(_, p)| format!("{p:.2}")).collect();
        let paper_row = paper::TABLE6.iter().find(|x| x.0 == opt);
        let (pmem, pfinal) = paper_row
            .map(|(_, m, v)| (*m, v[3]))
            .unwrap_or((f64::NAN, f64::NAN));
        println!("  [e2e/{opt}] final ppl {:.2}", r.final_ppl);
        t.row(vec![
            opt_label(opt).to_string(),
            format!("{pmem:.2}G"),
            format!("{pfinal:.2}"),
            marks.join(" / "),
        ]);
    }
    Ok(t.render())
}

/// Table 7: training throughput per optimizer.
pub fn table7(engine: &Engine, size: &str, steps: usize) -> anyhow::Result<String> {
    let opts = [
        "adam", "stable_spam", "muon", "galore", "fira", "apollo",
        "apollo_mini", "scale",
    ];
    let mut t = Table::new(
        "Table 7 — training throughput (tokens/sec)",
        &["method", "paper (1B, 4xH100)", "measured (tiny, 1-core CPU)", "rel. to Adam"],
    );
    let mut rows = Vec::new();
    for opt in opts {
        let r = train_once(engine, &RunSpec::new(opt, size, steps))?;
        println!("  [{size}/{opt}] {:.0} tok/s", r.tokens_per_sec);
        rows.push((opt, r.tokens_per_sec));
    }
    let adam_thr = rows.iter().find(|(o, _)| *o == "adam").map(|(_, t)| *t).unwrap_or(1.0);
    for (opt, thr) in rows {
        let paper_thr = paper::TABLE7
            .iter()
            .find(|(o, _)| *o == opt)
            .map(|(_, v)| format!("{v:.0}"))
            .unwrap_or_default();
        t.row(vec![
            opt_label(opt).to_string(),
            paper_thr,
            format!("{thr:.0}"),
            format!("{:.2}x", thr / adam_thr),
        ]);
    }
    t.footnote("paper's headline: NS-based methods ~18.5% slower; SCALE ~ Adam ~ APOLLO");
    Ok(t.render())
}

/// Table 8: adding momentum to the first (embedding) layer.
pub fn table8(engine: &Engine, sizes: &[String], steps: usize) -> anyhow::Result<String> {
    size3_table(
        engine,
        "Table 8 — momentum placement ablation (App. E)",
        &["sgd_colnorm", "scale", "scale_first_last"],
        paper::TABLE8,
        sizes,
        steps,
    )
}

/// Table 9 (App. F): architecture generality — GPT2-style block.
pub fn table9(engine: &Engine, steps: usize) -> anyhow::Result<String> {
    let opts = ["adam", "stable_spam", "muon", "galore", "fira", "apollo", "apollo_mini", "scale"];
    let outs = run_zoo(engine, &opts, "gpt2s", steps, false)?;
    let mut t = Table::new(
        "Table 9 — GPT2-style architecture (App. F)",
        &["method", "paper ppl (GPT2-M)", "measured ppl (gpt2s)"],
    );
    for r in &outs {
        let p = paper::TABLE9_GPT2
            .iter()
            .find(|(o, _)| *o == r.spec.optimizer)
            .map(|(_, v)| format!("{v:.2}"))
            .unwrap_or_default();
        t.row(vec![
            opt_label(&r.spec.optimizer).to_string(),
            p,
            ppl_cell(r.final_ppl),
        ]);
    }
    Ok(t.render())
}

/// Table 11 (App. H): overtraining at 1x/2x/4x the base budget.
pub fn table11(engine: &Engine, size: &str, base_steps: usize) -> anyhow::Result<String> {
    let opts = ["adam", "stable_spam", "muon", "fira", "apollo", "apollo_mini", "scale"];
    let mut t = Table::new(
        "Table 11 — overtraining (App. H)",
        &["method", "paper 1x/2x/4x", "measured 1x", "2x", "4x"],
    );
    let mut measured: Vec<(&str, Vec<f64>)> = opts.iter().map(|o| (*o, Vec::new())).collect();
    for mult in [1usize, 2, 4] {
        let outs = run_zoo(engine, &opts, size, base_steps * mult, false)?;
        for (slot, r) in measured.iter_mut().zip(outs) {
            slot.1.push(r.final_ppl);
        }
    }
    for (opt, ppls) in measured {
        let p = paper::TABLE11
            .iter()
            .find(|(o, _)| *o == opt)
            .map(|(_, v)| format!("{:.2}/{:.2}/{:.2}", v[0], v[1], v[2]))
            .unwrap_or_default();
        t.row(vec![
            opt_label(opt).to_string(),
            p,
            ppl_cell(ppls[0]),
            ppl_cell(ppls[1]),
            ppl_cell(ppls[2]),
        ]);
    }
    Ok(t.render())
}

/// Table 12 (App. I): finetuning. Substitution: domain-transfer
/// finetuning — continue training a pretrained model on a *shifted*
/// corpus (different generator seed = new word inventory/states) at a
/// low LR, comparing Adam vs SCALE transfer quality.
pub fn table12(
    engine: &Engine,
    size: &str,
    pretrain_steps: usize,
    ft_steps: usize,
) -> anyhow::Result<String> {
    use crate::coordinator::{TrainOptions, Trainer};
    let mut t = Table::new(
        "Table 12 — finetuning stand-in (domain transfer; App. I)",
        &["method", "paper GLUE avg", "pretrain ppl", "transfer ppl (new domain)"],
    );
    for (opt, paper_avg) in [("adam", 85.68), ("scale", 85.51)] {
        // pretrain on corpus domain seed 0
        let pre_opts = TrainOptions {
            size: size.into(),
            optimizer: opt.into(),
            steps: pretrain_steps,
            base_lr: super::default_lr(opt),
            schedule: None,
            shards: 4,
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            log_every: 0,
            quiet: true,
        };
        let mut pre_run = Trainer::new(engine, pre_opts)?;
        let pre_ppl = pre_run.train()?;
        // finetune the pretrained weights on domain seed 1 (new word
        // inventory + transition structure) at a 10x lower LR — fresh
        // optimizer state, warm-started parameters.
        let ft_opts = TrainOptions {
            size: size.into(),
            optimizer: opt.into(),
            steps: ft_steps,
            base_lr: super::default_lr(opt) * 0.1,
            schedule: None,
            shards: 4,
            seed: 1,
            eval_every: 0,
            eval_batches: 8,
            log_every: 0,
            quiet: true,
        };
        let mut tr = Trainer::new(engine, ft_opts)?;
        tr.params = pre_run.params.clone();
        let ft_ppl = tr.train()?;
        println!("  [{size}/{opt}] pretrain ppl {pre_ppl:.2} -> transfer ppl {ft_ppl:.2}");
        t.row(vec![
            opt_label(opt).to_string(),
            format!("{paper_avg:.2}"),
            ppl_cell(pre_ppl),
            ppl_cell(ft_ppl),
        ]);
    }
    t.footnote(
        "GLUE unavailable offline; substitution per DESIGN.md §3 (shifted-domain transfer)",
    );
    Ok(t.render())
}

/// Table 13 (App. M): mixed-normalization ablations on s130m. All four
/// `mix_*` rules execute natively (`exec::update` composes them from
/// the col/row/momentum kernels), so this table runs without PJRT.
pub fn table13(engine: &Engine, steps: usize) -> anyhow::Result<String> {
    let opts = [
        "scale", "mix_col_last_row_rest", "mix_row_first_col_rest",
        "mix_larger_dim", "mix_row_last_col_rest",
    ];
    let outs = run_zoo(engine, &opts, "s130m", steps, false)?;
    let mut t = Table::new(
        "Table 13 — mixed normalization schemes (App. M)",
        &["method", "paper ppl", "measured ppl"],
    );
    for r in &outs {
        let p = paper::TABLE13
            .iter()
            .find(|(o, _)| *o == r.spec.optimizer)
            .map(|(_, v)| format!("{v:.2}"))
            .unwrap_or_default();
        t.row(vec![
            opt_label(&r.spec.optimizer).to_string(),
            p,
            ppl_cell(r.final_ppl),
        ]);
    }
    t.footnote("paper's key finding: row-last degrades sharply; all-column (SCALE) is best");
    Ok(t.render())
}
