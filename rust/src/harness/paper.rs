//! The paper's published numbers, embedded for paper-vs-measured tables.
//! Keys are (exhibit, row-label) or structured constants per table.

/// Table 2: perplexity of SGD + one normalization, per size.
pub const TABLE2: &[(&str, [f64; 3])] = &[
    ("adam", [30.05, 23.13, 18.77]),
    ("stable_spam", [28.77, 22.20, 16.80]),
    ("sgd_ns", [34.15, 25.25, 18.73]),
    ("sgd_colnorm", [39.89, 28.85, 20.38]),
    ("sgd_rownorm", [79.27, 37.67, 21.63]),
    ("sign_sgd", [54.36, 40.42, 27.95]),
];

/// Table 3: normalization + last-layer momentum vs Adam.
pub const TABLE3: &[(&str, [f64; 3])] = &[
    ("adam", [30.05, 23.13, 18.77]),
    ("stable_spam", [28.77, 22.20, 16.80]),
    ("ns_mmt_last", [31.20, 22.33, 16.67]),
    ("scale", [f64::NAN, 22.57, 16.32]), // 60M cell blank in the paper
];

/// Table 5: main results; (optimizer, [ppl 60M,130M,350M,1B], [mem GB ...]).
pub const TABLE5: &[(&str, [f64; 4], [f64; 4])] = &[
    ("adam", [30.05, 23.13, 18.77, 15.79], [0.35, 0.81, 2.21, 8.04]),
    ("stable_spam", [28.77, 22.20, 16.80, 13.30], [0.35, 0.81, 2.21, 8.04]),
    ("muon", [28.86, 22.20, 16.70, 13.67], [0.23, 0.54, 1.47, 5.36]),
    ("galore", [34.58, 25.31, 19.37, 15.05], [0.28, 0.61, 1.59, 4.76]),
    ("fira", [30.34, 22.96, 16.82, 14.36], [0.28, 0.61, 1.59, 4.76]),
    ("swan", [30.00, 22.83, 17.14, f64::NAN], [0.25, 0.46, 1.00, f64::NAN]),
    ("apollo", [30.94, 22.93, 16.75, 14.28], [0.28, 0.61, 1.59, 4.76]),
    ("apollo_mini", [31.85, 23.63, 17.11, 13.48], [0.25, 0.46, 1.00, 3.20]),
    ("scale", [30.81, 22.57, 16.32, 13.49], [0.15, 0.32, 0.80, 2.81]),
];

/// Table 6: 7B ppl at 40K/80K/120K/150K steps (+ memory GB).
pub const TABLE6: &[(&str, f64, [f64; 4])] = &[
    ("apollo", 16.14, [f64::NAN, f64::NAN, f64::NAN, 13.02]),
    ("apollo_mini", 14.53, [f64::NAN, f64::NAN, f64::NAN, 13.09]),
    ("muon", 26.95, [f64::NAN, f64::NAN, f64::NAN, 12.72]),
    ("scale", 13.74, [17.99, 14.57, 12.86, 12.59]),
];

/// Table 7: throughput (tokens/sec) on LLaMA 1B, 4xH100.
pub const TABLE7: &[(&str, f64)] = &[
    ("adam", 45019.0),
    ("stable_spam", 44960.0),
    ("muon", 37748.0),
    ("galore", 41267.0),
    ("fira", 41285.0),
    ("apollo", 44193.0),
    ("apollo_mini", 44567.0),
    ("scale", 44728.0),
];

/// Table 8: first+last momentum ablation (ppl, [60M,130M,350M]).
pub const TABLE8: &[(&str, [f64; 3])] = &[
    ("sgd_colnorm", [39.89, 28.85, 20.38]),
    ("scale", [30.81, 22.57, 16.32]),
    ("scale_first_last", [30.35, 22.26, 16.14]),
];

/// Table 9: other architectures (GPT2-M column; Qwen omitted — our
/// gpt2s config is the architecture-generality stand-in).
pub const TABLE9_GPT2: &[(&str, f64)] = &[
    ("adam", 20.73),
    ("stable_spam", 18.90),
    ("muon", 19.61),
    ("galore", 23.66),
    ("fira", 19.41),
    ("apollo", 19.30),
    ("apollo_mini", 19.99),
    ("scale", 19.00),
];

/// Table 11: overtraining, 350M at 1x/2x/4x Chinchilla.
pub const TABLE11: &[(&str, [f64; 3])] = &[
    ("adam", [18.77, 17.60, 17.21]),
    ("stable_spam", [16.80, 15.85, 15.11]),
    ("muon", [16.70, 15.81, 15.18]),
    ("galore", [19.37, 18.40, 17.81]),
    ("fira", [16.82, 15.82, 15.31]),
    ("apollo", [16.75, 15.76, 15.06]),
    ("apollo_mini", [17.11, 16.02, 15.21]),
    ("scale", [16.32, 15.33, 14.77]),
];

/// Table 13: mixed-normalization ablations (130M).
pub const TABLE13: &[(&str, f64)] = &[
    ("scale", 22.57),
    ("mix_col_last_row_rest", 23.27),
    ("mix_row_first_col_rest", 22.94),
    ("mix_larger_dim", 23.52),
    ("mix_row_last_col_rest", 28.83),
];

/// Table 1: normalization time (ms) at d=1024/2048/4096 on an A40.
pub const TABLE1: &[(&str, [f64; 3])] = &[
    ("sv_exact", [79.77, 354.27, 1958.66]),
    ("ns", [6.03, 7.00, 14.41]),
    ("col", [0.10, 0.12, 0.17]),
    ("row", [0.09, 0.11, 0.13]),
    ("sign", [0.03, 0.03, 0.03]),
];

/// Paper sizes in column order for the 3-size tables.
pub const SIZE3: [&str; 3] = ["s60m", "s130m", "s350m"];
pub const SIZE3_LABEL: [&str; 3] = ["60M", "130M", "350M"];

pub fn lookup3(table: &[(&str, [f64; 3])], opt: &str) -> Option<[f64; 3]> {
    table.iter().find(|(o, _)| *o == opt).map(|(_, v)| *v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_orderings_sane() {
        // row-norm worse than col-norm everywhere (Table 2)
        let col = lookup3(TABLE2, "sgd_colnorm").unwrap();
        let row = lookup3(TABLE2, "sgd_rownorm").unwrap();
        for i in 0..3 {
            assert!(row[i] > col[i]);
        }
        // SCALE beats GaLore everywhere (Table 5)
        let scale = TABLE5.iter().find(|r| r.0 == "scale").unwrap();
        let galore = TABLE5.iter().find(|r| r.0 == "galore").unwrap();
        for i in 0..4 {
            assert!(scale.1[i] < galore.1[i]);
            assert!(scale.2[i] < galore.2[i]);
        }
    }
}
