//! Experiment harness: one entry point per paper table/figure.
//!
//! Every exhibit from the paper's evaluation (DESIGN.md §5) has a
//! function here that runs the corresponding workload on the tiny
//! simulation family and renders a paper-vs-measured table. The `scale`
//! CLI, the examples, and the benches are thin callers.
//!
//! Step budgets are parameters everywhere: absolute perplexities depend
//! on budget, but the paper's *orderings and gaps* emerge within a few
//! hundred steps (Fig. 9 shows orderings stable early).

pub mod figures;
pub mod paper;
pub mod tables;

use crate::coordinator::{TrainOptions, Trainer};
use crate::runtime::Engine;

/// Default peak LR per optimizer family for the tiny models, found by a
/// coarse sweep (EXPERIMENTS.md §Calibration). Overridable via --lr.
pub fn default_lr(optimizer: &str) -> f64 {
    match optimizer {
        "sgd" => 0.2,
        "sgd_momentum" => 0.2,
        "adam" | "stable_spam" => 2e-3,
        "galore" | "fira" | "apollo" | "apollo_mini" => 2e-3,
        "muon" | "swan" => 2e-2,
        // plain NS orthogonalization has per-entry magnitude ~1/sqrt(d)
        // (no Muon RMS rescale), so it needs a ~sqrt(d) larger LR to move
        // parameters at the same rate as the colnorm family
        "sgd_ns" | "ns_mmt_last" => 1e-1,
        "sign_sgd" => 1e-3,
        // AdamS: m/sqrt(b2*m^2+eps) is sign-like (per-entry magnitude
        // ~1/sqrt(b2)), so it runs at Adam-family rates
        "adams" => 1e-3,
        // column/row-normalized SGD family, SCALE, the adapm_* partial-
        // momentum policies, and the Table-13 mix_* ablations (all
        // norm-bounded updates of the same scale)
        _ => 1e-2,
    }
}

#[derive(Debug, Clone)]
pub struct RunSpec {
    pub optimizer: String,
    pub size: String,
    pub steps: usize,
    /// None -> default_lr(optimizer)
    pub lr: Option<f64>,
    pub seed: u64,
    pub shards: usize,
    pub eval_every: usize,
}

impl RunSpec {
    pub fn new(optimizer: &str, size: &str, steps: usize) -> RunSpec {
        RunSpec {
            optimizer: optimizer.into(),
            size: size.into(),
            steps,
            lr: None,
            seed: 0,
            shards: 4,
            eval_every: 0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct RunOutcome {
    pub spec: RunSpec,
    pub final_ppl: f64,
    pub final_eval_loss: f64,
    pub tokens_per_sec: f64,
    pub state_bytes: usize,
    pub param_bytes: usize,
    /// (step, train loss)
    pub curve: Vec<(usize, f64)>,
    /// (step, eval ppl) — populated when eval_every > 0
    pub eval_curve: Vec<(usize, f64)>,
}

/// Train one configuration to completion.
pub fn train_once(engine: &Engine, spec: &RunSpec) -> anyhow::Result<RunOutcome> {
    let opts = TrainOptions {
        size: spec.size.clone(),
        optimizer: spec.optimizer.clone(),
        steps: spec.steps,
        base_lr: spec.lr.unwrap_or_else(|| default_lr(&spec.optimizer)),
        schedule: None,
        shards: spec.shards,
        seed: spec.seed,
        eval_every: spec.eval_every,
        eval_batches: 8,
        log_every: 0,
        quiet: true,
    };
    let mut tr = Trainer::new(engine, opts)?;
    let ppl = tr.train()?;
    let last_eval = tr.metrics.evals.last().map(|e| e.loss).unwrap_or(f64::NAN);
    Ok(RunOutcome {
        spec: spec.clone(),
        final_ppl: ppl,
        final_eval_loss: last_eval,
        tokens_per_sec: tr.metrics.tokens_per_sec(),
        state_bytes: tr.state_bytes(),
        param_bytes: 4 * engine.manifest.size(&spec.size)?.param_count,
        curve: tr.metrics.steps.iter().map(|s| (s.step, s.loss)).collect(),
        eval_curve: tr.metrics.evals.iter().map(|e| (e.step, e.ppl)).collect(),
    })
}

/// Train a set of optimizers on one size; logs progress lines.
pub fn run_zoo(
    engine: &Engine,
    optimizers: &[&str],
    size: &str,
    steps: usize,
    quiet: bool,
) -> anyhow::Result<Vec<RunOutcome>> {
    let mut out = Vec::new();
    for opt in optimizers {
        let spec = RunSpec::new(opt, size, steps);
        let t0 = std::time::Instant::now();
        let r = train_once(engine, &spec)?;
        if !quiet {
            println!(
                "  [{size}/{opt}] ppl {:.2}  ({:.0} tok/s, state {} KiB, {:.1}s)",
                r.final_ppl,
                r.tokens_per_sec,
                r.state_bytes / 1024,
                t0.elapsed().as_secs_f64()
            );
        }
        out.push(r);
    }
    Ok(out)
}

/// Format a perplexity safely (divergence -> "div").
pub fn ppl_cell(ppl: f64) -> String {
    if !ppl.is_finite() || ppl > 1e5 {
        "div".to_string()
    } else {
        format!("{ppl:.2}")
    }
}
