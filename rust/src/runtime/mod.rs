//! Runtime layer: PJRT client + manifest-driven artifact execution.
//!
//! The only place in the crate that touches the `xla` FFI. Everything
//! above works in host [`tensor::Tensor`]s and artifact names.

pub mod artifact;
pub mod backend;
pub mod client;
pub mod tensor;

pub use artifact::Manifest;
pub use client::{Engine, Executable};
pub use tensor::Tensor;
