//! Manifest model: the typed view of `artifacts/manifest.json`.
//!
//! aot.py is the producer; nothing about shapes, parameter inventories or
//! optimizer-state layouts is hard-coded on the Rust side — the manifest
//! is the contract between the build-time Python layers and the runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn from_str(s: &str) -> anyhow::Result<DType> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => anyhow::bail!("unsupported dtype {other:?}"),
        }
    }

    /// Bytes per element, matched per variant so a future bf16/i8 dtype
    /// cannot silently mis-size the memory estimator (adding a variant
    /// is a compile error here until its width is declared; the
    /// estimator and `Trainer::state_bytes` route through this).
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 => 4,
            DType::I32 => 4,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: j.req("shape")?.as_shape()?,
            dtype: DType::from_str(j.req("dtype")?.as_str().unwrap_or(""))?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub size: Option<String>,
    pub optimizer: Option<String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// One model parameter as declared by model.param_specs.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    /// "embed" | "matrix" | "head" | "vector"
    pub kind: String,
    pub shape: Vec<usize>,
    /// Variance-analysis grouping: "embed", "blockN", "lm_head", ...
    pub layer: String,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct SizeInfo {
    pub name: String,
    pub paper_size: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub arch: String,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StateSlot {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub microbatch: usize,
    pub varprobe_big_factor: usize,
    pub sizes: BTreeMap<String, SizeInfo>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub state_specs: BTreeMap<String, Vec<StateSlot>>,
    /// Real LLaMA dims for the Appendix-B memory estimator.
    pub paper_dims: BTreeMap<String, PaperDims>,
    pub norm_bench_dims: Vec<usize>,
}

#[derive(Debug, Clone, Copy)]
pub struct PaperDims {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub d_ff: usize,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!(
                "cannot read {}/manifest.json ({e}); run `make artifacts` first",
                dir.display()))?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        Self::from_json(dir, &j)
    }

    fn from_json(dir: PathBuf, j: &Json) -> anyhow::Result<Manifest> {
        let mut sizes = BTreeMap::new();
        for (name, sj) in j.req("sizes")?.as_obj().unwrap() {
            let params = sj
                .req("params")?
                .as_arr()
                .unwrap()
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.req("name")?.as_str().unwrap().to_string(),
                        kind: p.req("kind")?.as_str().unwrap().to_string(),
                        shape: p.req("shape")?.as_shape()?,
                        layer: p.req("layer")?.as_str().unwrap().to_string(),
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let u = |k: &str| -> anyhow::Result<usize> {
                Ok(sj.req(k)?.as_usize().unwrap_or(0))
            };
            sizes.insert(
                name.clone(),
                SizeInfo {
                    name: name.clone(),
                    paper_size: sj.req("paper_size")?.as_str().unwrap().to_string(),
                    vocab: u("vocab")?,
                    d_model: u("d_model")?,
                    n_layers: u("n_layers")?,
                    n_heads: u("n_heads")?,
                    d_ff: u("d_ff")?,
                    seq_len: u("seq_len")?,
                    batch: u("batch")?,
                    arch: sj.req("arch")?.as_str().unwrap().to_string(),
                    param_count: u("param_count")?,
                    params,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, aj) in j.req("artifacts")?.as_obj().unwrap() {
            let tensors = |k: &str| -> anyhow::Result<Vec<TensorSpec>> {
                aj.req(k)?
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(TensorSpec::parse)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: aj.req("file")?.as_str().unwrap().to_string(),
                    kind: aj.req("kind")?.as_str().unwrap().to_string(),
                    size: aj.get("size").and_then(|x| x.as_str()).map(String::from),
                    optimizer: aj
                        .get("optimizer")
                        .and_then(|x| x.as_str())
                        .map(String::from),
                    inputs: tensors("inputs")?,
                    outputs: tensors("outputs")?,
                },
            );
        }

        let mut state_specs = BTreeMap::new();
        for (key, slots) in j.req("state_specs")?.as_obj().unwrap() {
            let v = slots
                .as_arr()
                .unwrap()
                .iter()
                .map(|s| {
                    Ok(StateSlot {
                        name: s.req("name")?.as_str().unwrap().to_string(),
                        shape: s.req("shape")?.as_shape()?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            state_specs.insert(key.clone(), v);
        }

        let mut paper_dims = BTreeMap::new();
        for (name, dj) in j.req("paper_dims")?.as_obj().unwrap() {
            paper_dims.insert(
                name.clone(),
                PaperDims {
                    vocab: dj.req("vocab")?.as_usize().unwrap(),
                    d_model: dj.req("d_model")?.as_usize().unwrap(),
                    n_layers: dj.req("n_layers")?.as_usize().unwrap(),
                    d_ff: dj.req("d_ff")?.as_usize().unwrap(),
                },
            );
        }

        Ok(Manifest {
            dir,
            microbatch: j.req("microbatch")?.as_usize().unwrap(),
            varprobe_big_factor: j.req("varprobe_big_factor")?.as_usize().unwrap(),
            sizes,
            artifacts,
            state_specs,
            paper_dims,
            norm_bench_dims: j.req("norm_bench_dims")?.as_shape()?,
        })
    }

    pub fn artifact(&self, name: &str) -> anyhow::Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact {name:?} not in manifest"))
    }

    pub fn size(&self, name: &str) -> anyhow::Result<&SizeInfo> {
        self.sizes
            .get(name)
            .ok_or_else(|| anyhow::anyhow!(
                "size {name:?} not in manifest (have: {:?})",
                self.sizes.keys().collect::<Vec<_>>()))
    }

    pub fn state_spec(&self, optimizer: &str, size: &str) -> anyhow::Result<&Vec<StateSlot>> {
        let key = format!("{optimizer}_{size}");
        self.state_specs
            .get(&key)
            .ok_or_else(|| anyhow::anyhow!("no state spec {key:?} (artifact not lowered?)"))
    }

    /// Optimizers with an update artifact for `size`.
    pub fn optimizers_for(&self, size: &str) -> Vec<String> {
        self.artifacts
            .values()
            .filter(|a| a.kind == "update" && a.size.as_deref() == Some(size))
            .filter_map(|a| a.optimizer.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// The manifest only exists after `make artifacts` (build-time Python
    /// lowering); skip instead of failing in artifact-less environments.
    fn manifest_or_skip() -> Option<Manifest> {
        match Manifest::load(art_dir()) {
            Ok(m) => Some(m),
            Err(e) => {
                eprintln!("skipping manifest test (run `make artifacts`): {e}");
                None
            }
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest_or_skip() else { return };
        assert!(m.microbatch >= 1);
        let s = m.size("s60m").unwrap();
        assert_eq!(s.params.last().unwrap().name, "lm_head");
        assert_eq!(s.params[0].kind, "embed");
        let total: usize = s.params.iter().map(|p| p.numel()).sum();
        assert_eq!(total, s.param_count);
    }

    #[test]
    fn update_artifact_io_consistent() {
        let Some(m) = manifest_or_skip() else { return };
        let s = m.size("s60m").unwrap();
        let a = m.artifact("update_scale_s60m").unwrap();
        let st = m.state_spec("scale", "s60m").unwrap();
        assert_eq!(a.inputs.len(), 2 * s.params.len() + st.len() + 2);
        assert_eq!(a.outputs.len(), s.params.len() + st.len());
        // state slot for the head momentum exists
        assert!(st.iter().any(|x| x.name == "lm_head.m"));
    }

    #[test]
    fn optimizers_for_ablation_size() {
        let Some(m) = manifest_or_skip() else { return };
        let opts = m.optimizers_for("s130m");
        for need in ["scale", "adam", "muon", "galore", "apollo_mini"] {
            assert!(opts.iter().any(|o| o == need), "{need}");
        }
    }
}
