//! Engine: loads manifest artifacts, compiles them once, executes them
//! from the training hot path — through PJRT when the `xla` feature is
//! on, through the native CPU executor ([`crate::exec`]) otherwise.
//!
//! With `--features xla`, interchange is HLO *text* (see aot.py /
//! DESIGN.md): xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id
//! serialized protos, while the text parser reassigns ids. On the
//! default build no artifact files are needed at all: `Engine::new`
//! falls back to the native manifest (`exec::native_manifest`) when
//! `manifest.json` is absent, and `Engine::load` builds a
//! [`crate::exec::NativeProgram`] per artifact — so `Trainer::train`,
//! eval, and every bench run end-to-end without Python or PJRT.
//!
//! Threading: the engine is shared (`&Engine`) across the DDP shard
//! threads of `Trainer::train_step`, so all interior mutability is
//! sync-safe — the executable cache behind a `Mutex`, the perf counters
//! as atomics. Callers pass inputs by reference ([`Engine::run_exe_refs`])
//! so the hot path never clones parameter tensors, and callers that own
//! reusable output buffers use [`Engine::run_exe_refs_into`] — on the
//! native executor that path is allocation-free in steady state.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::PjRtClient;
#[cfg(feature = "xla")]
use super::backend::{
    execute_views, HloModuleProto, Literal, LiteralView, PjRtLoadedExecutable, XlaComputation,
};

use super::artifact::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Newtype confining the thread-safety claim to exactly the FFI handle.
///
/// SAFETY (of the impls below): PJRT clients and loaded executables are
/// thread-safe at the C API level (PJRT is designed for concurrent
/// dispatch). The claim is scoped to these wrappers — Engine/Executable
/// derive their own Send/Sync from their fields. The native executor's
/// types are plain host data and need no unsafe.
///
/// PRECONDITION for enabling the `xla` feature: the C-API argument only
/// covers PJRT itself, not the Rust wrapper's own bookkeeping. Before
/// wiring a concrete xla-rs version, verify its PjRtClient /
/// PjRtLoadedExecutable hold their internal handles via Arc (or raw
/// pointers), NOT non-atomic Rc — an Rc refcount would race under the
/// DDP shard threads and these impls would be unsound for that version.
/// Tracked in ROADMAP "Deferred from PR 1".
struct SyncClient(PjRtClient);

#[cfg(feature = "xla")]
unsafe impl Send for SyncClient {}
#[cfg(feature = "xla")]
unsafe impl Sync for SyncClient {}

/// See [`SyncClient`].
#[cfg(feature = "xla")]
struct SyncExec(PjRtLoadedExecutable);

#[cfg(feature = "xla")]
unsafe impl Send for SyncExec {}
#[cfg(feature = "xla")]
unsafe impl Sync for SyncExec {}

/// The two executor backends behind one [`Executable`] face.
enum ExecKind {
    #[cfg(feature = "xla")]
    Pjrt(SyncExec),
    #[cfg(not(feature = "xla"))]
    Native(crate::exec::NativeProgram),
}

pub struct Engine {
    /// Constructed eagerly but allowed to fail without sinking the
    /// Engine: on the default build every artifact runs natively and the
    /// client is never consulted; with `xla`, manifest-only consumers
    /// (`scale list`, `memory-report`, `table 4`) still work and the
    /// stored error surfaces on the first compile.
    client: Result<SyncClient, String>,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Cumulative execute-call wall time in nanoseconds, for the perf report.
    exec_nanos: AtomicU64,
    exec_count: AtomicU64,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Engine> {
        let dir = artifact_dir.as_ref();
        // Native builds synthesize the manifest when `make artifacts`
        // has not produced one; a real manifest.json still wins so the
        // PJRT-lowered shapes stay authoritative where they exist.
        #[cfg(not(feature = "xla"))]
        let manifest = if dir.join("manifest.json").exists() {
            Manifest::load(dir)?
        } else {
            crate::exec::native_manifest(dir.to_path_buf())
        };
        #[cfg(feature = "xla")]
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()
            .map(SyncClient)
            .map_err(|e| e.to_string());
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            exec_nanos: AtomicU64::new(0),
            exec_count: AtomicU64::new(0),
        })
    }

    #[cfg(feature = "xla")]
    fn client(&self) -> anyhow::Result<&SyncClient> {
        self.client
            .as_ref()
            .map_err(|e| anyhow::anyhow!("PJRT client unavailable: {e}"))
    }

    /// Load an artifact (cached): PJRT-compiled with `--features xla`,
    /// a [`crate::exec::NativeProgram`] otherwise. The cache lock is
    /// held across the build on purpose: PJRT compiles are multi-second,
    /// and releasing the lock between miss and insert would let
    /// concurrent callers compile the same artifact twice. Loads happen
    /// at Trainer construction, not on the threaded step path, so the
    /// serialization is free in practice.
    pub fn load(&self, name: &str) -> anyhow::Result<Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let t0 = Instant::now();
        #[cfg(feature = "xla")]
        let kind = {
            let path = self.manifest.dir.join(&spec.file);
            let proto = HloModuleProto::from_text_file(&path)?;
            let comp = XlaComputation::from_proto(&proto);
            ExecKind::Pjrt(SyncExec(self.client()?.0.compile(&comp)?))
        };
        #[cfg(not(feature = "xla"))]
        let kind = ExecKind::Native(crate::exec::NativeProgram::new(&self.manifest, &spec)?);
        let e = Arc::new(Executable {
            spec,
            kind,
            compiled_in: t0.elapsed(),
        });
        cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Run an artifact end to end with host tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        self.run_exe(&exe, inputs)
    }

    pub fn run_exe(&self, exe: &Executable, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_exe_refs(exe, &refs)
    }

    /// Execute with borrowed inputs — the zero-clone entry point. The
    /// trainer assembles `[&params.., &state.., &grads.., &scalars..]`
    /// without cloning a single tensor.
    pub fn run_exe_refs(
        &self,
        exe: &Executable,
        inputs: &[&Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        let mut out = Vec::new();
        self.run_exe_refs_into(exe, inputs, &mut out)?;
        Ok(out)
    }

    /// Execute with borrowed inputs into caller-owned output tensors.
    /// When `out` already matches the artifact's output signature (the
    /// steady state of a training loop), the native executor writes
    /// every result in place — zero heap allocations per call; the PJRT
    /// path falls back to materializing fresh outputs.
    pub fn run_exe_refs_into(
        &self,
        exe: &Executable,
        inputs: &[&Tensor],
        out: &mut Vec<Tensor>,
    ) -> anyhow::Result<()> {
        exe.check_inputs(inputs)?;
        let t0 = Instant::now();
        match &exe.kind {
            #[cfg(feature = "xla")]
            ExecKind::Pjrt(sync) => {
                let views: Vec<LiteralView> = inputs
                    .iter()
                    .map(|t| t.as_literal_ref())
                    .collect::<anyhow::Result<_>>()?;
                let res = execute_views(&sync.0, views)?;
                let mut tuple = res[0][0].to_literal_sync()?;
                let tensors = untuple(&mut tuple, exe.spec.outputs.len())?;
                out.clear();
                out.extend(tensors);
            }
            #[cfg(not(feature = "xla"))]
            ExecKind::Native(prog) => prog.execute_into(&exe.spec, inputs, out)?,
        }
        self.exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Cumulative execute-call wall time.
    pub fn exec_time(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.exec_nanos.load(Ordering::Relaxed))
    }

    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    pub fn platform(&self) -> String {
        match &self.client {
            Ok(c) => c.0.platform_name(),
            #[cfg(not(feature = "xla"))]
            Err(_) => "native-cpu".to_string(),
            #[cfg(feature = "xla")]
            Err(_) => "unavailable".to_string(),
        }
    }
}

pub struct Executable {
    pub spec: ArtifactSpec,
    kind: ExecKind,
    pub compiled_in: std::time::Duration,
}

impl Executable {
    fn check_inputs(&self, inputs: &[&Tensor]) -> anyhow::Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            anyhow::bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != s.shape.as_slice() {
                anyhow::bail!(
                    "{}: input {:?} shape {:?} != manifest {:?}",
                    self.spec.name,
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                anyhow::bail!("{}: input {:?} dtype mismatch", self.spec.name, s.name);
            }
        }
        Ok(())
    }
}

#[cfg(feature = "xla")]
fn untuple(tuple: &mut Literal, expected: usize) -> anyhow::Result<Vec<Tensor>> {
    let parts = tuple.decompose_tuple()?;
    if parts.len() != expected {
        anyhow::bail!("tuple arity {} != manifest {}", parts.len(), expected);
    }
    parts.iter().map(Tensor::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// On the default build the native executor always works (the
    /// manifest synthesizes when absent); with `--features xla` these
    /// tests still need `make artifacts` + a real PJRT backend, so they
    /// skip gracefully there.
    fn engine_or_skip() -> Option<Engine> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Engine::new(dir) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping engine test (artifacts/PJRT unavailable): {e}");
                None
            }
        }
    }

    #[test]
    fn norm_col_artifact_runs_and_matches_native() {
        let Some(eng) = engine_or_skip() else { return };
        let d = eng.manifest.norm_bench_dims[0];
        let name = format!("norm_col_{d}");
        let mut rng = crate::util::rng::Pcg::new(1);
        let x: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        let out = eng
            .run(&name, &[Tensor::from_f32(&[d, d], x.clone())])
            .unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].f32s();
        let want = crate::optim::colnorm::colnorm(&x, d, d);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn init_artifact_matches_manifest_shapes() {
        let Some(eng) = engine_or_skip() else { return };
        let out = eng.run("init_s60m", &[Tensor::scalar_i32(0)]).unwrap();
        let size = eng.manifest.size("s60m").unwrap();
        assert_eq!(out.len(), size.params.len());
        for (t, p) in out.iter().zip(&size.params) {
            assert_eq!(t.shape(), p.shape.as_slice(), "{}", p.name);
        }
    }

    #[test]
    fn init_is_deterministic_and_seeded() {
        let Some(eng) = engine_or_skip() else { return };
        let a = eng.run("init_s60m", &[Tensor::scalar_i32(5)]).unwrap();
        let b = eng.run("init_s60m", &[Tensor::scalar_i32(5)]).unwrap();
        let c = eng.run("init_s60m", &[Tensor::scalar_i32(6)]).unwrap();
        // params[0] is the embedding (random); vector params are all-ones
        assert_eq!(a[0].f32s(), b[0].f32s());
        assert_ne!(a[0].f32s(), c[0].f32s());
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let Some(eng) = engine_or_skip() else { return };
        let d = eng.manifest.norm_bench_dims[0];
        let bad = Tensor::zeros(&[d, d + 1]);
        assert!(eng.run(&format!("norm_col_{d}"), &[bad]).is_err());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn run_exe_refs_into_reuses_buffers_and_counts_execs() {
        let eng = engine_or_skip().unwrap();
        let d = eng.manifest.norm_bench_dims[0];
        let exe = eng.load(&format!("norm_sign_{d}")).unwrap();
        let x = Tensor::from_f32(&[d, d], vec![-2.0; d * d]);
        let mut out = Vec::new();
        eng.run_exe_refs_into(&exe, &[&x], &mut out).unwrap();
        assert_eq!(out[0].f32s()[0], -1.0);
        let ptr = out[0].f32s().as_ptr();
        let before = eng.exec_count();
        eng.run_exe_refs_into(&exe, &[&x], &mut out).unwrap();
        assert_eq!(out[0].f32s().as_ptr(), ptr, "output buffer must be reused");
        assert_eq!(eng.exec_count(), before + 1);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn native_engine_reports_platform() {
        let eng = engine_or_skip().unwrap();
        assert_eq!(eng.platform(), "native-cpu");
    }
}
