//! PJRT engine: loads HLO-text artifacts, compiles them once, executes
//! them from the training hot path.
//!
//! Interchange is HLO *text* (see aot.py / DESIGN.md): xla_extension
//! 0.5.1 rejects jax>=0.5's 64-bit-id serialized protos, while the text
//! parser reassigns ids. Executables are cached per artifact name; all
//! artifacts are lowered with `return_tuple=True`, so each execution
//! yields a single tuple buffer that [`Executable::run`] untuples back
//! into host [`Tensor`]s.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifact::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

pub struct Engine {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
    /// Cumulative execute-call wall time, for the perf report.
    pub exec_time: RefCell<std::time::Duration>,
    pub exec_count: RefCell<u64>,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            exec_time: RefCell::new(std::time::Duration::ZERO),
            exec_count: RefCell::new(0),
        })
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let compiled_in = t0.elapsed();
        let e = Rc::new(Executable {
            spec,
            exe,
            compiled_in,
        });
        self.cache.borrow_mut().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Run an artifact end to end with host tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        self.run_exe(&exe, inputs)
    }

    pub fn run_exe(&self, exe: &Executable, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        exe.check_inputs(inputs)?;
        let lits: Vec<Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<anyhow::Result<_>>()?;
        let t0 = Instant::now();
        let out = exe.exe.execute::<Literal>(&lits)?;
        let tuple = out[0][0].to_literal_sync()?;
        *self.exec_time.borrow_mut() += t0.elapsed();
        *self.exec_count.borrow_mut() += 1;
        untuple(tuple, exe.spec.outputs.len())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}

pub struct Executable {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
    pub compiled_in: std::time::Duration,
}

impl Executable {
    fn check_inputs(&self, inputs: &[Tensor]) -> anyhow::Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            anyhow::bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != s.shape.as_slice() {
                anyhow::bail!(
                    "{}: input {:?} shape {:?} != manifest {:?}",
                    self.spec.name,
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                anyhow::bail!("{}: input {:?} dtype mismatch", self.spec.name, s.name);
            }
        }
        Ok(())
    }
}

fn untuple(mut tuple: Literal, expected: usize) -> anyhow::Result<Vec<Tensor>> {
    let parts = tuple.decompose_tuple()?;
    if parts.len() != expected {
        anyhow::bail!("tuple arity {} != manifest {}", parts.len(), expected);
    }
    parts.iter().map(Tensor::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Engine {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Engine::new(dir).expect("run `make artifacts` first")
    }

    #[test]
    fn norm_col_artifact_runs_and_matches_native() {
        let eng = engine();
        let d = eng.manifest.norm_bench_dims[0];
        let name = format!("norm_col_{d}");
        let mut rng = crate::util::rng::Pcg::new(1);
        let x: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        let out = eng
            .run(&name, &[Tensor::from_f32(&[d, d], x.clone())])
            .unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].f32s();
        let want = crate::optim::colnorm::colnorm(&x, d, d);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn init_artifact_matches_manifest_shapes() {
        let eng = engine();
        let out = eng.run("init_s60m", &[Tensor::scalar_i32(0)]).unwrap();
        let size = eng.manifest.size("s60m").unwrap();
        assert_eq!(out.len(), size.params.len());
        for (t, p) in out.iter().zip(&size.params) {
            assert_eq!(t.shape(), p.shape.as_slice(), "{}", p.name);
        }
    }

    #[test]
    fn init_is_deterministic_and_seeded() {
        let eng = engine();
        let a = eng.run("init_s60m", &[Tensor::scalar_i32(5)]).unwrap();
        let b = eng.run("init_s60m", &[Tensor::scalar_i32(5)]).unwrap();
        let c = eng.run("init_s60m", &[Tensor::scalar_i32(6)]).unwrap();
        // params[0] is the embedding (random); vector params are all-ones
        assert_eq!(a[0].f32s(), b[0].f32s());
        assert_ne!(a[0].f32s(), c[0].f32s());
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let eng = engine();
        let d = eng.manifest.norm_bench_dims[0];
        let bad = Tensor::zeros(&[d, d + 1]);
        assert!(eng.run(&format!("norm_col_{d}"), &[bad]).is_err());
    }
}
