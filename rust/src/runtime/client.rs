//! PJRT engine: loads HLO-text artifacts, compiles them once, executes
//! them from the training hot path.
//!
//! Interchange is HLO *text* (see aot.py / DESIGN.md): xla_extension
//! 0.5.1 rejects jax>=0.5's 64-bit-id serialized protos, while the text
//! parser reassigns ids. Executables are cached per artifact name; all
//! artifacts are lowered with `return_tuple=True`, so each execution
//! yields a single tuple buffer that [`Engine::run_exe`] untuples back
//! into host [`Tensor`]s.
//!
//! Threading: the engine is shared (`&Engine`) across the DDP shard
//! threads of `Trainer::train_step`, so all interior mutability is
//! sync-safe — the executable cache behind a `Mutex`, the perf counters
//! as atomics. Callers pass inputs by reference ([`Engine::run_exe_refs`])
//! so the hot path never clones parameter tensors just to build an
//! argument list, and inputs cross the backend seam as borrowed literal
//! views (`Tensor::as_literal_ref`) — on the stub backend no host copy
//! is made at all.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::backend::{
    execute_views, HloModuleProto, Literal, LiteralView, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};

use super::artifact::{ArtifactSpec, Manifest};
use super::tensor::Tensor;

/// Newtype confining the thread-safety claim to exactly the FFI handle.
///
/// SAFETY (of the impls below): PJRT clients and loaded executables are
/// thread-safe at the C API level (PJRT is designed for concurrent
/// dispatch). The claim is scoped to these wrappers — Engine/Executable
/// derive their own Send/Sync from their fields. The stub backend's
/// types are plain host data and need no unsafe.
///
/// PRECONDITION for enabling the `xla` feature: the C-API argument only
/// covers PJRT itself, not the Rust wrapper's own bookkeeping. Before
/// wiring a concrete xla-rs version, verify its PjRtClient /
/// PjRtLoadedExecutable hold their internal handles via Arc (or raw
/// pointers), NOT non-atomic Rc — an Rc refcount would race under the
/// DDP shard threads and these impls would be unsound for that version.
/// Tracked in ROADMAP "Deferred from PR 1".
struct SyncClient(PjRtClient);

#[cfg(feature = "xla")]
unsafe impl Send for SyncClient {}
#[cfg(feature = "xla")]
unsafe impl Sync for SyncClient {}

/// See [`SyncClient`].
struct SyncExec(PjRtLoadedExecutable);

#[cfg(feature = "xla")]
unsafe impl Send for SyncExec {}
#[cfg(feature = "xla")]
unsafe impl Sync for SyncExec {}

pub struct Engine {
    /// Constructed eagerly but allowed to fail without sinking the
    /// Engine: manifest-only consumers (`scale list`, `memory-report`,
    /// `table 4`) must work in stub builds; the stored error surfaces on
    /// the first attempt to compile or execute an artifact.
    client: Result<SyncClient, String>,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// Cumulative execute-call wall time in nanoseconds, for the perf report.
    exec_nanos: AtomicU64,
    exec_count: AtomicU64,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = PjRtClient::cpu()
            .map(SyncClient)
            .map_err(|e| e.to_string());
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            exec_nanos: AtomicU64::new(0),
            exec_count: AtomicU64::new(0),
        })
    }

    fn client(&self) -> anyhow::Result<&SyncClient> {
        self.client
            .as_ref()
            .map_err(|e| anyhow::anyhow!("PJRT client unavailable: {e}"))
    }

    /// Load + compile an artifact (cached). The cache lock is held across
    /// the compile on purpose: compiles are multi-second, and releasing
    /// the lock between miss and insert would let concurrent callers
    /// compile the same artifact twice. Loads happen at Trainer
    /// construction, not on the threaded step path, so the serialization
    /// is free in practice.
    pub fn load(&self, name: &str) -> anyhow::Result<Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(&path)?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client()?.0.compile(&comp)?;
        let compiled_in = t0.elapsed();
        let e = Arc::new(Executable {
            spec,
            exe: SyncExec(exe),
            compiled_in,
        });
        cache.insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Run an artifact end to end with host tensors.
    pub fn run(&self, name: &str, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        self.run_exe(&exe, inputs)
    }

    pub fn run_exe(&self, exe: &Executable, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
        let refs: Vec<&Tensor> = inputs.iter().collect();
        self.run_exe_refs(exe, &refs)
    }

    /// Execute with borrowed inputs — the zero-copy entry point. The
    /// trainer assembles `[&params.., &state.., &grads.., &scalars..]`
    /// without cloning a single tensor, and on the stub backend the
    /// input literals are *views* of the tensors' storage
    /// ([`Tensor::as_literal_ref`]) — no per-input host copy either.
    pub fn run_exe_refs(
        &self,
        exe: &Executable,
        inputs: &[&Tensor],
    ) -> anyhow::Result<Vec<Tensor>> {
        exe.check_inputs(inputs)?;
        let views: Vec<LiteralView> = inputs
            .iter()
            .map(|t| t.as_literal_ref())
            .collect::<anyhow::Result<_>>()?;
        let t0 = Instant::now();
        let out = execute_views(&exe.exe.0, views)?;
        let mut tuple = out[0][0].to_literal_sync()?;
        self.exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        untuple(&mut tuple, exe.spec.outputs.len())
    }

    /// Cumulative execute-call wall time.
    pub fn exec_time(&self) -> std::time::Duration {
        std::time::Duration::from_nanos(self.exec_nanos.load(Ordering::Relaxed))
    }

    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }

    pub fn platform(&self) -> String {
        match &self.client {
            Ok(c) => c.0.platform_name(),
            Err(_) => "unavailable".to_string(),
        }
    }
}

pub struct Executable {
    pub spec: ArtifactSpec,
    exe: SyncExec,
    pub compiled_in: std::time::Duration,
}

impl Executable {
    fn check_inputs(&self, inputs: &[&Tensor]) -> anyhow::Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            anyhow::bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape() != s.shape.as_slice() {
                anyhow::bail!(
                    "{}: input {:?} shape {:?} != manifest {:?}",
                    self.spec.name,
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
            if t.dtype() != s.dtype {
                anyhow::bail!("{}: input {:?} dtype mismatch", self.spec.name, s.name);
            }
        }
        Ok(())
    }
}

fn untuple(tuple: &mut Literal, expected: usize) -> anyhow::Result<Vec<Tensor>> {
    let parts = tuple.decompose_tuple()?;
    if parts.len() != expected {
        anyhow::bail!("tuple arity {} != manifest {}", parts.len(), expected);
    }
    parts.iter().map(Tensor::from_literal).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Engine tests need `make artifacts` (and a real PJRT backend); skip
    /// gracefully in environments without them so the suite stays green.
    fn engine_or_skip() -> Option<Engine> {
        if !cfg!(feature = "xla") {
            eprintln!("skipping engine test (needs --features xla to execute artifacts)");
            return None;
        }
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        match Engine::new(dir) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("skipping engine test (artifacts/PJRT unavailable): {e}");
                None
            }
        }
    }

    #[test]
    fn norm_col_artifact_runs_and_matches_native() {
        let Some(eng) = engine_or_skip() else { return };
        let d = eng.manifest.norm_bench_dims[0];
        let name = format!("norm_col_{d}");
        let mut rng = crate::util::rng::Pcg::new(1);
        let x: Vec<f32> = (0..d * d).map(|_| rng.normal() as f32).collect();
        let out = eng
            .run(&name, &[Tensor::from_f32(&[d, d], x.clone())])
            .unwrap();
        assert_eq!(out.len(), 1);
        let got = out[0].f32s();
        let want = crate::optim::colnorm::colnorm(&x, d, d);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-4, "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn init_artifact_matches_manifest_shapes() {
        let Some(eng) = engine_or_skip() else { return };
        let out = eng.run("init_s60m", &[Tensor::scalar_i32(0)]).unwrap();
        let size = eng.manifest.size("s60m").unwrap();
        assert_eq!(out.len(), size.params.len());
        for (t, p) in out.iter().zip(&size.params) {
            assert_eq!(t.shape(), p.shape.as_slice(), "{}", p.name);
        }
    }

    #[test]
    fn init_is_deterministic_and_seeded() {
        let Some(eng) = engine_or_skip() else { return };
        let a = eng.run("init_s60m", &[Tensor::scalar_i32(5)]).unwrap();
        let b = eng.run("init_s60m", &[Tensor::scalar_i32(5)]).unwrap();
        let c = eng.run("init_s60m", &[Tensor::scalar_i32(6)]).unwrap();
        // params[0] is the embedding (random); vector params are all-ones
        assert_eq!(a[0].f32s(), b[0].f32s());
        assert_ne!(a[0].f32s(), c[0].f32s());
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let Some(eng) = engine_or_skip() else { return };
        let d = eng.manifest.norm_bench_dims[0];
        let bad = Tensor::zeros(&[d, d + 1]);
        assert!(eng.run(&format!("norm_col_{d}"), &[bad]).is_err());
    }
}
