//! Host-side tensor: a shaped `Vec<f32>`/`Vec<i32>` with conversions to
//! and from `xla::Literal`. This is the coordinator's working currency —
//! gradients are all-reduced here, checkpoints serialize it, analysis
//! reads it.

use super::backend::{ElementType, Literal, LiteralView};

use super::artifact::DType;

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::F32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor::I32 {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::I32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn dtype(&self) -> DType {
        match self {
            Tensor::F32 { .. } => DType::F32,
            Tensor::I32 { .. } => DType::I32,
        }
    }

    pub fn f32s(&self) -> &[f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match self {
            Tensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match self {
            Tensor::I32 { data, .. } => data,
            _ => panic!("expected i32 tensor"),
        }
    }

    /// Mutable view of an i32 tensor's storage (token-batch reuse in the
    /// trainer's ring refill path).
    pub fn i32s_mut(&mut self) -> &mut [i32] {
        match self {
            Tensor::I32 { data, .. } => data,
            _ => panic!("expected i32 tensor"),
        }
    }

    /// Scalar extraction (0-d or 1-element tensors).
    pub fn item_f32(&self) -> f32 {
        let d = self.f32s();
        assert_eq!(d.len(), 1, "item() on non-scalar");
        d[0]
    }

    // ---- Literal conversion ----------------------------------------------

    pub fn to_literal(&self) -> anyhow::Result<Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => Literal::vec1(data),
            Tensor::I32 { data, .. } => Literal::vec1(data),
        };
        if dims.len() == 1 {
            return Ok(lit);
        }
        Ok(lit.reshape(&dims)?)
    }

    /// Borrowed literal view of this tensor — the zero-copy input leg of
    /// `Engine::run_exe_refs`. On the stub backend the view aliases this
    /// tensor's storage directly (no host copy; only the small dims
    /// vector is built). With `--features xla` it materializes an owned
    /// literal, since the FFI requires owned buffers at upload time.
    #[cfg(not(feature = "xla"))]
    pub fn as_literal_ref(&self) -> anyhow::Result<LiteralView<'_>> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        Ok(match self {
            Tensor::F32 { data, .. } => LiteralView::f32(dims, data),
            Tensor::I32 { data, .. } => LiteralView::i32(dims, data),
        })
    }

    /// See the stub-backend form above; this leg pays the host copy the
    /// FFI demands.
    #[cfg(feature = "xla")]
    pub fn as_literal_ref(&self) -> anyhow::Result<LiteralView<'_>> {
        Ok(LiteralView::from_owned(self.to_literal()?))
    }

    pub fn from_literal(lit: &Literal) -> anyhow::Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            ElementType::F32 => Ok(Tensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>()?,
            }),
            ElementType::S32 => Ok(Tensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>()?,
            }),
            other => anyhow::bail!("unsupported literal element type {other:?}"),
        }
    }

    // ---- numerics used by the coordinator ---------------------------------

    pub fn l2_norm(&self) -> f64 {
        self.f32s().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// In-place `self += other` (gradient accumulation). Borrows the
    /// source slice directly — no intermediate copy (this runs once per
    /// parameter per tree-reduce round, so the copy was pure waste).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape(), other.shape());
        let (dst, src) = match (self, other) {
            (Tensor::F32 { data: a, .. }, Tensor::F32 { data: b, .. }) => (a, b),
            _ => panic!("add_assign on non-f32 tensors"),
        };
        for (a, b) in dst.iter_mut().zip(src) {
            *a += b;
        }
    }

    /// In-place scale (all-reduce averaging).
    pub fn scale(&mut self, s: f32) {
        for a in self.f32s_mut() {
            *a *= s;
        }
    }

    /// In-place `self += alpha * x` (the BLAS axpy).
    pub fn axpy(&mut self, alpha: f32, x: &Tensor) {
        assert_eq!(self.shape(), x.shape());
        let (dst, src) = match (self, x) {
            (Tensor::F32 { data: a, .. }, Tensor::F32 { data: b, .. }) => (a, b),
            _ => panic!("axpy on non-f32 tensors"),
        };
        for (a, b) in dst.iter_mut().zip(src) {
            *a += alpha * b;
        }
    }

    /// In-place EMA: `self = beta*self + (1-beta)*x` (eq. 7 momentum).
    pub fn ema(&mut self, beta: f32, x: &Tensor) {
        assert_eq!(self.shape(), x.shape());
        let (dst, src) = match (self, x) {
            (Tensor::F32 { data: a, .. }, Tensor::F32 { data: b, .. }) => (a, b),
            _ => panic!("ema on non-f32 tensors"),
        };
        for (a, b) in dst.iter_mut().zip(src) {
            *a = beta * *a + (1.0 - beta) * b;
        }
    }

    /// In-place `self = scale*self + other` (fused scale-and-accumulate).
    pub fn mul_add(&mut self, scale: f32, other: &Tensor) {
        assert_eq!(self.shape(), other.shape());
        let (dst, src) = match (self, other) {
            (Tensor::F32 { data: a, .. }, Tensor::F32 { data: b, .. }) => (a, b),
            _ => panic!("mul_add on non-f32 tensors"),
        };
        for (a, b) in dst.iter_mut().zip(src) {
            *a = scale * *a + b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32_matrix() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i32_batch() {
        let t = Tensor::from_i32(&[2, 4], (0..8).collect());
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_scalar() {
        let t = Tensor::scalar_f32(3.5);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(back.item_f32(), 3.5);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn literal_view_is_zero_copy_and_faithful() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let view = t.as_literal_ref().unwrap();
        assert_eq!(view.dims(), &[2, 3]);
        // the view aliases the tensor's storage — no host copy
        assert_eq!(view.f32s().unwrap().as_ptr(), t.f32s().as_ptr());
        // materializing the view matches the owned to_literal path
        let owned = view.to_literal();
        assert_eq!(Tensor::from_literal(&owned).unwrap(), t);
        assert_eq!(owned, t.to_literal().unwrap());
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn literal_view_i32_and_scalar_shapes() {
        let b = Tensor::from_i32(&[2, 4], (0..8).collect());
        let vb = b.as_literal_ref().unwrap();
        assert_eq!(vb.dims(), &[2, 4]);
        assert!(vb.f32s().is_none());
        assert_eq!(Tensor::from_literal(&vb.to_literal()).unwrap(), b);
        let s = Tensor::scalar_f32(4.25);
        let vs = s.as_literal_ref().unwrap();
        assert!(vs.dims().is_empty());
        assert_eq!(
            Tensor::from_literal(&vs.to_literal()).unwrap().item_f32(),
            4.25
        );
    }

    #[test]
    fn accumulate_and_scale() {
        let mut a = Tensor::from_f32(&[3], vec![1., 2., 3.]);
        let b = Tensor::from_f32(&[3], vec![10., 20., 30.]);
        a.add_assign(&b);
        a.scale(0.5);
        assert_eq!(a.f32s(), &[5.5, 11.0, 16.5]);
    }

    #[test]
    fn l2() {
        let t = Tensor::from_f32(&[2], vec![3., 4.]);
        assert!((t.l2_norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn axpy_matches_manual() {
        let mut a = Tensor::from_f32(&[3], vec![1., 2., 3.]);
        let x = Tensor::from_f32(&[3], vec![10., 20., 30.]);
        a.axpy(0.5, &x);
        assert_eq!(a.f32s(), &[6.0, 12.0, 18.0]);
    }

    #[test]
    fn ema_matches_manual() {
        let mut m = Tensor::from_f32(&[2], vec![1.0, -1.0]);
        let g = Tensor::from_f32(&[2], vec![3.0, 5.0]);
        m.ema(0.9, &g);
        let want = [0.9 * 1.0 + 0.1 * 3.0, 0.9 * -1.0 + 0.1 * 5.0];
        for (a, b) in m.f32s().iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn mul_add_matches_manual() {
        let mut a = Tensor::from_f32(&[2], vec![2.0, 4.0]);
        let b = Tensor::from_f32(&[2], vec![1.0, 1.0]);
        a.mul_add(0.25, &b);
        assert_eq!(a.f32s(), &[1.5, 2.0]);
    }
}
