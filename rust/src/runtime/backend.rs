//! Backend facade: the one seam between the coordinator and the PJRT FFI.
//!
//! With the `xla` cargo feature, this re-exports the real `xla` crate
//! (xla-rs); the rest of the runtime is written against exactly the
//! symbols listed here. Without it (the default — this build environment
//! is offline and cannot fetch the FFI crate), a native stub stands in:
//! [`Literal`] is a fully functional host-side implementation (shape +
//! typed storage, so tensor round-trips and every pure-Rust code path
//! work), while compilation/execution entry points return a clear
//! runtime error instructing the user to rebuild with `--features xla`.

#[cfg(feature = "xla")]
pub use xla::{
    ElementType, HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};

#[cfg(not(feature = "xla"))]
mod stub {
    use std::fmt;
    use std::path::Path;

    /// Error type matching the `xla::Error` role: printable, `?`-friendly.
    #[derive(Debug)]
    pub struct BackendError(pub String);

    impl fmt::Display for BackendError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str(&self.0)
        }
    }

    impl std::error::Error for BackendError {}

    fn unavailable(what: &str) -> BackendError {
        BackendError(format!(
            "{what} requires the PJRT runtime; rebuild with `--features xla` \
             (and the xla-rs dependency) to execute artifacts"
        ))
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum ElementType {
        F32,
        S32,
        Pred,
    }

    // `pub` only for trait-signature visibility; the module is private.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Storage {
        F32(Vec<f32>),
        I32(Vec<i32>),
        Tuple(Vec<Literal>),
    }

    /// Host-side literal: shaped, typed storage mirroring `xla::Literal`'s
    /// API subset used by [`crate::runtime::tensor::Tensor`].
    #[derive(Debug, Clone, PartialEq)]
    pub struct Literal {
        dims: Vec<i64>,
        storage: Storage,
    }

    /// Shape view mirroring `xla::ArrayShape`.
    #[derive(Debug, Clone)]
    pub struct ArrayShape {
        dims: Vec<i64>,
        ty: ElementType,
    }

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            &self.dims
        }

        pub fn ty(&self) -> ElementType {
            self.ty
        }
    }

    /// Sealed helper for the generic `vec1`/`to_vec` entry points.
    pub trait NativeType: Copy + Sized {
        fn make(v: &[Self]) -> Storage;
        fn extract(lit: &Literal) -> Result<Vec<Self>, BackendError>;
    }

    impl NativeType for f32 {
        fn make(v: &[f32]) -> Storage {
            Storage::F32(v.to_vec())
        }

        fn extract(lit: &Literal) -> Result<Vec<f32>, BackendError> {
            match &lit.storage {
                Storage::F32(d) => Ok(d.clone()),
                _ => Err(BackendError("literal is not f32".into())),
            }
        }
    }

    impl NativeType for i32 {
        fn make(v: &[i32]) -> Storage {
            Storage::I32(v.to_vec())
        }

        fn extract(lit: &Literal) -> Result<Vec<i32>, BackendError> {
            match &lit.storage {
                Storage::I32(d) => Ok(d.clone()),
                _ => Err(BackendError("literal is not i32".into())),
            }
        }
    }

    impl Literal {
        pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
            Literal {
                dims: vec![v.len() as i64],
                storage: T::make(v),
            }
        }

        pub fn reshape(&self, dims: &[i64]) -> Result<Literal, BackendError> {
            let numel: i64 = dims.iter().product();
            let have: i64 = self.dims.iter().product();
            if numel != have {
                return Err(BackendError(format!(
                    "reshape {:?} -> {dims:?} changes element count",
                    self.dims
                )));
            }
            Ok(Literal {
                dims: dims.to_vec(),
                storage: self.storage.clone(),
            })
        }

        pub fn array_shape(&self) -> Result<ArrayShape, BackendError> {
            let ty = match &self.storage {
                Storage::F32(_) => ElementType::F32,
                Storage::I32(_) => ElementType::S32,
                Storage::Tuple(_) => {
                    return Err(BackendError("tuple literal has no array shape".into()))
                }
            };
            Ok(ArrayShape {
                dims: self.dims.clone(),
                ty,
            })
        }

        pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, BackendError> {
            T::extract(self)
        }

        pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, BackendError> {
            match std::mem::replace(&mut self.storage, Storage::Tuple(Vec::new())) {
                Storage::Tuple(parts) => Ok(parts),
                other => {
                    self.storage = other;
                    Err(BackendError("literal is not a tuple".into()))
                }
            }
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, BackendError> {
            Err(unavailable("parsing HLO text"))
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, BackendError> {
            Err(unavailable("creating a PJRT client"))
        }

        pub fn compile(
            &self,
            _comp: &XlaComputation,
        ) -> Result<PjRtLoadedExecutable, BackendError> {
            Err(unavailable("compiling an artifact"))
        }

        pub fn platform_name(&self) -> String {
            "stub".to_string()
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, BackendError> {
            Err(unavailable("executing an artifact"))
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, BackendError> {
            Err(unavailable("device-to-host transfer"))
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{
    ElementType, HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};
