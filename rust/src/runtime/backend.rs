//! Backend facade: the one seam between the coordinator and the PJRT FFI.
//!
//! With the `xla` cargo feature, this re-exports the real `xla` crate
//! (xla-rs); the rest of the runtime is written against exactly the
//! symbols listed here. Without it (the default — this build environment
//! is offline and cannot fetch the FFI crate), a native stub stands in:
//! [`Literal`] is a fully functional host-side implementation (shape +
//! typed storage, so tensor round-trips and every pure-Rust code path
//! work), while compilation/execution entry points return a clear
//! runtime error instructing the user to rebuild with `--features xla`.
//!
//! [`LiteralView`] is the borrowed input form: on the stub backend it
//! aliases the tensor's host storage (zero-copy — `run_exe_refs` callers
//! no longer pay `to_literal`'s per-input copy), while the FFI build
//! materializes owned literals at the [`execute_views`] seam because the
//! C API requires owned buffers at upload time.

#[cfg(feature = "xla")]
pub use xla::{
    ElementType, HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
    XlaComputation,
};

/// With the real FFI, executable inputs must be owned `xla::Literal`s —
/// the C API copies host buffers at upload time — so the "view" wraps an
/// owned literal and `Tensor::as_literal_ref` pays exactly the copy
/// `to_literal` did. The borrowed form below (stub build) is the
/// zero-copy one; donating PJRT buffers to avoid this copy on device is
/// tracked in ROADMAP.
#[cfg(feature = "xla")]
pub struct LiteralView<'a> {
    lit: Literal,
    _borrow: std::marker::PhantomData<&'a ()>,
}

#[cfg(feature = "xla")]
impl<'a> LiteralView<'a> {
    pub fn from_owned(lit: Literal) -> LiteralView<'a> {
        LiteralView {
            lit,
            _borrow: std::marker::PhantomData,
        }
    }
}

/// Execute with view inputs. The FFI path unwraps to owned literals;
/// the stub path (below) would pass borrows straight through.
#[cfg(feature = "xla")]
pub fn execute_views(
    exe: &PjRtLoadedExecutable,
    args: Vec<LiteralView<'_>>,
) -> Result<Vec<Vec<PjRtBuffer>>, xla::Error> {
    let owned: Vec<Literal> = args.into_iter().map(|v| v.lit).collect();
    exe.execute::<Literal>(&owned)
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::fmt;
    use std::path::Path;

    /// Error type matching the `xla::Error` role: printable, `?`-friendly.
    #[derive(Debug)]
    pub struct BackendError(pub String);

    impl fmt::Display for BackendError {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str(&self.0)
        }
    }

    impl std::error::Error for BackendError {}

    fn unavailable(what: &str) -> BackendError {
        BackendError(format!(
            "{what} requires the PJRT runtime; rebuild with `--features xla` \
             (and the xla-rs dependency) to execute artifacts"
        ))
    }

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum ElementType {
        F32,
        S32,
        Pred,
    }

    // `pub` only for trait-signature visibility; the module is private.
    #[derive(Debug, Clone, PartialEq)]
    pub enum Storage {
        F32(Vec<f32>),
        I32(Vec<i32>),
        Tuple(Vec<Literal>),
    }

    /// Host-side literal: shaped, typed storage mirroring `xla::Literal`'s
    /// API subset used by [`crate::runtime::tensor::Tensor`].
    #[derive(Debug, Clone, PartialEq)]
    pub struct Literal {
        dims: Vec<i64>,
        storage: Storage,
    }

    /// Shape view mirroring `xla::ArrayShape`.
    #[derive(Debug, Clone)]
    pub struct ArrayShape {
        dims: Vec<i64>,
        ty: ElementType,
    }

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            &self.dims
        }

        pub fn ty(&self) -> ElementType {
            self.ty
        }
    }

    /// Sealed helper for the generic `vec1`/`to_vec` entry points.
    pub trait NativeType: Copy + Sized {
        fn make(v: &[Self]) -> Storage;
        fn extract(lit: &Literal) -> Result<Vec<Self>, BackendError>;
    }

    impl NativeType for f32 {
        fn make(v: &[f32]) -> Storage {
            Storage::F32(v.to_vec())
        }

        fn extract(lit: &Literal) -> Result<Vec<f32>, BackendError> {
            match &lit.storage {
                Storage::F32(d) => Ok(d.clone()),
                _ => Err(BackendError("literal is not f32".into())),
            }
        }
    }

    impl NativeType for i32 {
        fn make(v: &[i32]) -> Storage {
            Storage::I32(v.to_vec())
        }

        fn extract(lit: &Literal) -> Result<Vec<i32>, BackendError> {
            match &lit.storage {
                Storage::I32(d) => Ok(d.clone()),
                _ => Err(BackendError("literal is not i32".into())),
            }
        }
    }

    impl Literal {
        pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
            Literal {
                dims: vec![v.len() as i64],
                storage: T::make(v),
            }
        }

        pub fn reshape(&self, dims: &[i64]) -> Result<Literal, BackendError> {
            let numel: i64 = dims.iter().product();
            let have: i64 = self.dims.iter().product();
            if numel != have {
                return Err(BackendError(format!(
                    "reshape {:?} -> {dims:?} changes element count",
                    self.dims
                )));
            }
            Ok(Literal {
                dims: dims.to_vec(),
                storage: self.storage.clone(),
            })
        }

        pub fn array_shape(&self) -> Result<ArrayShape, BackendError> {
            let ty = match &self.storage {
                Storage::F32(_) => ElementType::F32,
                Storage::I32(_) => ElementType::S32,
                Storage::Tuple(_) => {
                    return Err(BackendError("tuple literal has no array shape".into()))
                }
            };
            Ok(ArrayShape {
                dims: self.dims.clone(),
                ty,
            })
        }

        pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, BackendError> {
            T::extract(self)
        }

        pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, BackendError> {
            match std::mem::replace(&mut self.storage, Storage::Tuple(Vec::new())) {
                Storage::Tuple(parts) => Ok(parts),
                other => {
                    self.storage = other;
                    Err(BackendError("literal is not a tuple".into()))
                }
            }
        }
    }

    /// Borrowed input payload for zero-copy execution.
    #[derive(Debug, Clone, Copy)]
    pub enum StorageRef<'a> {
        F32(&'a [f32]),
        I32(&'a [i32]),
    }

    /// Borrowed counterpart of [`Literal`]: shape plus a *view* of the
    /// caller's host data. `Tensor::as_literal_ref` builds these without
    /// copying the payload — the zero-copy leg of `Engine::run_exe_refs`
    /// on this backend (the only allocation is the small dims vector).
    #[derive(Debug, Clone)]
    pub struct LiteralView<'a> {
        dims: Vec<i64>,
        storage: StorageRef<'a>,
    }

    impl<'a> LiteralView<'a> {
        pub fn f32(dims: Vec<i64>, data: &'a [f32]) -> LiteralView<'a> {
            debug_assert_eq!(dims.iter().product::<i64>(), data.len() as i64);
            LiteralView {
                dims,
                storage: StorageRef::F32(data),
            }
        }

        pub fn i32(dims: Vec<i64>, data: &'a [i32]) -> LiteralView<'a> {
            debug_assert_eq!(dims.iter().product::<i64>(), data.len() as i64);
            LiteralView {
                dims,
                storage: StorageRef::I32(data),
            }
        }

        pub fn dims(&self) -> &[i64] {
            &self.dims
        }

        /// The borrowed f32 payload, if this is an f32 view. The slice
        /// aliases the source tensor's storage — the zero-copy tests
        /// compare raw pointers through this.
        pub fn f32s(&self) -> Option<&'a [f32]> {
            match self.storage {
                StorageRef::F32(d) => Some(d),
                StorageRef::I32(_) => None,
            }
        }

        /// Materialize an owned [`Literal`] (copies). This is the seam a
        /// real upload path would cross; round-trip tests use it.
        pub fn to_literal(&self) -> Literal {
            let storage = match self.storage {
                StorageRef::F32(d) => Storage::F32(d.to_vec()),
                StorageRef::I32(d) => Storage::I32(d.to_vec()),
            };
            Literal {
                dims: self.dims.clone(),
                storage,
            }
        }
    }

    /// Borrowed-input execution: accepts views (no host copy on this
    /// backend) and fails with the same unavailable error as the owned
    /// path — the stub cannot execute artifacts.
    pub fn execute_views(
        _exe: &PjRtLoadedExecutable,
        _args: Vec<LiteralView<'_>>,
    ) -> Result<Vec<Vec<PjRtBuffer>>, BackendError> {
        Err(unavailable("executing an artifact"))
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, BackendError> {
            Err(unavailable("parsing HLO text"))
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, BackendError> {
            Err(unavailable("creating a PJRT client"))
        }

        pub fn compile(
            &self,
            _comp: &XlaComputation,
        ) -> Result<PjRtLoadedExecutable, BackendError> {
            Err(unavailable("compiling an artifact"))
        }

        pub fn platform_name(&self) -> String {
            "stub".to_string()
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, BackendError> {
            Err(unavailable("executing an artifact"))
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, BackendError> {
            Err(unavailable("device-to-host transfer"))
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{
    execute_views, ElementType, HloModuleProto, Literal, LiteralView, PjRtBuffer, PjRtClient,
    PjRtLoadedExecutable, XlaComputation,
};
