//! Analysis: the measurement machinery behind the paper's figures —
//! per-layer gradient variance (Fig. 4), LM-head gradient histograms and
//! column norms (Figs. 3/10), and the table renderer for the bench
//! harness output.

pub mod histogram;
pub mod tables;
pub mod variance;

pub use histogram::{head_column_norms, head_grad_histograms, Histogram};
pub use tables::Table;
pub use variance::{run_probed_training, VarianceSeries};
