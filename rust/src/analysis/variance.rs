//! Per-layer gradient-variance analysis — Fig. 4 (and App. J Figs. 6/7).
//!
//! The paper estimates per-layer gradient variance by comparing the
//! small-batch stochastic gradient against a large-batch estimate of the
//! true gradient (footnote 3). The `varprobe_<size>` artifact returns
//! per-parameter mean-squared deviations; this module aggregates them by
//! layer label (embed / blockN / lm_head) into the Fig.-4 series.

use std::collections::BTreeMap;

use crate::coordinator::Trainer;
use crate::runtime::Tensor;

#[derive(Debug, Clone)]
pub struct VarianceSeries {
    /// layer label -> variance estimate per probe step
    pub by_layer: BTreeMap<String, Vec<f64>>,
    pub probe_steps: Vec<usize>,
}

impl VarianceSeries {
    /// Mean variance per layer over the collected probes.
    pub fn means(&self) -> BTreeMap<String, f64> {
        self.by_layer
            .iter()
            .map(|(k, v)| (k.clone(), v.iter().sum::<f64>() / v.len().max(1) as f64))
            .collect()
    }

    /// The paper's headline check: the lm_head variance dominates.
    pub fn head_dominates(&self) -> bool {
        let means = self.means();
        let head = means.get("lm_head").copied().unwrap_or(0.0);
        means
            .iter()
            .filter(|(k, _)| k.starts_with("block"))
            .all(|(_, &v)| head > v)
    }
}

/// Probe the trainer's current parameters every `every` steps while
/// training for `steps` steps; returns the per-layer series.
///
/// Probe executions assemble their inputs by reference
/// (`Engine::run_exe_refs`) — the parameter set is never cloned per
/// probe, matching the trainer's own hot path.
pub fn run_probed_training(
    tr: &mut Trainer,
    steps: usize,
    every: usize,
) -> anyhow::Result<VarianceSeries> {
    let probe = tr.engine.load(&format!("varprobe_{}", tr.opts.size))?;
    let size = tr.engine.manifest.size(&tr.opts.size)?.clone();
    let big_factor = tr.engine.manifest.varprobe_big_factor;
    let mut series = VarianceSeries {
        by_layer: BTreeMap::new(),
        probe_steps: Vec::new(),
    };

    for _ in 0..steps {
        tr.train_step()?;
        if tr.step % every.max(1) != 0 {
            continue;
        }
        // draw small + big probe batches from a dedicated stream
        let small = probe_batch(tr, tr.microbatch, 0x9a);
        let big = probe_batch(tr, tr.microbatch * big_factor, 0x9b);
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(tr.params.len() + 2);
        inputs.extend(tr.params.iter());
        inputs.push(&small);
        inputs.push(&big);
        let out = tr.engine.run_exe_refs(&probe, &inputs)?;
        // aggregate per-element variances into per-layer totals
        let mut by_layer: BTreeMap<String, f64> = BTreeMap::new();
        for (p, v) in size.params.iter().zip(&out) {
            // v = ||g_small - g_big||^2 / numel; total = v * numel
            let total = v.item_f32() as f64 * p.numel() as f64;
            *by_layer.entry(layer_group(&p.name, &p.kind)).or_insert(0.0) += total;
        }
        for (k, v) in by_layer {
            series.by_layer.entry(k).or_default().push(v);
        }
        series.probe_steps.push(tr.step);
    }
    Ok(series)
}

/// Fig. 4 grouping: embed / blockN / lm_head; vectors fold into "norms".
fn layer_group(name: &str, kind: &str) -> String {
    if kind == "vector" {
        return "norms".to_string();
    }
    name.split('.').next().unwrap_or(name).to_string()
}

fn probe_batch(tr: &Trainer, b: usize, stream: u64) -> Tensor {
    tr.encode_batch(b, (stream << 40) | tr.step as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_grouping() {
        assert_eq!(layer_group("block3.wq", "matrix"), "block3");
        assert_eq!(layer_group("lm_head", "head"), "lm_head");
        assert_eq!(layer_group("embed", "embed"), "embed");
        assert_eq!(layer_group("block0.attn_norm", "vector"), "norms");
    }

    #[test]
    fn series_means_and_dominance() {
        let mut s = VarianceSeries {
            by_layer: BTreeMap::new(),
            probe_steps: vec![10, 20],
        };
        s.by_layer.insert("lm_head".into(), vec![10.0, 12.0]);
        s.by_layer.insert("block0".into(), vec![1.0, 2.0]);
        assert!((s.means()["lm_head"] - 11.0).abs() < 1e-12);
        assert!(s.head_dominates());
        s.by_layer.insert("block1".into(), vec![20.0, 20.0]);
        assert!(!s.head_dominates());
    }
}
