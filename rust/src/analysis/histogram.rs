//! LM-head gradient histograms and column norms — Fig. 3 and Fig. 10.
//!
//! Fig. 3 contrasts the value distribution of the LM-head gradient after
//! row-wise vs column-wise normalization (row-norm produces extreme
//! values that destabilize training). Fig. 10 plots per-column gradient
//! norms against token id — frequent tokens (low ids, by the tokenizer's
//! frequency-ranked vocabulary) carry far larger column norms.

use crate::optim::colnorm::{colnorm, column_norms, rownorm};

#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<usize>,
    pub max_abs: f64,
    pub n: usize,
}

impl Histogram {
    pub fn build(values: &[f32], bins: usize) -> Histogram {
        assert!(bins > 0);
        let lo = values.iter().copied().fold(f64::INFINITY, |a, b| a.min(b as f64));
        let hi = values
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, |a, b| a.max(b as f64));
        let span = (hi - lo).max(1e-12);
        let mut counts = vec![0usize; bins];
        for &v in values {
            let i = (((v as f64 - lo) / span) * bins as f64) as usize;
            counts[i.min(bins - 1)] += 1;
        }
        let max_abs = values.iter().fold(0f64, |a, &b| a.max((b as f64).abs()));
        Histogram {
            lo,
            hi,
            counts,
            max_abs,
            n: values.len(),
        }
    }

    /// ASCII rendering (log-scaled bars, like Fig. 3's log-count axis).
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1) as f64;
        let mut out = String::new();
        for (i, &c) in self.counts.iter().enumerate() {
            let a = self.lo + (self.hi - self.lo) * i as f64 / self.counts.len() as f64;
            let bar = if c == 0 {
                0
            } else {
                (((c as f64).ln_1p() / max.ln_1p()) * width as f64).ceil() as usize
            };
            out.push_str(&format!("{a:>10.3} |{}\n", "#".repeat(bar)));
        }
        out
    }
}

/// Fig. 3 reproduction: the LM-head gradient under both normalizations.
/// Returns (row_normalized_hist, col_normalized_hist).
///
/// Entries are reported in the paper's RMS convention (unit-norm rescaled
/// by the sqrt of the normalized axis length, so an all-equal vector maps
/// to all-ones). Under the frequent-token column skew of the LM head,
/// row-wise normalization concentrates each row's mass on a few columns
/// and the sqrt(|V|) factor blows those entries up to O(sqrt(|V|)) — the
/// "values up to 150" of Fig. 3(a) — while column-wise entries stay
/// within O(1) (Fig. 3(b)).
pub fn head_grad_histograms(
    head_grad: &[f32],
    d_model: usize,
    vocab: usize,
    bins: usize,
) -> (Histogram, Histogram) {
    let rs = (vocab as f32).sqrt();
    let cs = (d_model as f32).sqrt();
    let row: Vec<f32> = rownorm(head_grad, d_model, vocab)
        .into_iter()
        .map(|x| x * rs)
        .collect();
    let col: Vec<f32> = colnorm(head_grad, d_model, vocab)
        .into_iter()
        .map(|x| x * cs)
        .collect();
    (Histogram::build(&row, bins), Histogram::build(&col, bins))
}

/// Fig. 10 reproduction: per-column (per-token) gradient norms of the
/// LM head. Returns norms indexed by token id.
pub fn head_column_norms(head_grad: &[f32], d_model: usize, vocab: usize) -> Vec<f32> {
    column_norms(head_grad, d_model, vocab)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn histogram_counts_everything() {
        let vals = vec![-1.0f32, -0.5, 0.0, 0.5, 1.0, 1.0];
        let h = Histogram::build(&vals, 4);
        assert_eq!(h.counts.iter().sum::<usize>(), 6);
        assert_eq!(h.n, 6);
        assert_eq!(h.max_abs, 1.0);
    }

    #[test]
    fn histogram_property_total_preserved() {
        prop::quick("hist-total", |rng| {
            let n = prop::usize_in(rng, 1, 500);
            let vals = prop::matrix(rng, 1, n, 2.0);
            let bins = prop::usize_in(rng, 1, 32);
            let h = Histogram::build(&vals, bins);
            prop::ensure(h.counts.iter().sum::<usize>() == n, "lost values")
        });
    }

    #[test]
    fn rownorm_produces_larger_extremes_on_skewed_head() {
        // Construct the paper's regime: a few frequent-token columns with
        // huge norms, many rare columns with tiny norms. Row-wise
        // normalization then *inflates* the rare columns' entries.
        let (d, v) = (16, 128);
        let mut rng = crate::util::rng::Pcg::new(2);
        let mut g = vec![0f32; d * v];
        for r in 0..d {
            for c in 0..v {
                let scale = if c < 4 { 100.0 } else { 0.01 };
                g[r * v + c] = scale * rng.normal() as f32;
            }
        }
        let (row_h, col_h) = head_grad_histograms(&g, d, v, 32);
        assert!(
            row_h.max_abs > 3.0 * col_h.max_abs,
            "row {} vs col {}",
            row_h.max_abs,
            col_h.max_abs
        );
        // column-wise entries stay within the RMS O(1) band: sqrt(d)*1
        assert!(col_h.max_abs <= (d as f64).sqrt() + 1e-5);
    }

    #[test]
    fn column_norms_reflect_frequency_skew() {
        let (d, v) = (8, 64);
        let mut g = vec![0f32; d * v];
        for r in 0..d {
            for c in 0..v {
                g[r * v + c] = if c < 5 { 10.0 } else { 0.1 };
            }
        }
        let norms = head_column_norms(&g, d, v);
        assert!(norms[..5].iter().all(|&n| n > 10.0));
        assert!(norms[5..].iter().all(|&n| n < 1.0));
    }
}
