//! Table renderer: fixed-width terminal tables with the "paper vs
//! measured" layout every `scale table <n>` command prints.

#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnotes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnotes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    pub fn footnote(&mut self, note: &str) -> &mut Self {
        self.footnotes.push(note.to_string());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * (ncols - 1);
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let line = |out: &mut String| {
            out.push_str(&"-".repeat(total));
            out.push('\n');
        };
        line(&mut out);
        for (i, h) in self.headers.iter().enumerate() {
            if i > 0 {
                out.push_str(" | ");
            }
            out.push_str(&format!("{:<w$}", h, w = widths[i]));
        }
        out.push('\n');
        line(&mut out);
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                if i > 0 {
                    out.push_str(" | ");
                }
                // right-align numeric-ish cells
                if c.chars().next().map(|ch| ch.is_ascii_digit() || ch == '-').unwrap_or(false)
                {
                    out.push_str(&format!("{:>w$}", c, w = widths[i]));
                } else {
                    out.push_str(&format!("{:<w$}", c, w = widths[i]));
                }
            }
            out.push('\n');
        }
        line(&mut out);
        for n in &self.footnotes {
            out.push_str(&format!("  * {n}\n"));
        }
        out
    }
}

/// Format helpers used across the bench harness.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn gb(x: f64) -> String {
    format!("{x:.2}G")
}

pub fn opt_label(name: &str) -> &str {
    match name {
        "scale" => "SCALE (ours)",
        "stable_spam" => "Adam (Stable-SPAM)",
        "adam" => "Adam",
        "muon" => "Muon",
        "galore" => "GaLore",
        "fira" => "Fira",
        "apollo" => "APOLLO",
        "apollo_mini" => "APOLLO-Mini",
        "swan" => "SWAN (reconstr.)",
        "sgd" => "SGD",
        "sgd_momentum" => "SGD-M",
        "sgd_colnorm" => "column-wise",
        "sgd_rownorm" => "row-wise",
        "sign_sgd" => "sign",
        "sgd_ns" => "singular-value (NS)",
        "ns_mmt_last" => "Singular-val (NS) + mmt-last",
        "scale_first_last" => "SGD col mmt-(first+last)",
        "mix_col_last_row_rest" => "column-last, row-rest",
        "mix_row_first_col_rest" => "row-first, column-rest",
        "mix_larger_dim" => "norm along larger dim",
        "mix_row_last_col_rest" => "row-last, column-rest",
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Method", "PPL", "Mem"]);
        t.row(vec!["SCALE (ours)".into(), "16.32".into(), "0.80G".into()]);
        t.row(vec!["Adam".into(), "18.77".into(), "2.21G".into()]);
        t.footnote("paper values");
        let s = t.render();
        assert!(s.contains("SCALE (ours)"));
        assert!(s.contains("Method"));
        assert!(s.contains("* paper values"));
        // column alignment: both data rows have the separator at the same col
        let lines: Vec<&str> = s.lines().filter(|l| l.contains(" | ")).collect();
        let idx: Vec<usize> = lines.iter().map(|l| l.find(" | ").unwrap()).collect();
        assert!(idx.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn labels() {
        assert_eq!(opt_label("scale"), "SCALE (ours)");
        assert_eq!(opt_label("unknown_thing"), "unknown_thing");
    }
}
