//! `scale` — the launcher CLI for the SCALE reproduction.
//!
//! Subcommands:
//!   train            train one configuration (preset file + overrides)
//!   eval             evaluate a checkpoint's perplexity
//!   serve            KV-cache inference server (newline-JSON, stdio/TCP)
//!   table `<n>`      regenerate paper table n (1-13)
//!   figure `<n>`     regenerate paper figure n (1-10)
//!   memory-report    Appendix-B memory accounting (exact)
//!   variance         Fig.-4 style per-layer variance probe
//!   sweep            concurrent multi-axis grid (optimizer x lr x seed)
//!   sweep-lr         LR sweep for one optimizer
//!   compare          multi-seed verdict: mean/CI ranking at a memory budget
//!   lr-curve         Fig.-8 LR-sensitivity curves as a JSON artifact
//!   launch           fault-tolerant multi-process mesh training
//!   worker           internal: one mesh rank (spawned by launch)
//!   ablate-momentum  Theorem 2.1 noisy-quadratic placement study
//!   list             show available sizes/optimizers/artifacts
//!
//! All experiment commands accept --steps/--size to trade fidelity for
//! time; defaults are small (minutes, not hours) on a 1-core CPU.

use scale_llm::analysis::tables::Table;
use scale_llm::config;
use scale_llm::coordinator::{Checkpoint, CheckpointStore, GuardPolicy, TrainOptions, Trainer};
use scale_llm::harness::{self, figures, tables};
use scale_llm::memory::estimator::{measured_state_bytes, sharded_state_bytes};
use scale_llm::optim::sim;
use scale_llm::runtime::Engine;
use scale_llm::util::cli::Args;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifact_dir(args: &mut Args) -> String {
    args.get_or("artifacts", "artifacts")
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::from_env()?;
    // deterministic fault injection (chaos testing): --faults on any
    // subcommand, or the SCALE_FAULTS environment variable; when both
    // are set, --faults wins (the CLI is the more deliberate act)
    let fault_spec = args.get("faults").map(str::to_string);
    scale_llm::fault::configure_from_sources(fault_spec.as_deref())?;
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "train" => cmd_train(&mut args),
        "eval" => cmd_eval(&mut args),
        "serve" => cmd_serve(&mut args),
        "table" => cmd_table(&mut args),
        "figure" => cmd_figure(&mut args),
        "memory-report" => cmd_memory(&mut args),
        "variance" => cmd_variance(&mut args),
        "sweep" => cmd_sweep_grid(&mut args),
        "sweep-lr" => cmd_sweep(&mut args),
        "compare" => cmd_compare(&mut args),
        "lr-curve" => cmd_lr_curve(&mut args),
        "launch" => cmd_launch(&mut args),
        "worker" => cmd_worker(&mut args),
        "ablate-momentum" => cmd_ablate(&mut args),
        "list" => cmd_list(&mut args),
        "help" | "--help" => {
            println!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand {other:?}\n{HELP}"),
    }
}

const HELP: &str = "scale — SCALE optimizer reproduction (Rust + JAX + Pallas via PJRT)

usage: scale <subcommand> [options]

  train           --size s130m --optimizer scale --steps 200 --lr 1e-2
                  [--preset configs/x.json] [--save ckpt.bin]
                  [--resume ckpt.bin | --resume auto]
                  [--checkpoint-every N]  guard mode: auto-checkpoint into
                  --ckpt-dir (default ckpts), roll back on divergence with
                  --lr-backoff (0.5) up to --retries (3) times, keep the
                  newest --keep-last (3) snapshots
  eval            --load ckpt.bin [--eval-batches 16]
  serve           [--load ckpt.bin | --size tiny --seed 0] [--max-batch 4]
                  [--tcp 127.0.0.1:7878] [--quiet]   continuous-batching
                  KV-cache decode server; newline-JSON requests like
                  {\"id\":\"r1\",\"prompt\":[1,2,3],\"max_new\":8,\"seed\":7}
                  on stdin (or per TCP connection), one completion /
                  error line back per request; banner on stderr
  table <1..13>   regenerate a paper table  [--steps N] [--sizes s60m,s130m]
  figure <1..10>  regenerate a paper figure [--steps N] [--size s130m]
  memory-report   Appendix-B accounting (exact paper numbers)
                  [--ranks N] adds measured per-rank state bytes under
                  --shard-state (SCALE vs Adam at 1/2/../N ranks)
  variance        per-layer gradient variance probe [--optimizer ...]
  sweep           --size s130m --optimizers scale,adam --lrs 1e-3,1e-2
                  [--seeds 0,1] [--steps N] [--shards N] [--json]
                  [--max-concurrent N] [--retries N]   concurrent trial
                  grid on the shared pool; without --lr/--lrs each
                  optimizer uses its tuned default LR; --json emits the
                  report on stdout; --retries re-runs trials that hit
                  transient faults before slotting them as faulted
  sweep-lr        --optimizer scale --size s130m --steps 100
  compare         --optimizers scale,adapm_last,adams,adam --seeds 3
                  [--size tiny] [--steps N] [--lrs 1e-3,1e-2]
                  [--budget BYTES] [--json]   multi-seed statistical
                  verdict: per-(optimizer, lr) mean/stddev/95% CI over
                  seeds 0..N, ranked by best mean ppl among optimizers
                  whose measured state bytes fit --budget (0 = none);
                  without --lrs each optimizer runs its tuned default LR
  lr-curve        --optimizers scale,adam --seeds 2 [--size tiny]
                  [--steps N] [--lrs ...] [--out FILE] [--json]
                  Fig.-8 LR-sensitivity curves (multi-seed mean/CI per
                  LR on the paper grid); --out writes the JSON artifact
                  and re-parses it before reporting success
  launch          --ranks 2 --size s60m --optimizer scale --steps 100
                  fault-tolerant multi-process mesh training: forks one
                  `scale worker` per rank, localhost TCP with CRC-framed
                  wire, heartbeats, and respawn + checkpoint-rollback
                  recovery  [--max-respawns N] [--checkpoint-every N]
                  [--ckpt-dir DIR] [--keep-last N] [--heartbeat-every N]
                  [--connect-timeout-ms N] [--io-timeout-ms N]
                  [--shard-state]  shard the optimizer state over the
                  ranks (each worker owns + applies its slice of the
                  update plan; checkpoints become per-rank shard dirs;
                  bit-identical to the default mode)
  worker          internal: one mesh rank (spawned by launch)
  ablate-momentum Theorem 2.1 noisy-quadratic placement study
  list            artifacts / sizes / optimizers available

common: --artifacts DIR (default ./artifacts), --quiet,
        --faults SPEC (deterministic failpoint injection, e.g.
        grad_nan@5 or trial1/trial_panic@1; also via SCALE_FAULTS —
        when both are set, --faults wins)";

fn cmd_train(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let preset = args.get("preset").map(|s| s.to_string());
    let save = args.get("save").map(|s| s.to_string());
    let resume = args.get("resume").map(|s| s.to_string());
    let ckpt_every = args.get_usize("checkpoint-every", 0)?;
    let ckpt_dir = args.get_or("ckpt-dir", "ckpts");
    let keep_last = args.get_usize("keep-last", 3)?;
    let retries = args.get_usize("retries", 3)?;
    let lr_backoff = args.get_f64("lr-backoff", 0.5)?;
    let base = match preset {
        Some(p) => config::load_preset(p)?,
        None => TrainOptions::default(),
    };
    let opts = config::apply_cli(base, args)?;
    args.finish()?;

    let engine = Engine::new(&dir)?;
    println!(
        "platform: {} | size {} | optimizer {} | {} steps | lr {:.1e} | {} shards",
        engine.platform(),
        opts.size,
        opts.optimizer,
        opts.steps,
        opts.base_lr,
        opts.shards
    );
    let mut tr = Trainer::new(&engine, opts)?;
    match resume.as_deref() {
        // `--resume auto`: newest loadable snapshot in the run's
        // checkpoint directory (corrupt ones are quarantined over)
        Some("auto") => {
            let store = CheckpointStore::open(&ckpt_dir, keep_last)?;
            match store.latest()? {
                Some((step, ckpt)) => {
                    tr.restore(&ckpt)?;
                    println!("resumed from {} at step {step}", store.dir().display());
                }
                None => println!("no snapshot in {}; starting fresh", store.dir().display()),
            }
        }
        Some(path) => {
            let ckpt = Checkpoint::load(path)?;
            tr.restore(&ckpt)?;
            println!("resumed from {path} at step {}", tr.step);
        }
        None => {}
    }
    let ppl = if ckpt_every > 0 {
        let mut policy = GuardPolicy::new(&ckpt_dir);
        policy.checkpoint_every = ckpt_every;
        policy.keep_last = keep_last;
        policy.max_retries = retries;
        policy.lr_backoff = lr_backoff;
        tr.train_guarded(&policy)?
    } else {
        tr.train()?
    };
    println!(
        "final eval ppl {ppl:.3} | {:.0} tok/s | optimizer state {} KiB",
        tr.metrics.tokens_per_sec(),
        tr.state_bytes() / 1024
    );
    if let Some(s) = save {
        tr.checkpoint()?.save(&s)?;
        println!("checkpoint written to {s}");
    }
    Ok(())
}

fn cmd_eval(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let load = args
        .get("load")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow::anyhow!("eval requires --load <ckpt>"))?;
    let eval_batches = args.get_usize("eval-batches", 16)?;
    args.finish()?;
    let engine = Engine::new(&dir)?;
    let ckpt = Checkpoint::load(&load)?;
    let opts = TrainOptions {
        size: ckpt.size.clone(),
        optimizer: ckpt.optimizer.clone(),
        eval_batches,
        quiet: true,
        ..TrainOptions::default()
    };
    let mut tr = Trainer::new(&engine, opts)?;
    tr.restore(&ckpt)?;
    let loss = tr.eval()?;
    println!(
        "checkpoint {load}: step {} eval loss {loss:.4} ppl {:.3}",
        tr.step,
        loss.exp()
    );
    Ok(())
}

/// `scale serve`: KV-cache incremental decode behind the
/// continuous-batching scheduler, speaking newline-JSON over
/// stdin/stdout (default) or a TCP accept loop. Weights come from
/// `--load ckpt.bin` (trained) or a seeded init of `--size`.
fn cmd_serve(args: &mut Args) -> anyhow::Result<()> {
    use scale_llm::serve::{server::ServeOptions, ServeModel};
    let size = args.get_or("size", "tiny");
    let seed = args.get_usize("seed", 0)? as u64;
    let load = args.get("load").map(str::to_string);
    let max_batch = args.get_usize("max-batch", 4)?;
    let tcp = args.get("tcp").map(str::to_string);
    let quiet = args.flag("quiet");
    args.finish()?;
    let model = match &load {
        Some(p) => ServeModel::from_checkpoint(std::path::Path::new(p))?,
        None => ServeModel::init(&size, seed)?,
    };
    let opts = ServeOptions { max_batch, quiet };
    match tcp {
        Some(addr) => scale_llm::serve::server::run_tcp(&model, &addr, &opts),
        None => scale_llm::serve::server::run_stdio(&model, &opts),
    }
}

fn sizes_arg(args: &mut Args, default: &str) -> Vec<String> {
    let got = csv_list(args, "sizes");
    if got.is_empty() {
        default.split(',').map(String::from).collect()
    } else {
        got
    }
}

fn cmd_table(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let n: usize = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("table requires a number (1-13)"))?
        .parse()?;
    let steps = args.get_usize("steps", 150)?;
    let sizes = sizes_arg(args, "s60m,s130m,s350m");
    let size = args.get_or("size", "s130m");
    let bench_secs = args.get_f64("bench-secs", 2.0)?;
    args.finish()?;
    let engine = Engine::new(&dir)?;
    let out = match n {
        1 => tables::table1(&engine, bench_secs)?,
        2 => tables::table2(&engine, &sizes, steps)?,
        3 => tables::table3(&engine, &sizes, steps)?,
        4 => tables::table4(&engine)?,
        5 => tables::table5(&engine, &sizes, steps)?,
        6 => tables::table6(&engine, steps)?,
        7 => tables::table7(&engine, &size, steps.min(30))?,
        8 => tables::table8(&engine, &sizes, steps)?,
        9 => tables::table9(&engine, steps)?,
        11 => tables::table11(&engine, &size, steps)?,
        12 => tables::table12(&engine, &size, steps, steps / 2)?,
        13 => tables::table13(&engine, steps)?,
        10 => anyhow::bail!(
            "table 10 is Gemma-2B (resource-gated even in the paper); \
             see `scale table 9` for the architecture-generality check"
        ),
        _ => anyhow::bail!("unknown table {n}"),
    };
    println!("{out}");
    Ok(())
}

fn cmd_figure(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let n: usize = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("figure requires a number (1-10)"))?
        .parse()?;
    let steps = args.get_usize("steps", 150)?;
    let size = args.get_or("size", "s130m");
    let optimizer = args.get_or("optimizer", "sgd_colnorm");
    args.finish()?;
    let engine = Engine::new(&dir)?;
    let out = match n {
        1 => figures::figure1(&engine, &size, steps)?,
        2 => figures::figure2(&engine, &size, steps)?,
        3 => figures::figure3(&engine, &size, steps)?,
        4 | 6 | 7 => figures::figure4(&engine, &size, steps, &optimizer)?,
        5 => figures::figure5(&engine, steps)?,
        8 => figures::figure8(&engine, &size, steps)?,
        9 => figures::figure9(&engine, &size, steps)?,
        10 => figures::figure10(&engine, &size, steps)?,
        _ => anyhow::bail!("unknown figure {n}"),
    };
    println!("{out}");
    Ok(())
}

fn cmd_memory(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let ranks = args.get_usize("ranks", 0)?;
    args.finish()?;
    let engine = Engine::new(&dir)?;
    println!("{}", tables::table4(&engine)?);
    // measured footprints of the tiny runs
    let mut t = Table::new(
        "Measured optimizer-state footprint (this repo's tiny runs, f32)",
        &["size", "params KiB", "sgd", "scale", "muon", "apollo_mini", "adam"],
    );
    for (name, info) in &engine.manifest.sizes {
        let cell = |o: &str| -> String {
            measured_state_bytes(&engine.manifest, o, name)
                .map(|b| format!("{} KiB", b / 1024))
                .unwrap_or_else(|_| "-".into())
        };
        t.row(vec![
            name.clone(),
            format!("{}", 4 * info.param_count / 1024),
            cell("sgd"),
            cell("scale"),
            cell("muon"),
            cell("apollo_mini"),
            cell("adam"),
        ]);
    }
    println!("{}", t.render());
    // measured per-rank footprint under `launch --shard-state`: the
    // exact shard partition the mesh uses, peak rank vs peak rank
    if ranks > 0 {
        let mut counts: Vec<usize> = vec![1, 2, 4, ranks];
        counts.retain(|&c| c <= ranks);
        counts.sort_unstable();
        counts.dedup();
        let mut t = Table::new(
            "Sharded optimizer state (launch --shard-state): measured peak bytes per rank",
            &["size", "ranks", "scale peak/rank", "adam peak/rank", "scale/adam"],
        );
        for name in engine.manifest.sizes.keys() {
            for &c in &counts {
                let (Ok(scale), Ok(adam)) = (
                    sharded_state_bytes(&engine.manifest, "scale", name, c),
                    sharded_state_bytes(&engine.manifest, "adam", name, c),
                ) else {
                    continue;
                };
                let ps = scale.iter().max().copied().unwrap_or(0);
                let pa = adam.iter().max().copied().unwrap_or(0);
                t.row(vec![
                    name.clone(),
                    format!("{c}"),
                    format!("{ps} B"),
                    format!("{pa} B"),
                    if pa > 0 { format!("{:.3}", ps as f64 / pa as f64) } else { "-".into() },
                ]);
            }
        }
        t.footnote(
            "peak rank vs peak rank; the paper's <=45% SCALE/Adam bound holds at every rank count",
        );
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_variance(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    let size = args.get_or("size", "s130m");
    let steps = args.get_usize("steps", 120)?;
    let optimizer = args.get_or("optimizer", "sgd_colnorm");
    args.finish()?;
    let engine = Engine::new(&dir)?;
    println!("{}", figures::figure4(&engine, &size, steps, &optimizer)?);
    Ok(())
}

/// Comma-separated option value -> trimmed entries (absent key -> empty).
fn csv_list(args: &mut Args, key: &str) -> Vec<String> {
    args.get_or(key, "")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect()
}

/// `scale sweep`: the concurrent multi-trial engine. Axes left empty
/// collapse to the base value, so `--lrs`-only is the classic LR sweep
/// and `--optimizers`-only is a Table-13-style face-off — in which
/// case, unless `--lr`/`--lrs` pins one explicitly, every optimizer
/// trains at its own tuned default LR (the same resolution `table 13`
/// and `run_zoo` use), not at one shared base LR.
fn cmd_sweep_grid(args: &mut Args) -> anyhow::Result<()> {
    use scale_llm::coordinator::sweep::{report_json, SweepSpec};
    let dir = artifact_dir(args);
    let size = args.get_or("size", "s130m");
    let optimizer = args.get_or("optimizer", "scale");
    let steps = args.get_usize("steps", 100)?;
    let shards = args.get_usize("shards", 4)?;
    let seed = args.get_usize("seed", 0)? as u64;
    let eval_batches = args.get_usize("eval-batches", 8)?;
    let max_concurrent = args.get_usize("max-concurrent", 0)?;
    let lr_arg = args.get("lr").map(str::to_string);
    let lr = match &lr_arg {
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| anyhow::anyhow!("--lr expects a number, got {v:?}"))?,
        None => harness::default_lr(&optimizer),
    };
    let lrs: Vec<f64> = csv_list(args, "lrs")
        .iter()
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--lrs expects numbers, got {s:?}"))
        })
        .collect::<anyhow::Result<_>>()?;
    let optimizers = csv_list(args, "optimizers");
    let seeds: Vec<u64> = csv_list(args, "seeds")
        .iter()
        .map(|s| {
            s.parse::<u64>()
                .map_err(|_| anyhow::anyhow!("--seeds expects integers, got {s:?}"))
        })
        .collect::<anyhow::Result<_>>()?;
    let retries = args.get_usize("retries", 0)?;
    let json = args.flag("json");
    args.finish()?;

    // face-off semantics: with an optimizer axis and no explicit LR,
    // each optimizer resolves its own tuned default per trial
    let lr_for = if lr_arg.is_none() && lrs.is_empty() {
        Some(harness::default_lr as fn(&str) -> f64)
    } else {
        None
    };
    let engine = Engine::new(&dir)?;
    let base = TrainOptions {
        size,
        optimizer,
        steps,
        base_lr: lr,
        schedule: None,
        shards,
        seed,
        eval_every: 0,
        eval_batches,
        log_every: 0,
        quiet: true,
    };
    let spec = SweepSpec {
        base,
        lrs,
        optimizers,
        seeds,
        lr_for,
        max_concurrent,
        retries,
    };
    // fail fast on a typo'd optimizer before any trial trains
    for opt in &spec.optimizers {
        engine.manifest.artifact(&format!("update_{opt}_{}", spec.base.size))?;
    }
    let pts = spec.run(&engine)?;
    if json {
        println!("{}", report_json(&spec, &pts));
        return Ok(());
    }
    let mut t = Table::new(
        &format!("sweep — {} trials ({steps} steps, size {})", pts.len(), spec.base.size),
        &["optimizer", "lr", "seed", "final ppl", "outcome", "attempts"],
    );
    for p in &pts {
        t.row(vec![
            p.optimizer.clone(),
            format!("{:.0e}", p.lr),
            format!("{}", p.seed),
            harness::ppl_cell(p.ppl),
            p.outcome.as_str().into(),
            format!("{}", p.attempts),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_sweep(args: &mut Args) -> anyhow::Result<()> {
    use scale_llm::coordinator::sweep::{paper_lr_grid, SweepSpec};
    let dir = artifact_dir(args);
    let size = args.get_or("size", "s130m");
    let optimizer = args.get_or("optimizer", "scale");
    let steps = args.get_usize("steps", 100)?;
    let max_concurrent = args.get_usize("max-concurrent", 0)?;
    args.finish()?;
    let engine = Engine::new(&dir)?;
    let base = TrainOptions {
        size,
        optimizer: optimizer.clone(),
        steps,
        quiet: true,
        ..TrainOptions::default()
    };
    let mut spec = SweepSpec::lr_grid(base, &paper_lr_grid());
    spec.max_concurrent = max_concurrent;
    let pts = spec.run(&engine)?;
    let mut t = Table::new(
        &format!("LR sweep — {optimizer} ({steps} steps)"),
        &["lr", "final ppl", "diverged"],
    );
    for p in pts {
        t.row(vec![
            format!("{:.0e}", p.lr),
            harness::ppl_cell(p.ppl),
            if p.diverged { "yes".into() } else { "no".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// Parse `--lrs` / `--seeds N` style axes shared by compare/lr-curve:
/// `--seeds` here is a *count* (seeds 0..N), not a list — the verdict
/// layer owns the aggregation across them.
fn lrs_arg(args: &mut Args) -> anyhow::Result<Vec<f64>> {
    csv_list(args, "lrs")
        .iter()
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--lrs expects numbers, got {s:?}"))
        })
        .collect()
}

/// `scale compare`: the multi-seed statistical verdict. Runs every
/// (optimizer, lr) cell across seeds 0..N, folds the finite trials into
/// mean/stddev/95% CI (deterministic accumulation order — bit-stable
/// across pool sizes), and ranks optimizers by best mean ppl among
/// those whose measured state bytes fit `--budget`.
fn cmd_compare(args: &mut Args) -> anyhow::Result<()> {
    use scale_llm::coordinator::sweep::{compare_report_json, SweepSpec, VerdictSpec};
    let dir = artifact_dir(args);
    let size = args.get_or("size", "tiny");
    let steps = args.get_usize("steps", 40)?;
    let shards = args.get_usize("shards", 4)?;
    let eval_batches = args.get_usize("eval-batches", 8)?;
    let max_concurrent = args.get_usize("max-concurrent", 0)?;
    let retries = args.get_usize("retries", 0)?;
    let n_seeds = args.get_usize("seeds", 3)?;
    let budget = args.get_usize("budget", 0)?;
    let mut optimizers = csv_list(args, "optimizers");
    if optimizers.is_empty() {
        optimizers = ["scale", "adapm_last", "adams", "adam"].map(String::from).to_vec();
    }
    let lrs = lrs_arg(args)?;
    let json = args.flag("json");
    args.finish()?;
    anyhow::ensure!(n_seeds > 0, "--seeds must be at least 1");

    // without --lrs each optimizer trains at its own tuned default LR
    let lr_for = if lrs.is_empty() {
        Some(harness::default_lr as fn(&str) -> f64)
    } else {
        None
    };
    let engine = Engine::new(&dir)?;
    let base = TrainOptions {
        size,
        optimizer: optimizers[0].clone(),
        steps,
        shards,
        eval_batches,
        quiet: true,
        ..TrainOptions::default()
    };
    let spec = SweepSpec {
        base,
        lrs,
        optimizers,
        seeds: (0..n_seeds as u64).collect(),
        lr_for,
        max_concurrent,
        retries,
    };
    for opt in &spec.optimizers {
        engine.manifest.artifact(&format!("update_{opt}_{}", spec.base.size))?;
    }
    let pts = spec.run(&engine)?;
    let vspec = VerdictSpec { memory_budget: (budget > 0).then_some(budget) };
    let verdict =
        vspec.verdict(&pts, |opt| measured_state_bytes(&engine.manifest, opt, &spec.base.size))?;
    if json {
        println!("{}", compare_report_json(&spec, &vspec, &verdict));
        return Ok(());
    }
    let mut t = Table::new(
        &format!(
            "compare — {} optimizers x {n_seeds} seeds ({steps} steps, size {})",
            spec.optimizers.len(),
            spec.base.size
        ),
        &["rank", "optimizer", "best lr", "mean ppl", "ci95", "n_eff", "state bytes", "fits"],
    );
    for (i, r) in verdict.ranking.iter().enumerate() {
        t.row(vec![
            format!("{}", i + 1),
            r.optimizer.clone(),
            format!("{:.0e}", r.best.lr),
            harness::ppl_cell(r.best.mean_ppl),
            if r.best.n_effective >= 2 { format!("±{:.3}", r.best.ci95_ppl) } else { "-".into() },
            format!("{}/{}", r.best.n_effective, r.best.n_trials),
            format!("{}", r.state_bytes),
            if r.within_budget { "yes".into() } else { "no".into() },
        ]);
    }
    if budget > 0 {
        t.footnote(&format!("budget {budget} B: optimizers over budget rank below all that fit"));
    }
    println!("{}", t.render());
    Ok(())
}

/// `scale lr-curve`: Fig.-8 LR sensitivity as a committed JSON
/// artifact. Multi-seed mean/CI per (optimizer, lr) on the paper grid;
/// `--out` writes the artifact and re-parses the written bytes before
/// reporting success, refusing to emit an all-diverged curve.
fn cmd_lr_curve(args: &mut Args) -> anyhow::Result<()> {
    use scale_llm::coordinator::sweep::{
        aggregate_cells, lr_curve_report_json, paper_lr_grid, SweepSpec,
    };
    use scale_llm::util::json;
    let dir = artifact_dir(args);
    let size = args.get_or("size", "tiny");
    let steps = args.get_usize("steps", 40)?;
    let shards = args.get_usize("shards", 4)?;
    let eval_batches = args.get_usize("eval-batches", 8)?;
    let max_concurrent = args.get_usize("max-concurrent", 0)?;
    let n_seeds = args.get_usize("seeds", 2)?;
    let mut optimizers = csv_list(args, "optimizers");
    if optimizers.is_empty() {
        optimizers = ["scale", "adam"].map(String::from).to_vec();
    }
    let mut lrs = lrs_arg(args)?;
    if lrs.is_empty() {
        lrs = paper_lr_grid();
    }
    let out = args.get("out").map(str::to_string);
    let json_flag = args.flag("json");
    args.finish()?;
    anyhow::ensure!(n_seeds > 0, "--seeds must be at least 1");

    let engine = Engine::new(&dir)?;
    let base = TrainOptions {
        size,
        optimizer: optimizers[0].clone(),
        steps,
        shards,
        eval_batches,
        quiet: true,
        ..TrainOptions::default()
    };
    let spec = SweepSpec {
        base,
        lrs,
        optimizers,
        seeds: (0..n_seeds as u64).collect(),
        lr_for: None,
        max_concurrent,
        retries: 0,
    };
    for opt in &spec.optimizers {
        engine.manifest.artifact(&format!("update_{opt}_{}", spec.base.size))?;
    }
    let pts = spec.run(&engine)?;
    let cells = aggregate_cells(&pts);
    // an artifact where every cell diverged carries no curve at all —
    // refuse it the same way the bench refuses an empty history append
    anyhow::ensure!(
        cells.iter().any(|c| c.n_effective > 0),
        "every (optimizer, lr) cell diverged — refusing to emit an all-null LR curve"
    );
    let report = lr_curve_report_json(&spec, &cells);
    if let Some(path) = &out {
        let mut text = report.to_string();
        text.push('\n');
        std::fs::write(path, &text)?;
        // the committed artifact must round-trip through our own parser
        let back = json::parse(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("written artifact {path} does not re-parse: {e}"))?;
        anyhow::ensure!(back == report, "written artifact {path} round-trips to different JSON");
        println!("wrote {path} ({} curves)", spec.optimizers.len());
    }
    if json_flag {
        println!("{report}");
    } else if out.is_none() {
        let mut t = Table::new(
            &format!("LR curves — {n_seeds} seeds ({steps} steps, size {})", spec.base.size),
            &["optimizer", "lr", "mean ppl", "ci95", "n_eff"],
        );
        for c in &cells {
            t.row(vec![
                c.optimizer.clone(),
                format!("{:.0e}", c.lr),
                harness::ppl_cell(c.mean_ppl),
                if c.n_effective >= 2 { format!("±{:.3}", c.ci95_ppl) } else { "-".into() },
                format!("{}/{}", c.n_effective, c.n_trials),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

/// `scale launch --ranks N`: fault-tolerant multi-process mesh
/// training. The supervisor runs in this process; workers are forked
/// `scale worker` instances of the same binary.
fn cmd_launch(args: &mut Args) -> anyhow::Result<()> {
    use scale_llm::mesh::{self, MeshOptions};
    let dir = artifact_dir(args);
    let ranks = args.get_usize("ranks", 2)?;
    let base = config::apply_cli(TrainOptions::default(), args)?;
    let mut mopts = MeshOptions::new(base, ranks);
    mopts.artifacts = dir.clone();
    mopts.ckpt_dir = args.get_or("ckpt-dir", "mesh_ckpts").into();
    mopts.checkpoint_every = args.get_usize("checkpoint-every", 50)?;
    mopts.keep_last = args.get_usize("keep-last", 3)?;
    mopts.max_respawns = args.get_usize("max-respawns", 3)?;
    mopts.heartbeat_every = args.get_usize("heartbeat-every", 16)?;
    mopts.connect_timeout_ms = args.get_usize("connect-timeout-ms", 30_000)? as u64;
    mopts.read_timeout_ms = args.get_usize("io-timeout-ms", 30_000)? as u64;
    mopts.shard_state = args.flag("shard-state");
    args.finish()?;
    let engine = Engine::new(&dir)?;
    if !mopts.train.quiet {
        println!(
            "mesh: {ranks} ranks | size {} | optimizer {} | {} steps",
            mopts.train.size, mopts.train.optimizer, mopts.train.steps
        );
    }
    let (tr, report) = mesh::train(&engine, &mopts)?;
    println!(
        "mesh final eval ppl {:.3} | {} respawns | {} frame retries | optimizer state {} KiB",
        report.ppl,
        report.respawns,
        report.frame_retries,
        tr.state_bytes() / 1024
    );
    Ok(())
}

/// `scale worker`: one rank of a mesh run. Spawned by `launch`; not
/// meant to be invoked by hand.
fn cmd_worker(args: &mut Args) -> anyhow::Result<()> {
    use scale_llm::mesh::{self, WorkerOptions};
    let dir = artifact_dir(args);
    let rank = args.get_usize("rank", 0)?;
    let ranks = args.get_usize("ranks", 1)?;
    let connect = args
        .get("connect")
        .map(str::to_string)
        .ok_or_else(|| anyhow::anyhow!("worker requires --connect <addr>"))?;
    let shard_state = args.flag("shard-state");
    let mut train = config::apply_cli(TrainOptions::default(), args)?;
    train.shards = ranks;
    train.quiet = true;
    args.finish()?;
    let engine = Engine::new(&dir)?;
    mesh::run_worker(&engine, &WorkerOptions { rank, ranks, connect, shard_state, train })
}

fn cmd_ablate(args: &mut Args) -> anyhow::Result<()> {
    let seeds = args.get_usize("seeds", 5)? as u64;
    args.finish()?;
    let (none, on_noisy, on_quiet) = sim::momentum_placement_study(seeds);
    let mut t = Table::new(
        "Theorem 2.1 — momentum placement on the noisy-quadratic testbed",
        &["placement", "sum of layer tracking errors", "state cost"],
    );
    t.row(vec!["no momentum".into(), format!("{none:.4}"), "0".into()]);
    t.row(vec![
        "momentum on noisy (last) layer".into(),
        format!("{on_noisy:.4}"),
        "1 layer".into(),
    ]);
    t.row(vec![
        "momentum on quiet layers".into(),
        format!("{on_quiet:.4}"),
        "3 layers".into(),
    ]);
    t.footnote("the Theorem 2.1 shape: the noisy layer is where momentum pays");
    println!("{}", t.render());
    Ok(())
}

fn cmd_list(args: &mut Args) -> anyhow::Result<()> {
    let dir = artifact_dir(args);
    args.finish()?;
    let engine = Engine::new(&dir)?;
    let m = &engine.manifest;
    println!("platform: {}", engine.platform());
    println!("\nsizes:");
    for (name, s) in &m.sizes {
        println!(
            "  {name:<7} ~{} ({:.2}M params, vocab {}, d {}, {} layers, seq {})",
            s.paper_size,
            s.param_count as f64 / 1e6,
            s.vocab,
            s.d_model,
            s.n_layers,
            s.seq_len
        );
        let opts = m.optimizers_for(name);
        println!("          optimizers: {}", opts.join(", "));
    }
    println!("\nartifacts: {} total in {}", m.artifacts.len(), m.dir.display());
    Ok(())
}
