//! Training metrics: loss/perplexity tracking, EMA smoothing, throughput
//! meters, and CSV emission for the figure-generating benches.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub lr: f64,
    pub tokens: u64,
    pub elapsed_s: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct EvalRecord {
    pub step: usize,
    pub loss: f64,
    pub ppl: f64,
}

#[derive(Debug)]
pub struct Metrics {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub ema_loss: Option<f64>,
    ema_alpha: f64,
    start: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            steps: Vec::new(),
            evals: Vec::new(),
            ema_loss: None,
            ema_alpha: 0.05,
            start: Instant::now(),
        }
    }

    pub fn record_step(&mut self, step: usize, loss: f64, lr: f64, tokens: u64) {
        self.ema_loss = Some(match self.ema_loss {
            None => loss,
            Some(e) => (1.0 - self.ema_alpha) * e + self.ema_alpha * loss,
        });
        self.steps.push(StepRecord {
            step,
            loss,
            lr,
            tokens,
            elapsed_s: self.start.elapsed().as_secs_f64(),
        });
    }

    /// Drop every record past `step` and rebuild the EMA by replaying
    /// the retained losses through the exact `record_step` fold, so a
    /// guard rollback leaves metrics bit-identical to a run that never
    /// took the doomed steps. Used by `Trainer::train_guarded`.
    pub fn truncate_to_step(&mut self, step: usize) {
        self.steps.retain(|s| s.step <= step);
        self.evals.retain(|e| e.step <= step);
        let mut ema = None;
        for s in &self.steps {
            ema = Some(match ema {
                None => s.loss,
                Some(e) => (1.0 - self.ema_alpha) * e + self.ema_alpha * s.loss,
            });
        }
        self.ema_loss = ema;
    }

    pub fn record_eval(&mut self, step: usize, loss: f64) {
        self.evals.push(EvalRecord {
            step,
            loss,
            ppl: loss.exp(),
        });
    }

    pub fn final_ppl(&self) -> Option<f64> {
        self.evals.last().map(|e| e.ppl)
    }

    /// Mean training tokens/second over the run.
    pub fn tokens_per_sec(&self) -> f64 {
        match self.steps.last() {
            Some(last) if last.elapsed_s > 0.0 => last.tokens as f64 / last.elapsed_s,
            _ => 0.0,
        }
    }

    /// Smoothed loss curve, `window`-step moving average (the paper
    /// smooths Fig. 4 with a 50-iteration window).
    pub fn smoothed_losses(&self, window: usize) -> Vec<(usize, f64)> {
        let w = window.max(1);
        self.steps
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let lo = i.saturating_sub(w - 1);
                let mean = self.steps[lo..=i].iter().map(|r| r.loss).sum::<f64>()
                    / (i - lo + 1) as f64;
                (s.step, mean)
            })
            .collect()
    }

    pub fn write_csv(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,lr,tokens,elapsed_s")?;
        for s in &self.steps {
            writeln!(f, "{},{},{},{},{}", s.step, s.loss, s.lr, s.tokens, s.elapsed_s)?;
        }
        writeln!(f)?;
        writeln!(f, "eval_step,eval_loss,eval_ppl")?;
        for e in &self.evals {
            writeln!(f, "{},{},{}", e.step, e.loss, e.ppl)?;
        }
        Ok(())
    }
}

/// Render a sparkline-ish ASCII curve for terminal output.
pub fn ascii_curve(points: &[(usize, f64)], width: usize, height: usize) -> String {
    if points.is_empty() {
        return String::new();
    }
    let min = points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let max = points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
    let span = (max - min).max(1e-12);
    let mut grid = vec![vec![b' '; width]; height];
    for (i, &(_, v)) in points.iter().enumerate() {
        let x = i * (width - 1) / (points.len() - 1).max(1);
        let y = ((max - v) / span * (height - 1) as f64).round() as usize;
        grid[y.min(height - 1)][x] = b'*';
    }
    let mut out = String::new();
    out.push_str(&format!("{max:>10.4} ┐\n"));
    for row in grid {
        out.push_str("           │");
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("{min:>10.4} ┴{}\n", "─".repeat(width)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_smooths() {
        let mut m = Metrics::new();
        m.record_step(1, 10.0, 1e-3, 100);
        m.record_step(2, 0.0, 1e-3, 200);
        let e = m.ema_loss.unwrap();
        assert!(e > 5.0 && e < 10.0);
    }

    #[test]
    fn ppl_is_exp_loss() {
        let mut m = Metrics::new();
        m.record_eval(10, 2.0);
        assert!((m.final_ppl().unwrap() - 2.0f64.exp()).abs() < 1e-9);
    }

    #[test]
    fn smoothing_window() {
        let mut m = Metrics::new();
        for i in 1..=10 {
            m.record_step(i, i as f64, 1e-3, 0);
        }
        let s = m.smoothed_losses(5);
        assert_eq!(s.len(), 10);
        assert!((s[9].1 - 8.0).abs() < 1e-9); // mean of 6..=10
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut m = Metrics::new();
        m.record_step(1, 5.0, 1e-3, 128);
        m.record_eval(1, 4.5);
        let dir = std::env::temp_dir().join("scale_metrics_test.csv");
        m.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(text.contains("step,loss") && text.contains("eval_ppl"));
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn truncate_replays_ema_bit_exactly() {
        let losses = [9.3, 7.1, 6.6, 6.2, 5.9, 5.7];
        let mut full = Metrics::new();
        let mut short = Metrics::new();
        for (i, &l) in losses.iter().enumerate() {
            full.record_step(i + 1, l, 1e-3, 64);
            if i < 3 {
                short.record_step(i + 1, l, 1e-3, 64);
            }
        }
        full.record_eval(5, 5.9);
        full.truncate_to_step(3);
        assert_eq!(full.steps.len(), 3);
        assert!(full.evals.is_empty(), "evals past the rollback point must go too");
        assert_eq!(
            full.ema_loss.unwrap().to_bits(),
            short.ema_loss.unwrap().to_bits(),
            "replayed EMA must be bit-identical to never having taken the dropped steps"
        );
        full.truncate_to_step(0);
        assert!(full.steps.is_empty() && full.ema_loss.is_none());
    }

    #[test]
    fn ascii_curve_renders() {
        let pts: Vec<(usize, f64)> = (0..50).map(|i| (i, (50 - i) as f64)).collect();
        let s = ascii_curve(&pts, 40, 8);
        assert!(s.contains('*'));
    }
}
