//! The training coordinator: composes the AOT artifacts into the paper's
//! pretraining loop.
//!
//! Per step:
//!   1. each DDP shard draws its microbatch and runs `fwd_bwd_<size>`
//!      (loss + per-parameter gradients);
//!   2. shard gradients are tree-all-reduced to the global mean;
//!   3. `update_<opt>_<size>` applies one optimizer step
//!      (params, state, grads, lr, step) -> (params', state').
//!
//! Python never runs here; the loop is pure Rust + PJRT executions.

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::ddp;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::schedule::Schedule;
use crate::data::{self, Corpus, Tokenizer};
#[allow(unused_imports)]
use crate::data::Batcher;
use crate::runtime::{Engine, Executable, Tensor};

use std::rc::Rc;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub size: String,
    pub optimizer: String,
    pub steps: usize,
    pub base_lr: f64,
    /// None -> the paper's cosine+warmup over `steps`
    pub schedule: Option<Schedule>,
    /// DDP shards; global batch = shards * manifest.microbatch sequences
    pub shards: usize,
    pub seed: u64,
    /// 0 = evaluate only at the end
    pub eval_every: usize,
    pub eval_batches: usize,
    pub log_every: usize,
    pub quiet: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            size: "s60m".into(),
            optimizer: "scale".into(),
            steps: 100,
            base_lr: 1e-3,
            schedule: None,
            shards: 4,
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            log_every: 20,
            quiet: false,
        }
    }
}

/// Shard id offset reserved for the held-out eval stream.
const EVAL_SHARD: usize = 1 << 20;

/// Native parameter init mirroring model.init_params' scheme (ones for
/// norm gains, N(0, 0.02) embeddings, 1/sqrt(d_in) fan-in matrices).
/// Seeds are independent per parameter; exact agreement with the jax
/// init artifact is not required (both are valid draws of the same
/// scheme), only determinism per (size, seed).
fn native_init(size: &crate::runtime::artifact::SizeInfo, seed: u64) -> Vec<Tensor> {
    use crate::util::rng::Pcg;
    size.params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let n = p.numel();
            let mut rng = Pcg::with_stream(seed.wrapping_add(1), i as u64);
            let data: Vec<f32> = match (p.kind.as_str(), p.name.as_str()) {
                ("vector", _) => vec![1.0; n],
                ("embed", _) | (_, "pos_embed") => {
                    (0..n).map(|_| 0.02 * rng.normal() as f32).collect()
                }
                _ => {
                    let scale = 1.0 / (p.shape[0] as f32).sqrt();
                    (0..n).map(|_| scale * rng.normal() as f32).collect()
                }
            };
            Tensor::from_f32(&p.shape, data)
        })
        .collect()
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub opts: TrainOptions,
    pub schedule: Schedule,
    fwd: Rc<Executable>,
    upd: Rc<Executable>,
    evl: Rc<Executable>,
    pub params: Vec<Tensor>,
    pub state: Vec<Tensor>,
    pub step: usize,
    pub metrics: Metrics,
    corpus: std::sync::Arc<Corpus>,
    tokenizer: std::sync::Arc<Tokenizer>,
    n_params: usize,
    pub seq_len: usize,
    pub microbatch: usize,
    shard_positions: Vec<usize>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, opts: TrainOptions) -> anyhow::Result<Trainer<'e>> {
        let size = engine.manifest.size(&opts.size)?.clone();
        let fwd = engine.load(&format!("fwd_bwd_{}", opts.size))?;
        let upd = engine.load(&format!("update_{}_{}", opts.optimizer, opts.size))?;
        let evl = engine.load(&format!("eval_{}", opts.size))?;

        // init params natively (seeded), zero state from the manifest spec.
        // The init_<size> artifact exists for parity tests, but compiling
        // it costs 8-28s of PJRT time per process — native init removes it
        // from every run (EXPERIMENTS.md §Perf L3-2).
        let params = native_init(&size, opts.seed);
        let state: Vec<Tensor> = engine
            .manifest
            .state_spec(&opts.optimizer, &opts.size)?
            .iter()
            .map(|s| Tensor::zeros(&s.shape))
            .collect();

        let (corpus, tokenizer) = data::pipeline(size.vocab, opts.seed);
        let schedule = opts
            .schedule
            .unwrap_or_else(|| Schedule::paper_default(opts.base_lr, opts.steps));

        Ok(Trainer {
            engine,
            schedule,
            fwd,
            upd,
            evl,
            n_params: params.len(),
            params,
            state,
            step: 0,
            metrics: Metrics::new(),
            corpus,
            tokenizer,
            seq_len: size.seq_len,
            microbatch: engine.manifest.microbatch,
            shard_positions: vec![0; opts.shards.max(1)],
            opts,
        })
    }

    /// Draw the next microbatch for a (possibly virtual) shard id.
    /// Stream position is tracked per shard so the Trainer owns all
    /// mutability (see [`Batcher`] for the standalone pipeline form).
    fn next_batch(&mut self, shard: usize) -> Tensor {
        let b = self.microbatch;
        let w = self.seq_len + 1;
        let need_tokens = b * w;
        // generate enough characters: ~4 chars/token for BPE-compressed text
        let chunk = need_tokens * 8 + 1024;
        let stream_pos = if shard >= EVAL_SHARD {
            self.step // eval batches keyed by current step
        } else {
            self.shard_positions[shard]
        };
        let sub = ((shard as u64) << 24) | stream_pos as u64;
        let text = self.corpus.text(chunk, sub);
        let mut ids: Vec<i32> = self
            .tokenizer
            .encode(&text)
            .into_iter()
            .map(|x| x as i32)
            .collect();
        ids.truncate(need_tokens);
        while ids.len() < need_tokens {
            ids.push(0);
        }
        if shard < EVAL_SHARD {
            self.shard_positions[shard] += 1;
        }
        Tensor::from_i32(&[b, w], ids)
    }

    /// One fwd/bwd on a given batch: (loss, grads).
    pub fn grad_step(&self, batch: &Tensor) -> anyhow::Result<(f64, Vec<Tensor>)> {
        let mut inputs = self.params.clone();
        inputs.push(batch.clone());
        let mut out = self.engine.run_exe(&self.fwd, &inputs)?;
        let loss = out.remove(0).item_f32() as f64;
        Ok((loss, out))
    }

    /// One full coordinated training step (fwd/bwd per shard, all-reduce,
    /// optimizer update). Returns the mean shard loss.
    pub fn train_step(&mut self) -> anyhow::Result<f64> {
        self.step += 1;
        let shards = self.opts.shards.max(1);
        let mut shard_grads = Vec::with_capacity(shards);
        let mut loss_sum = 0.0;
        for s in 0..shards {
            let batch = self.next_batch(s);
            let (loss, grads) = self.grad_step(&batch)?;
            loss_sum += loss;
            shard_grads.push(grads);
        }
        let grads = ddp::tree_all_reduce(shard_grads);
        let lr = self.schedule.lr(self.step);

        let mut inputs =
            Vec::with_capacity(self.n_params + self.state.len() + grads.len() + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.state.iter().cloned());
        inputs.extend(grads);
        inputs.push(Tensor::scalar_f32(lr as f32));
        inputs.push(Tensor::scalar_f32(self.step as f32));
        let mut out = self.engine.run_exe(&self.upd, &inputs)?;
        let rest = out.split_off(self.n_params);
        self.params = out;
        self.state = rest;

        let loss = loss_sum / shards as f64;
        let tokens = (self.step * shards * self.microbatch * self.seq_len) as u64;
        self.metrics.record_step(self.step, loss, lr, tokens);
        Ok(loss)
    }

    /// Evaluate mean loss over `n` held-out batches; records perplexity.
    pub fn eval(&mut self) -> anyhow::Result<f64> {
        let n = self.opts.eval_batches.max(1);
        let mut sum = 0.0;
        for i in 0..n {
            let batch = {
                // held-out stream: shard ids far beyond training shards,
                // keyed by eval batch index (stable across calls)
                let b = self.microbatch;
                let w = self.seq_len + 1;
                let need = b * w;
                let text = self
                    .corpus
                    .text(need * 8 + 1024, ((EVAL_SHARD + i) as u64) << 24);
                let mut ids: Vec<i32> = self
                    .tokenizer
                    .encode(&text)
                    .into_iter()
                    .map(|x| x as i32)
                    .collect();
                ids.truncate(need);
                while ids.len() < need {
                    ids.push(0);
                }
                Tensor::from_i32(&[b, w], ids)
            };
            let mut inputs = self.params.clone();
            inputs.push(batch);
            let out = self.engine.run_exe(&self.evl, &inputs)?;
            sum += out[0].item_f32() as f64;
        }
        let loss = sum / n as f64;
        self.metrics.record_eval(self.step, loss);
        Ok(loss)
    }

    /// Run the full configured training loop; returns final eval ppl.
    pub fn train(&mut self) -> anyhow::Result<f64> {
        for _ in 0..self.opts.steps {
            let loss = self.train_step()?;
            if !self.opts.quiet
                && self.opts.log_every > 0
                && self.step % self.opts.log_every == 0
            {
                println!(
                    "  step {:>5}/{:<5} loss {:.4} (ema {:.4}) lr {:.2e}",
                    self.step,
                    self.opts.steps,
                    loss,
                    self.metrics.ema_loss.unwrap_or(loss),
                    self.schedule.lr(self.step)
                );
            }
            if self.opts.eval_every > 0 && self.step % self.opts.eval_every == 0 {
                let el = self.eval()?;
                if !self.opts.quiet {
                    println!(
                        "  step {:>5} eval loss {:.4} ppl {:.2}",
                        self.step,
                        el,
                        el.exp()
                    );
                }
            }
        }
        let final_loss = self.eval()?;
        Ok(final_loss.exp())
    }

    // ---- checkpointing -----------------------------------------------------

    pub fn checkpoint(&self) -> anyhow::Result<Checkpoint> {
        let m = &self.engine.manifest;
        let size = m.size(&self.opts.size)?;
        let st_spec = m.state_spec(&self.opts.optimizer, &self.opts.size)?;
        let mut tensors = Vec::new();
        for (p, s) in size.params.iter().zip(&self.params) {
            tensors.push((p.name.clone(), s.clone()));
        }
        for (sp, s) in st_spec.iter().zip(&self.state) {
            tensors.push((format!("state:{}", sp.name), s.clone()));
        }
        Ok(Checkpoint {
            size: self.opts.size.clone(),
            optimizer: self.opts.optimizer.clone(),
            step: self.step as u64,
            tensors,
        })
    }

    pub fn restore(&mut self, ckpt: &Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(ckpt.size == self.opts.size, "size mismatch");
        anyhow::ensure!(ckpt.optimizer == self.opts.optimizer, "optimizer mismatch");
        let n = self.n_params;
        anyhow::ensure!(ckpt.tensors.len() == n + self.state.len(), "tensor count");
        self.params = ckpt.tensors[..n].iter().map(|(_, t)| t.clone()).collect();
        self.state = ckpt.tensors[n..].iter().map(|(_, t)| t.clone()).collect();
        self.step = ckpt.step as usize;
        // keep the data streams aligned with the restored step
        for p in self.shard_positions.iter_mut() {
            *p = self.step;
        }
        Ok(())
    }

    /// Measured optimizer-state footprint of this run (f32 bytes).
    pub fn state_bytes(&self) -> usize {
        self.state.iter().map(|t| 4 * t.numel()).sum()
    }

    pub fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }
}
