//! The training coordinator: composes the AOT artifacts into the paper's
//! pretraining loop.
//!
//! Per step:
//!   1. each DDP shard draws its microbatch from a pre-tokenized token
//!      ring (BPE runs once per ring segment, not once per batch) and
//!      runs `fwd_bwd_<size>` (loss + per-parameter gradients) — shards
//!      run concurrently on the persistent worker pool;
//!   2. shard gradients are tree-all-reduced to the global mean in place
//!      (parallel across parameters, bit-stable);
//!   3. `update_<opt>_<size>` applies one optimizer step
//!      (params, state, grads, lr, step) -> (params', state').
//!
//! Python never runs here; the loop is pure Rust — native CPU programs
//! by default, PJRT executions with `--features xla`. The hot path is
//! clone-free, spawn-free, and (steady-state, on the native executor)
//! allocation-free for every tensor buffer: batches, fwd/bwd outputs,
//! and update outputs live in persistent buffers that executables write
//! in place (`Engine::run_exe_refs_into`), the reduce mutates shard 0's
//! gradients directly, and the new params/state are adopted by swapping
//! buffers with the previous step's. Every per-step fan-out (ring
//! refill, shard fwd/bwd, tree reduce, tiled kernels) dispatches onto
//! the [`WorkerPool`] bound at construction — zero thread spawns per
//! step.
//!
//! Trainers are themselves dispatchable: `coordinator::sweep` runs whole
//! trainings as jobs on the same shared pool, with this trainer's
//! per-step fan-outs becoming *nested* batches. Everything that feeds a
//! run's result is owned per trainer (params, state, rings, buffers) or
//! deterministic per `(size, seed)`, which is why concurrent trials are
//! bit-identical to serial ones.
//!
//! Durability: [`Trainer::train_guarded`] wraps the same loop in a
//! divergence guard — non-finite loss/gradients roll the run back to
//! the newest good snapshot in a
//! [`CheckpointStore`](crate::coordinator::checkpoint::CheckpointStore)
//! with LR backoff and a bounded retry budget, and auto-checkpoints
//! land every N steps. Failures are typed
//! ([`TrainError`](crate::coordinator::recovery::TrainError)) so
//! callers classify instead of string-matching.

use crate::coordinator::checkpoint::{Checkpoint, CheckpointStore};
use crate::coordinator::ddp;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::recovery::{GuardPolicy, TrainError};
use crate::coordinator::schedule::Schedule;
use crate::data::{self, Corpus, Tokenizer};
use crate::exec;
use crate::parallel::{self, WorkerPool};
use crate::runtime::{Engine, Executable, Tensor};

use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub size: String,
    pub optimizer: String,
    pub steps: usize,
    pub base_lr: f64,
    /// None -> the paper's cosine+warmup over `steps`
    pub schedule: Option<Schedule>,
    /// DDP shards; global batch = shards * manifest.microbatch sequences
    pub shards: usize,
    pub seed: u64,
    /// 0 = evaluate only at the end
    pub eval_every: usize,
    pub eval_batches: usize,
    pub log_every: usize,
    pub quiet: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            size: "s60m".into(),
            optimizer: "scale".into(),
            steps: 100,
            base_lr: 1e-3,
            schedule: None,
            shards: 4,
            seed: 0,
            eval_every: 0,
            eval_batches: 8,
            log_every: 20,
            quiet: false,
        }
    }
}

/// Shard id of the held-out eval ring — far beyond any training shard
/// index, so the eval byte stream never overlaps a training stream.
const EVAL_SHARD: usize = 1 << 20;

/// Microbatches per token-ring segment: one corpus-chunk generation +
/// BPE encode serves this many batches.
const RING_BATCHES: usize = 8;

/// Pre-tokenized token ring for one DDP shard. Segment content is a pure
/// function of (shard, segment index) — independent of call history — so
/// checkpoint resume reproduces the exact byte stream and the DDP
/// determinism tests stay bit-exact. (The standalone `data::Batcher`
/// remains the pipeline form for external callers.)
#[derive(Debug, Clone)]
struct TokenRing {
    tokens: Vec<i32>,
    /// segment currently cached; `usize::MAX` = empty
    segment: usize,
}

impl TokenRing {
    fn new() -> TokenRing {
        TokenRing {
            tokens: Vec::new(),
            segment: usize::MAX,
        }
    }

    /// Write the `[b, w]` batch at `stream_pos` for `shard` into `out`,
    /// refilling the ring (one corpus chunk + one BPE encode per
    /// RING_BATCHES batches). `out`'s storage is reused in place when it
    /// already has the right dtype and shape — the steady-state
    /// zero-allocation path.
    #[allow(clippy::too_many_arguments)]
    fn batch_into(
        &mut self,
        corpus: &Corpus,
        tokenizer: &Tokenizer,
        shard: usize,
        stream_pos: usize,
        b: usize,
        w: usize,
        out: &mut Tensor,
    ) {
        let need = b * w;
        let seg = stream_pos / RING_BATCHES;
        let seg_tokens = need * RING_BATCHES;
        if self.segment != seg || self.tokens.len() != seg_tokens {
            // generate enough characters: ~4 chars/token for BPE text
            let chunk = seg_tokens * 8 + 1024;
            let sub = ((shard as u64) << 24) | (seg * RING_BATCHES) as u64;
            let text = corpus.text(chunk, sub);
            self.tokens.clear();
            self.tokens
                .extend(tokenizer.encode(&text).into_iter().map(|x| x as i32));
            self.tokens.truncate(seg_tokens);
            while self.tokens.len() < seg_tokens {
                self.tokens.push(0);
            }
            self.segment = seg;
        }
        let off = (stream_pos % RING_BATCHES) * need;
        let src = &self.tokens[off..off + need];
        let fits = match out {
            Tensor::I32 { shape, .. } => shape.len() == 2 && shape[0] == b && shape[1] == w,
            _ => false,
        };
        if fits {
            out.i32s_mut().copy_from_slice(src);
        } else {
            *out = Tensor::from_i32(&[b, w], src.to_vec());
        }
    }
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub opts: TrainOptions,
    pub schedule: Schedule,
    fwd: Arc<Executable>,
    upd: Arc<Executable>,
    evl: Arc<Executable>,
    pub params: Vec<Tensor>,
    pub state: Vec<Tensor>,
    pub step: usize,
    pub metrics: Metrics,
    corpus: Arc<Corpus>,
    tokenizer: Arc<Tokenizer>,
    n_params: usize,
    pub seq_len: usize,
    pub microbatch: usize,
    shard_positions: Vec<usize>,
    rings: Vec<TokenRing>,
    /// Held-out eval stream, pre-tokenized like the training rings.
    eval_ring: TokenRing,
    /// Persistent per-shard token batches, written in place each step.
    batches: Vec<Tensor>,
    /// Persistent per-shard fwd/bwd outputs: `[loss, grads..]` each.
    fwd_outs: Vec<Vec<Tensor>>,
    /// Persistent update outputs `[params'.., state'..]`, swapped with
    /// `params`/`state` after each step (buffer ping-pong, no clones).
    upd_out: Vec<Tensor>,
    /// Reusable lr/step scalar inputs, mutated in place per step.
    lr_t: Tensor,
    step_t: Tensor,
    eval_batch: Tensor,
    eval_out: Vec<Tensor>,
    /// Persistent pool bound at construction (the process-wide shared
    /// pool); every per-step fan-out reuses it — no spawns per step.
    pool: &'static WorkerPool,
    /// Multiplied into every scheduled LR. Stays `1.0` (a bit-exact
    /// identity) until a guard rollback applies `GuardPolicy::lr_backoff`.
    lr_scale: f64,
    /// Full per-step gradient finiteness scan; enabled only inside
    /// `train_guarded` so plain runs pay nothing for the guard.
    guard_checks: bool,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, opts: TrainOptions) -> anyhow::Result<Trainer<'e>> {
        let size = engine.manifest.size(&opts.size)?.clone();
        let fwd = engine.load(&format!("fwd_bwd_{}", opts.size))?;
        let upd = engine.load(&format!("update_{}_{}", opts.optimizer, opts.size))?;
        let evl = engine.load(&format!("eval_{}", opts.size))?;

        // init params natively (seeded), zero state from the manifest spec.
        // The init_<size> artifact exists for parity tests, but compiling
        // it costs 8-28s of PJRT time per process — native init removes it
        // from every run (EXPERIMENTS.md §Perf L3-2).
        let params = exec::native_init(&size, opts.seed);
        let state: Vec<Tensor> = engine
            .manifest
            .state_spec(&opts.optimizer, &opts.size)?
            .iter()
            .map(|s| Tensor::zeros(&s.shape))
            .collect();

        let (corpus, tokenizer) = data::pipeline(size.vocab, opts.seed);
        let schedule = opts
            .schedule
            .unwrap_or_else(|| Schedule::paper_default(opts.base_lr, opts.steps));
        let shards = opts.shards.max(1);
        let mb = engine.manifest.microbatch;
        let w = size.seq_len + 1;
        let batches: Vec<Tensor> = (0..shards)
            .map(|_| Tensor::from_i32(&[mb, w], vec![0; mb * w]))
            .collect();
        let mut metrics = Metrics::new();
        // pre-size the history so steady-state steps never regrow it
        metrics.steps.reserve(opts.steps + 1);

        Ok(Trainer {
            engine,
            schedule,
            fwd,
            upd,
            evl,
            n_params: params.len(),
            params,
            state,
            step: 0,
            metrics,
            corpus,
            tokenizer,
            seq_len: size.seq_len,
            microbatch: mb,
            shard_positions: vec![0; shards],
            rings: (0..shards).map(|_| TokenRing::new()).collect(),
            eval_ring: TokenRing::new(),
            batches,
            fwd_outs: vec![Vec::new(); shards],
            upd_out: Vec::new(),
            lr_t: Tensor::scalar_f32(0.0),
            step_t: Tensor::scalar_f32(0.0),
            eval_batch: Tensor::from_i32(&[mb, w], vec![0; mb * w]),
            eval_out: Vec::new(),
            pool: parallel::shared(),
            lr_scale: 1.0,
            guard_checks: false,
            opts,
        })
    }

    /// One fwd/bwd on a given batch: (loss, grads). Inputs are assembled
    /// by reference — parameters are never cloned. This is the one-shot
    /// probe/figure entry point; the training loop itself reuses
    /// persistent output buffers instead.
    pub fn grad_step(&self, batch: &Tensor) -> anyhow::Result<(f64, Vec<Tensor>)> {
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(self.n_params + 1);
        inputs.extend(self.params.iter());
        inputs.push(batch);
        let mut out = self.engine.run_exe_refs(&self.fwd, &inputs)?;
        let loss = out.remove(0).item_f32() as f64;
        Ok((loss, out))
    }

    /// One full coordinated training step (concurrent fwd/bwd per shard,
    /// in-place parallel all-reduce, optimizer update). Returns the mean
    /// shard loss. Steady-state steps reuse every tensor buffer: the
    /// executables write into persistent outputs and the new
    /// params/state are adopted by swap.
    ///
    /// A non-finite mean loss (and, in guarded runs, any non-finite
    /// gradient) aborts the step *before* the optimizer update or the
    /// metrics record, returning [`TrainError::Divergence`] — params,
    /// state, and the EMA stay at their last healthy values, which is
    /// what makes rollback bit-exact. Engine failures surface as
    /// [`TrainError::Engine`].
    pub fn train_step(&mut self) -> Result<f64, TrainError> {
        self.begin_step();
        self.local_shard_outputs()?;
        self.finish_step()
    }

    /// Advance the step counter (and sanity-check the shard layout) —
    /// the head of [`Trainer::train_step`]. The mesh supervisor calls it
    /// before broadcasting the new step to remote ranks.
    pub(crate) fn begin_step(&mut self) {
        self.step += 1;
        // shard count is fixed at construction (rings + stream positions
        // are sized then); opts.shards is pub, so don't silently trust a
        // post-construction mutation
        debug_assert_eq!(
            self.rings.len(),
            self.opts.shards.max(1),
            "opts.shards changed after new()"
        );
    }

    /// Sections 1+2 of the step: per-shard microbatches and concurrent
    /// fwd/bwd into the persistent `fwd_outs` buffers. In a mesh run
    /// each remote rank computes its shard via [`Trainer::shard_forward`]
    /// and the supervisor installs the gathered results instead.
    fn local_shard_outputs(&mut self) -> Result<(), TrainError> {
        let shards = self.rings.len();
        let pool = self.pool;

        // 1) per-shard microbatches into the persistent batch tensors.
        //    The pool is engaged only when a ring actually needs a refill
        //    (the BPE-encode leg); warm steps — RING_BATCHES-1 of every
        //    RING_BATCHES — are in-place slice copies
        {
            let corpus = &self.corpus;
            let tokenizer = &self.tokenizer;
            let positions = &self.shard_positions;
            let rings = &mut self.rings;
            let batches = &mut self.batches;
            let (b, w) = (self.microbatch, self.seq_len + 1);
            let any_refill = rings
                .iter()
                .zip(positions.iter())
                .any(|(r, &pos)| r.segment != pos / RING_BATCHES);
            if shards > 1 && any_refill {
                let tasks: Vec<_> = rings
                    .iter_mut()
                    .zip(batches.iter_mut())
                    .enumerate()
                    .map(|(s, (ring, out))| {
                        let pos = positions[s];
                        move || ring.batch_into(corpus, tokenizer, s, pos, b, w, out)
                    })
                    .collect();
                pool.run(tasks);
            } else {
                for (s, (ring, out)) in rings.iter_mut().zip(batches.iter_mut()).enumerate() {
                    ring.batch_into(corpus, tokenizer, s, positions[s], b, w, out);
                }
            }
        }
        for pos in self.shard_positions.iter_mut() {
            *pos += 1;
        }

        // 2) concurrent fwd/bwd per shard on the pool; `run` returns
        //    results in shard order so the downstream reduction is
        //    bit-stable across runs. Outputs land in persistent buffers.
        {
            let engine = self.engine;
            let fwd = &self.fwd;
            let params = &self.params;
            let n_params = self.n_params;
            let batches = &self.batches;
            let outs = &mut self.fwd_outs;
            let results: Vec<anyhow::Result<()>> = if shards > 1 {
                let tasks: Vec<_> = outs
                    .iter_mut()
                    .zip(batches.iter())
                    .map(|(out, batch)| {
                        move || {
                            let mut inputs: Vec<&Tensor> = Vec::with_capacity(n_params + 1);
                            inputs.extend(params.iter());
                            inputs.push(batch);
                            engine.run_exe_refs_into(fwd, &inputs, out)
                        }
                    })
                    .collect();
                pool.run(tasks)
            } else {
                let mut inputs: Vec<&Tensor> = Vec::with_capacity(n_params + 1);
                inputs.extend(params.iter());
                inputs.push(&batches[0]);
                vec![engine.run_exe_refs_into(fwd, &inputs, &mut outs[0])]
            };
            for r in results {
                r?;
            }
        }
        Ok(())
    }

    /// The shard-independent tail of the step: mean loss, tree
    /// all-reduce, divergence guard, optimizer update, metrics record.
    /// Requires every `fwd_outs[s]` to hold a fresh `[loss, grads..]` —
    /// produced locally by [`Trainer::train_step`] or gathered from
    /// remote ranks by the mesh supervisor. The loss sum reads each
    /// shard's slot 0 *before* the reduce in shard order, exactly the
    /// sequence the fused path used (the reduce skips index 0, so the
    /// summed values are identical).
    pub(crate) fn finish_step(&mut self) -> Result<f64, TrainError> {
        let loss = self.reduce_and_guard()?;
        self.apply_update()?;
        self.record_step(loss);
        Ok(loss)
    }

    /// Sections 3a of the step: mean loss, tree all-reduce, divergence
    /// guard — everything `finish_step` does *before* the optimizer
    /// update. After it returns the reduced mean gradients sit in
    /// [`Trainer::reduced_grads`]. The sharded mesh mode calls this,
    /// ships each rank its gradient slice, and installs the returned
    /// param shards in place of [`Trainer::apply_update`].
    pub(crate) fn reduce_and_guard(&mut self) -> Result<f64, TrainError> {
        let shards = self.rings.len();
        let pool = self.pool;
        let mut loss_sum = 0.0;
        for out in self.fwd_outs.iter() {
            loss_sum += out[0].item_f32() as f64;
        }

        // 3) in-place parallel tree all-reduce across the shard outputs
        //    (index 0 of each is the loss scalar — skipped); the mean
        //    gradients land in fwd_outs[0][1..]
        ddp::tree_all_reduce_into(pool, &mut self.fwd_outs, 1);

        // deterministic fault injection (chaos suite / --faults): poison
        // the reduced gradients exactly where a real overflow would land.
        // One relaxed atomic load when no failpoint spec is installed.
        if crate::fault::fires("grad_nan") {
            for g in self.fwd_outs[0][1..].iter_mut() {
                g.f32s_mut().fill(f32::NAN);
            }
        }

        // divergence guard: bail before the update and before the
        // metrics record, so a doomed step leaves no trace to roll back
        let loss = loss_sum / shards as f64;
        if !loss.is_finite() {
            return Err(TrainError::divergence(self.step, "non-finite loss"));
        }
        if self.guard_checks {
            let finite = self.fwd_outs[0][1..]
                .iter()
                .all(|g| g.f32s().iter().all(|x| x.is_finite()));
            if !finite {
                return Err(TrainError::divergence(self.step, "non-finite gradient"));
            }
        }
        Ok(loss)
    }

    /// Section 4 of the step: optimizer update with borrowed inputs into
    /// the persistent update buffers; outputs become the new params/state
    /// by swap.
    fn apply_update(&mut self) -> Result<(), TrainError> {
        let lr = self.schedule.lr(self.step) * self.lr_scale;
        self.lr_t.f32s_mut()[0] = lr as f32;
        self.step_t.f32s_mut()[0] = self.step as f32;
        {
            let engine = self.engine;
            let upd = &self.upd;
            let params = &self.params;
            let state = &self.state;
            let grads = &self.fwd_outs[0][1..];
            let n = params.len() + state.len() + grads.len() + 2;
            let mut inputs: Vec<&Tensor> = Vec::with_capacity(n);
            inputs.extend(params.iter());
            inputs.extend(state.iter());
            inputs.extend(grads.iter());
            inputs.push(&self.lr_t);
            inputs.push(&self.step_t);
            engine.run_exe_refs_into(upd, &inputs, &mut self.upd_out)?;
        }
        for i in 0..self.n_params {
            std::mem::swap(&mut self.params[i], &mut self.upd_out[i]);
        }
        for j in 0..self.state.len() {
            std::mem::swap(&mut self.state[j], &mut self.upd_out[self.n_params + j]);
        }
        Ok(())
    }

    /// The metrics tail of the step, shared by `finish_step` and the
    /// sharded mesh path. The recorded lr recomputes the exact value
    /// `apply_update` used (`schedule.lr` is a pure function).
    pub(crate) fn record_step(&mut self, loss: f64) {
        let shards = self.rings.len();
        let lr = self.schedule.lr(self.step) * self.lr_scale;
        let tokens = (self.step * shards * self.microbatch * self.seq_len) as u64;
        self.metrics.record_step(self.step, loss, lr, tokens);
    }

    /// The f32 learning-rate bits the update kernels receive this step —
    /// the sharded mesh ships exactly these bits to the shard-owning
    /// ranks so their kernels see what a single-process step would.
    pub(crate) fn step_lr_f32(&self) -> f32 {
        (self.schedule.lr(self.step) * self.lr_scale) as f32
    }

    /// The reduced mean gradients (valid after
    /// [`Trainer::reduce_and_guard`]).
    pub(crate) fn reduced_grads(&self) -> &[Tensor] {
        &self.fwd_outs[0][1..]
    }

    /// Evaluate mean loss over `n` held-out batches; records perplexity.
    ///
    /// Eval batches come from the pre-tokenized `eval_ring` (shard id
    /// `EVAL_SHARD`, far beyond any training shard, so the streams are
    /// disjoint): one corpus chunk + one BPE encode serves `RING_BATCHES`
    /// eval batches, and the segment stays cached across eval calls.
    /// Ring content is a pure function of the batch index — independent
    /// of call history — so the held-out set is identical every eval and
    /// checkpoint resume stays bit-exact.
    pub fn eval(&mut self) -> anyhow::Result<f64> {
        let n = self.opts.eval_batches.max(1);
        let (b, w) = (self.microbatch, self.seq_len + 1);
        let mut sum = 0.0;
        for i in 0..n {
            {
                let ring = &mut self.eval_ring;
                let out = &mut self.eval_batch;
                ring.batch_into(&self.corpus, &self.tokenizer, EVAL_SHARD, i, b, w, out);
            }
            let mut inputs: Vec<&Tensor> = Vec::with_capacity(self.n_params + 1);
            inputs.extend(self.params.iter());
            inputs.push(&self.eval_batch);
            self.engine
                .run_exe_refs_into(&self.evl, &inputs, &mut self.eval_out)?;
            sum += self.eval_out[0].item_f32() as f64;
        }
        let loss = sum / n as f64;
        self.metrics.record_eval(self.step, loss);
        Ok(loss)
    }

    /// One-off `[b, seq_len+1]` token batch from a dedicated corpus
    /// stream `sub` — the probe/figure entry point (`analysis::variance`,
    /// the figure regenerators). Content is a pure function of
    /// `(b, sub)`; the training and eval paths use the token rings
    /// instead.
    pub fn encode_batch(&self, b: usize, sub: u64) -> Tensor {
        let w = self.seq_len + 1;
        let need = b * w;
        let text = self.corpus.text(need * 8 + 1024, sub);
        let mut ids: Vec<i32> = self
            .tokenizer
            .encode(&text)
            .into_iter()
            .map(|x| x as i32)
            .collect();
        ids.truncate(need);
        while ids.len() < need {
            ids.push(0);
        }
        Tensor::from_i32(&[b, w], ids)
    }

    /// Compute one shard's `[loss, grads..]` for an explicit stream
    /// position into `fwd_outs[shard]` — the mesh worker's unit of work
    /// (rank r computes shard r at stream position `step - 1`). Does not
    /// advance the trainer's own stream positions: in a mesh run the
    /// position is dictated by the coordinator's step counter, which is
    /// what lets a respawned worker resume bit-exactly mid-run.
    pub(crate) fn shard_forward(
        &mut self,
        shard: usize,
        stream_pos: usize,
    ) -> anyhow::Result<&[Tensor]> {
        anyhow::ensure!(shard < self.rings.len(), "shard {shard} out of range");
        let (b, w) = (self.microbatch, self.seq_len + 1);
        {
            let ring = &mut self.rings[shard];
            let out = &mut self.batches[shard];
            ring.batch_into(&self.corpus, &self.tokenizer, shard, stream_pos, b, w, out);
        }
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(self.n_params + 1);
        inputs.extend(self.params.iter());
        inputs.push(&self.batches[shard]);
        self.engine
            .run_exe_refs_into(&self.fwd, &inputs, &mut self.fwd_outs[shard])?;
        Ok(&self.fwd_outs[shard])
    }

    /// A shard's most recent `[loss, grads..]` output buffer.
    pub(crate) fn shard_out(&self, shard: usize) -> &[Tensor] {
        &self.fwd_outs[shard]
    }

    /// Mutable access to a shard's output slot — the mesh supervisor
    /// installs gathered remote results here before `finish_step`.
    pub(crate) fn shard_out_mut(&mut self, shard: usize) -> &mut Vec<Tensor> {
        &mut self.fwd_outs[shard]
    }

    /// Number of parameter tensors (a fwd/bwd output is 1 + this).
    pub(crate) fn n_params(&self) -> usize {
        self.n_params
    }

    /// Per-step logging + periodic-eval cadence shared by `train`,
    /// `train_guarded`, and the mesh supervisor.
    pub(crate) fn after_step(&mut self, loss: f64) -> Result<(), TrainError> {
        if !self.opts.quiet
            && self.opts.log_every > 0
            && self.step % self.opts.log_every == 0
        {
            println!(
                "  step {:>5}/{:<5} loss {:.4} (ema {:.4}) lr {:.2e}",
                self.step,
                self.opts.steps,
                loss,
                self.metrics.ema_loss.unwrap_or(loss),
                self.schedule.lr(self.step) * self.lr_scale
            );
        }
        if self.opts.eval_every > 0 && self.step % self.opts.eval_every == 0 {
            let el = self.eval().map_err(TrainError::engine)?;
            if !self.opts.quiet {
                println!(
                    "  step {:>5} eval loss {:.4} ppl {:.2}",
                    self.step,
                    el,
                    el.exp()
                );
            }
        }
        Ok(())
    }

    /// Run the configured training loop up to `opts.steps` *total*
    /// steps (a restored trainer trains only the remainder); returns
    /// final eval ppl. Divergence aborts the run — use
    /// [`Trainer::train_guarded`] for rollback-and-retry.
    pub fn train(&mut self) -> Result<f64, TrainError> {
        while self.step < self.opts.steps {
            let loss = self.train_step()?;
            self.after_step(loss)?;
        }
        let final_loss = self.eval().map_err(TrainError::engine)?;
        Ok(final_loss.exp())
    }

    /// [`Trainer::train`] under a durability [`GuardPolicy`]: snapshots
    /// step 0 as a rollback baseline, auto-checkpoints every
    /// `checkpoint_every` steps into the policy's
    /// [`CheckpointStore`], and on divergence restores the newest good
    /// snapshot, rewinds metrics bit-exactly, scales the LR by
    /// `lr_backoff`, and replays — up to `max_retries` rollbacks for
    /// the whole run. Non-divergence errors (Io, Engine) propagate
    /// immediately; retrying those is the sweep layer's call, not the
    /// trainer's.
    ///
    /// With `lr_backoff = 1.0` a rollback replay is bit-identical to a
    /// run that never diverged — checkpoint round-trips are exact, ring
    /// segments are pure functions of the stream position, and the EMA
    /// rewind replays the recorded fold. The chaos suite pins that.
    pub fn train_guarded(&mut self, policy: &GuardPolicy) -> Result<f64, TrainError> {
        policy.validate().map_err(TrainError::engine)?;
        let store =
            CheckpointStore::open(&policy.dir, policy.keep_last).map_err(TrainError::io)?;
        if self.step == 0 {
            let ck = self.checkpoint().map_err(TrainError::engine)?;
            store.save(&ck).map_err(TrainError::io)?;
        }
        self.guard_checks = true;
        let out = self.run_guarded(policy, &store);
        self.guard_checks = false;
        out
    }

    fn run_guarded(
        &mut self,
        policy: &GuardPolicy,
        store: &CheckpointStore,
    ) -> Result<f64, TrainError> {
        let mut retries_left = policy.max_retries;
        while self.step < self.opts.steps {
            match self.train_step() {
                Ok(loss) => {
                    self.after_step(loss)?;
                    if self.step % policy.checkpoint_every == 0 {
                        let ck = self.checkpoint().map_err(TrainError::engine)?;
                        store.save(&ck).map_err(TrainError::io)?;
                    }
                }
                Err(e @ TrainError::Divergence { .. }) => {
                    if retries_left == 0 {
                        return Err(e);
                    }
                    retries_left -= 1;
                    let bad_step = self.step;
                    let (_, ck) = store
                        .latest()
                        .map_err(TrainError::io)?
                        .ok_or_else(|| {
                            TrainError::io(anyhow::anyhow!("no snapshot to roll back to"))
                        })?;
                    self.restore(&ck).map_err(TrainError::engine)?;
                    self.metrics.truncate_to_step(self.step);
                    self.lr_scale *= policy.lr_backoff;
                    if !self.opts.quiet {
                        println!(
                            "  {e}; rolled back {bad_step} -> {} (lr scale {:.3}, {} retr{} left)",
                            self.step,
                            self.lr_scale,
                            retries_left,
                            if retries_left == 1 { "y" } else { "ies" }
                        );
                    }
                }
                Err(e) => return Err(e),
            }
        }
        let final_loss = self.eval().map_err(TrainError::engine)?;
        Ok(final_loss.exp())
    }

    /// Current LR multiplier: 1.0 until a guard rollback backs it off.
    pub fn lr_scale(&self) -> f64 {
        self.lr_scale
    }

    // ---- checkpointing -----------------------------------------------------

    pub fn checkpoint(&self) -> anyhow::Result<Checkpoint> {
        let m = &self.engine.manifest;
        let size = m.size(&self.opts.size)?;
        let st_spec = m.state_spec(&self.opts.optimizer, &self.opts.size)?;
        let mut tensors = Vec::new();
        for (p, s) in size.params.iter().zip(&self.params) {
            tensors.push((p.name.clone(), s.clone()));
        }
        for (sp, s) in st_spec.iter().zip(&self.state) {
            tensors.push((format!("state:{}", sp.name), s.clone()));
        }
        Ok(Checkpoint {
            size: self.opts.size.clone(),
            optimizer: self.opts.optimizer.clone(),
            step: self.step as u64,
            tensors,
        })
    }

    pub fn restore(&mut self, ckpt: &Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(ckpt.size == self.opts.size, "size mismatch");
        anyhow::ensure!(ckpt.optimizer == self.opts.optimizer, "optimizer mismatch");
        let n = self.n_params;
        anyhow::ensure!(ckpt.tensors.len() == n + self.state.len(), "tensor count");
        self.params = ckpt.tensors[..n].iter().map(|(_, t)| t.clone()).collect();
        self.state = ckpt.tensors[n..].iter().map(|(_, t)| t.clone()).collect();
        self.step = ckpt.step as usize;
        // keep the data streams aligned with the restored step; ring
        // segments are pure functions of the stream position, so no
        // invalidation is needed beyond the position itself
        for p in self.shard_positions.iter_mut() {
            *p = self.step;
        }
        Ok(())
    }

    /// Measured optimizer-state footprint of this run, sized by each
    /// buffer's actual dtype.
    pub fn state_bytes(&self) -> usize {
        self.state.iter().map(|t| t.dtype().bytes() * t.numel()).sum()
    }
}
