//! L3 coordinator: the training runtime that composes AOT artifacts into
//! the paper's pretraining pipeline — schedules, DDP reduction, metrics,
//! checkpoints, sweeps.

pub mod checkpoint;
pub mod ddp;
pub mod metrics;
pub mod schedule;
pub mod sweep;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use schedule::Schedule;
pub use trainer::{TrainOptions, Trainer};
