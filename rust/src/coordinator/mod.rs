//! L3 coordinator: the training runtime that composes executable
//! artifacts into the paper's pretraining pipeline — schedules, DDP
//! reduction, metrics, checkpoints, sweeps.
//!
//! # Step anatomy
//!
//! [`Trainer::train_step`] drives one data-parallel step entirely
//! through borrowed buffers: per-shard batches come out of pre-tokenized
//! `TokenRing`s (`trainer`), shard `fwd_bwd` executions fan out on the
//! shared [`crate::parallel::WorkerPool`] bound at construction, shard
//! gradients are tree-reduced in place ([`ddp::tree_all_reduce_into`],
//! bit-identical to the sequential reference), and the optimizer update
//! executable writes into persistent output tensors
//! (`Engine::run_exe_refs_into`), whose buffers are adopted back by
//! swap. Learning rates come from [`Schedule`] (the paper's warmup +
//! cosine/linear variants).
//!
//! # Steady-state contract
//!
//! After the warm-up step, the loop neither allocates on the executor
//! hot path nor spawns threads: arenas, rings, metrics history, and
//! output tensors are all sized up front and reused. Both halves are
//! enforced as deterministic gates in `benches/bench_throughput.rs`
//! (allocation counter + spawn counter), which CI runs.
//!
//! # Durability and experiments
//!
//! [`Checkpoint`] serializes params/state/ring positions so resume is
//! bit-exact (integration-tested); `metrics` records loss/throughput
//! series for the harness tables; `sweep` fans whole trials out as jobs
//! on the same shared pool ([`SweepSpec`]: optimizer × LR × seed grids),
//! slotted by trial index so the concurrent result vector is
//! bit-identical to the serial loop for every pool size.
//!
//! The same step anatomy also runs *across processes*: [`crate::mesh`]
//! splits `train_step` at the trainer's `begin_step` /
//! `finish_step` seams, farming the per-shard forward/backward out to
//! worker ranks over a CRC-framed wire while this module's reduction
//! and update tail stay on the coordinator — which is why mesh runs are
//! bit-identical to single-process ones, rank failures included.

pub mod checkpoint;
pub mod ddp;
pub mod metrics;
pub mod recovery;
pub mod schedule;
pub mod sweep;
pub mod trainer;

pub use checkpoint::{Checkpoint, CheckpointStore};
pub use recovery::{GuardPolicy, TrainError};
pub use schedule::Schedule;
pub use sweep::{
    CellStats, OptimizerVerdict, SweepPoint, SweepSpec, TrialOutcome, Verdict, VerdictSpec,
};
pub use trainer::{TrainOptions, Trainer};
