//! Learning-rate schedules. The paper (App. C) uses cosine decay with
//! linear warmup over the first 10% of iterations; constant and linear
//! variants exist for ablations and the LR-sensitivity sweep.

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// Linear warmup to `base` over `warmup` steps, then cosine decay to
    /// `base * min_ratio` at `total` steps.
    CosineWarmup {
        base: f64,
        warmup: usize,
        total: usize,
        min_ratio: f64,
    },
    Constant { base: f64 },
    /// Linear warmup then linear decay to zero.
    LinearWarmup { base: f64, warmup: usize, total: usize },
}

impl Schedule {
    /// The paper's default: 10% warmup, cosine to 10% of peak.
    pub fn paper_default(base: f64, total: usize) -> Schedule {
        Schedule::CosineWarmup {
            base,
            warmup: (total / 10).max(1),
            total,
            min_ratio: 0.1,
        }
    }

    /// LR at 1-based step `t`.
    pub fn lr(&self, t: usize) -> f64 {
        match *self {
            Schedule::Constant { base } => base,
            Schedule::CosineWarmup {
                base,
                warmup,
                total,
                min_ratio,
            } => {
                if t <= warmup {
                    base * t as f64 / warmup as f64
                } else {
                    let p = (t - warmup) as f64 / (total - warmup).max(1) as f64;
                    let p = p.min(1.0);
                    let cos = 0.5 * (1.0 + (std::f64::consts::PI * p).cos());
                    base * (min_ratio + (1.0 - min_ratio) * cos)
                }
            }
            Schedule::LinearWarmup { base, warmup, total } => {
                if t <= warmup {
                    base * t as f64 / warmup as f64
                } else {
                    let p = (t - warmup) as f64 / (total - warmup).max(1) as f64;
                    base * (1.0 - p.min(1.0))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, ensure};

    #[test]
    fn warmup_reaches_base() {
        let s = Schedule::paper_default(1e-3, 100);
        assert!((s.lr(10) - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn cosine_ends_at_min_ratio() {
        let s = Schedule::paper_default(1e-3, 100);
        assert!((s.lr(100) - 1e-4).abs() < 1e-6);
    }

    #[test]
    fn bounds_and_shape_property() {
        prop::quick("schedule-bounds", |rng| {
            let total = prop::usize_in(rng, 10, 5000);
            let base = prop::f32_in(rng, 1e-5, 1.0) as f64;
            let s = Schedule::paper_default(base, total);
            for t in 1..=total {
                let lr = s.lr(t);
                ensure(lr > 0.0 && lr <= base * (1.0 + 1e-9), format!("lr {lr} at {t}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn monotone_decay_after_warmup() {
        prop::quick("schedule-monotone-decay", |rng| {
            let total = prop::usize_in(rng, 50, 2000);
            let s = Schedule::paper_default(1e-3, total);
            let warmup = total / 10;
            let mut prev = f64::INFINITY;
            for t in (warmup + 1)..=total {
                let lr = s.lr(t);
                ensure(lr <= prev + 1e-15, format!("not decaying at {t}"))?;
                prev = lr;
            }
            Ok(())
        });
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { base: 0.5 };
        assert_eq!(s.lr(1), 0.5);
        assert_eq!(s.lr(10_000), 0.5);
    }

    #[test]
    fn linear_decays_to_zero() {
        let s = Schedule::LinearWarmup { base: 1.0, warmup: 10, total: 110 };
        assert!(s.lr(110) < 1e-9);
        assert!((s.lr(60) - 0.5).abs() < 1e-9);
    }
}
