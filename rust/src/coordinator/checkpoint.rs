//! Binary checkpointing: params + optimizer state + run position.
//!
//! Format (little-endian):
//!   magic "SCLK" | u32 version | str size | str optimizer | u64 step |
//!   u32 n_tensors | n x ( str name | u32 ndims | u64 dims... | f32 data... )
//!
//! Strings are u32-length-prefixed UTF-8. Resume must be bit-exact: the
//! integration suite checks train(2k) == train(k) + resume(k).

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::Tensor;

const MAGIC: &[u8; 4] = b"SCLK";
const VERSION: u32 = 1;

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub size: String,
    pub optimizer: String,
    pub step: u64,
    /// params then state, in manifest order, with names
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        write_str(&mut w, &self.size)?;
        write_str(&mut w, &self.optimizer)?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            write_str(&mut w, name)?;
            let shape = t.shape();
            w.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in t.f32s() {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Checkpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a SCALE checkpoint");
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let size = read_str(&mut r)?;
        let optimizer = read_str(&mut r)?;
        let mut step8 = [0u8; 8];
        r.read_exact(&mut step8)?;
        let step = u64::from_le_bytes(step8);
        let n = read_u32(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name = read_str(&mut r)?;
            let ndims = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                let mut d8 = [0u8; 8];
                r.read_exact(&mut d8)?;
                shape.push(u64::from_le_bytes(d8) as usize);
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0f32; numel];
            let mut buf = vec![0u8; numel * 4];
            r.read_exact(&mut buf)?;
            for (i, c) in buf.chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
            tensors.push((name, Tensor::from_f32(&shape, data)));
        }
        Ok(Checkpoint {
            size,
            optimizer,
            step,
            tensors,
        })
    }
}

fn write_str<W: Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn read_u32<R: Read>(r: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_str<R: Read>(r: &mut R) -> anyhow::Result<String> {
    let len = read_u32(r)? as usize;
    anyhow::ensure!(len < 1 << 20, "absurd string length {len}");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("scale_ckpt_{name}_{}", std::process::id()))
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            size: "s60m".into(),
            optimizer: "scale".into(),
            step: 123,
            tensors: vec![
                ("embed".into(), Tensor::from_f32(&[2, 3], vec![1., -2., 3., 4., 5., 6.5])),
                ("lm_head.m".into(), Tensor::from_f32(&[3], vec![0.1, 0.2, 0.3])),
                ("scalar".into(), Tensor::from_f32(&[], vec![9.0])),
            ],
        }
    }

    #[test]
    fn roundtrip_exact() {
        let p = tmp("rt");
        let c = sample();
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.size, c.size);
        assert_eq!(back.optimizer, c.optimizer);
        assert_eq!(back.step, c.step);
        assert_eq!(back.tensors.len(), c.tensors.len());
        for ((an, at), (bn, bt)) in c.tensors.iter().zip(&back.tensors) {
            assert_eq!(an, bn);
            assert_eq!(at, bt);
        }
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let p = tmp("trunc");
        sample().save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }
}
