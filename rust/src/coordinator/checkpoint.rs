//! Binary checkpointing: params + optimizer state + run position, with
//! durability guarantees (see docs/ARCHITECTURE.md "Durability & fault
//! model").
//!
//! Format v2 (little-endian):
//!   magic "SCLK" | u32 version=2
//!   | [ str size | str optimizer | u64 step | u32 n_tensors ] u32 crc
//!   | n x ( [ str name | u32 ndims | u64 dims... | f32 data... ] u32 crc )
//! Each bracketed region is followed by its own CRC-32 (ISO-HDLC), so a
//! torn write or bit rot is caught at load time instead of resuming
//! from garbage. Strings are u32-length-prefixed UTF-8.
//!
//! Saves are atomic: the bytes go to `<path>.tmp`, are fsynced, and are
//! renamed over `<path>` only once complete — a crash mid-save can tear
//! the `.tmp` but never an existing snapshot. Version 1 (no CRCs, no
//! atomic write) is still loadable; [`Checkpoint::save_v1`] keeps the
//! legacy writer around so that compatibility stays testable.
//!
//! The loader is hardened against hostile or corrupt headers: tensor
//! count, rank, and dims are bounded, and every payload is validated
//! against the bytes actually left in the file *before* any allocation.
//!
//! [`CheckpointStore`] manages a run directory of `step_XXXXXXXX.ckpt`
//! snapshots: keep-last-k retention, stale-`.tmp` cleanup, and
//! quarantine-with-fallback on corrupt snapshots. Resume must be
//! bit-exact: the integration suite checks train(2k) == train(k) +
//! resume(k), and the chaos suite (rust/tests/chaos.rs) checks the
//! same across injected crashes.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::runtime::Tensor;
use crate::util::crc::Crc32;

const MAGIC: &[u8; 4] = b"SCLK";
const VERSION: u32 = 2;

/// Hostile-header bounds: no real snapshot comes near these, and they
/// keep a corrupt length field from driving a multi-GB allocation.
const MAX_TENSORS: usize = 1 << 20;
const MAX_DIMS: usize = 8;
const MAX_DIM: u64 = 1 << 31;

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub size: String,
    pub optimizer: String,
    pub step: u64,
    /// params then state, in manifest order, with names
    pub tensors: Vec<(String, Tensor)>,
}

impl Checkpoint {
    /// Atomic v2 save: write `<path>.tmp`, fsync, rename over `path`.
    /// On error the torn `.tmp` is intentionally left behind (exactly
    /// what a crash would leave) and `path` is never touched;
    /// [`CheckpointStore`] sweeps stale `.tmp` files on open and after
    /// every successful save.
    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        if crate::fault::fires("save_io") {
            return Err(io_fault("failpoint save_io"));
        }
        let tmp = tmp_path(path);
        self.write_v2(&tmp)?;
        std::fs::rename(&tmp, path)?;
        sync_dir(path);
        Ok(())
    }

    fn write_v2(&self, tmp: &Path) -> anyhow::Result<()> {
        let refs: Vec<(&str, &Tensor)> =
            self.tensors.iter().map(|(n, t)| (n.as_str(), t)).collect();
        write_v2_file(tmp, &self.size, &self.optimizer, self.step, &refs)
    }

    /// Legacy v1 writer — direct, no CRCs, no atomic rename. Kept only
    /// so the v1 -> v2-loader compatibility path stays testable.
    pub fn save_v1(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&1u32.to_le_bytes())?;
        write_str(&mut w, &self.size)?;
        write_str(&mut w, &self.optimizer)?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, t) in &self.tensors {
            write_str(&mut w, name)?;
            let shape = t.shape();
            w.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in t.f32s() {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Checkpoint> {
        if crate::fault::fires("load_io") {
            return Err(io_fault("failpoint load_io"));
        }
        let file = std::fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        let mut r = Counted::new(std::io::BufReader::new(file));
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a SCALE checkpoint");
        let version = read_u32(&mut r)?;
        match version {
            1 => load_body_v1(&mut r, file_len),
            2 => load_body_v2(&mut r, file_len),
            v => anyhow::bail!("unsupported checkpoint version {v}"),
        }
    }
}

/// The v2 byte emitter behind both [`Checkpoint::save`] and the sharded
/// writer — borrowed tensors, so shard files are written straight from
/// the full checkpoint's slices without cloning.
fn write_v2_file(
    tmp: &Path,
    size: &str,
    optimizer: &str,
    step: u64,
    tensors: &[(&str, &Tensor)],
) -> anyhow::Result<()> {
    let file = std::fs::File::create(tmp)?;
    let mut w = std::io::BufWriter::new(&file);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    {
        let mut cw = CrcWriter::new(&mut w);
        write_str(&mut cw, size)?;
        write_str(&mut cw, optimizer)?;
        cw.write_all(&step.to_le_bytes())?;
        cw.write_all(&(tensors.len() as u32).to_le_bytes())?;
        let crc = cw.value();
        w.write_all(&crc.to_le_bytes())?;
    }
    let torn_at = tensors.len() / 2;
    for (i, (name, t)) in tensors.iter().enumerate() {
        if i == torn_at && crate::fault::fires("save_partial") {
            w.flush()?;
            return Err(io_fault("failpoint save_partial: simulated crash mid-save"));
        }
        let mut cw = CrcWriter::new(&mut w);
        write_str(&mut cw, name)?;
        let shape = t.shape();
        cw.write_all(&(shape.len() as u32).to_le_bytes())?;
        for &d in shape {
            cw.write_all(&(d as u64).to_le_bytes())?;
        }
        for &x in t.f32s() {
            cw.write_all(&x.to_le_bytes())?;
        }
        let crc = cw.value();
        w.write_all(&crc.to_le_bytes())?;
    }
    w.flush()?;
    file.sync_all()?;
    Ok(())
}

fn load_body_v2<R: Read>(r: &mut Counted<R>, file_len: u64) -> anyhow::Result<Checkpoint> {
    r.reset_crc();
    let size = read_str(r)?;
    let optimizer = read_str(r)?;
    let step = read_u64(r)?;
    let n = read_u32(r)? as usize;
    let computed = r.crc();
    let stored = read_u32(r)?;
    anyhow::ensure!(computed == stored, "checkpoint header corrupt (crc mismatch)");
    anyhow::ensure!(n <= MAX_TENSORS, "absurd tensor count {n}");
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        r.reset_crc();
        let name = read_str(r)?;
        let shape = read_shape(r, &name)?;
        let data = read_payload(r, &shape, file_len, &name)?;
        let computed = r.crc();
        let stored = read_u32(r)?;
        anyhow::ensure!(computed == stored, "tensor {name:?} corrupt (crc mismatch)");
        tensors.push((name, Tensor::from_f32(&shape, data)));
    }
    Ok(Checkpoint { size, optimizer, step, tensors })
}

fn load_body_v1<R: Read>(r: &mut Counted<R>, file_len: u64) -> anyhow::Result<Checkpoint> {
    let size = read_str(r)?;
    let optimizer = read_str(r)?;
    let step = read_u64(r)?;
    let n = read_u32(r)? as usize;
    anyhow::ensure!(n <= MAX_TENSORS, "absurd tensor count {n}");
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        let name = read_str(r)?;
        let shape = read_shape(r, &name)?;
        let data = read_payload(r, &shape, file_len, &name)?;
        tensors.push((name, Tensor::from_f32(&shape, data)));
    }
    Ok(Checkpoint { size, optimizer, step, tensors })
}

fn read_shape<R: Read>(r: &mut Counted<R>, name: &str) -> anyhow::Result<Vec<usize>> {
    let ndims = read_u32(r)? as usize;
    anyhow::ensure!(ndims <= MAX_DIMS, "tensor {name:?}: absurd rank {ndims}");
    let mut shape = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        let d = read_u64(r)?;
        anyhow::ensure!(d <= MAX_DIM, "tensor {name:?}: absurd dim {d}");
        shape.push(d as usize);
    }
    Ok(shape)
}

/// Read a tensor payload, validating the claimed byte count against
/// what the file actually still holds *before* allocating anything.
fn read_payload<R: Read>(
    r: &mut Counted<R>,
    shape: &[usize],
    file_len: u64,
    name: &str,
) -> anyhow::Result<Vec<f32>> {
    let numel = shape
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow::anyhow!("tensor {name:?}: element count overflows"))?;
    let bytes = numel
        .checked_mul(4)
        .ok_or_else(|| anyhow::anyhow!("tensor {name:?}: byte count overflows"))?;
    let remaining = file_len.saturating_sub(r.count());
    anyhow::ensure!(
        bytes as u64 <= remaining,
        "tensor {name:?}: payload of {bytes} bytes exceeds the {remaining} left in the file"
    );
    let mut data = vec![0f32; numel];
    let mut chunk = [0u8; 4096];
    let mut idx = 0;
    while idx < numel {
        let take = ((numel - idx) * 4).min(chunk.len());
        let buf = &mut chunk[..take];
        r.read_exact(buf)?;
        for c in buf.chunks_exact(4) {
            data[idx] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            idx += 1;
        }
    }
    Ok(data)
}

/// Directory of retained snapshots (`step_XXXXXXXX.ckpt`): atomic
/// saves, keep-last-k pruning, stale-`.tmp` cleanup, and quarantine
/// with fallback when the newest snapshot turns out corrupt.
#[derive(Debug, Clone)]
pub struct CheckpointStore {
    dir: PathBuf,
    keep: usize,
}

impl CheckpointStore {
    /// Open (creating if needed) a checkpoint directory and sweep any
    /// stale `.tmp` leftovers from interrupted saves. `keep` is clamped
    /// to at least 1.
    pub fn open(dir: impl Into<PathBuf>, keep: usize) -> anyhow::Result<CheckpointStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        let store = CheckpointStore { dir, keep: keep.max(1) };
        store.clean_tmp();
        Ok(store)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("step_{:08}.ckpt", step))
    }

    /// Atomically persist `ckpt` under its step name, then prune to the
    /// newest `keep` snapshots and sweep stale `.tmp` files.
    pub fn save(&self, ckpt: &Checkpoint) -> anyhow::Result<PathBuf> {
        let path = self.path_for(ckpt.step);
        ckpt.save(&path)?;
        self.clean_tmp();
        let mut steps = self.list()?;
        while steps.len() > self.keep {
            let (_, old) = steps.remove(0);
            std::fs::remove_file(old).ok();
        }
        Ok(path)
    }

    /// All snapshots by ascending step. Files not matching the strict
    /// `step_<digits>.ckpt` naming — `.tmp` leftovers, `.corrupt`
    /// quarantines, anything else — are ignored.
    pub fn list(&self) -> anyhow::Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(step) = parse_step(name) {
                out.push((step, entry.path()));
            }
        }
        out.sort();
        Ok(out)
    }

    /// Load the newest loadable snapshot. One that fails to load (torn
    /// write, bit rot, injected IO fault) is quarantined — renamed to
    /// `<name>.corrupt` — and the scan falls back to the next-newest.
    /// `None` means the directory holds no loadable snapshot.
    pub fn latest(&self) -> anyhow::Result<Option<(u64, Checkpoint)>> {
        let mut steps = self.list()?;
        steps.reverse();
        for (step, path) in steps {
            match Checkpoint::load(&path) {
                Ok(ck) => return Ok(Some((step, ck))),
                Err(e) => {
                    let mut q = path.file_name().unwrap_or_default().to_os_string();
                    q.push(".corrupt");
                    let qpath = path.with_file_name(q);
                    eprintln!(
                        "checkpoint {}: {e}; quarantined as {}",
                        path.display(),
                        qpath.display()
                    );
                    std::fs::rename(&path, &qpath).ok();
                }
            }
        }
        Ok(None)
    }

    fn clean_tmp(&self) {
        let Ok(rd) = std::fs::read_dir(&self.dir) else { return };
        for entry in rd.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name.ends_with(".ckpt.tmp") {
                std::fs::remove_file(entry.path()).ok();
            } else if name.ends_with(".d.tmp") {
                // a torn sharded save from a crashed process
                std::fs::remove_dir_all(entry.path()).ok();
            }
        }
    }

    // ---- sharded snapshots -------------------------------------------------

    /// Directory path of the sharded snapshot for `step`.
    pub fn shard_dir_for(&self, step: u64) -> PathBuf {
        self.dir.join(format!("step_{:08}.d", step))
    }

    /// Atomically persist a *sharded* snapshot: `step_NNNNNNNN.d/` holding
    /// one v2 checkpoint file per rank (`shard_NNN.ckpt`, rank r's
    /// parameter range + state range per `ranges[r]`) plus a CRC'd
    /// `manifest.bin`. The whole set is staged in `step_NNNNNNNN.d.tmp/`,
    /// every file fsynced, then published by a single directory rename +
    /// parent fsync — a crash mid-save tears only the `.d.tmp`, which
    /// [`CheckpointStore::open`] sweeps. Prunes to the newest `keep`
    /// sharded snapshots.
    ///
    /// `ckpt.tensors` must be the full params-then-state list (as built
    /// by the trainer); `n_params` splits it, and each of `ranges[r]` is
    /// `(param index range, state slot range)` from the shard plan.
    pub fn save_sharded(
        &self,
        ckpt: &Checkpoint,
        n_params: usize,
        ranges: &[(std::ops::Range<usize>, std::ops::Range<usize>)],
    ) -> anyhow::Result<PathBuf> {
        if crate::fault::fires("save_io") {
            return Err(io_fault("failpoint save_io"));
        }
        anyhow::ensure!(!ranges.is_empty() && ranges.len() <= MAX_SHARDS, "shard count");
        anyhow::ensure!(n_params <= ckpt.tensors.len(), "param split out of range");
        let path = self.shard_dir_for(ckpt.step);
        let tmp = tmp_path(&path);
        std::fs::remove_dir_all(&tmp).ok();
        std::fs::create_dir_all(&tmp)?;
        for (r, (pr, sr)) in ranges.iter().enumerate() {
            let mut refs: Vec<(&str, &Tensor)> = Vec::with_capacity(pr.len() + sr.len());
            for (n, t) in &ckpt.tensors[pr.start..pr.end] {
                refs.push((n.as_str(), t));
            }
            for (n, t) in &ckpt.tensors[n_params + sr.start..n_params + sr.end] {
                refs.push((n.as_str(), t));
            }
            let shard_path = tmp.join(shard_file_name(r));
            write_v2_file(&shard_path, &ckpt.size, &ckpt.optimizer, ckpt.step, &refs)?;
        }
        write_shard_manifest(&tmp.join(MANIFEST_NAME), ckpt, ranges.len() as u32)?;
        // fsync the staged directory so its entries are durable before
        // the rename publishes them
        if let Ok(d) = std::fs::File::open(&tmp) {
            let _ = d.sync_all();
        }
        std::fs::remove_dir_all(&path).ok();
        std::fs::rename(&tmp, &path)?;
        sync_dir(&path);
        self.clean_tmp();
        let mut steps = self.list_sharded()?;
        while steps.len() > self.keep {
            let (_, old) = steps.remove(0);
            std::fs::remove_dir_all(old).ok();
        }
        Ok(path)
    }

    /// All sharded snapshots by ascending step (strict
    /// `step_<digits>.d` naming; `.d.tmp` and `.corrupt` are ignored).
    pub fn list_sharded(&self) -> anyhow::Result<Vec<(u64, PathBuf)>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            if let Some(step) = parse_shard_step(name) {
                if entry.path().is_dir() {
                    out.push((step, entry.path()));
                }
            }
        }
        out.sort();
        Ok(out)
    }

    /// Load the newest *complete* sharded snapshot for `ranks` ranks,
    /// reassembled into the full params-then-state [`Checkpoint`].
    /// Individually corrupt shard files (torn write, bit rot) are
    /// quarantined as `<name>.corrupt`; a snapshot with a missing or
    /// quarantined shard, a bad manifest, or the wrong rank count is
    /// incomplete and the scan falls back to the next-newest. `None`
    /// means no complete sharded snapshot exists.
    pub fn latest_sharded(&self, ranks: usize) -> anyhow::Result<Option<(u64, Checkpoint)>> {
        let mut steps = self.list_sharded()?;
        steps.reverse();
        'snap: for (step, dir) in steps {
            let mpath = dir.join(MANIFEST_NAME);
            let meta = match read_shard_manifest(&mpath) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("sharded snapshot {}: manifest: {e}; skipped", dir.display());
                    quarantine(&mpath);
                    continue;
                }
            };
            if meta.ranks as usize != ranks || meta.step != step {
                eprintln!(
                    "sharded snapshot {}: written for {} ranks at step {} (want {ranks}); skipped",
                    dir.display(),
                    meta.ranks,
                    meta.step
                );
                continue;
            }
            let mut shards = Vec::with_capacity(ranks);
            for r in 0..ranks {
                let spath = dir.join(shard_file_name(r));
                if !spath.exists() {
                    eprintln!(
                        "sharded snapshot {}: shard {r} missing; incomplete, skipped",
                        dir.display()
                    );
                    continue 'snap;
                }
                match Checkpoint::load(&spath) {
                    Ok(ck)
                        if ck.step == meta.step
                            && ck.size == meta.size
                            && ck.optimizer == meta.optimizer =>
                    {
                        shards.push(ck)
                    }
                    Ok(_) => {
                        eprintln!(
                            "sharded snapshot {}: shard {r} disagrees with the manifest; skipped",
                            dir.display()
                        );
                        quarantine(&spath);
                        continue 'snap;
                    }
                    Err(e) => {
                        eprintln!(
                            "sharded snapshot {}: shard {r}: {e}; quarantined",
                            dir.display()
                        );
                        quarantine(&spath);
                        continue 'snap;
                    }
                }
            }
            return Ok(Some((step, assemble_shards(&shards)?)));
        }
        Ok(None)
    }
}

/// Reassemble per-rank shard checkpoints (each `[params of range, state
/// of range]`, ranges contiguous and ascending in rank order) into the
/// full params-then-state checkpoint the trainer restores from. State
/// tensors are recognized by the `state:` name prefix the trainer's
/// checkpoint builder stamps.
pub fn assemble_shards(shards: &[Checkpoint]) -> anyhow::Result<Checkpoint> {
    anyhow::ensure!(!shards.is_empty(), "no shards to assemble");
    let first = &shards[0];
    let mut params = Vec::new();
    let mut state = Vec::new();
    for ck in shards {
        anyhow::ensure!(
            ck.size == first.size && ck.optimizer == first.optimizer && ck.step == first.step,
            "shard checkpoints disagree on size/optimizer/step"
        );
        for (name, t) in &ck.tensors {
            if name.starts_with("state:") {
                state.push((name.clone(), t.clone()));
            } else {
                params.push((name.clone(), t.clone()));
            }
        }
    }
    let mut tensors = params;
    tensors.extend(state);
    Ok(Checkpoint {
        size: first.size.clone(),
        optimizer: first.optimizer.clone(),
        step: first.step,
        tensors,
    })
}

/// Shard-count sanity bound for sharded snapshots (mirrors the wire and
/// loader hostile-input posture).
const MAX_SHARDS: usize = 1 << 12;
const MANIFEST_NAME: &str = "manifest.bin";
const SHARD_MAGIC: &[u8; 4] = b"SCLS";
const SHARD_MANIFEST_VERSION: u32 = 1;

struct ShardManifest {
    ranks: u32,
    step: u64,
    size: String,
    optimizer: String,
}

/// `manifest.bin`: magic "SCLS" | u32 version | CRC'd region
/// [ u32 ranks | u64 step | str size | str optimizer ] | u32 crc —
/// the same region-checksum discipline as format v2.
fn write_shard_manifest(path: &Path, ckpt: &Checkpoint, ranks: u32) -> anyhow::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::new(&file);
    w.write_all(SHARD_MAGIC)?;
    w.write_all(&SHARD_MANIFEST_VERSION.to_le_bytes())?;
    {
        let mut cw = CrcWriter::new(&mut w);
        cw.write_all(&ranks.to_le_bytes())?;
        cw.write_all(&ckpt.step.to_le_bytes())?;
        write_str(&mut cw, &ckpt.size)?;
        write_str(&mut cw, &ckpt.optimizer)?;
        let crc = cw.value();
        w.write_all(&crc.to_le_bytes())?;
    }
    w.flush()?;
    file.sync_all()?;
    Ok(())
}

fn read_shard_manifest(path: &Path) -> anyhow::Result<ShardManifest> {
    let file = std::fs::File::open(path)?;
    let mut r = Counted::new(std::io::BufReader::new(file));
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == SHARD_MAGIC, "not a shard manifest");
    let version = read_u32(&mut r)?;
    anyhow::ensure!(version == SHARD_MANIFEST_VERSION, "unsupported manifest version {version}");
    r.reset_crc();
    let ranks = read_u32(&mut r)?;
    let step = read_u64(&mut r)?;
    let size = read_str(&mut r)?;
    let optimizer = read_str(&mut r)?;
    let computed = r.crc();
    let stored = read_u32(&mut r)?;
    anyhow::ensure!(computed == stored, "shard manifest corrupt (crc mismatch)");
    anyhow::ensure!(ranks as usize <= MAX_SHARDS && ranks > 0, "absurd rank count {ranks}");
    Ok(ShardManifest { ranks, step, size, optimizer })
}

fn shard_file_name(rank: usize) -> String {
    format!("shard_{:03}.ckpt", rank)
}

fn parse_shard_step(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("step_")?.strip_suffix(".d")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Rename a bad snapshot component to `<name>.corrupt` (best effort).
fn quarantine(path: &Path) {
    let mut q = path.file_name().unwrap_or_default().to_os_string();
    q.push(".corrupt");
    std::fs::rename(path, path.with_file_name(q)).ok();
}

fn parse_step(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("step_")?.strip_suffix(".ckpt")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Best-effort fsync of the directory holding `path`, so the rename
/// that published a snapshot survives power loss too.
fn sync_dir(path: &Path) {
    let parent = match path.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    if let Ok(d) = std::fs::File::open(parent) {
        let _ = d.sync_all();
    }
}

fn io_fault(msg: &str) -> anyhow::Error {
    std::io::Error::other(msg.to_string()).into()
}

/// Tee writer: forwards to the inner writer while accumulating the
/// CRC of everything written — frames one checksummed region.
struct CrcWriter<'a, W: Write> {
    w: &'a mut W,
    crc: Crc32,
}

impl<'a, W: Write> CrcWriter<'a, W> {
    fn new(w: &'a mut W) -> Self {
        CrcWriter { w, crc: Crc32::new() }
    }

    fn value(&self) -> u32 {
        self.crc.value()
    }
}

impl<W: Write> Write for CrcWriter<'_, W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let n = self.w.write(buf)?;
        self.crc.update(&buf[..n]);
        Ok(n)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.w.flush()
    }
}

/// Tee reader: counts bytes consumed (for payload-vs-file-length
/// validation) and accumulates the CRC of the current region.
struct Counted<R> {
    inner: R,
    crc: Crc32,
    count: u64,
}

impl<R: Read> Counted<R> {
    fn new(inner: R) -> Self {
        Counted { inner, crc: Crc32::new(), count: 0 }
    }

    fn reset_crc(&mut self) {
        self.crc = Crc32::new();
    }

    fn crc(&self) -> u32 {
        self.crc.value()
    }

    fn count(&self) -> u64 {
        self.count
    }
}

impl<R: Read> Read for Counted<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.crc.update(&buf[..n]);
        self.count += n as u64;
        Ok(n)
    }
}

fn write_str<W: Write>(w: &mut W, s: &str) -> std::io::Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())
}

fn read_u32<R: Read>(r: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_str<R: Read>(r: &mut R) -> anyhow::Result<String> {
    let len = read_u32(r)? as usize;
    anyhow::ensure!(len < 1 << 20, "absurd string length {len}");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("scale_ckpt_{name}_{}", std::process::id()))
    }

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("scale_store_{name}_{}", std::process::id()))
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            size: "s60m".into(),
            optimizer: "scale".into(),
            step: 123,
            tensors: vec![
                ("embed".into(), Tensor::from_f32(&[2, 3], vec![1., -2., 3., 4., 5., 6.5])),
                ("lm_head.m".into(), Tensor::from_f32(&[3], vec![0.1, 0.2, 0.3])),
                ("scalar".into(), Tensor::from_f32(&[], vec![9.0])),
            ],
        }
    }

    fn assert_same(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.size, b.size);
        assert_eq!(a.optimizer, b.optimizer);
        assert_eq!(a.step, b.step);
        assert_eq!(a.tensors.len(), b.tensors.len());
        for ((an, at), (bn, bt)) in a.tensors.iter().zip(&b.tensors) {
            assert_eq!(an, bn);
            assert_eq!(at, bt);
        }
    }

    #[test]
    fn roundtrip_exact() {
        let p = tmp("rt");
        let c = sample();
        c.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_same(&c, &back);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn v1_roundtrips_through_v2_loader() {
        let p = tmp("v1rt");
        let c = sample();
        c.save_v1(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[4..8], &1u32.to_le_bytes(), "save_v1 must stamp version 1");
        let back = Checkpoint::load(&p).unwrap();
        assert_same(&c, &back);
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn save_leaves_no_tmp_behind() {
        let p = tmp("atomic");
        sample().save(&p).unwrap();
        assert!(!tmp_path(&p).exists(), "successful save must rename its .tmp away");
        assert!(Checkpoint::load(&p).is_ok());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_garbage() {
        let p = tmp("bad");
        std::fs::write(&p, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_truncated() {
        let p = tmp("trunc");
        sample().save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(Checkpoint::load(&p).is_err());
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn rejects_flipped_payload_bit() {
        let p = tmp("flip");
        sample().save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p).unwrap_err().to_string();
        assert!(err.contains("crc") || err.contains("corrupt") || err.contains("absurd"), "{err}");
        std::fs::remove_file(p).ok();
    }

    /// A syntactically valid v1 prefix the hostile-header tests extend.
    fn v1_prefix(n_tensors: u32) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(MAGIC);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&4u32.to_le_bytes());
        b.extend_from_slice(b"tiny");
        b.extend_from_slice(&5u32.to_le_bytes());
        b.extend_from_slice(b"scale");
        b.extend_from_slice(&7u64.to_le_bytes());
        b.extend_from_slice(&n_tensors.to_le_bytes());
        b
    }

    #[test]
    fn hostile_headers_bounded_before_allocation() {
        // absurd rank
        let p = tmp("rank");
        let mut b = v1_prefix(1);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(b"t");
        b.extend_from_slice(&(u32::MAX).to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        assert!(Checkpoint::load(&p).unwrap_err().to_string().contains("absurd rank"));

        // absurd single dim
        let mut b = v1_prefix(1);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(b"t");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        assert!(Checkpoint::load(&p).unwrap_err().to_string().contains("absurd dim"));

        // dims individually legal but the claimed payload dwarfs the
        // file: must be rejected before any buffer is allocated
        let mut b = v1_prefix(1);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(b"t");
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&(1u64 << 20).to_le_bytes());
        b.extend_from_slice(&(1u64 << 10).to_le_bytes());
        std::fs::write(&p, &b).unwrap();
        assert!(Checkpoint::load(&p).unwrap_err().to_string().contains("exceeds"));

        // absurd tensor count
        let b = v1_prefix(u32::MAX);
        std::fs::write(&p, &b).unwrap();
        assert!(Checkpoint::load(&p).unwrap_err().to_string().contains("absurd tensor count"));
        std::fs::remove_file(p).ok();
    }

    /// Golden-bytes pin of the v1 layout: these literal bytes are the
    /// on-disk contract for checkpoints written before the v2 CRC
    /// format, so this test failing means old snapshots stopped
    /// loading — a regression, not a refactor.
    #[test]
    fn v1_golden_bytes_load_exactly() {
        let mut golden = v1_prefix(1); // "tiny" / "scale" / step 7
        golden.extend_from_slice(&1u32.to_le_bytes()); // name len
        golden.extend_from_slice(b"w");
        golden.extend_from_slice(&1u32.to_le_bytes()); // ndims
        golden.extend_from_slice(&2u64.to_le_bytes()); // dim 0
        golden.extend_from_slice(&1.5f32.to_le_bytes());
        golden.extend_from_slice(&(-2.0f32).to_le_bytes());

        let p = tmp("golden");
        std::fs::write(&p, &golden).unwrap();
        let ck = Checkpoint::load(&p).unwrap();
        assert_eq!(ck.size, "tiny");
        assert_eq!(ck.optimizer, "scale");
        assert_eq!(ck.step, 7);
        assert_eq!(ck.tensors.len(), 1);
        assert_eq!(ck.tensors[0].0, "w");
        assert_eq!(ck.tensors[0].1.shape(), &[2]);
        assert_eq!(
            ck.tensors[0].1.f32s().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            [1.5f32.to_bits(), (-2.0f32).to_bits()]
        );

        // and the v1 writer still emits exactly these bytes
        ck.save_v1(&p).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), golden, "save_v1 drifted from the golden layout");
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn store_retention_latest_and_quarantine() {
        let dir = tmp_dir("ret");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 2).unwrap();
        for step in [3u64, 6, 9] {
            let mut c = sample();
            c.step = step;
            store.save(&c).unwrap();
        }
        let steps: Vec<u64> = store.list().unwrap().iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, [6, 9], "keep-last-2 must prune step 3");
        let (step, ck) = store.latest().unwrap().expect("latest");
        assert_eq!((step, ck.step), (9, 9));

        // corrupt the newest: latest() must quarantine it and fall back
        let newest = store.path_for(9);
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let (step, ck) = store.latest().unwrap().expect("fallback");
        assert_eq!((step, ck.step), (6, 6));
        assert!(!newest.exists(), "corrupt snapshot must be moved aside");
        assert!(
            newest.with_file_name("step_00000009.ckpt.corrupt").exists(),
            "corrupt snapshot must be quarantined, not deleted"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_ignores_and_cleans_stale_tmp() {
        let dir = tmp_dir("tmpclean");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 3).unwrap();
        let mut c = sample();
        c.step = 4;
        store.save(&c).unwrap();
        // a torn write from a crashed process
        let stale = dir.join("step_00000008.ckpt.tmp");
        std::fs::write(&stale, b"torn").unwrap();
        let (step, _) = store.latest().unwrap().expect("latest");
        assert_eq!(step, 4, "a .tmp leftover must never be picked up as a snapshot");
        // re-opening the directory sweeps it
        CheckpointStore::open(&dir, 3).unwrap();
        assert!(!stale.exists(), "stale .tmp must be cleaned on open");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_survives_two_corrupt_snapshots() {
        // both of the two newest snapshots corrupt -> both quarantined
        // as .corrupt, the third-newest loads
        let dir = tmp_dir("twocorrupt");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 3).unwrap();
        for step in [3u64, 6, 9] {
            let mut c = sample();
            c.step = step;
            store.save(&c).unwrap();
        }
        for step in [6u64, 9] {
            let p = store.path_for(step);
            let mut bytes = std::fs::read(&p).unwrap();
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0xFF;
            std::fs::write(&p, &bytes).unwrap();
        }
        let (step, ck) = store.latest().unwrap().expect("third-newest must load");
        assert_eq!((step, ck.step), (3, 3));
        for step in [6u64, 9] {
            assert!(!store.path_for(step).exists(), "step {step} must be moved aside");
            let q = dir.join(format!("step_{:08}.ckpt.corrupt", step));
            assert!(q.exists(), "step {step} must be quarantined, not deleted");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Full params-then-state checkpoint + the shard split the sharded
    /// tests use: 3 params (slots 1, 0, 1) across 2 ranks.
    fn sharded_sample(step: u64) -> SplitSample {
        let ck = Checkpoint {
            size: "s60m".into(),
            optimizer: "scale".into(),
            step,
            tensors: vec![
                ("a".into(), Tensor::from_f32(&[2, 2], vec![1., 2., 3., 4.])),
                ("b".into(), Tensor::from_f32(&[3], vec![5., 6., 7.])),
                ("c".into(), Tensor::from_f32(&[4], vec![8., 9., 10., 11.])),
                ("state:a.m".into(), Tensor::from_f32(&[2, 2], vec![0.1, 0.2, 0.3, 0.4])),
                ("state:c.m".into(), Tensor::from_f32(&[4], vec![0.5, 0.6, 0.7, 0.8])),
            ],
        };
        (ck, 3, vec![(0..2, 0..1), (2..3, 1..2)])
    }

    type SplitSample =
        (Checkpoint, usize, Vec<(std::ops::Range<usize>, std::ops::Range<usize>)>);

    #[test]
    fn sharded_snapshot_roundtrips_and_is_atomic() {
        let dir = tmp_dir("shardrt");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 3).unwrap();
        let (ck, np, ranges) = sharded_sample(12);
        let snap = store.save_sharded(&ck, np, &ranges).unwrap();
        assert!(snap.join("manifest.bin").exists());
        assert!(snap.join("shard_000.ckpt").exists());
        assert!(snap.join("shard_001.ckpt").exists());
        assert!(!tmp_path(&snap).exists(), "publish must rename the .d.tmp away");
        let (step, back) = store.latest_sharded(2).unwrap().expect("latest");
        assert_eq!(step, 12);
        assert_same(&ck, &back);
        // the wrong rank count never matches
        assert!(store.latest_sharded(3).unwrap().is_none());
        // a stale .d.tmp from a crashed save is swept on open
        let stale = dir.join("step_00000099.d.tmp");
        std::fs::create_dir_all(&stale).unwrap();
        CheckpointStore::open(&dir, 3).unwrap();
        assert!(!stale.exists(), "stale .d.tmp must be cleaned on open");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_missing_shard_is_incomplete_and_skipped() {
        let dir = tmp_dir("shardmiss");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 3).unwrap();
        for step in [4u64, 8] {
            let (ck, np, ranges) = sharded_sample(step);
            store.save_sharded(&ck, np, &ranges).unwrap();
        }
        // newest snapshot loses one shard file -> incomplete -> fallback
        std::fs::remove_file(store.shard_dir_for(8).join("shard_001.ckpt")).unwrap();
        let (step, back) = store.latest_sharded(2).unwrap().expect("fallback");
        assert_eq!(step, 4);
        assert_same(&sharded_sample(4).0, &back);
        // if the older one is incomplete too there is no latest at all
        std::fs::remove_file(store.shard_dir_for(4).join("shard_000.ckpt")).unwrap();
        assert!(store.latest_sharded(2).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_corrupt_shard_is_quarantined_individually() {
        let dir = tmp_dir("shardcorrupt");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 3).unwrap();
        for step in [4u64, 8] {
            let (ck, np, ranges) = sharded_sample(step);
            store.save_sharded(&ck, np, &ranges).unwrap();
        }
        let bad = store.shard_dir_for(8).join("shard_001.ckpt");
        let mut bytes = std::fs::read(&bad).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&bad, &bytes).unwrap();
        let (step, back) = store.latest_sharded(2).unwrap().expect("fallback");
        assert_eq!(step, 4);
        assert_same(&sharded_sample(4).0, &back);
        assert!(!bad.exists(), "corrupt shard must be moved aside");
        assert!(
            bad.with_file_name("shard_001.ckpt.corrupt").exists(),
            "corrupt shard must be quarantined individually, not deleted"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_retention_prunes_old_snapshot_dirs() {
        let dir = tmp_dir("shardret");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 2).unwrap();
        for step in [3u64, 6, 9] {
            let (ck, np, ranges) = sharded_sample(step);
            store.save_sharded(&ck, np, &ranges).unwrap();
        }
        let steps: Vec<u64> = store.list_sharded().unwrap().iter().map(|(s, _)| *s).collect();
        assert_eq!(steps, [6, 9], "keep-last-2 must prune the step-3 dir");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_empty_dir_has_no_latest() {
        let dir = tmp_dir("empty");
        std::fs::remove_dir_all(&dir).ok();
        let store = CheckpointStore::open(&dir, 3).unwrap();
        assert!(store.latest().unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}
