//! Typed training failures and the divergence-guard policy.
//!
//! [`TrainError`] splits "the run is mathematically doomed"
//! (`Divergence`) from "the disk let us down" (`Io`) from "the executor
//! itself failed" (`Engine`) from "the worker mesh is unrecoverable"
//! (`Mesh`), so recovery code — `Trainer::train_guarded` rollback,
//! `sweep` trial retry, the `mesh` supervisor — classifies failures by
//! variant instead of string-matching `anyhow` messages. Divergence is
//! deterministic (same seed, same step, same non-finite value) and is
//! therefore never blindly re-run: the guard rolls back *with LR
//! backoff*, and a sweep trial slots it as a diverged point immediately.
//! Io and panics are treated as transient and retried up to a cap;
//! Engine errors (bad manifest, missing artifact) fail fast.
//!
//! [`GuardPolicy`] configures `Trainer::train_guarded`: where the run's
//! [`super::checkpoint::CheckpointStore`] lives, the auto-checkpoint
//! cadence, retention, the total rollback budget, and the LR backoff
//! applied on every rollback.

use std::fmt;
use std::path::PathBuf;

/// A classified training failure. Implements `std::error::Error`, so
/// `?` lifts it into `anyhow::Result` at the CLI/test boundary while
/// recovery code can still match on the variant.
#[derive(Debug)]
pub enum TrainError {
    /// Non-finite loss or gradients: deterministic, not retryable
    /// as-is — roll back and shrink the LR, or give up.
    Divergence { step: usize, what: &'static str },
    /// Checkpoint save/load failed: transient, worth retrying.
    Io(anyhow::Error),
    /// The executor or configuration failed: fail fast.
    Engine(anyhow::Error),
    /// The worker mesh failed beyond its recovery budget (rank
    /// respawns or frame retries exhausted, workers unreachable): the
    /// distributed run aborts cleanly instead of hanging.
    Mesh(anyhow::Error),
}

impl TrainError {
    pub fn divergence(step: usize, what: &'static str) -> TrainError {
        TrainError::Divergence { step, what }
    }

    pub fn io(e: anyhow::Error) -> TrainError {
        TrainError::Io(e)
    }

    pub fn engine(e: anyhow::Error) -> TrainError {
        TrainError::Engine(e)
    }

    pub fn mesh(e: anyhow::Error) -> TrainError {
        TrainError::Mesh(e)
    }

    pub fn is_divergence(&self) -> bool {
        matches!(self, TrainError::Divergence { .. })
    }
}

impl fmt::Display for TrainError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrainError::Divergence { step, what } => {
                write!(f, "divergence at step {step}: {what}")
            }
            TrainError::Io(e) => write!(f, "checkpoint io: {e}"),
            TrainError::Engine(e) => write!(f, "engine: {e}"),
            TrainError::Mesh(e) => write!(f, "mesh: {e}"),
        }
    }
}

// `anyhow::Error` (vendored) is string-backed and does not implement
// `std::error::Error`, so there is no source() chain to expose here.
impl std::error::Error for TrainError {}

/// Engine/config failures arrive through `?` from `anyhow` call sites;
/// IO and divergence are always constructed explicitly.
impl From<anyhow::Error> for TrainError {
    fn from(e: anyhow::Error) -> TrainError {
        TrainError::Engine(e)
    }
}

/// Configuration for `Trainer::train_guarded`: auto-checkpoint cadence
/// plus rollback-on-divergence with LR backoff and a bounded retry
/// budget.
#[derive(Debug, Clone)]
pub struct GuardPolicy {
    /// Run directory for the `CheckpointStore`.
    pub dir: PathBuf,
    /// Auto-checkpoint every N steps (>= 1). A baseline snapshot is
    /// also taken at step 0 so rollback always has a target.
    pub checkpoint_every: usize,
    /// Keep-last-k retention in the store.
    pub keep_last: usize,
    /// Total rollbacks allowed across the whole run; the retry after
    /// which a still-diverging run propagates its `Divergence` error.
    pub max_retries: usize,
    /// Multiplied into the trainer's LR scale on every rollback.
    /// `1.0` keeps the schedule bit-identical (useful when the
    /// divergence was injected, not earned); `0.5` is the classic
    /// halving.
    pub lr_backoff: f64,
}

impl GuardPolicy {
    pub fn new(dir: impl Into<PathBuf>) -> GuardPolicy {
        GuardPolicy {
            dir: dir.into(),
            checkpoint_every: 50,
            keep_last: 3,
            max_retries: 3,
            lr_backoff: 0.5,
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.checkpoint_every >= 1, "guard: checkpoint_every must be >= 1");
        anyhow::ensure!(
            self.lr_backoff.is_finite() && self.lr_backoff > 0.0,
            "guard: lr_backoff must be a positive finite factor"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_classification() {
        let d = TrainError::divergence(7, "non-finite loss");
        assert!(d.is_divergence());
        assert_eq!(d.to_string(), "divergence at step 7: non-finite loss");
        let io = TrainError::io(anyhow::anyhow!("disk on fire"));
        assert!(io.to_string().contains("checkpoint io"));
        let eng: TrainError = anyhow::anyhow!("no such artifact").into();
        assert!(matches!(eng, TrainError::Engine(_)));
        let mesh = TrainError::mesh(anyhow::anyhow!("rank 1 respawn budget exhausted"));
        assert!(!mesh.is_divergence());
        assert_eq!(mesh.to_string(), "mesh: rank 1 respawn budget exhausted");
    }

    #[test]
    fn lifts_into_anyhow() {
        // the blanket `impl From<E: std::error::Error> for anyhow::Error`
        // is what lets `?` carry a TrainError out of CLI/test code
        let e: anyhow::Error = TrainError::divergence(3, "non-finite gradient").into();
        assert!(e.to_string().contains("divergence at step 3"), "{e}");
    }

    #[test]
    fn policy_validation() {
        let mut p = GuardPolicy::new("ckpts");
        p.validate().unwrap();
        p.checkpoint_every = 0;
        assert!(p.validate().is_err());
        p.checkpoint_every = 1;
        p.lr_backoff = 0.0;
        assert!(p.validate().is_err());
        p.lr_backoff = f64::NAN;
        assert!(p.validate().is_err());
    }
}
