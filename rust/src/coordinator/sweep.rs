//! Sweep driver: run the trainer across a hyperparameter grid,
//! concurrently.
//!
//! Backs the paper's wandb sweeps (App. C), the LR-sensitivity study
//! (Fig. 8), and optimizer face-offs like the Table-13 ablations. A
//! [`SweepSpec`] is a grid of composable axes (optimizer × learning
//! rate × seed) over one base [`TrainOptions`]; every grid point is an
//! independent deterministic run, so [`SweepSpec::run`] dispatches the
//! trials as jobs on the process-wide shared [`WorkerPool`] and slots
//! results by trial index — the concurrent output is bit-identical to
//! the serial loop for every pool size. [`SweepSpec::run_serial`] is the
//! kept sequential reference; the differential suite in
//! `rust/tests/sweep_differential.rs` pins the equivalence, including
//! the `ppl = inf` slotting of diverged trials.
//!
//! # Why concurrent trials are bit-identical
//!
//! A trial is a pure function of its `TrainOptions`: each builds its own
//! [`Trainer`] (own params, state, token rings, persistent buffers) over
//! the shared `Engine`, whose per-program workspaces are scratch that
//! every execution fully overwrites before reading, and the data
//! pipeline cache is keyed by `(vocab, seed)` with deterministic
//! content. Scheduling therefore cannot reach any computed number.
//! Trial jobs fan their intra-trial work (shard fwd/bwd, tree reduce,
//! tiled kernels, GEMM blocks) out as *nested* batches on the same
//! pool; the batch-tagged queue makes that composition deadlock-free
//! (see [`crate::parallel`]). No sweep path ever spawns a thread — the
//! trials ride the pool every `Trainer` already uses.
//!
//! # Fault handling
//!
//! Trial failures are classified ([`TrialOutcome`]), not string-matched:
//! a deterministic divergence slots as a `diverged` point immediately,
//! while transient faults — a panic inside the trial job (including
//! panics re-raised from nested pool batches) or an Io/Engine error
//! after construction — are retried with a fresh `Trainer` up to
//! [`SweepSpec::retries`] times and then slotted as `faulted` instead of
//! aborting the batch. Construction errors (unknown optimizer/size)
//! still fail fast. Every trial runs inside
//! `fault::scoped("trial{i}", ..)`, so an injected fault spec like
//! `trial2/trial_panic@1` targets the same grid point at every pool
//! size — the chaos suite pins retried-sweep reports bit-identical to
//! fault-free ones.
//!
//! # Statistical verdicts
//!
//! The verdict layer ([`VerdictSpec`], [`aggregate_cells`]) turns raw
//! multi-seed points into conclusions: mean/stddev/95%-CI per
//! `(optimizer, lr)` cell via Welford's algorithm, accumulated strictly
//! in grid order over the index-slotted point list — so the report is
//! bit-stable across pool sizes and `max_concurrent` caps by
//! construction (scheduling never reorders the accumulation).
//! Non-finite trials (diverged/faulted) are excluded from the moments
//! and surfaced as an explicit `n_effective` count; an all-diverged
//! cell reports `mean_ppl = inf` (JSON `null`). [`VerdictSpec::verdict`]
//! then ranks optimizers by their best cell under an optional
//! optimizer-state memory budget (bytes from `memory::estimator`,
//! injected by the caller) — the `scale compare` answer to
//! "best ppl at this memory budget".

use crate::coordinator::recovery::TrainError;
use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::parallel::{self, WorkerPool};
use crate::runtime::Engine;
use crate::util::json::Json;

/// Typed classification of how a trial concluded, surfaced in
/// [`report_json`] as `outcome` (the `diverged` bool stays for
/// compatibility).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialOutcome {
    /// Clean first-attempt finish.
    Ok,
    /// Deterministic divergence: typed [`TrainError::Divergence`] or a
    /// final ppl past the 1e6 bar. Never retried — same seed, same math.
    Diverged,
    /// Transient faults exhausted the retry budget; slotted, not fatal.
    Faulted,
    /// Finished clean after at least one retry.
    Retried,
}

impl TrialOutcome {
    pub fn as_str(self) -> &'static str {
        match self {
            TrialOutcome::Ok => "ok",
            TrialOutcome::Diverged => "diverged",
            TrialOutcome::Faulted => "faulted",
            TrialOutcome::Retried => "retried",
        }
    }
}

/// One finished trial. `ppl` and `final_loss_ema` are `f64::INFINITY`
/// when the run diverged (non-finite loss or past the divergence bar)
/// or faulted past its retry budget.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub optimizer: String,
    pub lr: f64,
    pub seed: u64,
    pub ppl: f64,
    pub final_loss_ema: f64,
    pub diverged: bool,
    pub outcome: TrialOutcome,
    /// Attempts consumed (1 = no retry was needed).
    pub attempts: u32,
}

/// A multi-trial grid over one base configuration. Axes compose: the
/// trial list is the cartesian product, optimizer-major, then LR, then
/// seed. An empty axis means "just the base value" — so a plain LR
/// sweep, an optimizer face-off, and a seed-replication study are all
/// the same engine.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Template for every trial. Per-axis fields are overridden per
    /// trial; `schedule` is reset to `None` (fresh cosine at each peak)
    /// and `quiet` is forced (concurrent trials must not interleave
    /// logging).
    pub base: TrainOptions,
    /// Peak learning rates; empty -> one LR per trial, resolved from
    /// `lr_for` (when set) or `base.base_lr`.
    pub lrs: Vec<f64>,
    /// Optimizer names; empty -> just `base.optimizer`.
    pub optimizers: Vec<String>,
    /// Data/init seeds; empty -> just `base.seed`.
    pub seeds: Vec<u64>,
    /// Per-optimizer peak-LR resolver, consulted only when `lrs` is
    /// empty: an optimizer face-off then gives every optimizer its own
    /// tuned default instead of one shared LR (the Table-13 semantics;
    /// the CLI wires `harness::default_lr` here). `None` -> every
    /// trial uses `base.base_lr`.
    pub lr_for: Option<fn(&str) -> f64>,
    /// Upper bound on trials in flight at once (`0` = unbounded). Caps
    /// peak memory — every in-flight trial holds a full `Trainer` — at
    /// the cost of a wave barrier per chunk. Never affects results:
    /// chunking only changes scheduling, and results stay slotted by
    /// trial index.
    pub max_concurrent: usize,
    /// Retry budget per trial for transient faults (panics, Io/Engine
    /// errors after construction). `0` = fault once, slot as `faulted`.
    /// Divergence is never retried.
    pub retries: usize,
}

impl SweepSpec {
    pub fn new(base: TrainOptions) -> SweepSpec {
        SweepSpec {
            base,
            lrs: Vec::new(),
            optimizers: Vec::new(),
            seeds: Vec::new(),
            lr_for: None,
            max_concurrent: 0,
            retries: 0,
        }
    }

    /// The Fig. 8 / App. C shape: one optimizer, a grid of peak LRs.
    pub fn lr_grid(base: TrainOptions, lrs: &[f64]) -> SweepSpec {
        SweepSpec {
            lrs: lrs.to_vec(),
            ..SweepSpec::new(base)
        }
    }

    /// The Table-13 shape: one LR, a grid of optimizers.
    pub fn optimizer_grid(base: TrainOptions, optimizers: &[&str]) -> SweepSpec {
        SweepSpec {
            optimizers: optimizers.iter().map(|s| s.to_string()).collect(),
            ..SweepSpec::new(base)
        }
    }

    /// Trial options in canonical order (optimizer-major, then LR, then
    /// seed) — the order `run`, `run_on`, and `run_serial` all emit.
    pub fn trials(&self) -> Vec<TrainOptions> {
        let opt_axis: Vec<String> = if self.optimizers.is_empty() {
            vec![self.base.optimizer.clone()]
        } else {
            self.optimizers.clone()
        };
        let seed_axis: Vec<u64> = if self.seeds.is_empty() {
            vec![self.base.seed]
        } else {
            self.seeds.clone()
        };
        let n_lrs = self.lrs.len().max(1);
        let mut out = Vec::with_capacity(opt_axis.len() * n_lrs * seed_axis.len());
        for opt in &opt_axis {
            let lr_axis: Vec<f64> = if !self.lrs.is_empty() {
                self.lrs.clone()
            } else if let Some(f) = self.lr_for {
                vec![f(opt)]
            } else {
                vec![self.base.base_lr]
            };
            for &lr in &lr_axis {
                for &seed in &seed_axis {
                    let mut t = self.base.clone();
                    t.optimizer = opt.clone();
                    t.base_lr = lr;
                    t.seed = seed;
                    t.schedule = None; // rebuild the cosine schedule at this peak
                    t.quiet = true;
                    out.push(t);
                }
            }
        }
        out
    }

    /// Run every trial concurrently on the process-wide shared pool —
    /// the production entry point (zero thread spawns).
    pub fn run(&self, engine: &Engine) -> anyhow::Result<Vec<SweepPoint>> {
        self.run_on(engine, parallel::shared())
    }

    /// Run every trial as one job on `pool`, results slotted by trial
    /// index — bit-identical to [`run_serial`](Self::run_serial) for
    /// every pool size and every `max_concurrent` (a zero-worker pool
    /// degenerates to the inline loop). On a trial error the in-flight
    /// wave still runs to completion (the pool contract) but later
    /// waves are skipped, and the lowest-indexed error is returned.
    ///
    /// Peak memory: a queued trial holds only its `TrainOptions` — the
    /// `Trainer` is built inside the job — so at most
    /// `min(trials, pool lanes, max_concurrent)` full trainers are ever
    /// resident at once. Lower `max_concurrent` to trade wall-clock for
    /// a smaller bound.
    pub fn run_on(&self, engine: &Engine, pool: &WorkerPool) -> anyhow::Result<Vec<SweepPoint>> {
        let retries = self.retries;
        // the scope is keyed by the absolute grid index (not the wave
        // position), so `trial{i}/...` fault specs target the same grid
        // point for every pool size and every max_concurrent
        let mut queue: Vec<_> = self
            .trials()
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                move || crate::fault::scoped(&format!("trial{i}"), || run_trial(engine, t, retries))
            })
            .collect();
        let cap = if self.max_concurrent == 0 {
            queue.len()
        } else {
            self.max_concurrent
        };
        let mut results = Vec::with_capacity(queue.len());
        while !queue.is_empty() {
            let rest = queue.split_off(queue.len().min(cap));
            let wave = pool.run(queue);
            let failed = wave.iter().any(|r| r.is_err());
            results.extend(wave);
            if failed {
                break; // fail fast: don't train the remaining waves
            }
            queue = rest;
        }
        results.into_iter().collect()
    }

    /// The sequential reference loop the differential tests compare
    /// against. One behavioral difference from `run_on`: this stops at
    /// the first trial error instead of completing the batch (the
    /// returned value is identical either way).
    pub fn run_serial(&self, engine: &Engine) -> anyhow::Result<Vec<SweepPoint>> {
        let mut out = Vec::new();
        for (i, t) in self.trials().into_iter().enumerate() {
            let pt = crate::fault::scoped(&format!("trial{i}"), || {
                run_trial(engine, t, self.retries)
            })?;
            out.push(pt);
        }
        Ok(out)
    }
}

/// Train one grid point to completion, with bounded retries for
/// transient faults:
///
/// - construction failure (unknown optimizer/size) propagates — a
///   deterministic config mistake fails the sweep fast;
/// - divergence (typed, or a finite ppl past the 1e6 bar) slots as a
///   `diverged` point immediately — replaying deterministic math
///   cannot help;
/// - a panic inside the trial job (including panics the pool re-raises
///   from nested batches) or an Io/Engine error after construction is
///   retried with a fresh `Trainer` up to `retries` times, then
///   slotted as `faulted` rather than failing the whole batch.
fn run_trial(engine: &Engine, opts: TrainOptions, retries: usize) -> anyhow::Result<SweepPoint> {
    use std::panic::{self, AssertUnwindSafe};
    let (optimizer, lr, seed) = (opts.optimizer.clone(), opts.base_lr, opts.seed);
    let point = |ppl: f64, ema: f64, outcome: TrialOutcome, attempts: u32| SweepPoint {
        optimizer: optimizer.clone(),
        lr,
        seed,
        ppl,
        final_loss_ema: ema,
        diverged: outcome == TrialOutcome::Diverged,
        outcome,
        attempts,
    };
    let mut attempts: u32 = 0;
    loop {
        attempts += 1;
        // AssertUnwindSafe: on panic the Trainer and everything it
        // borrows are dropped inside the closure — nothing partially
        // mutated crosses back over the unwind boundary
        type Finished = (Result<f64, TrainError>, Option<f64>);
        let attempt = panic::catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<Finished> {
            if crate::fault::fires("trial_panic") {
                panic!("failpoint trial_panic");
            }
            let mut tr = Trainer::new(engine, opts.clone())?;
            let r = tr.train();
            Ok((r, tr.metrics.ema_loss))
        }));
        match attempt {
            // construction failed deterministically: fail the sweep fast
            Ok(Err(e)) => return Err(e),
            Ok(Ok((Ok(p), ema))) => {
                let ppl = if p.is_finite() { p } else { f64::INFINITY };
                let ema = match ema {
                    Some(e) if e.is_finite() => e,
                    _ => f64::INFINITY,
                };
                let outcome = if !ppl.is_finite() || ppl > 1e6 {
                    TrialOutcome::Diverged
                } else if attempts > 1 {
                    TrialOutcome::Retried
                } else {
                    TrialOutcome::Ok
                };
                return Ok(point(ppl, ema, outcome, attempts));
            }
            Ok(Ok((Err(TrainError::Divergence { .. }), _))) => {
                let o = TrialOutcome::Diverged;
                return Ok(point(f64::INFINITY, f64::INFINITY, o, attempts));
            }
            // transient — retry with a fresh Trainer, then slot
            Ok(Ok((Err(_), _))) | Err(_) => {
                if attempts > retries as u32 {
                    let o = TrialOutcome::Faulted;
                    return Ok(point(f64::INFINITY, f64::INFINITY, o, attempts));
                }
            }
        }
    }
}

/// Train `base` once per learning rate (concurrently, on the shared
/// pool); returns one point per LR, in grid order.
pub fn lr_sweep(
    engine: &Engine,
    base: &TrainOptions,
    lrs: &[f64],
) -> anyhow::Result<Vec<SweepPoint>> {
    SweepSpec::lr_grid(base.clone(), lrs).run(engine)
}

/// The paper's App. C learning-rate grid.
pub fn paper_lr_grid() -> Vec<f64> {
    vec![5e-5, 1e-4, 3e-4, 5e-4, 1e-3, 3e-3, 5e-3]
}

/// `null` for non-finite values — JSON has no infinity; `diverged`
/// carries the flag in the report.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Seeds are u64 and f64 is exact only below 2^53, so bigger seeds are
/// emitted as decimal strings — re-running a reported seed must
/// reproduce the trial that produced the numbers.
fn json_seed(seed: u64) -> Json {
    if seed < (1u64 << 53) {
        Json::num(seed as f64)
    } else {
        Json::str(&seed.to_string())
    }
}

/// Aggregated statistics for one `(optimizer, lr)` grid cell across its
/// seed axis. Non-finite trials (diverged/faulted) are excluded from
/// the moments; `n_effective` says how many survived. An all-diverged
/// cell carries `mean_ppl = f64::INFINITY` (emitted as JSON `null`)
/// with zero stddev/CI.
#[derive(Debug, Clone, PartialEq)]
pub struct CellStats {
    pub optimizer: String,
    pub lr: f64,
    /// Trials in the cell, diverged/faulted included.
    pub n_trials: usize,
    /// Trials with finite ppl — the sample size behind the moments.
    pub n_effective: usize,
    pub mean_ppl: f64,
    /// Sample standard deviation (n-1 denominator); 0 when fewer than
    /// two finite trials.
    pub stddev_ppl: f64,
    /// Normal-approximation 95% half-width: `1.96·stddev/√n_effective`.
    pub ci95_ppl: f64,
}

/// Welford accumulator — numerically stable single-pass moments with a
/// fixed accumulation order (push order == grid order).
struct Welford {
    n: usize,
    mean: f64,
    m2: f64,
}

impl Welford {
    fn new() -> Welford {
        Welford { n: 0, mean: 0.0, m2: 0.0 }
    }

    fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
}

/// Collapse index-slotted sweep points into per-`(optimizer, lr)` cell
/// statistics. Cells appear in first-appearance (grid) order and each
/// cell's Welford accumulation runs strictly in point order, so the
/// output is a pure function of the point list — bit-stable across
/// pool sizes and `max_concurrent` caps because `run`/`run_on` slot
/// points by trial index before any aggregation happens.
pub fn aggregate_cells(points: &[SweepPoint]) -> Vec<CellStats> {
    let mut keys: Vec<(String, u64)> = Vec::new();
    let mut trials: Vec<usize> = Vec::new();
    let mut accs: Vec<Welford> = Vec::new();
    for p in points {
        let key = (p.optimizer.clone(), p.lr.to_bits());
        let i = match keys.iter().position(|k| *k == key) {
            Some(i) => i,
            None => {
                keys.push(key);
                trials.push(0);
                accs.push(Welford::new());
                keys.len() - 1
            }
        };
        trials[i] += 1;
        if p.ppl.is_finite() {
            accs[i].push(p.ppl);
        }
    }
    keys.into_iter()
        .zip(trials)
        .zip(accs)
        .map(|(((optimizer, lr_bits), n_trials), w)| {
            let mean_ppl = if w.n == 0 { f64::INFINITY } else { w.mean };
            let stddev_ppl = if w.n >= 2 { (w.m2 / (w.n - 1) as f64).sqrt() } else { 0.0 };
            let ci95_ppl =
                if w.n >= 2 { 1.96 * stddev_ppl / (w.n as f64).sqrt() } else { 0.0 };
            CellStats {
                optimizer,
                lr: f64::from_bits(lr_bits),
                n_trials,
                n_effective: w.n,
                mean_ppl,
                stddev_ppl,
                ci95_ppl,
            }
        })
        .collect()
}

/// How to turn aggregated cells into an optimizer ranking.
#[derive(Debug, Clone, Copy, Default)]
pub struct VerdictSpec {
    /// Optimizer-state byte budget; optimizers over it still rank, but
    /// after every within-budget one. `None` = unbounded.
    pub memory_budget: Option<usize>,
}

/// One optimizer's verdict: its best cell plus the memory facts the
/// ranking used.
#[derive(Debug, Clone, PartialEq)]
pub struct OptimizerVerdict {
    pub optimizer: String,
    /// The cell with the lowest mean ppl (first such cell in grid order
    /// on ties — deterministic).
    pub best: CellStats,
    /// Measured optimizer-state bytes (`memory::estimator` semantics,
    /// supplied by the caller).
    pub state_bytes: usize,
    pub within_budget: bool,
}

/// The full verdict: every cell, plus the optimizer ranking.
#[derive(Debug, Clone, PartialEq)]
pub struct Verdict {
    pub cells: Vec<CellStats>,
    /// Sorted: within-budget first, then mean ppl ascending
    /// (`total_cmp` — all-diverged optimizers sink to the bottom of
    /// their budget class), then state bytes, then name.
    pub ranking: Vec<OptimizerVerdict>,
}

impl VerdictSpec {
    /// Aggregate `points` and rank the optimizers. `state_bytes_for`
    /// supplies measured optimizer-state bytes per optimizer name — the
    /// CLI wires `memory::estimator::measured_state_bytes`, tests wire
    /// fixtures. Deterministic: the ranking is a pure function of the
    /// point list, the byte map, and the budget.
    pub fn verdict(
        &self,
        points: &[SweepPoint],
        state_bytes_for: impl Fn(&str) -> anyhow::Result<usize>,
    ) -> anyhow::Result<Verdict> {
        let cells = aggregate_cells(points);
        let mut ranking: Vec<OptimizerVerdict> = Vec::new();
        for c in &cells {
            match ranking.iter_mut().find(|r| r.optimizer == c.optimizer) {
                Some(r) => {
                    if c.mean_ppl < r.best.mean_ppl {
                        r.best = c.clone();
                    }
                }
                None => {
                    let state_bytes = state_bytes_for(&c.optimizer)?;
                    ranking.push(OptimizerVerdict {
                        optimizer: c.optimizer.clone(),
                        best: c.clone(),
                        state_bytes,
                        within_budget: self.memory_budget.is_none_or(|b| state_bytes <= b),
                    });
                }
            }
        }
        ranking.sort_by(|a, b| {
            b.within_budget
                .cmp(&a.within_budget)
                .then(a.best.mean_ppl.total_cmp(&b.best.mean_ppl))
                .then(a.state_bytes.cmp(&b.state_bytes))
                .then(a.optimizer.cmp(&b.optimizer))
        });
        Ok(Verdict { cells, ranking })
    }
}

fn cell_json(c: &CellStats) -> Json {
    Json::obj(vec![
        ("optimizer", Json::str(&c.optimizer)),
        ("lr", num_or_null(c.lr)),
        ("n_trials", Json::num(c.n_trials as f64)),
        ("n_effective", Json::num(c.n_effective as f64)),
        ("mean_ppl", num_or_null(c.mean_ppl)),
        ("stddev_ppl", num_or_null(c.stddev_ppl)),
        ("ci95_ppl", num_or_null(c.ci95_ppl)),
    ])
}

/// Machine-readable compare report (`scale compare --json`).
pub fn compare_report_json(spec: &SweepSpec, vspec: &VerdictSpec, v: &Verdict) -> Json {
    let ranking: Vec<Json> = v
        .ranking
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("optimizer", Json::str(&r.optimizer)),
                ("state_bytes", Json::num(r.state_bytes as f64)),
                ("within_budget", Json::Bool(r.within_budget)),
                ("best", cell_json(&r.best)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("report", Json::str("compare")),
        ("size", Json::str(&spec.base.size)),
        ("steps", Json::num(spec.base.steps as f64)),
        ("budget_bytes", vspec.memory_budget.map_or(Json::Null, |b| Json::num(b as f64))),
        ("cells", Json::Arr(v.cells.iter().map(cell_json).collect())),
        ("ranking", Json::Arr(ranking)),
    ])
}

/// Machine-readable LR-sensitivity report (`scale lr-curve`): the
/// paper's Fig. 8 shape — one curve per optimizer, cells in LR grid
/// order, committed as a regenerable artifact under `docs/artifacts/`.
pub fn lr_curve_report_json(spec: &SweepSpec, cells: &[CellStats]) -> Json {
    let mut curves: Vec<(String, Vec<Json>)> = Vec::new();
    for c in cells {
        match curves.iter_mut().find(|(o, _)| *o == c.optimizer) {
            Some((_, pts)) => pts.push(cell_json(c)),
            None => curves.push((c.optimizer.clone(), vec![cell_json(c)])),
        }
    }
    let curves: Vec<Json> = curves
        .into_iter()
        .map(|(opt, pts)| {
            Json::obj(vec![("optimizer", Json::str(&opt)), ("points", Json::Arr(pts))])
        })
        .collect();
    Json::obj(vec![
        ("report", Json::str("lr_curve")),
        ("size", Json::str(&spec.base.size)),
        ("steps", Json::num(spec.base.steps as f64)),
        ("curves", Json::Arr(curves)),
    ])
}

/// Machine-readable sweep report (`scale sweep --json`).
pub fn report_json(spec: &SweepSpec, points: &[SweepPoint]) -> Json {
    let pts: Vec<Json> = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("optimizer", Json::str(&p.optimizer)),
                ("lr", num_or_null(p.lr)),
                ("seed", json_seed(p.seed)),
                ("ppl", num_or_null(p.ppl)),
                ("final_loss_ema", num_or_null(p.final_loss_ema)),
                ("diverged", Json::Bool(p.diverged)),
                ("outcome", Json::str(p.outcome.as_str())),
                ("attempts", Json::num(p.attempts as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("report", Json::str("sweep")),
        ("size", Json::str(&spec.base.size)),
        ("steps", Json::num(spec.base.steps as f64)),
        ("shards", Json::num(spec.base.shards.max(1) as f64)),
        ("trials", Json::num(points.len() as f64)),
        ("points", Json::Arr(pts)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_sorted_positive() {
        let g = paper_lr_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!(g.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn trial_order_is_optimizer_major_then_lr_then_seed() {
        let mut spec = SweepSpec::new(TrainOptions::default());
        spec.optimizers = vec!["scale".into(), "adam".into()];
        spec.lrs = vec![1e-3, 1e-2];
        spec.seeds = vec![0, 7];
        let ts = spec.trials();
        assert_eq!(ts.len(), 8);
        let key: Vec<(&str, f64, u64)> = ts
            .iter()
            .map(|t| (t.optimizer.as_str(), t.base_lr, t.seed))
            .collect();
        assert_eq!(key[0], ("scale", 1e-3, 0));
        assert_eq!(key[1], ("scale", 1e-3, 7));
        assert_eq!(key[2], ("scale", 1e-2, 0));
        assert_eq!(key[4], ("adam", 1e-3, 0));
        assert_eq!(key[7], ("adam", 1e-2, 7));
        assert!(ts.iter().all(|t| t.quiet && t.schedule.is_none()));
    }

    #[test]
    fn lr_for_resolves_per_optimizer_when_lr_axis_is_empty() {
        fn table_lr(opt: &str) -> f64 {
            if opt == "adam" { 2e-3 } else { 1e-2 }
        }
        let mut spec = SweepSpec::new(TrainOptions::default());
        spec.optimizers = vec!["scale".into(), "adam".into()];
        spec.lr_for = Some(table_lr);
        let ts = spec.trials();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].base_lr, 1e-2);
        assert_eq!(ts[1].base_lr, 2e-3);
        // an explicit LR axis wins over the resolver
        spec.lrs = vec![5e-4];
        let ts = spec.trials();
        assert!(ts.iter().all(|t| t.base_lr == 5e-4));
    }

    #[test]
    fn empty_axes_default_to_the_base_point() {
        let base = TrainOptions {
            optimizer: "muon".into(),
            base_lr: 0.5,
            seed: 9,
            ..TrainOptions::default()
        };
        let ts = SweepSpec::new(base).trials();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].optimizer, "muon");
        assert_eq!(ts[0].base_lr, 0.5);
        assert_eq!(ts[0].seed, 9);
    }

    #[test]
    fn report_json_guards_nonfinite_and_big_seeds() {
        let spec = SweepSpec::new(TrainOptions::default());
        let pts = vec![
            SweepPoint {
                optimizer: "scale".into(),
                lr: f64::INFINITY,
                seed: 0,
                ppl: f64::INFINITY,
                final_loss_ema: f64::INFINITY,
                diverged: true,
                outcome: TrialOutcome::Diverged,
                attempts: 1,
            },
            SweepPoint {
                optimizer: "adam".into(),
                lr: 1e-2,
                seed: 1 << 60,
                ppl: 2.0,
                final_loss_ema: 0.7,
                diverged: false,
                outcome: TrialOutcome::Retried,
                attempts: 2,
            },
        ];
        let text = report_json(&spec, &pts).to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("trials").unwrap().as_usize(), Some(2));
        let arr = back.get("points").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        // JSON has no infinity: non-finite lr/ppl/ema all become null
        assert_eq!(arr[0].get("lr").unwrap(), &Json::Null);
        assert_eq!(arr[0].get("ppl").unwrap(), &Json::Null);
        assert_eq!(arr[0].get("diverged").unwrap().as_bool(), Some(true));
        // seeds above 2^53 keep full precision as decimal strings
        assert_eq!(
            arr[1].get("seed").unwrap().as_str(),
            Some("1152921504606846976")
        );
        assert_eq!(arr[1].get("ppl").unwrap().as_f64(), Some(2.0));
        // typed outcomes ride along with the legacy diverged bool
        assert_eq!(arr[0].get("outcome").unwrap().as_str(), Some("diverged"));
        assert_eq!(arr[1].get("outcome").unwrap().as_str(), Some("retried"));
        assert_eq!(arr[1].get("attempts").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn outcome_strings_are_stable() {
        assert_eq!(TrialOutcome::Ok.as_str(), "ok");
        assert_eq!(TrialOutcome::Diverged.as_str(), "diverged");
        assert_eq!(TrialOutcome::Faulted.as_str(), "faulted");
        assert_eq!(TrialOutcome::Retried.as_str(), "retried");
    }

    // ---- verdict layer -----------------------------------------------

    fn pt(opt: &str, lr: f64, seed: u64, ppl: f64) -> SweepPoint {
        let diverged = !ppl.is_finite();
        SweepPoint {
            optimizer: opt.into(),
            lr,
            seed,
            ppl,
            final_loss_ema: ppl,
            diverged,
            outcome: if diverged { TrialOutcome::Diverged } else { TrialOutcome::Ok },
            attempts: 1,
        }
    }

    #[test]
    fn welford_matches_hand_computed_fixture() {
        // ppl {2, 4, 9}: mean 5, sample variance (9+1+16)/2 = 13 — all
        // exactly representable, so the assertions are exact
        let pts = [pt("scale", 1e-3, 0, 2.0), pt("scale", 1e-3, 1, 4.0), pt("scale", 1e-3, 2, 9.0)];
        let cells = aggregate_cells(&pts);
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!((c.n_trials, c.n_effective), (3, 3));
        assert_eq!(c.mean_ppl, 5.0);
        assert_eq!(c.stddev_ppl, 13f64.sqrt());
        assert_eq!(c.ci95_ppl, 1.96 * 13f64.sqrt() / 3f64.sqrt());
    }

    #[test]
    fn nonfinite_trials_are_excluded_with_explicit_n_effective() {
        // the diverged middle seed must not poison the moments: the cell
        // aggregates {2, 4} with mean 3, variance (1+1)/1 = 2
        let pts = [
            pt("scale", 1e-2, 0, 2.0),
            pt("scale", 1e-2, 1, f64::INFINITY),
            pt("scale", 1e-2, 2, 4.0),
        ];
        let cells = aggregate_cells(&pts);
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!((c.n_trials, c.n_effective), (3, 2));
        assert_eq!(c.mean_ppl, 3.0);
        assert_eq!(c.stddev_ppl, 2f64.sqrt());
        assert_eq!(c.ci95_ppl, 1.96 * 2f64.sqrt() / 2f64.sqrt());
    }

    #[test]
    fn all_diverged_cell_is_infinite_mean_and_json_null() {
        let pts = [pt("scale", 1e12, 0, f64::INFINITY), pt("scale", 1e12, 1, f64::INFINITY)];
        let cells = aggregate_cells(&pts);
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!((c.n_trials, c.n_effective), (2, 0));
        assert!(c.mean_ppl.is_infinite());
        assert_eq!((c.stddev_ppl, c.ci95_ppl), (0.0, 0.0));
        // and the JSON guard: infinite mean becomes null, counts survive
        let j = cell_json(c);
        assert_eq!(j.get("mean_ppl").unwrap(), &Json::Null);
        assert_eq!(j.get("n_effective").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("n_trials").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn single_and_zero_sample_cells_have_zero_spread() {
        let pts = [pt("adam", 1e-3, 0, 7.0)];
        let c = &aggregate_cells(&pts)[0];
        assert_eq!((c.n_effective, c.mean_ppl), (1, 7.0));
        assert_eq!((c.stddev_ppl, c.ci95_ppl), (0.0, 0.0));
    }

    #[test]
    fn cells_keep_grid_order_and_split_on_lr_bits() {
        let pts = [
            pt("scale", 1e-3, 0, 2.0),
            pt("scale", 1e-3, 1, 2.5),
            pt("scale", 1e-2, 0, 3.0),
            pt("adam", 1e-3, 0, 4.0),
        ];
        let cells = aggregate_cells(&pts);
        let keys: Vec<(&str, f64)> = cells.iter().map(|c| (c.optimizer.as_str(), c.lr)).collect();
        assert_eq!(keys, vec![("scale", 1e-3), ("scale", 1e-2), ("adam", 1e-3)]);
        assert_eq!(cells[0].n_effective, 2);
    }

    #[test]
    fn welford_tracks_two_pass_reference_on_random_cells() {
        // property check against the naive two-pass mean/stddev
        use crate::util::prop::{self, ensure};
        prop::quick("welford-two-pass", |rng| {
            let n = prop::usize_in(rng, 1, 12);
            let ppls: Vec<f64> =
                (0..n).map(|_| prop::f32_in(rng, 1.0, 100.0) as f64).collect();
            let pts: Vec<SweepPoint> =
                ppls.iter().enumerate().map(|(i, &p)| pt("scale", 1e-3, i as u64, p)).collect();
            let c = &aggregate_cells(&pts)[0];
            let mean = ppls.iter().sum::<f64>() / n as f64;
            ensure((c.mean_ppl - mean).abs() < 1e-9 * mean.abs().max(1.0), "mean drift")?;
            if n >= 2 {
                let var = ppls.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
                    / (n - 1) as f64;
                ensure(
                    (c.stddev_ppl - var.sqrt()).abs() < 1e-7,
                    format!("stddev {} vs {}", c.stddev_ppl, var.sqrt()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn verdict_ranks_within_budget_first_then_mean_ppl() {
        // adam wins on ppl but busts the budget; scale leads the
        // within-budget class; an all-diverged optimizer sinks last
        let pts = [
            pt("adam", 1e-3, 0, 2.0),
            pt("adam", 1e-3, 1, 2.2),
            pt("scale", 1e-2, 0, 2.5),
            pt("scale", 1e-2, 1, 2.7),
            pt("scale", 1e-1, 0, 9.0),
            pt("scale", 1e-1, 1, 9.5),
            pt("sgd", 1e-2, 0, f64::INFINITY),
            pt("sgd", 1e-2, 1, f64::INFINITY),
        ];
        let bytes = |opt: &str| -> anyhow::Result<usize> {
            Ok(match opt {
                "adam" => 100,
                "scale" => 40,
                _ => 0,
            })
        };
        let spec = VerdictSpec { memory_budget: Some(50) };
        let v = spec.verdict(&pts, bytes).unwrap();
        let order: Vec<&str> = v.ranking.iter().map(|r| r.optimizer.as_str()).collect();
        assert_eq!(order, vec!["scale", "sgd", "adam"]);
        assert_eq!(v.ranking[0].best.mean_ppl, 2.6);
        assert_eq!(v.ranking[0].best.lr, 1e-2, "best cell must be the low-LR one");
        assert!(v.ranking[0].within_budget && v.ranking[1].within_budget);
        assert!(!v.ranking[2].within_budget);
        assert_eq!(v.ranking[2].state_bytes, 100);
        // no budget: pure ppl order, diverged last via total_cmp
        let v = VerdictSpec::default().verdict(&pts, bytes).unwrap();
        let order: Vec<&str> = v.ranking.iter().map(|r| r.optimizer.as_str()).collect();
        assert_eq!(order, vec!["adam", "scale", "sgd"]);
        assert!(v.ranking.iter().all(|r| r.within_budget));
        // the report round-trips through the JSON layer
        let sweep = SweepSpec::new(TrainOptions::default());
        let text = compare_report_json(&sweep, &VerdictSpec::default(), &v).to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("report").unwrap().as_str(), Some("compare"));
        assert_eq!(back.get("budget_bytes").unwrap(), &Json::Null);
        let rank = back.get("ranking").unwrap().as_arr().unwrap();
        assert_eq!(rank.len(), 3);
        assert_eq!(rank[0].get("optimizer").unwrap().as_str(), Some("adam"));
        assert_eq!(rank[0].get("state_bytes").unwrap().as_usize(), Some(100));
        let best = rank[2].get("best").unwrap();
        assert_eq!(best.get("mean_ppl").unwrap(), &Json::Null, "diverged best is null");
        assert_eq!(best.get("n_effective").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn lr_curve_report_groups_cells_per_optimizer_in_lr_order() {
        let pts = [
            pt("scale", 1e-3, 0, 2.0),
            pt("scale", 1e-2, 0, 3.0),
            pt("adam", 1e-3, 0, 4.0),
            pt("adam", 1e-2, 0, f64::INFINITY),
        ];
        let spec = SweepSpec::new(TrainOptions::default());
        let cells = aggregate_cells(&pts);
        let text = lr_curve_report_json(&spec, &cells).to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("report").unwrap().as_str(), Some("lr_curve"));
        let curves = back.get("curves").unwrap().as_arr().unwrap();
        assert_eq!(curves.len(), 2);
        assert_eq!(curves[0].get("optimizer").unwrap().as_str(), Some("scale"));
        let pts0 = curves[0].get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts0.len(), 2);
        assert_eq!(pts0[0].get("lr").unwrap().as_f64(), Some(1e-3));
        assert_eq!(pts0[1].get("mean_ppl").unwrap().as_f64(), Some(3.0));
        let pts1 = curves[1].get("points").unwrap().as_arr().unwrap();
        assert_eq!(pts1[1].get("mean_ppl").unwrap(), &Json::Null);
    }
}
