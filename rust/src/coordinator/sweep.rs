//! Sweep driver: run the trainer across a hyperparameter grid,
//! concurrently.
//!
//! Backs the paper's wandb sweeps (App. C), the LR-sensitivity study
//! (Fig. 8), and optimizer face-offs like the Table-13 ablations. A
//! [`SweepSpec`] is a grid of composable axes (optimizer × learning
//! rate × seed) over one base [`TrainOptions`]; every grid point is an
//! independent deterministic run, so [`SweepSpec::run`] dispatches the
//! trials as jobs on the process-wide shared [`WorkerPool`] and slots
//! results by trial index — the concurrent output is bit-identical to
//! the serial loop for every pool size. [`SweepSpec::run_serial`] is the
//! kept sequential reference; the differential suite in
//! `rust/tests/sweep_differential.rs` pins the equivalence, including
//! the `ppl = inf` slotting of diverged trials.
//!
//! # Why concurrent trials are bit-identical
//!
//! A trial is a pure function of its `TrainOptions`: each builds its own
//! [`Trainer`] (own params, state, token rings, persistent buffers) over
//! the shared `Engine`, whose per-program workspaces are scratch that
//! every execution fully overwrites before reading, and the data
//! pipeline cache is keyed by `(vocab, seed)` with deterministic
//! content. Scheduling therefore cannot reach any computed number.
//! Trial jobs fan their intra-trial work (shard fwd/bwd, tree reduce,
//! tiled kernels, GEMM blocks) out as *nested* batches on the same
//! pool; the batch-tagged queue makes that composition deadlock-free
//! (see [`crate::parallel`]). No sweep path ever spawns a thread — the
//! trials ride the pool every `Trainer` already uses.
//!
//! # Fault handling
//!
//! Trial failures are classified ([`TrialOutcome`]), not string-matched:
//! a deterministic divergence slots as a `diverged` point immediately,
//! while transient faults — a panic inside the trial job (including
//! panics re-raised from nested pool batches) or an Io/Engine error
//! after construction — are retried with a fresh `Trainer` up to
//! [`SweepSpec::retries`] times and then slotted as `faulted` instead of
//! aborting the batch. Construction errors (unknown optimizer/size)
//! still fail fast. Every trial runs inside
//! `fault::scoped("trial{i}", ..)`, so an injected fault spec like
//! `trial2/trial_panic@1` targets the same grid point at every pool
//! size — the chaos suite pins retried-sweep reports bit-identical to
//! fault-free ones.

use crate::coordinator::recovery::TrainError;
use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::parallel::{self, WorkerPool};
use crate::runtime::Engine;
use crate::util::json::Json;

/// Typed classification of how a trial concluded, surfaced in
/// [`report_json`] as `outcome` (the `diverged` bool stays for
/// compatibility).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialOutcome {
    /// Clean first-attempt finish.
    Ok,
    /// Deterministic divergence: typed [`TrainError::Divergence`] or a
    /// final ppl past the 1e6 bar. Never retried — same seed, same math.
    Diverged,
    /// Transient faults exhausted the retry budget; slotted, not fatal.
    Faulted,
    /// Finished clean after at least one retry.
    Retried,
}

impl TrialOutcome {
    pub fn as_str(self) -> &'static str {
        match self {
            TrialOutcome::Ok => "ok",
            TrialOutcome::Diverged => "diverged",
            TrialOutcome::Faulted => "faulted",
            TrialOutcome::Retried => "retried",
        }
    }
}

/// One finished trial. `ppl` and `final_loss_ema` are `f64::INFINITY`
/// when the run diverged (non-finite loss or past the divergence bar)
/// or faulted past its retry budget.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub optimizer: String,
    pub lr: f64,
    pub seed: u64,
    pub ppl: f64,
    pub final_loss_ema: f64,
    pub diverged: bool,
    pub outcome: TrialOutcome,
    /// Attempts consumed (1 = no retry was needed).
    pub attempts: u32,
}

/// A multi-trial grid over one base configuration. Axes compose: the
/// trial list is the cartesian product, optimizer-major, then LR, then
/// seed. An empty axis means "just the base value" — so a plain LR
/// sweep, an optimizer face-off, and a seed-replication study are all
/// the same engine.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Template for every trial. Per-axis fields are overridden per
    /// trial; `schedule` is reset to `None` (fresh cosine at each peak)
    /// and `quiet` is forced (concurrent trials must not interleave
    /// logging).
    pub base: TrainOptions,
    /// Peak learning rates; empty -> one LR per trial, resolved from
    /// `lr_for` (when set) or `base.base_lr`.
    pub lrs: Vec<f64>,
    /// Optimizer names; empty -> just `base.optimizer`.
    pub optimizers: Vec<String>,
    /// Data/init seeds; empty -> just `base.seed`.
    pub seeds: Vec<u64>,
    /// Per-optimizer peak-LR resolver, consulted only when `lrs` is
    /// empty: an optimizer face-off then gives every optimizer its own
    /// tuned default instead of one shared LR (the Table-13 semantics;
    /// the CLI wires `harness::default_lr` here). `None` -> every
    /// trial uses `base.base_lr`.
    pub lr_for: Option<fn(&str) -> f64>,
    /// Upper bound on trials in flight at once (`0` = unbounded). Caps
    /// peak memory — every in-flight trial holds a full `Trainer` — at
    /// the cost of a wave barrier per chunk. Never affects results:
    /// chunking only changes scheduling, and results stay slotted by
    /// trial index.
    pub max_concurrent: usize,
    /// Retry budget per trial for transient faults (panics, Io/Engine
    /// errors after construction). `0` = fault once, slot as `faulted`.
    /// Divergence is never retried.
    pub retries: usize,
}

impl SweepSpec {
    pub fn new(base: TrainOptions) -> SweepSpec {
        SweepSpec {
            base,
            lrs: Vec::new(),
            optimizers: Vec::new(),
            seeds: Vec::new(),
            lr_for: None,
            max_concurrent: 0,
            retries: 0,
        }
    }

    /// The Fig. 8 / App. C shape: one optimizer, a grid of peak LRs.
    pub fn lr_grid(base: TrainOptions, lrs: &[f64]) -> SweepSpec {
        SweepSpec {
            lrs: lrs.to_vec(),
            ..SweepSpec::new(base)
        }
    }

    /// The Table-13 shape: one LR, a grid of optimizers.
    pub fn optimizer_grid(base: TrainOptions, optimizers: &[&str]) -> SweepSpec {
        SweepSpec {
            optimizers: optimizers.iter().map(|s| s.to_string()).collect(),
            ..SweepSpec::new(base)
        }
    }

    /// Trial options in canonical order (optimizer-major, then LR, then
    /// seed) — the order `run`, `run_on`, and `run_serial` all emit.
    pub fn trials(&self) -> Vec<TrainOptions> {
        let opt_axis: Vec<String> = if self.optimizers.is_empty() {
            vec![self.base.optimizer.clone()]
        } else {
            self.optimizers.clone()
        };
        let seed_axis: Vec<u64> = if self.seeds.is_empty() {
            vec![self.base.seed]
        } else {
            self.seeds.clone()
        };
        let n_lrs = self.lrs.len().max(1);
        let mut out = Vec::with_capacity(opt_axis.len() * n_lrs * seed_axis.len());
        for opt in &opt_axis {
            let lr_axis: Vec<f64> = if !self.lrs.is_empty() {
                self.lrs.clone()
            } else if let Some(f) = self.lr_for {
                vec![f(opt)]
            } else {
                vec![self.base.base_lr]
            };
            for &lr in &lr_axis {
                for &seed in &seed_axis {
                    let mut t = self.base.clone();
                    t.optimizer = opt.clone();
                    t.base_lr = lr;
                    t.seed = seed;
                    t.schedule = None; // rebuild the cosine schedule at this peak
                    t.quiet = true;
                    out.push(t);
                }
            }
        }
        out
    }

    /// Run every trial concurrently on the process-wide shared pool —
    /// the production entry point (zero thread spawns).
    pub fn run(&self, engine: &Engine) -> anyhow::Result<Vec<SweepPoint>> {
        self.run_on(engine, parallel::shared())
    }

    /// Run every trial as one job on `pool`, results slotted by trial
    /// index — bit-identical to [`run_serial`](Self::run_serial) for
    /// every pool size and every `max_concurrent` (a zero-worker pool
    /// degenerates to the inline loop). On a trial error the in-flight
    /// wave still runs to completion (the pool contract) but later
    /// waves are skipped, and the lowest-indexed error is returned.
    ///
    /// Peak memory: a queued trial holds only its `TrainOptions` — the
    /// `Trainer` is built inside the job — so at most
    /// `min(trials, pool lanes, max_concurrent)` full trainers are ever
    /// resident at once. Lower `max_concurrent` to trade wall-clock for
    /// a smaller bound.
    pub fn run_on(&self, engine: &Engine, pool: &WorkerPool) -> anyhow::Result<Vec<SweepPoint>> {
        let retries = self.retries;
        // the scope is keyed by the absolute grid index (not the wave
        // position), so `trial{i}/...` fault specs target the same grid
        // point for every pool size and every max_concurrent
        let mut queue: Vec<_> = self
            .trials()
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                move || crate::fault::scoped(&format!("trial{i}"), || run_trial(engine, t, retries))
            })
            .collect();
        let cap = if self.max_concurrent == 0 {
            queue.len()
        } else {
            self.max_concurrent
        };
        let mut results = Vec::with_capacity(queue.len());
        while !queue.is_empty() {
            let rest = queue.split_off(queue.len().min(cap));
            let wave = pool.run(queue);
            let failed = wave.iter().any(|r| r.is_err());
            results.extend(wave);
            if failed {
                break; // fail fast: don't train the remaining waves
            }
            queue = rest;
        }
        results.into_iter().collect()
    }

    /// The sequential reference loop the differential tests compare
    /// against. One behavioral difference from `run_on`: this stops at
    /// the first trial error instead of completing the batch (the
    /// returned value is identical either way).
    pub fn run_serial(&self, engine: &Engine) -> anyhow::Result<Vec<SweepPoint>> {
        let mut out = Vec::new();
        for (i, t) in self.trials().into_iter().enumerate() {
            let pt = crate::fault::scoped(&format!("trial{i}"), || {
                run_trial(engine, t, self.retries)
            })?;
            out.push(pt);
        }
        Ok(out)
    }
}

/// Train one grid point to completion, with bounded retries for
/// transient faults:
///
/// - construction failure (unknown optimizer/size) propagates — a
///   deterministic config mistake fails the sweep fast;
/// - divergence (typed, or a finite ppl past the 1e6 bar) slots as a
///   `diverged` point immediately — replaying deterministic math
///   cannot help;
/// - a panic inside the trial job (including panics the pool re-raises
///   from nested batches) or an Io/Engine error after construction is
///   retried with a fresh `Trainer` up to `retries` times, then
///   slotted as `faulted` rather than failing the whole batch.
fn run_trial(engine: &Engine, opts: TrainOptions, retries: usize) -> anyhow::Result<SweepPoint> {
    use std::panic::{self, AssertUnwindSafe};
    let (optimizer, lr, seed) = (opts.optimizer.clone(), opts.base_lr, opts.seed);
    let point = |ppl: f64, ema: f64, outcome: TrialOutcome, attempts: u32| SweepPoint {
        optimizer: optimizer.clone(),
        lr,
        seed,
        ppl,
        final_loss_ema: ema,
        diverged: outcome == TrialOutcome::Diverged,
        outcome,
        attempts,
    };
    let mut attempts: u32 = 0;
    loop {
        attempts += 1;
        // AssertUnwindSafe: on panic the Trainer and everything it
        // borrows are dropped inside the closure — nothing partially
        // mutated crosses back over the unwind boundary
        type Finished = (Result<f64, TrainError>, Option<f64>);
        let attempt = panic::catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<Finished> {
            if crate::fault::fires("trial_panic") {
                panic!("failpoint trial_panic");
            }
            let mut tr = Trainer::new(engine, opts.clone())?;
            let r = tr.train();
            Ok((r, tr.metrics.ema_loss))
        }));
        match attempt {
            // construction failed deterministically: fail the sweep fast
            Ok(Err(e)) => return Err(e),
            Ok(Ok((Ok(p), ema))) => {
                let ppl = if p.is_finite() { p } else { f64::INFINITY };
                let ema = match ema {
                    Some(e) if e.is_finite() => e,
                    _ => f64::INFINITY,
                };
                let outcome = if !ppl.is_finite() || ppl > 1e6 {
                    TrialOutcome::Diverged
                } else if attempts > 1 {
                    TrialOutcome::Retried
                } else {
                    TrialOutcome::Ok
                };
                return Ok(point(ppl, ema, outcome, attempts));
            }
            Ok(Ok((Err(TrainError::Divergence { .. }), _))) => {
                let o = TrialOutcome::Diverged;
                return Ok(point(f64::INFINITY, f64::INFINITY, o, attempts));
            }
            // transient — retry with a fresh Trainer, then slot
            Ok(Ok((Err(_), _))) | Err(_) => {
                if attempts > retries as u32 {
                    let o = TrialOutcome::Faulted;
                    return Ok(point(f64::INFINITY, f64::INFINITY, o, attempts));
                }
            }
        }
    }
}

/// Train `base` once per learning rate (concurrently, on the shared
/// pool); returns one point per LR, in grid order.
pub fn lr_sweep(
    engine: &Engine,
    base: &TrainOptions,
    lrs: &[f64],
) -> anyhow::Result<Vec<SweepPoint>> {
    SweepSpec::lr_grid(base.clone(), lrs).run(engine)
}

/// The paper's App. C learning-rate grid.
pub fn paper_lr_grid() -> Vec<f64> {
    vec![5e-5, 1e-4, 3e-4, 5e-4, 1e-3, 3e-3, 5e-3]
}

/// `null` for non-finite values — JSON has no infinity; `diverged`
/// carries the flag in the report.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Seeds are u64 and f64 is exact only below 2^53, so bigger seeds are
/// emitted as decimal strings — re-running a reported seed must
/// reproduce the trial that produced the numbers.
fn json_seed(seed: u64) -> Json {
    if seed < (1u64 << 53) {
        Json::num(seed as f64)
    } else {
        Json::str(&seed.to_string())
    }
}

/// Machine-readable sweep report (`scale sweep --json`).
pub fn report_json(spec: &SweepSpec, points: &[SweepPoint]) -> Json {
    let pts: Vec<Json> = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("optimizer", Json::str(&p.optimizer)),
                ("lr", num_or_null(p.lr)),
                ("seed", json_seed(p.seed)),
                ("ppl", num_or_null(p.ppl)),
                ("final_loss_ema", num_or_null(p.final_loss_ema)),
                ("diverged", Json::Bool(p.diverged)),
                ("outcome", Json::str(p.outcome.as_str())),
                ("attempts", Json::num(p.attempts as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("report", Json::str("sweep")),
        ("size", Json::str(&spec.base.size)),
        ("steps", Json::num(spec.base.steps as f64)),
        ("shards", Json::num(spec.base.shards.max(1) as f64)),
        ("trials", Json::num(points.len() as f64)),
        ("points", Json::Arr(pts)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_sorted_positive() {
        let g = paper_lr_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!(g.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn trial_order_is_optimizer_major_then_lr_then_seed() {
        let mut spec = SweepSpec::new(TrainOptions::default());
        spec.optimizers = vec!["scale".into(), "adam".into()];
        spec.lrs = vec![1e-3, 1e-2];
        spec.seeds = vec![0, 7];
        let ts = spec.trials();
        assert_eq!(ts.len(), 8);
        let key: Vec<(&str, f64, u64)> = ts
            .iter()
            .map(|t| (t.optimizer.as_str(), t.base_lr, t.seed))
            .collect();
        assert_eq!(key[0], ("scale", 1e-3, 0));
        assert_eq!(key[1], ("scale", 1e-3, 7));
        assert_eq!(key[2], ("scale", 1e-2, 0));
        assert_eq!(key[4], ("adam", 1e-3, 0));
        assert_eq!(key[7], ("adam", 1e-2, 7));
        assert!(ts.iter().all(|t| t.quiet && t.schedule.is_none()));
    }

    #[test]
    fn lr_for_resolves_per_optimizer_when_lr_axis_is_empty() {
        fn table_lr(opt: &str) -> f64 {
            if opt == "adam" { 2e-3 } else { 1e-2 }
        }
        let mut spec = SweepSpec::new(TrainOptions::default());
        spec.optimizers = vec!["scale".into(), "adam".into()];
        spec.lr_for = Some(table_lr);
        let ts = spec.trials();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].base_lr, 1e-2);
        assert_eq!(ts[1].base_lr, 2e-3);
        // an explicit LR axis wins over the resolver
        spec.lrs = vec![5e-4];
        let ts = spec.trials();
        assert!(ts.iter().all(|t| t.base_lr == 5e-4));
    }

    #[test]
    fn empty_axes_default_to_the_base_point() {
        let base = TrainOptions {
            optimizer: "muon".into(),
            base_lr: 0.5,
            seed: 9,
            ..TrainOptions::default()
        };
        let ts = SweepSpec::new(base).trials();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].optimizer, "muon");
        assert_eq!(ts[0].base_lr, 0.5);
        assert_eq!(ts[0].seed, 9);
    }

    #[test]
    fn report_json_guards_nonfinite_and_big_seeds() {
        let spec = SweepSpec::new(TrainOptions::default());
        let pts = vec![
            SweepPoint {
                optimizer: "scale".into(),
                lr: f64::INFINITY,
                seed: 0,
                ppl: f64::INFINITY,
                final_loss_ema: f64::INFINITY,
                diverged: true,
                outcome: TrialOutcome::Diverged,
                attempts: 1,
            },
            SweepPoint {
                optimizer: "adam".into(),
                lr: 1e-2,
                seed: 1 << 60,
                ppl: 2.0,
                final_loss_ema: 0.7,
                diverged: false,
                outcome: TrialOutcome::Retried,
                attempts: 2,
            },
        ];
        let text = report_json(&spec, &pts).to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("trials").unwrap().as_usize(), Some(2));
        let arr = back.get("points").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        // JSON has no infinity: non-finite lr/ppl/ema all become null
        assert_eq!(arr[0].get("lr").unwrap(), &Json::Null);
        assert_eq!(arr[0].get("ppl").unwrap(), &Json::Null);
        assert_eq!(arr[0].get("diverged").unwrap().as_bool(), Some(true));
        // seeds above 2^53 keep full precision as decimal strings
        assert_eq!(
            arr[1].get("seed").unwrap().as_str(),
            Some("1152921504606846976")
        );
        assert_eq!(arr[1].get("ppl").unwrap().as_f64(), Some(2.0));
        // typed outcomes ride along with the legacy diverged bool
        assert_eq!(arr[0].get("outcome").unwrap().as_str(), Some("diverged"));
        assert_eq!(arr[1].get("outcome").unwrap().as_str(), Some("retried"));
        assert_eq!(arr[1].get("attempts").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn outcome_strings_are_stable() {
        assert_eq!(TrialOutcome::Ok.as_str(), "ok");
        assert_eq!(TrialOutcome::Diverged.as_str(), "diverged");
        assert_eq!(TrialOutcome::Faulted.as_str(), "faulted");
        assert_eq!(TrialOutcome::Retried.as_str(), "retried");
    }
}
