//! Sweep driver: run the trainer across a hyperparameter grid.
//!
//! Backs the paper's wandb sweeps (App. C) and the LR-sensitivity study
//! (Fig. 8). Each point is an independent deterministic run.

use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::runtime::Engine;

#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub lr: f64,
    pub ppl: f64,
    pub final_loss_ema: f64,
    pub diverged: bool,
}

/// Train `base` once per learning rate; returns one point per LR.
pub fn lr_sweep(
    engine: &Engine,
    base: &TrainOptions,
    lrs: &[f64],
) -> anyhow::Result<Vec<SweepPoint>> {
    let mut out = Vec::with_capacity(lrs.len());
    for &lr in lrs {
        let mut opts = base.clone();
        opts.base_lr = lr;
        opts.schedule = None; // rebuild the cosine schedule at this peak
        opts.quiet = true;
        let mut tr = Trainer::new(engine, opts)?;
        let ppl = match tr.train() {
            Ok(p) if p.is_finite() => p,
            _ => f64::INFINITY,
        };
        let ema = tr.metrics.ema_loss.unwrap_or(f64::INFINITY);
        out.push(SweepPoint {
            lr,
            ppl,
            final_loss_ema: ema,
            diverged: !ppl.is_finite() || ppl > 1e6,
        });
    }
    Ok(out)
}

/// The paper's App. C learning-rate grid.
pub fn paper_lr_grid() -> Vec<f64> {
    vec![5e-5, 1e-4, 3e-4, 5e-4, 1e-3, 3e-3, 5e-3]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_sorted_positive() {
        let g = paper_lr_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!(g.iter().all(|&x| x > 0.0));
    }
}
