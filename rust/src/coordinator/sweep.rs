//! Sweep driver: run the trainer across a hyperparameter grid,
//! concurrently.
//!
//! Backs the paper's wandb sweeps (App. C), the LR-sensitivity study
//! (Fig. 8), and optimizer face-offs like the Table-13 ablations. A
//! [`SweepSpec`] is a grid of composable axes (optimizer × learning
//! rate × seed) over one base [`TrainOptions`]; every grid point is an
//! independent deterministic run, so [`SweepSpec::run`] dispatches the
//! trials as jobs on the process-wide shared [`WorkerPool`] and slots
//! results by trial index — the concurrent output is bit-identical to
//! the serial loop for every pool size. [`SweepSpec::run_serial`] is the
//! kept sequential reference; the differential suite in
//! `rust/tests/sweep_differential.rs` pins the equivalence, including
//! the `ppl = inf` slotting of diverged trials.
//!
//! # Why concurrent trials are bit-identical
//!
//! A trial is a pure function of its `TrainOptions`: each builds its own
//! [`Trainer`] (own params, state, token rings, persistent buffers) over
//! the shared `Engine`, whose per-program workspaces are scratch that
//! every execution fully overwrites before reading, and the data
//! pipeline cache is keyed by `(vocab, seed)` with deterministic
//! content. Scheduling therefore cannot reach any computed number.
//! Trial jobs fan their intra-trial work (shard fwd/bwd, tree reduce,
//! tiled kernels, GEMM blocks) out as *nested* batches on the same
//! pool; the batch-tagged queue makes that composition deadlock-free
//! (see [`crate::parallel`]). No sweep path ever spawns a thread — the
//! trials ride the pool every `Trainer` already uses.

use crate::coordinator::trainer::{TrainOptions, Trainer};
use crate::parallel::{self, WorkerPool};
use crate::runtime::Engine;
use crate::util::json::Json;

/// One finished trial. `ppl` and `final_loss_ema` are `f64::INFINITY`
/// when the run diverged (non-finite loss or past the divergence bar).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub optimizer: String,
    pub lr: f64,
    pub seed: u64,
    pub ppl: f64,
    pub final_loss_ema: f64,
    pub diverged: bool,
}

/// A multi-trial grid over one base configuration. Axes compose: the
/// trial list is the cartesian product, optimizer-major, then LR, then
/// seed. An empty axis means "just the base value" — so a plain LR
/// sweep, an optimizer face-off, and a seed-replication study are all
/// the same engine.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Template for every trial. Per-axis fields are overridden per
    /// trial; `schedule` is reset to `None` (fresh cosine at each peak)
    /// and `quiet` is forced (concurrent trials must not interleave
    /// logging).
    pub base: TrainOptions,
    /// Peak learning rates; empty -> one LR per trial, resolved from
    /// `lr_for` (when set) or `base.base_lr`.
    pub lrs: Vec<f64>,
    /// Optimizer names; empty -> just `base.optimizer`.
    pub optimizers: Vec<String>,
    /// Data/init seeds; empty -> just `base.seed`.
    pub seeds: Vec<u64>,
    /// Per-optimizer peak-LR resolver, consulted only when `lrs` is
    /// empty: an optimizer face-off then gives every optimizer its own
    /// tuned default instead of one shared LR (the Table-13 semantics;
    /// the CLI wires `harness::default_lr` here). `None` -> every
    /// trial uses `base.base_lr`.
    pub lr_for: Option<fn(&str) -> f64>,
    /// Upper bound on trials in flight at once (`0` = unbounded). Caps
    /// peak memory — every in-flight trial holds a full `Trainer` — at
    /// the cost of a wave barrier per chunk. Never affects results:
    /// chunking only changes scheduling, and results stay slotted by
    /// trial index.
    pub max_concurrent: usize,
}

impl SweepSpec {
    pub fn new(base: TrainOptions) -> SweepSpec {
        SweepSpec {
            base,
            lrs: Vec::new(),
            optimizers: Vec::new(),
            seeds: Vec::new(),
            lr_for: None,
            max_concurrent: 0,
        }
    }

    /// The Fig. 8 / App. C shape: one optimizer, a grid of peak LRs.
    pub fn lr_grid(base: TrainOptions, lrs: &[f64]) -> SweepSpec {
        SweepSpec {
            lrs: lrs.to_vec(),
            ..SweepSpec::new(base)
        }
    }

    /// The Table-13 shape: one LR, a grid of optimizers.
    pub fn optimizer_grid(base: TrainOptions, optimizers: &[&str]) -> SweepSpec {
        SweepSpec {
            optimizers: optimizers.iter().map(|s| s.to_string()).collect(),
            ..SweepSpec::new(base)
        }
    }

    /// Trial options in canonical order (optimizer-major, then LR, then
    /// seed) — the order `run`, `run_on`, and `run_serial` all emit.
    pub fn trials(&self) -> Vec<TrainOptions> {
        let opt_axis: Vec<String> = if self.optimizers.is_empty() {
            vec![self.base.optimizer.clone()]
        } else {
            self.optimizers.clone()
        };
        let seed_axis: Vec<u64> = if self.seeds.is_empty() {
            vec![self.base.seed]
        } else {
            self.seeds.clone()
        };
        let n_lrs = self.lrs.len().max(1);
        let mut out = Vec::with_capacity(opt_axis.len() * n_lrs * seed_axis.len());
        for opt in &opt_axis {
            let lr_axis: Vec<f64> = if !self.lrs.is_empty() {
                self.lrs.clone()
            } else if let Some(f) = self.lr_for {
                vec![f(opt)]
            } else {
                vec![self.base.base_lr]
            };
            for &lr in &lr_axis {
                for &seed in &seed_axis {
                    let mut t = self.base.clone();
                    t.optimizer = opt.clone();
                    t.base_lr = lr;
                    t.seed = seed;
                    t.schedule = None; // rebuild the cosine schedule at this peak
                    t.quiet = true;
                    out.push(t);
                }
            }
        }
        out
    }

    /// Run every trial concurrently on the process-wide shared pool —
    /// the production entry point (zero thread spawns).
    pub fn run(&self, engine: &Engine) -> anyhow::Result<Vec<SweepPoint>> {
        self.run_on(engine, parallel::shared())
    }

    /// Run every trial as one job on `pool`, results slotted by trial
    /// index — bit-identical to [`run_serial`](Self::run_serial) for
    /// every pool size and every `max_concurrent` (a zero-worker pool
    /// degenerates to the inline loop). On a trial error the in-flight
    /// wave still runs to completion (the pool contract) but later
    /// waves are skipped, and the lowest-indexed error is returned.
    ///
    /// Peak memory: a queued trial holds only its `TrainOptions` — the
    /// `Trainer` is built inside the job — so at most
    /// `min(trials, pool lanes, max_concurrent)` full trainers are ever
    /// resident at once. Lower `max_concurrent` to trade wall-clock for
    /// a smaller bound.
    pub fn run_on(&self, engine: &Engine, pool: &WorkerPool) -> anyhow::Result<Vec<SweepPoint>> {
        let mut queue: Vec<_> = self
            .trials()
            .into_iter()
            .map(|t| move || run_trial(engine, t))
            .collect();
        let cap = if self.max_concurrent == 0 {
            queue.len()
        } else {
            self.max_concurrent
        };
        let mut results = Vec::with_capacity(queue.len());
        while !queue.is_empty() {
            let rest = queue.split_off(queue.len().min(cap));
            let wave = pool.run(queue);
            let failed = wave.iter().any(|r| r.is_err());
            results.extend(wave);
            if failed {
                break; // fail fast: don't train the remaining waves
            }
            queue = rest;
        }
        results.into_iter().collect()
    }

    /// The sequential reference loop the differential tests compare
    /// against. One behavioral difference from `run_on`: this stops at
    /// the first trial error instead of completing the batch (the
    /// returned value is identical either way).
    pub fn run_serial(&self, engine: &Engine) -> anyhow::Result<Vec<SweepPoint>> {
        let mut out = Vec::new();
        for t in self.trials() {
            out.push(run_trial(engine, t)?);
        }
        Ok(out)
    }
}

/// Train one grid point to completion. Divergence (non-finite loss, or
/// a training error after construction) lands in the `ppl = inf` slot
/// rather than failing the sweep, exactly like the serial loop always
/// did; construction errors (unknown optimizer/size) still propagate.
fn run_trial(engine: &Engine, opts: TrainOptions) -> anyhow::Result<SweepPoint> {
    let (optimizer, lr, seed) = (opts.optimizer.clone(), opts.base_lr, opts.seed);
    let mut tr = Trainer::new(engine, opts)?;
    let ppl = match tr.train() {
        Ok(p) if p.is_finite() => p,
        _ => f64::INFINITY,
    };
    let ema = match tr.metrics.ema_loss {
        Some(e) if e.is_finite() => e,
        _ => f64::INFINITY,
    };
    Ok(SweepPoint {
        optimizer,
        lr,
        seed,
        ppl,
        final_loss_ema: ema,
        diverged: !ppl.is_finite() || ppl > 1e6,
    })
}

/// Train `base` once per learning rate (concurrently, on the shared
/// pool); returns one point per LR, in grid order.
pub fn lr_sweep(
    engine: &Engine,
    base: &TrainOptions,
    lrs: &[f64],
) -> anyhow::Result<Vec<SweepPoint>> {
    SweepSpec::lr_grid(base.clone(), lrs).run(engine)
}

/// The paper's App. C learning-rate grid.
pub fn paper_lr_grid() -> Vec<f64> {
    vec![5e-5, 1e-4, 3e-4, 5e-4, 1e-3, 3e-3, 5e-3]
}

/// `null` for non-finite values — JSON has no infinity; `diverged`
/// carries the flag in the report.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::num(x)
    } else {
        Json::Null
    }
}

/// Seeds are u64 and f64 is exact only below 2^53, so bigger seeds are
/// emitted as decimal strings — re-running a reported seed must
/// reproduce the trial that produced the numbers.
fn json_seed(seed: u64) -> Json {
    if seed < (1u64 << 53) {
        Json::num(seed as f64)
    } else {
        Json::str(&seed.to_string())
    }
}

/// Machine-readable sweep report (`scale sweep --json`).
pub fn report_json(spec: &SweepSpec, points: &[SweepPoint]) -> Json {
    let pts: Vec<Json> = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("optimizer", Json::str(&p.optimizer)),
                ("lr", num_or_null(p.lr)),
                ("seed", json_seed(p.seed)),
                ("ppl", num_or_null(p.ppl)),
                ("final_loss_ema", num_or_null(p.final_loss_ema)),
                ("diverged", Json::Bool(p.diverged)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("report", Json::str("sweep")),
        ("size", Json::str(&spec.base.size)),
        ("steps", Json::num(spec.base.steps as f64)),
        ("shards", Json::num(spec.base.shards.max(1) as f64)),
        ("trials", Json::num(points.len() as f64)),
        ("points", Json::Arr(pts)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_is_sorted_positive() {
        let g = paper_lr_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!(g.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn trial_order_is_optimizer_major_then_lr_then_seed() {
        let mut spec = SweepSpec::new(TrainOptions::default());
        spec.optimizers = vec!["scale".into(), "adam".into()];
        spec.lrs = vec![1e-3, 1e-2];
        spec.seeds = vec![0, 7];
        let ts = spec.trials();
        assert_eq!(ts.len(), 8);
        let key: Vec<(&str, f64, u64)> = ts
            .iter()
            .map(|t| (t.optimizer.as_str(), t.base_lr, t.seed))
            .collect();
        assert_eq!(key[0], ("scale", 1e-3, 0));
        assert_eq!(key[1], ("scale", 1e-3, 7));
        assert_eq!(key[2], ("scale", 1e-2, 0));
        assert_eq!(key[4], ("adam", 1e-3, 0));
        assert_eq!(key[7], ("adam", 1e-2, 7));
        assert!(ts.iter().all(|t| t.quiet && t.schedule.is_none()));
    }

    #[test]
    fn lr_for_resolves_per_optimizer_when_lr_axis_is_empty() {
        fn table_lr(opt: &str) -> f64 {
            if opt == "adam" { 2e-3 } else { 1e-2 }
        }
        let mut spec = SweepSpec::new(TrainOptions::default());
        spec.optimizers = vec!["scale".into(), "adam".into()];
        spec.lr_for = Some(table_lr);
        let ts = spec.trials();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].base_lr, 1e-2);
        assert_eq!(ts[1].base_lr, 2e-3);
        // an explicit LR axis wins over the resolver
        spec.lrs = vec![5e-4];
        let ts = spec.trials();
        assert!(ts.iter().all(|t| t.base_lr == 5e-4));
    }

    #[test]
    fn empty_axes_default_to_the_base_point() {
        let base = TrainOptions {
            optimizer: "muon".into(),
            base_lr: 0.5,
            seed: 9,
            ..TrainOptions::default()
        };
        let ts = SweepSpec::new(base).trials();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].optimizer, "muon");
        assert_eq!(ts[0].base_lr, 0.5);
        assert_eq!(ts[0].seed, 9);
    }

    #[test]
    fn report_json_guards_nonfinite_and_big_seeds() {
        let spec = SweepSpec::new(TrainOptions::default());
        let pts = vec![
            SweepPoint {
                optimizer: "scale".into(),
                lr: f64::INFINITY,
                seed: 0,
                ppl: f64::INFINITY,
                final_loss_ema: f64::INFINITY,
                diverged: true,
            },
            SweepPoint {
                optimizer: "adam".into(),
                lr: 1e-2,
                seed: 1 << 60,
                ppl: 2.0,
                final_loss_ema: 0.7,
                diverged: false,
            },
        ];
        let text = report_json(&spec, &pts).to_string();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("trials").unwrap().as_usize(), Some(2));
        let arr = back.get("points").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        // JSON has no infinity: non-finite lr/ppl/ema all become null
        assert_eq!(arr[0].get("lr").unwrap(), &Json::Null);
        assert_eq!(arr[0].get("ppl").unwrap(), &Json::Null);
        assert_eq!(arr[0].get("diverged").unwrap().as_bool(), Some(true));
        // seeds above 2^53 keep full precision as decimal strings
        assert_eq!(
            arr[1].get("seed").unwrap().as_str(),
            Some("1152921504606846976")
        );
        assert_eq!(arr[1].get("ppl").unwrap().as_f64(), Some(2.0));
    }
}
