//! Simulated data-parallel gradient reduction.
//!
//! The paper's 7B runs use 8-16 GPU DDP; here the coordinator shards the
//! global batch into `n` microbatch gradients and combines them with a
//! binary-tree all-reduce — the same reduction topology a ring/tree
//! collective implements, executed deterministically on host tensors.
//! Determinism matters: pairwise tree addition gives the *same* float
//! rounding every run (unlike a data-race reduction), which is what makes
//! the DDP(1-shard, accumulated) == DDP(n-shard) integration test exact
//! up to associativity-reordering tolerance.

use crate::runtime::Tensor;

/// Mean-reduce `shards[k][p]` over k (shards) for every parameter p,
/// using pairwise tree combination. Consumes the shard gradients.
pub fn tree_all_reduce(mut shards: Vec<Vec<Tensor>>) -> Vec<Tensor> {
    assert!(!shards.is_empty());
    let n = shards.len();
    // tree rounds: combine stride-separated partners
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (left, right) = shards.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                d.add_assign(s);
            }
            i += 2 * stride;
        }
        // drop the consumed partners' storage eagerly
        stride *= 2;
    }
    let mut out = shards.swap_remove(0);
    let inv = 1.0 / n as f32;
    for t in out.iter_mut() {
        t.scale(inv);
    }
    out
}

/// Sequential baseline (reference semantics for tests).
pub fn sequential_mean(shards: &[Vec<Tensor>]) -> Vec<Tensor> {
    let n = shards.len();
    let mut out = shards[0].clone();
    for s in &shards[1..] {
        for (d, x) in out.iter_mut().zip(s.iter()) {
            d.add_assign(x);
        }
    }
    let inv = 1.0 / n as f32;
    for t in out.iter_mut() {
        t.scale(inv);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn shard(rng: &mut crate::util::rng::Pcg, shapes: &[Vec<usize>]) -> Vec<Tensor> {
        shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                Tensor::from_f32(s, (0..n).map(|_| rng.normal() as f32).collect())
            })
            .collect()
    }

    #[test]
    fn matches_sequential_mean() {
        prop::check("tree-allreduce-mean", 32, |rng| {
            let k = prop::usize_in(rng, 1, 9);
            let shapes = vec![vec![3, 4], vec![7], vec![2, 2, 2]];
            let shards: Vec<Vec<Tensor>> = (0..k).map(|_| shard(rng, &shapes)).collect();
            let want = sequential_mean(&shards);
            let got = tree_all_reduce(shards);
            for (w, g) in want.iter().zip(&got) {
                prop::slices_close(g.f32s(), w.f32s(), 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_shard_is_identity() {
        let t = vec![Tensor::from_f32(&[2], vec![1.0, -2.0])];
        let out = tree_all_reduce(vec![t.clone()]);
        assert_eq!(out[0].f32s(), t[0].f32s());
    }

    #[test]
    fn constant_shards_average_to_constant() {
        let mk = |v: f32| vec![Tensor::from_f32(&[3], vec![v; 3])];
        let out = tree_all_reduce(vec![mk(1.0), mk(2.0), mk(3.0), mk(6.0)]);
        assert_eq!(out[0].f32s(), &[3.0, 3.0, 3.0]);
    }

    #[test]
    fn deterministic() {
        let mut rng = crate::util::rng::Pcg::new(4);
        let shapes = vec![vec![5, 5]];
        let shards: Vec<Vec<Tensor>> = (0..7).map(|_| shard(&mut rng, &shapes)).collect();
        let a = tree_all_reduce(shards.clone());
        let b = tree_all_reduce(shards);
        assert_eq!(a[0].f32s(), b[0].f32s());
    }
}
