//! Simulated data-parallel gradient reduction.
//!
//! The paper's 7B runs use 8-16 GPU DDP; here the coordinator shards the
//! global batch into `n` microbatch gradients and combines them with a
//! binary-tree all-reduce — the same reduction topology a ring/tree
//! collective implements, executed deterministically on host tensors.
//! Determinism matters: pairwise tree addition gives the *same* float
//! rounding every run (unlike a data-race reduction), which is what makes
//! the DDP(1-shard, accumulated) == DDP(n-shard) integration test exact
//! up to associativity-reordering tolerance.
//!
//! Parallelism: per *parameter*, not per tree round. Each parameter's
//! shard column is an independent reduction, so columns are distributed
//! over the persistent [`WorkerPool`] (large tensors dominate, so columns
//! are interleaved round-robin to balance) — no threads are spawned on
//! the step path. Within a column the pairwise tree order is exactly the
//! sequential order — results are bit-identical to the single-threaded
//! reduction regardless of pool size or scheduling, which the
//! determinism tests below pin down.
//!
//! The multi-process mesh reuses this exact reduction —
//! [`crate::mesh::reduce_ranks_into`] is a named delegation to
//! [`tree_all_reduce_into`] — so gradients gathered from worker
//! *processes* combine with the same pairwise order as in-process
//! shards, and cross-process training inherits the bit-determinism
//! pinned here by construction.

use crate::parallel::{self, WorkerPool};
use crate::runtime::Tensor;

/// Reduce one parameter's shard column in place with pairwise tree
/// combination; the mean lands in `*col[0]`.
fn tree_reduce_column(col: &mut [&mut Tensor]) {
    let n = col.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (left, right) = col.split_at_mut(i + stride);
            left[i].add_assign(&*right[0]);
            i += 2 * stride;
        }
        stride *= 2;
    }
    col[0].scale(1.0 / n as f32);
}

/// Mean-reduce `shards[k][p]` over k (shards) for every parameter p,
/// using pairwise tree combination. Consumes the shard gradients.
/// Large-parameter columns run concurrently on the process-wide shared
/// [`WorkerPool`] ([`parallel::shared`]); nothing is spawned per call.
pub fn tree_all_reduce(shards: Vec<Vec<Tensor>>) -> Vec<Tensor> {
    tree_all_reduce_in(parallel::shared(), shards)
}

/// [`tree_all_reduce`] against an explicit pool — the trainer passes its
/// own handle; tests and benches pass purpose-built pools.
pub fn tree_all_reduce_in(pool: &WorkerPool, mut shards: Vec<Vec<Tensor>>) -> Vec<Tensor> {
    tree_all_reduce_into(pool, &mut shards, 0);
    shards.swap_remove(0)
}

/// Borrowed, in-place form: reduces `shards[k][p]` over k for every
/// `p >= skip`, leaving the mean in `shards[0][p]` and the partial sums
/// the tree wrote into the other shards behind (callers treat those as
/// scratch). `skip` lets the trainer reduce executable outputs whose
/// leading entries are not gradients (the per-shard loss scalar).
///
/// The float semantics are exactly [`tree_all_reduce_in`]'s: per column
/// the pairwise tree order is the sequential order, so results are
/// bit-identical to the single-threaded reduction for every pool size.
/// The parallel-dispatch threshold comes from the calibrated
/// [`parallel::tuned_min_ops`] instead of a hard-coded constant.
pub fn tree_all_reduce_into(pool: &WorkerPool, shards: &mut [Vec<Tensor>], skip: usize) {
    assert!(!shards.is_empty());
    let n_shards = shards.len();
    let n_params = shards[0].len();
    for s in shards.iter() {
        assert_eq!(s.len(), n_params, "ragged shard gradient lists");
    }
    assert!(skip <= n_params, "skip beyond parameter count");
    if n_shards == 1 {
        // a single shard's mean is itself (the tree would scale by 1/1,
        // which is bitwise identity) — skip the traversal entirely
        return;
    }

    // transpose to per-parameter columns of borrows (no tensor moves)
    let n_cols = n_params - skip;
    let mut columns: Vec<Vec<&mut Tensor>> =
        (0..n_cols).map(|_| Vec::with_capacity(n_shards)).collect();
    for shard in shards.iter_mut() {
        for (p, t) in shard.iter_mut().enumerate() {
            if p >= skip {
                columns[p - skip].push(t);
            }
        }
    }

    let thr = parallel::tuned_min_ops();
    let big_elems: usize = columns
        .iter()
        .filter(|c| c[0].numel() >= thr)
        .map(|c| c[0].numel())
        .sum();
    let workers = if big_elems >= thr {
        pool.parallelism().min(n_cols)
    } else {
        1
    };

    if workers > 1 {
        // round-robin interleave so every worker gets a mix of large and
        // small tensors (parameter lists are typically sorted by layer,
        // with the huge embed/head tensors at the ends)
        let mut slots: Vec<Vec<Vec<&mut Tensor>>> = (0..workers).map(|_| Vec::new()).collect();
        for (p, col) in columns.into_iter().enumerate() {
            slots[p % workers].push(col);
        }
        let tasks: Vec<_> = slots
            .into_iter()
            .map(|slot| {
                move || {
                    for mut col in slot {
                        tree_reduce_column(&mut col);
                    }
                }
            })
            .collect();
        pool.run(tasks);
    } else {
        for mut col in columns {
            tree_reduce_column(&mut col);
        }
    }
}

/// Sequential baseline (reference semantics for tests).
pub fn sequential_mean(shards: &[Vec<Tensor>]) -> Vec<Tensor> {
    let n = shards.len();
    let mut out = shards[0].clone();
    for s in &shards[1..] {
        for (d, x) in out.iter_mut().zip(s.iter()) {
            d.add_assign(x);
        }
    }
    let inv = 1.0 / n as f32;
    for t in out.iter_mut() {
        t.scale(inv);
    }
    out
}

/// The original single-threaded tree reduction, kept as the bit-level
/// reference the parallel implementation must reproduce exactly.
pub fn tree_all_reduce_sequential(mut shards: Vec<Vec<Tensor>>) -> Vec<Tensor> {
    assert!(!shards.is_empty());
    let n = shards.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (left, right) = shards.split_at_mut(i + stride);
            let dst = &mut left[i];
            let src = &right[0];
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                d.add_assign(s);
            }
            i += 2 * stride;
        }
        stride *= 2;
    }
    let mut out = shards.swap_remove(0);
    let inv = 1.0 / n as f32;
    for t in out.iter_mut() {
        t.scale(inv);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn shard(rng: &mut crate::util::rng::Pcg, shapes: &[Vec<usize>]) -> Vec<Tensor> {
        shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                Tensor::from_f32(s, (0..n).map(|_| rng.normal() as f32).collect())
            })
            .collect()
    }

    #[test]
    fn matches_sequential_mean() {
        prop::check("tree-allreduce-mean", 32, |rng| {
            let k = prop::usize_in(rng, 1, 9);
            let shapes = vec![vec![3, 4], vec![7], vec![2, 2, 2]];
            let shards: Vec<Vec<Tensor>> = (0..k).map(|_| shard(rng, &shapes)).collect();
            let want = sequential_mean(&shards);
            let got = tree_all_reduce(shards);
            for (w, g) in want.iter().zip(&got) {
                prop::slices_close(g.f32s(), w.f32s(), 1e-5)?;
            }
            Ok(())
        });
    }

    #[test]
    fn parallel_bit_identical_to_sequential_tree() {
        // large tensors force the threaded path; results must match the
        // single-threaded tree reduction bit for bit
        prop::check("tree-allreduce-parallel-bits", 8, |rng| {
            let k = prop::usize_in(rng, 2, 8);
            let shapes = vec![vec![128, 150], vec![33], vec![64, 300], vec![5, 5]];
            let shards: Vec<Vec<Tensor>> = (0..k).map(|_| shard(rng, &shapes)).collect();
            let want = tree_all_reduce_sequential(shards.clone());
            let got = tree_all_reduce(shards);
            for (p, (w, g)) in want.iter().zip(&got).enumerate() {
                prop::ensure(w.f32s() == g.f32s(), format!("param {p} differs"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn single_shard_is_identity() {
        let t = vec![Tensor::from_f32(&[2], vec![1.0, -2.0])];
        let out = tree_all_reduce(vec![t.clone()]);
        assert_eq!(out[0].f32s(), t[0].f32s());
    }

    #[test]
    fn constant_shards_average_to_constant() {
        let mk = |v: f32| vec![Tensor::from_f32(&[3], vec![v; 3])];
        let out = tree_all_reduce(vec![mk(1.0), mk(2.0), mk(3.0), mk(6.0)]);
        assert_eq!(out[0].f32s(), &[3.0, 3.0, 3.0]);
    }

    #[test]
    fn deterministic() {
        let mut rng = crate::util::rng::Pcg::new(4);
        // above the parallel threshold so the threaded path is what's pinned
        let shapes = vec![vec![130, 130]];
        let shards: Vec<Vec<Tensor>> = (0..7).map(|_| shard(&mut rng, &shapes)).collect();
        let a = tree_all_reduce(shards.clone());
        let b = tree_all_reduce(shards);
        assert_eq!(a[0].f32s(), b[0].f32s());
    }

    #[test]
    fn bit_identical_across_pool_sizes() {
        // pool size must never change the float rounding: every pool
        // reduces each column in the same sequential pairwise order
        prop::check("tree-allreduce-pool-sizes", 6, |rng| {
            let k = prop::usize_in(rng, 2, 6);
            let shapes = vec![vec![140, 130], vec![40], vec![64, 280]];
            let shards: Vec<Vec<Tensor>> = (0..k).map(|_| shard(rng, &shapes)).collect();
            let want = tree_all_reduce_sequential(shards.clone());
            for workers in [0usize, 1, 3, 7] {
                let pool = crate::parallel::WorkerPool::new(workers);
                let got = tree_all_reduce_in(&pool, shards.clone());
                for (p, (w, g)) in want.iter().zip(&got).enumerate() {
                    prop::ensure(
                        w.f32s() == g.f32s(),
                        format!("param {p} differs with {workers} workers"),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn into_form_with_skip_matches_sequential_tree() {
        // the trainer's borrowed path: skip=1 leaves the loss slot alone
        // and reduces the rest bit-identically to the owned tree
        prop::check("tree-allreduce-into-skip", 8, |rng| {
            let k = prop::usize_in(rng, 1, 6);
            let shapes = vec![vec![1], vec![40, 30], vec![17]];
            let mut shards: Vec<Vec<Tensor>> = (0..k).map(|_| shard(rng, &shapes)).collect();
            let inner: Vec<Vec<Tensor>> = shards.iter().map(|s| s[1..].to_vec()).collect();
            let want = tree_all_reduce_sequential(inner);
            let keep: Vec<f32> = shards.iter().map(|s| s[0].f32s()[0]).collect();
            let pool = crate::parallel::WorkerPool::new(3);
            tree_all_reduce_into(&pool, &mut shards, 1);
            for (p, w) in want.iter().enumerate() {
                prop::ensure(shards[0][p + 1].f32s() == w.f32s(), format!("param {p}"))?;
            }
            for (s, k0) in shards.iter().zip(&keep) {
                prop::ensure(s[0].f32s()[0] == *k0, "skipped slot modified")?;
            }
            Ok(())
        });
    }

    #[test]
    fn pool_reuse_across_100_reduces_spawns_nothing() {
        let pool = crate::parallel::WorkerPool::new(4);
        let spawned = crate::parallel::threads_spawned_by_current_thread();
        let mut rng = crate::util::rng::Pcg::new(9);
        let shapes = vec![vec![130, 130], vec![17]];
        let shards: Vec<Vec<Tensor>> = (0..4).map(|_| shard(&mut rng, &shapes)).collect();
        let want = tree_all_reduce_sequential(shards.clone());
        for _ in 0..100 {
            let got = tree_all_reduce_in(&pool, shards.clone());
            assert_eq!(got[0].f32s(), want[0].f32s());
            assert_eq!(got[1].f32s(), want[1].f32s());
        }
        assert_eq!(
            crate::parallel::threads_spawned_by_current_thread(),
            spawned,
            "tree_all_reduce_in must not spawn threads per step"
        );
    }
}
